//! Integration suite of the signed model-bundle subsystem (`bundle::*`):
//!
//! 1. pack → inspect → open round-trips the seeded tiny model losslessly;
//! 2. TAMPER: flipping any single byte anywhere in the file makes `open`
//!    fail, and flips inside a payload name the offending entry;
//! 3. a model rebuilt from bundle params produces bit-identical logits to
//!    the seeded original (and carries the `Loaded` origin marker);
//! 4. a fleet warm-started from a bundle is bit-identical to a solo
//!    backend warm-started from the same bundle.

use std::path::PathBuf;
use std::sync::Arc;
use std::time::{Duration, Instant};

use shiftaddvit::bundle::{archive, sign};
use shiftaddvit::coordinator::backend::{create_backend, InferenceBackend};
use shiftaddvit::coordinator::batcher::Request;
use shiftaddvit::coordinator::config::ServerConfig;
use shiftaddvit::coordinator::metrics::Metrics;
use shiftaddvit::data::synth_images;
use shiftaddvit::fleet::router::Router;
use shiftaddvit::infer::model::{ModelParams, NativeModel, NativeModelConfig, WeightsOrigin};
use shiftaddvit::kernels::planner::Planner;
use shiftaddvit::kernels::registry::KernelRegistry;
use shiftaddvit::model::ops::Variant;

const POLL: Duration = Duration::from_secs(120);

fn tmp_path(name: &str) -> PathBuf {
    std::env::temp_dir().join(format!("savit_bundle_it_{}_{name}", std::process::id()))
}

fn fresh_planner() -> Arc<Planner> {
    Arc::new(Planner::new(Arc::new(KernelRegistry::with_defaults())))
}

/// Pack the seeded tiny model (flat params + the construction-time planner
/// table) into a temp bundle under the default key; returns the path and
/// the pack-time digest.
fn packed_seeded_bundle(name: &str) -> (PathBuf, String) {
    let cfg = NativeModelConfig::tiny(Variant::SHIFTADD_MOE);
    let model_name = cfg.spec.name;
    let params = ModelParams::seeded(&cfg).to_flat(&cfg);
    let planner = fresh_planner();
    let _probe = NativeModel::from_params(cfg, Arc::clone(&planner), &params).unwrap();
    let table = planner.to_table_json();
    let path = tmp_path(name);
    let digest = archive::pack(
        &path,
        model_name,
        &params,
        &table,
        true,
        sign::DEFAULT_KEY.as_bytes(),
    )
    .unwrap();
    (path, digest)
}

// ---------------------------------------------------------------------------
// 1. Round trip
// ---------------------------------------------------------------------------

#[test]
fn pack_inspect_open_round_trips() {
    let (path, digest) = packed_seeded_bundle("roundtrip.sabundle");

    let info = archive::inspect(&path).unwrap();
    assert_eq!(info.digest, digest);
    assert!(info.untrained);
    let names: Vec<&str> = info.entries.iter().map(|e| e.name.as_str()).collect();
    assert_eq!(names, ["params.sap", "planner_table.json"]);

    let b = archive::open(&path, sign::DEFAULT_KEY.as_bytes()).unwrap();
    std::fs::remove_file(&path).ok();
    assert_eq!(b.digest, digest);
    let cfg = NativeModelConfig::tiny(Variant::SHIFTADD_MOE);
    assert_eq!(b.model, cfg.spec.name);
    assert!(b.untrained);
    assert!(!b.cpu_features.is_empty());
    assert_eq!(b.params, ModelParams::seeded(&cfg).to_flat(&cfg));
    assert!(b.table.get("choices").is_some(), "planner table rides along");
}

// ---------------------------------------------------------------------------
// 2. Tamper detection, byte by byte
// ---------------------------------------------------------------------------

#[test]
fn every_flipped_byte_is_rejected_and_payload_flips_name_the_entry() {
    let (path, _) = packed_seeded_bundle("tamper.sabundle");
    let info = archive::inspect(&path).unwrap();
    let params_len = info.entries[0].len;
    let clean = std::fs::read(&path).unwrap();
    std::fs::remove_file(&path).ok();

    // On-disk layout: 8B magic + 4B manifest_len + 4B sig_len + 32B sig,
    // then the manifest, then payloads in entry order.
    let manifest_len = u32::from_le_bytes([clean[8], clean[9], clean[10], clean[11]]) as usize;
    let payload_start = 48 + manifest_len;
    assert_eq!(payload_start + params_len + info.entries[1].len, clean.len());

    let key = sign::DEFAULT_KEY.as_bytes();
    let step = (clean.len() / 61).max(1);
    let mut positions: Vec<usize> = (0..clean.len()).step_by(step).collect();
    positions.push(clean.len() - 1);
    let flipped = tmp_path("tamper_flip.sabundle");
    for pos in positions {
        let mut bytes = clean.clone();
        bytes[pos] ^= 0x01;
        std::fs::write(&flipped, &bytes).unwrap();
        let err = match archive::open(&flipped, key) {
            Ok(_) => panic!("flip at byte {pos} verified anyway"),
            Err(e) => format!("{e:#}"),
        };
        if pos >= payload_start {
            let entry = if pos < payload_start + params_len {
                "params.sap"
            } else {
                "planner_table.json"
            };
            assert!(
                err.contains(entry),
                "flip at byte {pos} blamed the wrong entry: {err}"
            );
        }
    }
    std::fs::remove_file(&flipped).ok();
}

// ---------------------------------------------------------------------------
// 3. Bit-identical logits through the export → pack → open → load chain
// ---------------------------------------------------------------------------

#[test]
fn bundle_params_rebuild_bit_identical_logits() {
    let (path, _) = packed_seeded_bundle("logits.sabundle");
    let b = archive::open(&path, sign::DEFAULT_KEY.as_bytes()).unwrap();
    std::fs::remove_file(&path).ok();

    let seeded = NativeModel::tiny(Variant::SHIFTADD_MOE);
    assert_eq!(seeded.origin, WeightsOrigin::SeededUntrained);
    let cfg = NativeModelConfig::tiny(Variant::SHIFTADD_MOE);
    let loaded = NativeModel::from_params(cfg, fresh_planner(), &b.params).unwrap();
    assert_eq!(loaded.origin, WeightsOrigin::Loaded);

    let (xs, _) = synth_images::gen_batch(17, 2);
    let (want, _) = seeded.forward(&xs, 2);
    let (got, _) = loaded.forward(&xs, 2);
    assert_eq!(want, got, "bundle round-trip must be bit-identical");
}

// ---------------------------------------------------------------------------
// 4. Fleet-from-bundle ≡ solo-from-bundle
// ---------------------------------------------------------------------------

fn bundle_request(id: usize) -> Request {
    let s = synth_images::gen_image(70_000 + id as u32);
    Request {
        id,
        pixels: s.pixels,
        label: Some(s.label),
        arrived: Instant::now(),
        trace: shiftaddvit::obs::trace::TraceCtx::NONE,
    }
}

#[test]
fn fleet_from_bundle_matches_solo_from_bundle() {
    let (path, digest) = packed_seeded_bundle("fleet.sabundle");
    // max_batch 1: per-tensor INT8 calibration spans a batch, so bitwise
    // comparison needs identical batch composition on both sides.
    let cfg = ServerConfig {
        bundle: Some(path.to_string_lossy().into_owned()),
        workers: 2,
        max_batch: 1,
        ..ServerConfig::default()
    };

    let n = 4;
    let solo = create_backend(&cfg).unwrap();
    let mut m = Metrics::default();
    let mut want = Vec::with_capacity(n);
    for i in 0..n {
        let t = solo.submit(bundle_request(i));
        solo.step(1, &mut m).unwrap();
        want.push(solo.poll(&t).expect("solo step completed").logits);
    }

    let mut router = Router::from_server_config(&cfg).unwrap();
    assert_eq!(router.bundle_digest(), Some(digest.as_str()));
    let tickets: Vec<_> = (0..n)
        .map(|i| router.submit(bundle_request(i)).unwrap())
        .collect();
    for (i, t) in tickets.iter().enumerate() {
        let out = router.poll_wait(t, POLL).unwrap();
        assert_eq!(
            out.logits, want[i],
            "request {i}: fleet-from-bundle diverged from solo-from-bundle"
        );
    }
    router.shutdown().unwrap();
    std::fs::remove_file(&path).ok();
}
