//! Property + end-to-end suite for the observability subsystem (PR 10):
//!
//! 1. histogram record/merge agree with exact percentiles within the
//!    documented bucket error, across several sample distributions;
//! 2. span trees are well-formed — every recorded parent id is live in the
//!    ring and child intervals nest strictly inside their parents;
//! 3. the Chrome trace export round-trips through `util::json`;
//! 4. `GET /metrics.prom` (and `/metrics?format=prometheus`) over a real
//!    socket passes the exposition lint;
//! 5. one `POST /classify` over a real socket yields a **connected span
//!    tree** — ingress → placement → worker inbox → backend step → at
//!    least one kernel-dispatch span — verified by walking parent ids.
//!
//! The span recorder is process-global, so every test that toggles it
//! serializes on [`recorder_lock`].

use std::sync::{Arc, Mutex, OnceLock};
use std::time::Duration;

use shiftaddvit::coordinator::backend::{InferenceBackend, NativeBackend};
use shiftaddvit::coordinator::metrics::Metrics;
use shiftaddvit::fleet::http::{FrontDoorConfig, HttpFrontDoor};
use shiftaddvit::fleet::policy::PolicyKind;
use shiftaddvit::fleet::worker::BackendFactory;
use shiftaddvit::fleet::{Router, RouterConfig};
use shiftaddvit::model::ops::Variant;
use shiftaddvit::obs::hist::Hist;
use shiftaddvit::obs::trace::{self as otrace, SpanEvent};
use shiftaddvit::obs::prom;
use shiftaddvit::util::httpd;
use shiftaddvit::util::json::Json;
use shiftaddvit::util::rng::XorShift64;
use shiftaddvit::util::stats;

const CLIENT_TIMEOUT: Duration = Duration::from_secs(120);

fn recorder_lock() -> &'static Mutex<()> {
    static LOCK: OnceLock<Mutex<()>> = OnceLock::new();
    LOCK.get_or_init(|| Mutex::new(()))
}

// ---------------------------------------------------------------------------
// 1. Histogram accuracy properties
// ---------------------------------------------------------------------------

/// Record `samples` into one histogram and into 4 shards merged back
/// together; assert both agree with each other exactly and with the exact
/// percentiles within the documented ≤19% bucket error (0.20 in tests).
fn check_hist_accuracy(name: &str, samples: &[f64]) {
    let mut solo = Hist::new();
    let mut shards = vec![Hist::new(), Hist::new(), Hist::new(), Hist::new()];
    for (i, &v) in samples.iter().enumerate() {
        solo.record(v);
        shards[i % 4].record(v);
    }
    let mut merged = Hist::new();
    for s in &shards {
        merged.merge(s);
    }
    assert_eq!(solo.count(), merged.count(), "{name}: merge loses samples");
    assert_eq!(solo.sum(), merged.sum(), "{name}: merge changes the sum");
    let mut sorted = samples.to_vec();
    sorted.sort_by(|a, b| a.total_cmp(b));
    for q in [0.1, 0.5, 0.9, 0.95, 0.99] {
        let exact = stats::percentile(&sorted, q);
        for (which, h) in [("solo", &solo), ("merged", &merged)] {
            let approx = h.percentile(q);
            assert_eq!(
                solo.percentile(q),
                merged.percentile(q),
                "{name} q={q}: merged percentile must equal solo exactly"
            );
            if exact > 0.0 {
                let rel = (approx - exact).abs() / exact;
                assert!(
                    rel <= 0.20,
                    "{name} {which} q={q}: approx {approx} vs exact {exact} (rel {rel:.3})"
                );
            }
        }
    }
    // exact moments survive bucketing
    let mean_exact = samples.iter().sum::<f64>() / samples.len() as f64;
    assert!((solo.mean() - mean_exact).abs() < 1e-9 * mean_exact.abs().max(1.0));
    assert_eq!(solo.max(), sorted.last().copied().unwrap());
    assert_eq!(solo.min(), sorted.first().copied().unwrap());
}

#[test]
fn hist_tracks_exact_percentiles_across_distributions() {
    let mut rng = XorShift64::new(0x0B5E);
    // uniform-ish latencies around 1ms
    let uniform: Vec<f64> = (0..5000).map(|_| 0.1 + 2.0 * rng.uniform() as f64).collect();
    check_hist_accuracy("uniform", &uniform);
    // heavy-tailed: most requests fast, stragglers 1000x slower
    let tailed: Vec<f64> = (0..5000)
        .map(|i| {
            let base = 0.2 + rng.uniform() as f64;
            if i % 100 == 0 {
                base * 1000.0
            } else {
                base
            }
        })
        .collect();
    check_hist_accuracy("heavy-tail", &tailed);
    // geometric sweep spanning many octaves
    let sweep: Vec<f64> = (0..2000).map(|i| (i as f64 * 0.01).exp()).collect();
    check_hist_accuracy("geometric", &sweep);
}

#[test]
fn metrics_fleet_merge_equals_solo_percentiles() {
    // Regression for the fleet-merge percentile bias (satellite b): with
    // histogram merging, N workers' merged report is exactly the solo
    // report over the union of the traffic, including tail percentiles.
    let samples: Vec<f64> = (0..20_000).map(|i| ((i * 61) % 1237) as f64 * 0.05 + 0.1).collect();
    let mut solo = Metrics::default();
    let mut workers = vec![Metrics::default(), Metrics::default(), Metrics::default()];
    for (i, &v) in samples.iter().enumerate() {
        solo.record("http_classify", v);
        solo.decode_tokens.record((i % 32) as f64);
        let w = &mut workers[i % 3];
        w.record("http_classify", v);
        w.decode_tokens.record((i % 32) as f64);
    }
    let mut merged = Metrics::default();
    for w in &workers {
        merged.merge(w);
    }
    let s = solo.stage_summary("http_classify").unwrap();
    let m = merged.stage_summary("http_classify").unwrap();
    assert_eq!(s.n, m.n);
    assert_eq!(s.mean, m.mean);
    assert_eq!(s.p50, m.p50);
    assert_eq!(s.p95, m.p95);
    assert_eq!(s.p99, m.p99);
    assert_eq!(solo.decode_tokens.percentile(0.99), merged.decode_tokens.percentile(0.99));
}

// ---------------------------------------------------------------------------
// 2 + 3. Span-tree shape and Chrome export round-trip
// ---------------------------------------------------------------------------

/// Walk every recorded span: non-zero parents must exist in the snapshot
/// (live parents), and a child's interval must nest inside its parent's.
fn assert_well_formed(events: &[SpanEvent]) {
    let by_id: std::collections::BTreeMap<u64, &SpanEvent> =
        events.iter().map(|e| (e.id, e)).collect();
    for e in events {
        if e.parent == 0 {
            continue;
        }
        let p = by_id
            .get(&e.parent)
            .unwrap_or_else(|| panic!("span {} '{}' has dead parent {}", e.id, e.name, e.parent));
        assert_eq!(e.trace, p.trace, "child and parent share a trace id");
        // strict nesting: the child opened after and closed before its
        // parent (parents drop last, so their duration covers children)
        assert!(
            e.start_us >= p.start_us - 1.0,
            "span '{}' starts before its parent '{}'",
            e.name,
            p.name
        );
        assert!(
            e.start_us + e.dur_us <= p.start_us + p.dur_us + 1.0,
            "span '{}' outlives its parent '{}'",
            e.name,
            p.name
        );
    }
}

#[test]
fn span_trees_are_well_formed_and_bounded() {
    let _l = recorder_lock().lock().unwrap();
    otrace::set_enabled(true);
    otrace::reset();
    // three levels, several siblings, on one thread
    {
        let r = otrace::root("request");
        for _ in 0..3 {
            let s = otrace::span("step", r.ctx());
            let _g = otrace::set_current(s.ctx());
            for _ in 0..2 {
                let _k = otrace::span("matadd/simd", otrace::current());
            }
        }
    }
    otrace::set_enabled(false);
    let events = otrace::events();
    otrace::reset();
    assert_eq!(events.len(), 1 + 3 + 6);
    assert_well_formed(&events);
    let roots: Vec<_> = events.iter().filter(|e| e.parent == 0).collect();
    assert_eq!(roots.len(), 1);
    assert_eq!(roots[0].name, "request");
    assert!(events.iter().all(|e| e.trace == roots[0].trace));
}

#[test]
fn chrome_export_round_trips_through_util_json() {
    let _l = recorder_lock().lock().unwrap();
    otrace::set_enabled(true);
    otrace::reset();
    {
        let mut r = otrace::root("req");
        r.arg("id", "7");
        let _c = otrace::span("work", r.ctx());
    }
    otrace::set_enabled(false);
    let text = otrace::export_chrome().to_string();
    otrace::reset();

    let v = Json::parse(&text).expect("chrome export parses back");
    assert_eq!(v.get("displayTimeUnit").and_then(|d| d.as_str()), Some("ms"));
    let evs = v.get("traceEvents").and_then(|e| e.as_arr()).unwrap();
    assert_eq!(evs.len(), 2);
    for e in evs {
        assert_eq!(e.get("ph").unwrap().as_str(), Some("X"));
        assert_eq!(e.get("pid").unwrap().as_usize(), Some(1));
        assert!(e.get("ts").unwrap().as_f64().is_some());
        assert!(e.get("dur").unwrap().as_f64().is_some());
        let args = e.get("args").unwrap();
        assert!(args.get("span_id").unwrap().as_f64().is_some());
        assert!(args.get("parent_id").unwrap().as_f64().is_some());
        assert!(args.get("trace_id").unwrap().as_f64().is_some());
    }
    // re-serialize: identical bytes (shortest-roundtrip numbers)
    assert_eq!(v.to_string(), Json::parse(&text).unwrap().to_string());
}

// ---------------------------------------------------------------------------
// 4 + 5. Socket-path: Prometheus exposition + connected span tree
// ---------------------------------------------------------------------------

fn factory() -> BackendFactory {
    Arc::new(|| {
        let b: Box<dyn InferenceBackend> = Box::new(NativeBackend::tiny(Variant::SHIFTADD_MOE));
        Ok(b)
    })
}

fn fleet(workers: usize) -> Router {
    Router::new(
        RouterConfig {
            workers,
            max_batch: 4,
            policy: PolicyKind::RoundRobin,
            step_delay_ms: 0.0,
            ..RouterConfig::default()
        },
        factory(),
    )
    .expect("fleet starts")
}

fn door_cfg() -> FrontDoorConfig {
    FrontDoorConfig {
        handlers: 4,
        request_timeout: CLIENT_TIMEOUT,
        io_timeout: Duration::from_secs(60),
        ..FrontDoorConfig::default()
    }
}

fn get(addr: std::net::SocketAddr, path: &str) -> httpd::HttpResponse {
    httpd::request(addr, "GET", path, None, CLIENT_TIMEOUT).expect("GET")
}

#[test]
fn metrics_prom_over_socket_passes_exposition_lint() {
    let _l = recorder_lock().lock().unwrap();
    otrace::set_enabled(false);
    let door = HttpFrontDoor::start(fleet(2), None, "127.0.0.1:0", door_cfg()).unwrap();
    let addr = door.addr();

    // drive one request through so histogram families are populated
    let sample = shiftaddvit::data::synth_images::gen_image(31_337);
    let body = Json::obj(vec![(
        "pixels",
        Json::Arr(sample.pixels.iter().map(|&p| Json::Num(p as f64)).collect()),
    )])
    .to_string();
    let resp = httpd::request(addr, "POST", "/classify", Some(body.as_bytes()), CLIENT_TIMEOUT)
        .expect("POST /classify");
    assert_eq!(resp.status, 200, "body: {}", resp.text().unwrap_or(""));

    for path in ["/metrics.prom", "/metrics?format=prometheus"] {
        let resp = get(addr, path);
        assert_eq!(resp.status, 200, "{path}");
        assert!(
            resp.header("content-type")
                .is_some_and(|ct| ct.starts_with("text/plain")),
            "{path}: exposition is text/plain"
        );
        let text = resp.text().expect("exposition is UTF-8");
        prom::lint(text).unwrap_or_else(|e| panic!("{path} fails lint: {e}"));
        assert!(text.contains("# TYPE shiftaddvit_requests_total counter"));
        assert!(
            text.contains("shiftaddvit_stage_duration_ms_bucket"),
            "{path}: histogram families present after traffic"
        );
        assert!(text.contains("le=\"+Inf\""));
    }
    // the JSON shape is still served at the bare path
    let j = Json::parse(get(addr, "/metrics").text().unwrap()).unwrap();
    assert!(j.get("engine").is_some());
    assert!(j.get("front_door").is_some());
    door.shutdown().unwrap();
}

#[test]
fn classify_over_socket_yields_a_connected_span_tree() {
    let _l = recorder_lock().lock().unwrap();
    let door = HttpFrontDoor::start(fleet(1), None, "127.0.0.1:0", door_cfg()).unwrap();
    let addr = door.addr();
    // enable AFTER fleet warmup so the ring holds only this request's tree
    otrace::set_enabled(true);
    otrace::reset();

    let sample = shiftaddvit::data::synth_images::gen_image(77_001);
    let body = Json::obj(vec![(
        "pixels",
        Json::Arr(sample.pixels.iter().map(|&p| Json::Num(p as f64)).collect()),
    )])
    .to_string();
    let resp = httpd::request(addr, "POST", "/classify", Some(body.as_bytes()), CLIENT_TIMEOUT)
        .expect("POST /classify");
    assert_eq!(resp.status, 200, "body: {}", resp.text().unwrap_or(""));
    let id = Json::parse(resp.text().unwrap())
        .unwrap()
        .get("id")
        .and_then(|v| v.as_usize())
        .expect("response carries the request id");

    // The ingress span records when the handler drops it, which can land
    // just after the client sees the response: poll briefly for the root.
    let mut events = Vec::new();
    for _ in 0..200 {
        events = otrace::events();
        if events.iter().any(|e| e.name == "http_classify") {
            break;
        }
        std::thread::sleep(Duration::from_millis(10));
    }
    otrace::set_enabled(false);
    door.shutdown().unwrap();
    otrace::reset();

    assert_well_formed(&events);
    let root = events
        .iter()
        .find(|e| e.name == "http_classify")
        .expect("ingress root span recorded");
    assert_eq!(root.parent, 0, "ingress is a trace root");
    assert!(
        root.args.iter().any(|(k, v)| k == "id" && *v == id.to_string()),
        "root span tagged with the request id"
    );
    let in_trace: Vec<&SpanEvent> = events.iter().filter(|e| e.trace == root.trace).collect();

    // Every layer of the request path shows up inside THIS trace, each
    // reachable from the root by walking parent ids.
    let find = |name: &str| {
        in_trace
            .iter()
            .find(|e| e.name == name)
            .copied()
            .unwrap_or_else(|| panic!("no '{name}' span in the request's trace"))
    };
    let by_id: std::collections::BTreeMap<u64, &SpanEvent> =
        in_trace.iter().map(|e| (e.id, *e)).collect();
    let reaches_root = |mut e: &SpanEvent| {
        for _ in 0..64 {
            if e.id == root.id {
                return true;
            }
            match by_id.get(&e.parent) {
                Some(p) => e = *p,
                None => return false,
            }
        }
        false
    };
    let place = find("place");
    let inbox = find("worker_inbox");
    let step = find("backend_step");
    assert_eq!(place.parent, root.id, "placement parents on ingress");
    assert_eq!(inbox.parent, root.id, "worker inbox parents on ingress");
    assert!(reaches_root(step), "backend step links back to ingress");
    assert!(
        step.args
            .iter()
            .any(|(k, v)| k == "request_ids" && v.split(',').any(|s| s == id.to_string())),
        "backend step served this request"
    );
    let kernels: Vec<&&SpanEvent> = in_trace
        .iter()
        .filter(|e| e.name.contains('/') && e.parent == step.id)
        .collect();
    assert!(
        !kernels.is_empty(),
        "at least one kernel-dispatch span (primitive/backend) under the step"
    );
    assert!(kernels.iter().all(|k| reaches_root(k)));
}
