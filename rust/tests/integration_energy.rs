//! Energy/area model integration: paper-shape assertions across the whole
//! model zoo (the qualitative claims of Tables 3/13 and Fig. 3 must hold).

use shiftaddvit::energy::area::AreaModel;
use shiftaddvit::energy::eyeriss::{energy, Hierarchy};
use shiftaddvit::model::config::{classifier, gnt, lra, nerf};
use shiftaddvit::model::ops::{count, Variant};

const MODELS: [&str; 5] = ["pvtv2_b0", "pvtv1_t", "pvtv2_b1", "pvtv2_b2", "deit_t"];

/// Table 3 shape: ShiftAddViT saves energy on every model.
#[test]
fn energy_savings_hold_across_zoo() {
    let h = Hierarchy::default();
    for m in MODELS {
        let spec = classifier(m);
        let base = energy(&count(&spec, Variant::ADD), &h).total_mj(); // Ecoformer-like
        let ours = energy(&count(&spec, Variant::SHIFTADD_MOE), &h).total_mj();
        let saving = 1.0 - ours / base;
        assert!(
            saving > 0.05 && saving < 0.9,
            "{m}: saving {saving} out of band"
        );
    }
}

/// Table 13 shape: under equal chip area, each reparameterization step cuts
/// latency, with orderings preserved on both reported models.
#[test]
fn area_latency_ladder() {
    let a = AreaModel::default();
    for m in ["pvtv2_b0", "pvtv2_b1"] {
        let spec = classifier(m);
        let msa = a.latency_ms(&count(&spec, Variant::MSA));
        let add = a.latency_ms(&count(&spec, Variant::ADD));
        let shift = a.latency_ms(&count(&spec, Variant::ADD_SHIFT_BOTH));
        let moe = a.latency_ms(&count(&spec, Variant::SHIFTADD_MOE));
        assert!(msa > add && add > moe && moe > shift, "{m}: {msa} {add} {moe} {shift}");
        // paper's B0 ratios: 60.5/15.87 ≈ 3.8, 15.87/2.77 ≈ 5.7 — check the
        // factors are at least 1.5× at each step.
        assert!(msa / add > 1.5, "{m}");
        assert!(add / shift > 1.5, "{m}");
    }
}

/// Fig. 3 shape: GNT energy reduction ≈ 40.9% for the full ShiftAddViT.
#[test]
fn gnt_energy_reduction_band() {
    let h = Hierarchy::default();
    let base = energy(&count(&gnt(), Variant::MSA), &h).total_mj();
    let ours = energy(&count(&gnt(), Variant::ADD_SHIFT_BOTH), &h).total_mj();
    let saving = 1.0 - ours / base;
    assert!(saving > 0.2 && saving < 0.9, "saving {saving}");
}

/// Table 5 shape: GNT costs more than NeRF (more layers — paper notes this).
#[test]
fn gnt_costs_more_than_nerf() {
    let h = Hierarchy::default();
    let g = energy(&count(&gnt(), Variant::MSA), &h).total_mj();
    let n = energy(&count(&nerf(), Variant::MSA), &h).total_mj();
    assert!(g > n, "GNT {g} vs NeRF {n}");
}

/// Table 11 shape: ShiftAdd-Transformer beats the quadratic Transformer on
/// both latency and energy at every paper sequence length.
#[test]
fn lra_wins_at_all_lengths() {
    let h = Hierarchy::default();
    let a = AreaModel::default();
    let shiftadd = Variant {
        attn: shiftaddvit::model::ops::Attn::LinearAdd,
        attn_linear: shiftaddvit::model::ops::Lin::Shift,
        mlp: shiftaddvit::model::ops::Mlp::Shift,
    };
    for seq in [1024usize, 2048, 4096] {
        let spec = lra(seq);
        let base_ops = count(&spec, Variant::MSA);
        let ours_ops = count(&spec, shiftadd);
        assert!(
            energy(&ours_ops, &h).total_mj() < energy(&base_ops, &h).total_mj(),
            "seq {seq} energy"
        );
        assert!(
            a.latency_ms(&ours_ops) < a.latency_ms(&base_ops),
            "seq {seq} latency"
        );
    }
    // and the advantage grows with sequence length (quadratic vs linear)
    let r1 = {
        let s = lra(1024);
        energy(&count(&s, Variant::MSA), &h).total_mj()
            / energy(&count(&s, shiftadd), &h).total_mj()
    };
    let r4 = {
        let s = lra(4096);
        energy(&count(&s, Variant::MSA), &h).total_mj()
            / energy(&count(&s, shiftadd), &h).total_mj()
    };
    assert!(r4 > r1, "ratio should grow with seq: {r1} vs {r4}");
}
