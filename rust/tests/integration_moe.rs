//! MoE machinery integration: routing + dispatch + balance over realistic
//! gate distributions (no artifacts needed).

use shiftaddvit::moe::balance::{alphas, ideal_split, load_loss, sync_cost};
use shiftaddvit::moe::dispatch::{partition, scatter};
use shiftaddvit::moe::router::{route, softmax, Route};
use shiftaddvit::util::rng::XorShift64;

/// Routing → partition → identity-expert → scatter must reconstruct the
/// gated input exactly.
#[test]
fn dispatch_round_trip_identity() {
    let mut rng = XorShift64::new(1);
    let (tokens, dim) = (100usize, 8usize);
    let feats = rng.normals(tokens * dim);
    let mut gates = Vec::with_capacity(tokens * 2);
    for _ in 0..tokens {
        let mut g = [rng.uniform(), rng.uniform()];
        softmax(&mut g);
        gates.extend_from_slice(&g);
    }
    let routes = route(&gates, 2);
    let parts = partition(&feats, dim, &routes, 2, &[16, 32, 64, 128]);
    let mut out = vec![0.0f32; tokens * dim];
    for p in &parts {
        // identity expert: output = padded input
        scatter(&mut out, dim, p, &p.padded, &routes);
    }
    for t in 0..tokens {
        for d in 0..dim {
            let want = routes[t].gate * feats[t * dim + d];
            let got = out[t * dim + d];
            assert!((got - want).abs() < 1e-6, "tok {t} dim {d}");
        }
    }
}

/// A router biased toward expert 0 must shift the observed load; the
/// latency-aware loss must notice the imbalance relative to expert speeds.
#[test]
fn ll_loss_detects_speed_mismatched_load() {
    let mut rng = XorShift64::new(2);
    let tokens = 1000;
    let mut gates = Vec::new();
    for _ in 0..tokens {
        // 50/50 router
        let mut g = [rng.uniform(), rng.uniform()];
        softmax(&mut g);
        gates.extend_from_slice(&g);
    }
    let routes = route(&gates, 2);
    let counts = [
        routes.iter().filter(|r| r.expert == 0).count(),
        routes.iter().filter(|r| r.expert == 1).count(),
    ];
    // Experts with 3:1 speed difference — a 50/50 split is unbalanced.
    let a = alphas(&[3.0, 1.0]);
    let loss_5050 = load_loss(&counts, &a);
    let ideal = ideal_split(&[3.0, 1.0], tokens);
    let loss_ideal = load_loss(&ideal, &a);
    assert!(loss_5050 > loss_ideal + 0.05, "{loss_5050} vs {loss_ideal}");
    // and the ideal split has a lower makespan
    let (mk_5050, _) = sync_cost(&counts, &[3.0, 1.0]);
    let (mk_ideal, _) = sync_cost(&ideal, &[3.0, 1.0]);
    assert!(mk_ideal < mk_5050);
}

/// Table 7's mechanism end-to-end: moving from an even split toward the
/// latency-proportional split reduces MoE layer makespan monotonically.
#[test]
fn balancing_monotonically_improves_makespan() {
    let per_token = [2.0, 0.5];
    let total = 256usize;
    let ideal = ideal_split(&per_token, total);
    let mut prev = f64::INFINITY;
    for step in 0..=4 {
        // interpolate even → ideal
        let f = step as f64 / 4.0;
        let n0 = ((1.0 - f) * (total as f64 / 2.0) + f * ideal[0] as f64).round() as usize;
        let split = [n0, total - n0];
        let (mk, _) = sync_cost(&split, &per_token);
        assert!(mk <= prev + 1e-9, "step {step}: {mk} > {prev}");
        prev = mk;
    }
}

/// Empty-expert edge: all tokens to one expert still round-trips.
#[test]
fn single_expert_takes_all() {
    let dim = 4;
    let tokens = 10;
    let feats: Vec<f32> = (0..tokens * dim).map(|i| i as f32).collect();
    let routes: Vec<Route> = (0..tokens)
        .map(|_| Route {
            expert: 1,
            gate: 1.0,
        })
        .collect();
    let parts = partition(&feats, dim, &routes, 2, &[16]);
    assert_eq!(parts.len(), 1);
    assert_eq!(parts[0].expert, 1);
    assert_eq!(parts[0].indices.len(), tokens);
}
