//! Property tests on coordinator/MoE/kernel invariants (randomized via the
//! in-repo harness — DESIGN.md §6).

use shiftaddvit::kernels::{matadd, matmul, matshift};
use shiftaddvit::moe::balance::{alphas, ideal_split, sync_cost};
use shiftaddvit::moe::dispatch::{partition, scatter};
use shiftaddvit::moe::router::{route, softmax};
use shiftaddvit::quant::{binary, pow2};
use shiftaddvit::util::prop::{assert_close, check};
use shiftaddvit::util::stats::scv;

/// Every token appears in exactly one partition, regardless of routing.
#[test]
fn prop_partition_is_a_permutation() {
    check("partition-permutation", 50, 64, |rng, size| {
        let tokens = size * 3 + 1;
        let dim = 1 + size % 7;
        let feats = rng.normals(tokens * dim);
        let mut gates = Vec::new();
        for _ in 0..tokens {
            let mut g = [rng.uniform(), rng.uniform()];
            softmax(&mut g);
            gates.extend_from_slice(&g);
        }
        let routes = route(&gates, 2);
        let parts = partition(&feats, dim, &routes, 2, &[8, 32]);
        let mut seen = vec![0usize; tokens];
        for p in &parts {
            if p.indices.len() > p.bucket {
                return Err(format!("bucket overflow {} > {}", p.indices.len(), p.bucket));
            }
            for &i in &p.indices {
                seen[i] += 1;
            }
        }
        if seen.iter().any(|&c| c != 1) {
            return Err("token not covered exactly once".into());
        }
        Ok(())
    });
}

/// Gather→scatter with identity experts reconstructs gate-scaled input.
#[test]
fn prop_dispatch_round_trip() {
    check("dispatch-round-trip", 30, 32, |rng, size| {
        let tokens = size + 1;
        let dim = 4;
        let feats = rng.normals(tokens * dim);
        let mut gates = Vec::new();
        for _ in 0..tokens {
            let mut g = [rng.uniform() + 1e-3, rng.uniform() + 1e-3];
            softmax(&mut g);
            gates.extend_from_slice(&g);
        }
        let routes = route(&gates, 2);
        let parts = partition(&feats, dim, &routes, 2, &[4, 16, 64]);
        let mut out = vec![0.0f32; tokens * dim];
        for p in &parts {
            scatter(&mut out, dim, p, &p.padded, &routes);
        }
        let want: Vec<f32> = (0..tokens * dim)
            .map(|i| routes[i / dim].gate * feats[i])
            .collect();
        assert_close(&out, &want, 1e-5)
    });
}

/// The ideal split always (weakly) beats the even split on makespan and
/// zeroes the α-weighted SCV.
#[test]
fn prop_ideal_split_optimality() {
    check("ideal-split", 40, 20, |rng, size| {
        let l0 = 0.1 + 4.0 * rng.uniform() as f64;
        let l1 = 0.1 + 4.0 * rng.uniform() as f64;
        let total = 50 + size * 10;
        let split = ideal_split(&[l0, l1], total);
        if split.iter().sum::<usize>() != total {
            return Err("split loses tokens".into());
        }
        let (mk_ideal, _) = sync_cost(&split, &[l0, l1]);
        let (mk_even, _) = sync_cost(&[total / 2, total - total / 2], &[l0, l1]);
        if mk_ideal > mk_even * 1.05 + 1e-9 {
            return Err(format!("ideal {mk_ideal} worse than even {mk_even}"));
        }
        // α-weighted loads near-equal at the ideal split
        let a = alphas(&[l0, l1]);
        let w: Vec<f64> = split
            .iter()
            .zip(&a)
            .map(|(&n, al)| n as f64 * al)
            .collect();
        if scv(&w) > 0.05 {
            return Err(format!("scv {} at ideal split", scv(&w)));
        }
        Ok(())
    });
}

/// MatShift ≍ dense matmul of dequantized weights within INT8 error.
#[test]
fn prop_matshift_semantics() {
    check("matshift-semantics", 25, 16, |rng, size| {
        let (m, k, n) = (size + 1, size + 2, size + 3);
        let x = rng.normals(m * k);
        let wf: Vec<f32> = rng.normals(k * n).iter().map(|v| v * 0.25).collect();
        let w = pow2::quantize(&wf, k, n);
        let got = matshift::matshift_f32(&x, &w, m);
        let want = matmul::matmul_naive(&x, &pow2::dequantize(&w), m, k, n);
        assert_close(&got, &want, 0.1)
    });
}

/// MatAdd with a ±1 operand equals 2·Hamming-similarity − d accumulation
/// (the packed-bits identity that makes binarized attention adds-only).
#[test]
fn prop_matadd_hamming_identity() {
    check("matadd-hamming", 25, 16, |rng, size| {
        let d = 8 * (1 + size % 4); // multiple of 8 for clean packing
        let a: Vec<i8> = (0..d)
            .map(|_| if rng.uniform() < 0.5 { -1 } else { 1 })
            .collect();
        let b: Vec<i8> = (0..d)
            .map(|_| if rng.uniform() < 0.5 { -1 } else { 1 })
            .collect();
        let af: Vec<f32> = a.iter().map(|&v| v as f32).collect();
        let dot = matadd::matadd_f32(&af, &b, 1, d, 1)[0];
        let m = binary::hamming_sim(&binary::pack_bits(&a), &binary::pack_bits(&b), d) as f32;
        if (dot - (2.0 * m - d as f32)).abs() > 1e-5 {
            return Err(format!("dot {dot} vs 2m-d {}", 2.0 * m - d as f32));
        }
        Ok(())
    });
}

/// pow2 quantization: dequantized magnitude within one octave, signs exact.
#[test]
fn prop_pow2_octave_bound() {
    check("pow2-octave", 30, 32, |rng, size| {
        let n = size + 1;
        let w: Vec<f32> = rng
            .normals(n)
            .iter()
            .map(|v| v.clamp(-100.0, 100.0))
            .collect();
        let q = pow2::quantize(&w, 1, n);
        let d = pow2::dequantize(&q);
        for (x, y) in w.iter().zip(&d) {
            if x.abs() > 0.004 && x.abs() < 100.0 {
                let ratio = y.abs() / x.abs();
                if !(0.7..=1.42).contains(&ratio) {
                    return Err(format!("ratio {ratio} for {x} -> {y}"));
                }
                if x.signum() != y.signum() {
                    return Err("sign flip".into());
                }
            }
        }
        Ok(())
    });
}
