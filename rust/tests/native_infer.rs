//! Native inference engine integration tests:
//!
//! 1. attention fidelity (paper §5.4): KSH-binarized LinearAdd attention
//!    approximates its full-precision linear-attention counterpart within
//!    tolerance on random inputs;
//! 2. the native LinearAdd block forward is *bit-exact* against a readable
//!    oracle composed from the reference kernels;
//! 3. `serve()` completes an end-to-end classification run on the native
//!    backend with no XLA artifacts present.

use std::sync::Arc;

use shiftaddvit::coordinator::backend::{create_backend, InferenceBackend, NativeBackend};
use shiftaddvit::coordinator::config::{BackendKind, ServerConfig};
use shiftaddvit::coordinator::server::serve_backend;
use shiftaddvit::infer::attn::{hamming_linear_attn_kernel, hamming_linear_attn_ref};
use shiftaddvit::infer::block::{BlockRaw, MlpKind, NativeBlock};
use shiftaddvit::kernels::api::{Primitive, RawWeights};
use shiftaddvit::kernels::matmul::matmul_naive;
use shiftaddvit::kernels::matshift::matshift_f32;
use shiftaddvit::kernels::planner::Planner;
use shiftaddvit::kernels::registry::KernelRegistry;
use shiftaddvit::model::ops::Variant;
use shiftaddvit::quant::ksh::KshHasher;
use shiftaddvit::quant::pow2;
use shiftaddvit::util::prop::check;
use shiftaddvit::util::rng::XorShift64;

// ---------------------------------------------------------------------------
// 1. Attention fidelity (paper §5.4)
// ---------------------------------------------------------------------------

/// Full-precision counterpart of Hamming-similarity attention: the expected
/// match count between random-hyperplane codes of q and k is
/// `bits·(1 − θ/π)` (θ = angle in the original feature space), so
/// `out_i = Σⱼ (1−θᵢⱼ/π)·vⱼ / Σⱼ (1−θᵢⱼ/π)` is the infinite-bits limit the
/// binarized path must track.
fn expected_hamming_attn(q: &[f32], k: &[f32], v: &[f32], n: usize, d: usize) -> Vec<f32> {
    let norm = |x: &[f32]| x.iter().map(|a| a * a).sum::<f32>().sqrt().max(1e-12);
    let mut out = vec![0.0f32; n * d];
    for i in 0..n {
        let qi = &q[i * d..(i + 1) * d];
        let qn = norm(qi);
        let mut den = 0.0f32;
        let mut num = vec![0.0f32; d];
        for j in 0..n {
            let kj = &k[j * d..(j + 1) * d];
            let dot: f32 = qi.iter().zip(kj).map(|(a, b)| a * b).sum();
            let cos = (dot / (qn * norm(kj))).clamp(-1.0, 1.0);
            let w = 1.0 - cos.acos() / std::f32::consts::PI;
            den += w;
            for (nn, &vv) in num.iter_mut().zip(&v[j * d..(j + 1) * d]) {
                *nn += w * vv;
            }
        }
        for e in 0..d {
            out[i * d + e] = num[e] / (den + 1e-6);
        }
    }
    out
}

#[test]
fn ksh_linear_add_tracks_full_precision_linear_attention() {
    // Property: with a wide enough hash family, binarized LinearAdd
    // attention approximates the full-precision similarity attention —
    // paper §5.4's justification for KSH over vanilla binarization.
    let d = 8;
    let bits = 512;
    check("ksh-attn-fidelity", 12, 10, |rng, size| {
        let n = size + 2;
        let q = rng.normals(n * d);
        let k = rng.normals(n * d);
        let v = rng.normals(n * d);
        let hasher = KshHasher::new(d, bits, 0xB17 + size as u64);
        let qc = hasher.hash_matrix(&q, n);
        let kc = hasher.hash_matrix(&k, n);
        let got = hamming_linear_attn_ref(&qc, &kc, &v, n, bits, d);
        let want = expected_hamming_attn(&q, &k, &v, n, d);
        let mut sum_err = 0.0f64;
        let mut max_err = 0.0f32;
        for (g, w) in got.iter().zip(&want) {
            let e = (g - w).abs();
            sum_err += e as f64;
            max_err = max_err.max(e);
        }
        let mean_err = sum_err / got.len() as f64;
        if mean_err > 0.1 {
            return Err(format!("mean abs err {mean_err} (n={n})"));
        }
        if max_err > 0.35 {
            return Err(format!("max abs err {max_err} (n={n})"));
        }
        Ok(())
    });
}

#[test]
fn hamming_attention_kernel_path_is_bit_exact() {
    // Every registered MatAdd backend must reproduce the readable oracle
    // exactly when driving the binarized attention.
    let registry = KernelRegistry::with_defaults();
    let mut rng = XorShift64::new(4242);
    for (n, d, bits) in [(7, 4, 8), (16, 8, 16), (33, 8, 8)] {
        let hasher = KshHasher::new(d, bits, 3);
        let q = rng.normals(n * d);
        let k = rng.normals(n * d);
        let v = rng.normals(n * d);
        let qc = hasher.hash_matrix(&q, n);
        let kc = hasher.hash_matrix(&k, n);
        let want = hamming_linear_attn_ref(&qc, &kc, &v, n, bits, d);
        for kernel in registry.for_primitive(Primitive::MatAdd) {
            let got = hamming_linear_attn_kernel(&kernel, &qc, &kc, &v, n, bits, d);
            assert_eq!(got, want, "{} (n={n})", kernel.id());
        }
    }
}

// ---------------------------------------------------------------------------
// 2. Native block forward vs readable oracle (bit-exact)
// ---------------------------------------------------------------------------

fn oracle_layer_norm(x: &[f32], g: &[f32], b: &[f32], d: usize) -> Vec<f32> {
    let mut out = vec![0.0f32; x.len()];
    for (row, orow) in x.chunks(d).zip(out.chunks_mut(d)) {
        let mut mu = 0.0f32;
        for &v in row {
            mu += v;
        }
        mu /= d as f32;
        let mut var = 0.0f32;
        for &v in row {
            var += (v - mu) * (v - mu);
        }
        var /= d as f32;
        let denom = (var + 1e-6).sqrt();
        for ((o, &v), (&gg, &bb)) in orow.iter_mut().zip(row).zip(g.iter().zip(b)) {
            *o = (v - mu) / denom * gg + bb;
        }
    }
    out
}

/// Shift linear via the reference pipeline: pow2 weights + INT8 activation
/// quantization + i64 shift-accumulate + dequant, then bias.
fn oracle_shift_linear(x: &[f32], raw: &RawWeights, bias: &[f32], m: usize) -> Vec<f32> {
    let q = pow2::quantize(&raw.data, raw.k, raw.n);
    let mut y = matshift_f32(x, &q, m);
    for row in y.chunks_mut(bias.len()) {
        for (v, &b) in row.iter_mut().zip(bias) {
            *v += b;
        }
    }
    y
}

fn oracle_dense_linear(x: &[f32], raw: &RawWeights, bias: &[f32], m: usize) -> Vec<f32> {
    let mut y = matmul_naive(x, &raw.data, m, raw.k, raw.n);
    for row in y.chunks_mut(bias.len()) {
        for (v, &b) in row.iter_mut().zip(bias) {
            *v += b;
        }
    }
    y
}

fn oracle_dwconv(x: &[f32], dw: &[f32], grid: usize, d: usize) -> Vec<f32> {
    let mut out = vec![0.0f32; grid * grid * d];
    for y in 0..grid {
        for xx in 0..grid {
            for c in 0..d {
                let mut acc = 0.0f32;
                for dy in 0..3usize {
                    for dx in 0..3usize {
                        let (sy, sx) = (y + dy, xx + dx);
                        if sy >= 1 && sy <= grid && sx >= 1 && sx <= grid {
                            acc += x[((sy - 1) * grid + (sx - 1)) * d + c]
                                * dw[(dy * 3 + dx) * d + c];
                        }
                    }
                }
                out[(y * grid + xx) * d + c] = acc;
            }
        }
    }
    out
}

/// Readable re-implementation of the Mult/Shift MoE MLP: softmax gate,
/// top-1 routing (first-wins ties), bucket-padded partitions in token
/// order, per-expert 2-layer MLP on reference kernels, gate-scaled scatter.
fn oracle_moe_mlp(u: &[f32], raw: &BlockRaw, t: usize, buckets: &[usize]) -> Vec<f32> {
    let d = raw.gate_w.k;
    let mut probs = matmul_naive(u, &raw.gate_w.data, t, d, 2);
    for row in probs.chunks_mut(2) {
        let m = row.iter().fold(f32::NEG_INFINITY, |a, &b| a.max(b));
        let mut sum = 0.0;
        for v in row.iter_mut() {
            *v = (*v - m).exp();
            sum += *v;
        }
        for v in row.iter_mut() {
            *v /= sum;
        }
    }
    // top-1: strictly-greater wins, first expert wins ties.
    let routes: Vec<(usize, f32)> = probs
        .chunks(2)
        .map(|g| {
            if g[1] > g[0] {
                (1, g[1])
            } else {
                (0, g[0])
            }
        })
        .collect();
    let max_bucket = *buckets.last().unwrap();
    let mut out = vec![0.0f32; t * d];
    for expert in 0..2usize {
        let idxs: Vec<usize> = (0..t).filter(|&i| routes[i].0 == expert).collect();
        for chunk in idxs.chunks(max_bucket) {
            let bucket = buckets
                .iter()
                .copied()
                .find(|&b| b >= chunk.len())
                .unwrap_or(max_bucket);
            let mut padded = vec![0.0f32; bucket * d];
            for (row, &ti) in chunk.iter().enumerate() {
                padded[row * d..(row + 1) * d].copy_from_slice(&u[ti * d..(ti + 1) * d]);
            }
            let mut h = if expert == 0 {
                oracle_dense_linear(&padded, &raw.w1, &raw.b1, bucket)
            } else {
                oracle_shift_linear(&padded, &raw.w1s, &raw.b1s, bucket)
            };
            for v in h.iter_mut() {
                *v = v.max(0.0);
            }
            let y = if expert == 0 {
                oracle_dense_linear(&h, &raw.w2, &raw.b2, bucket)
            } else {
                oracle_shift_linear(&h, &raw.w2s, &raw.b2s, bucket)
            };
            for (row, &ti) in chunk.iter().enumerate() {
                let g = routes[ti].1;
                for e in 0..d {
                    out[ti * d + e] = g * y[row * d + e];
                }
            }
        }
    }
    out
}

/// The readable oracle for the fully reparameterized LinearAdd block:
/// identical composition, reference kernels everywhere.
fn oracle_block_forward(
    x: &mut [f32],
    raw: &BlockRaw,
    tokens: usize,
    heads: usize,
    b: usize,
    buckets: &[usize],
    hash_seed: u64,
) {
    let d = raw.wq.k;
    let t = b * tokens;
    let hd = d / heads;
    let bits = hd;
    let grid = (tokens as f64).sqrt().round() as usize;
    let hasher = KshHasher::new(hd, bits, hash_seed);

    // attention sublayer
    let u = oracle_layer_norm(x, &raw.ln1_g, &raw.ln1_b, d);
    let q = oracle_shift_linear(&u, &raw.wq, &raw.bq, t);
    let k = oracle_shift_linear(&u, &raw.wk, &raw.bk, t);
    let v = oracle_shift_linear(&u, &raw.wv, &raw.bv, t);
    let mut o = vec![0.0f32; t * d];
    for img in 0..b {
        let base = img * tokens * d;
        for h in 0..heads {
            let mut qh = vec![0.0f32; tokens * hd];
            let mut kh = vec![0.0f32; tokens * hd];
            let mut vh = vec![0.0f32; tokens * hd];
            for i in 0..tokens {
                let src = base + i * d + h * hd;
                qh[i * hd..(i + 1) * hd].copy_from_slice(&q[src..src + hd]);
                kh[i * hd..(i + 1) * hd].copy_from_slice(&k[src..src + hd]);
                vh[i * hd..(i + 1) * hd].copy_from_slice(&v[src..src + hd]);
            }
            let qc = hasher.hash_matrix(&qh, tokens);
            let kc = hasher.hash_matrix(&kh, tokens);
            let oh = hamming_linear_attn_ref(&qc, &kc, &vh, tokens, bits, hd);
            for i in 0..tokens {
                let dst = base + i * d + h * hd;
                o[dst..dst + hd].copy_from_slice(&oh[i * hd..(i + 1) * hd]);
            }
        }
        let conv = oracle_dwconv(&v[base..base + tokens * d], &raw.dw, grid, d);
        for (ov, cv) in o[base..base + tokens * d].iter_mut().zip(&conv) {
            *ov += cv;
        }
    }
    let a = oracle_shift_linear(&o, &raw.wo, &raw.bo, t);
    for (xv, av) in x.iter_mut().zip(&a) {
        *xv += av;
    }

    // MoE MLP sublayer
    let u2 = oracle_layer_norm(x, &raw.ln2_g, &raw.ln2_b, d);
    let y = oracle_moe_mlp(&u2, raw, t, buckets);
    for (xv, yv) in x.iter_mut().zip(&y) {
        *xv += yv;
    }
}

#[test]
fn native_linear_add_block_is_bit_exact_vs_oracle() {
    let (tokens, dim, heads) = (16, 8, 2);
    let buckets = [4usize, 16, 64];
    let hash_seed = 0xFACE;
    let mut rng = XorShift64::new(2024);
    let raw_native = BlockRaw::random(&mut rng, dim, dim * 2);
    // identical raw weights for the oracle (same rng stream replay)
    let mut rng2 = XorShift64::new(2024);
    let raw_oracle = BlockRaw::random(&mut rng2, dim, dim * 2);

    let planner = Planner::new(Arc::new(KernelRegistry::with_defaults()));
    let blk = NativeBlock::from_raw(
        raw_native,
        tokens,
        heads,
        Variant::SHIFTADD_MOE,
        &planner,
        &buckets,
        hash_seed,
    );
    assert!(matches!(blk.mlp, MlpKind::Moe(_)));

    let mut rng3 = XorShift64::new(555);
    for b in [1usize, 2] {
        let x0 = rng3.normals(b * tokens * dim);
        let mut native = x0.clone();
        blk.forward(&mut native, b);
        let mut oracle = x0.clone();
        oracle_block_forward(
            &mut oracle,
            &raw_oracle,
            tokens,
            heads,
            b,
            &buckets,
            hash_seed,
        );
        assert_eq!(native, oracle, "block forward diverged at batch {b}");
    }
}

// ---------------------------------------------------------------------------
// 3. End-to-end native serving, zero artifacts
// ---------------------------------------------------------------------------

#[test]
fn serve_completes_end_to_end_on_native_backend() {
    // No Manifest / artifacts are touched anywhere on this path.
    let cfg = ServerConfig {
        requests: 12,
        max_batch: 4,
        batch_deadline_ms: 1.0,
        arrival_ms: 0.0,
        ..ServerConfig::default()
    };
    assert_eq!(cfg.backend, BackendKind::Native);
    let backend = create_backend(&cfg).expect("native backend needs no artifacts");
    let report = serve_backend(backend.as_ref(), &cfg).unwrap();
    assert_eq!(report.metrics.requests, 12);
    assert!(report.metrics.batches >= 3); // max_batch 4
    assert!(report.throughput_rps > 0.0);
    assert!(report.latency.p99 >= report.latency.p50);
    // routing happened in the MoE blocks
    let total_routed: usize = report.metrics.expert_tokens.iter().sum();
    assert!(total_routed > 0);
    // both experts were timed, so the LL-loss diagnostics are available
    assert!(report.metrics.ll_loss().is_some() || report.metrics.expert_tokens[1] == 0);
    // dispatch masks surfaced for the Fig. 6/9 visualisation
    assert!(!report.sample_masks.is_empty());
    assert_eq!(report.sample_masks[0].len(), 64);
    // the request path records per-step occupancy into the report
    let occ = report.occupancy.as_ref().expect("steps ran");
    assert!(occ.mean > 0.0 && occ.mean <= 1.0);
    assert!(report.step_tokens.is_some());
}

#[test]
fn native_backend_reports_serving_topology() {
    let backend = NativeBackend::tiny(Variant::SHIFTADD_MOE);
    assert_eq!(backend.img(), 32);
    assert_eq!(backend.tokens(), 64);
    assert_eq!(backend.num_classes(), 8);
    assert!(backend.name().contains("native"));
}
