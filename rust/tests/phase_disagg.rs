//! Phase-disaggregation suite for the continuous batcher:
//!
//! 1. PROPERTY: for random workloads, arrival interleavings, and prefill
//!    budgets {1 token, exactly one chunk, unbounded}, the disaggregated
//!    scheduler's logits are *bit-exact* vs the legacy single-phase path
//!    and vs solo full-prefix inference (all engines share one planner, so
//!    equality is a pure scheduling statement);
//! 2. ISOLATION: a long prompt landing mid-run never lifts the decode
//!    dispatch above `max_live · chunk` tokens, yet still catches up at
//!    the budget rate while every live slot is taken;
//! 3. the serve loop runs under both explicit scheduler configs (solo and
//!    fleet) and reports queue-wait / time-to-first-token percentiles;
//! 4. the scheduler/prefill-budget config keys parse from JSON files.

use std::sync::Arc;

use shiftaddvit::coordinator::config::{SchedulerKind, ServerConfig, Workload};
use shiftaddvit::coordinator::metrics::Metrics;
use shiftaddvit::coordinator::server::serve_stream;
use shiftaddvit::coordinator::sessions::{SchedulerMode, SessionEngine, StreamStatus, StreamTicket};
use shiftaddvit::infer::session::{SessionSpec, StreamAttn, StreamModel};
use shiftaddvit::kernels::planner::Planner;
use shiftaddvit::kernels::registry::KernelRegistry;
use shiftaddvit::model::ops::Lin;
use shiftaddvit::util::prop::check;
use shiftaddvit::util::rng::XorShift64;

fn shared_planner() -> Arc<Planner> {
    Arc::new(Planner::new(Arc::new(KernelRegistry::with_defaults())))
}

/// Drive one engine over a staggered arrival schedule (`arrive_at[i]` =
/// scheduler tick before which session `i` is submitted) and return every
/// session's logits in submission order, plus the run's metrics.
fn run_schedule(
    planner: &Arc<Planner>,
    spec: &SessionSpec,
    seqs: &[Vec<f32>],
    arrive_at: &[usize],
    chunk: usize,
    max_live: usize,
    mode: SchedulerMode,
) -> (Vec<Vec<f32>>, Metrics) {
    let model = StreamModel::new(spec.clone(), Arc::clone(planner));
    let mut eng = SessionEngine::with_mode(model, chunk, max_live, mode);
    let mut tickets: Vec<Option<StreamTicket>> = vec![None; seqs.len()];
    let mut metrics = Metrics::default();
    let mut tick = 0usize;
    while tickets.iter().any(|t| t.is_none()) || !eng.idle() {
        for (i, &at) in arrive_at.iter().enumerate() {
            if at == tick {
                tickets[i] = Some(eng.submit(seqs[i].clone()));
            }
        }
        if !eng.idle() {
            eng.step(&mut metrics);
        }
        tick += 1;
    }
    let outs = tickets
        .iter()
        .map(|t| {
            eng.poll(t.as_ref().expect("all sessions submitted"))
                .expect("engine drained every session")
                .logits
        })
        .collect();
    (outs, metrics)
}

// ---------------------------------------------------------------------------
// 1. Scheduling-invariance property
// ---------------------------------------------------------------------------

#[test]
fn property_any_budget_and_interleaving_matches_single_phase() {
    let spec = SessionSpec::tiny(StreamAttn::LinearAdd, Lin::Mult);
    let planner = shared_planner();
    let solo_model = StreamModel::new(spec.clone(), Arc::clone(&planner));
    let d = spec.dim;
    check("phase-disagg-equivalence", 8, 5, |rng, size| {
        let n_sessions = 2 + rng.range(0, size + 2);
        let chunk = 1 + rng.range(0, 4);
        let max_live = 1 + rng.range(0, 3);
        let lens: Vec<usize> = (0..n_sessions)
            .map(|_| 1 + rng.range(0, 4 * chunk + 3))
            .collect();
        let seqs: Vec<Vec<f32>> = lens.iter().map(|&n| rng.normals(n * d)).collect();
        // arrivals scattered over the first ~2·n ticks: some sessions land
        // while others are mid-prefill, mid-decode, or already done
        let arrive_at: Vec<usize> = (0..n_sessions)
            .map(|_| rng.range(0, 2 * n_sessions))
            .collect();

        let solo: Vec<Vec<f32>> = seqs.iter().map(|s| solo_model.forward_full(s)).collect();
        let (want, _) = run_schedule(
            &planner,
            &spec,
            &seqs,
            &arrive_at,
            chunk,
            max_live,
            SchedulerMode::SinglePhase,
        );
        if want != solo {
            return Err(format!(
                "single-phase baseline diverged from solo (chunk {chunk}, \
                 max_live {max_live}, lens {lens:?}, arrivals {arrive_at:?})"
            ));
        }
        for budget in [1usize, chunk, usize::MAX] {
            let (got, m) = run_schedule(
                &planner,
                &spec,
                &seqs,
                &arrive_at,
                chunk,
                max_live,
                SchedulerMode::Disaggregated {
                    prefill_budget: budget,
                },
            );
            if got != want {
                return Err(format!(
                    "budget {budget}: logits diverged from single-phase (chunk \
                     {chunk}, max_live {max_live}, lens {lens:?}, arrivals {arrive_at:?})"
                ));
            }
            if m.prefill_tokens.max() > budget as f64 {
                return Err(format!(
                    "budget {budget}: a prefill dispatch exceeded it (max {})",
                    m.prefill_tokens.max()
                ));
            }
            if m.decode_tokens.max() > (chunk * max_live) as f64 {
                return Err(format!(
                    "a decode dispatch exceeded max_live·chunk = {}",
                    chunk * max_live
                ));
            }
        }
        Ok(())
    });
}

// ---------------------------------------------------------------------------
// 2. Long-prompt isolation
// ---------------------------------------------------------------------------

#[test]
fn long_prompt_arrival_never_inflates_the_decode_dispatch() {
    let spec = SessionSpec::tiny(StreamAttn::LinearAdd, Lin::Shift);
    let planner = shared_planner();
    let d = spec.dim;
    let (chunk, max_live, budget) = (2usize, 2usize, 4usize);
    let model = StreamModel::new(spec.clone(), Arc::clone(&planner));
    let mut eng = SessionEngine::disaggregated(model, chunk, max_live, budget);
    let mut m = Metrics::default();

    // A stream of short sessions keeps the decode batch saturated; a
    // 24-token prompt lands alongside them and must catch up in the
    // prefill dispatch without ever riding in (or delaying) decode.
    for i in 0..6u64 {
        eng.submit(XorShift64::new(1 + i).normals(2 * d));
    }
    let long = XorShift64::new(9).normals(24 * d);
    let tl = eng.submit(long.clone());
    let mut prefill_rates = Vec::new();
    while !eng.idle() {
        let fed_before = match eng.status(&tl) {
            StreamStatus::Streaming { fed, .. } => fed,
            _ => 0,
        };
        let st = eng.step(&mut m);
        // the decode dispatch never grows because of the arrival
        assert!(
            st.decode_tokens <= chunk * max_live,
            "decode dispatch inflated to {} tokens",
            st.decode_tokens
        );
        assert!(st.prefill_tokens <= budget);
        if let StreamStatus::Streaming { fed, .. } = eng.status(&tl) {
            if st.live == max_live && fed > fed_before {
                // the decode batch was full, yet the prompt still caught up
                // — and at the budget rate, not the decode chunk rate
                prefill_rates.push(fed - fed_before);
            }
        }
    }
    assert!(
        prefill_rates.iter().any(|&r| r == budget),
        "long prompt should prefill at the budget rate while slots are full \
         (saw {prefill_rates:?})"
    );
    let out = eng.poll(&tl).expect("long prompt completed");
    assert_eq!(out.tokens, 24);
    assert_eq!(
        out.logits,
        eng.model.forward_full(&long),
        "budgeted catch-up diverged from solo full-prefix"
    );
    assert!(out.ttft_ms() >= out.queue_wait_ms());
    assert!(out.latency_ms() >= out.ttft_ms());
}

// ---------------------------------------------------------------------------
// 3. Serve loop under explicit scheduler configs
// ---------------------------------------------------------------------------

#[test]
fn serve_stream_reports_latency_gauges_under_both_schedulers() {
    for kind in [SchedulerKind::SinglePhase, SchedulerKind::Disaggregated] {
        let cfg = ServerConfig {
            requests: 5,
            stream_tokens: 10,
            stream_chunk: 4,
            max_live: 2,
            scheduler: kind,
            prefill_budget: 6,
            workload: Workload::Stream,
            ..ServerConfig::default()
        };
        let report = serve_stream(&cfg).unwrap();
        assert_eq!(report.metrics.requests, 5, "{}", kind.name());
        assert_eq!(report.queue_wait.n, 5);
        assert_eq!(report.ttft.n, 5);
        // per-session orderings (wait ≤ ttft ≤ latency) survive into the
        // percentiles because they hold pointwise
        assert!(report.ttft.p50 >= report.queue_wait.p50);
        assert!(report.latency.p99 >= report.ttft.p99);
        let js = report.to_json();
        assert!(js.get("queue_wait_ms").is_some());
        assert!(js.get("ttft_ms").is_some());
        if kind == SchedulerKind::Disaggregated {
            // both phase gauges flowed into the merged metrics
            assert!(!report.metrics.prefill_queue.is_empty());
            assert!(report.metrics.decode_tokens.sum() > 0.0);
        }
    }
}

#[test]
fn fleet_stream_shares_one_planner_table_and_merges_gauges() {
    let cfg = ServerConfig {
        requests: 6,
        stream_tokens: 8,
        stream_chunk: 4,
        max_live: 2,
        workers: 2,
        workload: Workload::Stream,
        ..ServerConfig::default()
    };
    let report = serve_stream(&cfg).unwrap();
    assert_eq!(report.metrics.requests, 6);
    assert_eq!(report.per_worker.len(), 2);
    assert_eq!(
        report.per_worker.iter().map(|b| b.requests).sum::<usize>(),
        6,
        "every session placed on exactly one worker"
    );
    assert_eq!(report.queue_wait.n, 6);
    assert_eq!(report.ttft.n, 6);
    // the factory table pinned on every worker: plans exist and none were
    // re-benchmarked inside a worker thread
    assert!(!report.metrics.chosen_backends.is_empty());
}

// ---------------------------------------------------------------------------
// 4. Config plumbing
// ---------------------------------------------------------------------------

#[test]
fn scheduler_config_keys_parse_from_json() {
    let dir = std::env::temp_dir().join("savit_phase_disagg_test");
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join("cfg.json");
    std::fs::write(
        &path,
        r#"{"workload": "stream", "scheduler": "single-phase", "prefill_budget": 9}"#,
    )
    .unwrap();
    let cfg = ServerConfig::from_file(&path).unwrap();
    assert_eq!(cfg.workload, Workload::Stream);
    assert_eq!(cfg.scheduler, SchedulerKind::SinglePhase);
    assert_eq!(cfg.prefill_budget, 9);
    assert_eq!(cfg.resolve_prefill_budget(), 9, "explicit budget wins");

    std::fs::write(&path, r#"{"stream_chunk": 4, "max_live": 3}"#).unwrap();
    let auto = ServerConfig::from_file(&path).unwrap();
    assert_eq!(auto.scheduler, SchedulerKind::Disaggregated, "default");
    assert_eq!(
        auto.resolve_prefill_budget(),
        12,
        "budget auto-sizes to one full decode batch"
    );
}
