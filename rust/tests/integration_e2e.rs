//! End-to-end: NVS renderer + LRA path + dispatch-vs-ground-truth — the
//! remaining cross-module compositions. Skips without artifacts.

use shiftaddvit::data::{lra, synth_images};
use shiftaddvit::nvs::metrics::psnr;
use shiftaddvit::nvs::render::eval_scene;
use shiftaddvit::nvs::scenes::Scene;
use shiftaddvit::runtime::artifact::Manifest;
use shiftaddvit::runtime::engine::Engine;
use shiftaddvit::runtime::tensor::Tensor;

fn engine_or_skip() -> Option<Engine> {
    if !Manifest::available() {
        eprintln!("SKIP: artifacts missing — run `make artifacts`");
        return None;
    }
    Some(Engine::from_default_dir().expect("engine"))
}

#[test]
fn nvs_render_produces_valid_image() {
    let Some(engine) = engine_or_skip() else { return };
    if engine.manifest().get("nvs_gnt_r256").is_err()
        || engine.manifest().root.get("nvs_scenes").is_none()
    {
        eprintln!("SKIP: nvs artifacts/scenes missing");
        return;
    }
    let scene = Scene::from_manifest(&engine.manifest().root, "orchids").unwrap();
    let e = eval_scene(&engine, &scene, "nvs_gnt_r256", 16, 0.15).unwrap();
    assert_eq!(e.pred.len(), 16 * 16 * 3);
    assert!(e.pred.iter().all(|v| v.is_finite()));
    // a sigmoid-headed model always lands in (0,1)
    assert!(e.pred.iter().all(|v| (0.0..=1.0).contains(v)));
    // PSNR must beat a black frame (sanity floor, trained or not)
    let black = vec![0.0f32; e.gt.len()];
    assert!(e.psnr > psnr(&black, &e.gt) - 3.0, "psnr {}", e.psnr);
}

#[test]
fn nvs_ground_truth_consistent_between_poses() {
    let Some(engine) = engine_or_skip() else { return };
    if engine.manifest().root.get("nvs_scenes").is_none() {
        eprintln!("SKIP: scenes not exported");
        return;
    }
    let scene = Scene::from_manifest(&engine.manifest().root, "flower").unwrap();
    let a = scene.render_gt(16, 0.0);
    let b = scene.render_gt(16, 0.3);
    // different poses → different images, same statistics ballpark
    assert_ne!(a, b);
    let mean = |v: &[f32]| v.iter().sum::<f32>() / v.len() as f32;
    assert!((mean(&a) - mean(&b)).abs() < 0.3);
}

#[test]
fn lra_artifacts_execute() {
    let Some(engine) = engine_or_skip() else { return };
    let arts = engine.manifest().by_kind("lra");
    if arts.is_empty() {
        eprintln!("SKIP: lra artifacts missing");
        return;
    }
    for meta in arts {
        let seq = meta.inputs[0].shape[1];
        let toks = lra::gen_sequences(3, 1, seq);
        let out = engine
            .call(&meta.name, &[Tensor::i32(vec![1, seq], toks)])
            .unwrap();
        assert!(out[0].as_f32().unwrap().iter().all(|v| v.is_finite()));
    }
}

/// Fig. 6/9 mechanism: with a *trained* router the Mult-expert mask should
/// overlap the object tokens better than chance. With random weights this
/// cannot be asserted — so the test only validates the plumbing (masks have
/// the right size and both expert classes are reachable across samples) and
/// prints the overlap for EXPERIMENTS.md.
#[test]
fn dispatch_mask_plumbing() {
    use shiftaddvit::coordinator::config::DispatchMode;
    use shiftaddvit::coordinator::metrics::Metrics;
    use shiftaddvit::coordinator::scheduler::MoePipeline;

    if !Manifest::available() {
        eprintln!("SKIP: artifacts missing");
        return;
    }
    let m = Manifest::load(&Manifest::default_dir()).unwrap();
    if m.serve.is_none() {
        return;
    }
    let tokens = m.serve.as_ref().unwrap().tokens;
    let patch = m.serve.as_ref().unwrap().patch;
    let pipeline = MoePipeline::new(&m, DispatchMode::Real).unwrap();
    let mut metrics = Metrics::default();
    let mut iou_sum = 0.0f64;
    let n = 6;
    for i in 0..n {
        let s = synth_images::gen_image(7_000_000 + i);
        let out = pipeline.run_batch(&s.pixels, 1, &mut metrics).unwrap();
        let mask = &out.dispatch_mask_blk0[0];
        assert_eq!(mask.len(), tokens);
        let gt = synth_images::object_mask(&s, patch);
        let inter = mask
            .iter()
            .zip(&gt)
            .filter(|(a, b)| **a && **b)
            .count() as f64;
        let union = mask
            .iter()
            .zip(&gt)
            .filter(|(a, b)| **a || **b)
            .count()
            .max(1) as f64;
        iou_sum += inter / union;
    }
    println!(
        "router-dispatch vs object-token IoU over {n} samples: {:.3}",
        iou_sum / n as f64
    );
}
