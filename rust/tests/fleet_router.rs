//! Fleet router integration suite:
//!
//! 1. PROPERTY: fleet outputs are bit-identical to a single-worker run for
//!    the same request set, under every routing policy (solo batches:
//!    `max_batch = 1`, because per-tensor INT8 calibration spans a batch);
//! 2. placement is deterministic for a fixed policy seed;
//! 3. `LeastLoaded` actually tracks occupancy: busy workers are routed
//!    around, and gauges drain as work completes;
//! 4. `Affinity` keeps equal request shapes on one worker;
//! 5. chaos: killing a worker mid-flight still completes every submitted
//!    request on the survivors (resubmission), bit-identically;
//! 6. `remove_worker` under load drains cleanly — nothing lost, nothing
//!    duplicated;
//! 7. fleet serving also scales the classify serve loop end to end
//!    (`serve_fleet` report sanity + per-worker breakdown).

use std::collections::HashSet;
use std::sync::Arc;
use std::time::{Duration, Instant};

use shiftaddvit::coordinator::backend::{InferenceBackend, NativeBackend};
use shiftaddvit::coordinator::batcher::Request;
use shiftaddvit::coordinator::config::ServerConfig;
use shiftaddvit::coordinator::metrics::Metrics;
use shiftaddvit::coordinator::server::serve_fleet;
use shiftaddvit::data::synth_images;
use shiftaddvit::fleet::policy::PolicyKind;
use shiftaddvit::fleet::router::{Router, RouterConfig};
use shiftaddvit::fleet::worker::BackendFactory;
use shiftaddvit::model::ops::Variant;

const POLL: Duration = Duration::from_secs(120);

fn factory() -> BackendFactory {
    Arc::new(|| {
        let b: Box<dyn InferenceBackend> = Box::new(NativeBackend::tiny(Variant::SHIFTADD_MOE));
        Ok(b)
    })
}

fn request(id: usize) -> Request {
    let s = synth_images::gen_image(40_000 + id as u32);
    Request {
        id,
        pixels: s.pixels,
        label: Some(s.label),
        arrived: Instant::now(),
        trace: shiftaddvit::obs::trace::TraceCtx::NONE,
    }
}

fn router_with(workers: usize, policy: PolicyKind, max_batch: usize, step_delay_ms: f64) -> Router {
    Router::new(
        RouterConfig {
            workers,
            max_batch,
            policy,
            step_delay_ms,
            ..RouterConfig::default()
        },
        factory(),
    )
    .expect("fleet starts")
}

/// Solo reference: the same requests through ONE engine, one request per
/// batch — the bit-exactness baseline every fleet run must reproduce.
fn solo_logits(n: usize) -> Vec<Vec<f32>> {
    let backend = NativeBackend::tiny(Variant::SHIFTADD_MOE);
    let mut m = Metrics::default();
    let mut outs = Vec::with_capacity(n);
    for i in 0..n {
        let t = backend.submit(request(i));
        backend.step(1, &mut m).unwrap();
        outs.push(backend.poll(&t).expect("solo step completed").logits);
    }
    outs
}

// ---------------------------------------------------------------------------
// 1. Bit-identical property under every policy
// ---------------------------------------------------------------------------

#[test]
fn fleet_outputs_are_bit_identical_to_single_worker_under_every_policy() {
    let n = 6;
    let want = solo_logits(n);
    for policy in [
        PolicyKind::RoundRobin,
        PolicyKind::LeastLoaded,
        PolicyKind::Affinity,
    ] {
        let mut r = router_with(2, policy, 1, 0.0);
        let tickets: Vec<_> = (0..n).map(|i| r.submit(request(i)).unwrap()).collect();
        for (i, t) in tickets.iter().enumerate() {
            let out = r.poll_wait(t, POLL).unwrap();
            assert_eq!(out.request_id, i);
            assert_eq!(
                out.logits, want[i],
                "policy {policy:?}: request {i} diverged from the solo run"
            );
        }
        r.shutdown().unwrap();
    }
}

// ---------------------------------------------------------------------------
// 2. Deterministic placement under a fixed seed
// ---------------------------------------------------------------------------

#[test]
fn placement_is_deterministic_for_a_fixed_policy_seed() {
    for policy in [
        PolicyKind::RoundRobin,
        PolicyKind::LeastLoaded,
        PolicyKind::Affinity,
    ] {
        // Throttled steps: all 9 submissions land before any step finishes,
        // so the load gauges the policy sees are timing-independent.
        let place = |seed: u64| -> Vec<usize> {
            let mut r = Router::new(
                RouterConfig {
                    workers: 3,
                    max_batch: 4,
                    policy,
                    policy_seed: seed,
                    step_delay_ms: 50.0,
                },
                factory(),
            )
            .expect("fleet starts");
            let placed: Vec<usize> =
                (0..9).map(|i| r.submit(request(i)).unwrap().worker).collect();
            r.shutdown().unwrap();
            placed
        };
        assert_eq!(
            place(7),
            place(7),
            "policy {policy:?}: same seed must place identically"
        );
    }
}

// ---------------------------------------------------------------------------
// 3. LeastLoaded tracks occupancy
// ---------------------------------------------------------------------------

#[test]
fn least_loaded_routes_around_busy_workers_and_gauges_drain() {
    // Throttle steps so the first request is still in flight when the
    // second arrives: the occupancy gauge must steer it to the idle worker.
    let mut r = router_with(2, PolicyKind::LeastLoaded, 4, 60.0);
    let t1 = r.submit(request(0)).unwrap();
    let t2 = r.submit(request(1)).unwrap();
    assert_ne!(t1.worker, t2.worker, "least-loaded must pick the idle worker");
    r.poll_wait(&t1, POLL).unwrap();
    r.poll_wait(&t2, POLL).unwrap();
    // gauges drained back to zero: a fresh pair splits again instead of
    // piling onto one worker
    let t3 = r.submit(request(2)).unwrap();
    let t4 = r.submit(request(3)).unwrap();
    assert_ne!(t3.worker, t4.worker, "drained gauges must split fresh work");
    r.poll_wait(&t3, POLL).unwrap();
    r.poll_wait(&t4, POLL).unwrap();
    r.shutdown().unwrap();
}

// ---------------------------------------------------------------------------
// 4. Affinity pins equal shapes
// ---------------------------------------------------------------------------

#[test]
fn affinity_keeps_equal_shapes_on_one_worker() {
    let mut r = router_with(3, PolicyKind::Affinity, 4, 0.0);
    let tickets: Vec<_> = (0..8).map(|i| r.submit(request(i)).unwrap()).collect();
    let pinned = tickets[0].worker;
    assert!(
        tickets.iter().all(|t| t.worker == pinned),
        "classify requests share one shape, so affinity must pin them all"
    );
    for t in &tickets {
        r.poll_wait(t, POLL).unwrap();
    }
    let (merged, per_worker) = r.metrics_report();
    assert_eq!(merged.requests, 8);
    assert_eq!(
        per_worker.iter().filter(|b| b.requests > 0).count(),
        1,
        "exactly one worker served the pinned shape"
    );
    r.shutdown().unwrap();
}

// ---------------------------------------------------------------------------
// 5. Chaos: kill a worker mid-flight
// ---------------------------------------------------------------------------

#[test]
fn killing_a_worker_mid_flight_completes_every_request_bit_identically() {
    let n = 6;
    let want = solo_logits(n);
    // Solo batches (bit-exactness baseline) + throttled steps, so the
    // victim's work is reliably still in flight when the kill lands.
    let mut r = router_with(3, PolicyKind::RoundRobin, 1, 80.0);
    let tickets: Vec<_> = (0..n).map(|i| r.submit(request(i)).unwrap()).collect();
    let victim = tickets[0].worker;
    r.kill_worker(victim).unwrap();
    for (i, t) in tickets.iter().enumerate() {
        let out = r.poll_wait(t, POLL).unwrap();
        assert_eq!(
            out.logits, want[i],
            "request {i} diverged after worker {victim} died"
        );
    }
    assert!(
        r.resubmitted() > 0,
        "the killed worker's stranded requests were re-placed"
    );
    assert_eq!(r.worker_count(), 2, "the dead worker was reaped");
    assert!(r.readiness().ready, "survivors still admit requests");
    r.shutdown().unwrap();
}

// ---------------------------------------------------------------------------
// 6. remove_worker drains cleanly under load
// ---------------------------------------------------------------------------

#[test]
fn remove_worker_under_load_loses_and_duplicates_nothing() {
    let mut r = router_with(2, PolicyKind::RoundRobin, 2, 20.0);
    let tickets: Vec<_> = (0..8).map(|i| r.submit(request(i)).unwrap()).collect();
    let removed = tickets[0].worker;
    // blocks until the removed worker finished its live work
    r.remove_worker(removed).unwrap();
    assert_eq!(r.worker_count(), 1);
    let mut seen = HashSet::new();
    for t in &tickets {
        let out = r.poll_wait(t, POLL).unwrap();
        assert!(
            seen.insert(out.request_id),
            "duplicate output for request {}",
            out.request_id
        );
        assert!(r.poll(t).is_none(), "second poll must find nothing");
    }
    assert_eq!(seen.len(), 8, "every request completed exactly once");
    assert_eq!(r.resubmitted(), 0, "drained work finishes, it is never re-placed");
    r.shutdown().unwrap();
}

// ---------------------------------------------------------------------------
// 7. End-to-end fleet serve loop
// ---------------------------------------------------------------------------

#[test]
fn serve_fleet_end_to_end_reports_per_worker_breakdown() {
    let cfg = ServerConfig {
        requests: 10,
        max_batch: 4,
        workers: 2,
        policy: PolicyKind::LeastLoaded,
        ..ServerConfig::default()
    };
    let report = serve_fleet(&cfg).unwrap();
    assert_eq!(report.metrics.requests, 10);
    assert!(report.throughput_rps > 0.0);
    assert!(report.latency.p99 >= report.latency.p50);
    assert_eq!(report.per_worker.len(), 2);
    assert_eq!(
        report.per_worker.iter().map(|b| b.requests).sum::<usize>(),
        10,
        "per-worker breakdown must account for every request"
    );
    // per-request ids were threaded into the merged metrics: every client
    // id shows up exactly once across the fleet
    let mut ids: Vec<usize> = report.metrics.request_ids.iter().copied().collect();
    ids.sort_unstable();
    assert_eq!(ids, (0..10).collect::<Vec<_>>());
    report.print(); // smoke: fleet report printing must not panic
}
