//! Streaming-equivalence suite for the session API:
//!
//! 1. PROPERTY: `extend()`-ing a session token-by-token and in random chunk
//!    splits is *bit-exact* vs one-shot full-prefix inference, for every
//!    streamable (attention, linear) combination;
//! 2. multi-session interleaving through the continuous batcher
//!    (`SessionEngine`) is bit-exact vs solo streaming;
//! 3. the request-level backend contract (`submit/step/poll`) and its
//!    `run_batch` adapter agree, and the end-to-end serve loops (classify
//!    and stream) populate the new occupancy gauges;
//! 4. offline planner tables round-trip through `ServerConfig` and skip
//!    startup benchmarking.

use std::sync::Arc;

use shiftaddvit::coordinator::backend::{create_backend, NativeBackend};
use shiftaddvit::coordinator::config::{ServerConfig, Workload};
use shiftaddvit::coordinator::metrics::Metrics;
use shiftaddvit::coordinator::server::{
    serve_backend, serve_stream, stream_arrival_schedule, stream_workload_lens,
};
use shiftaddvit::coordinator::sessions::SessionEngine;
use shiftaddvit::infer::session::{SessionSpec, StreamAttn, StreamModel};
use shiftaddvit::kernels::planner::Planner;
use shiftaddvit::kernels::registry::KernelRegistry;
use shiftaddvit::model::ops::{Lin, Variant};
use shiftaddvit::util::prop::check;
use shiftaddvit::util::rng::XorShift64;

// ---------------------------------------------------------------------------
// 1. Streaming equivalence property (bit-exact)
// ---------------------------------------------------------------------------

#[test]
fn property_chunked_streaming_is_bit_exact_vs_full_prefix() {
    for (attn, lin) in [
        (StreamAttn::Linear, Lin::Mult),
        (StreamAttn::LinearAdd, Lin::Mult),
        (StreamAttn::LinearAdd, Lin::Shift),
    ] {
        // one model per combination, reused across property cases (planner
        // benchmarking is the expensive part)
        let model = StreamModel::tiny(attn, lin);
        let d = model.spec.dim;
        check(
            &format!("stream-equivalence-{attn:?}-{lin:?}"),
            10,
            8,
            |rng, size| {
                let n = size + 2;
                let toks = rng.normals(n * d);
                let want = model.forward_full(&toks);

                // token-by-token
                let mut s1 = model.begin();
                for i in 0..n {
                    model.extend(&mut s1, &toks[i * d..(i + 1) * d]);
                }
                if model.finish(&s1) != want {
                    return Err(format!("token-by-token diverged (n={n})"));
                }

                // random chunk split
                let mut s2 = model.begin();
                let mut fed = 0usize;
                while fed < n {
                    let take = 1 + rng.range(0, (n - fed).min(4));
                    model.extend(&mut s2, &toks[fed * d..(fed + take) * d]);
                    fed += take;
                }
                if model.finish(&s2) != want {
                    return Err(format!("random chunk split diverged (n={n})"));
                }
                Ok(())
            },
        );
    }
}

#[test]
fn streamed_state_size_is_constant_in_sequence_length() {
    let spec = SessionSpec::tiny(StreamAttn::LinearAdd, Lin::Shift);
    let model = StreamModel::tiny(StreamAttn::LinearAdd, Lin::Shift);
    let d = model.spec.dim;
    let mut s = model.begin();
    let floats = spec.state_floats();
    for i in 0..5 {
        model.extend(&mut s, &XorShift64::new(i).normals(16 * d));
        assert_eq!(spec.state_floats(), floats, "state must not grow with tokens");
    }
    assert_eq!(s.tokens_seen, 80);
}

// ---------------------------------------------------------------------------
// 2. Multi-session interleaving through the continuous batcher
// ---------------------------------------------------------------------------

#[test]
fn interleaved_sessions_through_batcher_match_solo_bit_exactly() {
    let model = StreamModel::tiny(StreamAttn::LinearAdd, Lin::Shift);
    let d = model.spec.dim;
    // Mixed lengths force sessions to join/leave the fused batch mid-flight.
    let lens = [9usize, 4, 13, 6, 2];
    let seqs: Vec<Vec<f32>> = lens
        .iter()
        .enumerate()
        .map(|(i, &n)| XorShift64::new(0xAB + i as u64).normals(n * d))
        .collect();
    let solo: Vec<Vec<f32>> = seqs.iter().map(|s| model.forward_full(s)).collect();

    let mut engine = SessionEngine::new(model, 3, 3);
    let tickets: Vec<_> = seqs.iter().map(|s| engine.submit(s.clone())).collect();
    let mut metrics = Metrics::default();
    let steps = engine.run_to_completion(&mut metrics);
    assert!(steps > 2, "workload must take several fused steps");
    for (i, t) in tickets.iter().enumerate() {
        let out = engine.poll(t).expect("all sessions completed");
        assert_eq!(out.tokens, lens[i]);
        assert_eq!(
            out.logits, solo[i],
            "session {i}: interleaved fused stepping diverged from solo"
        );
    }
    // occupancy + per-step token gauges populated by the engine
    assert_eq!(metrics.batch_occupancy.count() as usize, steps);
    assert_eq!(metrics.step_tokens.count() as usize, steps);
    assert!(metrics.live_sessions.max() <= 3.0);
}

// ---------------------------------------------------------------------------
// 3. Request-level backend contract + serve loops
// ---------------------------------------------------------------------------

#[test]
fn classify_serve_populates_occupancy_gauges() {
    let cfg = ServerConfig {
        requests: 10,
        max_batch: 4,
        batch_deadline_ms: 1.0,
        arrival_ms: 0.0,
        ..ServerConfig::default()
    };
    let backend = create_backend(&cfg).expect("native backend needs no artifacts");
    let report = serve_backend(backend.as_ref(), &cfg).unwrap();
    assert_eq!(report.metrics.requests, 10);
    let occ = report.occupancy.as_ref().expect("steps ran");
    assert!(occ.mean > 0.0 && occ.mean <= 1.0);
    let tok = report.step_tokens.as_ref().expect("steps ran");
    assert!(tok.mean > 0.0);
    assert!(report.latency.p99 >= report.latency.p50);
}

#[test]
fn stream_serve_end_to_end() {
    let cfg = ServerConfig {
        requests: 5,
        stream_tokens: 12,
        stream_chunk: 4,
        max_live: 2,
        workload: Workload::Stream,
        ..ServerConfig::default()
    };
    let report = serve_stream(&cfg).unwrap();
    assert_eq!(report.sessions, 5);
    let expected: usize = stream_workload_lens(5, 12).iter().sum();
    assert_eq!(report.total_tokens, expected);
    assert!(report.tokens_per_sec > 0.0);
    assert!(report.steps > 0);
    let occ = report.occupancy.as_ref().expect("engine stepped");
    assert!(occ.mean > 0.0 && occ.mean <= 1.0);
    assert_eq!(report.metrics.requests, 5);
    // plan-time chosen-backend gauge populated by the native engine
    assert!(
        !report.metrics.chosen_backends.is_empty(),
        "stream serve must report which kernel backends were planned"
    );
}

#[test]
fn stream_arrival_schedule_is_deterministic_and_monotone() {
    let a = stream_arrival_schedule(16, 5.0, 42);
    assert_eq!(a, stream_arrival_schedule(16, 5.0, 42), "same seed, same schedule");
    // (seed 40 differs from 42 in a bit XorShift64's seed mask keeps)
    assert_ne!(a, stream_arrival_schedule(16, 5.0, 40), "seed changes the draw");
    assert_eq!(a.len(), 16);
    assert_eq!(a[0], 0.0, "first session arrives immediately");
    for w in a.windows(2) {
        assert!(w[1] >= w[0], "arrival offsets must be non-decreasing");
        let gap = w[1] - w[0];
        assert!((2.5..7.5).contains(&gap), "jitter spans mean·[0.5, 1.5): {gap}");
    }
    // closed-loop degenerate case: zero mean → everything at t=0
    assert!(stream_arrival_schedule(4, 0.0, 7).iter().all(|&t| t == 0.0));
}

#[test]
fn open_loop_stream_exercises_admission_control_under_pacing() {
    // Staggered arrivals (1 ms mean) against a 2-slot live cap: sessions
    // must trickle into the continuous batch as slots free up, and every
    // result must still come back (the engine's bit-exactness contract is
    // interleaving-invariant, so only completion + gauges need checking).
    let cfg = ServerConfig {
        requests: 6,
        stream_tokens: 10,
        stream_chunk: 4,
        max_live: 2,
        arrival_ms: 1.0,
        workload: Workload::Stream,
        ..ServerConfig::default()
    };
    let report = serve_stream(&cfg).unwrap();
    assert_eq!(report.sessions, 6);
    assert_eq!(report.metrics.requests, 6, "every paced session completed");
    assert!(
        report.metrics.live_sessions.max() <= 2.0,
        "admission control must cap live sessions"
    );
    assert!(report.steps > 0);
    assert_eq!(
        report.total_tokens,
        stream_workload_lens(6, 10).iter().sum::<usize>()
    );
    assert!(report.latency.p99 >= report.latency.p50);
}

// ---------------------------------------------------------------------------
// 4. Offline planner tables via ServerConfig
// ---------------------------------------------------------------------------

#[test]
fn planner_table_roundtrip_skips_startup_benchmarking() {
    let dir = std::env::temp_dir().join("savit_session_stream_test");
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join("planner_table.json");

    // 1. autotune online (model construction benchmarks every shape), dump
    let tuned = NativeBackend::tiny(Variant::SHIFTADD_MOE);
    let choices = tuned.model.planner.choices();
    assert!(!choices.is_empty());
    assert!(choices.iter().any(|c| !c.measured_ms.is_empty()));
    tuned.model.planner.save_table(&path).unwrap();

    // 2. cold-start through ServerConfig with the table pinned
    let cfg = ServerConfig {
        planner_table: Some(path.to_string_lossy().into_owned()),
        ..ServerConfig::default()
    };
    let cold = create_backend(&cfg).unwrap();
    let pinned = cold.planner_choices();
    assert_eq!(pinned.len(), choices.len());
    assert!(
        pinned.iter().all(|c| c.measured_ms.is_empty()),
        "pinned startup must not re-benchmark any shape"
    );

    // 3. same decisions -> same logits as the tuned backend
    let (xs, _) = shiftaddvit::data::synth_images::gen_batch(12, 2);
    let mut m = Metrics::default();
    use shiftaddvit::coordinator::backend::InferenceBackend;
    let a = tuned.run_batch(&xs, 2, &mut m).unwrap();
    let b = cold.run_batch(&xs, 2, &mut m).unwrap();
    assert_eq!(
        a.logits.as_f32().unwrap(),
        b.logits.as_f32().unwrap(),
        "pinned backend must be numerically identical"
    );

    // 4. a broken table fails loudly, not silently
    std::fs::write(dir.join("bad.json"), "{\"choices\": [{}]}").unwrap();
    let planner = Planner::new(Arc::new(KernelRegistry::with_defaults()));
    assert!(planner.load_table(&dir.join("bad.json")).is_err());
}
