//! Property suite over the kernel registry: every registered backend must
//! agree with the dense matmul oracle *on its own prepared weights* across
//! randomized PVT-ish shapes. The suite iterates the registry, so a future
//! backend registered in `KernelRegistry::with_defaults()` is covered
//! automatically — no test edits.

use std::sync::Arc;

use shiftaddvit::kernels::api::{Primitive, RawWeights};
use shiftaddvit::kernels::matmul::matmul_naive;
use shiftaddvit::kernels::planner::{Planner, Shape};
use shiftaddvit::kernels::registry::KernelRegistry;
use shiftaddvit::util::prop::{assert_close, check};

/// out = run(prepare(w), prepare_operand(x)) ≈ x @ prepare(w).dense(),
/// within each backend's self-declared tolerance.
#[test]
fn every_backend_matches_the_dense_oracle() {
    let registry = KernelRegistry::with_defaults();
    assert!(registry.len() >= 11, "registry unexpectedly small");
    for kernel in registry.iter() {
        check(&format!("oracle-{}", kernel.id()), 10, 10, |rng, size| {
            let (m, k, n) = (size + 2, size + 3, size + 1);
            // Halved weights keep pow2 exponents small so the INT8
            // activation error budget holds with margin (seed-test idiom).
            let wf: Vec<f32> = rng.normals(k * n).iter().map(|v| v * 0.5).collect();
            let raw = RawWeights::new(wf, k, n);
            let x = rng.normals(m * k);
            let w = kernel.prepare(&raw);
            let op = kernel.prepare_operand(&x, m, k);
            let mut out = vec![0.0f32; m * n];
            kernel.run(&w, &op, &mut out);
            let want = matmul_naive(&x, &w.dense(), m, k, n);
            assert_close(&out, &want, kernel.tolerance())
        });
    }
}

/// The row-parallel backends chunk by rows without changing per-row
/// accumulation order, so they must be *bit-identical* to their serial
/// counterparts — including at sizes large enough to actually fan out.
#[test]
fn rowpar_backends_match_serial_bit_exactly() {
    let registry = KernelRegistry::with_defaults();
    for (par_id, serial_id) in [
        ("matshift/rowpar", "matshift/planes"),
        ("matadd/rowpar", "matadd/bitplane"),
    ] {
        let par = registry.lookup(par_id).expect(par_id);
        let serial = registry.lookup(serial_id).expect(serial_id);
        check(&format!("exact-{par_id}"), 8, 8, |rng, size| {
            // m spans both the serial fallback (< 32 rows) and the pool path
            let (m, k, n) = (size * 24 + 7, size + 4, size + 2);
            let raw = RawWeights::new(rng.normals(k * n), k, n);
            let x = rng.normals(m * k);
            let (wp, ws) = (par.prepare(&raw), serial.prepare(&raw));
            let (op, os) = (par.prepare_operand(&x, m, k), serial.prepare_operand(&x, m, k));
            let mut yp = vec![0.0f32; m * n];
            let mut ys = vec![0.0f32; m * n];
            par.run(&wp, &op, &mut yp);
            serial.run(&ws, &os, &mut ys);
            if yp != ys {
                return Err(format!("{par_id} diverged from {serial_id} at m={m}"));
            }
            Ok(())
        });
    }
}

/// Planner end-to-end over the registry: it must return a registered
/// backend of the right primitive for every primitive, cache per shape, and
/// honour pins.
#[test]
fn planner_returns_registered_backends_for_every_primitive() {
    let registry = Arc::new(KernelRegistry::with_defaults());
    let planner = Planner::new(registry.clone());
    let shape = Shape::new(12, 10, 8);
    for p in Primitive::ALL {
        let chosen = planner.choose(p, shape);
        assert_eq!(chosen.primitive(), p);
        assert!(
            registry.lookup(&chosen.id()).is_some(),
            "{} not registered",
            chosen.id()
        );
    }
    assert_eq!(planner.choices().len(), Primitive::ALL.len());
    // pins survive alongside benchmarked choices
    planner.pin(Primitive::MatShift, shape, "rowpar");
    assert_eq!(
        planner.choose(Primitive::MatShift, shape).id(),
        "matshift/rowpar"
    );
}

/// `tolerance()` must be an honest bound: backends that quantize
/// activations declare a wider budget than exact ones.
#[test]
fn shift_backends_declare_quantization_tolerance() {
    let registry = KernelRegistry::with_defaults();
    for kernel in registry.iter() {
        if kernel.primitive() == Primitive::MatShift {
            assert!(kernel.tolerance() > 1e-3, "{}", kernel.id());
        } else {
            assert!(kernel.tolerance() <= 1e-3, "{}", kernel.id());
        }
    }
}
