//! Coordinator integration: the full serving pipeline (stem → blocks with
//! real sparse MoE dispatch → head) against the dense single-HLO model.

use shiftaddvit::coordinator::config::{DispatchMode, ServerConfig};
use shiftaddvit::coordinator::metrics::Metrics;
use shiftaddvit::coordinator::scheduler::MoePipeline;
use shiftaddvit::coordinator::server::serve;
use shiftaddvit::data::synth_images;
use shiftaddvit::runtime::artifact::Manifest;
use shiftaddvit::runtime::engine::Engine;
use shiftaddvit::runtime::tensor::Tensor;

fn manifest_or_skip() -> Option<Manifest> {
    if !Manifest::available() {
        eprintln!("SKIP: artifacts missing — run `make artifacts`");
        return None;
    }
    let m = Manifest::load(&Manifest::default_dir()).unwrap();
    if m.serve.is_none() {
        eprintln!("SKIP: no serving topology in manifest");
        return None;
    }
    Some(m)
}

/// The decomposed pipeline with sparse dispatch must reproduce the dense
/// single-HLO forward of the same variant (identical weights are baked into
/// both at AOT time).
#[test]
fn pipeline_matches_dense_model() {
    let Some(m) = manifest_or_skip() else { return };
    let serve_cfg = m.serve.clone().unwrap();
    let dense_name = format!(
        "cls_{}_{}_bs1",
        serve_cfg.model,
        m.root
            .get("serve")
            .and_then(|s| s.get("variant"))
            .and_then(|v| v.as_str())
            .unwrap_or("add_quant_moe_both")
    );
    let engine = Engine::new(m.clone()).unwrap();
    if engine.manifest().get(&dense_name).is_err() {
        eprintln!("SKIP: {dense_name} not lowered");
        return;
    }
    let pipeline = MoePipeline::new(&m, DispatchMode::Real).unwrap();
    pipeline.warmup().unwrap();
    let mut metrics = Metrics::default();
    for seed in [11u32, 222, 3333] {
        let s = synth_images::gen_image(seed);
        let out = pipeline.run_batch(&s.pixels, 1, &mut metrics).unwrap();
        let dense = engine
            .call(
                &dense_name,
                &[Tensor::f32(vec![1, 32, 32, 3], s.pixels.clone())],
            )
            .unwrap();
        let (a, b) = (
            out.logits.as_f32().unwrap(),
            dense[0].as_f32().unwrap(),
        );
        for (x, y) in a.iter().zip(b) {
            assert!(
                (x - y).abs() < 2e-3,
                "seed {seed}: pipeline {x} vs dense {y}"
            );
        }
    }
}

/// All three dispatch modes must agree numerically (they only differ in
/// scheduling/timing).
#[test]
fn dispatch_modes_agree() {
    let Some(m) = manifest_or_skip() else { return };
    let s = synth_images::gen_image(42);
    let mut logits = Vec::new();
    for mode in [DispatchMode::Real, DispatchMode::Modularized, DispatchMode::Dense] {
        let pipeline = MoePipeline::new(&m, mode).unwrap();
        let mut metrics = Metrics::default();
        let out = pipeline.run_batch(&s.pixels, 1, &mut metrics).unwrap();
        logits.push(out.logits.as_f32().unwrap().to_vec());
    }
    for other in &logits[1..] {
        for (x, y) in logits[0].iter().zip(other) {
            assert!((x - y).abs() < 2e-3, "{x} vs {y}");
        }
    }
}

/// Batched execution must agree with per-image execution (padding rows must
/// not leak into real outputs).
#[test]
fn batching_is_transparent() {
    let Some(m) = manifest_or_skip() else { return };
    let pipeline = MoePipeline::new(&m, DispatchMode::Real).unwrap();
    pipeline.warmup().unwrap();
    let mut metrics = Metrics::default();
    let n = 3; // pads to bucket 4
    let (xs, _) = synth_images::gen_batch(500, n);
    let batched = pipeline.run_batch(&xs, n, &mut metrics).unwrap();
    for i in 0..n {
        let s = synth_images::gen_image(500 + i as u32);
        let single = pipeline.run_batch(&s.pixels, 1, &mut metrics).unwrap();
        let a = &batched.logits.as_f32().unwrap()[i * 8..(i + 1) * 8];
        let b = single.logits.as_f32().unwrap();
        for (x, y) in a.iter().zip(b) {
            assert!((x - y).abs() < 2e-3, "img {i}: batched {x} vs single {y}");
        }
    }
}

/// End-to-end serve() smoke: batching, routing, metrics, accuracy counter.
#[test]
fn serve_end_to_end() {
    let Some(m) = manifest_or_skip() else { return };
    let cfg = ServerConfig {
        requests: 12,
        max_batch: 4,
        batch_deadline_ms: 1.0,
        dispatch: DispatchMode::Real,
        arrival_ms: 0.0,
        ..ServerConfig::default()
    };
    let report = serve(&m, &cfg).unwrap();
    assert_eq!(report.metrics.requests, 12);
    assert!(report.metrics.batches >= 3); // max_batch 4
    assert!(report.throughput_rps > 0.0);
    assert!(report.latency.p99 >= report.latency.p50);
    // routing happened
    let total_routed: usize = report.metrics.expert_tokens.iter().sum();
    assert!(total_routed > 0);
}
