//! Input-sensitivity + cross-language numerics regression.
//!
//! Guards against the constant-elision failure mode: `as_hlo_text()`
//! without `print_large_constants=True` elides baked weights as
//! `constant({...})`, which the text parser fills with zeros — every model
//! then produces input-INDEPENDENT outputs. These tests fail loudly if that
//! ever regresses.

use shiftaddvit::data::synth_images;
use shiftaddvit::runtime::artifact::Manifest;
use shiftaddvit::runtime::engine::Engine;
use shiftaddvit::runtime::tensor::Tensor;

fn engine_or_skip() -> Option<Engine> {
    if !Manifest::available() {
        eprintln!("SKIP: artifacts missing — run `make artifacts`");
        return None;
    }
    Some(Engine::from_default_dir().expect("engine"))
}

#[test]
fn classifier_outputs_depend_on_input() {
    let Some(e) = engine_or_skip() else { return };
    if e.manifest().get("cls_pvtv2_b0_msa_bs1").is_err() {
        return;
    }
    let (x1, _) = synth_images::gen_batch(1, 1);
    let (x2, _) = synth_images::gen_batch(99, 1);
    let a = e
        .call("cls_pvtv2_b0_msa_bs1", &[Tensor::f32(vec![1, 32, 32, 3], x1)])
        .unwrap();
    let b = e
        .call("cls_pvtv2_b0_msa_bs1", &[Tensor::f32(vec![1, 32, 32, 3], x2)])
        .unwrap();
    assert_ne!(
        a[0], b[0],
        "logits identical for different images — baked weights were elided \
         from the HLO text (see aot.py::to_hlo_text)"
    );
}

#[test]
fn artifact_has_no_elided_constants() {
    let Some(e) = engine_or_skip() else { return };
    for name in ["cls_pvtv2_b0_msa_bs1", "nvs_gnt_r256", "serve_head_bs1"] {
        if let Ok(meta) = e.manifest().get(name) {
            let text = std::fs::read_to_string(&meta.path).unwrap();
            assert!(
                !text.contains("{...}"),
                "{name}: HLO text contains elided constants"
            );
        }
    }
}

#[test]
fn nvs_outputs_depend_on_rays() {
    let Some(e) = engine_or_skip() else { return };
    if e.manifest().get("nvs_gnt_r256").is_err() {
        return;
    }
    let n = 256;
    let o = vec![0.0f32; n * 3];
    let mk = |dx: f32, dy: f32| {
        let mut d = vec![0.0f32; n * 3];
        for i in 0..n {
            d[i * 3] = dx;
            d[i * 3 + 1] = dy;
            d[i * 3 + 2] = 1.0;
        }
        d
    };
    let r1 = e
        .call(
            "nvs_gnt_r256",
            &[
                Tensor::f32(vec![n, 3], o.clone()),
                Tensor::f32(vec![n, 3], mk(0.5, 0.5)),
            ],
        )
        .unwrap();
    let r2 = e
        .call(
            "nvs_gnt_r256",
            &[
                Tensor::f32(vec![n, 3], o),
                Tensor::f32(vec![n, 3], mk(-0.5, -0.2)),
            ],
        )
        .unwrap();
    assert_ne!(r1[0], r2[0], "NVS output ignores ray directions");
}
