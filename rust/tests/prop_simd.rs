//! Property suite for the SIMD kernel subsystem (`kernels::simd`): the
//! correctness contract is **bit-exactness vs `matadd/ref` and
//! `matshift/ref` on every shape** — odd dimensions, non-multiple-of-
//! lane-width k/n, every KSH bit width the attention path uses, grouped
//! dispatch, and the forced portable fallback (`SHIFTADD_NO_SIMD=1`; CI
//! runs this suite in both modes).

use std::sync::Arc;

use shiftaddvit::infer::attn::{
    hamming_linear_attn_batched, hamming_linear_attn_kernel, hamming_linear_attn_ref,
};
use shiftaddvit::kernels::api::{LinearKernel, Operand, RawWeights};
use shiftaddvit::kernels::matadd::PackedPm1;
use shiftaddvit::kernels::matshift::ShiftPlanes;
use shiftaddvit::kernels::parallel::MIN_PAR_ROWS;
use shiftaddvit::kernels::registry::KernelRegistry;
use shiftaddvit::kernels::simd::{self, SimdLevel};
use shiftaddvit::kernels::{matadd, matshift};
use shiftaddvit::quant::pow2;
use shiftaddvit::util::prop::check;
use shiftaddvit::util::rng::XorShift64;

fn pm1(rng: &mut XorShift64, len: usize) -> Vec<i8> {
    (0..len)
        .map(|_| if rng.uniform() < 0.5 { -1 } else { 1 })
        .collect()
}

fn int8_ops(rng: &mut XorShift64, len: usize) -> Vec<i32> {
    (0..len).map(|_| rng.range(0, 255) as i32 - 127).collect()
}

/// The deliberately awkward shape grid: boundaries of the 8-lane column
/// blocks, the 4-lane NEON MatShift tile, the 32-wide k-tiling, and the
/// pool fan-out threshold.
fn shape_grid() -> Vec<(usize, usize, usize)> {
    let mut grid = Vec::new();
    for &m in &[1usize, 3, MIN_PAR_ROWS - 1, MIN_PAR_ROWS, MIN_PAR_ROWS * 2 + 3] {
        for &(k, n) in &[(1usize, 1usize), (5, 7), (31, 9), (32, 8), (33, 17), (64, 16)] {
            grid.push((m, k, n));
        }
    }
    grid
}

// ---------------------------------------------------------------------------
// Backend-level bit-exactness vs the /ref oracles
// ---------------------------------------------------------------------------

/// `matadd/simd` ≡ `matadd/ref` (bit-exact) on the full shape grid: same
/// ±1 codes, identical per-element accumulation order, so the outputs are
/// equal as bit patterns, not merely close.
#[test]
fn matadd_simd_bit_exact_vs_ref_on_shape_grid() {
    let registry = KernelRegistry::with_defaults();
    let simd_k = registry.lookup("matadd/simd").expect("registered");
    let ref_k = registry.lookup("matadd/ref").expect("registered");
    let mut rng = XorShift64::new(0x51D0);
    for (m, k, n) in shape_grid() {
        let x = rng.normals(m * k);
        // ±1 raw weights: ref ternarizes, simd binarizes — identical codes
        let raw = RawWeights::new(
            pm1(&mut rng, k * n).iter().map(|&v| v as f32).collect(),
            k,
            n,
        );
        let mut got = vec![0.0f32; m * n];
        let mut want = vec![0.0f32; m * n];
        simd_k.run(
            &simd_k.prepare(&raw),
            &simd_k.prepare_operand(&x, m, k),
            &mut got,
        );
        ref_k.run(
            &ref_k.prepare(&raw),
            &ref_k.prepare_operand(&x, m, k),
            &mut want,
        );
        assert_eq!(got, want, "matadd/simd diverged from /ref at {m}x{k}x{n}");
    }
}

/// `matshift/simd` ≡ `matshift/ref` (bit-exact) on the full shape grid
/// under one shared INT8 operand: identical i64 accumulators (integer
/// arithmetic, the i32 tiles cannot wrap under the INT8 operand contract),
/// identical dequantization.
#[test]
fn matshift_simd_bit_exact_vs_ref_on_shape_grid() {
    let registry = KernelRegistry::with_defaults();
    let simd_k = registry.lookup("matshift/simd").expect("registered");
    let ref_k = registry.lookup("matshift/ref").expect("registered");
    let mut rng = XorShift64::new(0x51D1);
    for (m, k, n) in shape_grid() {
        let x = rng.normals(m * k);
        let raw = RawWeights::new(rng.normals(k * n), k, n);
        let op = Operand::quantized(&x, m, k);
        let mut got = vec![0.0f32; m * n];
        let mut want = vec![0.0f32; m * n];
        simd_k.run(&simd_k.prepare(&raw), &op, &mut got);
        ref_k.run(&ref_k.prepare(&raw), &op, &mut want);
        assert_eq!(got, want, "matshift/simd diverged from /ref at {m}x{k}x{n}");
    }
}

// ---------------------------------------------------------------------------
// Every available instruction-set core vs the serial row cores
// ---------------------------------------------------------------------------

/// Each core the host can execute — portable always, plus AVX2/NEON where
/// detected — must be bit-identical to the serial row kernels on random
/// odd shapes and row sub-ranges (unavailable levels clamp to portable, so
/// iterating all three is safe everywhere).
#[test]
fn every_level_matches_serial_row_cores() {
    for level in [SimdLevel::Portable, SimdLevel::Avx2, SimdLevel::Neon] {
        check(&format!("simd-level-{level:?}"), 16, 14, |rng, size| {
            let (m, k, n) = (size + 1, size * 2 + 3, size + 5);
            let x = rng.normals(m * k);
            let packed = PackedPm1::pack(&pm1(rng, k * n), k, n);
            let a = simd::matadd_pm1_rows_at(level, &x, &packed, 0, m);
            if a != matadd::matadd_pm1_rows(&x, &packed, 0, m) {
                return Err(format!("matadd {level:?} diverged at {m}x{k}x{n}"));
            }
            // sub-range (the pool-chunk unit)
            let r0 = m / 2;
            if simd::matadd_pm1_rows_at(level, &x, &packed, r0, m)
                != matadd::matadd_pm1_rows(&x, &packed, r0, m)
            {
                return Err(format!("matadd {level:?} row range diverged"));
            }
            let xq = int8_ops(rng, m * k);
            let planes = ShiftPlanes::from_pow2(&pow2::quantize(&rng.normals(k * n), k, n));
            if simd::matshift_rows_at(level, &xq, &planes, 0, m)
                != matshift::matshift_fast_rows(&xq, &planes, 0, m)
            {
                return Err(format!("matshift {level:?} diverged at {m}x{k}x{n}"));
            }
            Ok(())
        });
    }
}

// ---------------------------------------------------------------------------
// Grouped dispatch ≡ per-group
// ---------------------------------------------------------------------------

/// `run_grouped` on the simd backends — including the fork/join override —
/// must be bit-exact vs per-group `run`, across group counts and row
/// counts spanning the forked and per-group-pooled branches.
#[test]
fn simd_run_grouped_matches_per_group_dispatch() {
    let registry = KernelRegistry::with_defaults();
    for id in ["matadd/simd", "matshift/simd"] {
        let kernel = registry.lookup(id).expect(id);
        let mut rng = XorShift64::new(0x6709);
        for (g, m) in [(1usize, 3usize), (3, 5), (8, 2), (2, MIN_PAR_ROWS + 5)] {
            let (k, n) = (13, 11);
            let ws: Vec<_> = (0..g)
                .map(|_| kernel.prepare(&RawWeights::new(rng.normals(k * n), k, n)))
                .collect();
            let x = rng.normals(g * m * k);
            let mut fused = vec![0.0f32; g * m * n];
            kernel.run_grouped(&ws, &x, m, &mut fused);
            for (gi, w) in ws.iter().enumerate() {
                let op = kernel.prepare_operand(&x[gi * m * k..(gi + 1) * m * k], m, k);
                let mut solo = vec![0.0f32; m * n];
                kernel.run(w, &op, &mut solo);
                assert_eq!(
                    &fused[gi * m * n..(gi + 1) * m * n],
                    solo.as_slice(),
                    "{id}: grouped dispatch diverged at group {gi}/{g} (m={m})"
                );
            }
        }
    }
}

// ---------------------------------------------------------------------------
// KSH attention bit widths
// ---------------------------------------------------------------------------

/// The Hamming LinearAdd attention on `matadd/simd` is bit-exact vs the
/// readable oracle for every KSH code width the model family uses —
/// including widths straddling the 8-lane blocks — and the fused batched
/// entry point agrees per group.
#[test]
fn hamming_attention_on_simd_backend_is_bit_exact_for_all_ksh_widths() {
    let registry = KernelRegistry::with_defaults();
    let kernel: Arc<dyn LinearKernel> = registry.lookup("matadd/simd").expect("registered");
    let mut rng = XorShift64::new(0x4A11);
    for &bits in &[3usize, 7, 8, 15, 16, 17] {
        for &(n, d) in &[(5usize, 4usize), (16, 8), (23, 9)] {
            let qc = pm1(&mut rng, n * bits);
            let kc = pm1(&mut rng, n * bits);
            let v = rng.normals(n * d);
            let got = hamming_linear_attn_kernel(&kernel, &qc, &kc, &v, n, bits, d);
            let want = hamming_linear_attn_ref(&qc, &kc, &v, n, bits, d);
            assert_eq!(got, want, "bits={bits} n={n} d={d}");

            // fused batched path: 3 groups through two grouped dispatches
            let g = 3usize;
            let qcg = pm1(&mut rng, g * n * bits);
            let kcg = pm1(&mut rng, g * n * bits);
            let vg = rng.normals(g * n * d);
            let fused = hamming_linear_attn_batched(&kernel, &qcg, &kcg, &vg, n, bits, d);
            for gi in 0..g {
                let want = hamming_linear_attn_ref(
                    &qcg[gi * n * bits..(gi + 1) * n * bits],
                    &kcg[gi * n * bits..(gi + 1) * n * bits],
                    &vg[gi * n * d..(gi + 1) * n * d],
                    n,
                    bits,
                    d,
                );
                assert_eq!(
                    &fused[gi * n * d..(gi + 1) * n * d],
                    want.as_slice(),
                    "batched group {gi}, bits={bits}"
                );
            }
        }
    }
}

// ---------------------------------------------------------------------------
// Forced fallback (SHIFTADD_NO_SIMD)
// ---------------------------------------------------------------------------

/// The env override must force the portable level; without it, the active
/// level is whatever the hardware probe found. CI runs the whole suite
/// twice — default and `SHIFTADD_NO_SIMD=1` — so both sides of this branch
/// execute, and every bit-exactness test above runs on the portable cores
/// in the second pass.
#[test]
fn active_level_honors_the_no_simd_override() {
    use shiftaddvit::kernels::simd::detect;
    assert_eq!(detect::resolve_level(true), SimdLevel::Portable);
    assert_eq!(detect::resolve_level(false), detect::hardware_level());
    if detect::no_simd_env() {
        assert_eq!(
            simd::active_level(),
            SimdLevel::Portable,
            "SHIFTADD_NO_SIMD is set: the simd backends must run portable"
        );
    } else {
        assert_eq!(simd::active_level(), detect::hardware_level());
    }
}

/// Even with the hardware level active, the portable core is reachable
/// explicitly and agrees with the backend output (so a table or result
/// produced under `SHIFTADD_NO_SIMD=1` is interchangeable with one from
/// the vectorized path).
#[test]
fn portable_and_active_levels_are_interchangeable() {
    let mut rng = XorShift64::new(0xFA11);
    let (m, k, n) = (7, 19, 21);
    let x = rng.normals(m * k);
    let packed = PackedPm1::pack(&pm1(&mut rng, k * n), k, n);
    assert_eq!(
        simd::matadd_pm1_rows_at(SimdLevel::Portable, &x, &packed, 0, m),
        simd::matadd_pm1_rows_simd(&x, &packed, 0, m)
    );
    let xq = int8_ops(&mut rng, m * k);
    let planes = ShiftPlanes::from_pow2(&pow2::quantize(&rng.normals(k * n), k, n));
    assert_eq!(
        simd::matshift_rows_at(SimdLevel::Portable, &xq, &planes, 0, m),
        simd::matshift_rows_simd(&xq, &planes, 0, m)
    );
}
