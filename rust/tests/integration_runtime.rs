//! Runtime integration: load real artifacts, execute, validate numerics.
//! Tests skip (with a notice) when `make artifacts` has not been run.

use shiftaddvit::data::synth_images;
use shiftaddvit::runtime::artifact::Manifest;
use shiftaddvit::runtime::engine::Engine;
use shiftaddvit::runtime::tensor::Tensor;

fn engine_or_skip() -> Option<Engine> {
    if !Manifest::available() {
        eprintln!("SKIP: artifacts missing — run `make artifacts`");
        return None;
    }
    Some(Engine::from_default_dir().expect("engine"))
}

#[test]
fn manifest_loads_and_lists_artifacts() {
    let Some(engine) = engine_or_skip() else { return };
    let m = engine.manifest();
    assert!(!m.models.is_empty());
    // every referenced HLO file exists on disk
    for meta in m.models.values() {
        assert!(meta.path.exists(), "missing {:?}", meta.path);
    }
}

#[test]
fn classifier_executes_and_shapes_match() {
    let Some(engine) = engine_or_skip() else { return };
    let names: Vec<String> = engine
        .manifest()
        .by_kind("classifier")
        .iter()
        .map(|m| m.name.clone())
        .collect();
    assert!(!names.is_empty(), "no classifier artifacts");
    let name = &names[0];
    let meta = engine.manifest().get(name).unwrap();
    let bs = meta.inputs[0].shape[0];
    let (xs, _) = synth_images::gen_batch(1, bs);
    let out = engine
        .call(name, &[Tensor::f32(vec![bs, 32, 32, 3], xs)])
        .expect("execute");
    assert_eq!(out.len(), 1);
    assert_eq!(out[0].shape, vec![bs, 8]);
    assert!(out[0].as_f32().unwrap().iter().all(|v| v.is_finite()));
}

#[test]
fn pallas_lowered_model_matches_dense_lowering() {
    // The three-layer composition proof: the pallas-kernel HLO and the dense
    // HLO of the same variant+weights must produce (near-)identical logits.
    let Some(engine) = engine_or_skip() else { return };
    let pallas = "pallas_pvtv2_b0_add_quant_moe_both_bs1";
    let dense = "cls_pvtv2_b0_add_quant_moe_both_bs1";
    if engine.manifest().get(pallas).is_err() || engine.manifest().get(dense).is_err() {
        eprintln!("SKIP: pallas/dense pair not in manifest");
        return;
    }
    let (xs, _) = synth_images::gen_batch(77, 1);
    let input = Tensor::f32(vec![1, 32, 32, 3], xs);
    let a = engine.call(pallas, std::slice::from_ref(&input)).unwrap();
    let b = engine.call(dense, std::slice::from_ref(&input)).unwrap();
    let (av, bv) = (a[0].as_f32().unwrap(), b[0].as_f32().unwrap());
    for (x, y) in av.iter().zip(bv) {
        assert!((x - y).abs() < 1e-3, "pallas {x} vs dense {y}");
    }
}

#[test]
fn execution_is_deterministic() {
    let Some(engine) = engine_or_skip() else { return };
    let name = "cls_pvtv2_b0_msa_bs1";
    if engine.manifest().get(name).is_err() {
        eprintln!("SKIP: {name} not in manifest");
        return;
    }
    let (xs, _) = synth_images::gen_batch(3, 1);
    let input = Tensor::f32(vec![1, 32, 32, 3], xs);
    let a = engine.call(name, std::slice::from_ref(&input)).unwrap();
    let b = engine.call(name, std::slice::from_ref(&input)).unwrap();
    assert_eq!(a[0], b[0]);
}

#[test]
fn compile_cache_hits() {
    let Some(engine) = engine_or_skip() else { return };
    let name = engine.manifest().models.keys().next().unwrap().clone();
    let before = engine.cached();
    let _ = engine.load(&name).unwrap();
    let after_first = engine.cached();
    let _ = engine.load(&name).unwrap();
    assert_eq!(engine.cached(), after_first);
    assert!(after_first >= before);
}

#[test]
fn engine_worker_round_trip() {
    use shiftaddvit::runtime::worker::EngineWorker;
    if !Manifest::available() {
        eprintln!("SKIP: artifacts missing");
        return;
    }
    let manifest = Manifest::load(&Manifest::default_dir()).unwrap();
    let names: Vec<String> = manifest
        .by_kind("classifier")
        .iter()
        .map(|m| m.name.clone())
        .collect();
    if names.is_empty() {
        return;
    }
    let meta = manifest.get(&names[0]).unwrap();
    let bs = meta.inputs[0].shape[0];
    let worker = EngineWorker::spawn(0, manifest.clone());
    let (xs, _) = synth_images::gen_batch(10, bs);
    // two concurrent calls through the same worker
    let p1 = worker.call_async(&names[0], vec![Tensor::f32(vec![bs, 32, 32, 3], xs.clone())]);
    let p2 = worker.call_async(&names[0], vec![Tensor::f32(vec![bs, 32, 32, 3], xs)]);
    let r1 = p1.wait().unwrap();
    let r2 = p2.wait().unwrap();
    assert_eq!(r1[0], r2[0]);
}
