//! Socket-path integration tests for the HTTP front door: the probe
//! reports, classify ingress, and stream ingress served over a real
//! `TcpListener`, checked against the in-process contracts —
//!
//! 1. probe JSON over the wire matches the in-process `to_json()` shapes;
//! 2. a classify POST answers logits **bit-identical** to an in-process
//!    fleet serving the same pixels (seeded weights + every kernel backend
//!    bit-exact + shortest-roundtrip JSON numbers);
//! 3. malformed bodies, wrong methods, and unknown paths map to 4xx
//!    without wedging the server;
//! 4. `/stream` answers a chunked event stream ending in deterministic
//!    logits;
//! 5. chaos: killing a worker under live HTTP traffic completes every
//!    request on the survivors;
//! 6. (serving-path hardening) a zero-request serve run exits with an
//!    empty report instead of panicking in `Summary::from`.

use std::sync::Arc;
use std::time::{Duration, Instant};

use shiftaddvit::coordinator::backend::{InferenceBackend, NativeBackend};
use shiftaddvit::coordinator::batcher::Request;
use shiftaddvit::coordinator::config::{ServerConfig, Workload};
use shiftaddvit::coordinator::server::{serve_auto, serve_stream};
use shiftaddvit::coordinator::sessions::{SchedulerMode, SessionEngine};
use shiftaddvit::data::synth_images;
use shiftaddvit::fleet::http::{FrontDoorConfig, HttpFrontDoor};
use shiftaddvit::fleet::policy::PolicyKind;
use shiftaddvit::fleet::router::ReadinessReport;
use shiftaddvit::fleet::worker::BackendFactory;
use shiftaddvit::fleet::{Router, RouterConfig};
use shiftaddvit::infer::session::{SessionSpec, StreamAttn, StreamModel};
use shiftaddvit::kernels::planner::Planner;
use shiftaddvit::kernels::registry::KernelRegistry;
use shiftaddvit::model::ops::{Lin, Variant};
use shiftaddvit::util::httpd;
use shiftaddvit::util::json::Json;
use shiftaddvit::util::rng::XorShift64;

const CLIENT_TIMEOUT: Duration = Duration::from_secs(120);

fn factory() -> BackendFactory {
    Arc::new(|| {
        let b: Box<dyn InferenceBackend> = Box::new(NativeBackend::tiny(Variant::SHIFTADD_MOE));
        Ok(b)
    })
}

fn fleet(workers: usize, max_batch: usize, step_delay_ms: f64) -> Router {
    Router::new(
        RouterConfig {
            workers,
            max_batch,
            policy: PolicyKind::RoundRobin,
            step_delay_ms,
            ..RouterConfig::default()
        },
        factory(),
    )
    .expect("fleet starts")
}

fn stream_engine() -> SessionEngine {
    let planner = Arc::new(Planner::new(Arc::new(KernelRegistry::with_defaults())));
    let model = StreamModel::new(SessionSpec::tiny(StreamAttn::LinearAdd, Lin::Shift), planner);
    SessionEngine::with_mode(model, 4, 4, SchedulerMode::Disaggregated { prefill_budget: 16 })
}

fn door_cfg() -> FrontDoorConfig {
    FrontDoorConfig {
        handlers: 8,
        request_timeout: CLIENT_TIMEOUT,
        io_timeout: Duration::from_secs(60),
        ..FrontDoorConfig::default()
    }
}

fn get(addr: std::net::SocketAddr, path: &str) -> httpd::HttpResponse {
    httpd::request(addr, "GET", path, None, CLIENT_TIMEOUT).expect("GET")
}

fn post(addr: std::net::SocketAddr, path: &str, body: &str) -> httpd::HttpResponse {
    httpd::request(addr, "POST", path, Some(body.as_bytes()), CLIENT_TIMEOUT).expect("POST")
}

fn classify_body(pixels: &[f32], label: Option<usize>) -> String {
    let mut rows = vec![(
        "pixels",
        Json::Arr(pixels.iter().map(|&p| Json::Num(p as f64)).collect()),
    )];
    if let Some(l) = label {
        rows.push(("label", Json::num(l as f64)));
    }
    Json::obj(rows).to_string()
}

fn logits_f32(j: &Json, key: &str) -> Vec<f32> {
    j.get(key)
        .and_then(|v| v.as_arr())
        .expect("logits array")
        .iter()
        .map(|v| v.as_f64().expect("logit is a number") as f32)
        .collect()
}

#[test]
fn probes_over_http_match_in_process_shapes() {
    let door = HttpFrontDoor::start(fleet(2, 4, 0.0), None, "127.0.0.1:0", door_cfg()).unwrap();
    let addr = door.addr();

    let live = get(addr, "/liveness");
    assert_eq!(live.status, 200);
    let j = Json::parse(live.text().unwrap()).unwrap();
    assert_eq!(j.get("live").and_then(|v| v.as_str()), Some("true"));
    let rows = j.get("workers").and_then(|v| v.as_arr()).unwrap();
    assert_eq!(rows.len(), 2);
    for row in rows {
        assert_eq!(row.get("state").and_then(|v| v.as_str()), Some("ready"));
    }

    // Readiness has no heartbeat-varying fields, so the wire bytes must be
    // EXACTLY the in-process report's serialization.
    let ready = get(addr, "/readiness");
    assert_eq!(ready.status, 200);
    let want = ReadinessReport {
        total: 2,
        ready_workers: 2,
        ready: true,
        bundle_digest: None,
    }
    .to_json()
    .to_string();
    assert_eq!(ready.text().unwrap(), want);

    let metrics = get(addr, "/metrics");
    assert_eq!(metrics.status, 200);
    let j = Json::parse(metrics.text().unwrap()).unwrap();
    assert_eq!(
        j.get("policy").and_then(|v| v.as_str()),
        Some("round-robin")
    );
    assert_eq!(j.get("workers").and_then(|v| v.as_arr()).unwrap().len(), 2);
    assert!(j.get("engine").is_some());
    assert!(j.get("front_door").is_some(), "http ingress section");

    door.shutdown().unwrap();
}

#[test]
fn classify_over_http_is_bit_identical_to_in_process() {
    // In-process baseline: a separately built fleet (same seeded weights;
    // every kernel backend is bit-exact, so planner choices can't diverge
    // the numbers).
    let sample = synth_images::gen_image(42_424);
    let mut baseline = fleet(1, 4, 0.0);
    let ticket = baseline
        .submit(Request {
            id: 0,
            pixels: sample.pixels.clone(),
            label: Some(sample.label),
            arrived: Instant::now(),
            trace: shiftaddvit::obs::trace::TraceCtx::NONE,
        })
        .unwrap();
    let want = baseline.poll_wait(&ticket, CLIENT_TIMEOUT).unwrap();
    baseline.shutdown().unwrap();

    let door = HttpFrontDoor::start(fleet(2, 4, 0.0), None, "127.0.0.1:0", door_cfg()).unwrap();
    let resp = post(
        door.addr(),
        "/classify",
        &classify_body(&sample.pixels, Some(sample.label)),
    );
    assert_eq!(resp.status, 200, "body: {}", resp.text().unwrap_or(""));
    let j = Json::parse(resp.text().unwrap()).unwrap();
    assert_eq!(j.get("id").and_then(|v| v.as_usize()), Some(0));
    let got = logits_f32(&j, "logits");
    assert_eq!(got, want.logits, "logits must survive the socket exactly");
    let pred = j.get("pred").and_then(|v| v.as_usize()).unwrap();
    assert!(pred < synth_images::NUM_CLASSES);

    // The ingress audit trail saw the request (reported as a bounded
    // recent window, so a long-running /metrics response can't grow).
    let m = Json::parse(get(door.addr(), "/metrics").text().unwrap()).unwrap();
    let front = m.get("front_door").unwrap();
    assert_eq!(front.get("requests").and_then(|v| v.as_usize()), Some(1));
    assert_eq!(
        front.get("recent_request_ids").unwrap().usize_vec().unwrap(),
        vec![0]
    );
    assert!(front.get("request_ids").is_none(), "full id list must not ship");

    door.shutdown().unwrap();
}

#[test]
fn malformed_requests_map_to_4xx_without_wedging() {
    let door = HttpFrontDoor::start(
        fleet(1, 4, 0.0),
        Some(stream_engine()),
        "127.0.0.1:0",
        door_cfg(),
    )
    .unwrap();
    let addr = door.addr();

    assert_eq!(post(addr, "/classify", "this is not json").status, 400);
    assert_eq!(
        post(addr, "/classify", r#"{"pixels": [1.0, 2.0, 3.0]}"#).status,
        400,
        "wrong pixel count"
    );
    assert_eq!(
        post(addr, "/classify", r#"{"nope": true}"#).status,
        400,
        "missing pixels key"
    );
    assert_eq!(post(addr, "/stream", r#"{"tokens": [1.0]}"#).status, 400);
    assert_eq!(post(addr, "/stream", "garbage").status, 400);
    assert_eq!(get(addr, "/classify").status, 405, "wrong method");
    assert_eq!(get(addr, "/no-such-route").status, 404);

    // Every error body carries a machine-readable reason.
    let resp = post(addr, "/classify", "not json");
    assert!(Json::parse(resp.text().unwrap())
        .unwrap()
        .get("error")
        .is_some());

    // None of that wedged the server.
    assert_eq!(get(addr, "/readiness").status, 200);
    door.shutdown().unwrap();
}

#[test]
fn stream_over_http_sends_progress_then_deterministic_done() {
    let door = HttpFrontDoor::start(
        fleet(1, 4, 0.0),
        Some(stream_engine()),
        "127.0.0.1:0",
        door_cfg(),
    )
    .unwrap();
    let dim = SessionSpec::tiny(StreamAttn::LinearAdd, Lin::Shift).dim;
    let n_tokens = 6usize;
    let tokens: Vec<f32> = XorShift64::new(0x70C0).normals(n_tokens * dim);
    let body = Json::obj(vec![(
        "tokens",
        Json::Arr(tokens.iter().map(|&t| Json::Num(t as f64)).collect()),
    )])
    .to_string();

    let run = |addr| {
        let resp = post(addr, "/stream", &body);
        assert_eq!(resp.status, 200);
        assert_eq!(
            resp.header("transfer-encoding").map(str::to_ascii_lowercase),
            Some("chunked".to_string())
        );
        let events: Vec<Json> = resp
            .text()
            .unwrap()
            .lines()
            .map(|l| Json::parse(l).expect("each chunk line is JSON"))
            .collect();
        assert!(!events.is_empty());
        // progress strictly advances, then exactly one final done event
        let mut last_fed = 0usize;
        for e in &events[..events.len() - 1] {
            assert_eq!(e.get("event").and_then(|v| v.as_str()), Some("progress"));
            let fed = e.get("fed").and_then(|v| v.as_usize()).unwrap();
            assert!(fed > last_fed, "progress must advance ({fed} vs {last_fed})");
            assert_eq!(
                e.get("total").and_then(|v| v.as_usize()),
                Some(n_tokens),
                "total is the session's token count"
            );
            last_fed = fed;
        }
        let done = events.last().unwrap();
        assert_eq!(done.get("event").and_then(|v| v.as_str()), Some("done"));
        assert_eq!(done.get("tokens").and_then(|v| v.as_usize()), Some(n_tokens));
        logits_f32(done, "logits")
    };

    let first = run(door.addr());
    assert!(!first.is_empty());
    let second = run(door.addr());
    assert_eq!(first, second, "same tokens, same engine, same logits");
    door.shutdown().unwrap();
}

#[test]
fn killing_a_worker_under_live_http_traffic_loses_nothing() {
    // Slow steps + batch-of-1 hold requests in flight long enough for the
    // kill to strand some of them mid-service.
    let door = HttpFrontDoor::start(fleet(3, 1, 40.0), None, "127.0.0.1:0", door_cfg()).unwrap();
    let addr = door.addr();
    let n = 8usize;
    let clients: Vec<_> = (0..n)
        .map(|i| {
            std::thread::spawn(move || {
                let sample = synth_images::gen_image(90_000 + i as u32);
                let resp = post(addr, "/classify", &classify_body(&sample.pixels, None));
                (i, resp)
            })
        })
        .collect();

    std::thread::sleep(Duration::from_millis(120));
    door.kill_worker(0).expect("worker 0 was alive");

    for c in clients {
        let (i, resp) = c.join().expect("client thread");
        assert_eq!(
            resp.status,
            200,
            "request {i} failed: {}",
            resp.text().unwrap_or("")
        );
        let j = Json::parse(resp.text().unwrap()).unwrap();
        assert_eq!(
            logits_f32(&j, "logits").len(),
            synth_images::NUM_CLASSES,
            "request {i} answered real logits"
        );
    }
    door.shutdown().unwrap();
}

#[test]
fn zero_request_serve_exits_with_an_empty_report() {
    // Regression: report builders called Summary::from on empty samples,
    // which used to assert. A serve run with no traffic must produce an
    // all-zero report, not a panic.
    let cfg = ServerConfig {
        requests: 0,
        ..ServerConfig::default()
    };
    let report = serve_auto(&cfg).expect("zero-request classify serve completes");
    assert_eq!(report.metrics.requests, 0);
    assert_eq!(report.latency.n, 0);
    assert_eq!(report.latency.p99, 0.0);
    assert_eq!(report.accuracy, 0.0);
    report.print(); // must not panic either

    let stream_cfg = ServerConfig {
        requests: 0,
        workload: Workload::Stream,
        ..ServerConfig::default()
    };
    let report = serve_stream(&stream_cfg).expect("zero-session stream serve completes");
    assert_eq!(report.sessions, 0);
    assert_eq!(report.latency.n, 0);
    assert_eq!(report.token_latency.n, 0);
    report.print();
}
