//! Bit-exactness property suite for the fused batched image-path attention
//! (the PR-4 tentpole): one fused kernel dispatch per primitive per layer
//! (`AttnExec::Fused`) must be **bit-exact** against the historical
//! image-by-image, head-by-head execution (`AttnExec::PerImage`) — for all
//! three attention families, at every batch size, head count, and odd token
//! count the generator draws — while issuing a constant number of kernel
//! dispatches per layer instead of `b·heads·4`.

use std::sync::Arc;

use shiftaddvit::data::synth_images;
use shiftaddvit::infer::attn::{
    hamming_linear_attn_batched, hamming_linear_attn_kernel, pack_heads, unpack_heads,
};
use shiftaddvit::infer::block::{AttnExec, BlockRaw, NativeBlock};
use shiftaddvit::infer::model::NativeModel;
use shiftaddvit::kernels::api::{Primitive, RawWeights};
use shiftaddvit::kernels::planner::Planner;
use shiftaddvit::kernels::registry::KernelRegistry;
use shiftaddvit::model::ops::{Attn, Variant};
use shiftaddvit::quant::ksh::KshHasher;
use shiftaddvit::util::prop::check;
use shiftaddvit::util::rng::XorShift64;

fn planner() -> Planner {
    Planner::new(Arc::new(KernelRegistry::with_defaults()))
}

// ---------------------------------------------------------------------------
// 1. Block level: fused forward ≡ per-image forward, all variants
// ---------------------------------------------------------------------------

/// One randomized case: build a block for `variant`, run the same input
/// through both execution modes, demand bit-identical activations and the
/// expected dispatch counts.
fn block_case(rng: &mut XorShift64, variant: Variant, case: u64) -> Result<(), String> {
    let b = [1usize, 2, 3, 5][rng.range(0, 4)];
    let heads = [1usize, 2, 4][rng.range(0, 3)];
    // Odd token counts: linear variants need a square grid for the DWConv
    // branch (3²=9, 5²=25 — both odd); MSA takes any count.
    let tokens = if variant.attn == Attn::Msa {
        [7usize, 9, 13][rng.range(0, 3)]
    } else {
        [9usize, 25][rng.range(0, 2)]
    };
    // dim = heads·hd with hd ∈ {2, 3, 5}, so the head_dim (and with it the
    // LinearAdd code width) is frequently non-power-of-two.
    let dim = heads * [2usize, 3, 5][rng.range(0, 3)];
    let p = planner();
    let raw = BlockRaw::random(rng, dim, dim * 2);
    let blk = NativeBlock::from_raw(raw, tokens, heads, variant, &p, &[16, 64], 0xC0DE + case);

    let x0 = rng.normals(b * tokens * dim);
    let mut fused = x0.clone();
    let tr_fused = blk.forward_with(&mut fused, b, AttnExec::Fused);
    let mut seq = x0;
    let tr_seq = blk.forward_per_image(&mut seq, b);
    if fused != seq {
        let bad = fused
            .iter()
            .zip(&seq)
            .position(|(a, b)| a != b)
            .unwrap_or(0);
        return Err(format!(
            "fused != per-image at elem {bad} (variant {variant:?}, b={b}, heads={heads}, \
             tokens={tokens}, dim={dim})"
        ));
    }
    let (want_fused, want_seq) = if variant.attn == Attn::LinearAdd {
        (2, b * heads * 4)
    } else {
        (0, 0)
    };
    if tr_fused.attn_dispatches != want_fused {
        return Err(format!(
            "fused path issued {} dispatches, want {want_fused}",
            tr_fused.attn_dispatches
        ));
    }
    if tr_seq.attn_dispatches != want_seq {
        return Err(format!(
            "per-image path issued {} dispatches, want {want_seq}",
            tr_seq.attn_dispatches
        ));
    }
    Ok(())
}

#[test]
fn fused_block_forward_is_bit_exact_msa() {
    let mut case = 0u64;
    check("fused-block-msa", 10, 6, |rng, _| {
        case += 1;
        block_case(rng, Variant::MSA, case)
    });
}

#[test]
fn fused_block_forward_is_bit_exact_linear() {
    let mut case = 0u64;
    check("fused-block-linear", 10, 6, |rng, _| {
        case += 1;
        block_case(rng, Variant::LINEAR, case)
    });
}

#[test]
fn fused_block_forward_is_bit_exact_linear_add() {
    let mut case = 0u64;
    check("fused-block-linear-add", 10, 6, |rng, _| {
        case += 1;
        block_case(rng, Variant::ADD, case)
    });
}

#[test]
fn fused_block_forward_is_bit_exact_full_reparameterization() {
    // The deployed mixtures ride the same fused path: shift attention
    // linears (ADD_SHIFT_BOTH) and the Mult/Shift MoE MLP (SHIFTADD_MOE).
    let mut case = 100u64;
    for variant in [Variant::ADD_SHIFT_BOTH, Variant::SHIFTADD_MOE] {
        check("fused-block-reparam", 6, 4, |rng, _| {
            case += 1;
            block_case(rng, variant, case)
        });
    }
}

// ---------------------------------------------------------------------------
// 2. Model level: fused classify ≡ per-image classify, dispatch gauges
// ---------------------------------------------------------------------------

#[test]
fn fused_model_forward_is_bit_exact_and_amortizes_dispatches() {
    let model = NativeModel::tiny(Variant::ADD);
    for b in [1usize, 3] {
        let (xs, _) = synth_images::gen_batch(1234 + b as u32, b);
        let (lf, tf) = model.forward_with(&xs, b, AttnExec::Fused);
        let (ls, ts) = model.forward_with(&xs, b, AttnExec::PerImage);
        assert_eq!(lf, ls, "logits diverged at batch {b}");
        assert_eq!(tf.blocks, 2);
        // fused: 2 grouped MatAdd dispatches per LinearAdd layer, batch-free
        assert_eq!(tf.attn_dispatches, 4, "batch {b}");
        // per-image: b·heads·4 per layer (tiny spec: heads 2 then 4)
        assert_eq!(ts.attn_dispatches, b * (2 + 4) * 4, "batch {b}");
    }
}

// ---------------------------------------------------------------------------
// 3. Kernel level: grouped dispatch ≡ per-group run, every MatAdd backend
// ---------------------------------------------------------------------------

#[test]
fn run_grouped_matches_per_group_runs_bit_exactly() {
    let registry = KernelRegistry::with_defaults();
    let mut rng = XorShift64::new(71);
    // covers the one-job-per-group fusion (small m), the serial fallback,
    // and the large-m delegation to row-chunked run()
    for (g, m, k, n) in [
        (1usize, 3usize, 5usize, 4usize),
        (4, 7, 9, 6),
        (13, 5, 8, 3),
        (2, 40, 6, 4),
    ] {
        let x = rng.normals(g * m * k);
        let raws: Vec<RawWeights> = (0..g)
            .map(|_| RawWeights::new(rng.normals(k * n), k, n))
            .collect();
        for kernel in registry.for_primitive(Primitive::MatAdd) {
            let ws: Vec<_> = raws.iter().map(|r| kernel.prepare(r)).collect();
            let mut fused = vec![0.0f32; g * m * n];
            kernel.run_grouped(&ws, &x, m, &mut fused);
            for gi in 0..g {
                let op = kernel.prepare_operand(&x[gi * m * k..(gi + 1) * m * k], m, k);
                let mut solo = vec![0.0f32; m * n];
                kernel.run(&ws[gi], &op, &mut solo);
                assert_eq!(
                    &fused[gi * m * n..(gi + 1) * m * n],
                    solo.as_slice(),
                    "{} group {gi} (G={g})",
                    kernel.id()
                );
            }
        }
    }
}

#[test]
fn batched_hamming_attention_matches_ref_on_odd_shapes() {
    // Non-power-of-two bits/hd and odd token counts through the fused
    // two-dispatch path, against the per-head kernel (itself oracle-exact).
    let registry = KernelRegistry::with_defaults();
    check("batched-hamming-odd", 8, 5, |rng, size| {
        let g = 1 + rng.range(0, 6);
        let n = 3 + 2 * size; // odd
        let d = [2usize, 3, 5, 6][rng.range(0, 4)];
        let bits = [3usize, 5, 7, 11][rng.range(0, 4)];
        let h = KshHasher::new(d, bits, 9 + size as u64);
        let q = rng.normals(g * n * d);
        let k = rng.normals(g * n * d);
        let v = rng.normals(g * n * d);
        let qc = h.hash_matrix(&q, g * n);
        let kc = h.hash_matrix(&k, g * n);
        for kernel in registry.for_primitive(Primitive::MatAdd) {
            let got = hamming_linear_attn_batched(&kernel, &qc, &kc, &v, n, bits, d);
            for gi in 0..g {
                let want = hamming_linear_attn_kernel(
                    &kernel,
                    &qc[gi * n * bits..(gi + 1) * n * bits],
                    &kc[gi * n * bits..(gi + 1) * n * bits],
                    &v[gi * n * d..(gi + 1) * n * d],
                    n,
                    bits,
                    d,
                );
                if got[gi * n * d..(gi + 1) * n * d] != want[..] {
                    return Err(format!(
                        "{} group {gi} diverged (g={g}, n={n}, d={d}, bits={bits})",
                        kernel.id()
                    ));
                }
            }
        }
        Ok(())
    });
}

#[test]
fn pack_heads_roundtrips_for_any_geometry() {
    check("pack-heads-roundtrip", 12, 6, |rng, size| {
        let b = 1 + rng.range(0, 5);
        let heads = [1usize, 2, 4][rng.range(0, 3)];
        let hd = 1 + size;
        let n = 3 + rng.range(0, 9);
        let x = rng.normals(b * n * heads * hd);
        let packed = pack_heads(&x, b, n, heads, hd);
        if unpack_heads(&packed, b, n, heads, hd) != x {
            return Err(format!("roundtrip broke (b={b}, heads={heads}, hd={hd}, n={n})"));
        }
        Ok(())
    });
}
