//! Fig. 5/8 — MatAdd kernel speedups over MatMul (PVT attention shapes).
use shiftaddvit::harness::figures;

fn main() {
    figures::fig5_matadd(1); // Fig. 5
    figures::fig5_matadd(4); // Fig. 8 companion
}
