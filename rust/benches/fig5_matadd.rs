//! Fig. 5/8 — MatAdd backend sweep over the KernelRegistry: human table
//! plus machine-readable per-backend JSON from the same measurements. New
//! backends registered in `KernelRegistry::with_defaults()` are benchmarked
//! without edits here.
use shiftaddvit::harness::figures;

fn main() {
    for batch in [1usize, 4] {
        // Fig. 5 at batch 1; Fig. 8 companion batched.
        let j = figures::fig5_matadd(batch);
        println!("{j}");
    }
}
