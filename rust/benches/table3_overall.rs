//! Table 3 — overall accuracy/latency/energy across the model zoo.
//! Latency cells fall back to the native engine when artifacts are absent.
use shiftaddvit::harness::overall;
use shiftaddvit::runtime::engine::Engine;

fn main() {
    let engine = Engine::from_default_dir().ok();
    if engine.is_none() {
        eprintln!("no artifacts — latency columns use the native engine");
    }
    overall::table3(engine.as_ref()).expect("table3");
}
