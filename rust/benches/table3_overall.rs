//! Table 3 — overall accuracy/latency/energy across the model zoo.
use shiftaddvit::harness::overall;
use shiftaddvit::runtime::engine::Engine;

fn main() {
    match Engine::from_default_dir() {
        Ok(engine) => overall::table3(&engine).expect("table3"),
        Err(e) => eprintln!("SKIP (run `make artifacts`): {e}"),
    }
}
