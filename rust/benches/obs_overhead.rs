//! Observability overhead: the same in-process classify traffic timed with
//! (a) tracing off, (b) the span ring recording, and (c) recording plus a
//! Chrome-JSON export per batch of requests. The deltas are the full cost
//! of the span plumbing on the serving path — target: ring-on throughput
//! within 2% of tracing-off. Emits a table and a trailing JSON object.

use std::sync::Arc;
use std::time::{Duration, Instant};

use shiftaddvit::coordinator::backend::{InferenceBackend, NativeBackend};
use shiftaddvit::coordinator::batcher::Request;
use shiftaddvit::data::synth_images;
use shiftaddvit::fleet::worker::BackendFactory;
use shiftaddvit::fleet::{Router, RouterConfig};
use shiftaddvit::model::ops::Variant;
use shiftaddvit::obs::trace as otrace;
use shiftaddvit::util::bench::{f1, f2, Table};
use shiftaddvit::util::json::Json;
use shiftaddvit::util::stats::Summary;

const REQUESTS: usize = 48;
const WORKERS: usize = 2;
const TIMEOUT: Duration = Duration::from_secs(120);

fn factory() -> BackendFactory {
    Arc::new(|| {
        let b: Box<dyn InferenceBackend> = Box::new(NativeBackend::tiny(Variant::SHIFTADD_MOE));
        Ok(b)
    })
}

fn fleet() -> Router {
    Router::new(
        RouterConfig {
            workers: WORKERS,
            max_batch: 4,
            ..RouterConfig::default()
        },
        factory(),
    )
    .expect("fleet starts")
}

/// Drive `REQUESTS` classify requests through an in-process fleet and
/// return (throughput req/s, latency summary, spans recorded).
fn run(mode: &str, export_each: usize) -> (f64, Summary, usize) {
    otrace::reset();
    let mut router = fleet();
    let mut lat = Vec::with_capacity(REQUESTS);
    let t0 = Instant::now();
    for id in 0..REQUESTS {
        let sample = synth_images::gen_image(9_100_000 + id as u32);
        let t = Instant::now();
        let root = otrace::root(mode);
        let ticket = router
            .submit(Request {
                id,
                pixels: sample.pixels,
                label: None,
                arrived: t,
                trace: root.ctx(),
            })
            .expect("submit");
        router.poll_wait(&ticket, TIMEOUT).expect("poll");
        drop(root);
        lat.push(t.elapsed().as_secs_f64() * 1e3);
        if export_each > 0 && (id + 1) % export_each == 0 {
            // live export, like a scraper hitting GET /trace mid-run
            let _ = otrace::export_chrome().to_string();
        }
    }
    let wall = t0.elapsed().as_secs_f64();
    router.shutdown().expect("fleet drains");
    (REQUESTS as f64 / wall, Summary::from(&lat), otrace::len())
}

fn main() {
    let mut table = Table::new(&["mode", "throughput (req/s)", "p50 (ms)", "p99 (ms)", "spans"]);
    let mut rows = Vec::new();

    // warmup run so planner autotuning doesn't land in any timed mode
    otrace::set_enabled(false);
    run("warmup", 0);

    let mut results = Vec::new();
    for (mode, enabled, export_each) in [
        ("tracing-off", false, 0usize),
        ("ring-on", true, 0),
        ("ring+export", true, 16),
    ] {
        otrace::set_enabled(enabled);
        let (rps, s, spans) = run(mode, export_each);
        otrace::set_enabled(false);
        table.row(&[
            mode.to_string(),
            f1(rps),
            f2(s.p50),
            f2(s.p99),
            spans.to_string(),
        ]);
        rows.push(Json::obj(vec![
            ("mode", Json::str(mode)),
            ("throughput_rps", Json::num(rps)),
            ("p50_ms", Json::num(s.p50)),
            ("p99_ms", Json::num(s.p99)),
            ("spans_recorded", Json::num(spans as f64)),
        ]));
        results.push((mode, rps));
    }
    otrace::reset();

    table.print("span-ring overhead on the in-process classify path");
    let off = results[0].1;
    let on = results[1].1;
    let overhead_pct = 100.0 * (off - on) / off;
    println!("ring-on overhead vs tracing-off: {overhead_pct:.2}% (target < 2%)");

    let json = Json::obj(vec![
        ("bench", Json::str("obs_overhead")),
        ("workers", Json::num(WORKERS as f64)),
        ("ring_on_overhead_pct", Json::num(overhead_pct)),
        ("modes", Json::Arr(rows)),
    ]);
    println!("\n{json}");
}
