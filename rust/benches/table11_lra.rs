//! Table 11 — LRA benchmark across attention families.
use shiftaddvit::harness::lra;
use shiftaddvit::runtime::engine::Engine;

fn main() {
    let engine = Engine::from_default_dir().ok();
    lra::table11(engine.as_ref()).expect("table11");
}
