//! Tables 4 & 6 — breakdown ladder for all four PVT models + the MoE
//! real-vs-modularized dual latency from the serving coordinator.
use shiftaddvit::harness::breakdown;
use shiftaddvit::runtime::engine::Engine;

fn main() {
    let engine = match Engine::from_default_dir() {
        Ok(e) => e,
        Err(e) => {
            eprintln!("SKIP (run `make artifacts`): {e}");
            return;
        }
    };
    for model in ["pvtv2_b0", "pvtv1_t", "pvtv2_b1", "pvtv2_b2"] {
        breakdown::breakdown(&engine, model).expect("breakdown");
    }
    breakdown::moe_dual_latency(engine.manifest(), 32).expect("dual latency");
}
