//! HTTP front-door overhead: the same classify traffic served (a) by the
//! in-process router submit/poll surface and (b) over a real TCP socket
//! through `fleet::http`, sequentially and with concurrent clients. The
//! delta is the full cost of the front door — parse, JSON body, dispatch,
//! condvar wait, serialize — per request. Emits a table and a trailing
//! JSON object for tooling.

use std::sync::Arc;
use std::time::{Duration, Instant};

use shiftaddvit::coordinator::backend::{InferenceBackend, NativeBackend};
use shiftaddvit::coordinator::batcher::Request;
use shiftaddvit::data::synth_images;
use shiftaddvit::fleet::http::{FrontDoorConfig, HttpFrontDoor};
use shiftaddvit::fleet::worker::BackendFactory;
use shiftaddvit::fleet::{Router, RouterConfig};
use shiftaddvit::model::ops::Variant;
use shiftaddvit::util::bench::{f1, f2, Table};
use shiftaddvit::util::httpd;
use shiftaddvit::util::json::Json;
use shiftaddvit::util::stats::Summary;

const REQUESTS: usize = 32;
const WORKERS: usize = 2;
const CONCURRENT_CLIENTS: usize = 4;
const TIMEOUT: Duration = Duration::from_secs(120);

fn factory() -> BackendFactory {
    Arc::new(|| {
        let b: Box<dyn InferenceBackend> = Box::new(NativeBackend::tiny(Variant::SHIFTADD_MOE));
        Ok(b)
    })
}

fn fleet() -> Router {
    Router::new(
        RouterConfig {
            workers: WORKERS,
            max_batch: 4,
            ..RouterConfig::default()
        },
        factory(),
    )
    .expect("fleet starts")
}

fn classify_body(pixels: &[f32]) -> String {
    Json::obj(vec![(
        "pixels",
        Json::Arr(pixels.iter().map(|&p| Json::Num(p as f64)).collect()),
    )])
    .to_string()
}

fn summary_row(table: &mut Table, mode: &str, s: &Summary, wall_s: f64) {
    table.row(&[
        mode.to_string(),
        f1(REQUESTS as f64 / wall_s),
        f2(s.p50),
        f2(s.p99),
    ]);
}

fn latency_json(mode: &str, s: &Summary, wall_s: f64) -> Json {
    Json::obj(vec![
        ("mode", Json::str(mode)),
        ("requests", Json::num(REQUESTS as f64)),
        ("throughput_rps", Json::num(REQUESTS as f64 / wall_s)),
        ("p50_ms", Json::num(s.p50)),
        ("p99_ms", Json::num(s.p99)),
        ("mean_ms", Json::num(s.mean)),
    ])
}

fn main() {
    let mut table = Table::new(&["mode", "throughput (req/s)", "p50 (ms)", "p99 (ms)"]);
    let mut rows = Vec::new();

    // --- in-process baseline ------------------------------------------------
    let mut router = fleet();
    let mut lat = Vec::with_capacity(REQUESTS);
    let t0 = Instant::now();
    for id in 0..REQUESTS {
        let sample = synth_images::gen_image(8_000_000 + id as u32);
        let t = Instant::now();
        let ticket = router
            .submit(Request {
                id,
                pixels: sample.pixels,
                label: None,
                arrived: t,
                trace: shiftaddvit::obs::trace::TraceCtx::NONE,
            })
            .expect("submit");
        router.poll_wait(&ticket, TIMEOUT).expect("poll");
        lat.push(t.elapsed().as_secs_f64() * 1e3);
    }
    let wall = t0.elapsed().as_secs_f64();
    router.shutdown().expect("fleet drains");
    let s = Summary::from(&lat);
    summary_row(&mut table, "in-process", &s, wall);
    rows.push(latency_json("in-process", &s, wall));

    // --- over the socket, one client ---------------------------------------
    let door = HttpFrontDoor::start(fleet(), None, "127.0.0.1:0", FrontDoorConfig::default())
        .expect("front door starts");
    let addr = door.addr();
    let mut lat = Vec::with_capacity(REQUESTS);
    let t0 = Instant::now();
    for id in 0..REQUESTS {
        let sample = synth_images::gen_image(8_000_000 + id as u32);
        let body = classify_body(&sample.pixels);
        let t = Instant::now();
        let resp = httpd::request(addr, "POST", "/classify", Some(body.as_bytes()), TIMEOUT)
            .expect("classify over http");
        assert_eq!(resp.status, 200);
        lat.push(t.elapsed().as_secs_f64() * 1e3);
    }
    let wall = t0.elapsed().as_secs_f64();
    let s = Summary::from(&lat);
    summary_row(&mut table, "http x1", &s, wall);
    rows.push(latency_json("http x1", &s, wall));

    // --- over the socket, concurrent clients --------------------------------
    let t0 = Instant::now();
    let handles: Vec<_> = (0..CONCURRENT_CLIENTS)
        .map(|c| {
            std::thread::spawn(move || {
                let mut lat = Vec::new();
                for i in 0..REQUESTS / CONCURRENT_CLIENTS {
                    let id = c * (REQUESTS / CONCURRENT_CLIENTS) + i;
                    let sample = synth_images::gen_image(8_000_000 + id as u32);
                    let body = classify_body(&sample.pixels);
                    let t = Instant::now();
                    let resp = httpd::request(
                        addr,
                        "POST",
                        "/classify",
                        Some(body.as_bytes()),
                        TIMEOUT,
                    )
                    .expect("classify over http");
                    assert_eq!(resp.status, 200);
                    lat.push(t.elapsed().as_secs_f64() * 1e3);
                }
                lat
            })
        })
        .collect();
    let mut lat = Vec::with_capacity(REQUESTS);
    for h in handles {
        lat.extend(h.join().expect("client thread"));
    }
    let wall = t0.elapsed().as_secs_f64();
    door.shutdown().expect("front door drains");
    let s = Summary::from(&lat);
    let label = format!("http x{CONCURRENT_CLIENTS}");
    summary_row(&mut table, &label, &s, wall);
    rows.push(latency_json(&label, &s, wall));

    table.print("HTTP front door vs in-process classify");

    let json = Json::obj(vec![
        ("bench", Json::str("http_front")),
        ("workers", Json::num(WORKERS as f64)),
        ("modes", Json::Arr(rows)),
    ]);
    println!("\n{json}");
}
