//! Fig. 4/7 — MatShift kernel speedups over MatMul/FakeShift (PVT shapes).
use shiftaddvit::harness::figures;

fn main() {
    figures::fig4_matshift(1); // Fig. 4 (batch 1)
    figures::fig4_matshift(4); // Fig. 7 companion (batched; paper uses 32)
}
