//! Fig. 4/7 — MatShift backend sweep over the KernelRegistry: human table
//! plus machine-readable per-backend JSON from the same measurements. New
//! backends registered in `KernelRegistry::with_defaults()` are benchmarked
//! without edits here.
use shiftaddvit::harness::figures;

fn main() {
    for batch in [1usize, 4] {
        // Fig. 4 at batch 1; Fig. 7 companion batched (paper uses 32).
        let j = figures::fig4_matshift(batch);
        println!("{j}");
    }
}
