//! Session streaming throughput: per-session sequential stepping vs fused
//! multi-session batched stepping (`StreamModel::extend_batch`), in
//! tokens/sec — the measured case for cross-request continuous batching:
//! one MatMul/MatShift dispatch per linear per layer per step, amortized
//! over every live session, instead of one dispatch chain per session.
//!
//! Part two is the scheduler sweep: open-loop short-session arrivals with
//! one adversarial long prompt injected mid-run, stepped under the legacy
//! single-phase scheduler vs the phase-disaggregated one at two prefill
//! budgets — the p99 per-token latency the disaggregation is judged on.
//! Emits both tables and a JSON object for tooling.

use std::sync::Arc;

use shiftaddvit::coordinator::metrics::Metrics;
use shiftaddvit::coordinator::sessions::{SchedulerMode, SessionEngine, StreamTicket};
use shiftaddvit::infer::session::{SessionSpec, SessionState, StreamAttn, StreamModel};
use shiftaddvit::kernels::planner::Planner;
use shiftaddvit::kernels::registry::KernelRegistry;
use shiftaddvit::model::ops::Lin;
use shiftaddvit::util::bench::{f1, f2, time_ms};
use shiftaddvit::util::json::Json;
use shiftaddvit::util::rng::XorShift64;
use shiftaddvit::util::stats::Summary;

const TOKENS: usize = 64;
const CHUNK: usize = 8;

// --- adversarial scheduler sweep ------------------------------------------
const ADV_CHUNK: usize = 4;
const ADV_MAX_LIVE: usize = 2;
const ADV_SHORTS: usize = 20;
const ADV_SHORT_TOKENS: usize = 8;
const ADV_LONG_TOKENS: usize = 384;
/// scheduler tick the adversarial long prompt lands on
const ADV_LONG_AT: usize = 2;
const ADV_ARRIVALS_PER_TICK: usize = 2;

struct AdvOutcome {
    short_tok: Summary,
    long_ms: f64,
    long_tok_ms: f64,
    decode_p99: f64,
    steps: usize,
}

/// One adversarial run: `ADV_SHORTS` short sessions arrive open-loop
/// (`ADV_ARRIVALS_PER_TICK` per scheduler tick) with a long prompt
/// injected at tick `ADV_LONG_AT`; under single-phase scheduling the
/// prompt occupies a scarce live slot for `ADV_LONG_TOKENS / ADV_CHUNK`
/// steps, while disaggregation keeps it in the budgeted prefill dispatch.
fn adversarial_run(mode: SchedulerMode, planner: &Arc<Planner>) -> AdvOutcome {
    let spec = SessionSpec::tiny(StreamAttn::LinearAdd, Lin::Shift);
    let model = StreamModel::new(spec.clone(), Arc::clone(planner));
    let d = spec.dim;
    let mut eng = SessionEngine::with_mode(model, ADV_CHUNK, ADV_MAX_LIVE, mode);
    let mut metrics = Metrics::default();
    let mut shorts: Vec<StreamTicket> = Vec::new();
    let mut long_ticket = None;
    let mut decode_ms = Vec::new();
    let mut steps = 0usize;
    let mut tick = 0usize;
    while shorts.len() < ADV_SHORTS || long_ticket.is_none() || !eng.idle() {
        for _ in 0..ADV_ARRIVALS_PER_TICK {
            if shorts.len() < ADV_SHORTS {
                let seed = 0xAD5 + shorts.len() as u64;
                shorts.push(eng.submit(XorShift64::new(seed).normals(ADV_SHORT_TOKENS * d)));
            }
        }
        if tick == ADV_LONG_AT {
            long_ticket = Some(eng.submit(XorShift64::new(0xADD).normals(ADV_LONG_TOKENS * d)));
        }
        if !eng.idle() {
            let st = eng.step(&mut metrics);
            steps += 1;
            if st.decode_tokens > 0 {
                decode_ms.push(st.decode_ms);
            }
        }
        tick += 1;
    }
    let mut short_tok = Vec::new();
    for t in &shorts {
        let o = eng.poll(t).expect("short session completed");
        short_tok.push(o.latency_ms() / o.tokens as f64);
    }
    let lo = eng
        .poll(&long_ticket.expect("long prompt submitted"))
        .expect("long prompt completed");
    AdvOutcome {
        short_tok: Summary::from(&short_tok),
        long_ms: lo.latency_ms(),
        long_tok_ms: lo.latency_ms() / lo.tokens as f64,
        decode_p99: Summary::from(&decode_ms).p99,
        steps,
    }
}

fn main() {
    // The paper's deployed mixture: Hamming LinearAdd attention (MatAdd)
    // + shift-reparameterized linears (MatShift).
    let model = StreamModel::tiny(StreamAttn::LinearAdd, Lin::Shift);
    let d = model.spec.dim;

    let mut table = shiftaddvit::util::bench::Table::new(&[
        "sessions",
        "sequential (tok/s)",
        "batched (tok/s)",
        "speedup",
    ]);
    let mut rows = Vec::new();

    for &nsess in &[1usize, 2, 4, 8] {
        let seqs: Vec<Vec<f32>> = (0..nsess)
            .map(|i| XorShift64::new(0xBE2C + i as u64).normals(TOKENS * d))
            .collect();
        let total_tokens = (nsess * TOKENS) as f64;

        // --- sequential: one session at a time, chunk by chunk -----------
        let seq_samples = time_ms(
            || {
                for seq in &seqs {
                    let mut s = model.begin();
                    for c in seq.chunks(CHUNK * d) {
                        model.extend(&mut s, c);
                    }
                    std::hint::black_box(model.finish(&s));
                }
            },
            2,
            7,
        );
        let seq_ms = Summary::from(&seq_samples).p50;

        // --- batched: every session's next chunk in ONE fused step -------
        let bat_samples = time_ms(
            || {
                let mut states: Vec<SessionState> =
                    (0..nsess).map(|_| model.begin()).collect();
                for step in 0..TOKENS / CHUNK {
                    let chunks: Vec<&[f32]> = seqs
                        .iter()
                        .map(|s| &s[step * CHUNK * d..(step + 1) * CHUNK * d])
                        .collect();
                    let mut refs: Vec<&mut SessionState> = states.iter_mut().collect();
                    model.extend_batch(&mut refs, &chunks);
                }
                for s in &states {
                    std::hint::black_box(model.finish(s));
                }
            },
            2,
            7,
        );
        let bat_ms = Summary::from(&bat_samples).p50;

        let seq_tok_s = total_tokens / (seq_ms / 1e3);
        let bat_tok_s = total_tokens / (bat_ms / 1e3);
        table.row(&[
            nsess.to_string(),
            f1(seq_tok_s),
            f1(bat_tok_s),
            f2(bat_tok_s / seq_tok_s),
        ]);
        rows.push(Json::obj(vec![
            ("sessions", Json::num(nsess as f64)),
            ("sequential_ms", Json::num(seq_ms)),
            ("batched_ms", Json::num(bat_ms)),
            ("sequential_tok_s", Json::num(seq_tok_s)),
            ("batched_tok_s", Json::num(bat_tok_s)),
            ("speedup", Json::num(bat_tok_s / seq_tok_s)),
        ]));
    }

    table.print("Streaming sessions — sequential vs fused batched stepping");

    // --- adversarial long-prompt sweep: single-phase vs disaggregated -----
    // One shared planner across every run, so the comparison is pure
    // scheduling (identical kernel placements, bit-exact logits).
    let planner = Arc::new(Planner::new(Arc::new(KernelRegistry::with_defaults())));
    let cases = [
        ("single-phase", SchedulerMode::SinglePhase, 0usize),
        (
            "disagg",
            SchedulerMode::Disaggregated {
                prefill_budget: ADV_CHUNK * ADV_MAX_LIVE,
            },
            ADV_CHUNK * ADV_MAX_LIVE,
        ),
        (
            "disagg",
            SchedulerMode::Disaggregated {
                prefill_budget: 2 * ADV_CHUNK * ADV_MAX_LIVE,
            },
            2 * ADV_CHUNK * ADV_MAX_LIVE,
        ),
    ];
    let mut adv_table = shiftaddvit::util::bench::Table::new(&[
        "scheduler",
        "budget",
        "short p50 (ms/tok)",
        "short p99 (ms/tok)",
        "long prompt (ms)",
        "decode p99 (ms)",
        "steps",
    ]);
    let mut adv_rows = Vec::new();
    for (name, mode, budget) in cases {
        let out = adversarial_run(mode, &planner);
        adv_table.row(&[
            name.to_string(),
            if budget == 0 {
                "-".to_string()
            } else {
                budget.to_string()
            },
            f2(out.short_tok.p50),
            f2(out.short_tok.p99),
            f1(out.long_ms),
            f2(out.decode_p99),
            out.steps.to_string(),
        ]);
        adv_rows.push(Json::obj(vec![
            ("scheduler", Json::str(name)),
            ("prefill_budget", Json::num(budget as f64)),
            ("short_tok_p50_ms", Json::num(out.short_tok.p50)),
            ("short_tok_p99_ms", Json::num(out.short_tok.p99)),
            ("long_ms", Json::num(out.long_ms)),
            ("long_tok_ms", Json::num(out.long_tok_ms)),
            ("decode_p99_ms", Json::num(out.decode_p99)),
            ("steps", Json::num(out.steps as f64)),
        ]));
    }
    adv_table.print(&format!(
        "Adversarial arrivals — {ADV_SHORTS}×{ADV_SHORT_TOKENS}-token sessions + one \
         {ADV_LONG_TOKENS}-token prompt (chunk {ADV_CHUNK}, max_live {ADV_MAX_LIVE})"
    ));

    let json = Json::obj(vec![
        ("bench", Json::str("session_stream")),
        ("dim", Json::num(d as f64)),
        ("depth", Json::num(model.spec.depth as f64)),
        ("tokens_per_session", Json::num(TOKENS as f64)),
        ("chunk", Json::num(CHUNK as f64)),
        ("results", Json::Arr(rows)),
        ("adversarial", Json::Arr(adv_rows)),
    ]);
    println!("\n{json}");
}
