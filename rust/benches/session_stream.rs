//! Session streaming throughput: per-session sequential stepping vs fused
//! multi-session batched stepping (`StreamModel::extend_batch`), in
//! tokens/sec — the measured case for cross-request continuous batching:
//! one MatMul/MatShift dispatch per linear per layer per step, amortized
//! over every live session, instead of one dispatch chain per session.
//! Emits both the table and a JSON object for tooling.

use shiftaddvit::infer::session::{SessionState, StreamAttn, StreamModel};
use shiftaddvit::model::ops::Lin;
use shiftaddvit::util::bench::{f1, f2, time_ms};
use shiftaddvit::util::json::Json;
use shiftaddvit::util::rng::XorShift64;
use shiftaddvit::util::stats::Summary;

const TOKENS: usize = 64;
const CHUNK: usize = 8;

fn main() {
    // The paper's deployed mixture: Hamming LinearAdd attention (MatAdd)
    // + shift-reparameterized linears (MatShift).
    let model = StreamModel::tiny(StreamAttn::LinearAdd, Lin::Shift);
    let d = model.spec.dim;

    let mut table = shiftaddvit::util::bench::Table::new(&[
        "sessions",
        "sequential (tok/s)",
        "batched (tok/s)",
        "speedup",
    ]);
    let mut rows = Vec::new();

    for &nsess in &[1usize, 2, 4, 8] {
        let seqs: Vec<Vec<f32>> = (0..nsess)
            .map(|i| XorShift64::new(0xBE2C + i as u64).normals(TOKENS * d))
            .collect();
        let total_tokens = (nsess * TOKENS) as f64;

        // --- sequential: one session at a time, chunk by chunk -----------
        let seq_samples = time_ms(
            || {
                for seq in &seqs {
                    let mut s = model.begin();
                    for c in seq.chunks(CHUNK * d) {
                        model.extend(&mut s, c);
                    }
                    std::hint::black_box(model.finish(&s));
                }
            },
            2,
            7,
        );
        let seq_ms = Summary::from(&seq_samples).p50;

        // --- batched: every session's next chunk in ONE fused step -------
        let bat_samples = time_ms(
            || {
                let mut states: Vec<SessionState> =
                    (0..nsess).map(|_| model.begin()).collect();
                for step in 0..TOKENS / CHUNK {
                    let chunks: Vec<&[f32]> = seqs
                        .iter()
                        .map(|s| &s[step * CHUNK * d..(step + 1) * CHUNK * d])
                        .collect();
                    let mut refs: Vec<&mut SessionState> = states.iter_mut().collect();
                    model.extend_batch(&mut refs, &chunks);
                }
                for s in &states {
                    std::hint::black_box(model.finish(s));
                }
            },
            2,
            7,
        );
        let bat_ms = Summary::from(&bat_samples).p50;

        let seq_tok_s = total_tokens / (seq_ms / 1e3);
        let bat_tok_s = total_tokens / (bat_ms / 1e3);
        table.row(&[
            nsess.to_string(),
            f1(seq_tok_s),
            f1(bat_tok_s),
            f2(bat_tok_s / seq_tok_s),
        ]);
        rows.push(Json::obj(vec![
            ("sessions", Json::num(nsess as f64)),
            ("sequential_ms", Json::num(seq_ms)),
            ("batched_ms", Json::num(bat_ms)),
            ("sequential_tok_s", Json::num(seq_tok_s)),
            ("batched_tok_s", Json::num(bat_tok_s)),
            ("speedup", Json::num(bat_tok_s / seq_tok_s)),
        ]));
    }

    table.print("Streaming sessions — sequential vs fused batched stepping");
    let json = Json::obj(vec![
        ("bench", Json::str("session_stream")),
        ("dim", Json::num(d as f64)),
        ("depth", Json::num(model.spec.depth as f64)),
        ("tokens_per_session", Json::num(TOKENS as f64)),
        ("chunk", Json::num(CHUNK as f64)),
        ("results", Json::Arr(rows)),
    ]);
    println!("\n{json}");
}
