//! Table 5 (+8/9/10) — NVS quality and per-frame cost.
use shiftaddvit::harness::nvs;
use shiftaddvit::runtime::engine::Engine;

fn main() {
    nvs::table5_cost();
    match Engine::from_default_dir() {
        Ok(engine) => {
            let scenes = ["orchids", "flower"];
            nvs::table5_quality(&engine, &scenes, 24).expect("table5");
        }
        Err(e) => eprintln!("quality rows skipped (run `make artifacts`): {e}"),
    }
}
