//! Table 7 — latency-aware load-balancing loss ablation.
//!
//! Accuracy columns come from the `llloss` training preset (with vs without
//! the LL term). The latency column is regenerated mechanistically: the
//! routers' observed token splits are replayed through the synchronization
//! model with the *measured* per-expert costs from the serving pipeline
//! (falling back to Eyeriss per-token costs if artifacts are missing), and
//! normalized latency = makespan(split) / makespan(w/o-LL split).

use shiftaddvit::coordinator::config::{DispatchMode, ServerConfig};
use shiftaddvit::coordinator::server::serve;
use shiftaddvit::harness::results::Results;
use shiftaddvit::moe::balance::{ideal_split, sync_cost};
use shiftaddvit::runtime::artifact::Manifest;
use shiftaddvit::util::bench::{f2, Table};

fn main() {
    let results = Results::load();

    // Measured per-token expert costs (ms) from a short modularized serve
    // run, if artifacts exist; otherwise Eyeriss MAC-energy proxies.
    let per_token = if Manifest::available() {
        let m = Manifest::load(&Manifest::default_dir()).unwrap();
        match serve(
            &m,
            &ServerConfig {
                requests: 16,
                dispatch: DispatchMode::Modularized,
                ..ServerConfig::default()
            },
        ) {
            Ok(report) => {
                let t = &report.metrics.expert_times;
                let n = &report.metrics.expert_tokens;
                let per = [
                    t[0].mean() / (n[0].max(1) as f64 / report.metrics.batches.max(1) as f64),
                    t[1].mean() / (n[1].max(1) as f64 / report.metrics.batches.max(1) as f64),
                ];
                println!(
                    "measured per-token expert cost: mult {:.4} ms, shift {:.4} ms",
                    per[0], per[1]
                );
                per
            }
            Err(e) => {
                eprintln!("serve failed ({e}); using Eyeriss proxy costs");
                [0.004, 0.001]
            }
        }
    } else {
        eprintln!("artifacts missing; using Eyeriss proxy per-token costs");
        [0.004, 0.001]
    };

    let total_tokens = 1000usize;
    // w/o LL-loss: the router balances *counts* (homogeneous-MoE prior) →
    // 50/50; w/ LL-loss: the latency-proportional split.
    let wo = [total_tokens / 2, total_tokens / 2];
    let w = ideal_split(&per_token, total_tokens);
    let (mk_wo, idle_wo) = sync_cost(&wo, &per_token);
    let (mk_w, idle_w) = sync_cost(&w, &per_token);

    let mut t = Table::new(&["Model", "Method", "Acc (%)", "Norm. latency", "Idle (ms)"]);
    for model in ["pvtv2_b0", "pvtv1_t"] {
        t.row(&[
            model.to_string(),
            "w/o LL-Loss".into(),
            results.fmt_acc(&format!("llloss_{model}_without")),
            "100.0%".into(),
            f2(idle_wo),
        ]);
        t.row(&[
            model.to_string(),
            "w/ LL-Loss".into(),
            results.fmt_acc(&format!("llloss_{model}_with")),
            format!("{:.1}%", 100.0 * mk_w / mk_wo),
            f2(idle_w),
        ]);
    }
    t.print("Table 7 — LL-loss ablation (latency replayed through the sync model with measured expert costs)");
}
