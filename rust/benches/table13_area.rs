//! Table 13 — Eyeriss latency under the same chip area: cheaper primitives
//! buy more PEs, so shift/add variants win big even where GPU wall-clock
//! hides it.

use shiftaddvit::energy::area::AreaModel;
use shiftaddvit::model::config::classifier;
use shiftaddvit::model::ops::{count, Variant};
use shiftaddvit::util::bench::{f2, Table};

fn main() {
    let a = AreaModel::default();
    let mut t = Table::new(&[
        "Model",
        "Variant",
        "MACs (G)",
        "Eyeriss lat (ms)",
        "speedup vs MSA",
    ]);
    for model in ["pvtv2_b0", "pvtv2_b1"] {
        let spec = classifier(model);
        let msa_lat = a.latency_ms(&count(&spec, Variant::MSA));
        for (label, var) in [
            ("MSA", Variant::MSA),
            ("LinearAttn+Add", Variant::ADD),
            ("+Shift (Attn & MLP)", Variant::ADD_SHIFT_BOTH),
            ("+MoE (Attn & MLP)", Variant::SHIFTADD_MOE),
        ] {
            let ops = count(&spec, var);
            let lat = a.latency_ms(&ops);
            t.row(&[
                spec.name.to_string(),
                label.to_string(),
                f2(ops.total_macs() / 1e9),
                f2(lat),
                format!("{:.1}x", msa_lat / lat),
            ]);
        }
    }
    t.print("Table 13 — latency under the same chip area (168-FP32-PE budget, heterogeneous array)");
}
