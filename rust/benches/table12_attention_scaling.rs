//! Table 12 — attention-type latency vs batch size and resolution.
use shiftaddvit::harness::scaling;
use shiftaddvit::runtime::engine::Engine;

fn main() {
    scaling::table12_analytic();
    match Engine::from_default_dir() {
        Ok(engine) => scaling::table12_measured(&engine).expect("measured"),
        Err(e) => eprintln!("measured rows skipped: {e}"),
    }
}
