//! Table 12 — attention-type latency vs batch size and resolution.
//! Measured rows: XLA artifacts when present, native engine always.
use shiftaddvit::harness::scaling;
use shiftaddvit::runtime::engine::Engine;

fn main() {
    scaling::table12_analytic();
    let engine = Engine::from_default_dir().ok();
    scaling::table12_measured(engine.as_ref()).expect("measured");
}
