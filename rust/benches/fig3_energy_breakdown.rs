//! Fig. 3 — Eyeriss energy breakdown for DeiT-T and GNT.
use shiftaddvit::harness::figures;

fn main() {
    figures::table1();
    figures::fig3_energy_breakdown();
}
