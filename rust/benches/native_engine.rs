//! Native-engine latency: the pure-Rust `infer` forward pass per variant
//! and batch size, the **batched image path** sweep (sequential
//! per-image/per-head attention vs the fused per-layer dispatches, in
//! images/sec with dispatch counts per layer), the **kernel-family sweep**
//! (serial vs rowpar vs simd backends pinned end to end via
//! `Planner::force`, stamped with the detected CPU feature set), and an
//! end-to-end native serving throughput run — the measured (not analytic)
//! counterpart of the reparameterization ladder, runnable with zero
//! artifacts. Emits a JSON object for tooling alongside the tables.

use std::sync::Arc;

use shiftaddvit::coordinator::backend::NativeBackend;
use shiftaddvit::coordinator::config::ServerConfig;
use shiftaddvit::coordinator::server::serve_backend;
use shiftaddvit::data::synth_images;
use shiftaddvit::infer::block::AttnExec;
use shiftaddvit::infer::model::{tiny_latencies_ms, NativeModel, NativeModelConfig};
use shiftaddvit::kernels::api::Primitive;
use shiftaddvit::kernels::planner::Planner;
use shiftaddvit::kernels::registry::KernelRegistry;
use shiftaddvit::kernels::simd;
use shiftaddvit::model::ops::Variant;
use shiftaddvit::util::bench::{f1, f2, time_ms, Table};
use shiftaddvit::util::json::Json;
use shiftaddvit::util::stats::Summary;

fn main() {
    let mut t = Table::new(&["Variant", "bs1 (ms)", "bs8 (ms)", "bs32 (ms)"]);
    for (label, variant) in [
        ("MSA", Variant::MSA),
        ("Linear", Variant::LINEAR),
        ("LinearAdd", Variant::ADD),
        ("Add+ShiftBoth", Variant::ADD_SHIFT_BOTH),
        ("ShiftAdd+MoE", Variant::SHIFTADD_MOE),
    ] {
        let lat = tiny_latencies_ms(variant, &[1, 8, 32]);
        t.row(&[
            label.to_string(),
            f2(lat[0]),
            f2(lat[1]),
            f2(lat[2]),
        ]);
    }
    t.print("Native engine — tiny-analogue forward latency per variant");

    // --- batched image path: sequential vs fused attention dispatch -------
    // The deployed mixture (LinearAdd attention): the per-image path pays
    // b·heads·4 MatAdd dispatches per layer, the fused path a constant 2.
    let model = NativeModel::tiny(Variant::SHIFTADD_MOE);
    let mut sweep = Table::new(&[
        "batch",
        "sequential (img/s)",
        "fused (img/s)",
        "speedup",
        "disp/layer seq",
        "disp/layer fused",
    ]);
    let mut rows = Vec::new();
    for &bs in &[1usize, 2, 4, 8, 16, 32] {
        let (xs, _) = synth_images::gen_batch(9_000 + bs as u32, bs);
        // Dispatch counts are deterministic per (mode, batch), so capture
        // the trace from inside the timed runs instead of paying extra
        // untimed forwards.
        let seq_cell = std::cell::RefCell::new(None);
        let seq_ms = Summary::from(&time_ms(
            || {
                let (_, tr) = model.forward_with(&xs, bs, AttnExec::PerImage);
                *seq_cell.borrow_mut() = Some(tr);
            },
            2,
            5,
        ))
        .p50;
        let fused_cell = std::cell::RefCell::new(None);
        let fused_ms = Summary::from(&time_ms(
            || {
                let (_, tr) = model.forward_with(&xs, bs, AttnExec::Fused);
                *fused_cell.borrow_mut() = Some(tr);
            },
            2,
            5,
        ))
        .p50;
        let tr_seq = seq_cell.into_inner().expect("timed runs happened");
        let tr_fused = fused_cell.into_inner().expect("timed runs happened");
        let dpl_seq = tr_seq.attn_dispatches as f64 / tr_seq.blocks as f64;
        let dpl_fused = tr_fused.attn_dispatches as f64 / tr_fused.blocks as f64;
        let seq_img_s = bs as f64 / (seq_ms / 1e3);
        let fused_img_s = bs as f64 / (fused_ms / 1e3);
        sweep.row(&[
            bs.to_string(),
            f1(seq_img_s),
            f1(fused_img_s),
            f2(fused_img_s / seq_img_s),
            f1(dpl_seq),
            f1(dpl_fused),
        ]);
        rows.push(Json::obj(vec![
            ("batch", Json::num(bs as f64)),
            ("sequential_ms", Json::num(seq_ms)),
            ("fused_ms", Json::num(fused_ms)),
            ("sequential_img_s", Json::num(seq_img_s)),
            ("fused_img_s", Json::num(fused_img_s)),
            ("speedup", Json::num(fused_img_s / seq_img_s)),
            ("dispatches_per_layer_sequential", Json::num(dpl_seq)),
            ("dispatches_per_layer_fused", Json::num(dpl_fused)),
        ]));
    }
    sweep.print("Batched image path — per-image vs fused per-layer dispatch");

    // --- kernel-family sweep: simd vs rowpar vs serial behind the fused
    // path. `Planner::force` pins every MatAdd (and MatShift) shape to one
    // backend, so each row is the end-to-end images/sec of that kernel
    // family on the deployed mixture — the measured trajectory the SIMD
    // subsystem is accountable to.
    let level = simd::active_level();
    let kbs = 8usize;
    let (kxs, _) = synth_images::gen_batch(11_000, kbs);
    let mut ksweep = Table::new(&["pinned backends", "bs8 fused (ms)", "bs8 (img/s)"]);
    let mut krows = Vec::new();
    for (label, matadd, matshift) in [
        ("matadd/bitplane + matshift/planes", "bitplane", "planes"),
        ("matadd/rowpar + matshift/rowpar", "rowpar", "rowpar"),
        ("matadd/simd + matshift/simd", "simd", "simd"),
    ] {
        let planner = Arc::new(Planner::new(Arc::new(KernelRegistry::with_defaults())));
        planner.force(Primitive::MatAdd, matadd);
        planner.force(Primitive::MatShift, matshift);
        let pinned = NativeModel::new(NativeModelConfig::tiny(Variant::SHIFTADD_MOE), planner);
        let ms = Summary::from(&time_ms(
            || {
                pinned.forward(&kxs, kbs);
            },
            2,
            5,
        ))
        .p50;
        let img_s = kbs as f64 / (ms / 1e3);
        ksweep.row(&[label.to_string(), f2(ms), f1(img_s)]);
        krows.push(Json::obj(vec![
            ("matadd_backend", Json::str(matadd)),
            ("matshift_backend", Json::str(matshift)),
            ("ms", Json::num(ms)),
            ("img_s", Json::num(img_s)),
        ]));
    }
    ksweep.print(&format!(
        "Fused image path by kernel family (cpu_features: {})",
        level.name()
    ));

    let json = Json::obj(vec![
        ("bench", Json::str("native_engine")),
        ("variant", Json::str("shiftadd_moe")),
        ("cpu_features", Json::str(level.name())),
        ("results", Json::Arr(rows)),
        ("kernel_family_sweep", Json::Arr(krows)),
    ]);
    println!("\n{json}");

    let cfg = ServerConfig {
        requests: 48,
        ..ServerConfig::default()
    };
    let backend = NativeBackend::tiny(Variant::SHIFTADD_MOE);
    let report = serve_backend(&backend, &cfg).expect("native serve");
    println!(
        "\nnative serving: {} requests  {:.1} img/s  p50 {:.2} ms  p99 {:.2} ms",
        report.metrics.requests, report.throughput_rps, report.latency.p50, report.latency.p99
    );
}
