//! Native-engine latency: the pure-Rust `infer` forward pass per variant
//! and batch size, plus an end-to-end native serving throughput run — the
//! measured (not analytic) counterpart of the reparameterization ladder,
//! runnable with zero artifacts.

use shiftaddvit::coordinator::backend::NativeBackend;
use shiftaddvit::coordinator::config::ServerConfig;
use shiftaddvit::coordinator::server::serve_backend;
use shiftaddvit::infer::model::tiny_latencies_ms;
use shiftaddvit::model::ops::Variant;
use shiftaddvit::util::bench::{f2, Table};

fn main() {
    let mut t = Table::new(&["Variant", "bs1 (ms)", "bs8 (ms)", "bs32 (ms)"]);
    for (label, variant) in [
        ("MSA", Variant::MSA),
        ("Linear", Variant::LINEAR),
        ("LinearAdd", Variant::ADD),
        ("Add+ShiftBoth", Variant::ADD_SHIFT_BOTH),
        ("ShiftAdd+MoE", Variant::SHIFTADD_MOE),
    ] {
        let lat = tiny_latencies_ms(variant, &[1, 8, 32]);
        t.row(&[
            label.to_string(),
            f2(lat[0]),
            f2(lat[1]),
            f2(lat[2]),
        ]);
    }
    t.print("Native engine — tiny-analogue forward latency per variant");

    let cfg = ServerConfig {
        requests: 48,
        ..ServerConfig::default()
    };
    let backend = NativeBackend::tiny(Variant::SHIFTADD_MOE);
    let report = serve_backend(&backend, &cfg).expect("native serve");
    println!(
        "\nnative serving: {} requests  {:.1} img/s  p50 {:.2} ms  p99 {:.2} ms",
        report.metrics.requests, report.throughput_rps, report.latency.p50, report.latency.p99
    );
}
