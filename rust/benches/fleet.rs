//! Sharded serving throughput: the classify workload behind the fleet
//! router, swept over workers ∈ {1, 2, 4} × routing policy with the same
//! open-loop synthetic client, plus a stream-workload sweep for per-token
//! latency percentiles. `workers = 1` is the classic single-engine loop
//! (no fleet layer) — the scaling baseline. Emits the tables and a
//! trailing JSON object with latency percentiles for tooling.

use shiftaddvit::coordinator::config::ServerConfig;
use shiftaddvit::coordinator::server::{serve_auto, serve_stream};
use shiftaddvit::fleet::policy::PolicyKind;
use shiftaddvit::util::bench::{f1, f2, Table};
use shiftaddvit::util::json::Json;
use shiftaddvit::util::stats::Summary;

/// classify requests per run (open-loop paced)
const REQUESTS: usize = 48;
/// stream sessions per run
const SESSIONS: usize = 12;
/// mean open-loop inter-arrival (ms) — keeps every fleet size busy
const ARRIVAL_MS: f64 = 1.0;

const POLICIES: [PolicyKind; 3] = [
    PolicyKind::RoundRobin,
    PolicyKind::LeastLoaded,
    PolicyKind::Affinity,
];

fn latency_json(s: &Summary) -> Json {
    Json::obj(vec![
        ("mean", Json::num(s.mean)),
        ("p50", Json::num(s.p50)),
        ("p95", Json::num(s.p95)),
        ("p99", Json::num(s.p99)),
        ("max", Json::num(s.max)),
    ])
}

fn main() {
    // --- classify: workers × policy, open-loop client -----------------------
    let mut table = Table::new(&[
        "workers",
        "policy",
        "throughput (img/s)",
        "p50 (ms)",
        "p99 (ms)",
        "speedup",
    ]);
    let mut classify_rows = Vec::new();
    let mut base_rps = 0.0f64;
    for &workers in &[1usize, 2, 4] {
        for &policy in &POLICIES {
            // the single-engine baseline has no router, so policy is moot
            if workers == 1 && policy != PolicyKind::RoundRobin {
                continue;
            }
            let cfg = ServerConfig {
                requests: REQUESTS,
                max_batch: 4,
                arrival_ms: ARRIVAL_MS,
                workers,
                policy,
                ..ServerConfig::default()
            };
            let report = serve_auto(&cfg).expect("classify serving run");
            if workers == 1 {
                base_rps = report.throughput_rps;
            }
            let policy_cell = if workers == 1 {
                "(solo)".to_string()
            } else {
                policy.name().to_string()
            };
            table.row(&[
                workers.to_string(),
                policy_cell,
                f1(report.throughput_rps),
                f2(report.latency.p50),
                f2(report.latency.p99),
                f2(report.throughput_rps / base_rps),
            ]);
            classify_rows.push(Json::obj(vec![
                ("workers", Json::num(workers as f64)),
                ("policy", Json::str(policy.name())),
                ("requests", Json::num(REQUESTS as f64)),
                ("throughput_rps", Json::num(report.throughput_rps)),
                ("latency_ms", latency_json(&report.latency)),
                ("speedup", Json::num(report.throughput_rps / base_rps)),
            ]));
        }
    }
    table.print("Fleet serving — classify throughput, workers × policy");

    // --- stream: per-token latency percentiles across fleet sizes -----------
    let mut stream_table = Table::new(&[
        "workers",
        "tok/s",
        "token p50 (ms)",
        "token p95 (ms)",
        "token p99 (ms)",
    ]);
    let mut stream_rows = Vec::new();
    for &workers in &[1usize, 2, 4] {
        let cfg = ServerConfig {
            requests: SESSIONS,
            arrival_ms: ARRIVAL_MS,
            workers,
            policy: PolicyKind::LeastLoaded,
            ..ServerConfig::default()
        };
        let report = serve_stream(&cfg).expect("stream serving run");
        stream_table.row(&[
            workers.to_string(),
            f1(report.tokens_per_sec),
            f2(report.token_latency.p50),
            f2(report.token_latency.p95),
            f2(report.token_latency.p99),
        ]);
        let mut row = report.to_json();
        if let Json::Obj(map) = &mut row {
            map.insert("workers".to_string(), Json::num(workers as f64));
        }
        stream_rows.push(row);
    }
    stream_table.print("Fleet serving — stream per-token latency, least-loaded");

    let json = Json::obj(vec![
        ("bench", Json::str("fleet")),
        ("arrival_ms", Json::num(ARRIVAL_MS)),
        ("classify", Json::Arr(classify_rows)),
        ("stream", Json::Arr(stream_rows)),
    ]);
    println!("\n{json}");
}
