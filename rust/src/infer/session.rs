//! Session-based streaming inference — the KV-free incremental API over
//! the linear-attention state (ROADMAP "KV-free streaming").
//!
//! ShiftAddViT's linear/LinearAdd attention keeps only an O(d·bits)
//! accumulator per head (the kᵀv matrix plus code sums —
//! [`crate::infer::attn::HammingAttnState`] / [`ReluAttnState`]), so a
//! token sequence can stream through the model without ever re-running its
//! prefix. This module makes that state first-class:
//!
//! ```text
//!   let model = StreamModel::tiny(StreamAttn::LinearAdd, Lin::Shift);
//!   let mut s = model.begin();
//!   model.extend(&mut s, &chunk_a);     // any chunking — token granularity
//!   model.extend(&mut s, &chunk_b);
//!   let logits = model.finish(&s);      // == model.forward_full(all_tokens)
//! ```
//!
//! **Bit-exactness contract.** `extend`-ing a session in *any* chunk split
//! (token-by-token, random splits, one shot) yields bit-identical state and
//! logits, because every per-token operation is row-independent:
//! LayerNorm is row-wise, the attention state absorbs tokens strictly in
//! ascending order, and every linear either consumes f32 operands (MatMul)
//! or uses a **frozen** INT8 activation scale
//! ([`crate::infer::block::LinearLayer::new_frozen`]) instead of per-tensor
//! calibration. Attention is **causal** (token i attends over tokens 0..=i),
//! the semantics under which prefix-free streaming is well-defined.
//!
//! **Cross-session fused stepping.** [`StreamModel::extend_batch`] packs
//! token chunks from several live sessions into ONE operand per linear per
//! layer — a single fused MatMul/MatShift dispatch amortized across
//! requests, continuous-batching style (the attention-state updates and
//! KSH hashing are O(d·bits) scalar loops per token, not kernel
//! dispatches). Row independence makes the packed step bit-exact against
//! stepping each session alone; `coordinator::sessions::SessionEngine`
//! drives this loop across live requests.

use std::sync::Arc;

use crate::infer::attn::{HammingAttnState, ReluAttnState};
use crate::infer::block::{dense_init, layer_norm, LinearLayer};
use crate::kernels::api::Primitive;
use crate::kernels::planner::Planner;
use crate::kernels::registry::KernelRegistry;
use crate::model::ops::Lin;
use crate::quant::ksh::KshHasher;
use crate::util::rng::XorShift64;

/// Frozen symmetric INT8 activation scale used by every quantizing linear
/// on the session path (≈ ±6.0 full-scale; LayerNormed activations are
/// O(1), so saturation is rare). A *fixed* scale is what makes shift
/// linears chunk- and batch-invariant — see the module docs.
pub const STREAM_ACT_SCALE: f32 = 6.0 / 127.0;

/// Attention families a session can stream (MSA is excluded: its state is
/// the full K/V history, which defeats KV-free streaming).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum StreamAttn {
    /// full-precision ReLU linear attention (paper "Linear" row)
    Linear,
    /// KSH-binarized Hamming attention (paper "LinearAdd" row)
    LinearAdd,
}

/// Construction parameters of a [`StreamModel`].
#[derive(Clone, Debug)]
pub struct SessionSpec {
    pub dim: usize,
    pub depth: usize,
    pub heads: usize,
    pub hidden: usize,
    pub num_classes: usize,
    pub attn: StreamAttn,
    /// primitive behind the q/k/v/o and MLP linears (Mult → MatMul,
    /// Shift → MatShift with a frozen activation scale)
    pub linear: Lin,
    pub seed: u64,
    /// representative chunk row count the planner benchmarks at
    pub plan_m: usize,
}

impl SessionSpec {
    /// The tiny streaming analogue (same scale as `NativeModelConfig::tiny`).
    pub fn tiny(attn: StreamAttn, linear: Lin) -> SessionSpec {
        SessionSpec {
            dim: 32,
            depth: 2,
            heads: 2,
            hidden: 64,
            num_classes: 8,
            attn,
            linear,
            seed: 0x5E55_10,
            plan_m: 32,
        }
    }

    pub fn head_dim(&self) -> usize {
        self.dim / self.heads
    }

    /// Hash-code width (= head_dim, as in the image model).
    pub fn bits(&self) -> usize {
        self.head_dim()
    }

    /// f32s of attention state one live session holds across all layers and
    /// heads — the constant memory cost that replaces a KV cache.
    pub fn state_floats(&self) -> usize {
        let hd = self.head_dim();
        let per_head = match self.attn {
            StreamAttn::Linear => hd * hd + hd,
            StreamAttn::LinearAdd => self.bits() * hd + self.bits() + hd,
        };
        self.depth * self.heads * per_head + self.dim
    }
}

/// One pre-norm streaming block: causal linear attention + dense MLP, every
/// linear on a planner-chosen registry backend. (No DWConv branch — that is
/// a spatial-grid operation; token streams have no 2-D geometry.)
struct StreamBlock {
    ln1_g: Vec<f32>,
    ln1_b: Vec<f32>,
    ln2_g: Vec<f32>,
    ln2_b: Vec<f32>,
    wq: LinearLayer,
    wk: LinearLayer,
    wv: LinearLayer,
    wo: LinearLayer,
    l1: LinearLayer,
    l2: LinearLayer,
    /// KSH family shared by the block's heads (LinearAdd only)
    hasher: Option<KshHasher>,
}

/// Per-head attention state of one block of one session.
#[derive(Clone, Debug)]
pub enum HeadState {
    Linear(ReluAttnState),
    Hamming(HammingAttnState),
}

/// The whole per-session state: one [`HeadState`] per (layer, head) plus
/// the running mean-pool accumulator — O(depth·heads·d·bits) floats total,
/// independent of how many tokens have streamed through.
#[derive(Clone, Debug)]
pub struct SessionState {
    /// depth × heads attention states
    blocks: Vec<Vec<HeadState>>,
    /// Σ over tokens of the final-layer normalized outputs (dim)
    pooled: Vec<f32>,
    pub tokens_seen: usize,
}

/// Diagnostics from one fused [`StreamModel::extend_batch`] step.
#[derive(Clone, Copy, Debug, Default)]
pub struct StepTrace {
    /// live sessions packed into the step
    pub sessions: usize,
    /// total token rows fused into each per-layer dispatch
    pub total_tokens: usize,
    /// largest single-session chunk in the step — chunk lengths are
    /// heterogeneous, which is what lets the disaggregated scheduler fuse
    /// a big prefill catch-up next to zero-length skips in one dispatch
    pub max_chunk: usize,
}

/// The token-streaming causal model behind sessions.
pub struct StreamModel {
    pub spec: SessionSpec,
    pub planner: Arc<Planner>,
    blocks: Vec<StreamBlock>,
    norm_g: Vec<f32>,
    norm_b: Vec<f32>,
    head: LinearLayer,
}

impl StreamModel {
    pub fn new(spec: SessionSpec, planner: Arc<Planner>) -> StreamModel {
        assert!(spec.depth > 0, "spec has no blocks");
        assert_eq!(spec.dim % spec.heads, 0, "dim must split into heads");
        let mut rng = XorShift64::new(spec.seed);
        let prim = match spec.linear {
            Lin::Mult => Primitive::MatMul,
            Lin::Shift => Primitive::MatShift,
        };
        let lin = |planner: &Planner, rng: &mut XorShift64, k: usize, n: usize| {
            LinearLayer::new_frozen(
                planner,
                prim,
                &dense_init(rng, k, n),
                vec![0.0; n],
                spec.plan_m,
                STREAM_ACT_SCALE,
            )
        };
        let d = spec.dim;
        let blocks = (0..spec.depth)
            .map(|bi| StreamBlock {
                ln1_g: vec![1.0; d],
                ln1_b: vec![0.0; d],
                ln2_g: vec![1.0; d],
                ln2_b: vec![0.0; d],
                wq: lin(&planner, &mut rng, d, d),
                wk: lin(&planner, &mut rng, d, d),
                wv: lin(&planner, &mut rng, d, d),
                wo: lin(&planner, &mut rng, d, d),
                l1: lin(&planner, &mut rng, d, spec.hidden),
                l2: lin(&planner, &mut rng, spec.hidden, d),
                hasher: match spec.attn {
                    StreamAttn::LinearAdd => Some(KshHasher::new(
                        spec.head_dim(),
                        spec.bits(),
                        spec.seed ^ (0x5E55_0000 + bi as u64),
                    )),
                    StreamAttn::Linear => None,
                },
            })
            .collect();
        // Classifier head stays full-precision MatMul (one row per finish).
        let head = LinearLayer::new(
            &planner,
            Primitive::MatMul,
            &dense_init(&mut rng, d, spec.num_classes),
            vec![0.0; spec.num_classes],
            1,
        );
        StreamModel {
            norm_g: vec![1.0; d],
            norm_b: vec![0.0; d],
            spec,
            planner,
            blocks,
            head,
        }
    }

    /// Zero-setup constructor with its own planner over the default registry.
    pub fn tiny(attn: StreamAttn, linear: Lin) -> StreamModel {
        let planner = Arc::new(Planner::new(Arc::new(KernelRegistry::with_defaults())));
        StreamModel::new(SessionSpec::tiny(attn, linear), planner)
    }

    /// Open a session: fresh per-(layer, head) attention state.
    pub fn begin(&self) -> SessionState {
        let hd = self.spec.head_dim();
        SessionState {
            blocks: (0..self.spec.depth)
                .map(|_| {
                    (0..self.spec.heads)
                        .map(|_| match self.spec.attn {
                            StreamAttn::Linear => HeadState::Linear(ReluAttnState::new(hd)),
                            StreamAttn::LinearAdd => {
                                HeadState::Hamming(HammingAttnState::new(self.spec.bits(), hd))
                            }
                        })
                        .collect()
                })
                .collect(),
            pooled: vec![0.0; self.spec.dim],
            tokens_seen: 0,
        }
    }

    /// Stream a chunk of tokens (`tokens`: m × dim, any m ≥ 0) through one
    /// session. Equivalent to `extend_batch` with a single session.
    pub fn extend(&self, session: &mut SessionState, tokens: &[f32]) -> StepTrace {
        self.extend_batch(&mut [session], &[tokens])
    }

    /// Fused continuous-batching step: pack each session's chunk into ONE
    /// operand per linear per layer, so kernel dispatch and planner lookups
    /// amortize across every live session. Bit-exact against extending each
    /// session alone (see module docs).
    ///
    /// `chunks[i]` is session `i`'s next tokens (mᵢ × dim; mᵢ may be 0).
    /// Chunk lengths are fully heterogeneous — the phase-disaggregated
    /// scheduler (`coordinator::sessions`) relies on this to fuse one
    /// session's large prefill catch-up with other sessions' zero-length
    /// skips in a single budgeted dispatch.
    pub fn extend_batch(&self, sessions: &mut [&mut SessionState], chunks: &[&[f32]]) -> StepTrace {
        assert_eq!(sessions.len(), chunks.len(), "one chunk per session");
        let d = self.spec.dim;
        let hd = self.spec.head_dim();
        let ms: Vec<usize> = chunks
            .iter()
            .map(|c| {
                assert_eq!(c.len() % d, 0, "chunk is not a multiple of dim");
                c.len() / d
            })
            .collect();
        let total: usize = ms.iter().sum();
        let max_chunk = ms.iter().copied().max().unwrap_or(0);
        if total == 0 {
            return StepTrace {
                sessions: sessions.len(),
                total_tokens: 0,
                max_chunk: 0,
            };
        }
        let mut x = Vec::with_capacity(total * d);
        for c in chunks {
            x.extend_from_slice(c);
        }

        for (bi, blk) in self.blocks.iter().enumerate() {
            // --- attention sublayer: fused projections, per-session state --
            let u = layer_norm(&x, &blk.ln1_g, &blk.ln1_b, d);
            let q = blk.wq.forward(&u, total);
            let k = blk.wk.forward(&u, total);
            let v = blk.wv.forward(&u, total);
            let mut o = vec![0.0f32; total * d];
            let mut row0 = 0usize;
            for (si, sess) in sessions.iter_mut().enumerate() {
                for t in 0..ms[si] {
                    let r = row0 + t;
                    for (h, head) in sess.blocks[bi].iter_mut().enumerate() {
                        let qrow = &q[r * d + h * hd..r * d + (h + 1) * hd];
                        let krow = &k[r * d + h * hd..r * d + (h + 1) * hd];
                        let vrow = &v[r * d + h * hd..r * d + (h + 1) * hd];
                        let oh = match head {
                            HeadState::Linear(st) => {
                                st.push(krow, vrow);
                                st.query(qrow)
                            }
                            HeadState::Hamming(st) => {
                                let hasher = blk.hasher.as_ref().expect("LinearAdd needs hasher");
                                let kc = hasher.hash(krow);
                                st.push(&kc, vrow);
                                st.query(&hasher.hash(qrow))
                            }
                        };
                        o[r * d + h * hd..r * d + (h + 1) * hd].copy_from_slice(&oh);
                    }
                }
                row0 += ms[si];
            }
            let a = blk.wo.forward(&o, total);
            for (xv, av) in x.iter_mut().zip(&a) {
                *xv += av;
            }

            // --- MLP sublayer: fused two-layer dense ----------------------
            let u2 = layer_norm(&x, &blk.ln2_g, &blk.ln2_b, d);
            let mut hbuf = blk.l1.forward(&u2, total);
            for v in hbuf.iter_mut() {
                *v = v.max(0.0);
            }
            let y = blk.l2.forward(&hbuf, total);
            for (xv, yv) in x.iter_mut().zip(&y) {
                *xv += yv;
            }
        }

        // --- final LN + running mean-pool accumulation --------------------
        let u = layer_norm(&x, &self.norm_g, &self.norm_b, d);
        let mut row0 = 0usize;
        for (si, sess) in sessions.iter_mut().enumerate() {
            for t in 0..ms[si] {
                let row = &u[(row0 + t) * d..(row0 + t + 1) * d];
                for (p, &v) in sess.pooled.iter_mut().zip(row) {
                    *p += v;
                }
            }
            sess.tokens_seen += ms[si];
            row0 += ms[si];
        }
        StepTrace {
            sessions: sessions.len(),
            total_tokens: total,
            max_chunk,
        }
    }

    /// Close a session: mean-pool the accumulated final-layer outputs and
    /// classify. Does not consume the state — callers may keep streaming
    /// and finish again later (anytime inference).
    pub fn finish(&self, session: &SessionState) -> Vec<f32> {
        assert!(session.tokens_seen > 0, "finish() on an empty session");
        let inv = 1.0 / session.tokens_seen as f32;
        let mean: Vec<f32> = session.pooled.iter().map(|&p| p * inv).collect();
        self.head.forward(&mean, 1)
    }

    /// One-shot full-prefix recompute — the reference the streaming path is
    /// tested bit-exact against: a fresh session extended with the whole
    /// sequence at once.
    pub fn forward_full(&self, tokens: &[f32]) -> Vec<f32> {
        let mut s = self.begin();
        self.extend(&mut s, tokens);
        self.finish(&s)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn gen_tokens(seed: u64, n: usize, d: usize) -> Vec<f32> {
        XorShift64::new(seed).normals(n * d)
    }

    #[test]
    fn token_by_token_extend_is_bit_exact_vs_one_shot() {
        for (attn, lin) in [
            (StreamAttn::Linear, Lin::Mult),
            (StreamAttn::LinearAdd, Lin::Mult),
            (StreamAttn::LinearAdd, Lin::Shift),
        ] {
            let model = StreamModel::tiny(attn, lin);
            let d = model.spec.dim;
            let n = 10;
            let toks = gen_tokens(7, n, d);
            let want = model.forward_full(&toks);
            let mut s = model.begin();
            for i in 0..n {
                model.extend(&mut s, &toks[i * d..(i + 1) * d]);
            }
            assert_eq!(s.tokens_seen, n);
            assert_eq!(model.finish(&s), want, "{attn:?}/{lin:?} diverged");
        }
    }

    #[test]
    fn empty_chunk_is_a_no_op() {
        let model = StreamModel::tiny(StreamAttn::LinearAdd, Lin::Mult);
        let d = model.spec.dim;
        let toks = gen_tokens(9, 4, d);
        let mut a = model.begin();
        model.extend(&mut a, &toks);
        let mut b = model.begin();
        model.extend(&mut b, &[]);
        let tr = model.extend(&mut b, &toks);
        assert_eq!(tr.total_tokens, 4);
        model.extend(&mut b, &[]);
        assert_eq!(model.finish(&a), model.finish(&b));
    }

    #[test]
    fn fused_two_session_step_matches_solo() {
        let model = StreamModel::tiny(StreamAttn::LinearAdd, Lin::Shift);
        let d = model.spec.dim;
        let ta = gen_tokens(21, 6, d);
        let tb = gen_tokens(22, 6, d);
        // solo
        let mut sa = model.begin();
        model.extend(&mut sa, &ta);
        let mut sb = model.begin();
        model.extend(&mut sb, &tb);
        // fused: both sessions' chunks in every step
        let mut fa = model.begin();
        let mut fb = model.begin();
        for step in 0..2 {
            let ca = &ta[step * 3 * d..(step + 1) * 3 * d];
            let cb = &tb[step * 3 * d..(step + 1) * 3 * d];
            let tr = model.extend_batch(&mut [&mut fa, &mut fb], &[ca, cb]);
            assert_eq!(tr.total_tokens, 6);
            assert_eq!(tr.sessions, 2);
        }
        assert_eq!(model.finish(&fa), model.finish(&sa));
        assert_eq!(model.finish(&fb), model.finish(&sb));
    }

    #[test]
    fn heterogeneous_chunk_lengths_fuse_bit_exactly() {
        // One big catch-up chunk, one steady chunk, one zero-length skip in
        // the same fused dispatch — the disaggregated scheduler's shape.
        let model = StreamModel::tiny(StreamAttn::LinearAdd, Lin::Shift);
        let d = model.spec.dim;
        let ta = gen_tokens(41, 9, d);
        let tb = gen_tokens(42, 2, d);
        let tc = gen_tokens(43, 3, d);
        let mut sa = model.begin();
        let mut sb = model.begin();
        let mut sc = model.begin();
        let empty: &[f32] = &[];
        let tr = model.extend_batch(
            &mut [&mut sa, &mut sb, &mut sc],
            &[ta.as_slice(), empty, tc.as_slice()],
        );
        assert_eq!(tr.total_tokens, 12);
        assert_eq!(tr.max_chunk, 9);
        let tr2 = model.extend_batch(
            &mut [&mut sa, &mut sb, &mut sc],
            &[empty, tb.as_slice(), empty],
        );
        assert_eq!((tr2.total_tokens, tr2.max_chunk), (2, 2));
        assert_eq!(model.finish(&sa), model.forward_full(&ta));
        assert_eq!(model.finish(&sb), model.forward_full(&tb));
        assert_eq!(model.finish(&sc), model.forward_full(&tc));
    }

    #[test]
    fn finish_is_repeatable_and_anytime() {
        let model = StreamModel::tiny(StreamAttn::Linear, Lin::Mult);
        let d = model.spec.dim;
        let toks = gen_tokens(33, 8, d);
        let mut s = model.begin();
        model.extend(&mut s, &toks[..4 * d]);
        let early = model.finish(&s);
        assert_eq!(model.finish(&s), early, "finish must not consume state");
        model.extend(&mut s, &toks[4 * d..]);
        let late = model.finish(&s);
        assert_eq!(late, model.forward_full(&toks));
        assert_ne!(early, late);
    }

    #[test]
    #[should_panic(expected = "empty session")]
    fn finish_on_empty_session_panics() {
        let model = StreamModel::tiny(StreamAttn::Linear, Lin::Mult);
        model.finish(&model.begin());
    }

    #[test]
    fn state_floats_matches_actual_state() {
        let spec = SessionSpec::tiny(StreamAttn::LinearAdd, Lin::Mult);
        let model = StreamModel::tiny(StreamAttn::LinearAdd, Lin::Mult);
        let s = model.begin();
        let per_head: usize = match &s.blocks[0][0] {
            HeadState::Hamming(st) => st.state_floats(),
            HeadState::Linear(st) => st.state_floats(),
        };
        assert_eq!(spec.state_floats(), spec.depth * spec.heads * per_head + spec.dim);
    }
}
