//! The native pre-norm ShiftAddViT block — mirror of
//! `python/compile/model.py`'s per-block forward, with every linear on a
//! registry [`LinearKernel`] backend:
//!
//! ```text
//!   x += Wo( attn(LN1(x)) [+ DWConv(V)] )     attention sublayer
//!   x += MLP(LN2(x))                          Mult | Shift | MoE sublayer
//! ```
//!
//! The attention family and the primitive behind each linear follow the
//! [`Variant`] (the same enum the analytic op counting uses), so
//! `Variant::SHIFTADD_MOE` executes exactly the mixture the paper deploys:
//! KSH-binarized LinearAdd attention (MatAdd), shift-reparameterized
//! attention linears (MatShift), and the Mult/Shift MoE MLP
//! ([`crate::moe::experts::MoeMlp`]). Raw weights are retained on the block
//! (`raw`) so oracle tests can re-derive every deployment format.

use std::sync::Arc;
use std::time::Instant;

use crate::infer::attn::{
    hamming_linear_attn_batched, hamming_linear_attn_kernel, pack_heads, relu_linear_attn,
    relu_linear_attn_batched, softmax_attn, softmax_attn_batched, unpack_heads,
};
use crate::kernels::api::{LinearKernel, Operand, PreparedWeights, Primitive, RawWeights};
use crate::kernels::planner::{Planner, Shape};
use crate::model::ops::{Attn, Lin, Mlp, Variant};
use crate::moe::experts::{MlpExpert, MoeMlp, MoeTrace};
use crate::quant::ksh::KshHasher;
use crate::util::rng::XorShift64;

/// LayerNorm epsilon (mirrors `model.py::layer_norm`).
pub const LN_EPS: f32 = 1e-6;

/// Row-wise LayerNorm over the last dim: `(x-μ)/√(σ²+ε)·g + b`.
pub fn layer_norm(x: &[f32], g: &[f32], b: &[f32], d: usize) -> Vec<f32> {
    assert_eq!(x.len() % d, 0);
    let mut out = vec![0.0f32; x.len()];
    for (row, orow) in x.chunks(d).zip(out.chunks_mut(d)) {
        let mut mu = 0.0f32;
        for &v in row {
            mu += v;
        }
        mu /= d as f32;
        let mut var = 0.0f32;
        for &v in row {
            var += (v - mu) * (v - mu);
        }
        var /= d as f32;
        let denom = (var + LN_EPS).sqrt();
        for ((o, &v), (&gg, &bb)) in orow.iter_mut().zip(row).zip(g.iter().zip(b)) {
            *o = (v - mu) / denom * gg + bb;
        }
    }
    out
}

/// Depthwise 3×3 convolution over an `h × w` token grid, SAME (zero)
/// padding at every edge. `x`: (h·w × d) row-major tokens; `dw`: (3·3·d).
pub fn dwconv3x3_hw(x: &[f32], dw: &[f32], h: usize, w: usize, d: usize) -> Vec<f32> {
    assert_eq!(x.len(), h * w * d);
    assert_eq!(dw.len(), 9 * d);
    let mut out = vec![0.0f32; h * w * d];
    for y in 0..h {
        for xx in 0..w {
            for c in 0..d {
                let mut acc = 0.0f32;
                for dy in 0..3usize {
                    for dx in 0..3usize {
                        let sy = y + dy;
                        let sx = xx + dx;
                        if sy >= 1 && sy <= h && sx >= 1 && sx <= w {
                            acc += x[((sy - 1) * w + (sx - 1)) * d + c] * dw[(dy * 3 + dx) * d + c];
                        }
                    }
                }
                out[(y * w + xx) * d + c] = acc;
            }
        }
    }
    out
}

/// Depthwise 3×3 convolution over one image's square token grid, SAME
/// padding (mirrors `model.py::dwconv_tokens`). `x`: (grid² × d).
pub fn dwconv3x3(x: &[f32], dw: &[f32], grid: usize, d: usize) -> Vec<f32> {
    dwconv3x3_hw(x, dw, grid, grid, d)
}

/// DWConv over every image of a batch in one call, images fanned across
/// the shared kernel pool. Per-image outputs are disjoint and each image
/// runs the untouched [`dwconv3x3`], so the batched result is bit-exact vs
/// the per-image loop. `x` (b·grid² × d) is taken by value so the fan-out
/// `Arc`-shares it without copying the activation buffer.
pub fn dwconv3x3_batched(x: Vec<f32>, dw: &[f32], b: usize, grid: usize, d: usize) -> Vec<f32> {
    let px = grid * grid * d;
    assert_eq!(x.len(), b * px);
    let pool = crate::kernels::parallel::shared_pool();
    if b < 2 || pool.len() == 1 {
        let mut out = Vec::with_capacity(b * px);
        for img in 0..b {
            out.extend(dwconv3x3(&x[img * px..(img + 1) * px], dw, grid, d));
        }
        return out;
    }
    let xa = Arc::new(x);
    let dwa = Arc::new(dw.to_vec());
    let jobs: Vec<_> = (0..b)
        .map(|img| {
            let (xa, dwa) = (xa.clone(), dwa.clone());
            move || dwconv3x3(&xa[img * px..(img + 1) * px], &dwa, grid, d)
        })
        .collect();
    pool.scatter(jobs).concat()
}

/// Xavier-ish dense init used by every native weight matrix (mirror of
/// `model.py::_dense_init`).
pub fn dense_init(rng: &mut XorShift64, k: usize, n: usize) -> RawWeights {
    let scale = (2.0 / (k + n) as f32).sqrt();
    RawWeights::new(rng.normals(k * n).iter().map(|v| v * scale).collect(), k, n)
}

/// One linear layer on a planner-chosen registry backend, weights prepared
/// once at construction into the backend's deployment format.
pub struct LinearLayer {
    pub kernel: Arc<dyn LinearKernel>,
    pub weights: PreparedWeights,
    pub bias: Vec<f32>,
    /// Frozen symmetric INT8 activation scale. When set, operands are
    /// quantized with this fixed scale instead of the backend's per-tensor
    /// calibration, making `forward` **row-independent**: the output of a
    /// row does not depend on which other rows share the operand. The
    /// streaming session path (`infer::session`) relies on this for its
    /// chunk-split and cross-session batching bit-exactness guarantees.
    /// `None` (the default) keeps the backend's own operand preparation.
    pub act_scale: Option<f32>,
}

impl LinearLayer {
    /// `plan_m` is the representative row count the planner benchmarks at
    /// (the per-image token count; kernels accept any m at run time).
    pub fn new(
        planner: &Planner,
        primitive: Primitive,
        raw: &RawWeights,
        bias: Vec<f32>,
        plan_m: usize,
    ) -> LinearLayer {
        assert_eq!(bias.len(), raw.n);
        let kernel = planner.choose(primitive, Shape::new(plan_m, raw.k, raw.n));
        LinearLayer {
            weights: kernel.prepare(raw),
            kernel,
            bias,
            act_scale: None,
        }
    }

    /// Like [`LinearLayer::new`], but freezes the INT8 activation scale for
    /// quantizing primitives so the layer becomes row-independent. Only
    /// MatShift consumes INT8 operands; for other primitives the scale is
    /// ignored (their operand prep is already row-independent f32).
    pub fn new_frozen(
        planner: &Planner,
        primitive: Primitive,
        raw: &RawWeights,
        bias: Vec<f32>,
        plan_m: usize,
        act_scale: f32,
    ) -> LinearLayer {
        let mut layer = LinearLayer::new(planner, primitive, raw, bias, plan_m);
        if primitive == Primitive::MatShift {
            layer.act_scale = Some(act_scale);
        }
        layer
    }

    /// `y (m×n) = x (m×k) @ W + bias`.
    pub fn forward(&self, x: &[f32], m: usize) -> Vec<f32> {
        let k = self.weights.k();
        let op = match self.act_scale {
            Some(scale) => Operand::quantized_with_scale(x, m, k, scale),
            None => self.kernel.prepare_operand(x, m, k),
        };
        let mut out = vec![0.0f32; m * self.weights.n()];
        crate::kernels::registry::dispatch(self.kernel.as_ref(), &self.weights, &op, &mut out);
        for row in out.chunks_mut(self.bias.len()) {
            for (v, &b) in row.iter_mut().zip(&self.bias) {
                *v += b;
            }
        }
        out
    }
}

/// Raw (conversion-time) weights of one block — the oracle-visible source
/// of truth every deployment format is prepared from.
pub struct BlockRaw {
    pub ln1_g: Vec<f32>,
    pub ln1_b: Vec<f32>,
    pub ln2_g: Vec<f32>,
    pub ln2_b: Vec<f32>,
    pub wq: RawWeights,
    pub bq: Vec<f32>,
    pub wk: RawWeights,
    pub bk: Vec<f32>,
    pub wv: RawWeights,
    pub bv: Vec<f32>,
    pub wo: RawWeights,
    pub bo: Vec<f32>,
    /// depthwise 3×3 kernel on the V branch, (3·3·dim)
    pub dw: Vec<f32>,
    /// Mult expert / dense-MLP weights
    pub w1: RawWeights,
    pub b1: Vec<f32>,
    pub w2: RawWeights,
    pub b2: Vec<f32>,
    /// Shift expert weights (separate, as in `model.py`)
    pub w1s: RawWeights,
    pub b1s: Vec<f32>,
    pub w2s: RawWeights,
    pub b2s: Vec<f32>,
    /// router gate (dim × 2)
    pub gate_w: RawWeights,
}

impl BlockRaw {
    pub fn random(rng: &mut XorShift64, dim: usize, hidden: usize) -> BlockRaw {
        BlockRaw {
            ln1_g: vec![1.0; dim],
            ln1_b: vec![0.0; dim],
            ln2_g: vec![1.0; dim],
            ln2_b: vec![0.0; dim],
            wq: dense_init(rng, dim, dim),
            bq: vec![0.0; dim],
            wk: dense_init(rng, dim, dim),
            bk: vec![0.0; dim],
            wv: dense_init(rng, dim, dim),
            bv: vec![0.0; dim],
            wo: dense_init(rng, dim, dim),
            bo: vec![0.0; dim],
            dw: rng.normals(9 * dim).iter().map(|v| v * 0.1).collect(),
            w1: dense_init(rng, dim, hidden),
            b1: vec![0.0; hidden],
            w2: dense_init(rng, hidden, dim),
            b2: vec![0.0; dim],
            w1s: dense_init(rng, dim, hidden),
            b1s: vec![0.0; hidden],
            w2s: dense_init(rng, hidden, dim),
            b2s: vec![0.0; dim],
            gate_w: RawWeights::new(
                rng.normals(dim * 2).iter().map(|v| v * 0.02).collect(),
                dim,
                2,
            ),
        }
    }
}

/// The MLP sublayer's execution form.
pub enum MlpKind {
    /// one dense path (Mult or Shift primitive behind both linears)
    Dense { l1: LinearLayer, l2: LinearLayer },
    /// sparse Mult/Shift mixture with a router
    Moe(MoeMlp),
}

/// How the attention sublayer executes over a batch of images.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum AttnExec {
    /// One fused per-layer dispatch per primitive across every image and
    /// head: one KSH hash sweep, two grouped MatAdd calls (LinearAdd), one
    /// pool fan-out for the scalar families and the DWConv branch.
    Fused,
    /// The historical reference: image-by-image, head-by-head dispatch
    /// (`b·heads·4` MatAdd calls per LinearAdd layer). Kept as the
    /// bit-exactness baseline the property suite compares against.
    PerImage,
}

/// Per-block diagnostics from one forward.
pub struct BlockTrace {
    pub attn_ms: f64,
    pub mlp_ms: f64,
    /// Kernel **calls** the attention sublayer issued (LinearAdd only; the
    /// scalar families dispatch no kernels): the fused path makes 2
    /// grouped [`LinearKernel::run_grouped`] calls per layer — each
    /// covering all images×heads with one packed operand, though backends
    /// without a grouped override still fan out per group internally — the
    /// per-image path `b·heads·4` plain `run` calls.
    pub attn_dispatches: usize,
    /// present iff the block's MLP is a MoE
    pub moe: Option<MoeTrace>,
}

/// One native transformer block.
pub struct NativeBlock {
    pub dim: usize,
    pub heads: usize,
    pub tokens: usize,
    pub grid: usize,
    pub variant: Variant,
    pub raw: BlockRaw,
    wq: LinearLayer,
    wk: LinearLayer,
    wv: LinearLayer,
    wo: LinearLayer,
    pub mlp: MlpKind,
    /// KSH hash family (LinearAdd only); seeded per stage so every block of
    /// a stage shares one family, as Ecoformer prescribes.
    pub hasher: Option<KshHasher>,
    /// MatAdd backend the Hamming attention runs on (LinearAdd only)
    matadd: Option<Arc<dyn LinearKernel>>,
    /// code width (= head_dim, `model.py`'s hash_bits default)
    pub bits: usize,
}

impl NativeBlock {
    pub fn from_raw(
        raw: BlockRaw,
        tokens: usize,
        heads: usize,
        variant: Variant,
        planner: &Planner,
        buckets: &[usize],
        hash_seed: u64,
    ) -> NativeBlock {
        let dim = raw.wq.k;
        assert_eq!(dim % heads.max(1), 0, "dim must split into heads");
        let grid = (tokens as f64).sqrt().round() as usize;
        assert!(
            grid * grid == tokens || variant.attn == Attn::Msa,
            "linear variants need a square token grid (got {tokens} tokens)"
        );
        let lin_prim = match variant.attn_linear {
            Lin::Mult => Primitive::MatMul,
            Lin::Shift => Primitive::MatShift,
        };
        let wq = LinearLayer::new(planner, lin_prim, &raw.wq, raw.bq.clone(), tokens);
        let wk = LinearLayer::new(planner, lin_prim, &raw.wk, raw.bk.clone(), tokens);
        let wv = LinearLayer::new(planner, lin_prim, &raw.wv, raw.bv.clone(), tokens);
        let wo = LinearLayer::new(planner, lin_prim, &raw.wo, raw.bo.clone(), tokens);
        let mlp = match variant.mlp {
            Mlp::Mult => MlpKind::Dense {
                l1: LinearLayer::new(planner, Primitive::MatMul, &raw.w1, raw.b1.clone(), tokens),
                l2: LinearLayer::new(planner, Primitive::MatMul, &raw.w2, raw.b2.clone(), tokens),
            },
            Mlp::Shift => MlpKind::Dense {
                l1: LinearLayer::new(
                    planner,
                    Primitive::MatShift,
                    &raw.w1s,
                    raw.b1s.clone(),
                    tokens,
                ),
                l2: LinearLayer::new(
                    planner,
                    Primitive::MatShift,
                    &raw.w2s,
                    raw.b2s.clone(),
                    tokens,
                ),
            },
            Mlp::Moe { .. } => {
                let max_m = *buckets.last().expect("no buckets");
                let mult = MlpExpert::new(
                    planner,
                    Primitive::MatMul,
                    &raw.w1,
                    raw.b1.clone(),
                    &raw.w2,
                    raw.b2.clone(),
                    max_m,
                );
                let shift = MlpExpert::new(
                    planner,
                    Primitive::MatShift,
                    &raw.w1s,
                    raw.b1s.clone(),
                    &raw.w2s,
                    raw.b2s.clone(),
                    max_m,
                );
                MlpKind::Moe(MoeMlp::mult_shift(
                    planner,
                    &raw.gate_w,
                    mult,
                    shift,
                    buckets.to_vec(),
                ))
            }
        };
        let hd = dim / heads;
        let bits = hd;
        let (hasher, matadd) = if variant.attn == Attn::LinearAdd {
            // The fused image path issues grouped dispatches whose per-group
            // row count is hd+1; plan the MatAdd backend at the
            // heads·(hd+1) fused shape so saved tables carry the batched
            // geometry. `choose_batched` adopts any pinned same-(k, n)
            // decision at another row count — in particular tables written
            // before the fused path existed, which pinned the per-head
            // m = hd shape — so startup never re-benchmarks a known family.
            (
                Some(KshHasher::new(hd, bits, hash_seed)),
                Some(planner.choose_batched(
                    Primitive::MatAdd,
                    Shape::new(heads * (hd + 1), tokens, bits),
                )),
            )
        } else {
            (None, None)
        };
        NativeBlock {
            dim,
            heads,
            tokens,
            grid,
            variant,
            raw,
            wq,
            wk,
            wv,
            wo,
            mlp,
            hasher,
            matadd,
            bits,
        }
    }

    /// In-place block forward over `b` images' tokens (`x`: b·tokens×dim),
    /// on the fused batched attention path.
    pub fn forward(&self, x: &mut [f32], b: usize) -> BlockTrace {
        self.forward_with(x, b, AttnExec::Fused)
    }

    /// The per-image/per-head reference execution — the baseline
    /// [`NativeBlock::forward`] is property-tested bit-exact against
    /// (`rust/tests/prop_batched_attn.rs` drives the comparison through
    /// this method).
    pub fn forward_per_image(&self, x: &mut [f32], b: usize) -> BlockTrace {
        self.forward_with(x, b, AttnExec::PerImage)
    }

    /// Block forward with an explicit attention execution mode.
    pub fn forward_with(&self, x: &mut [f32], b: usize, exec: AttnExec) -> BlockTrace {
        let d = self.dim;
        let n = self.tokens;
        let t = b * n;
        assert_eq!(x.len(), t * d);

        // --- attention sublayer -------------------------------------------
        let t_attn = Instant::now();
        let u = layer_norm(x, &self.raw.ln1_g, &self.raw.ln1_b, d);
        let q = self.wq.forward(&u, t);
        let k = self.wk.forward(&u, t);
        let v = self.wv.forward(&u, t);
        let (o, attn_dispatches) = match exec {
            AttnExec::Fused => self.attn_fused(&q, &k, v, b),
            AttnExec::PerImage => self.attn_per_image(&q, &k, &v, b),
        };
        let a = self.wo.forward(&o, t);
        for (xv, av) in x.iter_mut().zip(&a) {
            *xv += av;
        }
        let attn_ms = t_attn.elapsed().as_secs_f64() * 1e3;

        // --- MLP sublayer -------------------------------------------------
        let t_mlp = Instant::now();
        let u2 = layer_norm(x, &self.raw.ln2_g, &self.raw.ln2_b, d);
        let (y, moe) = match &self.mlp {
            MlpKind::Dense { l1, l2 } => {
                let mut h = l1.forward(&u2, t);
                for v in h.iter_mut() {
                    *v = v.max(0.0);
                }
                (l2.forward(&h, t), None)
            }
            MlpKind::Moe(m) => {
                let (y, trace) = m.forward(&u2, t);
                (y, Some(trace))
            }
        };
        for (xv, yv) in x.iter_mut().zip(&y) {
            *xv += yv;
        }
        BlockTrace {
            attn_ms,
            mlp_ms: t_mlp.elapsed().as_secs_f64() * 1e3,
            attn_dispatches,
            moe,
        }
    }

    /// Fused attention over all images and heads: one head-major packing,
    /// one KSH hash sweep, per-layer grouped/fanned dispatches, batched
    /// DWConv (`v` by value so its fan-out is copy-free). Returns the
    /// attention output (b·n × d) and the grouped-call count.
    fn attn_fused(&self, q: &[f32], k: &[f32], v: Vec<f32>, b: usize) -> (Vec<f32>, usize) {
        let d = self.dim;
        let n = self.tokens;
        let hd = d / self.heads;
        let g = b * self.heads;
        let qh = pack_heads(q, b, n, self.heads, hd);
        let kh = pack_heads(k, b, n, self.heads, hd);
        let vh = pack_heads(&v, b, n, self.heads, hd);
        let (oh, dispatches) = match self.variant.attn {
            Attn::Msa => (softmax_attn_batched(qh, kh, vh, n, hd), 0),
            Attn::Linear => (relu_linear_attn_batched(qh, kh, vh, n, hd), 0),
            Attn::LinearAdd => {
                let hasher = self.hasher.as_ref().expect("LinearAdd needs a hasher");
                let kernel = self.matadd.as_ref().expect("LinearAdd needs MatAdd");
                // ONE hash sweep over every image's and head's tokens.
                let qc = hasher.hash_matrix(&qh, g * n);
                let kc = hasher.hash_matrix(&kh, g * n);
                (
                    hamming_linear_attn_batched(kernel, &qc, &kc, &vh, n, self.bits, hd),
                    2,
                )
            }
        };
        let mut o = unpack_heads(&oh, b, n, self.heads, hd);
        if self.variant.attn != Attn::Msa {
            // Parallel DWConv on the V branch (local features), every image
            // in one batched call (consumes `v`).
            let conv = dwconv3x3_batched(v, &self.raw.dw, b, self.grid, d);
            for (ov, cv) in o.iter_mut().zip(&conv) {
                *ov += cv;
            }
        }
        (o, dispatches)
    }

    /// The historical image-by-image, head-by-head attention loop.
    fn attn_per_image(&self, q: &[f32], k: &[f32], v: &[f32], b: usize) -> (Vec<f32>, usize) {
        let d = self.dim;
        let n = self.tokens;
        let hd = d / self.heads;
        let mut o = vec![0.0f32; b * n * d];
        let mut dispatches = 0usize;
        let mut qh = vec![0.0f32; n * hd];
        let mut kh = vec![0.0f32; n * hd];
        let mut vh = vec![0.0f32; n * hd];
        for img in 0..b {
            let base = img * n * d;
            for h in 0..self.heads {
                for i in 0..n {
                    let src = base + i * d + h * hd;
                    qh[i * hd..(i + 1) * hd].copy_from_slice(&q[src..src + hd]);
                    kh[i * hd..(i + 1) * hd].copy_from_slice(&k[src..src + hd]);
                    vh[i * hd..(i + 1) * hd].copy_from_slice(&v[src..src + hd]);
                }
                let oh = match self.variant.attn {
                    Attn::Msa => softmax_attn(&qh, &kh, &vh, n, hd),
                    Attn::Linear => relu_linear_attn(&qh, &kh, &vh, n, hd),
                    Attn::LinearAdd => {
                        let hasher = self.hasher.as_ref().expect("LinearAdd needs a hasher");
                        let kernel = self.matadd.as_ref().expect("LinearAdd needs MatAdd");
                        let qc = hasher.hash_matrix(&qh, n);
                        let kc = hasher.hash_matrix(&kh, n);
                        dispatches += 4; // kᵀv, z, q(kᵀv), den
                        hamming_linear_attn_kernel(kernel, &qc, &kc, &vh, n, self.bits, hd)
                    }
                };
                for i in 0..n {
                    let dst = base + i * d + h * hd;
                    o[dst..dst + hd].copy_from_slice(&oh[i * hd..(i + 1) * hd]);
                }
            }
            if self.variant.attn != Attn::Msa {
                // Parallel DWConv on the V branch (local features).
                let conv = dwconv3x3(&v[base..base + n * d], &self.raw.dw, self.grid, d);
                for (ov, cv) in o[base..base + n * d].iter_mut().zip(&conv) {
                    *ov += cv;
                }
            }
        }
        (o, dispatches)
    }

    /// Registry ids of the four attention linears (diagnostics).
    pub fn linear_backend_id(&self) -> String {
        self.wq.kernel.id()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kernels::registry::KernelRegistry;

    fn planner() -> Planner {
        Planner::new(Arc::new(KernelRegistry::with_defaults()))
    }

    #[test]
    fn layer_norm_normalizes_rows() {
        let d = 4;
        let g = vec![1.0; d];
        let b = vec![0.0; d];
        let x = vec![1.0f32, 2.0, 3.0, 4.0];
        let y = layer_norm(&x, &g, &b, d);
        let mean: f32 = y.iter().sum::<f32>() / d as f32;
        assert!(mean.abs() < 1e-5);
        let var: f32 = y.iter().map(|v| v * v).sum::<f32>() / d as f32;
        assert!((var - 1.0).abs() < 1e-3, "{var}");
    }

    #[test]
    fn dwconv_identity_kernel_recovers_input() {
        // A kernel with 1 at the center tap and 0 elsewhere is identity.
        let (grid, d) = (4, 3);
        let mut dw = vec![0.0f32; 9 * d];
        for c in 0..d {
            dw[4 * d + c] = 1.0; // center tap (dy=1, dx=1)
        }
        let mut rng = XorShift64::new(3);
        let x = rng.normals(grid * grid * d);
        assert_eq!(dwconv3x3(&x, &dw, grid, d), x);
    }

    #[test]
    fn dwconv_edge_padding_counts_neighbors() {
        // All-ones input and all-ones kernel: each output equals the number
        // of in-bounds taps — 4 at corners, 6 on edges, 9 in the interior.
        let (grid, d) = (3, 2);
        let x = vec![1.0f32; grid * grid * d];
        let dw = vec![1.0f32; 9 * d];
        let out = dwconv3x3(&x, &dw, grid, d);
        let want = [4.0, 6.0, 4.0, 6.0, 9.0, 6.0, 4.0, 6.0, 4.0];
        for (cell, &w) in want.iter().enumerate() {
            for c in 0..d {
                assert_eq!(out[cell * d + c], w, "cell {cell} channel {c}");
            }
        }
    }

    #[test]
    fn dwconv_corner_tap_shifts_the_grid() {
        // A kernel with only the (dy=0, dx=0) tap reads x[y-1][x-1]: output
        // row/col 0 see zero padding, the rest is the input shifted by one.
        let grid = 3;
        let x: Vec<f32> = (1..=9).map(|v| v as f32).collect();
        let mut dw = vec![0.0f32; 9];
        dw[0] = 1.0;
        let out = dwconv3x3(&x, &dw, grid, 1);
        assert_eq!(out, vec![0.0, 0.0, 0.0, 0.0, 1.0, 2.0, 0.0, 4.0, 5.0]);
    }

    #[test]
    fn dwconv_non_square_grid_matches_bruteforce() {
        // 2×4 grid against an independent brute-force accumulation.
        let (h, w, d) = (2usize, 4usize, 3usize);
        let mut rng = XorShift64::new(77);
        let x = rng.normals(h * w * d);
        let dw = rng.normals(9 * d);
        let got = dwconv3x3_hw(&x, &dw, h, w, d);
        for y in 0..h as isize {
            for xx in 0..w as isize {
                for c in 0..d {
                    let mut want = 0.0f32;
                    for dy in -1..=1isize {
                        for dx in -1..=1isize {
                            let (sy, sx) = (y + dy, xx + dx);
                            if sy >= 0 && sy < h as isize && sx >= 0 && sx < w as isize {
                                want += x[((sy * w as isize + sx) as usize) * d + c]
                                    * dw[(((dy + 1) * 3 + dx + 1) as usize) * d + c];
                            }
                        }
                    }
                    let got_v = got[((y * w as isize + xx) as usize) * d + c];
                    assert_eq!(got_v, want, "({y},{xx}) channel {c}");
                }
            }
        }
    }

    #[test]
    fn dwconv_batched_matches_per_image_bit_exactly() {
        let (b, grid, d) = (3, 4, 2);
        let mut rng = XorShift64::new(91);
        let x = rng.normals(b * grid * grid * d);
        let dw = rng.normals(9 * d);
        let got = dwconv3x3_batched(x.clone(), &dw, b, grid, d);
        let px = grid * grid * d;
        for img in 0..b {
            assert_eq!(
                &got[img * px..(img + 1) * px],
                dwconv3x3(&x[img * px..(img + 1) * px], &dw, grid, d).as_slice(),
                "image {img}"
            );
        }
    }

    #[test]
    fn block_forward_all_variants_finite_and_shaped() {
        let (tokens, dim, heads) = (16, 8, 2);
        let mut rng = XorShift64::new(17);
        for variant in [
            Variant::MSA,
            Variant::LINEAR,
            Variant::ADD,
            Variant::ADD_SHIFT_BOTH,
            Variant::SHIFTADD_MOE,
        ] {
            let p = planner();
            let raw = BlockRaw::random(&mut rng, dim, dim * 2);
            let blk = NativeBlock::from_raw(raw, tokens, heads, variant, &p, &[16, 64], 7);
            let mut x = rng.normals(2 * tokens * dim);
            let trace = blk.forward(&mut x, 2);
            assert!(x.iter().all(|v| v.is_finite()), "{variant:?}");
            assert_eq!(trace.moe.is_some(), matches!(variant.mlp, Mlp::Moe { .. }));
        }
    }

    #[test]
    fn frozen_scale_shift_layer_is_row_independent() {
        // Per-tensor INT8 calibration makes a MatShift layer's output depend
        // on which rows share the operand; a frozen scale must not.
        let p = planner();
        let mut rng = XorShift64::new(41);
        let raw = dense_init(&mut rng, 8, 8);
        let layer =
            LinearLayer::new_frozen(&p, Primitive::MatShift, &raw, vec![0.1; 8], 16, 6.0 / 127.0);
        assert!(layer.act_scale.is_some());
        let x = rng.normals(4 * 8);
        let all = layer.forward(&x, 4);
        for i in 0..4 {
            let one = layer.forward(&x[i * 8..(i + 1) * 8], 1);
            assert_eq!(one, &all[i * 8..(i + 1) * 8], "row {i} depends on batch");
        }
        // Non-quantizing primitives ignore the frozen scale.
        let dense = LinearLayer::new_frozen(&p, Primitive::MatMul, &raw, vec![0.0; 8], 16, 1.0);
        assert!(dense.act_scale.is_none());
    }

    #[test]
    fn residual_path_preserves_scale() {
        // Pre-norm + residual: output must not be wildly larger than input.
        let (tokens, dim, heads) = (16, 8, 2);
        let mut rng = XorShift64::new(23);
        let p = planner();
        let raw = BlockRaw::random(&mut rng, dim, dim * 2);
        let blk = NativeBlock::from_raw(raw, tokens, heads, Variant::SHIFTADD_MOE, &p, &[16, 64], 7);
        let x0 = rng.normals(tokens * dim);
        let mut x = x0.clone();
        blk.forward(&mut x, 1);
        let norm0: f32 = x0.iter().map(|v| v * v).sum::<f32>().sqrt();
        let norm1: f32 = x.iter().map(|v| v * v).sum::<f32>().sqrt();
        assert!(norm1 < 20.0 * norm0, "{norm1} vs {norm0}");
        assert!(norm1 > 0.0);
    }
}
