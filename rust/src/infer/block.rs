//! The native pre-norm ShiftAddViT block — mirror of
//! `python/compile/model.py`'s per-block forward, with every linear on a
//! registry [`LinearKernel`] backend:
//!
//! ```text
//!   x += Wo( attn(LN1(x)) [+ DWConv(V)] )     attention sublayer
//!   x += MLP(LN2(x))                          Mult | Shift | MoE sublayer
//! ```
//!
//! The attention family and the primitive behind each linear follow the
//! [`Variant`] (the same enum the analytic op counting uses), so
//! `Variant::SHIFTADD_MOE` executes exactly the mixture the paper deploys:
//! KSH-binarized LinearAdd attention (MatAdd), shift-reparameterized
//! attention linears (MatShift), and the Mult/Shift MoE MLP
//! ([`crate::moe::experts::MoeMlp`]). Raw weights are retained on the block
//! (`raw`) so oracle tests can re-derive every deployment format.

use std::sync::Arc;
use std::time::Instant;

use crate::infer::attn::{hamming_linear_attn_kernel, relu_linear_attn, softmax_attn};
use crate::kernels::api::{LinearKernel, Operand, PreparedWeights, Primitive, RawWeights};
use crate::kernels::planner::{Planner, Shape};
use crate::model::ops::{Attn, Lin, Mlp, Variant};
use crate::moe::experts::{MlpExpert, MoeMlp, MoeTrace};
use crate::quant::ksh::KshHasher;
use crate::util::rng::XorShift64;

/// LayerNorm epsilon (mirrors `model.py::layer_norm`).
pub const LN_EPS: f32 = 1e-6;

/// Row-wise LayerNorm over the last dim: `(x-μ)/√(σ²+ε)·g + b`.
pub fn layer_norm(x: &[f32], g: &[f32], b: &[f32], d: usize) -> Vec<f32> {
    assert_eq!(x.len() % d, 0);
    let mut out = vec![0.0f32; x.len()];
    for (row, orow) in x.chunks(d).zip(out.chunks_mut(d)) {
        let mut mu = 0.0f32;
        for &v in row {
            mu += v;
        }
        mu /= d as f32;
        let mut var = 0.0f32;
        for &v in row {
            var += (v - mu) * (v - mu);
        }
        var /= d as f32;
        let denom = (var + LN_EPS).sqrt();
        for ((o, &v), (&gg, &bb)) in orow.iter_mut().zip(row).zip(g.iter().zip(b)) {
            *o = (v - mu) / denom * gg + bb;
        }
    }
    out
}

/// Depthwise 3×3 convolution over one image's token grid, SAME padding
/// (mirrors `model.py::dwconv_tokens`). `x`: (grid² × d); `dw`: (3·3·d).
pub fn dwconv3x3(x: &[f32], dw: &[f32], grid: usize, d: usize) -> Vec<f32> {
    assert_eq!(x.len(), grid * grid * d);
    assert_eq!(dw.len(), 9 * d);
    let mut out = vec![0.0f32; grid * grid * d];
    for y in 0..grid {
        for xx in 0..grid {
            for c in 0..d {
                let mut acc = 0.0f32;
                for dy in 0..3usize {
                    for dx in 0..3usize {
                        let sy = y + dy;
                        let sx = xx + dx;
                        if sy >= 1 && sy <= grid && sx >= 1 && sx <= grid {
                            acc += x[((sy - 1) * grid + (sx - 1)) * d + c]
                                * dw[(dy * 3 + dx) * d + c];
                        }
                    }
                }
                out[(y * grid + xx) * d + c] = acc;
            }
        }
    }
    out
}

/// Xavier-ish dense init used by every native weight matrix (mirror of
/// `model.py::_dense_init`).
pub fn dense_init(rng: &mut XorShift64, k: usize, n: usize) -> RawWeights {
    let scale = (2.0 / (k + n) as f32).sqrt();
    RawWeights::new(rng.normals(k * n).iter().map(|v| v * scale).collect(), k, n)
}

/// One linear layer on a planner-chosen registry backend, weights prepared
/// once at construction into the backend's deployment format.
pub struct LinearLayer {
    pub kernel: Arc<dyn LinearKernel>,
    pub weights: PreparedWeights,
    pub bias: Vec<f32>,
    /// Frozen symmetric INT8 activation scale. When set, operands are
    /// quantized with this fixed scale instead of the backend's per-tensor
    /// calibration, making `forward` **row-independent**: the output of a
    /// row does not depend on which other rows share the operand. The
    /// streaming session path (`infer::session`) relies on this for its
    /// chunk-split and cross-session batching bit-exactness guarantees.
    /// `None` (the default) keeps the backend's own operand preparation.
    pub act_scale: Option<f32>,
}

impl LinearLayer {
    /// `plan_m` is the representative row count the planner benchmarks at
    /// (the per-image token count; kernels accept any m at run time).
    pub fn new(
        planner: &Planner,
        primitive: Primitive,
        raw: &RawWeights,
        bias: Vec<f32>,
        plan_m: usize,
    ) -> LinearLayer {
        assert_eq!(bias.len(), raw.n);
        let kernel = planner.choose(primitive, Shape::new(plan_m, raw.k, raw.n));
        LinearLayer {
            weights: kernel.prepare(raw),
            kernel,
            bias,
            act_scale: None,
        }
    }

    /// Like [`LinearLayer::new`], but freezes the INT8 activation scale for
    /// quantizing primitives so the layer becomes row-independent. Only
    /// MatShift consumes INT8 operands; for other primitives the scale is
    /// ignored (their operand prep is already row-independent f32).
    pub fn new_frozen(
        planner: &Planner,
        primitive: Primitive,
        raw: &RawWeights,
        bias: Vec<f32>,
        plan_m: usize,
        act_scale: f32,
    ) -> LinearLayer {
        let mut layer = LinearLayer::new(planner, primitive, raw, bias, plan_m);
        if primitive == Primitive::MatShift {
            layer.act_scale = Some(act_scale);
        }
        layer
    }

    /// `y (m×n) = x (m×k) @ W + bias`.
    pub fn forward(&self, x: &[f32], m: usize) -> Vec<f32> {
        let k = self.weights.k();
        let op = match self.act_scale {
            Some(scale) => Operand::quantized_with_scale(x, m, k, scale),
            None => self.kernel.prepare_operand(x, m, k),
        };
        let mut out = vec![0.0f32; m * self.weights.n()];
        self.kernel.run(&self.weights, &op, &mut out);
        for row in out.chunks_mut(self.bias.len()) {
            for (v, &b) in row.iter_mut().zip(&self.bias) {
                *v += b;
            }
        }
        out
    }
}

/// Raw (conversion-time) weights of one block — the oracle-visible source
/// of truth every deployment format is prepared from.
pub struct BlockRaw {
    pub ln1_g: Vec<f32>,
    pub ln1_b: Vec<f32>,
    pub ln2_g: Vec<f32>,
    pub ln2_b: Vec<f32>,
    pub wq: RawWeights,
    pub bq: Vec<f32>,
    pub wk: RawWeights,
    pub bk: Vec<f32>,
    pub wv: RawWeights,
    pub bv: Vec<f32>,
    pub wo: RawWeights,
    pub bo: Vec<f32>,
    /// depthwise 3×3 kernel on the V branch, (3·3·dim)
    pub dw: Vec<f32>,
    /// Mult expert / dense-MLP weights
    pub w1: RawWeights,
    pub b1: Vec<f32>,
    pub w2: RawWeights,
    pub b2: Vec<f32>,
    /// Shift expert weights (separate, as in `model.py`)
    pub w1s: RawWeights,
    pub b1s: Vec<f32>,
    pub w2s: RawWeights,
    pub b2s: Vec<f32>,
    /// router gate (dim × 2)
    pub gate_w: RawWeights,
}

impl BlockRaw {
    pub fn random(rng: &mut XorShift64, dim: usize, hidden: usize) -> BlockRaw {
        BlockRaw {
            ln1_g: vec![1.0; dim],
            ln1_b: vec![0.0; dim],
            ln2_g: vec![1.0; dim],
            ln2_b: vec![0.0; dim],
            wq: dense_init(rng, dim, dim),
            bq: vec![0.0; dim],
            wk: dense_init(rng, dim, dim),
            bk: vec![0.0; dim],
            wv: dense_init(rng, dim, dim),
            bv: vec![0.0; dim],
            wo: dense_init(rng, dim, dim),
            bo: vec![0.0; dim],
            dw: rng.normals(9 * dim).iter().map(|v| v * 0.1).collect(),
            w1: dense_init(rng, dim, hidden),
            b1: vec![0.0; hidden],
            w2: dense_init(rng, hidden, dim),
            b2: vec![0.0; dim],
            w1s: dense_init(rng, dim, hidden),
            b1s: vec![0.0; hidden],
            w2s: dense_init(rng, hidden, dim),
            b2s: vec![0.0; dim],
            gate_w: RawWeights::new(
                rng.normals(dim * 2).iter().map(|v| v * 0.02).collect(),
                dim,
                2,
            ),
        }
    }
}

/// The MLP sublayer's execution form.
pub enum MlpKind {
    /// one dense path (Mult or Shift primitive behind both linears)
    Dense { l1: LinearLayer, l2: LinearLayer },
    /// sparse Mult/Shift mixture with a router
    Moe(MoeMlp),
}

/// Per-block diagnostics from one forward.
pub struct BlockTrace {
    pub attn_ms: f64,
    pub mlp_ms: f64,
    /// present iff the block's MLP is a MoE
    pub moe: Option<MoeTrace>,
}

/// One native transformer block.
pub struct NativeBlock {
    pub dim: usize,
    pub heads: usize,
    pub tokens: usize,
    pub grid: usize,
    pub variant: Variant,
    pub raw: BlockRaw,
    wq: LinearLayer,
    wk: LinearLayer,
    wv: LinearLayer,
    wo: LinearLayer,
    pub mlp: MlpKind,
    /// KSH hash family (LinearAdd only); seeded per stage so every block of
    /// a stage shares one family, as Ecoformer prescribes.
    pub hasher: Option<KshHasher>,
    /// MatAdd backend the Hamming attention runs on (LinearAdd only)
    matadd: Option<Arc<dyn LinearKernel>>,
    /// code width (= head_dim, `model.py`'s hash_bits default)
    pub bits: usize,
}

impl NativeBlock {
    pub fn from_raw(
        raw: BlockRaw,
        tokens: usize,
        heads: usize,
        variant: Variant,
        planner: &Planner,
        buckets: &[usize],
        hash_seed: u64,
    ) -> NativeBlock {
        let dim = raw.wq.k;
        assert_eq!(dim % heads.max(1), 0, "dim must split into heads");
        let grid = (tokens as f64).sqrt().round() as usize;
        assert!(
            grid * grid == tokens || variant.attn == Attn::Msa,
            "linear variants need a square token grid (got {tokens} tokens)"
        );
        let lin_prim = match variant.attn_linear {
            Lin::Mult => Primitive::MatMul,
            Lin::Shift => Primitive::MatShift,
        };
        let wq = LinearLayer::new(planner, lin_prim, &raw.wq, raw.bq.clone(), tokens);
        let wk = LinearLayer::new(planner, lin_prim, &raw.wk, raw.bk.clone(), tokens);
        let wv = LinearLayer::new(planner, lin_prim, &raw.wv, raw.bv.clone(), tokens);
        let wo = LinearLayer::new(planner, lin_prim, &raw.wo, raw.bo.clone(), tokens);
        let mlp = match variant.mlp {
            Mlp::Mult => MlpKind::Dense {
                l1: LinearLayer::new(planner, Primitive::MatMul, &raw.w1, raw.b1.clone(), tokens),
                l2: LinearLayer::new(planner, Primitive::MatMul, &raw.w2, raw.b2.clone(), tokens),
            },
            Mlp::Shift => MlpKind::Dense {
                l1: LinearLayer::new(
                    planner,
                    Primitive::MatShift,
                    &raw.w1s,
                    raw.b1s.clone(),
                    tokens,
                ),
                l2: LinearLayer::new(
                    planner,
                    Primitive::MatShift,
                    &raw.w2s,
                    raw.b2s.clone(),
                    tokens,
                ),
            },
            Mlp::Moe { .. } => {
                let max_m = *buckets.last().expect("no buckets");
                let mult = MlpExpert::new(
                    planner,
                    Primitive::MatMul,
                    &raw.w1,
                    raw.b1.clone(),
                    &raw.w2,
                    raw.b2.clone(),
                    max_m,
                );
                let shift = MlpExpert::new(
                    planner,
                    Primitive::MatShift,
                    &raw.w1s,
                    raw.b1s.clone(),
                    &raw.w2s,
                    raw.b2s.clone(),
                    max_m,
                );
                MlpKind::Moe(MoeMlp::mult_shift(
                    planner,
                    &raw.gate_w,
                    mult,
                    shift,
                    buckets.to_vec(),
                ))
            }
        };
        let hd = dim / heads;
        let bits = hd;
        let (hasher, matadd) = if variant.attn == Attn::LinearAdd {
            (
                Some(KshHasher::new(hd, bits, hash_seed)),
                Some(planner.choose(Primitive::MatAdd, Shape::new(hd, tokens, bits))),
            )
        } else {
            (None, None)
        };
        NativeBlock {
            dim,
            heads,
            tokens,
            grid,
            variant,
            raw,
            wq,
            wk,
            wv,
            wo,
            mlp,
            hasher,
            matadd,
            bits,
        }
    }

    /// In-place block forward over `b` images' tokens (`x`: b·tokens×dim).
    pub fn forward(&self, x: &mut [f32], b: usize) -> BlockTrace {
        let d = self.dim;
        let n = self.tokens;
        let t = b * n;
        assert_eq!(x.len(), t * d);
        let hd = d / self.heads;

        // --- attention sublayer -------------------------------------------
        let t_attn = Instant::now();
        let u = layer_norm(x, &self.raw.ln1_g, &self.raw.ln1_b, d);
        let q = self.wq.forward(&u, t);
        let k = self.wk.forward(&u, t);
        let v = self.wv.forward(&u, t);
        let mut o = vec![0.0f32; t * d];
        let mut qh = vec![0.0f32; n * hd];
        let mut kh = vec![0.0f32; n * hd];
        let mut vh = vec![0.0f32; n * hd];
        for img in 0..b {
            let base = img * n * d;
            for h in 0..self.heads {
                for i in 0..n {
                    let src = base + i * d + h * hd;
                    qh[i * hd..(i + 1) * hd].copy_from_slice(&q[src..src + hd]);
                    kh[i * hd..(i + 1) * hd].copy_from_slice(&k[src..src + hd]);
                    vh[i * hd..(i + 1) * hd].copy_from_slice(&v[src..src + hd]);
                }
                let oh = match self.variant.attn {
                    Attn::Msa => softmax_attn(&qh, &kh, &vh, n, hd),
                    Attn::Linear => relu_linear_attn(&qh, &kh, &vh, n, hd),
                    Attn::LinearAdd => {
                        let hasher = self.hasher.as_ref().expect("LinearAdd needs a hasher");
                        let kernel = self.matadd.as_ref().expect("LinearAdd needs MatAdd");
                        let qc = hasher.hash_matrix(&qh, n);
                        let kc = hasher.hash_matrix(&kh, n);
                        hamming_linear_attn_kernel(kernel, &qc, &kc, &vh, n, self.bits, hd)
                    }
                };
                for i in 0..n {
                    let dst = base + i * d + h * hd;
                    o[dst..dst + hd].copy_from_slice(&oh[i * hd..(i + 1) * hd]);
                }
            }
            if self.variant.attn != Attn::Msa {
                // Parallel DWConv on the V branch (local features).
                let conv = dwconv3x3(&v[base..base + n * d], &self.raw.dw, self.grid, d);
                for (ov, cv) in o[base..base + n * d].iter_mut().zip(&conv) {
                    *ov += cv;
                }
            }
        }
        let a = self.wo.forward(&o, t);
        for (xv, av) in x.iter_mut().zip(&a) {
            *xv += av;
        }
        let attn_ms = t_attn.elapsed().as_secs_f64() * 1e3;

        // --- MLP sublayer -------------------------------------------------
        let t_mlp = Instant::now();
        let u2 = layer_norm(x, &self.raw.ln2_g, &self.raw.ln2_b, d);
        let (y, moe) = match &self.mlp {
            MlpKind::Dense { l1, l2 } => {
                let mut h = l1.forward(&u2, t);
                for v in h.iter_mut() {
                    *v = v.max(0.0);
                }
                (l2.forward(&h, t), None)
            }
            MlpKind::Moe(m) => {
                let (y, trace) = m.forward(&u2, t);
                (y, Some(trace))
            }
        };
        for (xv, yv) in x.iter_mut().zip(&y) {
            *xv += yv;
        }
        BlockTrace {
            attn_ms,
            mlp_ms: t_mlp.elapsed().as_secs_f64() * 1e3,
            moe,
        }
    }

    /// Registry ids of the four attention linears (diagnostics).
    pub fn linear_backend_id(&self) -> String {
        self.wq.kernel.id()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kernels::registry::KernelRegistry;

    fn planner() -> Planner {
        Planner::new(Arc::new(KernelRegistry::with_defaults()))
    }

    #[test]
    fn layer_norm_normalizes_rows() {
        let d = 4;
        let g = vec![1.0; d];
        let b = vec![0.0; d];
        let x = vec![1.0f32, 2.0, 3.0, 4.0];
        let y = layer_norm(&x, &g, &b, d);
        let mean: f32 = y.iter().sum::<f32>() / d as f32;
        assert!(mean.abs() < 1e-5);
        let var: f32 = y.iter().map(|v| v * v).sum::<f32>() / d as f32;
        assert!((var - 1.0).abs() < 1e-3, "{var}");
    }

    #[test]
    fn dwconv_identity_kernel_recovers_input() {
        // A kernel with 1 at the center tap and 0 elsewhere is identity.
        let (grid, d) = (4, 3);
        let mut dw = vec![0.0f32; 9 * d];
        for c in 0..d {
            dw[4 * d + c] = 1.0; // center tap (dy=1, dx=1)
        }
        let mut rng = XorShift64::new(3);
        let x = rng.normals(grid * grid * d);
        assert_eq!(dwconv3x3(&x, &dw, grid, d), x);
    }

    #[test]
    fn block_forward_all_variants_finite_and_shaped() {
        let (tokens, dim, heads) = (16, 8, 2);
        let mut rng = XorShift64::new(17);
        for variant in [
            Variant::MSA,
            Variant::LINEAR,
            Variant::ADD,
            Variant::ADD_SHIFT_BOTH,
            Variant::SHIFTADD_MOE,
        ] {
            let p = planner();
            let raw = BlockRaw::random(&mut rng, dim, dim * 2);
            let blk = NativeBlock::from_raw(raw, tokens, heads, variant, &p, &[16, 64], 7);
            let mut x = rng.normals(2 * tokens * dim);
            let trace = blk.forward(&mut x, 2);
            assert!(x.iter().all(|v| v.is_finite()), "{variant:?}");
            assert_eq!(trace.moe.is_some(), matches!(variant.mlp, Mlp::Moe { .. }));
        }
    }

    #[test]
    fn frozen_scale_shift_layer_is_row_independent() {
        // Per-tensor INT8 calibration makes a MatShift layer's output depend
        // on which rows share the operand; a frozen scale must not.
        let p = planner();
        let mut rng = XorShift64::new(41);
        let raw = dense_init(&mut rng, 8, 8);
        let layer =
            LinearLayer::new_frozen(&p, Primitive::MatShift, &raw, vec![0.1; 8], 16, 6.0 / 127.0);
        assert!(layer.act_scale.is_some());
        let x = rng.normals(4 * 8);
        let all = layer.forward(&x, 4);
        for i in 0..4 {
            let one = layer.forward(&x[i * 8..(i + 1) * 8], 1);
            assert_eq!(one, &all[i * 8..(i + 1) * 8], "row {i} depends on batch");
        }
        // Non-quantizing primitives ignore the frozen scale.
        let dense = LinearLayer::new_frozen(&p, Primitive::MatMul, &raw, vec![0.0; 8], 16, 1.0);
        assert!(dense.act_scale.is_none());
    }

    #[test]
    fn residual_path_preserves_scale() {
        // Pre-norm + residual: output must not be wildly larger than input.
        let (tokens, dim, heads) = (16, 8, 2);
        let mut rng = XorShift64::new(23);
        let p = planner();
        let raw = BlockRaw::random(&mut rng, dim, dim * 2);
        let blk = NativeBlock::from_raw(raw, tokens, heads, Variant::SHIFTADD_MOE, &p, &[16, 64], 7);
        let x0 = rng.normals(tokens * dim);
        let mut x = x0.clone();
        blk.forward(&mut x, 1);
        let norm0: f32 = x0.iter().map(|v| v * v).sum::<f32>().sqrt();
        let norm1: f32 = x.iter().map(|v| v * v).sum::<f32>().sqrt();
        assert!(norm1 < 20.0 * norm0, "{norm1} vs {norm0}");
        assert!(norm1 > 0.0);
    }
}
