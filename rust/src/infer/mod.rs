//! The native pure-Rust ShiftAddViT inference engine.
//!
//! Executes the paper's reparameterized forward pass end-to-end on the
//! kernel registry — no XLA artifacts, no Python: [`attn`] implements the
//! three attention families (softmax MSA, full-precision linear Q(KᵀV),
//! and KSH-binarized LinearAdd on packed MatAdd backends), [`block`] the
//! pre-norm transformer block (shift-reparameterized linears, DWConv V
//! branch, Mult/Shift MoE MLP), and [`model`] the multi-stage
//! `ModelSpec`-driven classifier with planner-chosen backends per shape.
//!
//! The serving stack consumes this engine through
//! `coordinator::backend::NativeBackend`; the XLA artifact pipeline remains
//! available behind the same `InferenceBackend` trait.

pub mod attn;
pub mod block;
pub mod model;
