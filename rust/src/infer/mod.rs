//! The native pure-Rust ShiftAddViT inference engine.
//!
//! Executes the paper's reparameterized forward pass end-to-end on the
//! kernel registry — no XLA artifacts, no Python: [`attn`] implements the
//! three attention families (softmax MSA, full-precision linear Q(KᵀV),
//! and KSH-binarized LinearAdd on packed MatAdd backends) plus the
//! streaming per-head attention states, [`block`] the pre-norm transformer
//! block (shift-reparameterized linears, DWConv V branch, Mult/Shift MoE
//! MLP), [`model`] the multi-stage `ModelSpec`-driven classifier with
//! planner-chosen backends per shape, and [`session`] the KV-free
//! streaming API: first-class `SessionState` with `begin / extend /
//! finish` and a fused `extend_batch` that packs token chunks from many
//! live sessions into one kernel dispatch per layer.
//!
//! The serving stack consumes this engine through
//! `coordinator::backend::NativeBackend` (one-shot image batches, now a
//! thin adapter over the request-level submit/step/poll contract) and
//! `coordinator::sessions::SessionEngine` (continuous batching of
//! streaming sessions); the XLA artifact pipeline remains available behind
//! the same `InferenceBackend` trait.

pub mod attn;
pub mod block;
pub mod model;
pub mod session;
