//! The native ShiftAddViT model: a full [`ModelSpec`]-driven multi-stage
//! forward pass executed entirely through registry kernels — patch
//! embedding, pyramid stages of [`NativeBlock`]s with 2×2 patch-merging
//! downsamples between them, final LayerNorm, mean pool, and the
//! classification head. This is the executable counterpart of the analytic
//! `model::ops::count` path and the engine behind the native serving
//! backend (`coordinator::backend::NativeBackend`, which now serves it
//! through the request-level submit/step/poll contract). Its token-level
//! streaming sibling — causal, KV-free, chunked — is
//! [`crate::infer::session::StreamModel`]; the image pyramid itself cannot
//! stream (patch-merging downsamples and the DWConv branch are spatial,
//! and image attention is bidirectional), which is why the two entry
//! points coexist.
//!
//! Weights come from a [`ModelParams`] value: either deterministic seeded
//! init (`NativeModel::new`, origin [`WeightsOrigin::SeededUntrained`]) or
//! externally trained params loaded through the flat params format
//! (`NativeModel::from_params`, fed by `python/compile/params_io.py::
//! export_flat` via a signed `.sabundle`). The planner picks the fastest
//! registered backend per (primitive, shape) at construction; all backends
//! of a primitive are numerically identical (the registry's bit-exactness
//! contracts), so outputs depend only on the weights, never on which
//! backend won.

use std::sync::Arc;
use std::time::Instant;

use anyhow::{bail, Result};

use crate::bundle::params::FlatParams;
use crate::data::synth_images;
use crate::infer::block::{dense_init, layer_norm, AttnExec, BlockRaw, LinearLayer, NativeBlock};
use crate::kernels::api::{Primitive, RawWeights};
use crate::kernels::planner::Planner;
use crate::kernels::registry::KernelRegistry;
use crate::model::config::{ModelSpec, Stage};
use crate::model::ops::Variant;
use crate::moe::router::EXPERT_MULT;
use crate::util::bench::time_ms;
use crate::util::rng::XorShift64;
use crate::util::stats::Summary;

/// Construction parameters of a native model.
#[derive(Clone, Debug)]
pub struct NativeModelConfig {
    pub spec: ModelSpec,
    pub img: usize,
    pub patch: usize,
    pub num_classes: usize,
    pub variant: Variant,
    pub seed: u64,
    /// MoE dispatch bucket ladder (token counts)
    pub token_buckets: Vec<usize>,
}

impl NativeModelConfig {
    /// The tiny two-stage serving analogue (32² synthetic-shapes images,
    /// same data distribution as the AOT-compiled artifacts).
    pub fn tiny(variant: Variant) -> NativeModelConfig {
        NativeModelConfig {
            spec: ModelSpec {
                name: "native-tiny",
                input: 32,
                stages: vec![
                    Stage {
                        tokens: 64,
                        dim: 32,
                        depth: 1,
                        heads: 2,
                        mlp_ratio: 4,
                    },
                    Stage {
                        tokens: 16,
                        dim: 64,
                        depth: 1,
                        heads: 4,
                        mlp_ratio: 2,
                    },
                ],
            },
            img: synth_images::IMG,
            patch: 4,
            num_classes: synth_images::NUM_CLASSES,
            variant,
            seed: 0xA11CE,
            token_buckets: vec![16, 64, 256, 1024],
        }
    }
}

struct NativeStage {
    /// 2×2 avg-pool + projection entering this stage (None for stage 0)
    downsample: Option<LinearLayer>,
    blocks: Vec<NativeBlock>,
    grid: usize,
    dim: usize,
    tokens: usize,
}

/// Diagnostics from one [`NativeModel::forward`].
#[derive(Default)]
pub struct ForwardTrace {
    /// named stage wall-clock, in execution order
    pub stage_ms: Vec<(String, f64)>,
    /// per-image Mult-expert token masks of the first MoE block
    pub mask_blk0: Vec<Vec<bool>>,
    pub expert_tokens: [usize; 2],
    pub gate_sums: [f64; 2],
    /// per-MoE-block (mult_ms, shift_ms) pairs
    pub expert_ms: Vec<[f64; 2]>,
    pub padding_waste: Vec<f64>,
    /// attention kernel calls summed across all blocks this forward
    /// (fused path: 2 grouped calls per LinearAdd layer regardless of
    /// batch size — see `BlockTrace::attn_dispatches` for what a grouped
    /// call covers; per-image path: b·heads·4 plain calls per layer)
    pub attn_dispatches: usize,
    /// transformer blocks executed (the dispatches-per-layer denominator)
    pub blocks: usize,
}

/// Where a [`NativeModel`]'s weights came from.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum WeightsOrigin {
    /// Deterministic seeded init — an explicitly *untrained* model.
    SeededUntrained,
    /// Loaded from external params (a flat params blob or a bundle).
    Loaded,
}

/// Raw weights of one stage: the optional 2×2-downsample projection that
/// enters the stage (weights + bias; None for stage 0), then its blocks.
pub struct StageParams {
    pub downsample: Option<(RawWeights, Vec<f32>)>,
    pub blocks: Vec<BlockRaw>,
}

/// The complete raw weights of a [`NativeModel`], independent of any
/// kernel backend — what `export_flat` produces on the Python side and a
/// `.sabundle` carries. [`ModelParams::seeded`] replicates the historical
/// seeded init draw-for-draw, so `to_flat` → `from_flat` → `build` is
/// bit-identical to building from the seed directly.
pub struct ModelParams {
    pub embed_w: RawWeights,
    pub embed_b: Vec<f32>,
    pub pos: Vec<f32>,
    pub stages: Vec<StageParams>,
    pub norm_g: Vec<f32>,
    pub norm_b: Vec<f32>,
    pub head_w: RawWeights,
    pub head_b: Vec<f32>,
}

impl ModelParams {
    /// Deterministic seeded init (the RNG draw order is load-bearing: it
    /// must match what `NativeModel::new` always did, so seeds keep
    /// producing bit-identical weights across releases).
    pub fn seeded(cfg: &NativeModelConfig) -> ModelParams {
        let mut rng = XorShift64::new(cfg.seed);
        let patch_dim = cfg.patch * cfg.patch * 3;
        let d0 = cfg.spec.stages[0].dim;
        let tokens0 = cfg.spec.stages[0].tokens;
        let embed_w = dense_init(&mut rng, patch_dim, d0);
        let pos: Vec<f32> = rng.normals(tokens0 * d0).iter().map(|v| v * 0.02).collect();
        let mut stages = Vec::new();
        for (si, st) in cfg.spec.stages.iter().enumerate() {
            let downsample = if si == 0 {
                None
            } else {
                let prev = &cfg.spec.stages[si - 1];
                Some((dense_init(&mut rng, prev.dim, st.dim), vec![0.0; st.dim]))
            };
            let blocks = (0..st.depth)
                .map(|_| BlockRaw::random(&mut rng, st.dim, st.dim * st.mlp_ratio))
                .collect();
            stages.push(StageParams { downsample, blocks });
        }
        let dl = cfg.spec.stages.last().unwrap().dim;
        let head_w = dense_init(&mut rng, dl, cfg.num_classes);
        ModelParams {
            embed_w,
            embed_b: vec![0.0; d0],
            pos,
            stages,
            norm_g: vec![1.0; dl],
            norm_b: vec![0.0; dl],
            head_w,
            head_b: vec![0.0; cfg.num_classes],
        }
    }

    /// Serialize into the flat dotted-key tensor format (`embed.w`, `pos`,
    /// `stages.{si}.downsample.w`, `stages.{si}.blocks.{bi}.wq`, …).
    pub fn to_flat(&self, cfg: &NativeModelConfig) -> FlatParams {
        let mut flat = FlatParams::new();
        insert_mat(&mut flat, "embed.w", &self.embed_w);
        insert_vec(&mut flat, "embed.b", &self.embed_b);
        let tokens0 = cfg.spec.stages[0].tokens;
        let d0 = cfg.spec.stages[0].dim;
        flat.insert("pos", vec![tokens0, d0], self.pos.clone());
        for (si, sp) in self.stages.iter().enumerate() {
            if let Some((w, b)) = &sp.downsample {
                insert_mat(&mut flat, &format!("stages.{si}.downsample.w"), w);
                insert_vec(&mut flat, &format!("stages.{si}.downsample.b"), b);
            }
            for (bi, blk) in sp.blocks.iter().enumerate() {
                insert_block(&mut flat, &format!("stages.{si}.blocks.{bi}"), blk);
            }
        }
        insert_vec(&mut flat, "norm.g", &self.norm_g);
        insert_vec(&mut flat, "norm.b", &self.norm_b);
        insert_mat(&mut flat, "head.w", &self.head_w);
        insert_vec(&mut flat, "head.b", &self.head_b);
        flat
    }

    /// Strict inverse of [`ModelParams::to_flat`]: every tensor the spec
    /// calls for must be present with the exact shape, and tensors the
    /// spec does not know about are rejected by name.
    pub fn from_flat(cfg: &NativeModelConfig, flat: &FlatParams) -> Result<ModelParams> {
        if cfg.spec.stages.is_empty() {
            bail!("spec has no stages");
        }
        let mut r = ParamReader {
            flat,
            seen: std::collections::BTreeSet::new(),
        };
        let patch_dim = cfg.patch * cfg.patch * 3;
        let d0 = cfg.spec.stages[0].dim;
        let tokens0 = cfg.spec.stages[0].tokens;
        let embed_w = r.mat("embed.w", patch_dim, d0)?;
        let embed_b = r.vec("embed.b", d0)?;
        let pos = r.shaped("pos", &[tokens0, d0])?;
        let mut stages = Vec::new();
        for (si, st) in cfg.spec.stages.iter().enumerate() {
            let downsample = if si == 0 {
                None
            } else {
                let prev = &cfg.spec.stages[si - 1];
                let w = r.mat(&format!("stages.{si}.downsample.w"), prev.dim, st.dim)?;
                let b = r.vec(&format!("stages.{si}.downsample.b"), st.dim)?;
                Some((w, b))
            };
            let mut blocks = Vec::new();
            for bi in 0..st.depth {
                let prefix = format!("stages.{si}.blocks.{bi}");
                blocks.push(read_block(&mut r, &prefix, st.dim, st.dim * st.mlp_ratio)?);
            }
            stages.push(StageParams { downsample, blocks });
        }
        let dl = cfg.spec.stages.last().unwrap().dim;
        let params = ModelParams {
            embed_w,
            embed_b,
            pos,
            stages,
            norm_g: r.vec("norm.g", dl)?,
            norm_b: r.vec("norm.b", dl)?,
            head_w: r.mat("head.w", dl, cfg.num_classes)?,
            head_b: r.vec("head.b", cfg.num_classes)?,
        };
        if r.seen.len() != flat.len() {
            let extra = flat
                .names()
                .into_iter()
                .find(|n| !r.seen.contains(*n))
                .unwrap_or("?");
            bail!(
                "params contain {} tensors the spec does not know about (e.g. '{extra}')",
                flat.len() - r.seen.len()
            );
        }
        Ok(params)
    }
}

fn insert_mat(flat: &mut FlatParams, name: &str, w: &RawWeights) {
    flat.insert(name, vec![w.k, w.n], w.data.clone());
}

fn insert_vec(flat: &mut FlatParams, name: &str, v: &[f32]) {
    flat.insert(name, vec![v.len()], v.to_vec());
}

fn insert_block(flat: &mut FlatParams, p: &str, b: &BlockRaw) {
    insert_vec(flat, &format!("{p}.ln1_g"), &b.ln1_g);
    insert_vec(flat, &format!("{p}.ln1_b"), &b.ln1_b);
    insert_vec(flat, &format!("{p}.ln2_g"), &b.ln2_g);
    insert_vec(flat, &format!("{p}.ln2_b"), &b.ln2_b);
    insert_mat(flat, &format!("{p}.wq"), &b.wq);
    insert_vec(flat, &format!("{p}.bq"), &b.bq);
    insert_mat(flat, &format!("{p}.wk"), &b.wk);
    insert_vec(flat, &format!("{p}.bk"), &b.bk);
    insert_mat(flat, &format!("{p}.wv"), &b.wv);
    insert_vec(flat, &format!("{p}.bv"), &b.bv);
    insert_mat(flat, &format!("{p}.wo"), &b.wo);
    insert_vec(flat, &format!("{p}.bo"), &b.bo);
    flat.insert(&format!("{p}.dw"), vec![9, b.dw.len() / 9], b.dw.clone());
    insert_mat(flat, &format!("{p}.w1"), &b.w1);
    insert_vec(flat, &format!("{p}.b1"), &b.b1);
    insert_mat(flat, &format!("{p}.w2"), &b.w2);
    insert_vec(flat, &format!("{p}.b2"), &b.b2);
    insert_mat(flat, &format!("{p}.w1s"), &b.w1s);
    insert_vec(flat, &format!("{p}.b1s"), &b.b1s);
    insert_mat(flat, &format!("{p}.w2s"), &b.w2s);
    insert_vec(flat, &format!("{p}.b2s"), &b.b2s);
    insert_mat(flat, &format!("{p}.gate_w"), &b.gate_w);
}

/// Tracks which tensors a [`ModelParams::from_flat`] read consumed so
/// unknown extras can be rejected afterwards.
struct ParamReader<'a> {
    flat: &'a FlatParams,
    seen: std::collections::BTreeSet<String>,
}

impl ParamReader<'_> {
    fn mat(&mut self, name: &str, k: usize, n: usize) -> Result<RawWeights> {
        self.seen.insert(name.to_string());
        self.flat.req_matrix(name, k, n)
    }

    fn vec(&mut self, name: &str, n: usize) -> Result<Vec<f32>> {
        self.seen.insert(name.to_string());
        self.flat.req_vec(name, n)
    }

    fn shaped(&mut self, name: &str, dims: &[usize]) -> Result<Vec<f32>> {
        self.seen.insert(name.to_string());
        self.flat.req_shaped(name, dims)
    }
}

fn read_block(r: &mut ParamReader<'_>, p: &str, dim: usize, hidden: usize) -> Result<BlockRaw> {
    Ok(BlockRaw {
        ln1_g: r.vec(&format!("{p}.ln1_g"), dim)?,
        ln1_b: r.vec(&format!("{p}.ln1_b"), dim)?,
        ln2_g: r.vec(&format!("{p}.ln2_g"), dim)?,
        ln2_b: r.vec(&format!("{p}.ln2_b"), dim)?,
        wq: r.mat(&format!("{p}.wq"), dim, dim)?,
        bq: r.vec(&format!("{p}.bq"), dim)?,
        wk: r.mat(&format!("{p}.wk"), dim, dim)?,
        bk: r.vec(&format!("{p}.bk"), dim)?,
        wv: r.mat(&format!("{p}.wv"), dim, dim)?,
        bv: r.vec(&format!("{p}.bv"), dim)?,
        wo: r.mat(&format!("{p}.wo"), dim, dim)?,
        bo: r.vec(&format!("{p}.bo"), dim)?,
        dw: r.shaped(&format!("{p}.dw"), &[9, dim])?,
        w1: r.mat(&format!("{p}.w1"), dim, hidden)?,
        b1: r.vec(&format!("{p}.b1"), hidden)?,
        w2: r.mat(&format!("{p}.w2"), hidden, dim)?,
        b2: r.vec(&format!("{p}.b2"), dim)?,
        w1s: r.mat(&format!("{p}.w1s"), dim, hidden)?,
        b1s: r.vec(&format!("{p}.b1s"), hidden)?,
        w2s: r.mat(&format!("{p}.w2s"), hidden, dim)?,
        b2s: r.vec(&format!("{p}.b2s"), dim)?,
        gate_w: r.mat(&format!("{p}.gate_w"), dim, 2)?,
    })
}

/// The native multi-stage model.
pub struct NativeModel {
    pub cfg: NativeModelConfig,
    pub planner: Arc<Planner>,
    /// whether the weights are seeded (untrained) or externally loaded
    pub origin: WeightsOrigin,
    embed: LinearLayer,
    pos: Vec<f32>,
    stages: Vec<NativeStage>,
    norm_g: Vec<f32>,
    norm_b: Vec<f32>,
    head: LinearLayer,
}

impl NativeModel {
    pub fn new(cfg: NativeModelConfig, planner: Arc<Planner>) -> NativeModel {
        assert!(!cfg.spec.stages.is_empty(), "spec has no stages");
        let params = ModelParams::seeded(&cfg);
        NativeModel::build(cfg, planner, params, WeightsOrigin::SeededUntrained)
    }

    /// Build from externally loaded flat params (strict shape checking; the
    /// model is marked [`WeightsOrigin::Loaded`]).
    pub fn from_params(
        cfg: NativeModelConfig,
        planner: Arc<Planner>,
        flat: &FlatParams,
    ) -> Result<NativeModel> {
        let params = ModelParams::from_flat(&cfg, flat)?;
        Ok(NativeModel::build(cfg, planner, params, WeightsOrigin::Loaded))
    }

    fn build(
        cfg: NativeModelConfig,
        planner: Arc<Planner>,
        params: ModelParams,
        origin: WeightsOrigin,
    ) -> NativeModel {
        assert!(!cfg.spec.stages.is_empty(), "spec has no stages");
        let grid0 = cfg.img / cfg.patch;
        assert_eq!(
            grid0 * grid0,
            cfg.spec.stages[0].tokens,
            "stage-0 tokens must equal the patch grid"
        );
        let ModelParams {
            embed_w,
            embed_b,
            pos,
            stages: stage_params,
            norm_g,
            norm_b,
            head_w,
            head_b,
        } = params;
        assert_eq!(
            stage_params.len(),
            cfg.spec.stages.len(),
            "params stage count must match the spec"
        );
        let embed = LinearLayer::new(
            &planner,
            Primitive::MatMul,
            &embed_w,
            embed_b,
            cfg.spec.stages[0].tokens,
        );
        let mut stages = Vec::new();
        for ((si, st), sp) in cfg.spec.stages.iter().enumerate().zip(stage_params) {
            let grid = (st.tokens as f64).sqrt().round() as usize;
            assert_eq!(grid * grid, st.tokens, "stage {si} tokens must be square");
            let downsample = if si == 0 {
                assert!(sp.downsample.is_none(), "stage 0 cannot have a downsample");
                None
            } else {
                let prev = &cfg.spec.stages[si - 1];
                assert_eq!(
                    st.tokens * 4,
                    prev.tokens,
                    "stage {si} must be a 2×2 downsample of stage {}",
                    si - 1
                );
                let (w, b) = sp.downsample.expect("stage params missing the downsample");
                Some(LinearLayer::new(
                    &planner,
                    Primitive::MatMul,
                    &w,
                    b,
                    st.tokens,
                ))
            };
            assert_eq!(sp.blocks.len(), st.depth, "stage {si} depth mismatch");
            // One hash family per stage, shared by the stage's blocks.
            let hash_seed = cfg.seed ^ (0x5A5A_0000 + si as u64);
            let blocks = sp
                .blocks
                .into_iter()
                .map(|raw| {
                    NativeBlock::from_raw(
                        raw,
                        st.tokens,
                        st.heads,
                        cfg.variant,
                        &planner,
                        &cfg.token_buckets,
                        hash_seed,
                    )
                })
                .collect();
            stages.push(NativeStage {
                downsample,
                blocks,
                grid,
                dim: st.dim,
                tokens: st.tokens,
            });
        }
        let dl = cfg.spec.stages.last().unwrap().dim;
        assert_eq!(norm_g.len(), dl, "final norm params must be dim-sized");
        assert_eq!(norm_b.len(), dl, "final norm params must be dim-sized");
        let head = LinearLayer::new(&planner, Primitive::MatMul, &head_w, head_b, 8);
        NativeModel {
            norm_g,
            norm_b,
            cfg,
            planner,
            origin,
            embed,
            pos,
            stages,
            head,
        }
    }

    /// A tiny serving-shaped model with its own planner over the default
    /// registry — the zero-setup entry point examples and harnesses use.
    pub fn tiny(variant: Variant) -> NativeModel {
        let planner = Arc::new(Planner::new(Arc::new(KernelRegistry::with_defaults())));
        NativeModel::new(NativeModelConfig::tiny(variant), planner)
    }

    pub fn tokens(&self) -> usize {
        self.stages[0].tokens
    }

    pub fn num_blocks(&self) -> usize {
        self.stages.iter().map(|s| s.blocks.len()).sum()
    }

    /// Classify `b` flattened HWC images → (logits (b×classes), trace), on
    /// the fused batched attention path.
    pub fn forward(&self, images: &[f32], b: usize) -> (Vec<f32>, ForwardTrace) {
        self.forward_with(images, b, AttnExec::Fused)
    }

    /// Classify with an explicit attention execution mode
    /// ([`AttnExec::PerImage`] is the bit-exact sequential reference the
    /// property suite and the `native_engine` bench compare against).
    pub fn forward_with(
        &self,
        images: &[f32],
        b: usize,
        exec: AttnExec,
    ) -> (Vec<f32>, ForwardTrace) {
        let img = self.cfg.img;
        let patch = self.cfg.patch;
        let grid0 = img / patch;
        let px = img * img * 3;
        assert_eq!(images.len(), b * px, "image buffer is not b·img²·3");
        let mut trace = ForwardTrace::default();

        // --- stem: patch embed + positional ------------------------------
        let t0 = Instant::now();
        let s0 = &self.stages[0];
        let d0 = s0.dim;
        let patch_dim = patch * patch * 3;
        let mut patches = vec![0.0f32; b * s0.tokens * patch_dim];
        for bi in 0..b {
            for gy in 0..grid0 {
                for gx in 0..grid0 {
                    let tok = gy * grid0 + gx;
                    let dst = (bi * s0.tokens + tok) * patch_dim;
                    let mut w = 0;
                    for py in 0..patch {
                        for pxx in 0..patch {
                            let src = bi * px + ((gy * patch + py) * img + gx * patch + pxx) * 3;
                            patches[dst + w] = images[src];
                            patches[dst + w + 1] = images[src + 1];
                            patches[dst + w + 2] = images[src + 2];
                            w += 3;
                        }
                    }
                }
            }
        }
        let mut t = self.embed.forward(&patches, b * s0.tokens);
        for bi in 0..b {
            let base = bi * s0.tokens * d0;
            for (tv, pv) in t[base..base + s0.tokens * d0].iter_mut().zip(&self.pos) {
                *tv += pv;
            }
        }
        trace
            .stage_ms
            .push(("stem".to_string(), t0.elapsed().as_secs_f64() * 1e3));

        // --- stages -------------------------------------------------------
        let mut gi = 0usize;
        for (si, stage) in self.stages.iter().enumerate() {
            if let Some(ds) = &stage.downsample {
                let t0 = Instant::now();
                let prev = &self.stages[si - 1];
                let pooled = pool2x2(&t, b, prev.grid, prev.dim);
                t = ds.forward(&pooled, b * stage.tokens);
                trace.stage_ms.push((
                    format!("stage{si}_down"),
                    t0.elapsed().as_secs_f64() * 1e3,
                ));
            }
            for blk in &stage.blocks {
                let btr = blk.forward_with(&mut t, b, exec);
                trace.attn_dispatches += btr.attn_dispatches;
                trace.blocks += 1;
                trace.stage_ms.push((format!("blk{gi}_attn"), btr.attn_ms));
                let mlp_name = if btr.moe.is_some() {
                    format!("blk{gi}_moe")
                } else {
                    format!("blk{gi}_mlp")
                };
                trace.stage_ms.push((mlp_name, btr.mlp_ms));
                if let Some(moe) = btr.moe {
                    for r in &moe.routes {
                        trace.expert_tokens[r.expert] += 1;
                    }
                    trace.gate_sums[0] += moe.gate_sums[0];
                    trace.gate_sums[1] += moe.gate_sums[1];
                    trace.expert_ms.push(moe.expert_ms);
                    trace.padding_waste.push(moe.padding_waste);
                    if trace.mask_blk0.is_empty() {
                        for bi in 0..b {
                            trace.mask_blk0.push(
                                moe.routes[bi * stage.tokens..(bi + 1) * stage.tokens]
                                    .iter()
                                    .map(|r| r.expert == EXPERT_MULT)
                                    .collect(),
                            );
                        }
                    }
                }
                gi += 1;
            }
        }

        // --- head: LN → mean pool → classifier ---------------------------
        let t0 = Instant::now();
        let last = self.stages.last().unwrap();
        let (dl, nl) = (last.dim, last.tokens);
        let u = layer_norm(&t, &self.norm_g, &self.norm_b, dl);
        let mut pooled = vec![0.0f32; b * dl];
        for bi in 0..b {
            for i in 0..nl {
                let row = &u[(bi * nl + i) * dl..(bi * nl + i + 1) * dl];
                let dst = &mut pooled[bi * dl..(bi + 1) * dl];
                for (p, &v) in dst.iter_mut().zip(row) {
                    *p += v;
                }
            }
        }
        for v in pooled.iter_mut() {
            *v /= nl as f32;
        }
        let logits = self.head.forward(&pooled, b);
        trace
            .stage_ms
            .push(("head".to_string(), t0.elapsed().as_secs_f64() * 1e3));
        (logits, trace)
    }
}

/// 2×2 average pool over each image's token grid (patch merging).
fn pool2x2(x: &[f32], b: usize, grid: usize, d: usize) -> Vec<f32> {
    assert_eq!(x.len(), b * grid * grid * d);
    let g2 = grid / 2;
    let mut out = vec![0.0f32; b * g2 * g2 * d];
    for bi in 0..b {
        for y in 0..g2 {
            for xx in 0..g2 {
                for c in 0..d {
                    let mut acc = 0.0f32;
                    for dy in 0..2usize {
                        for dx in 0..2usize {
                            acc += x[(bi * grid * grid + (2 * y + dy) * grid + 2 * xx + dx) * d + c];
                        }
                    }
                    out[(bi * g2 * g2 + y * g2 + xx) * d + c] = acc * 0.25;
                }
            }
        }
    }
    out
}

/// p50 wall-clock (ms) of one batch forward on an already-built model —
/// the native counterpart of `harness::overall::cls_latency_ms`.
pub fn latency_ms(model: &NativeModel, bs: usize) -> f64 {
    let (xs, _) = synth_images::gen_batch(42_000, bs);
    let samples = time_ms(
        || {
            model.forward(&xs, bs);
        },
        2,
        5,
    );
    Summary::from(&samples).p50
}

/// Convenience: build the tiny model once and measure it at every batch
/// size (model construction — planner benchmarking + weight packing — is
/// far more expensive than one tiny forward, so callers wanting several
/// batch sizes should use this instead of repeated single measurements).
pub fn tiny_latencies_ms(variant: Variant, batch_sizes: &[usize]) -> Vec<f64> {
    let model = NativeModel::tiny(variant);
    batch_sizes.iter().map(|&bs| latency_ms(&model, bs)).collect()
}

/// Single (variant, bs) measurement; builds the tiny model for this call —
/// prefer [`tiny_latencies_ms`] when measuring several batch sizes.
pub fn tiny_latency_ms(variant: Variant, bs: usize) -> f64 {
    tiny_latencies_ms(variant, &[bs])[0]
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tiny_forward_shapes_and_finiteness() {
        let model = NativeModel::tiny(Variant::SHIFTADD_MOE);
        assert_eq!(model.num_blocks(), 2);
        let (xs, _) = synth_images::gen_batch(7, 3);
        let (logits, trace) = model.forward(&xs, 3);
        assert_eq!(logits.len(), 3 * synth_images::NUM_CLASSES);
        assert!(logits.iter().all(|v| v.is_finite()));
        // both blocks are MoE ⇒ routed tokens cover 2 blocks × 3 images
        let routed: usize = trace.expert_tokens.iter().sum();
        assert_eq!(routed, 3 * (64 + 16));
        assert_eq!(trace.mask_blk0.len(), 3);
        assert_eq!(trace.mask_blk0[0].len(), 64);
        assert!(trace.stage_ms.iter().any(|(n, _)| n == "stem"));
        assert!(trace.stage_ms.iter().any(|(n, _)| n == "head"));
        assert!(trace.stage_ms.iter().any(|(n, _)| n == "stage1_down"));
        assert_eq!(model.origin, WeightsOrigin::SeededUntrained);
    }

    #[test]
    fn flat_params_round_trip_is_lossless() {
        let cfg = NativeModelConfig::tiny(Variant::SHIFTADD_MOE);
        let params = ModelParams::seeded(&cfg);
        let flat = params.to_flat(&cfg);
        let back = ModelParams::from_flat(&cfg, &flat).unwrap();
        assert_eq!(flat, back.to_flat(&cfg));
    }

    #[test]
    fn from_flat_rejects_unknown_tensors() {
        let cfg = NativeModelConfig::tiny(Variant::SHIFTADD_MOE);
        let mut flat = ModelParams::seeded(&cfg).to_flat(&cfg);
        flat.insert("rogue.tensor", vec![1], vec![0.0]);
        let err = ModelParams::from_flat(&cfg, &flat).unwrap_err().to_string();
        assert!(err.contains("rogue.tensor"), "unexpected error: {err}");
    }

    #[test]
    fn from_flat_rejects_wrong_shapes() {
        let cfg = NativeModelConfig::tiny(Variant::SHIFTADD_MOE);
        let mut flat = ModelParams::seeded(&cfg).to_flat(&cfg);
        // head bias must be num_classes (8) long
        flat.insert("head.b", vec![3], vec![0.0; 3]);
        assert!(ModelParams::from_flat(&cfg, &flat).is_err());
    }

    #[test]
    fn same_seed_same_logits() {
        // Planner choices may differ between builds, but every backend of a
        // primitive is numerically identical — logits depend only on seed.
        let a = NativeModel::tiny(Variant::SHIFTADD_MOE);
        let b = NativeModel::tiny(Variant::SHIFTADD_MOE);
        let (xs, _) = synth_images::gen_batch(11, 2);
        let (la, _) = a.forward(&xs, 2);
        let (lb, _) = b.forward(&xs, 2);
        assert_eq!(la, lb);
    }

    #[test]
    fn variants_change_the_forward() {
        let (xs, _) = synth_images::gen_batch(5, 1);
        let (l_msa, _) = NativeModel::tiny(Variant::MSA).forward(&xs, 1);
        let (l_add, _) = NativeModel::tiny(Variant::ADD).forward(&xs, 1);
        assert_ne!(l_msa, l_add);
    }

    #[test]
    fn pool2x2_averages_quads() {
        // 2×2 grid, 1 channel: single output = mean of 4.
        let x = vec![1.0f32, 2.0, 3.0, 6.0];
        let out = pool2x2(&x, 1, 2, 1);
        assert_eq!(out, vec![3.0]);
    }

    #[test]
    #[should_panic(expected = "downsample")]
    fn non_pyramid_spec_rejected() {
        let mut cfg = NativeModelConfig::tiny(Variant::LINEAR);
        cfg.spec.stages[1].tokens = 25; // square, but not stage-0 tokens / 4
        let planner = Arc::new(Planner::new(Arc::new(KernelRegistry::with_defaults())));
        NativeModel::new(cfg, planner);
    }
}
