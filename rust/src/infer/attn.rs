//! Native attention kernels — the executable counterparts of the analytic
//! `model::ops::Attn` variants (mirrors `python/compile/kernels/ref.py` and
//! the pure-jnp paths of `python/compile/model.py`).
//!
//! All three attention families operate per head on one image's tokens:
//!
//! - [`softmax_attn`] — quadratic MSA, `softmax(QKᵀ/√d)V`;
//! - [`relu_linear_attn`] — full-precision linear attention in Q(KᵀV) order
//!   with ReLU feature maps (the paper's "Linear" row);
//! - [`hamming_linear_attn_kernel`] — the LinearAdd row: Q/K are ±1 codes in
//!   Hamming space (KSH binarization from `quant::ksh`), every matmul
//!   against a code matrix is an accumulation-only MatAdd executed through a
//!   registry [`LinearKernel`], and the attention weight is the Hamming
//!   *similarity* `(bits + qcᵢ·kcⱼ)/2 ∈ [0, bits]` — non-negative by
//!   construction, so the normalizer never crosses zero.
//!
//! [`hamming_linear_attn_ref`] is the readable oracle: identical per-element
//! accumulation order (ascending contraction index), so the kernel path is
//! *bit-exact* against it — asserted by `rust/tests/native_infer.rs`.

use std::sync::Arc;

use crate::kernels::api::{LinearKernel, PreparedWeights, RawWeights};
use crate::kernels::registry::dispatch_grouped;

/// Numerical floor shared with `python/compile/kernels/ref.py::linattn_ref`.
pub const EPS: f32 = 1e-6;

/// ReLU feature map of the full-precision linear attention
/// (`model.py`: `relu(x) + 1e-3`) — shared by the one-shot and the
/// streaming paths so they stay bit-identical.
#[inline]
pub fn relu_feat(x: f32) -> f32 {
    x.max(0.0) + 1e-3
}

/// Standard MSA per head: `softmax(q kᵀ / √d) v`; q, k, v are (n × d).
pub fn softmax_attn(q: &[f32], k: &[f32], v: &[f32], n: usize, d: usize) -> Vec<f32> {
    assert_eq!(q.len(), n * d);
    assert_eq!(k.len(), n * d);
    assert_eq!(v.len(), n * d);
    let scale = 1.0 / (d as f32).sqrt();
    let mut out = vec![0.0f32; n * d];
    let mut row = vec![0.0f32; n];
    for i in 0..n {
        for (j, r) in row.iter_mut().enumerate() {
            let mut acc = 0.0f32;
            for e in 0..d {
                acc += q[i * d + e] * k[j * d + e];
            }
            *r = acc * scale;
        }
        let m = row.iter().fold(f32::NEG_INFINITY, |a, &b| a.max(b));
        let mut sum = 0.0f32;
        for r in row.iter_mut() {
            *r = (*r - m).exp();
            sum += *r;
        }
        for r in row.iter_mut() {
            *r /= sum;
        }
        let orow = &mut out[i * d..(i + 1) * d];
        for (j, &a) in row.iter().enumerate() {
            let vrow = &v[j * d..(j + 1) * d];
            for (o, &vv) in orow.iter_mut().zip(vrow) {
                *o += a * vv;
            }
        }
    }
    out
}

/// Full-precision linear attention per head, Q(KᵀV) order with ReLU feature
/// maps (`model.py`: `fq = relu(q)+1e-3`, `kv = fkᵀv`, `out = fq·kv /
/// (fq·Σfk + eps)`). Linear in n.
pub fn relu_linear_attn(q: &[f32], k: &[f32], v: &[f32], n: usize, d: usize) -> Vec<f32> {
    assert_eq!(q.len(), n * d);
    assert_eq!(k.len(), n * d);
    assert_eq!(v.len(), n * d);
    let feat = relu_feat;
    // kv (d × d) and z (d) accumulated over tokens.
    let mut kv = vec![0.0f32; d * d];
    let mut z = vec![0.0f32; d];
    for j in 0..n {
        for e in 0..d {
            let fk = feat(k[j * d + e]);
            z[e] += fk;
            let kvrow = &mut kv[e * d..(e + 1) * d];
            let vrow = &v[j * d..(j + 1) * d];
            for (kk, &vv) in kvrow.iter_mut().zip(vrow) {
                *kk += fk * vv;
            }
        }
    }
    let mut out = vec![0.0f32; n * d];
    for i in 0..n {
        let orow = &mut out[i * d..(i + 1) * d];
        let mut den = 0.0f32;
        for e in 0..d {
            let fq = feat(q[i * d + e]);
            den += fq * z[e];
            let kvrow = &kv[e * d..(e + 1) * d];
            for (o, &kk) in orow.iter_mut().zip(kvrow) {
                *o += fq * kk;
            }
        }
        for o in orow.iter_mut() {
            *o /= den + EPS;
        }
    }
    out
}

/// Binarized linear attention through a registry MatAdd backend.
///
/// `qc`, `kc`: (n × bits) ±1 codes; `v`: (n × d) float tokens. Computed in
/// Q(KᵀV) order; the 1/2 factors of the Hamming similarity cancel between
/// numerator and denominator (ref.py derivation):
///
/// ```text
///   numᵢ = bits·Σⱼvⱼ + qcᵢ @ (kcᵀ v)
///   denᵢ = n·bits     + qcᵢ @ (kcᵀ 1)
///   outᵢ = numᵢ / (denᵢ + eps)
/// ```
///
/// Every product against a code matrix runs as `x @ codes` through
/// `kernel`, with transposes so the binary operand always sits on the
/// weight side of the [`LinearKernel`] contract.
pub fn hamming_linear_attn_kernel(
    kernel: &Arc<dyn LinearKernel>,
    qc: &[i8],
    kc: &[i8],
    v: &[f32],
    n: usize,
    bits: usize,
    d: usize,
) -> Vec<f32> {
    assert_eq!(qc.len(), n * bits);
    assert_eq!(kc.len(), n * bits);
    assert_eq!(v.len(), n * d);

    // vᵀ (d × n): contraction over tokens puts codes on the weight side.
    let mut vt = vec![0.0f32; d * n];
    for j in 0..n {
        for e in 0..d {
            vt[e * n + j] = v[j * d + e];
        }
    }
    let kc_w = kernel.prepare(&RawWeights::new(
        kc.iter().map(|&c| c as f32).collect(),
        n,
        bits,
    ));
    // kvᵀ (d × bits) = vᵀ @ kc  — MatAdd over tokens.
    let mut kvt = vec![0.0f32; d * bits];
    kernel.run(&kc_w, &kernel.prepare_operand(&vt, d, n), &mut kvt);
    // z (1 × bits) = 1ᵀ @ kc — per-bit code sums.
    let ones = vec![1.0f32; n];
    let mut z = vec![0.0f32; bits];
    kernel.run(&kc_w, &kernel.prepare_operand(&ones, 1, n), &mut z);

    // qcᵀ (bits × n) as weights: numᵀ = kvᵀ @ qcᵀ, den = z @ qcᵀ.
    let mut qct = vec![0.0f32; bits * n];
    for i in 0..n {
        for b in 0..bits {
            qct[b * n + i] = qc[i * bits + b] as f32;
        }
    }
    let qc_w = kernel.prepare(&RawWeights::new(qct, bits, n));
    let mut numt = vec![0.0f32; d * n];
    kernel.run(&qc_w, &kernel.prepare_operand(&kvt, d, bits), &mut numt);
    let mut den = vec![0.0f32; n];
    kernel.run(&qc_w, &kernel.prepare_operand(&z, 1, bits), &mut den);

    // Σⱼ vⱼ (ascending j — same order as the oracle).
    let mut sv = vec![0.0f32; d];
    for j in 0..n {
        for (s, &vv) in sv.iter_mut().zip(&v[j * d..(j + 1) * d]) {
            *s += vv;
        }
    }
    let bias = (n * bits) as f32;
    let bf = bits as f32;
    let mut out = vec![0.0f32; n * d];
    for i in 0..n {
        let denom = bias + den[i] + EPS;
        for e in 0..d {
            out[i * d + e] = (bf * sv[e] + numt[e * n + i]) / denom;
        }
    }
    out
}

/// Readable oracle for [`hamming_linear_attn_kernel`]: plain ± accumulation
/// loops, same contraction order per output element — bit-exact.
pub fn hamming_linear_attn_ref(
    qc: &[i8],
    kc: &[i8],
    v: &[f32],
    n: usize,
    bits: usize,
    d: usize,
) -> Vec<f32> {
    assert_eq!(qc.len(), n * bits);
    assert_eq!(kc.len(), n * bits);
    assert_eq!(v.len(), n * d);
    // kv (bits × d) = kcᵀ v and z (bits) = kcᵀ 1, accumulation only.
    let mut kv = vec![0.0f32; bits * d];
    let mut z = vec![0.0f32; bits];
    for b in 0..bits {
        for j in 0..n {
            let c = kc[j * bits + b];
            if c > 0 {
                z[b] += 1.0;
            } else {
                z[b] -= 1.0;
            }
            let kvrow = &mut kv[b * d..(b + 1) * d];
            let vrow = &v[j * d..(j + 1) * d];
            for (kk, &vv) in kvrow.iter_mut().zip(vrow) {
                if c > 0 {
                    *kk += vv;
                } else {
                    *kk -= vv;
                }
            }
        }
    }
    let mut sv = vec![0.0f32; d];
    for j in 0..n {
        for (s, &vv) in sv.iter_mut().zip(&v[j * d..(j + 1) * d]) {
            *s += vv;
        }
    }
    let bias = (n * bits) as f32;
    let bf = bits as f32;
    let mut out = vec![0.0f32; n * d];
    for i in 0..n {
        let mut den = 0.0f32;
        let mut num = vec![0.0f32; d];
        for b in 0..bits {
            let c = qc[i * bits + b];
            let kvrow = &kv[b * d..(b + 1) * d];
            if c > 0 {
                den += z[b];
                for (nn, &kk) in num.iter_mut().zip(kvrow) {
                    *nn += kk;
                }
            } else {
                den -= z[b];
                for (nn, &kk) in num.iter_mut().zip(kvrow) {
                    *nn -= kk;
                }
            }
        }
        let denom = bias + den + EPS;
        for e in 0..d {
            out[i * d + e] = (bf * sv[e] + num[e]) / denom;
        }
    }
    out
}

// ---------------------------------------------------------------------------
// Batched (fused per-layer) image-path attention
// ---------------------------------------------------------------------------
//
// The image path runs `G = images × heads` independent attention problems
// per layer. The entry points below take all G groups packed head-major —
// group `g = img·heads + h` owns rows `g·n..(g+1)·n` — and execute them in
// one call: the LinearAdd family through TWO grouped MatAdd dispatches
// ([`LinearKernel::run_grouped`]) instead of 4·G per-head ones, the scalar
// families (softmax / ReLU-linear) through one fork/join over the shared
// kernel pool. Per-group arithmetic and accumulation order are identical to
// the per-head functions, so every batched entry point is **bit-exact**
// against its per-group counterpart (asserted by
// `rust/tests/prop_batched_attn.rs`).

/// Gather `(b·n × heads·hd)` head-interleaved rows into head-major groups:
/// output group `g = img·heads + h` holds the image's tokens restricted to
/// head `h`, as `n` contiguous rows of `hd`.
pub fn pack_heads(x: &[f32], b: usize, n: usize, heads: usize, hd: usize) -> Vec<f32> {
    let d = heads * hd;
    assert_eq!(x.len(), b * n * d, "pack_heads: buffer is not b·n·d");
    let mut out = vec![0.0f32; b * n * d];
    for img in 0..b {
        for h in 0..heads {
            let gbase = (img * heads + h) * n * hd;
            for i in 0..n {
                let src = (img * n + i) * d + h * hd;
                out[gbase + i * hd..gbase + (i + 1) * hd].copy_from_slice(&x[src..src + hd]);
            }
        }
    }
    out
}

/// Scatter head-major groups back to `(b·n × heads·hd)` interleaved rows —
/// the exact inverse of [`pack_heads`].
pub fn unpack_heads(xh: &[f32], b: usize, n: usize, heads: usize, hd: usize) -> Vec<f32> {
    let d = heads * hd;
    assert_eq!(xh.len(), b * n * d, "unpack_heads: buffer is not b·n·d");
    let mut out = vec![0.0f32; b * n * d];
    for img in 0..b {
        for h in 0..heads {
            let gbase = (img * heads + h) * n * hd;
            for i in 0..n {
                let dst = (img * n + i) * d + h * hd;
                out[dst..dst + hd].copy_from_slice(&xh[gbase + i * hd..gbase + (i + 1) * hd]);
            }
        }
    }
    out
}

/// Run a per-head attention family over packed groups in one call, fanning
/// groups across the shared kernel pool (group outputs are disjoint and
/// each group's math is the untouched per-head function, so the packed
/// result is bit-exact vs calling `f` per group). Buffers are taken by
/// value so the fan-out can `Arc`-share them without copying — callers own
/// freshly packed head-major buffers anyway. The group count is implied by
/// the buffer length: `G = q.len() / (n·d)`.
fn attn_groups(
    q: Vec<f32>,
    k: Vec<f32>,
    v: Vec<f32>,
    n: usize,
    d: usize,
    f: fn(&[f32], &[f32], &[f32], usize, usize) -> Vec<f32>,
) -> Vec<f32> {
    assert_eq!(q.len() % (n * d), 0, "attn_groups: buffer is not G·n·d");
    let g = q.len() / (n * d);
    assert_eq!(k.len(), g * n * d);
    assert_eq!(v.len(), g * n * d);
    let pool = crate::kernels::parallel::shared_pool();
    let gs = n * d;
    if g < 2 || pool.len() == 1 {
        let mut out = Vec::with_capacity(g * gs);
        for gi in 0..g {
            out.extend(f(
                &q[gi * gs..(gi + 1) * gs],
                &k[gi * gs..(gi + 1) * gs],
                &v[gi * gs..(gi + 1) * gs],
                n,
                d,
            ));
        }
        return out;
    }
    let qa = Arc::new(q);
    let ka = Arc::new(k);
    let va = Arc::new(v);
    let jobs: Vec<_> = (0..g)
        .map(|gi| {
            let (qa, ka, va) = (qa.clone(), ka.clone(), va.clone());
            move || {
                f(
                    &qa[gi * gs..(gi + 1) * gs],
                    &ka[gi * gs..(gi + 1) * gs],
                    &va[gi * gs..(gi + 1) * gs],
                    n,
                    d,
                )
            }
        })
        .collect();
    pool.scatter(jobs).concat()
}

/// Batched [`softmax_attn`] over `q.len() / (n·d)` packed groups (one call
/// per layer; buffers by value so the pool fan-out is copy-free).
pub fn softmax_attn_batched(q: Vec<f32>, k: Vec<f32>, v: Vec<f32>, n: usize, d: usize) -> Vec<f32> {
    attn_groups(q, k, v, n, d, softmax_attn)
}

/// Batched [`relu_linear_attn`] over `q.len() / (n·d)` packed groups (one
/// call per layer; buffers by value so the pool fan-out is copy-free).
pub fn relu_linear_attn_batched(
    q: Vec<f32>,
    k: Vec<f32>,
    v: Vec<f32>,
    n: usize,
    d: usize,
) -> Vec<f32> {
    attn_groups(q, k, v, n, d, relu_linear_attn)
}

/// Fused batched LinearAdd attention over `G = v.len() / (n·d)` packed
/// (image × head) groups — same signature as the per-head
/// [`hamming_linear_attn_kernel`], inputs interpreted group-major: the
/// per-group math restructured into exactly **two** grouped MatAdd
/// dispatches per call —
///
/// ```text
///   stage 1:  [vᵀ; 1ᵀ]  @ kc   →  per-group kvᵀ (d×bits) over z (1×bits)
///   stage 2:  [kvᵀ; z]  @ qcᵀ  →  per-group numᵀ (d×n)  over den (1×n)
/// ```
///
/// — instead of 4·G per-head kernel calls. Stacking the ones/z row under
/// each group's operand is safe because every MatAdd backend computes
/// output rows independently; per-element accumulation order is unchanged,
/// so the result is bit-exact against per-group
/// [`hamming_linear_attn_kernel`] (and hence [`hamming_linear_attn_ref`]).
pub fn hamming_linear_attn_batched(
    kernel: &Arc<dyn LinearKernel>,
    qc: &[i8],
    kc: &[i8],
    v: &[f32],
    n: usize,
    bits: usize,
    d: usize,
) -> Vec<f32> {
    assert_eq!(v.len() % (n * d), 0, "batched attn: values are not G·n·d");
    let g = v.len() / (n * d);
    assert_eq!(qc.len(), g * n * bits);
    assert_eq!(kc.len(), g * n * bits);
    if g == 0 {
        // Degenerate empty batch: match the per-image path, which returns
        // cleanly instead of tripping run_grouped's no-groups assert.
        return Vec::new();
    }
    let rows = d + 1; // d value rows + the ones/z row per group

    // Stage-1 operand: per group, rows 0..d = vᵀ (d × n), row d = 1ᵀ.
    let mut x1 = vec![0.0f32; g * rows * n];
    for gi in 0..g {
        let vb = gi * n * d;
        let xb = gi * rows * n;
        for j in 0..n {
            for e in 0..d {
                x1[xb + e * n + j] = v[vb + j * d + e];
            }
            x1[xb + d * n + j] = 1.0;
        }
    }
    let kc_w: Vec<PreparedWeights> = (0..g)
        .map(|gi| {
            kernel.prepare(&RawWeights::new(
                kc[gi * n * bits..(gi + 1) * n * bits]
                    .iter()
                    .map(|&c| c as f32)
                    .collect(),
                n,
                bits,
            ))
        })
        .collect();
    let mut kvz = vec![0.0f32; g * rows * bits];
    dispatch_grouped(kernel.as_ref(), &kc_w, &x1, rows, &mut kvz);

    // Stage-2 weights: qcᵀ (bits × n) per group.
    let qc_w: Vec<PreparedWeights> = (0..g)
        .map(|gi| {
            let mut qct = vec![0.0f32; bits * n];
            for i in 0..n {
                for bb in 0..bits {
                    qct[bb * n + i] = qc[(gi * n + i) * bits + bb] as f32;
                }
            }
            kernel.prepare(&RawWeights::new(qct, bits, n))
        })
        .collect();
    let mut numden = vec![0.0f32; g * rows * n];
    dispatch_grouped(kernel.as_ref(), &qc_w, &kvz, rows, &mut numden);

    // Epilogue: per-group Σⱼvⱼ and the shared normalizer, same ascending-j
    // order as the per-head path.
    let bias = (n * bits) as f32;
    let bf = bits as f32;
    let mut out = vec![0.0f32; g * n * d];
    for gi in 0..g {
        let vb = gi * n * d;
        let mut sv = vec![0.0f32; d];
        for j in 0..n {
            for (s, &vv) in sv.iter_mut().zip(&v[vb + j * d..vb + (j + 1) * d]) {
                *s += vv;
            }
        }
        let nb = gi * rows * n;
        for i in 0..n {
            let denom = bias + numden[nb + d * n + i] + EPS;
            for e in 0..d {
                out[vb + i * d + e] = (bf * sv[e] + numden[nb + e * n + i]) / denom;
            }
        }
    }
    out
}

// ---------------------------------------------------------------------------
// Streaming (causal) attention state — the O(d·bits) per-head session state
// ---------------------------------------------------------------------------

/// Streaming per-head state of the Hamming LinearAdd attention: the kᵀv
/// accumulator (`kv`, bits × d), the per-bit code sums (`z`, bits), the
/// value sum (`sv`, d), and the token count. This is everything linear
/// attention needs — O(d·bits) floats per head, independent of the prefix
/// length — and it is exactly the state `infer::session` exposes as a
/// first-class session object.
///
/// Semantics are **causal**: [`HammingAttnState::push`] absorbs one token's
/// key code and value, [`HammingAttnState::query`] answers attention over
/// every token pushed so far. Pushing tokens in ascending order and
/// querying after each push reproduces [`hamming_causal_attn_ref`]
/// *bit-exactly* (identical per-element accumulation order), which is what
/// makes chunked streaming equal to full-prefix recompute.
#[derive(Clone, Debug)]
pub struct HammingAttnState {
    pub bits: usize,
    pub d: usize,
    /// kᵀv accumulator (bits × d), token-ascending accumulation
    kv: Vec<f32>,
    /// per-bit ±1 code sums (bits)
    z: Vec<f32>,
    /// Σⱼ vⱼ (d)
    sv: Vec<f32>,
    /// tokens absorbed so far
    pub count: usize,
}

impl HammingAttnState {
    pub fn new(bits: usize, d: usize) -> HammingAttnState {
        HammingAttnState {
            bits,
            d,
            kv: vec![0.0; bits * d],
            z: vec![0.0; bits],
            sv: vec![0.0; d],
            count: 0,
        }
    }

    /// Number of f32s this state holds — the constant per-head memory cost
    /// of a live session (`bits·d + bits + d`).
    pub fn state_floats(&self) -> usize {
        self.kv.len() + self.z.len() + self.sv.len()
    }

    /// Absorb one token: `kc` (bits) ±1 key code, `v` (d) value row.
    pub fn push(&mut self, kc: &[i8], v: &[f32]) {
        assert_eq!(kc.len(), self.bits);
        assert_eq!(v.len(), self.d);
        for (b, &c) in kc.iter().enumerate() {
            if c > 0 {
                self.z[b] += 1.0;
            } else {
                self.z[b] -= 1.0;
            }
            let kvrow = &mut self.kv[b * self.d..(b + 1) * self.d];
            for (kk, &vv) in kvrow.iter_mut().zip(v) {
                if c > 0 {
                    *kk += vv;
                } else {
                    *kk -= vv;
                }
            }
        }
        for (s, &vv) in self.sv.iter_mut().zip(v) {
            *s += vv;
        }
        self.count += 1;
    }

    /// Attention output (d) of query code `qc` over every pushed token.
    pub fn query(&self, qc: &[i8]) -> Vec<f32> {
        assert_eq!(qc.len(), self.bits);
        let mut den = 0.0f32;
        let mut num = vec![0.0f32; self.d];
        for (b, &c) in qc.iter().enumerate() {
            let kvrow = &self.kv[b * self.d..(b + 1) * self.d];
            if c > 0 {
                den += self.z[b];
                for (nn, &kk) in num.iter_mut().zip(kvrow) {
                    *nn += kk;
                }
            } else {
                den -= self.z[b];
                for (nn, &kk) in num.iter_mut().zip(kvrow) {
                    *nn -= kk;
                }
            }
        }
        let bias = (self.count * self.bits) as f32;
        let bf = self.bits as f32;
        let denom = bias + den + EPS;
        num.iter()
            .zip(&self.sv)
            .map(|(&nn, &sv)| (bf * sv + nn) / denom)
            .collect()
    }
}

/// Streaming per-head state of the full-precision ReLU linear attention:
/// `kv` (d × d) feature-weighted value accumulator and `z` (d) feature
/// sums. Same causal push/query contract as [`HammingAttnState`].
#[derive(Clone, Debug)]
pub struct ReluAttnState {
    pub d: usize,
    kv: Vec<f32>,
    z: Vec<f32>,
    pub count: usize,
}

impl ReluAttnState {
    pub fn new(d: usize) -> ReluAttnState {
        ReluAttnState {
            d,
            kv: vec![0.0; d * d],
            z: vec![0.0; d],
            count: 0,
        }
    }

    pub fn state_floats(&self) -> usize {
        self.kv.len() + self.z.len()
    }

    /// Absorb one token's key and value rows (each d).
    pub fn push(&mut self, k: &[f32], v: &[f32]) {
        assert_eq!(k.len(), self.d);
        assert_eq!(v.len(), self.d);
        for (e, &ke) in k.iter().enumerate() {
            let fk = relu_feat(ke);
            self.z[e] += fk;
            let kvrow = &mut self.kv[e * self.d..(e + 1) * self.d];
            for (kk, &vv) in kvrow.iter_mut().zip(v) {
                *kk += fk * vv;
            }
        }
        self.count += 1;
    }

    /// Attention output (d) of query row `q` over every pushed token.
    pub fn query(&self, q: &[f32]) -> Vec<f32> {
        assert_eq!(q.len(), self.d);
        let mut den = 0.0f32;
        let mut out = vec![0.0f32; self.d];
        for (e, &qe) in q.iter().enumerate() {
            let fq = relu_feat(qe);
            den += fq * self.z[e];
            let kvrow = &self.kv[e * self.d..(e + 1) * self.d];
            for (o, &kk) in out.iter_mut().zip(kvrow) {
                *o += fq * kk;
            }
        }
        for o in out.iter_mut() {
            *o /= den + EPS;
        }
        out
    }
}

/// Readable causal oracle for [`HammingAttnState`]: output `i` attends over
/// tokens `0..=i` only, each prefix recomputed from scratch (O(n²·bits·d))
/// with the same per-element accumulation order as the streaming state —
/// bit-exact against push-then-query streaming.
pub fn hamming_causal_attn_ref(
    qc: &[i8],
    kc: &[i8],
    v: &[f32],
    n: usize,
    bits: usize,
    d: usize,
) -> Vec<f32> {
    assert_eq!(qc.len(), n * bits);
    assert_eq!(kc.len(), n * bits);
    assert_eq!(v.len(), n * d);
    let mut out = vec![0.0f32; n * d];
    for i in 0..n {
        let mut st = HammingAttnState::new(bits, d);
        for j in 0..=i {
            st.push(&kc[j * bits..(j + 1) * bits], &v[j * d..(j + 1) * d]);
        }
        out[i * d..(i + 1) * d].copy_from_slice(&st.query(&qc[i * bits..(i + 1) * bits]));
    }
    out
}

/// Readable causal oracle for [`ReluAttnState`] (full prefix recompute per
/// output token).
pub fn relu_causal_attn_ref(q: &[f32], k: &[f32], v: &[f32], n: usize, d: usize) -> Vec<f32> {
    assert_eq!(q.len(), n * d);
    assert_eq!(k.len(), n * d);
    assert_eq!(v.len(), n * d);
    let mut out = vec![0.0f32; n * d];
    for i in 0..n {
        let mut st = ReluAttnState::new(d);
        for j in 0..=i {
            st.push(&k[j * d..(j + 1) * d], &v[j * d..(j + 1) * d]);
        }
        out[i * d..(i + 1) * d].copy_from_slice(&st.query(&q[i * d..(i + 1) * d]));
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kernels::registry::KernelRegistry;
    use crate::quant::ksh::KshHasher;
    use crate::util::rng::XorShift64;

    #[test]
    fn softmax_attn_rows_average_v() {
        // With identical scores, out_i = mean of v rows.
        let n = 3;
        let d = 2;
        let q = vec![0.0f32; n * d];
        let k = vec![0.0f32; n * d];
        let v: Vec<f32> = (0..n * d).map(|i| i as f32).collect();
        let out = softmax_attn(&q, &k, &v, n, d);
        for i in 0..n {
            assert!((out[i * d] - 2.0).abs() < 1e-5);
            assert!((out[i * d + 1] - 3.0).abs() < 1e-5);
        }
    }

    #[test]
    fn relu_linear_attn_is_convex_combination_ish() {
        // Non-negative weights ⇒ outputs stay within [min, max] of v per dim.
        let mut rng = XorShift64::new(11);
        let (n, d) = (6, 4);
        let q = rng.normals(n * d);
        let k = rng.normals(n * d);
        let v = rng.normals(n * d);
        let out = relu_linear_attn(&q, &k, &v, n, d);
        for e in 0..d {
            let lo = (0..n).map(|j| v[j * d + e]).fold(f32::INFINITY, f32::min);
            let hi = (0..n)
                .map(|j| v[j * d + e])
                .fold(f32::NEG_INFINITY, f32::max);
            for i in 0..n {
                let o = out[i * d + e];
                assert!(o >= lo - 1e-3 && o <= hi + 1e-3, "{o} not in [{lo},{hi}]");
            }
        }
    }

    #[test]
    fn hamming_kernel_matches_ref_bit_exactly() {
        let registry = KernelRegistry::with_defaults();
        let mut rng = XorShift64::new(77);
        let (n, d, bits) = (10, 6, 16);
        let h = KshHasher::new(d, bits, 5);
        let q = rng.normals(n * d);
        let k = rng.normals(n * d);
        let v = rng.normals(n * d);
        let qc = h.hash_matrix(&q, n);
        let kc = h.hash_matrix(&k, n);
        let want = hamming_linear_attn_ref(&qc, &kc, &v, n, bits, d);
        for kernel in registry.for_primitive(crate::kernels::api::Primitive::MatAdd) {
            let got = hamming_linear_attn_kernel(&kernel, &qc, &kc, &v, n, bits, d);
            assert_eq!(got, want, "{} diverged from the oracle", kernel.id());
        }
    }

    #[test]
    fn hamming_kernel_matches_ref_on_non_power_of_two_shapes() {
        // bits and hd independent and non-pow2 — previously only exercised
        // indirectly through block shapes where bits == hd was a power of 2.
        let registry = KernelRegistry::with_defaults();
        let mut rng = XorShift64::new(333);
        for (n, d, bits) in [(9, 3, 5), (11, 6, 7), (5, 5, 13), (7, 2, 3)] {
            let h = KshHasher::new(d, bits, 17);
            let q = rng.normals(n * d);
            let k = rng.normals(n * d);
            let v = rng.normals(n * d);
            let qc = h.hash_matrix(&q, n);
            let kc = h.hash_matrix(&k, n);
            let want = hamming_linear_attn_ref(&qc, &kc, &v, n, bits, d);
            for kernel in registry.for_primitive(crate::kernels::api::Primitive::MatAdd) {
                let got = hamming_linear_attn_kernel(&kernel, &qc, &kc, &v, n, bits, d);
                assert_eq!(got, want, "{} (n={n} d={d} bits={bits})", kernel.id());
            }
        }
    }

    #[test]
    fn pack_unpack_heads_roundtrip() {
        let (b, n, heads, hd) = (3, 5, 2, 4);
        let mut rng = XorShift64::new(55);
        let x = rng.normals(b * n * heads * hd);
        let packed = pack_heads(&x, b, n, heads, hd);
        assert_eq!(unpack_heads(&packed, b, n, heads, hd), x);
        // group g = img·heads + h holds that head's rows contiguously
        let d = heads * hd;
        assert_eq!(packed[0..hd], x[0..hd]); // img 0, head 0, token 0
        let g1 = n * hd; // img 0, head 1 group base
        assert_eq!(packed[g1..g1 + hd], x[hd..d]);
    }

    #[test]
    fn batched_hamming_matches_per_head_bit_exactly() {
        let registry = KernelRegistry::with_defaults();
        let mut rng = XorShift64::new(808);
        let (g, n, d, bits) = (5, 9, 6, 11);
        let h = KshHasher::new(d, bits, 3);
        let q = rng.normals(g * n * d);
        let k = rng.normals(g * n * d);
        let v = rng.normals(g * n * d);
        let qc = h.hash_matrix(&q, g * n);
        let kc = h.hash_matrix(&k, g * n);
        for kernel in registry.for_primitive(crate::kernels::api::Primitive::MatAdd) {
            let got = hamming_linear_attn_batched(&kernel, &qc, &kc, &v, n, bits, d);
            for gi in 0..g {
                let want = hamming_linear_attn_kernel(
                    &kernel,
                    &qc[gi * n * bits..(gi + 1) * n * bits],
                    &kc[gi * n * bits..(gi + 1) * n * bits],
                    &v[gi * n * d..(gi + 1) * n * d],
                    n,
                    bits,
                    d,
                );
                assert_eq!(
                    &got[gi * n * d..(gi + 1) * n * d],
                    want.as_slice(),
                    "{} group {gi}",
                    kernel.id()
                );
            }
        }
    }

    #[test]
    fn batched_scalar_families_match_per_head_bit_exactly() {
        let mut rng = XorShift64::new(606);
        let (g, n, d) = (6, 7, 5);
        let q = rng.normals(g * n * d);
        let k = rng.normals(g * n * d);
        let v = rng.normals(g * n * d);
        let sm = softmax_attn_batched(q.clone(), k.clone(), v.clone(), n, d);
        let rl = relu_linear_attn_batched(q.clone(), k.clone(), v.clone(), n, d);
        for gi in 0..g {
            let s = gi * n * d..(gi + 1) * n * d;
            assert_eq!(
                &sm[s.clone()],
                softmax_attn(&q[s.clone()], &k[s.clone()], &v[s.clone()], n, d).as_slice()
            );
            assert_eq!(
                &rl[s.clone()],
                relu_linear_attn(&q[s.clone()], &k[s.clone()], &v[s.clone()], n, d).as_slice()
            );
        }
    }

    #[test]
    fn streaming_hamming_state_matches_causal_oracle_bit_exactly() {
        let (n, d, bits) = (12, 5, 16);
        let h = KshHasher::new(d, bits, 21);
        let mut rng = XorShift64::new(91);
        let q = rng.normals(n * d);
        let k = rng.normals(n * d);
        let v = rng.normals(n * d);
        let qc = h.hash_matrix(&q, n);
        let kc = h.hash_matrix(&k, n);
        let want = hamming_causal_attn_ref(&qc, &kc, &v, n, bits, d);
        let mut st = HammingAttnState::new(bits, d);
        assert_eq!(st.state_floats(), bits * d + bits + d);
        for i in 0..n {
            st.push(&kc[i * bits..(i + 1) * bits], &v[i * d..(i + 1) * d]);
            let got = st.query(&qc[i * bits..(i + 1) * bits]);
            assert_eq!(got, &want[i * d..(i + 1) * d], "token {i}");
        }
        assert_eq!(st.count, n);
    }

    #[test]
    fn streaming_relu_state_matches_causal_oracle_bit_exactly() {
        let (n, d) = (9, 6);
        let mut rng = XorShift64::new(37);
        let q = rng.normals(n * d);
        let k = rng.normals(n * d);
        let v = rng.normals(n * d);
        let want = relu_causal_attn_ref(&q, &k, &v, n, d);
        let mut st = ReluAttnState::new(d);
        for i in 0..n {
            st.push(&k[i * d..(i + 1) * d], &v[i * d..(i + 1) * d]);
            let got = st.query(&q[i * d..(i + 1) * d]);
            assert_eq!(got, &want[i * d..(i + 1) * d], "token {i}");
        }
    }

    #[test]
    fn causal_last_token_equals_full_attention_row() {
        // The final causal output row attends over the whole sequence, so it
        // must equal the last row of the existing (non-causal) reference.
        let (n, d, bits) = (8, 4, 32);
        let h = KshHasher::new(d, bits, 5);
        let mut rng = XorShift64::new(11);
        let q = rng.normals(n * d);
        let k = rng.normals(n * d);
        let v = rng.normals(n * d);
        let qc = h.hash_matrix(&q, n);
        let kc = h.hash_matrix(&k, n);
        let full = hamming_linear_attn_ref(&qc, &kc, &v, n, bits, d);
        let causal = hamming_causal_attn_ref(&qc, &kc, &v, n, bits, d);
        for e in 0..d {
            let a = full[(n - 1) * d + e];
            let b = causal[(n - 1) * d + e];
            assert!((a - b).abs() < 1e-5, "elem {e}: {a} vs {b}");
        }
    }

    #[test]
    fn identical_codes_give_self_peak() {
        // If qc == kc, token i matches itself on every bit, so the weight on
        // v_i is maximal (bits matches) — the output leans toward v_i.
        let (n, d, bits) = (4, 3, 32);
        let h = KshHasher::new(d, bits, 9);
        let mut rng = XorShift64::new(13);
        let x = rng.normals(n * d);
        let codes = h.hash_matrix(&x, n);
        let mut v = vec![0.0f32; n * d];
        for i in 0..n {
            v[i * d + i % d] = 1.0; // near-one-hot rows
        }
        let out = hamming_linear_attn_ref(&codes, &codes, &v, n, bits, d);
        for i in 0..n {
            // the self column must carry the largest output weight
            let self_val = out[i * d + i % d];
            assert!(self_val > 0.0, "row {i} lost its own value");
        }
    }
}
