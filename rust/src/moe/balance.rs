//! Latency-aware load balancing (paper Eq. 4, §4.2) — serving-side
//! evaluation of the importance/load losses and the expected
//! synchronization cost of an expert assignment.

use crate::util::stats::scv;

/// Latency-aware coefficients α_i = Lat_i / Σ_j Lat_j.
///
/// Minimizing SCV({α_i · S_i}) drives S_i ∝ 1/α_i: faster experts receive
/// proportionally more tokens.
pub fn alphas(latencies_ms: &[f64]) -> Vec<f64> {
    let sum: f64 = latencies_ms.iter().sum();
    assert!(sum > 0.0, "latencies must be positive");
    latencies_ms.iter().map(|l| l / sum).collect()
}

/// The importance loss L_IMP: SCV of α-weighted gate-value sums per expert.
pub fn importance_loss(gate_sums: &[f64], alphas: &[f64]) -> f64 {
    let weighted: Vec<f64> = gate_sums.iter().zip(alphas).map(|(g, a)| g * a).collect();
    scv(&weighted)
}

/// The load loss L_LOAD: SCV of α-weighted token counts per expert.
pub fn load_loss(token_counts: &[usize], alphas: &[f64]) -> f64 {
    let weighted: Vec<f64> = token_counts
        .iter()
        .zip(alphas)
        .map(|(&c, a)| c as f64 * a)
        .collect();
    scv(&weighted)
}

/// The token split that equalizes expert finish times — the target the
/// LL-loss trains the router toward. Finish time of expert i with n_i tokens
/// ≈ n_i · per_token_ms_i, equalized ⇒ n_i ∝ 1/per_token_ms_i.
pub fn ideal_split(per_token_ms: &[f64], total_tokens: usize) -> Vec<usize> {
    let inv: Vec<f64> = per_token_ms.iter().map(|l| 1.0 / l).collect();
    let z: f64 = inv.iter().sum();
    let mut out: Vec<usize> = inv
        .iter()
        .map(|v| ((v / z) * total_tokens as f64).floor() as usize)
        .collect();
    // Distribute the rounding remainder to the fastest expert. total_cmp
    // keeps the pick total when a latency sample is NaN (NaN ranks
    // greatest, so a poisoned expert is never chosen as fastest).
    let assigned: usize = out.iter().sum();
    if let Some(fastest) = per_token_ms
        .iter()
        .enumerate()
        .min_by(|a, b| a.1.total_cmp(b.1))
        .map(|(i, _)| i)
    {
        out[fastest] += total_tokens - assigned;
    }
    out
}

/// Synchronization cost of an assignment: experts run in parallel, the MoE
/// layer finishes when the slowest does. Returns (makespan_ms, idle_ms)
/// where idle is the summed wait of the non-critical experts — the quantity
/// the LL-loss minimizes (paper: "reduce the synchronization time").
pub fn sync_cost(token_counts: &[usize], per_token_ms: &[f64]) -> (f64, f64) {
    let finish: Vec<f64> = token_counts
        .iter()
        .zip(per_token_ms)
        .map(|(&n, l)| n as f64 * l)
        .collect();
    let makespan = finish.iter().cloned().fold(0.0, f64::max);
    let idle = finish.iter().map(|f| makespan - f).sum();
    (makespan, idle)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn alphas_normalize() {
        let a = alphas(&[3.0, 1.0]);
        assert!((a[0] - 0.75).abs() < 1e-12);
        assert!((a.iter().sum::<f64>() - 1.0).abs() < 1e-12);
    }

    #[test]
    fn losses_zero_at_latency_proportional_balance() {
        // Mult is 3× slower than Shift ⇒ balanced when Shift gets 3× tokens.
        let a = alphas(&[3.0, 1.0]);
        let loss_balanced = load_loss(&[100, 300], &a);
        let loss_equal = load_loss(&[200, 200], &a);
        assert!(loss_balanced < 1e-12, "{loss_balanced}");
        assert!(loss_equal > 0.1);
    }

    #[test]
    fn ideal_split_equalizes_finish_times() {
        let per = [3.0, 1.0];
        let split = ideal_split(&per, 400);
        assert_eq!(split.iter().sum::<usize>(), 400);
        let (_, idle) = sync_cost(&split, &per);
        let (_, idle_naive) = sync_cost(&[200, 200], &per);
        assert!(idle < idle_naive, "{idle} vs {idle_naive}");
        // n0·3 ≈ n1·1 ⇒ n0 = 100, n1 = 300
        assert_eq!(split, vec![100, 300]);
    }

    #[test]
    fn ideal_split_tolerates_nan_latency() {
        // A poisoned per-token latency must not panic the fastest-expert
        // pick, and the healthy expert absorbs the remainder.
        let split = ideal_split(&[1.0, f64::NAN], 10);
        assert_eq!(split.iter().sum::<usize>(), 10);
        assert_eq!(split[1], 0, "NaN expert receives no remainder");
    }

    #[test]
    fn sync_cost_of_skewed_assignment() {
        let (makespan, idle) = sync_cost(&[10, 0], &[1.0, 1.0]);
        assert_eq!(makespan, 10.0);
        assert_eq!(idle, 10.0);
    }

    #[test]
    fn importance_loss_tracks_gate_imbalance() {
        let a = alphas(&[1.0, 1.0]);
        assert!(importance_loss(&[5.0, 5.0], &a) < 1e-12);
        assert!(importance_loss(&[9.0, 1.0], &a) > 0.3);
    }
}
