//! Kernel-level MoE expert execution through the
//! [`crate::kernels::registry::KernelRegistry`] — the
//! modularized counterpart of the artifact-based pipeline in
//! `coordinator::scheduler`: partitions from [`crate::moe::dispatch`] run
//! through registry backends instead of compiled HLO executables.
//!
//! The paper's pair is expert 0 = Mult (dense matmul) and expert 1 = Shift
//! (MatShift); [`MoeLayer::mult_shift`] wires exactly that, with each
//! expert's backend chosen by the [`Planner`] for the largest bucket shape —
//! which is how the Shift expert picks up the row-parallel pool backend on
//! multi-core hosts.

use std::sync::Arc;

use crate::kernels::api::{LinearKernel, PreparedWeights, Primitive, RawWeights};
use crate::kernels::planner::{Planner, Shape};
use crate::moe::dispatch::{partition, scatter};
use crate::moe::router::Route;

/// One expert: a registry backend plus its prepared weights.
pub struct Expert {
    pub kernel: Arc<dyn LinearKernel>,
    pub weights: PreparedWeights,
}

impl Expert {
    /// Prepare `raw` into `kernel`'s deployment format (conversion-time).
    pub fn new(kernel: Arc<dyn LinearKernel>, raw: &RawWeights) -> Expert {
        let weights = kernel.prepare(raw);
        Expert { kernel, weights }
    }

    /// `y (m×n) = expert(x (m×k))`.
    ///
    /// `prepare_operand` copies (and for shift backends quantizes) the
    /// partition once per call — O(m·k) next to the O(m·k·n) kernel; a
    /// borrowing operand variant is the obvious follow-up if this ever
    /// shows in serving profiles.
    pub fn forward(&self, x: &[f32], m: usize) -> Vec<f32> {
        let op = self.kernel.prepare_operand(x, m, self.weights.k());
        let mut out = vec![0.0f32; m * self.weights.n()];
        self.kernel.run(&self.weights, &op, &mut out);
        out
    }
}

/// A kernel-level MoE linear layer: one [`Expert`] per routing class;
/// `forward` partitions tokens into compiled-bucket-padded chunks, runs each
/// through its expert's backend, and scatters gate-scaled outputs back.
pub struct MoeLayer {
    pub dim: usize,
    pub experts: Vec<Expert>,
    pub buckets: Vec<usize>,
}

impl MoeLayer {
    /// The paper's Mult/Shift expert pair with planner-chosen backends.
    /// Both weight matrices must share the input dim; output dims must match
    /// for scatter to be well-defined.
    pub fn mult_shift(
        planner: &Planner,
        raw_mult: &RawWeights,
        raw_shift: &RawWeights,
        buckets: Vec<usize>,
    ) -> MoeLayer {
        assert_eq!(raw_mult.k, raw_shift.k, "experts must share input dim");
        assert_eq!(raw_mult.n, raw_shift.n, "experts must share output dim");
        let dim = raw_mult.k;
        let max_bucket = *buckets.last().expect("no buckets");
        let mult = planner.choose(Primitive::MatMul, Shape::new(max_bucket, dim, raw_mult.n));
        let shift = planner.choose(Primitive::MatShift, Shape::new(max_bucket, dim, raw_shift.n));
        MoeLayer {
            dim,
            experts: vec![Expert::new(mult, raw_mult), Expert::new(shift, raw_shift)],
            buckets,
        }
    }

    /// Registry ids of the experts' backends (for metrics/reporting).
    pub fn backend_ids(&self) -> Vec<String> {
        self.experts.iter().map(|e| e.kernel.id()).collect()
    }

    /// Dispatch `tokens` (T×dim row-major) by `routes`, run each partition
    /// through its expert's kernel, and scatter gate-scaled outputs back
    /// into a (T×n) buffer.
    pub fn forward(&self, tokens: &[f32], routes: &[Route]) -> Vec<f32> {
        assert_eq!(tokens.len(), routes.len() * self.dim);
        let n_out = self.experts[0].weights.n();
        debug_assert!(self.experts.iter().all(|e| e.weights.n() == n_out));
        let parts = partition(tokens, self.dim, routes, self.experts.len(), &self.buckets);
        let mut out = vec![0.0f32; routes.len() * n_out];
        for p in &parts {
            let expert_out = self.experts[p.expert].forward(&p.padded, p.bucket);
            scatter(&mut out, n_out, p, &expert_out, routes);
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kernels::registry::KernelRegistry;
    use crate::util::rng::XorShift64;

    fn identity(dim: usize) -> Vec<f32> {
        let mut eye = vec![0.0f32; dim * dim];
        for i in 0..dim {
            eye[i * dim + i] = 1.0;
        }
        eye
    }

    fn routes_alternating(n: usize) -> Vec<Route> {
        (0..n)
            .map(|i| Route {
                expert: i % 2,
                gate: 1.0,
            })
            .collect()
    }

    #[test]
    fn identity_experts_round_trip_within_quant_error() {
        let dim = 8;
        let raw = RawWeights::new(identity(dim), dim, dim);
        let planner = Planner::new(Arc::new(KernelRegistry::with_defaults()));
        let layer = MoeLayer::mult_shift(&planner, &raw, &raw, vec![4, 16]);
        let mut rng = XorShift64::new(3);
        let feats = rng.normals(10 * dim);
        let out = layer.forward(&feats, &routes_alternating(10));
        assert_eq!(out.len(), feats.len());
        for (o, f) in out.iter().zip(&feats) {
            // Mult expert is exact; Shift expert carries pow2(0)=2^-8
            // off-diagonal grid plus INT8 activation error.
            assert!((o - f).abs() < 0.1, "{o} vs {f}");
        }
    }

    #[test]
    fn gates_scale_expert_outputs() {
        let dim = 4;
        let raw = RawWeights::new(identity(dim), dim, dim);
        let planner = Planner::new(Arc::new(KernelRegistry::with_defaults()));
        // pin both experts to the exact dense backend so gating is the only
        // transformation under test
        planner.pin(Primitive::MatMul, Shape::new(8, dim, dim), "blocked");
        planner.pin(Primitive::MatShift, Shape::new(8, dim, dim), "planes");
        let layer = MoeLayer::mult_shift(&planner, &raw, &raw, vec![8]);
        let feats = vec![1.0f32; 2 * dim];
        let routes = vec![
            Route {
                expert: 0,
                gate: 0.25,
            },
            Route {
                expert: 0,
                gate: 0.5,
            },
        ];
        let out = layer.forward(&feats, &routes);
        assert!(out[..dim].iter().all(|v| (*v - 0.25).abs() < 1e-6));
        assert!(out[dim..].iter().all(|v| (*v - 0.5).abs() < 1e-6));
    }

    #[test]
    fn pinned_rowpar_matches_serial_planes_exactly() {
        let dim = 16;
        let mut rng = XorShift64::new(9);
        let raw = RawWeights::new(rng.normals(dim * dim), dim, dim);
        let registry = Arc::new(KernelRegistry::with_defaults());

        let mk_layer = |backend: &str| {
            let planner = Planner::new(registry.clone());
            planner.pin(Primitive::MatMul, Shape::new(64, dim, dim), "blocked");
            planner.pin(Primitive::MatShift, Shape::new(64, dim, dim), backend);
            MoeLayer::mult_shift(&planner, &raw, &raw, vec![64])
        };
        let par = mk_layer("rowpar");
        let ser = mk_layer("planes");
        assert!(par.backend_ids().contains(&"matshift/rowpar".to_string()));

        let tokens = 50;
        let feats = rng.normals(tokens * dim);
        let routes: Vec<Route> = (0..tokens)
            .map(|_| Route {
                expert: 1,
                gate: 1.0,
            })
            .collect();
        // same integer math, chunked by rows → bit-identical outputs
        assert_eq!(par.forward(&feats, &routes), ser.forward(&feats, &routes));
    }
}
