//! Kernel-level MoE expert execution through the
//! [`crate::kernels::registry::KernelRegistry`] — the
//! modularized counterpart of the artifact-based pipeline in
//! `coordinator::scheduler`: partitions from [`crate::moe::dispatch`] run
//! through registry backends instead of compiled HLO executables.
//!
//! The paper's pair is expert 0 = Mult (dense matmul) and expert 1 = Shift
//! (MatShift); [`MoeLayer::mult_shift`] wires exactly that, with each
//! expert's backend chosen by the [`Planner`] for the largest bucket shape —
//! which is how the Shift expert picks up the row-parallel pool backend on
//! multi-core hosts.

use std::sync::Arc;
use std::time::Instant;

use crate::kernels::api::{LinearKernel, PreparedWeights, Primitive, RawWeights};
use crate::kernels::planner::{Planner, Shape};
use crate::moe::dispatch::{padding_waste, partition, scatter};
use crate::moe::router::{self, Route};

/// One expert: a registry backend plus its prepared weights.
pub struct Expert {
    pub kernel: Arc<dyn LinearKernel>,
    pub weights: PreparedWeights,
}

impl Expert {
    /// Prepare `raw` into `kernel`'s deployment format (conversion-time).
    pub fn new(kernel: Arc<dyn LinearKernel>, raw: &RawWeights) -> Expert {
        let weights = kernel.prepare(raw);
        Expert { kernel, weights }
    }

    /// `y (m×n) = expert(x (m×k))`.
    ///
    /// `prepare_operand` copies (and for shift backends quantizes) the
    /// partition once per call — O(m·k) next to the O(m·k·n) kernel; a
    /// borrowing operand variant is the obvious follow-up if this ever
    /// shows in serving profiles.
    pub fn forward(&self, x: &[f32], m: usize) -> Vec<f32> {
        let op = self.kernel.prepare_operand(x, m, self.weights.k());
        let mut out = vec![0.0f32; m * self.weights.n()];
        crate::kernels::registry::dispatch(self.kernel.as_ref(), &self.weights, &op, &mut out);
        out
    }
}

/// A kernel-level MoE linear layer: one [`Expert`] per routing class;
/// `forward` partitions tokens into compiled-bucket-padded chunks, runs each
/// through its expert's backend, and scatters gate-scaled outputs back.
pub struct MoeLayer {
    pub dim: usize,
    pub experts: Vec<Expert>,
    pub buckets: Vec<usize>,
}

impl MoeLayer {
    /// The paper's Mult/Shift expert pair with planner-chosen backends.
    /// Both weight matrices must share the input dim; output dims must match
    /// for scatter to be well-defined.
    pub fn mult_shift(
        planner: &Planner,
        raw_mult: &RawWeights,
        raw_shift: &RawWeights,
        buckets: Vec<usize>,
    ) -> MoeLayer {
        assert_eq!(raw_mult.k, raw_shift.k, "experts must share input dim");
        assert_eq!(raw_mult.n, raw_shift.n, "experts must share output dim");
        let dim = raw_mult.k;
        let max_bucket = *buckets.last().expect("no buckets");
        let mult = planner.choose(Primitive::MatMul, Shape::new(max_bucket, dim, raw_mult.n));
        let shift = planner.choose(Primitive::MatShift, Shape::new(max_bucket, dim, raw_shift.n));
        MoeLayer {
            dim,
            experts: vec![Expert::new(mult, raw_mult), Expert::new(shift, raw_shift)],
            buckets,
        }
    }

    /// Registry ids of the experts' backends (for metrics/reporting).
    pub fn backend_ids(&self) -> Vec<String> {
        self.experts.iter().map(|e| e.kernel.id()).collect()
    }

    /// Dispatch `tokens` (T×dim row-major) by `routes`, run each partition
    /// through its expert's kernel, and scatter gate-scaled outputs back
    /// into a (T×n) buffer.
    pub fn forward(&self, tokens: &[f32], routes: &[Route]) -> Vec<f32> {
        assert_eq!(tokens.len(), routes.len() * self.dim);
        let n_out = self.experts[0].weights.n();
        debug_assert!(self.experts.iter().all(|e| e.weights.n() == n_out));
        let parts = partition(tokens, self.dim, routes, self.experts.len(), &self.buckets);
        let mut out = vec![0.0f32; routes.len() * n_out];
        for p in &parts {
            let expert_out = self.experts[p.expert].forward(&p.padded, p.bucket);
            scatter(&mut out, n_out, p, &expert_out, routes);
        }
        out
    }
}

/// One two-layer MLP expert (`relu(x@w1+b1)@w2+b2`) with both linears on
/// registry backends — the unit the paper's MoE MLP routes tokens to
/// (Mult expert: MatMul backends; Shift expert: MatShift backends).
pub struct MlpExpert {
    pub l1: Expert,
    pub b1: Vec<f32>,
    pub l2: Expert,
    pub b2: Vec<f32>,
}

impl MlpExpert {
    /// Both linears on planner-chosen backends of `primitive`, benchmarked
    /// at the largest-bucket shape (conversion-time, like [`MoeLayer`]).
    pub fn new(
        planner: &Planner,
        primitive: Primitive,
        raw1: &RawWeights,
        b1: Vec<f32>,
        raw2: &RawWeights,
        b2: Vec<f32>,
        max_m: usize,
    ) -> MlpExpert {
        assert_eq!(raw1.n, raw2.k, "hidden dims must chain");
        assert_eq!(b1.len(), raw1.n);
        assert_eq!(b2.len(), raw2.n);
        let k1 = planner.choose(primitive, Shape::new(max_m, raw1.k, raw1.n));
        let k2 = planner.choose(primitive, Shape::new(max_m, raw2.k, raw2.n));
        MlpExpert {
            l1: Expert::new(k1, raw1),
            b1,
            l2: Expert::new(k2, raw2),
            b2,
        }
    }

    /// `y (m×n2) = relu(x@w1 + b1) @ w2 + b2`.
    pub fn forward(&self, x: &[f32], m: usize) -> Vec<f32> {
        let mut h = self.l1.forward(x, m);
        for row in h.chunks_mut(self.b1.len()) {
            for (v, &b) in row.iter_mut().zip(&self.b1) {
                *v = (*v + b).max(0.0);
            }
        }
        let mut y = self.l2.forward(&h, m);
        for row in y.chunks_mut(self.b2.len()) {
            for (v, &b) in row.iter_mut().zip(&self.b2) {
                *v += b;
            }
        }
        y
    }
}

/// Diagnostics from one [`MoeMlp::forward`] call — feeds the serving
/// metrics (expert load, gate mass, per-expert wall clock, padding waste).
#[derive(Clone, Debug)]
pub struct MoeTrace {
    /// per-token routing decisions, in token order
    pub routes: Vec<Route>,
    /// summed softmax gate probability per expert column
    pub gate_sums: [f64; 2],
    /// wall-clock spent in each expert's kernels (ms)
    pub expert_ms: [f64; 2],
    pub padding_waste: f64,
}

/// The paper's full MoE MLP at kernel level: a MatMul router gate, top-1
/// dispatch (`moe::router`), bucket-padded partitions (`moe::dispatch`),
/// one [`MlpExpert`] per routing class, and gate-scaled scatter — the
/// native-engine counterpart of the `serve_expert_*` artifact pipeline in
/// `coordinator::scheduler`.
pub struct MoeMlp {
    pub dim: usize,
    gate: Expert,
    pub experts: Vec<MlpExpert>,
    pub buckets: Vec<usize>,
}

impl MoeMlp {
    /// The paper's Mult/Shift expert pair behind a router gate.
    pub fn mult_shift(
        planner: &Planner,
        gate_raw: &RawWeights,
        mult: MlpExpert,
        shift: MlpExpert,
        buckets: Vec<usize>,
    ) -> MoeMlp {
        assert_eq!(gate_raw.n, 2, "router gate must emit 2 expert logits");
        assert_eq!(mult.l1.weights.k(), gate_raw.k, "experts must consume dim");
        assert_eq!(shift.l1.weights.k(), gate_raw.k, "experts must consume dim");
        assert_eq!(
            mult.b2.len(),
            shift.b2.len(),
            "experts must share output dim for scatter"
        );
        let max_bucket = *buckets.last().expect("no buckets");
        let gk = planner.choose(
            Primitive::MatMul,
            Shape::new(max_bucket, gate_raw.k, gate_raw.n),
        );
        MoeMlp {
            dim: gate_raw.k,
            gate: Expert::new(gk, gate_raw),
            experts: vec![mult, shift],
            buckets,
        }
    }

    /// Route `t` tokens (t×dim row-major), run each bucket-padded partition
    /// through its expert, scatter gate-scaled outputs back.
    pub fn forward(&self, tokens: &[f32], t: usize) -> (Vec<f32>, MoeTrace) {
        assert_eq!(tokens.len(), t * self.dim);
        // Router: logits → softmax → top-1 (paper's G(x) = p_i·1{p_i ≥ p_j}).
        let mut probs = self.gate.forward(tokens, t);
        for row in probs.chunks_mut(2) {
            router::softmax(row);
        }
        let routes = router::route(&probs, 2);
        let mut gate_sums = [0.0f64; 2];
        for row in probs.chunks(2) {
            gate_sums[0] += row[0] as f64;
            gate_sums[1] += row[1] as f64;
        }
        let n_out = self.experts[0].b2.len();
        let parts = partition(tokens, self.dim, &routes, self.experts.len(), &self.buckets);
        let mut out = vec![0.0f32; t * n_out];
        let mut expert_ms = [0.0f64; 2];
        for p in &parts {
            let t0 = Instant::now();
            let y = self.experts[p.expert].forward(&p.padded, p.bucket);
            expert_ms[p.expert] += t0.elapsed().as_secs_f64() * 1e3;
            scatter(&mut out, n_out, p, &y, &routes);
        }
        let trace = MoeTrace {
            gate_sums,
            expert_ms,
            padding_waste: padding_waste(&parts),
            routes,
        };
        (out, trace)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kernels::registry::KernelRegistry;
    use crate::util::rng::XorShift64;

    fn identity(dim: usize) -> Vec<f32> {
        let mut eye = vec![0.0f32; dim * dim];
        for i in 0..dim {
            eye[i * dim + i] = 1.0;
        }
        eye
    }

    fn routes_alternating(n: usize) -> Vec<Route> {
        (0..n)
            .map(|i| Route {
                expert: i % 2,
                gate: 1.0,
            })
            .collect()
    }

    #[test]
    fn identity_experts_round_trip_within_quant_error() {
        let dim = 8;
        let raw = RawWeights::new(identity(dim), dim, dim);
        let planner = Planner::new(Arc::new(KernelRegistry::with_defaults()));
        let layer = MoeLayer::mult_shift(&planner, &raw, &raw, vec![4, 16]);
        let mut rng = XorShift64::new(3);
        let feats = rng.normals(10 * dim);
        let out = layer.forward(&feats, &routes_alternating(10));
        assert_eq!(out.len(), feats.len());
        for (o, f) in out.iter().zip(&feats) {
            // Mult expert is exact; Shift expert carries pow2(0)=2^-8
            // off-diagonal grid plus INT8 activation error.
            assert!((o - f).abs() < 0.1, "{o} vs {f}");
        }
    }

    #[test]
    fn gates_scale_expert_outputs() {
        let dim = 4;
        let raw = RawWeights::new(identity(dim), dim, dim);
        let planner = Planner::new(Arc::new(KernelRegistry::with_defaults()));
        // pin both experts to the exact dense backend so gating is the only
        // transformation under test
        planner.pin(Primitive::MatMul, Shape::new(8, dim, dim), "blocked");
        planner.pin(Primitive::MatShift, Shape::new(8, dim, dim), "planes");
        let layer = MoeLayer::mult_shift(&planner, &raw, &raw, vec![8]);
        let feats = vec![1.0f32; 2 * dim];
        let routes = vec![
            Route {
                expert: 0,
                gate: 0.25,
            },
            Route {
                expert: 0,
                gate: 0.5,
            },
        ];
        let out = layer.forward(&feats, &routes);
        assert!(out[..dim].iter().all(|v| (*v - 0.25).abs() < 1e-6));
        assert!(out[dim..].iter().all(|v| (*v - 0.5).abs() < 1e-6));
    }

    #[test]
    fn pinned_rowpar_matches_serial_planes_exactly() {
        let dim = 16;
        let mut rng = XorShift64::new(9);
        let raw = RawWeights::new(rng.normals(dim * dim), dim, dim);
        let registry = Arc::new(KernelRegistry::with_defaults());

        let mk_layer = |backend: &str| {
            let planner = Planner::new(registry.clone());
            planner.pin(Primitive::MatMul, Shape::new(64, dim, dim), "blocked");
            planner.pin(Primitive::MatShift, Shape::new(64, dim, dim), backend);
            MoeLayer::mult_shift(&planner, &raw, &raw, vec![64])
        };
        let par = mk_layer("rowpar");
        let ser = mk_layer("planes");
        assert!(par.backend_ids().contains(&"matshift/rowpar".to_string()));

        let tokens = 50;
        let feats = rng.normals(tokens * dim);
        let routes: Vec<Route> = (0..tokens)
            .map(|_| Route {
                expert: 1,
                gate: 1.0,
            })
            .collect();
        // same integer math, chunked by rows → bit-identical outputs
        assert_eq!(par.forward(&feats, &routes), ser.forward(&feats, &routes));
    }

    fn tiny_moe_mlp(planner: &Planner, dim: usize, hidden: usize) -> MoeMlp {
        let mut rng = XorShift64::new(31);
        let raw = |rng: &mut XorShift64, k: usize, n: usize| {
            RawWeights::new(rng.normals(k * n).iter().map(|v| v * 0.3).collect(), k, n)
        };
        let mult = MlpExpert::new(
            planner,
            Primitive::MatMul,
            &raw(&mut rng, dim, hidden),
            vec![0.0; hidden],
            &raw(&mut rng, hidden, dim),
            vec![0.0; dim],
            16,
        );
        let shift = MlpExpert::new(
            planner,
            Primitive::MatShift,
            &raw(&mut rng, dim, hidden),
            vec![0.0; hidden],
            &raw(&mut rng, hidden, dim),
            vec![0.0; dim],
            16,
        );
        let gate = raw(&mut rng, dim, 2);
        MoeMlp::mult_shift(planner, &gate, mult, shift, vec![4, 16])
    }

    #[test]
    fn moe_mlp_routes_every_token_once() {
        let planner = Planner::new(Arc::new(KernelRegistry::with_defaults()));
        let moe = tiny_moe_mlp(&planner, 8, 16);
        let mut rng = XorShift64::new(99);
        let t = 11;
        let tokens = rng.normals(t * 8);
        let (out, trace) = moe.forward(&tokens, t);
        assert_eq!(out.len(), t * 8);
        assert_eq!(trace.routes.len(), t);
        assert!(out.iter().all(|v| v.is_finite()));
        // softmax gates: the two columns sum to t
        assert!((trace.gate_sums[0] + trace.gate_sums[1] - t as f64).abs() < 1e-4);
        assert!((0.0..=1.0).contains(&trace.padding_waste));
    }

    #[test]
    fn moe_mlp_gate_scales_outputs() {
        // With both experts identical and gates ≈ (0.5, 0.5), outputs are
        // ≈ 0.5 · expert(x) regardless of the routing decision.
        let planner = Planner::new(Arc::new(KernelRegistry::with_defaults()));
        let dim = 4;
        let raw1 = RawWeights::new(identity(dim), dim, dim);
        let mk = |prim| {
            MlpExpert::new(
                &planner,
                prim,
                &raw1,
                vec![0.0; dim],
                &raw1,
                vec![0.0; dim],
                8,
            )
        };
        // zero gate weights ⇒ uniform softmax ⇒ gate value 0.5
        let gate = RawWeights::new(vec![0.0; dim * 2], dim, 2);
        let moe = MoeMlp::mult_shift(
            &planner,
            &gate,
            mk(Primitive::MatMul),
            mk(Primitive::MatMul),
            vec![8],
        );
        let x = vec![1.0f32; 2 * dim];
        let (out, _) = moe.forward(&x, 2);
        // identity·identity through relu of positive inputs = x, gated by 0.5
        for v in &out {
            assert!((v - 0.5).abs() < 1e-5, "{v}");
        }
    }
}
