//! Top-1 router over softmax gates (the paper's G(x) = p_i · 1{p_i ≥ p_j}).
//!
//! At serving time the gate probabilities arrive from the `serve_*_premlp`
//! HLO executables; this module turns them into a dispatch decision.

/// Routing decision for one token.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct Route {
    /// winning expert index (0 = Mult, 1 = Shift)
    pub expert: usize,
    /// the winning gate value p_i (scales the expert output)
    pub gate: f32,
}

pub const EXPERT_MULT: usize = 0;
pub const EXPERT_SHIFT: usize = 1;

/// Route a batch of tokens from (T, E) gate probabilities.
pub fn route(gates: &[f32], experts: usize) -> Vec<Route> {
    assert!(experts >= 1);
    assert_eq!(gates.len() % experts, 0);
    gates
        .chunks(experts)
        .map(|g| {
            let (mut best, mut bv) = (0usize, f32::NEG_INFINITY);
            for (i, &v) in g.iter().enumerate() {
                if v > bv {
                    best = i;
                    bv = v;
                }
            }
            Route {
                expert: best,
                gate: bv,
            }
        })
        .collect()
}

/// Softmax a slice of logits in place (for host-side routing when the HLO
/// emits raw logits).
pub fn softmax(logits: &mut [f32]) {
    let m = logits.iter().fold(f32::NEG_INFINITY, |a, &b| a.max(b));
    let mut sum = 0.0;
    for v in logits.iter_mut() {
        *v = (*v - m).exp();
        sum += *v;
    }
    for v in logits.iter_mut() {
        *v /= sum;
    }
}

/// Fraction of tokens routed to each expert.
pub fn load_fractions(routes: &[Route], experts: usize) -> Vec<f64> {
    let mut counts = vec![0usize; experts];
    for r in routes {
        counts[r.expert] += 1;
    }
    counts
        .iter()
        .map(|&c| c as f64 / routes.len().max(1) as f64)
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn routes_to_argmax() {
        let gates = [0.7, 0.3, 0.2, 0.8];
        let r = route(&gates, 2);
        assert_eq!(r[0].expert, EXPERT_MULT);
        assert!((r[0].gate - 0.7).abs() < 1e-6);
        assert_eq!(r[1].expert, EXPERT_SHIFT);
    }

    #[test]
    fn softmax_normalizes() {
        let mut l = [1.0f32, 2.0, 3.0];
        softmax(&mut l);
        let s: f32 = l.iter().sum();
        assert!((s - 1.0).abs() < 1e-6);
        assert!(l[2] > l[1] && l[1] > l[0]);
    }

    #[test]
    fn load_fractions_sum_to_one() {
        let gates = [0.9, 0.1, 0.1, 0.9, 0.6, 0.4, 0.2, 0.8];
        let r = route(&gates, 2);
        let f = load_fractions(&r, 2);
        assert!((f.iter().sum::<f64>() - 1.0).abs() < 1e-12);
        assert!((f[0] - 0.5).abs() < 1e-12);
    }
}
