//! Sparse token dispatch: partition tokens between experts, pad each
//! partition to a compiled bucket size, and scatter expert outputs back —
//! the runtime realization of the paper's "dynamic input allocation"
//! (handled by Nimble/TVM in the paper, by this module + pre-compiled
//! bucket-shaped executables here).

use crate::moe::router::Route;

/// A token partition destined for one expert.
#[derive(Clone, Debug)]
pub struct Partition {
    pub expert: usize,
    /// original token indices, in order
    pub indices: Vec<usize>,
    /// gathered token features, padded with zeros to `bucket` rows
    pub padded: Vec<f32>,
    /// chosen bucket size (rows in `padded`)
    pub bucket: usize,
}

/// Pick the smallest compiled bucket ≥ n (buckets must be sorted ascending).
///
/// # Overflow contract
///
/// `n` must not exceed the largest bucket: there is no compiled executable
/// bigger than that, so oversized partitions must be split into
/// largest-bucket chunks *before* bucket selection — [`partition`] does
/// exactly that. Debug builds assert the contract; release builds keep the
/// legacy clamp-to-largest fallback, which any caller that skipped
/// splitting will then trip over when it gathers `n` rows into a
/// `bucket < n` buffer.
pub fn pick_bucket(buckets: &[usize], n: usize) -> usize {
    let largest = *buckets.last().expect("no buckets");
    debug_assert!(
        n <= largest,
        "token count {n} exceeds largest bucket {largest}: split into chunks first (see partition())"
    );
    for &b in buckets {
        if b >= n {
            return b;
        }
    }
    largest
}

/// Partition `tokens` (T × dim, row-major) by routing decision into one
/// padded partition per expert. Token counts beyond the largest bucket are
/// split into multiple chunks of the largest bucket.
pub fn partition(
    tokens: &[f32],
    dim: usize,
    routes: &[Route],
    experts: usize,
    buckets: &[usize],
) -> Vec<Partition> {
    assert_eq!(tokens.len(), routes.len() * dim);
    let max_bucket = *buckets.last().expect("no buckets");
    let mut by_expert: Vec<Vec<usize>> = vec![Vec::new(); experts];
    for (i, r) in routes.iter().enumerate() {
        by_expert[r.expert].push(i);
    }
    let mut parts = Vec::new();
    for (e, idxs) in by_expert.into_iter().enumerate() {
        if idxs.is_empty() {
            continue;
        }
        for chunk in idxs.chunks(max_bucket) {
            let bucket = pick_bucket(buckets, chunk.len());
            let mut padded = vec![0.0f32; bucket * dim];
            for (row, &ti) in chunk.iter().enumerate() {
                padded[row * dim..(row + 1) * dim]
                    .copy_from_slice(&tokens[ti * dim..(ti + 1) * dim]);
            }
            parts.push(Partition {
                expert: e,
                indices: chunk.to_vec(),
                padded,
                bucket,
            });
        }
    }
    parts
}

/// Scatter expert outputs back into a (T × dim) buffer, scaling each token
/// by its gate value (the paper's y = G(x)·E_i(x)).
pub fn scatter(
    out: &mut [f32],
    dim: usize,
    part: &Partition,
    expert_out: &[f32],
    routes: &[Route],
) {
    assert!(expert_out.len() >= part.indices.len() * dim);
    for (row, &ti) in part.indices.iter().enumerate() {
        let g = routes[ti].gate;
        let src = &expert_out[row * dim..(row + 1) * dim];
        let dst = &mut out[ti * dim..(ti + 1) * dim];
        for (d, s) in dst.iter_mut().zip(src) {
            *d = g * s;
        }
    }
}

/// Wasted rows due to bucket padding (for the metrics endpoint).
pub fn padding_waste(parts: &[Partition]) -> f64 {
    let used: usize = parts.iter().map(|p| p.indices.len()).sum();
    let padded: usize = parts.iter().map(|p| p.bucket).sum();
    if padded == 0 {
        0.0
    } else {
        1.0 - used as f64 / padded as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::moe::router::Route;

    fn mk_routes(experts: &[usize]) -> Vec<Route> {
        experts
            .iter()
            .map(|&e| Route {
                expert: e,
                gate: 1.0,
            })
            .collect()
    }

    #[test]
    fn pick_bucket_smallest_fit() {
        let b = [16, 32, 64];
        assert_eq!(pick_bucket(&b, 1), 16);
        assert_eq!(pick_bucket(&b, 16), 16);
        assert_eq!(pick_bucket(&b, 17), 32);
        assert_eq!(pick_bucket(&b, 64), 64);
    }

    #[test]
    #[cfg(debug_assertions)]
    #[should_panic(expected = "exceeds largest bucket")]
    fn pick_bucket_rejects_oversize_in_debug() {
        pick_bucket(&[16, 32, 64], 100);
    }

    /// Regression for the chunk-split path: counts beyond the largest bucket
    /// split into largest-bucket chunks, and the remainder chunk picks the
    /// *smallest* fitting bucket, not the largest.
    #[test]
    fn chunk_split_picks_smallest_bucket_per_chunk() {
        let dim = 1;
        let routes = mk_routes(&vec![0; 11]);
        let tokens = vec![2.0; 11];
        let parts = partition(&tokens, dim, &routes, 1, &[4, 8]);
        assert_eq!(parts.len(), 2); // 8 + 3
        assert_eq!(parts[0].indices.len(), 8);
        assert_eq!(parts[0].bucket, 8);
        assert_eq!(parts[1].indices.len(), 3);
        assert_eq!(parts[1].bucket, 4, "remainder must downshift to bucket 4");
        // every token exactly once, in order, and padding rows stay zero
        let seen: Vec<usize> = parts.iter().flat_map(|p| p.indices.clone()).collect();
        assert_eq!(seen, (0..11).collect::<Vec<_>>());
        assert_eq!(&parts[1].padded[3..], &[0.0f32][..]); // bucket 4 - 3 rows
    }

    #[test]
    fn partition_covers_every_token_once() {
        let dim = 2;
        let routes = mk_routes(&[0, 1, 0, 0, 1, 0]);
        let tokens: Vec<f32> = (0..routes.len() * dim).map(|i| i as f32).collect();
        let parts = partition(&tokens, dim, &routes, 2, &[4, 8]);
        let mut seen: Vec<usize> = parts.iter().flat_map(|p| p.indices.clone()).collect();
        seen.sort();
        assert_eq!(seen, vec![0, 1, 2, 3, 4, 5]);
    }

    #[test]
    fn partition_gathers_correct_rows() {
        let dim = 2;
        let routes = mk_routes(&[1, 0]);
        let tokens = vec![10.0, 11.0, 20.0, 21.0];
        let parts = partition(&tokens, dim, &routes, 2, &[4]);
        let p0 = parts.iter().find(|p| p.expert == 0).unwrap();
        assert_eq!(&p0.padded[0..2], &[20.0, 21.0]);
        assert_eq!(p0.padded[2..], [0.0; 6]); // zero padding
    }

    #[test]
    fn oversized_partition_splits_into_chunks() {
        let dim = 1;
        let routes = mk_routes(&vec![0; 10]);
        let tokens = vec![1.0; 10];
        let parts = partition(&tokens, dim, &routes, 2, &[4]);
        assert_eq!(parts.len(), 3); // 4 + 4 + 2→bucket4
        assert!(parts.iter().all(|p| p.bucket == 4));
        let total: usize = parts.iter().map(|p| p.indices.len()).sum();
        assert_eq!(total, 10);
    }

    #[test]
    fn scatter_applies_gate() {
        let dim = 2;
        let mut routes = mk_routes(&[0, 0]);
        routes[1].gate = 0.5;
        let tokens = vec![0.0; 4];
        let parts = partition(&tokens, dim, &routes, 1, &[2]);
        let mut out = vec![0.0f32; 4];
        scatter(&mut out, dim, &parts[0], &[1.0, 2.0, 3.0, 4.0], &routes);
        assert_eq!(out, vec![1.0, 2.0, 1.5, 2.0]);
    }

    #[test]
    fn waste_metric() {
        let dim = 1;
        let routes = mk_routes(&[0, 0, 0]);
        let parts = partition(&vec![0.0; 3], dim, &routes, 1, &[4]);
        assert!((padding_waste(&parts) - 0.25).abs() < 1e-12);
    }
}
