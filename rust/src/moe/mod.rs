//! The paper's MoE machinery on the serving side: token→expert routing,
//! bucket-padded dispatch, kernel-level expert execution through the
//! `KernelRegistry`, and the latency-aware load-balancing math (Eq. 4)
//! evaluated over live traffic.

pub mod balance;
pub mod dispatch;
pub mod experts;
pub mod router;
