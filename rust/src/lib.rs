//! # ShiftAddViT — Mixture of Multiplication Primitives Towards Efficient Vision Transformers
//!
//! A three-layer Rust + JAX + Pallas reproduction of the NeurIPS 2023 paper
//! *ShiftAddViT* (You, Shi, Guo, Lin — Georgia Tech).
//!
//! Layers:
//! - **L3 (this crate)** — the serving coordinator: request router, dynamic
//!   batcher, MoE token dispatcher with latency-aware load balancing, the
//!   Eyeriss-like energy/latency model, and the PJRT runtime that executes
//!   AOT-compiled model artifacts.
//!
//!   Inside L3, the kernel layer is organized around a backend registry
//!   (`kernels::api::LinearKernel` + `kernels::registry::KernelRegistry` +
//!   `kernels::planner::Planner`): every multiplication primitive (MatMul,
//!   MatAdd, MatShift, FakeShift) is a set of named backends behind one
//!   `prepare`/`prepare_operand`/`run` contract, including row-parallel
//!   backends on the persistent `util::pool::Pool` and explicit-SIMD
//!   backends (`kernels::simd`: AVX2/NEON `core::arch` inner loops behind
//!   runtime CPU-feature detection, portable fallback everywhere,
//!   `SHIFTADD_NO_SIMD=1` override). The harness figures,
//!   the kernel-level MoE experts (`moe::experts`), the fig4/fig5 benches,
//!   and the Eyeriss op counting (`model::ops::PrimitiveStyles`) all
//!   resolve kernels through the registry; the planner memoizes the fastest
//!   backend per (primitive, shape).
//!
//!   The `infer` subsystem is a native pure-Rust forward-pass engine over
//!   those kernels (KSH-binarized LinearAdd attention, shift linears,
//!   Mult/Shift MoE MLPs), and the coordinator is engine-agnostic: the XLA
//!   artifact pipeline and the native engine both serve behind one
//!   `coordinator::backend::InferenceBackend` trait — a request-level
//!   `submit(Request) -> Ticket` / `step` / `poll` contract (the one-shot
//!   `run_batch` survives as a thin adapter) — so the full serving loop
//!   runs with zero artifacts present. `infer::session` adds KV-free
//!   streaming on the linear-attention state (`begin`/`extend`/`finish`,
//!   bit-exact under any chunking), and
//!   `coordinator::sessions::SessionEngine` continuously batches live
//!   sessions into one fused kernel dispatch per layer per step. The
//!   `bundle` subsystem packages model params + the autotuned planner
//!   table into one signed, content-addressed `.sabundle` archive that
//!   solo and fleet serving verify once and warm-start from (`--bundle`).
//! - **L2 (`python/compile/model.py`)** — the ShiftAddViT model family in JAX
//!   (PVT-style pyramid ViTs, DeiT, a GNT-style ray transformer), lowered once
//!   to HLO text by `python/compile/aot.py`.
//! - **L1 (`python/compile/kernels/`)** — Pallas kernels for the paper's
//!   customized primitives: `MatShift` (power-of-two weights), `MatAdd`
//!   (binary weights → accumulation only), and binarized linear attention.
//!
//! Python never runs on the request path: `make artifacts` lowers everything
//! to `artifacts/*.hlo.txt` and the Rust binary is self-contained afterwards.

pub mod util;
pub mod obs;
pub mod quant;
pub mod kernels;
pub mod energy;
pub mod model;
pub mod moe;
pub mod data;
pub mod bundle;
pub mod infer;
pub mod runtime;
pub mod coordinator;
pub mod fleet;
pub mod nvs;
pub mod harness;

pub use anyhow::{Error, Result};
