//! Plain host tensors — the `Send`-able currency between coordinator threads
//! and engine workers (PJRT buffers/literals stay thread-local).

use anyhow::{bail, Result};

#[derive(Clone, Debug, PartialEq)]
pub enum TensorData {
    F32(Vec<f32>),
    I32(Vec<i32>),
}

/// A dense host tensor (row-major).
#[derive(Clone, Debug, PartialEq)]
pub struct Tensor {
    pub shape: Vec<usize>,
    pub data: TensorData,
}

impl Tensor {
    pub fn f32(shape: Vec<usize>, data: Vec<f32>) -> Tensor {
        assert_eq!(shape.iter().product::<usize>(), data.len());
        Tensor {
            shape,
            data: TensorData::F32(data),
        }
    }

    pub fn i32(shape: Vec<usize>, data: Vec<i32>) -> Tensor {
        assert_eq!(shape.iter().product::<usize>(), data.len());
        Tensor {
            shape,
            data: TensorData::I32(data),
        }
    }

    pub fn zeros(shape: Vec<usize>) -> Tensor {
        let n = shape.iter().product();
        Tensor::f32(shape, vec![0.0; n])
    }

    pub fn len(&self) -> usize {
        self.shape.iter().product()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    pub fn as_f32(&self) -> Result<&[f32]> {
        match &self.data {
            TensorData::F32(v) => Ok(v),
            _ => bail!("tensor is not f32"),
        }
    }

    pub fn as_f32_mut(&mut self) -> Result<&mut Vec<f32>> {
        match &mut self.data {
            TensorData::F32(v) => Ok(v),
            _ => bail!("tensor is not f32"),
        }
    }

    pub fn as_i32(&self) -> Result<&[i32]> {
        match &self.data {
            TensorData::I32(v) => Ok(v),
            _ => bail!("tensor is not i32"),
        }
    }

    /// Argmax over the last axis (logits → class ids). Total on NaN rows:
    /// `total_cmp` ranks NaN greatest instead of panicking, so a poisoned
    /// logit row yields a (NaN) class id rather than taking the server down.
    pub fn argmax_last(&self) -> Result<Vec<usize>> {
        let d = *self.shape.last().expect("scalar tensor");
        let v = self.as_f32()?;
        Ok(v.chunks(d)
            .map(|row| {
                row.iter()
                    .enumerate()
                    .max_by(|a, b| a.1.total_cmp(b.1))
                    .map(|(i, _)| i)
                    .unwrap()
            })
            .collect())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn shape_product_checked() {
        let t = Tensor::f32(vec![2, 3], vec![0.0; 6]);
        assert_eq!(t.len(), 6);
    }

    #[test]
    #[should_panic]
    fn mismatched_shape_panics() {
        Tensor::f32(vec![2, 3], vec![0.0; 5]);
    }

    #[test]
    fn argmax_rows() {
        let t = Tensor::f32(vec![2, 3], vec![0.1, 0.9, 0.0, 0.5, 0.2, 0.3]);
        assert_eq!(t.argmax_last().unwrap(), vec![1, 0]);
    }

    #[test]
    fn argmax_tolerates_nan_rows() {
        // NaN ranks greatest under total_cmp: no panic, and the poisoned
        // entry is what gets reported.
        let t = Tensor::f32(vec![2, 3], vec![0.1, f32::NAN, 0.3, 0.5, 0.2, 0.3]);
        assert_eq!(t.argmax_last().unwrap(), vec![1, 0]);
    }

    #[test]
    fn dtype_guards() {
        let t = Tensor::i32(vec![2], vec![1, 2]);
        assert!(t.as_f32().is_err());
        assert_eq!(t.as_i32().unwrap(), &[1, 2]);
    }
}
