//! Engine worker threads: because PJRT handles are `!Send`, each worker
//! thread constructs its *own* [`Engine`] (client + compile cache) and the
//! coordinator talks to it over channels with plain [`Tensor`]s. This is the
//! substrate for the paper's parallel-expert execution (FasterMoE/DeepSpeed
//! play this role on GPU clusters).

use std::sync::mpsc;
use std::thread;

use anyhow::{anyhow, Result};

use crate::log_error;
use crate::runtime::artifact::Manifest;
use crate::runtime::engine::Engine;
use crate::runtime::tensor::Tensor;

enum Msg {
    Call {
        name: String,
        inputs: Vec<Tensor>,
        reply: mpsc::Sender<Result<Vec<Tensor>>>,
    },
    /// Pre-compile a list of artifacts (warmup).
    Preload {
        names: Vec<String>,
        reply: mpsc::Sender<Result<()>>,
    },
    Shutdown,
}

/// Handle to one engine worker thread.
pub struct EngineWorker {
    tx: mpsc::Sender<Msg>,
    handle: Option<thread::JoinHandle<()>>,
    pub id: usize,
}

/// Pending reply from a worker.
pub struct Pending {
    rx: mpsc::Receiver<Result<Vec<Tensor>>>,
}

impl Pending {
    pub fn wait(self) -> Result<Vec<Tensor>> {
        self.rx
            .recv()
            .map_err(|_| anyhow!("engine worker dropped reply"))?
    }
}

impl EngineWorker {
    pub fn spawn(id: usize, manifest: Manifest) -> EngineWorker {
        let (tx, rx) = mpsc::channel::<Msg>();
        let handle = thread::Builder::new()
            .name(format!("engine-{id}"))
            .spawn(move || {
                let engine = match Engine::new(manifest) {
                    Ok(e) => e,
                    Err(e) => {
                        log_error!("engine-{id}: failed to init: {e:#}");
                        return;
                    }
                };
                while let Ok(msg) = rx.recv() {
                    match msg {
                        Msg::Call {
                            name,
                            inputs,
                            reply,
                        } => {
                            let _ = reply.send(engine.call(&name, &inputs));
                        }
                        Msg::Preload { names, reply } => {
                            // compile AND run once (zeros): PJRT's lazy
                            // first-execution setup stays off the hot path
                            let r = names.iter().try_for_each(|n| engine.warm(n));
                            let _ = reply.send(r);
                        }
                        Msg::Shutdown => break,
                    }
                }
            })
            .expect("spawn engine worker");
        EngineWorker {
            tx,
            handle: Some(handle),
            id,
        }
    }

    /// Asynchronously execute `name` on this worker.
    pub fn call_async(&self, name: &str, inputs: Vec<Tensor>) -> Pending {
        let (reply, rx) = mpsc::channel();
        self.tx
            .send(Msg::Call {
                name: name.to_string(),
                inputs,
                reply,
            })
            .expect("engine worker gone");
        Pending { rx }
    }

    /// Synchronous call.
    pub fn call(&self, name: &str, inputs: Vec<Tensor>) -> Result<Vec<Tensor>> {
        self.call_async(name, inputs).wait()
    }

    /// Pre-compile artifacts on this worker (blocks until done).
    pub fn preload(&self, names: &[String]) -> Result<()> {
        let (reply, rx) = mpsc::channel();
        self.tx
            .send(Msg::Preload {
                names: names.to_vec(),
                reply,
            })
            .expect("engine worker gone");
        rx.recv().map_err(|_| anyhow!("worker dropped"))?
    }
}

impl Drop for EngineWorker {
    fn drop(&mut self) {
        let _ = self.tx.send(Msg::Shutdown);
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
    }
}

/// A set of engine workers — one per expert (plus one for the backbone).
pub struct EnginePool {
    pub workers: Vec<EngineWorker>,
}

impl EnginePool {
    pub fn new(n: usize, manifest: &Manifest) -> EnginePool {
        EnginePool {
            workers: (0..n.max(1))
                .map(|i| EngineWorker::spawn(i, manifest.clone()))
                .collect(),
        }
    }

    pub fn worker(&self, i: usize) -> &EngineWorker {
        &self.workers[i % self.workers.len()]
    }

    pub fn len(&self) -> usize {
        self.workers.len()
    }

    pub fn is_empty(&self) -> bool {
        self.workers.is_empty()
    }
}
