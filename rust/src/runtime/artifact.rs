//! `artifacts/manifest.json` parsing: artifact registry + serving topology.

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

use anyhow::{anyhow, Context, Result};

use crate::util::json::Json;

/// Shape+dtype of one executable input.
#[derive(Clone, Debug)]
pub struct TensorSpec {
    pub shape: Vec<usize>,
    pub dtype: String,
}

/// One lowered HLO artifact.
#[derive(Clone, Debug)]
pub struct ArtifactMeta {
    pub name: String,
    pub path: PathBuf,
    pub inputs: Vec<TensorSpec>,
    pub kind: String,
    /// raw manifest entry for kind-specific fields (block, expert, tokens…)
    pub raw: Json,
}

impl ArtifactMeta {
    pub fn field_usize(&self, key: &str) -> Option<usize> {
        self.raw.get(key).and_then(|v| v.as_usize())
    }

    pub fn field_str(&self, key: &str) -> Option<&str> {
        self.raw.get(key).and_then(|v| v.as_str())
    }
}

/// Serving topology (the MoE pipeline the coordinator runs).
#[derive(Clone, Debug)]
pub struct ServeConfig {
    pub model: String,
    pub img: usize,
    pub patch: usize,
    pub tokens: usize,
    pub dim: usize,
    pub depth: usize,
    pub num_classes: usize,
    pub batch_buckets: Vec<usize>,
    pub token_buckets: Vec<usize>,
}

/// The parsed manifest.
#[derive(Clone, Debug)]
pub struct Manifest {
    pub dir: PathBuf,
    pub models: BTreeMap<String, ArtifactMeta>,
    pub serve: Option<ServeConfig>,
    /// whole manifest document (scene definitions, meta, …)
    pub root: Json,
}

impl Manifest {
    /// Load `<dir>/manifest.json`.
    pub fn load(dir: &Path) -> Result<Manifest> {
        let path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("reading {path:?} — run `make artifacts` first"))?;
        let root = Json::parse(&text)?;
        let mut models = BTreeMap::new();
        for (name, entry) in root
            .req("models")?
            .as_obj()
            .ok_or_else(|| anyhow!("manifest 'models' is not an object"))?
        {
            let inputs = entry
                .req("inputs")?
                .as_arr()
                .ok_or_else(|| anyhow!("inputs not array"))?
                .iter()
                .map(|i| {
                    Ok(TensorSpec {
                        shape: i.req("shape")?.usize_vec()?,
                        dtype: i
                            .req("dtype")?
                            .as_str()
                            .ok_or_else(|| anyhow!("dtype not string"))?
                            .to_string(),
                    })
                })
                .collect::<Result<Vec<_>>>()?;
            models.insert(
                name.clone(),
                ArtifactMeta {
                    name: name.clone(),
                    path: dir.join(
                        entry
                            .req("path")?
                            .as_str()
                            .ok_or_else(|| anyhow!("path not string"))?,
                    ),
                    inputs,
                    kind: entry
                        .get("kind")
                        .and_then(|k| k.as_str())
                        .unwrap_or("unknown")
                        .to_string(),
                    raw: entry.clone(),
                },
            );
        }
        let serve = match root.get("serve") {
            Some(s) if s.get("model").is_some() => Some(ServeConfig {
                model: s.req("model")?.as_str().unwrap_or_default().to_string(),
                img: s.req("img")?.as_usize().unwrap(),
                patch: s.req("patch")?.as_usize().unwrap(),
                tokens: s.req("tokens")?.as_usize().unwrap(),
                dim: s.req("dim")?.as_usize().unwrap(),
                depth: s.req("depth")?.as_usize().unwrap(),
                num_classes: s.req("num_classes")?.as_usize().unwrap(),
                batch_buckets: s.req("batch_buckets")?.usize_vec()?,
                token_buckets: s.req("token_buckets")?.usize_vec()?,
            }),
            _ => None,
        };
        Ok(Manifest {
            dir: dir.to_path_buf(),
            models,
            serve,
            root,
        })
    }

    /// Default artifacts dir: `$SHIFTADDVIT_ARTIFACTS` or `./artifacts`.
    pub fn default_dir() -> PathBuf {
        std::env::var("SHIFTADDVIT_ARTIFACTS")
            .map(PathBuf::from)
            .unwrap_or_else(|_| PathBuf::from("artifacts"))
    }

    pub fn get(&self, name: &str) -> Result<&ArtifactMeta> {
        self.models
            .get(name)
            .ok_or_else(|| anyhow!("artifact '{name}' not in manifest"))
    }

    /// All artifacts of a kind, name-sorted.
    pub fn by_kind(&self, kind: &str) -> Vec<&ArtifactMeta> {
        self.models.values().filter(|m| m.kind == kind).collect()
    }

    /// True if the artifacts directory exists with a manifest (used by tests
    /// to skip gracefully when `make artifacts` has not run).
    pub fn available() -> bool {
        Self::default_dir().join("manifest.json").exists()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_synthetic_manifest() {
        let dir = std::env::temp_dir().join("savit_manifest_test");
        std::fs::create_dir_all(&dir).unwrap();
        std::fs::write(
            dir.join("manifest.json"),
            r#"{"models": {"m": {"path": "m.hlo.txt", "kind": "classifier",
                "inputs": [{"shape": [1, 4], "dtype": "float32"}], "batch": 1}},
                "serve": {}}"#,
        )
        .unwrap();
        let m = Manifest::load(&dir).unwrap();
        let a = m.get("m").unwrap();
        assert_eq!(a.inputs[0].shape, vec![1, 4]);
        assert_eq!(a.kind, "classifier");
        assert_eq!(a.field_usize("batch"), Some(1));
        assert!(m.serve.is_none());
        assert!(m.get("missing").is_err());
    }
}
