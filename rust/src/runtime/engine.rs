//! Thread-local PJRT engine: HLO-text load → compile (cached) → execute.
//!
//! Follows /opt/xla-example/load_hlo: text is the interchange format (the
//! crate's XLA 0.5.1 rejects jax≥0.5 protos with 64-bit instruction ids),
//! and AOT functions are lowered with `return_tuple=True`, so every output
//! is a tuple literal decomposed into [`Tensor`]s.

use std::cell::RefCell;
use std::collections::HashMap;
use std::rc::Rc;
use std::time::Instant;

use anyhow::{bail, Context, Result};

use crate::runtime::artifact::Manifest;
use crate::runtime::tensor::{Tensor, TensorData};

/// A compiled executable plus bookkeeping.
pub struct Compiled {
    pub exe: xla::PjRtLoadedExecutable,
    pub name: String,
    pub compile_ms: f64,
}

/// Thread-local engine: one PJRT CPU client + a compile cache.
pub struct Engine {
    client: xla::PjRtClient,
    manifest: Manifest,
    cache: RefCell<HashMap<String, Rc<Compiled>>>,
}

impl Engine {
    pub fn new(manifest: Manifest) -> Result<Engine> {
        let client = xla::PjRtClient::cpu().context("creating PJRT CPU client")?;
        Ok(Engine {
            client,
            manifest,
            cache: RefCell::new(HashMap::new()),
        })
    }

    pub fn from_default_dir() -> Result<Engine> {
        Engine::new(Manifest::load(&Manifest::default_dir())?)
    }

    pub fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    /// Load + compile an artifact by manifest name (cached).
    pub fn load(&self, name: &str) -> Result<Rc<Compiled>> {
        if let Some(c) = self.cache.borrow().get(name) {
            return Ok(c.clone());
        }
        let meta = self.manifest.get(name)?;
        let t0 = Instant::now();
        let proto = xla::HloModuleProto::from_text_file(
            meta.path
                .to_str()
                .ok_or_else(|| anyhow::anyhow!("non-utf8 path"))?,
        )
        .with_context(|| format!("parsing HLO text {:?}", meta.path))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .with_context(|| format!("compiling '{name}'"))?;
        let compiled = Rc::new(Compiled {
            exe,
            name: name.to_string(),
            compile_ms: t0.elapsed().as_secs_f64() * 1e3,
        });
        self.cache
            .borrow_mut()
            .insert(name.to_string(), compiled.clone());
        Ok(compiled)
    }

    /// Execute a loaded artifact with host tensors; returns the decomposed
    /// output tuple as host tensors.
    pub fn run(&self, compiled: &Compiled, inputs: &[Tensor]) -> Result<Vec<Tensor>> {
        let literals: Vec<xla::Literal> = inputs
            .iter()
            .map(to_literal)
            .collect::<Result<Vec<_>>>()?;
        let result = compiled
            .exe
            .execute::<xla::Literal>(&literals)
            .with_context(|| format!("executing '{}'", compiled.name))?;
        let mut tuple = result[0][0]
            .to_literal_sync()
            .context("fetching result literal")?;
        let parts = tuple.decompose_tuple().context("decomposing tuple")?;
        parts.into_iter().map(from_literal).collect()
    }

    /// Convenience: load + run by name.
    pub fn call(&self, name: &str, inputs: &[Tensor]) -> Result<Vec<Tensor>> {
        let c = self.load(name)?;
        self.run(&c, inputs)
    }

    /// Load + execute once with zero inputs — pulls PJRT's lazy first-run
    /// initialization out of the measured hot path (§Perf L3-1).
    pub fn warm(&self, name: &str) -> Result<()> {
        let meta = self.manifest.get(name)?;
        let inputs: Vec<Tensor> = meta
            .inputs
            .iter()
            .map(|spec| {
                let n: usize = spec.shape.iter().product();
                if spec.dtype.contains("int") {
                    Tensor::i32(spec.shape.clone(), vec![0; n])
                } else {
                    Tensor::f32(spec.shape.clone(), vec![0.0; n])
                }
            })
            .collect();
        let c = self.load(name)?;
        self.run(&c, &inputs)?;
        Ok(())
    }

    /// Number of compiled executables currently cached.
    pub fn cached(&self) -> usize {
        self.cache.borrow().len()
    }
}

fn to_literal(t: &Tensor) -> Result<xla::Literal> {
    let dims: Vec<i64> = t.shape.iter().map(|&d| d as i64).collect();
    let lit = match &t.data {
        TensorData::F32(v) => xla::Literal::vec1(v),
        TensorData::I32(v) => xla::Literal::vec1(v),
    };
    Ok(lit.reshape(&dims)?)
}

fn from_literal(lit: xla::Literal) -> Result<Tensor> {
    let shape = lit.array_shape()?;
    let dims: Vec<usize> = shape.dims().iter().map(|&d| d as usize).collect();
    match shape.ty() {
        xla::ElementType::F32 => Ok(Tensor::f32(dims, lit.to_vec::<f32>()?)),
        xla::ElementType::S32 => Ok(Tensor::i32(dims, lit.to_vec::<i32>()?)),
        other => bail!("unsupported output element type {other:?}"),
    }
}
