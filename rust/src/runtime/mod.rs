//! PJRT runtime: load AOT-compiled HLO-text artifacts and execute them from
//! the serving hot path.
//!
//! Thread model: the `xla` crate's `PjRtClient` is `Rc`-backed (neither
//! `Send` nor `Sync`), so an [`engine::Engine`] is strictly thread-local.
//! Cross-thread parallelism (the MoE's "experts run concurrently") is
//! provided by [`worker::EnginePool`]: each worker thread owns a private
//! client + compile cache and exchanges plain [`tensor::Tensor`] messages.

pub mod artifact;
pub mod engine;
pub mod tensor;
pub mod worker;
