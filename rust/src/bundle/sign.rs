//! Keyed-HMAC signing of bundle manifests (HMAC-SHA256, RFC 2104).
//!
//! Bundles are signed over the 32-byte SHA-256 digest of the manifest, so
//! the signature transitively covers every entry's content hash. The key is
//! a caller-supplied byte string (`--bundle-key`); [`DEFAULT_KEY`] is a
//! development key so the round-trip works out of the box — production
//! deployments pass their own.

use super::hash::{sha256, Sha256};

/// Development signing key used when the caller does not supply one.
pub const DEFAULT_KEY: &str = "shiftaddvit-dev-bundle-key";

const BLOCK: usize = 64;

/// HMAC-SHA256 over `msg` with `key` (keys longer than one block are hashed
/// first, per RFC 2104).
pub fn hmac_sha256(key: &[u8], msg: &[u8]) -> [u8; 32] {
    let mut k = [0u8; BLOCK];
    if key.len() > BLOCK {
        k[..32].copy_from_slice(&sha256(key));
    } else {
        k[..key.len()].copy_from_slice(key);
    }
    let mut ipad = [0u8; BLOCK];
    let mut opad = [0u8; BLOCK];
    for i in 0..BLOCK {
        ipad[i] = k[i] ^ 0x36;
        opad[i] = k[i] ^ 0x5c;
    }
    let mut inner = Sha256::new();
    inner.update(&ipad);
    inner.update(msg);
    let inner_digest = inner.finalize();
    let mut outer = Sha256::new();
    outer.update(&opad);
    outer.update(&inner_digest);
    outer.finalize()
}

/// Verify `sig` against HMAC-SHA256(key, msg) without early exit on the
/// first mismatching byte (XOR-fold compare).
pub fn verify_hmac(key: &[u8], msg: &[u8], sig: &[u8]) -> bool {
    if sig.len() != 32 {
        return false;
    }
    let expect = hmac_sha256(key, msg);
    let mut diff = 0u8;
    for (a, b) in expect.iter().zip(sig.iter()) {
        diff |= a ^ b;
    }
    diff == 0
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::bundle::hash::hex;

    // RFC 4231 test case 1: key = 0x0b * 20, data = "Hi There".
    #[test]
    fn rfc4231_case1() {
        let key = [0x0bu8; 20];
        assert_eq!(
            hex(&hmac_sha256(&key, b"Hi There")),
            "b0344c61d8db38535ca8afceaf0bf12b881dc200c9833da726e9376c2e32cff7"
        );
    }

    // RFC 4231 test case 2: key = "Jefe".
    #[test]
    fn rfc4231_case2() {
        assert_eq!(
            hex(&hmac_sha256(b"Jefe", b"what do ya want for nothing?")),
            "5bdcc146bf60754e6a042426089575c75a003f089d2739839dec58b964ec3843"
        );
    }

    #[test]
    fn long_key_is_hashed_first() {
        let long_key = vec![0xaau8; 131];
        let direct = hmac_sha256(&long_key, b"msg");
        let hashed = hmac_sha256(&sha256(&long_key), b"msg");
        assert_eq!(direct, hashed);
    }

    #[test]
    fn verify_accepts_and_rejects() {
        let sig = hmac_sha256(b"k", b"payload");
        assert!(verify_hmac(b"k", b"payload", &sig));
        let mut bad = sig;
        bad[13] ^= 0x01;
        assert!(!verify_hmac(b"k", b"payload", &bad));
        assert!(!verify_hmac(b"other-key", b"payload", &sig));
        assert!(!verify_hmac(b"k", b"payload", &sig[..31]));
    }
}
