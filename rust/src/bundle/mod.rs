//! Signed, content-addressed model bundles (`.sabundle`).
//!
//! The deployable artifact for the native engine: one file carrying the
//! flat params blob ([`params::FlatParams`], written by
//! `python/compile/params_io.py::export_flat`), the autotuned planner
//! table with its `cpu_features` stamp, and a manifest that SHA-256
//! content-addresses every entry ([`hash`]) and is HMAC-signed over its
//! digest ([`sign`]). `archive` packs and verifies the container; the
//! serving stack (`coordinator::backend::load_bundle`) verifies a bundle
//! once and warm-starts every fleet worker from the same loaded params and
//! pinned planner table.

pub mod archive;
pub mod hash;
pub mod params;
pub mod sign;

pub use archive::{inspect, open, pack, unpack, BundleInfo, LoadedBundle};
pub use params::{FlatParams, FlatTensor};
