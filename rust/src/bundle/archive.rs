//! `.sabundle` archive read/write: one signed, content-addressed file
//! carrying everything a worker needs to warm-start — model params, the
//! autotuned planner table, and its `cpu_features` stamp.
//!
//! ```text
//! magic  "SABUNDL1"                      (8 bytes)
//! u32 LE manifest length
//! u32 LE signature length (always 32)
//! signature bytes        HMAC-SHA256(key, sha256(manifest))
//! manifest bytes         compact JSON (see below)
//! payload bytes          every entry's content, concatenated in
//!                        manifest order, no padding
//! ```
//!
//! The manifest lists every entry with its length and SHA-256, so the
//! signature over the manifest digest transitively covers each payload
//! byte; the payload must also end exactly where the entry lengths say it
//! does, so appended junk is rejected too. Flipping any single byte in the
//! file makes `open` fail — magic/header mangling, manifest edits, and
//! signature bit-flips die at the signature check, payload flips die at the
//! per-entry content hash with the offending entry named.

use std::path::Path;

use anyhow::{bail, Context, Result};

use super::hash::{hex, sha256};
use super::params::FlatParams;
use super::sign::{hmac_sha256, verify_hmac};
use crate::kernels::simd::detect;
use crate::util::json::Json;

/// File magic for the bundle archive, version 1.
pub const MAGIC: &[u8; 8] = b"SABUNDL1";
/// `format` field every manifest must carry.
pub const FORMAT: &str = "sabundle-v1";
/// Entry name of the flat params blob.
pub const ENTRY_PARAMS: &str = "params.sap";
/// Entry name of the planner table JSON.
pub const ENTRY_TABLE: &str = "planner_table.json";

/// One manifest entry: name, payload length, payload SHA-256 (hex).
#[derive(Clone, Debug)]
pub struct EntryInfo {
    pub name: String,
    pub len: usize,
    pub sha256: String,
}

/// Header-level view of a bundle (no key needed; signature not checked).
#[derive(Clone, Debug)]
pub struct BundleInfo {
    pub digest: String,
    pub model: String,
    pub untrained: bool,
    pub cpu_features: String,
    pub entries: Vec<EntryInfo>,
}

/// A fully verified bundle: signature checked, every entry hash checked.
#[derive(Clone, Debug)]
pub struct LoadedBundle {
    /// Hex SHA-256 of the manifest — the bundle's content address.
    pub digest: String,
    pub model: String,
    pub untrained: bool,
    pub cpu_features: String,
    pub params: FlatParams,
    pub table: Json,
}

/// Write a bundle and return its hex digest.
pub fn pack(
    path: &Path,
    model: &str,
    params: &FlatParams,
    table: &Json,
    untrained: bool,
    key: &[u8],
) -> Result<String> {
    let payloads: Vec<(&str, Vec<u8>)> = vec![
        (ENTRY_PARAMS, params.to_bytes()),
        (ENTRY_TABLE, table.to_string().into_bytes()),
    ];
    let cpu = match table.get("cpu_features").and_then(|v| v.as_str()) {
        Some(s) => s.to_string(),
        None => detect::active_level().name().to_string(),
    };
    let entries: Vec<Json> = payloads
        .iter()
        .map(|(name, bytes)| {
            Json::obj(vec![
                ("name", Json::str(*name)),
                ("len", Json::num(bytes.len() as f64)),
                ("sha256", Json::str(hex(&sha256(bytes)))),
            ])
        })
        .collect();
    let manifest = Json::obj(vec![
        ("format", Json::str(FORMAT)),
        ("model", Json::str(model)),
        ("untrained", Json::Bool(untrained)),
        ("cpu_features", Json::str(cpu)),
        ("entries", Json::Arr(entries)),
    ]);
    let manifest_bytes = manifest.to_string().into_bytes();
    let digest = sha256(&manifest_bytes);
    let sig = hmac_sha256(key, &digest);

    let mut file = Vec::new();
    file.extend_from_slice(MAGIC);
    file.extend_from_slice(&(manifest_bytes.len() as u32).to_le_bytes());
    file.extend_from_slice(&(sig.len() as u32).to_le_bytes());
    file.extend_from_slice(&sig);
    file.extend_from_slice(&manifest_bytes);
    for (_, bytes) in &payloads {
        file.extend_from_slice(bytes);
    }
    std::fs::write(path, &file).with_context(|| format!("writing bundle {path:?}"))?;
    Ok(hex(&digest))
}

/// Raw structural view of a bundle file: header parsed, nothing verified.
struct RawBundle<'a> {
    sig: &'a [u8],
    manifest_bytes: &'a [u8],
    payload: &'a [u8],
}

fn parse_raw(bytes: &[u8]) -> Result<RawBundle<'_>> {
    if bytes.len() < 16 || &bytes[..8] != MAGIC {
        bail!("bad bundle magic (not a SABUNDL1 archive)");
    }
    let manifest_len = u32::from_le_bytes([bytes[8], bytes[9], bytes[10], bytes[11]]) as usize;
    let sig_len = u32::from_le_bytes([bytes[12], bytes[13], bytes[14], bytes[15]]) as usize;
    if sig_len != 32 {
        bail!("bundle signature length is {sig_len}, expected 32");
    }
    let sig_end = 16 + sig_len;
    let manifest_end = sig_end.checked_add(manifest_len).context("header overflow")?;
    if manifest_end > bytes.len() {
        bail!(
            "bundle truncated: header promises {manifest_end} bytes, file has {}",
            bytes.len()
        );
    }
    Ok(RawBundle {
        sig: &bytes[16..sig_end],
        manifest_bytes: &bytes[sig_end..manifest_end],
        payload: &bytes[manifest_end..],
    })
}

/// Fetch a string field out of a manifest-shaped JSON object.
fn req_str(j: &Json, key: &str) -> Result<String> {
    let s = j
        .req(key)?
        .as_str()
        .with_context(|| format!("manifest '{key}' is not a string"))?;
    Ok(s.to_string())
}

fn parse_manifest(manifest_bytes: &[u8]) -> Result<BundleInfo> {
    let text = std::str::from_utf8(manifest_bytes).context("bundle manifest is not utf-8")?;
    let manifest = Json::parse(text).context("bundle manifest is not valid JSON")?;
    let format = req_str(&manifest, "format")?;
    if format != FORMAT {
        bail!("unsupported bundle format '{format}' (expected '{FORMAT}')");
    }
    let model = req_str(&manifest, "model")?;
    let untrained = manifest.req("untrained")?.as_bool().context("bad 'untrained'")?;
    let cpu_features = req_str(&manifest, "cpu_features")?;
    let mut entries = Vec::new();
    let list = manifest.req("entries")?.as_arr().context("bad 'entries'")?;
    for e in list {
        entries.push(EntryInfo {
            name: req_str(e, "name")?,
            len: e.req("len")?.as_usize().context("bad entry 'len'")?,
            sha256: req_str(e, "sha256")?,
        });
    }
    Ok(BundleInfo {
        digest: hex(&sha256(manifest_bytes)),
        model,
        untrained,
        cpu_features,
        entries,
    })
}

/// Slice the payload region into per-entry byte ranges (aligned with
/// `info.entries`), enforcing that the entry lengths cover the payload
/// exactly — no missing and no trailing bytes.
fn slice_entries<'a>(info: &BundleInfo, payload: &'a [u8]) -> Result<Vec<&'a [u8]>> {
    let mut out = Vec::with_capacity(info.entries.len());
    let mut pos = 0usize;
    for e in &info.entries {
        let end = pos.checked_add(e.len).context("entry length overflow")?;
        if end > payload.len() {
            bail!("bundle entry '{}' runs past the end of the file", e.name);
        }
        out.push(&payload[pos..end]);
        pos = end;
    }
    if pos != payload.len() {
        bail!("bundle has {} trailing payload bytes", payload.len() - pos);
    }
    Ok(out)
}

/// Read header + manifest without verifying the signature or entry hashes.
pub fn inspect(path: &Path) -> Result<BundleInfo> {
    let bytes = std::fs::read(path).with_context(|| format!("reading bundle {path:?}"))?;
    let raw = parse_raw(&bytes)?;
    let info = parse_manifest(raw.manifest_bytes)?;
    slice_entries(&info, raw.payload)?;
    Ok(info)
}

/// Open and fully verify a bundle: signature over the manifest digest
/// first, then every entry's content hash.
pub fn open(path: &Path, key: &[u8]) -> Result<LoadedBundle> {
    let bytes = std::fs::read(path).with_context(|| format!("reading bundle {path:?}"))?;
    let raw = parse_raw(&bytes)?;
    let digest = sha256(raw.manifest_bytes);
    if !verify_hmac(key, &digest, raw.sig) {
        bail!("bundle signature verification failed (tampered manifest or wrong key)");
    }
    let info = parse_manifest(raw.manifest_bytes)?;
    let slices = slice_entries(&info, raw.payload)?;
    let mut params = None;
    let mut table = None;
    for (e, data) in info.entries.iter().zip(slices) {
        if hex(&sha256(data)) != e.sha256 {
            bail!("bundle entry '{}' failed its content hash", e.name);
        }
        match e.name.as_str() {
            ENTRY_PARAMS => {
                let p = FlatParams::from_bytes(data).context("decoding bundle params")?;
                params = Some(p);
            }
            ENTRY_TABLE => {
                let text = std::str::from_utf8(data).context("bundle table is not utf-8")?;
                let t = Json::parse(text).context("bundle planner table is not JSON")?;
                table = Some(t);
            }
            _ => {}
        }
    }
    let params = params.context("bundle has no 'params.sap' entry")?;
    let table = table.context("bundle has no 'planner_table.json' entry")?;
    Ok(LoadedBundle {
        digest: info.digest,
        model: info.model,
        untrained: info.untrained,
        cpu_features: info.cpu_features,
        params,
        table,
    })
}

/// Verify a bundle and write its manifest and entries into `dir`.
pub fn unpack(path: &Path, dir: &Path, key: &[u8]) -> Result<()> {
    let bytes = std::fs::read(path).with_context(|| format!("reading bundle {path:?}"))?;
    let raw = parse_raw(&bytes)?;
    let digest = sha256(raw.manifest_bytes);
    if !verify_hmac(key, &digest, raw.sig) {
        bail!("bundle signature verification failed (tampered manifest or wrong key)");
    }
    let info = parse_manifest(raw.manifest_bytes)?;
    let slices = slice_entries(&info, raw.payload)?;
    std::fs::create_dir_all(dir).with_context(|| format!("creating {dir:?}"))?;
    std::fs::write(dir.join("manifest.json"), raw.manifest_bytes)?;
    for (e, data) in info.entries.iter().zip(slices) {
        if hex(&sha256(data)) != e.sha256 {
            bail!("bundle entry '{}' failed its content hash", e.name);
        }
        std::fs::write(dir.join(&e.name), data)?;
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny_params() -> FlatParams {
        let mut p = FlatParams::new();
        p.insert("w", vec![2, 2], vec![1.0, 2.0, 3.0, 4.0]);
        p.insert("b", vec![2], vec![0.5, -0.5]);
        p
    }

    fn tiny_table() -> Json {
        Json::parse(r#"{"cpu_features": "portable", "choices": []}"#).unwrap()
    }

    #[test]
    fn pack_open_round_trip() {
        let path = std::env::temp_dir().join("savit_bundle_roundtrip_test.sabundle");
        let digest = pack(&path, "tiny", &tiny_params(), &tiny_table(), true, b"k").unwrap();
        let b = open(&path, b"k").unwrap();
        assert_eq!(b.digest, digest);
        assert_eq!(b.model, "tiny");
        assert!(b.untrained);
        assert_eq!(b.cpu_features, "portable");
        assert_eq!(b.params, tiny_params());
        assert_eq!(b.table, tiny_table());
        let info = inspect(&path).unwrap();
        assert_eq!(info.digest, digest);
        assert_eq!(info.entries.len(), 2);
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn wrong_key_is_rejected() {
        let path = std::env::temp_dir().join("savit_bundle_wrongkey_test.sabundle");
        pack(&path, "tiny", &tiny_params(), &tiny_table(), true, b"k").unwrap();
        let err = open(&path, b"other").unwrap_err().to_string();
        assert!(err.contains("signature"), "unexpected error: {err}");
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn payload_flip_names_the_entry() {
        let path = std::env::temp_dir().join("savit_bundle_flip_test.sabundle");
        pack(&path, "tiny", &tiny_params(), &tiny_table(), true, b"k").unwrap();
        let mut bytes = std::fs::read(&path).unwrap();
        let last = bytes.len() - 1; // inside planner_table.json, the final entry
        bytes[last] ^= 0x40;
        std::fs::write(&path, &bytes).unwrap();
        let err = open(&path, b"k").unwrap_err().to_string();
        assert!(err.contains("planner_table.json"), "unexpected error: {err}");
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn appended_bytes_are_rejected() {
        let path = std::env::temp_dir().join("savit_bundle_append_test.sabundle");
        pack(&path, "tiny", &tiny_params(), &tiny_table(), true, b"k").unwrap();
        let mut bytes = std::fs::read(&path).unwrap();
        bytes.push(0);
        std::fs::write(&path, &bytes).unwrap();
        let err = open(&path, b"k").unwrap_err().to_string();
        assert!(err.contains("trailing"), "unexpected error: {err}");
        std::fs::remove_file(&path).ok();
    }
}
