//! Flat binary params format (`.sap`): dotted keys → f32 tensors.
//!
//! The same byte layout is written by `python/compile/params_io.py::
//! export_flat` and read here, so trained JAX weights cross the language
//! boundary without a JSON/npz dependency on the Rust side:
//!
//! ```text
//! magic  "SAPF0001"                       (8 bytes)
//! u32 LE entry count
//! per entry, sorted by key:
//!   u16 LE key length, utf-8 key bytes
//!   u8 ndim (<= 8), then ndim x u32 LE dims
//!   product(dims) x f32 LE tensor data
//! ```
//!
//! Entries are sorted by key on both sides, so the byte stream — and hence
//! the bundle content hash — is a pure function of the tensor values.

use std::collections::BTreeMap;
use std::path::Path;

use anyhow::{bail, Context, Result};

use crate::kernels::api::RawWeights;

/// File magic for the flat params format, version 1.
pub const MAGIC: &[u8; 8] = b"SAPF0001";

const MAX_NDIM: usize = 8;

/// One named tensor: shape plus row-major f32 data.
#[derive(Clone, Debug, PartialEq)]
pub struct FlatTensor {
    pub dims: Vec<usize>,
    pub data: Vec<f32>,
}

impl FlatTensor {
    pub fn new(dims: Vec<usize>, data: Vec<f32>) -> Self {
        let expect: usize = dims.iter().product();
        assert_eq!(data.len(), expect, "tensor data does not match its dims");
        FlatTensor { dims, data }
    }
}

/// An ordered map of dotted keys to tensors with a canonical byte encoding.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct FlatParams {
    entries: BTreeMap<String, FlatTensor>,
}

impl FlatParams {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn insert(&mut self, name: &str, dims: Vec<usize>, data: Vec<f32>) {
        assert!(dims.len() <= MAX_NDIM, "tensor '{name}' has too many dims");
        self.entries.insert(name.to_string(), FlatTensor::new(dims, data));
    }

    pub fn get(&self, name: &str) -> Option<&FlatTensor> {
        self.entries.get(name)
    }

    pub fn len(&self) -> usize {
        self.entries.len()
    }

    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }

    /// Sorted tensor names.
    pub fn names(&self) -> Vec<&str> {
        self.entries.keys().map(String::as_str).collect()
    }

    /// Fetch a tensor that must exist.
    pub fn req(&self, name: &str) -> Result<&FlatTensor> {
        match self.entries.get(name) {
            Some(t) => Ok(t),
            None => bail!("params missing tensor '{name}'"),
        }
    }

    /// Fetch a 2-D tensor with the exact shape `[k, n]` as kernel weights.
    pub fn req_matrix(&self, name: &str, k: usize, n: usize) -> Result<RawWeights> {
        let t = self.req(name)?;
        if t.dims != [k, n] {
            bail!(
                "tensor '{name}' has shape {:?}, expected [{k}, {n}]",
                t.dims
            );
        }
        Ok(RawWeights::new(t.data.clone(), k, n))
    }

    /// Fetch a 1-D tensor with exactly `n` elements.
    pub fn req_vec(&self, name: &str, n: usize) -> Result<Vec<f32>> {
        let t = self.req(name)?;
        if t.dims != [n] {
            bail!("tensor '{name}' has shape {:?}, expected [{n}]", t.dims);
        }
        Ok(t.data.clone())
    }

    /// Fetch a tensor with an arbitrary exact shape, returning its data.
    pub fn req_shaped(&self, name: &str, dims: &[usize]) -> Result<Vec<f32>> {
        let t = self.req(name)?;
        if t.dims != dims {
            bail!("tensor '{name}' has shape {:?}, expected {dims:?}", t.dims);
        }
        Ok(t.data.clone())
    }

    /// Canonical byte encoding (see the module doc for the layout).
    pub fn to_bytes(&self) -> Vec<u8> {
        let mut out = Vec::new();
        out.extend_from_slice(MAGIC);
        out.extend_from_slice(&(self.entries.len() as u32).to_le_bytes());
        for (name, t) in &self.entries {
            out.extend_from_slice(&(name.len() as u16).to_le_bytes());
            out.extend_from_slice(name.as_bytes());
            out.push(t.dims.len() as u8);
            for d in &t.dims {
                out.extend_from_slice(&(*d as u32).to_le_bytes());
            }
            for v in &t.data {
                out.extend_from_slice(&v.to_le_bytes());
            }
        }
        out
    }

    /// Decode the canonical byte encoding. Every read is bounds-checked;
    /// malformed input yields an error, never a panic, and trailing bytes
    /// after the last entry are rejected.
    pub fn from_bytes(bytes: &[u8]) -> Result<Self> {
        let mut r = Reader { bytes, pos: 0 };
        let magic = r.take(8)?;
        if magic != MAGIC {
            bail!("bad params magic (not a SAPF0001 flat params blob)");
        }
        let count = r.u32()? as usize;
        let mut entries = BTreeMap::new();
        let mut prev_name: Option<String> = None;
        for _ in 0..count {
            let name_len = r.u16()? as usize;
            let name = std::str::from_utf8(r.take(name_len)?)
                .context("params entry name is not utf-8")?
                .to_string();
            if let Some(prev) = &prev_name {
                if *prev >= name {
                    bail!("params entries are not sorted by key ('{prev}' >= '{name}')");
                }
            }
            let ndim = r.u8()? as usize;
            if ndim > MAX_NDIM {
                bail!("tensor '{name}' has {ndim} dims (max {MAX_NDIM})");
            }
            let mut dims = Vec::with_capacity(ndim);
            for _ in 0..ndim {
                dims.push(r.u32()? as usize);
            }
            let numel: usize = dims.iter().product();
            let raw = r.take(numel.checked_mul(4).context("tensor size overflow")?)?;
            let data: Vec<f32> = raw
                .chunks_exact(4)
                .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
                .collect();
            prev_name = Some(name.clone());
            entries.insert(name, FlatTensor { dims, data });
        }
        if r.pos != bytes.len() {
            bail!(
                "{} trailing bytes after the last params entry",
                bytes.len() - r.pos
            );
        }
        Ok(FlatParams { entries })
    }

    pub fn save(&self, path: &Path) -> Result<()> {
        std::fs::write(path, self.to_bytes()).with_context(|| format!("writing {path:?}"))
    }

    pub fn load(path: &Path) -> Result<Self> {
        let bytes = std::fs::read(path).with_context(|| format!("reading {path:?}"))?;
        Self::from_bytes(&bytes).with_context(|| format!("decoding params in {path:?}"))
    }
}

struct Reader<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Reader<'a> {
    fn take(&mut self, n: usize) -> Result<&'a [u8]> {
        let end = self.pos.checked_add(n).context("params blob offset overflow")?;
        if end > self.bytes.len() {
            bail!(
                "params blob truncated at byte {} (wanted {n} more)",
                self.pos
            );
        }
        let out = &self.bytes[self.pos..end];
        self.pos = end;
        Ok(out)
    }

    fn u8(&mut self) -> Result<u8> {
        Ok(self.take(1)?[0])
    }

    fn u16(&mut self) -> Result<u16> {
        let b = self.take(2)?;
        Ok(u16::from_le_bytes([b[0], b[1]]))
    }

    fn u32(&mut self) -> Result<u32> {
        let b = self.take(4)?;
        Ok(u32::from_le_bytes([b[0], b[1], b[2], b[3]]))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample() -> FlatParams {
        let mut p = FlatParams::new();
        p.insert("b.vec", vec![3], vec![1.0, -2.5, 3.25]);
        p.insert("a.mat", vec![2, 2], vec![0.5, 1.5, -0.5, 4.0]);
        p.insert("c.scalar", vec![], vec![7.0]);
        p
    }

    #[test]
    fn round_trip_preserves_everything() {
        let p = sample();
        let back = FlatParams::from_bytes(&p.to_bytes()).unwrap();
        assert_eq!(p, back);
        assert_eq!(back.names(), vec!["a.mat", "b.vec", "c.scalar"]);
        assert_eq!(back.req("b.vec").unwrap().data, vec![1.0, -2.5, 3.25]);
    }

    #[test]
    fn typed_readers_enforce_shapes() {
        let p = sample();
        let m = p.req_matrix("a.mat", 2, 2).unwrap();
        assert_eq!((m.k, m.n), (2, 2));
        assert!(p.req_matrix("a.mat", 4, 1).is_err());
        assert_eq!(p.req_vec("b.vec", 3).unwrap().len(), 3);
        assert!(p.req_vec("a.mat", 4).is_err());
        assert!(p.req("missing").is_err());
    }

    #[test]
    fn malformed_blobs_error_instead_of_panicking() {
        let good = sample().to_bytes();
        assert!(FlatParams::from_bytes(&good[..good.len() - 1]).is_err());
        assert!(FlatParams::from_bytes(b"NOTMAGIC").is_err());
        let mut trailing = good.clone();
        trailing.push(0);
        assert!(FlatParams::from_bytes(&trailing).is_err());
        for cut in [0, 4, 9, 13] {
            assert!(FlatParams::from_bytes(&good[..cut]).is_err(), "cut {cut}");
        }
    }
}
