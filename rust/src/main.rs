//! `shiftaddvit` — the L3 launcher.
//!
//! ```text
//! shiftaddvit serve   [--backend native|xla] [--requests N] [--max-batch B]
//!                     [--dispatch real|modularized|dense]
//!                     [--arrival-ms X] [--config cfg.json]
//!                     [--workload classify|stream] [--stream-tokens T]
//!                     [--chunk C] [--max-live L]
//!                     [--scheduler single-phase|disaggregated]
//!                     [--prefill-budget TOKENS]
//!                     [--workers N] [--policy round-robin|least-loaded|affinity]
//!                     [--planner-table t.json] [--save-planner-table t.json]
//!                     [--bundle m.sabundle] [--bundle-key K]
//!                     [--http PORT]   (serve over HTTP instead of the
//!                                      synthetic benchmark client)
//!                     [--trace-out trace.json]  (dump Chrome trace-event
//!                                      JSON of the run, Perfetto-loadable)
//! shiftaddvit bundle  pack [--out m.sabundle] [--params p.sap]
//!                     [--planner-table t.json] [--key K]
//! shiftaddvit bundle  verify|inspect|unpack --bundle m.sabundle
//!                     [--out dir] [--key K]
//! shiftaddvit table   --id 1|3|4|6|11|12   [--model pvtv2_b0]
//! shiftaddvit fig     --id 3|4|5           [--batch 1]
//! shiftaddvit energy-report [--model pvtv2_b0]
//! shiftaddvit dispatch-viz [--samples 4]
//! shiftaddvit nvs-render --scene orchids [--img 32] [--out out/]
//! ```

use anyhow::{bail, Result};

use shiftaddvit::coordinator::config::{
    BackendKind, DispatchMode, SchedulerKind, ServerConfig, Workload,
};
use shiftaddvit::coordinator::server::serve_workload;
use shiftaddvit::fleet::policy::PolicyKind;
use shiftaddvit::energy::eyeriss::{energy, Hierarchy};
use shiftaddvit::harness::{breakdown, figures, lra, nvs, overall, scaling};
use shiftaddvit::model::config::classifier;
use shiftaddvit::model::ops::{count, Variant};
use shiftaddvit::runtime::artifact::Manifest;
use shiftaddvit::runtime::engine::Engine;
use shiftaddvit::util::cli::Args;

fn main() -> Result<()> {
    shiftaddvit::util::log::init_default(shiftaddvit::util::log::Level::Warn);
    let args = Args::parse();
    match args.subcommand.as_deref() {
        Some("serve") => cmd_serve(&args),
        Some("bundle") => cmd_bundle(&args),
        Some("table") => cmd_table(&args),
        Some("fig") => cmd_fig(&args),
        Some("energy-report") => cmd_energy(&args),
        Some("dispatch-viz") => cmd_dispatch_viz(&args),
        Some("nvs-render") => cmd_nvs_render(&args),
        _ => {
            eprintln!("{}", HELP);
            Ok(())
        }
    }
}

const HELP: &str = "usage: shiftaddvit <serve|bundle|table|fig|energy-report|dispatch-viz|nvs-render> [flags]
`serve` defaults to the native engine (no artifacts needed); the xla
backend and the nvs/dispatch-viz commands need `make artifacts` first.
`bundle pack|verify|inspect|unpack` manages signed `.sabundle` model
archives (serve with `--bundle m.sabundle`). See README.md for details";

fn manifest() -> Result<Manifest> {
    Manifest::load(&Manifest::default_dir())
}

fn cmd_serve(args: &Args) -> Result<()> {
    let mut cfg = match args.get("config") {
        Some(p) => ServerConfig::from_file(std::path::Path::new(p))?,
        None => ServerConfig::default(),
    };
    cfg.requests = args.usize_or("requests", cfg.requests)?;
    cfg.max_batch = args.usize_or("max-batch", cfg.max_batch)?;
    cfg.arrival_ms = args.f64_or("arrival-ms", cfg.arrival_ms)?;
    cfg.stream_tokens = args.usize_or("stream-tokens", cfg.stream_tokens)?;
    cfg.stream_chunk = args.usize_or("chunk", cfg.stream_chunk)?;
    cfg.max_live = args.usize_or("max-live", cfg.max_live)?;
    cfg.prefill_budget = args.usize_or("prefill-budget", cfg.prefill_budget)?;
    cfg.workers = args.usize_or("workers", cfg.workers)?;
    cfg.http_port = args.usize_or("http", cfg.http_port)?;
    if let Some(s) = args.get("scheduler") {
        cfg.scheduler = SchedulerKind::parse(s)?;
    }
    if let Some(p) = args.get("policy") {
        cfg.policy = PolicyKind::parse(p)?;
    }
    if let Some(d) = args.get("dispatch") {
        cfg.dispatch = DispatchMode::parse(d)?;
    }
    if let Some(b) = args.get("backend") {
        cfg.backend = BackendKind::parse(b)?;
    }
    if let Some(w) = args.get("workload") {
        cfg.workload = Workload::parse(w)?;
    }
    if let Some(p) = args.get("planner-table") {
        cfg.planner_table = Some(p.to_string());
    }
    if let Some(p) = args.get("save-planner-table") {
        cfg.planner_table_save = Some(p.to_string());
    }
    if let Some(p) = args.get("bundle") {
        cfg.bundle = Some(p.to_string());
    }
    if let Some(k) = args.get("bundle-key") {
        cfg.bundle_key = Some(k.to_string());
    }
    if let Some(p) = args.get("trace-out") {
        cfg.trace_out = Some(p.to_string());
    }
    if cfg.workers > 1 {
        println!(
            "serving the {} workload on the {} backend across {} workers ({})",
            cfg.workload.name(),
            cfg.backend.name(),
            cfg.workers,
            cfg.policy.name()
        );
    } else {
        println!(
            "serving the {} workload on the {} backend",
            cfg.workload.name(),
            cfg.backend.name()
        );
    }
    serve_workload(&cfg)
}

/// `bundle pack|verify|inspect|unpack`: build and manage signed,
/// content-addressed `.sabundle` model archives. `pack` with no `--params`
/// exports the deterministic seeded weights (marked untrained in the
/// manifest) and autotunes a planner table covering both the image model
/// and the streaming session shapes; `--params p.sap` packs trained
/// weights exported by `python/compile/params_io.py::export_flat`.
fn cmd_bundle(args: &Args) -> Result<()> {
    use shiftaddvit::bundle::{archive, sign, FlatParams};
    use shiftaddvit::infer::model::{ModelParams, NativeModel, NativeModelConfig};
    use shiftaddvit::infer::session::{SessionSpec, StreamAttn, StreamModel};
    use shiftaddvit::kernels::planner::Planner;
    use shiftaddvit::kernels::registry::KernelRegistry;
    use shiftaddvit::model::ops::Lin;
    use shiftaddvit::util::json::Json;
    use std::path::Path;
    use std::sync::Arc;

    fn need_bundle<'a>(args: &'a Args, verb: &str) -> Result<&'a str> {
        match args.get("bundle") {
            Some(p) => Ok(p),
            None => bail!("bundle {verb} needs --bundle PATH"),
        }
    }

    let key_text = args.get_or("key", sign::DEFAULT_KEY);
    let key = key_text.as_bytes();
    match args.positional.first().map(String::as_str) {
        Some("pack") => {
            let out = args.get_or("out", "native-tiny.sabundle");
            let cfg = NativeModelConfig::tiny(Variant::SHIFTADD_MOE);
            let model_name = cfg.spec.name;
            let (params, untrained) = match args.get("params") {
                Some(p) => (FlatParams::load(Path::new(p))?, false),
                None => (ModelParams::seeded(&cfg).to_flat(&cfg), true),
            };
            let table = match args.get("planner-table") {
                Some(p) => Json::parse(&std::fs::read_to_string(p)?)?,
                None => {
                    // Autotune every shape serving will pin: building the
                    // image model and a streaming session model logs the
                    // planner decisions both workloads need.
                    let reg = Arc::new(KernelRegistry::with_defaults());
                    let planner = Arc::new(Planner::new(reg));
                    let _img = NativeModel::from_params(cfg, Arc::clone(&planner), &params)?;
                    let _stream = StreamModel::new(
                        SessionSpec::tiny(StreamAttn::LinearAdd, Lin::Shift),
                        Arc::clone(&planner),
                    );
                    planner.to_table_json()
                }
            };
            let digest = archive::pack(
                Path::new(&out),
                model_name,
                &params,
                &table,
                untrained,
                key,
            )?;
            println!(
                "packed {out}: model {model_name} ({} weights, {} tensors) digest {digest}",
                if untrained { "seeded-untrained" } else { "trained" },
                params.len()
            );
        }
        Some("verify") => {
            let path = need_bundle(args, "verify")?;
            let b = archive::open(Path::new(path), key)?;
            println!(
                "OK {path}: model {} ({} weights, {} tensors, cpu_features {}) digest {}",
                b.model,
                if b.untrained { "seeded-untrained" } else { "trained" },
                b.params.len(),
                b.cpu_features,
                b.digest
            );
        }
        Some("inspect") => {
            let path = need_bundle(args, "inspect")?;
            let info = archive::inspect(Path::new(path))?;
            println!(
                "bundle {path}: model {} ({}) digest {}",
                info.model,
                if info.untrained { "seeded-untrained" } else { "trained" },
                info.digest
            );
            for e in &info.entries {
                println!("  {:20} {:>10} bytes  sha256 {}", e.name, e.len, e.sha256);
            }
            println!("(inspect parses the manifest only; run `bundle verify` to check hashes)");
        }
        Some("unpack") => {
            let path = need_bundle(args, "unpack")?;
            let dir = args.get_or("out", "bundle_out");
            archive::unpack(Path::new(path), Path::new(&dir), key)?;
            println!("unpacked {path} into {dir}/");
        }
        other => bail!("bundle needs a verb: pack|verify|inspect|unpack (got {other:?})"),
    }
    Ok(())
}

fn cmd_table(args: &Args) -> Result<()> {
    let id = args.get("id").unwrap_or("3");
    match id {
        "1" => figures::table1(),
        "3" => {
            // Artifact engine optional: missing latency cells fall back to
            // the native engine.
            let engine = Engine::from_default_dir().ok();
            overall::table3(engine.as_ref())?;
        }
        "4" | "6" => {
            let engine = Engine::from_default_dir()?;
            let model = args.get_or("model", if id == "4" { "pvtv2_b0" } else { "pvtv2_b1" });
            breakdown::breakdown(&engine, &model)?;
            breakdown::moe_dual_latency(engine.manifest(), args.usize_or("requests", 32)?)?;
        }
        "5" => {
            let engine = Engine::from_default_dir()?;
            nvs::table5_quality(&engine, &["orchids", "flower"], args.usize_or("img", 32)?)?;
            nvs::table5_cost();
        }
        "11" => {
            let engine = Engine::from_default_dir().ok();
            lra::table11(engine.as_ref())?;
        }
        "12" => {
            scaling::table12_analytic();
            // Wall-clock rows: XLA artifacts when present, native always.
            let engine = Engine::from_default_dir().ok();
            scaling::table12_measured(engine.as_ref())?;
        }
        other => bail!("unknown table id '{other}' (1|3|4|5|6|11|12; 7 and 13 are cargo benches)"),
    }
    Ok(())
}

fn cmd_fig(args: &Args) -> Result<()> {
    let id = args.get("id").unwrap_or("4");
    let batch = args.usize_or("batch", 1)?;
    match id {
        "3" => figures::fig3_energy_breakdown(),
        "4" => {
            figures::fig4_matshift(batch);
        }
        "5" => {
            figures::fig5_matadd(batch);
        }
        other => bail!("unknown fig id '{other}' (3|4|5)"),
    }
    Ok(())
}

fn cmd_energy(args: &Args) -> Result<()> {
    figures::table1();
    let model = args.get_or("model", "pvtv2_b0");
    let spec = classifier(&model);
    let h = Hierarchy::default();
    println!("\nper-variant energy for {}:", spec.name);
    for (name, var) in [
        ("MSA", Variant::MSA),
        ("Linear", Variant::LINEAR),
        ("LinearAdd", Variant::ADD),
        ("Add+ShiftAttn", Variant::ADD_SHIFT_ATTN),
        ("Add+ShiftBoth", Variant::ADD_SHIFT_BOTH),
        ("ShiftAdd+MoE", Variant::SHIFTADD_MOE),
    ] {
        let r = energy(&count(&spec, var), &h);
        println!(
            "  {name:16} compute {:8.2} mJ  dram {:8.2}  onchip {:8.2}  total {:8.2} mJ",
            r.compute_mj,
            r.dram_mj,
            r.onchip_mj,
            r.total_mj()
        );
    }
    Ok(())
}

fn cmd_dispatch_viz(args: &Args) -> Result<()> {
    use shiftaddvit::coordinator::metrics::Metrics;
    use shiftaddvit::coordinator::scheduler::MoePipeline;
    use shiftaddvit::data::synth_images;
    use shiftaddvit::util::image::ascii_grid;

    let m = manifest()?;
    let pipeline = MoePipeline::new(&m, DispatchMode::Real)?;
    pipeline.warmup()?;
    let samples = args.usize_or("samples", 4)?;
    let mut metrics = Metrics::default();
    for i in 0..samples {
        let s = synth_images::gen_image(9_000_000 + i as u32);
        let out = pipeline.run_batch(&s.pixels, 1, &mut metrics)?;
        let grid = (m.serve.as_ref().unwrap().tokens as f64).sqrt() as usize;
        let gt = synth_images::object_mask(&s, m.serve.as_ref().unwrap().patch);
        println!(
            "\nsample {i}: label={} ({})",
            s.label,
            synth_images::SHAPE_NAMES[s.label]
        );
        println!("router dispatch (█=Mult, ·=Shift):");
        println!("{}", ascii_grid(&out.dispatch_mask_blk0[0], grid));
        println!("ground-truth object tokens:");
        println!("{}", ascii_grid(&gt, grid));
    }
    metrics.print();
    Ok(())
}

fn cmd_nvs_render(args: &Args) -> Result<()> {
    use shiftaddvit::nvs::render::eval_scene;
    use shiftaddvit::nvs::scenes::Scene;
    use shiftaddvit::util::image::write_ppm;

    let engine = Engine::from_default_dir()?;
    let scene_name = args.get_or("scene", "orchids");
    let img = args.usize_or("img", 32)?;
    let out_dir = std::path::PathBuf::from(args.get_or("out", "out"));
    std::fs::create_dir_all(&out_dir)?;
    let scene = Scene::from_manifest(&engine.manifest().root, &scene_name)?;
    for (artifact, label, _) in nvs::NVS_LADDER {
        match eval_scene(&engine, &scene, artifact, img, 0.15) {
            Ok(e) => {
                let fname = out_dir.join(format!("{scene_name}_{artifact}.ppm"));
                write_ppm(&fname, &e.pred, img, img)?;
                println!(
                    "{label:36} PSNR {:6.2}  SSIM {:.3}  LPIPS* {:.3}  -> {fname:?}",
                    e.psnr, e.ssim, e.lpips
                );
            }
            Err(e) => println!("{label:36} unavailable ({e})"),
        }
    }
    let gt = scene.render_gt(img, 0.15);
    write_ppm(&out_dir.join(format!("{scene_name}_groundtruth.ppm")), &gt, img, img)?;
    Ok(())
}
