//! Ray-batched renderer: drives the `nvs_*` artifacts (GNT-style ray
//! transformer) over camera rays, in fixed-size ray batches (the paper
//! samples 2048 rays/iteration; our artifacts are compiled at 256).

use anyhow::Result;

use crate::nvs::scenes::{camera_rays, Scene};
use crate::runtime::engine::Engine;
use crate::runtime::tensor::Tensor;

/// Render a full image with an NVS artifact. Returns HWC RGB floats.
pub fn render(engine: &Engine, artifact: &str, img: usize, pose_angle: f32) -> Result<Vec<f32>> {
    let meta = engine.manifest().get(artifact)?;
    let rays_per_batch = meta.inputs[0].shape[0];
    let (origins, dirs) = camera_rays(img, pose_angle);
    let total = img * img;
    let compiled = engine.load(artifact)?;
    let mut out = vec![0.0f32; total * 3];
    let mut start = 0;
    while start < total {
        let n = (total - start).min(rays_per_batch);
        // pad the final batch
        let mut o = vec![0.0f32; rays_per_batch * 3];
        let mut d = vec![0.0f32; rays_per_batch * 3];
        d.iter_mut().skip(2).step_by(3).for_each(|z| *z = 1.0); // unit pad dirs
        o[..n * 3].copy_from_slice(&origins[start * 3..(start + n) * 3]);
        d[..n * 3].copy_from_slice(&dirs[start * 3..(start + n) * 3]);
        let rgb = engine.run(
            &compiled,
            &[
                Tensor::f32(vec![rays_per_batch, 3], o),
                Tensor::f32(vec![rays_per_batch, 3], d),
            ],
        )?;
        out[start * 3..(start + n) * 3].copy_from_slice(&rgb[0].as_f32()?[..n * 3]);
        start += n;
    }
    Ok(out)
}

/// Render ground truth + model prediction and score them.
pub struct SceneEval {
    pub psnr: f64,
    pub ssim: f64,
    pub lpips: f64,
    pub pred: Vec<f32>,
    pub gt: Vec<f32>,
}

pub fn eval_scene(
    engine: &Engine,
    scene: &Scene,
    artifact: &str,
    img: usize,
    pose_angle: f32,
) -> Result<SceneEval> {
    let gt = scene.render_gt(img, pose_angle);
    let pred = render(engine, artifact, img, pose_angle)?;
    Ok(SceneEval {
        psnr: crate::nvs::metrics::psnr(&pred, &gt),
        ssim: crate::nvs::metrics::ssim(&pred, &gt),
        lpips: crate::nvs::metrics::lpips_proxy(&pred, &gt, img, img),
        pred,
        gt,
    })
}
