//! Image-quality metrics for the NVS task: PSNR, SSIM [63], and an
//! LPIPS-proxy (gradient-structure distance — LPIPS itself needs a learned
//! network; the proxy preserves the ordering for our analytic scenes and is
//! documented as a substitution in DESIGN.md).

/// PSNR (dB) between two RGB float images in [0,1].
pub fn psnr(a: &[f32], b: &[f32]) -> f64 {
    assert_eq!(a.len(), b.len());
    let mse: f64 = a
        .iter()
        .zip(b)
        .map(|(x, y)| ((x - y) as f64).powi(2))
        .sum::<f64>()
        / a.len() as f64;
    -10.0 * (mse + 1e-12).log10()
}

fn to_gray(rgb: &[f32]) -> Vec<f32> {
    rgb.chunks(3)
        .map(|p| 0.299 * p[0] + 0.587 * p[1] + 0.114 * p[2])
        .collect()
}

/// Global SSIM over the luma channel (single-window variant of [63]).
pub fn ssim(a_rgb: &[f32], b_rgb: &[f32]) -> f64 {
    let a = to_gray(a_rgb);
    let b = to_gray(b_rgb);
    let n = a.len() as f64;
    let mu_a = a.iter().map(|&v| v as f64).sum::<f64>() / n;
    let mu_b = b.iter().map(|&v| v as f64).sum::<f64>() / n;
    let var_a = a.iter().map(|&v| (v as f64 - mu_a).powi(2)).sum::<f64>() / n;
    let var_b = b.iter().map(|&v| (v as f64 - mu_b).powi(2)).sum::<f64>() / n;
    let cov = a
        .iter()
        .zip(&b)
        .map(|(&x, &y)| (x as f64 - mu_a) * (y as f64 - mu_b))
        .sum::<f64>()
        / n;
    let (c1, c2) = (0.01f64.powi(2), 0.03f64.powi(2));
    ((2.0 * mu_a * mu_b + c1) * (2.0 * cov + c2))
        / ((mu_a * mu_a + mu_b * mu_b + c1) * (var_a + var_b + c2))
}

/// LPIPS-proxy: normalized L2 distance between local gradient maps
/// (edge-structure mismatch; lower = perceptually closer).
pub fn lpips_proxy(a_rgb: &[f32], b_rgb: &[f32], w: usize, h: usize) -> f64 {
    let ga = grad_mag(&to_gray(a_rgb), w, h);
    let gb = grad_mag(&to_gray(b_rgb), w, h);
    let num: f64 = ga
        .iter()
        .zip(&gb)
        .map(|(x, y)| ((x - y) as f64).powi(2))
        .sum();
    let den: f64 = ga
        .iter()
        .chain(gb.iter())
        .map(|x| (*x as f64).powi(2))
        .sum::<f64>()
        + 1e-9;
    (num / den).sqrt()
}

fn grad_mag(gray: &[f32], w: usize, h: usize) -> Vec<f32> {
    let mut g = vec![0.0f32; w * h];
    for y in 0..h.saturating_sub(1) {
        for x in 0..w.saturating_sub(1) {
            let dx = gray[y * w + x + 1] - gray[y * w + x];
            let dy = gray[(y + 1) * w + x] - gray[y * w + x];
            g[y * w + x] = (dx * dx + dy * dy).sqrt();
        }
    }
    g
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn identical_images_perfect_scores() {
        let img = vec![0.5f32; 16 * 16 * 3];
        assert!(psnr(&img, &img) > 100.0);
        assert!((ssim(&img, &img) - 1.0).abs() < 1e-9);
        assert!(lpips_proxy(&img, &img, 16, 16) < 1e-9);
    }

    #[test]
    fn noisier_is_worse() {
        let a = vec![0.5f32; 8 * 8 * 3];
        let mut b1 = a.clone();
        let mut b2 = a.clone();
        for (i, v) in b1.iter_mut().enumerate() {
            *v += if i % 2 == 0 { 0.01 } else { -0.01 };
        }
        for (i, v) in b2.iter_mut().enumerate() {
            *v += if i % 2 == 0 { 0.1 } else { -0.1 };
        }
        assert!(psnr(&a, &b1) > psnr(&a, &b2));
        assert!(ssim(&a, &b1) > ssim(&a, &b2));
    }

    #[test]
    fn psnr_known_value() {
        // uniform error of 0.1 ⇒ MSE 0.01 ⇒ PSNR 20 dB
        let a = vec![0.0f32; 300];
        let b = vec![0.1f32; 300];
        assert!((psnr(&a, &b) - 20.0).abs() < 1e-6);
    }
}
