//! Analytic scenes (LLFF substitute) — definitions come from the manifest
//! (exported by `python/compile/model_nvs.py`) so both sides ray-trace the
//! same ground truth.

use anyhow::{anyhow, Result};

use crate::util::json::Json;

#[derive(Clone, Copy, Debug)]
pub struct Sphere {
    pub c: [f32; 3],
    pub r: f32,
    pub rgb: [f32; 3],
}

#[derive(Clone, Debug)]
pub struct Scene {
    pub name: String,
    pub spheres: Vec<Sphere>,
    pub plane_col: [f32; 3],
    pub sky: [f32; 3],
}

/// The eight LLFF-analogue scene names.
pub const SCENE_NAMES: [&str; 8] = [
    "room", "fern", "leaves", "fortress", "orchids", "flower", "trex", "horns",
];

fn vec3(j: &Json) -> Result<[f32; 3]> {
    let a = j.as_arr().ok_or_else(|| anyhow!("expected array"))?;
    Ok([
        a[0].as_f64().unwrap() as f32,
        a[1].as_f64().unwrap() as f32,
        a[2].as_f64().unwrap() as f32,
    ])
}

impl Scene {
    /// Parse one scene from the manifest's `nvs_scenes` section.
    pub fn from_manifest(root: &Json, name: &str) -> Result<Scene> {
        let sc = root
            .req("nvs_scenes")?
            .get(name)
            .ok_or_else(|| anyhow!("scene '{name}' not in manifest"))?;
        let spheres = sc
            .req("spheres")?
            .as_arr()
            .ok_or_else(|| anyhow!("spheres not array"))?
            .iter()
            .map(|s| {
                let a = s.as_arr().ok_or_else(|| anyhow!("sphere not array"))?;
                Ok(Sphere {
                    c: [
                        a[0].as_f64().unwrap() as f32,
                        a[1].as_f64().unwrap() as f32,
                        a[2].as_f64().unwrap() as f32,
                    ],
                    r: a[3].as_f64().unwrap() as f32,
                    rgb: [
                        a[4].as_f64().unwrap() as f32,
                        a[5].as_f64().unwrap() as f32,
                        a[6].as_f64().unwrap() as f32,
                    ],
                })
            })
            .collect::<Result<Vec<_>>>()?;
        Ok(Scene {
            name: name.to_string(),
            spheres,
            plane_col: vec3(sc.req("plane_col")?)?,
            sky: vec3(sc.req("sky")?)?,
        })
    }

    /// Exact reference render of one ray (mirror of model_nvs.ray_trace).
    pub fn trace(&self, o: [f32; 3], d_in: [f32; 3]) -> [f32; 3] {
        let norm = (d_in[0] * d_in[0] + d_in[1] * d_in[1] + d_in[2] * d_in[2]).sqrt();
        let d = [d_in[0] / norm, d_in[1] / norm, d_in[2] / norm];
        let mut tmin = f32::INFINITY;
        // sky modulated by elevation
        let elev = d[1].clamp(0.0, 1.0);
        let mut col = [
            self.sky[0] * (0.6 + 0.4 * elev),
            self.sky[1] * (0.6 + 0.4 * elev),
            self.sky[2] * (0.6 + 0.4 * elev),
        ];
        // ground plane y = -0.5
        if d[1].abs() > 1e-6 {
            let tp = (-0.5 - o[1]) / d[1];
            if tp > 1e-3 && tp < tmin {
                let px = o[0] + tp * d[0];
                let pz = o[2] + tp * d[2];
                let checker = if ((px.floor() + pz.floor()) as i64).rem_euclid(2) == 0 {
                    1.0
                } else {
                    0.0
                };
                for c in 0..3 {
                    col[c] = self.plane_col[c] * (0.7 + 0.3 * checker);
                }
                tmin = tp;
            }
        }
        let light = {
            let l = [0.5f32, 0.8, -0.3];
            let n = (l[0] * l[0] + l[1] * l[1] + l[2] * l[2]).sqrt();
            [l[0] / n, l[1] / n, l[2] / n]
        };
        for s in &self.spheres {
            let oc = [o[0] - s.c[0], o[1] - s.c[1], o[2] - s.c[2]];
            let b = oc[0] * d[0] + oc[1] * d[1] + oc[2] * d[2];
            let cq = oc[0] * oc[0] + oc[1] * oc[1] + oc[2] * oc[2] - s.r * s.r;
            let disc = b * b - cq;
            if disc > 0.0 {
                let ts = -b - disc.sqrt();
                if ts > 1e-3 && ts < tmin {
                    let p = [o[0] + ts * d[0], o[1] + ts * d[1], o[2] + ts * d[2]];
                    let nrm = [
                        (p[0] - s.c[0]) / s.r,
                        (p[1] - s.c[1]) / s.r,
                        (p[2] - s.c[2]) / s.r,
                    ];
                    let lam = (nrm[0] * light[0] + nrm[1] * light[1] + nrm[2] * light[2])
                        .clamp(0.1, 1.0);
                    col = [s.rgb[0] * lam, s.rgb[1] * lam, s.rgb[2] * lam];
                    tmin = ts;
                }
            }
        }
        col
    }

    /// Render a full image (HWC) at the given pose.
    pub fn render_gt(&self, img: usize, pose_angle: f32) -> Vec<f32> {
        let (origins, dirs) = camera_rays(img, pose_angle);
        let mut out = vec![0.0f32; img * img * 3];
        for i in 0..img * img {
            let c = self.trace(
                [origins[i * 3], origins[i * 3 + 1], origins[i * 3 + 2]],
                [dirs[i * 3], dirs[i * 3 + 1], dirs[i * 3 + 2]],
            );
            out[i * 3..i * 3 + 3].copy_from_slice(&c);
        }
        out
    }
}

/// Pinhole camera rays (mirror of model_nvs.camera_rays): returns flat
/// (img², 3) origins and directions.
pub fn camera_rays(img: usize, pose_angle: f32) -> (Vec<f32>, Vec<f32>) {
    let (ca, sa) = (pose_angle.cos(), pose_angle.sin());
    let mut origins = vec![0.0f32; img * img * 3];
    let mut dirs = Vec::with_capacity(img * img * 3);
    for y in 0..img {
        for x in 0..img {
            let u = (x as f32 + 0.5) / img as f32 * 2.0 - 1.0;
            let v = 1.0 - (y as f32 + 0.5) / img as f32 * 2.0;
            // rotate [u, v, 1] around y: matches dirs @ rot.T in python
            let d = [u * ca + sa, v, -u * sa + ca];
            dirs.extend_from_slice(&d);
        }
    }
    let _ = &mut origins;
    (origins, dirs)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn toy_scene() -> Scene {
        Scene {
            name: "toy".into(),
            spheres: vec![Sphere {
                c: [0.0, 0.0, 3.0],
                r: 0.5,
                rgb: [1.0, 0.0, 0.0],
            }],
            plane_col: [0.3, 0.3, 0.3],
            sky: [0.5, 0.6, 0.8],
        }
    }

    #[test]
    fn center_ray_hits_sphere() {
        let s = toy_scene();
        let c = s.trace([0.0, 0.0, 0.0], [0.0, 0.0, 1.0]);
        assert!(c[0] > 0.05 && c[1] == 0.0 && c[2] == 0.0, "{c:?}");
    }

    #[test]
    fn up_ray_hits_sky() {
        let s = toy_scene();
        let c = s.trace([0.0, 0.0, 0.0], [0.0, 1.0, 0.0]);
        assert!((c[2] - 0.8).abs() < 1e-6, "{c:?}");
    }

    #[test]
    fn down_ray_hits_plane() {
        let s = toy_scene();
        let c = s.trace([0.0, 0.0, 0.0], [0.0, -1.0, 0.1]);
        assert!(c[0] == c[1] && c[1] == c[2], "{c:?}"); // gray checker
    }

    #[test]
    fn camera_rays_shapes() {
        let (o, d) = camera_rays(4, 0.0);
        assert_eq!(o.len(), 48);
        assert_eq!(d.len(), 48);
        // central pixels look roughly +z
        assert!(d[2] > 0.9);
    }

    #[test]
    fn render_gt_in_unit_range() {
        let img = toy_scene().render_gt(8, 0.1);
        assert!(img.iter().all(|v| (0.0..=1.2).contains(v)));
    }
}
