//! 3D novel-view-synthesis substrate (Table 5, Figs. 10): analytic
//! light-field scenes, the ray-batched renderer over the GNT-style
//! artifacts, and image quality metrics.

pub mod metrics;
pub mod render;
pub mod scenes;
