//! Table 1 — unit energy (pJ) and area (µm²) per operation, 45 nm CMOS
//! [33, 70]. These constants parameterize the whole Eyeriss model.

/// Arithmetic primitive kinds used across the model.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum Op {
    MultFp32,
    MultFp16,
    MultInt32,
    MultInt8,
    AddFp32,
    AddFp16,
    AddInt32,
    AddInt8,
    ShiftInt32,
    ShiftInt16,
    ShiftInt8,
}

impl Op {
    /// Unit energy in pJ (Table 1).
    pub fn energy_pj(self) -> f64 {
        match self {
            Op::MultFp32 => 3.7,
            Op::MultFp16 => 0.9,
            Op::MultInt32 => 3.1,
            Op::MultInt8 => 0.2,
            Op::AddFp32 => 1.1,
            Op::AddFp16 => 0.4,
            Op::AddInt32 => 0.1,
            Op::AddInt8 => 0.03,
            Op::ShiftInt32 => 0.13,
            Op::ShiftInt16 => 0.057,
            Op::ShiftInt8 => 0.024,
        }
    }

    /// Unit area in µm² (Table 1).
    pub fn area_um2(self) -> f64 {
        match self {
            Op::MultFp32 => 7700.0,
            Op::MultFp16 => 1640.0,
            Op::MultInt32 => 3495.0,
            Op::MultInt8 => 282.0,
            Op::AddFp32 => 4184.0,
            Op::AddFp16 => 1360.0,
            Op::AddInt32 => 137.0,
            Op::AddInt8 => 36.0,
            Op::ShiftInt32 => 157.0,
            Op::ShiftInt16 => 73.0,
            Op::ShiftInt8 => 34.0,
        }
    }

    pub fn name(self) -> &'static str {
        match self {
            Op::MultFp32 => "Mult FP32",
            Op::MultFp16 => "Mult FP16",
            Op::MultInt32 => "Mult INT32",
            Op::MultInt8 => "Mult INT8",
            Op::AddFp32 => "Add FP32",
            Op::AddFp16 => "Add FP16",
            Op::AddInt32 => "Add INT32",
            Op::AddInt8 => "Add INT8",
            Op::ShiftInt32 => "Shift INT32",
            Op::ShiftInt16 => "Shift INT16",
            Op::ShiftInt8 => "Shift INT8",
        }
    }

    pub const ALL: [Op; 11] = [
        Op::MultFp32,
        Op::MultFp16,
        Op::MultInt32,
        Op::MultInt8,
        Op::AddFp32,
        Op::AddFp16,
        Op::AddInt32,
        Op::AddInt8,
        Op::ShiftInt32,
        Op::ShiftInt16,
        Op::ShiftInt8,
    ];
}

/// A MAC in a given "compute style" — how the paper's primitives decompose
/// into Table 1 ops. Energies per *MAC-equivalent*.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum MacStyle {
    /// FP32 multiply + FP32 accumulate (baseline MatMul / Linear).
    MultFp32,
    /// Sign-masked FP32 accumulate only (MatAdd; binarized operand).
    AddFp32,
    /// INT32 accumulate only (MatAdd on quantized activations).
    AddInt32,
    /// INT32 shift + INT32 accumulate (MatShift).
    ShiftInt32,
    /// INT8 mult + INT32 accumulate (INT8-quantized dense layer).
    MultInt8,
}

impl MacStyle {
    /// Energy per MAC (compute only, pJ).
    pub fn energy_pj(self) -> f64 {
        match self {
            MacStyle::MultFp32 => Op::MultFp32.energy_pj() + Op::AddFp32.energy_pj(),
            MacStyle::AddFp32 => Op::AddFp32.energy_pj(),
            MacStyle::AddInt32 => Op::AddInt32.energy_pj(),
            MacStyle::ShiftInt32 => Op::ShiftInt32.energy_pj() + Op::AddInt32.energy_pj(),
            MacStyle::MultInt8 => Op::MultInt8.energy_pj() + Op::AddInt32.energy_pj(),
        }
    }

    /// PE area per MAC unit (µm²) — drives Table 13's same-chip-area PEs.
    pub fn area_um2(self) -> f64 {
        match self {
            MacStyle::MultFp32 => Op::MultFp32.area_um2() + Op::AddFp32.area_um2(),
            MacStyle::AddFp32 => Op::AddFp32.area_um2(),
            MacStyle::AddInt32 => Op::AddInt32.area_um2(),
            MacStyle::ShiftInt32 => Op::ShiftInt32.area_um2() + Op::AddInt32.area_um2(),
            MacStyle::MultInt8 => Op::MultInt8.area_um2() + Op::AddInt32.area_um2(),
        }
    }

    /// Bytes of operand traffic per MAC (weight side) — data-movement model.
    pub fn weight_bytes(self) -> f64 {
        match self {
            MacStyle::MultFp32 => 4.0,
            MacStyle::AddFp32 => 0.125,   // 1-bit binary operand
            MacStyle::AddInt32 => 0.125,  // 1-bit binary operand
            MacStyle::ShiftInt32 => 2.0,  // sign+exponent INT8 planes
            MacStyle::MultInt8 => 1.0,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table1_headline_ratios() {
        // Paper: shifts save up to 23.8× energy and 22.3× area vs INT32 mult.
        let e_ratio = Op::MultInt32.energy_pj() / Op::ShiftInt32.energy_pj();
        let a_ratio = Op::MultInt32.area_um2() / Op::ShiftInt32.area_um2();
        assert!((e_ratio - 23.8).abs() < 0.2, "{e_ratio}");
        assert!((a_ratio - 22.3).abs() < 0.2, "{a_ratio}");
        // Adds: up to 31.0× energy and 25.5× area savings vs mult.
        let e_add = Op::MultInt32.energy_pj() / Op::AddInt32.energy_pj();
        let a_add = Op::MultInt32.area_um2() / Op::AddInt32.area_um2();
        assert!((e_add - 31.0).abs() < 0.2, "{e_add}");
        assert!((a_add - 25.5).abs() < 0.3, "{a_add}");
        // INT8 add vs FP32 mult: ~123× (paper: "up to 196×" refers to
        // FP32 mult vs INT8 add = 3.7/0.03 ≈ 123; with area-adjusted
        // accounting they quote up to 196×). Check the raw ratio.
        assert!((Op::MultFp32.energy_pj() / Op::AddInt8.energy_pj() - 123.3).abs() < 1.0);
    }

    #[test]
    fn mac_styles_ordered_by_cost() {
        assert!(MacStyle::MultFp32.energy_pj() > MacStyle::ShiftInt32.energy_pj());
        assert!(MacStyle::ShiftInt32.energy_pj() > MacStyle::AddInt32.energy_pj());
        assert!(MacStyle::MultFp32.area_um2() > MacStyle::ShiftInt32.area_um2());
    }

    #[test]
    fn all_ops_have_positive_cost() {
        for op in Op::ALL {
            assert!(op.energy_pj() > 0.0 && op.area_um2() > 0.0, "{:?}", op);
        }
    }
}
