//! Area-constrained latency model (Table 13): under a fixed chip-area
//! budget, cheaper primitives afford *more* parallel PEs, so shift/add
//! variants gain latency even when GPU wall-clock hides it.

use crate::energy::ops::MacStyle;
use crate::model::ops::OpsBreakdown;

/// Accelerator envelope for the latency model.
#[derive(Clone, Copy, Debug)]
pub struct AreaModel {
    /// total PE-array area budget (µm²); default sized so an FP32 design
    /// gets 168 PEs (Eyeriss's 12×14 array).
    pub area_um2: f64,
    /// clock (GHz)
    pub ghz: f64,
    /// DRAM bandwidth (GB/s)
    pub dram_gbs: f64,
}

impl Default for AreaModel {
    fn default() -> Self {
        AreaModel {
            area_um2: 168.0 * (MacStyle::MultFp32.area_um2()),
            ghz: 1.0,
            dram_gbs: 25.6,
        }
    }
}

impl AreaModel {
    /// Parallel PEs affordable for a primitive style under the area budget.
    pub fn pes(&self, style: MacStyle) -> f64 {
        (self.area_um2 / style.area_um2()).floor().max(1.0)
    }

    /// Latency (ms) of one inference: the array is statically partitioned
    /// into per-primitive PE pools (heterogeneous array — the paper's
    /// "under the same chip areas" comparison). Styles execute their MACs
    /// sequentially per layer, so total compute time is Σ m_i / (PEs_i · f)
    /// with PEs_i = A_i / area_i. The optimal fixed partition minimizing
    /// that sum under Σ A_i = A is A_i ∝ √(m_i · area_i) (Lagrange), giving
    ///
    ///   T = (Σ_i √(m_i · area_i))² / (A · f)
    ///
    /// overlapped with DRAM traffic roofline-style: max(compute, memory).
    pub fn latency_ms(&self, ops: &OpsBreakdown) -> f64 {
        // aggregate per style
        let mut styles: Vec<(MacStyle, f64)> = Vec::new();
        for (s, m) in ops.all() {
            if let Some(e) = styles.iter_mut().find(|(t, _)| *t == s) {
                e.1 += m;
            } else {
                styles.push((s, m));
            }
        }
        if styles.is_empty() {
            return 0.0;
        }
        let sqrt_sum: f64 = styles
            .iter()
            .map(|(s, m)| (m * s.area_um2()).sqrt())
            .sum();
        let compute_s = sqrt_sum * sqrt_sum / (self.area_um2 * self.ghz * 1e9);
        let mem_s = (ops.weight_bytes + ops.act_bytes) / (self.dram_gbs * 1e9);
        compute_s.max(mem_s) * 1e3
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::config::classifier;
    use crate::model::ops::{count, Variant};

    #[test]
    fn fp32_array_is_168_pes() {
        let a = AreaModel::default();
        assert_eq!(a.pes(MacStyle::MultFp32) as usize, 168);
    }

    #[test]
    fn cheaper_primitives_afford_more_pes() {
        let a = AreaModel::default();
        assert!(a.pes(MacStyle::ShiftInt32) > 10.0 * a.pes(MacStyle::MultFp32));
        assert!(a.pes(MacStyle::AddInt32) > a.pes(MacStyle::ShiftInt32));
    }

    #[test]
    fn table13_shape_shift_beats_linear_beats_msa() {
        // Table 13 (PVTv2-B0): MSA 60.50 → LA+Add 15.87 → +Shift 2.77 ms.
        // We reproduce the ordering and the rough magnitudes of the gaps.
        let a = AreaModel::default();
        let spec = classifier("pvtv2_b0");
        let msa = a.latency_ms(&count(&spec, Variant::MSA));
        let add = a.latency_ms(&count(&spec, Variant::ADD));
        let shift = a.latency_ms(&count(&spec, Variant::ADD_SHIFT_BOTH));
        let moe = a.latency_ms(&count(&spec, Variant::SHIFTADD_MOE));
        assert!(msa > 2.0 * add, "msa {msa} add {add}");
        assert!(add > 2.0 * shift, "add {add} shift {shift}");
        assert!(moe > shift && moe < add, "shift {shift} moe {moe} add {add}");
    }

    #[test]
    fn memory_bound_floor() {
        // A style mix with tiny MACs but huge bytes must be memory-bound.
        use crate::model::ops::OpsBreakdown;
        let mut ops = OpsBreakdown::default();
        ops.mlp.push((MacStyle::AddInt32, 1000.0));
        ops.act_bytes = 1e9; // 1 GB
        let a = AreaModel::default();
        let ms = a.latency_ms(&ops);
        assert!(ms > 30.0, "{ms}"); // ≥ 1GB / 25.6GB/s ≈ 39 ms
    }
}
