//! Eyeriss-like accelerator energy model [12, 77]: compute energy from the
//! Table 1 op costs + data-movement energy through a four-level hierarchy
//! (DRAM → global buffer → NoC → register file), with reuse factors in the
//! style of the DNN-Chip Predictor [77].

use crate::energy::ops::MacStyle;
use crate::model::ops::OpsBreakdown;

/// Per-byte access energies (pJ/byte), 45 nm, derived from the Eyeriss
/// normalized hierarchy costs (RF : NoC : GLB : DRAM ≈ 1 : 2 : 6 : 200
/// relative to a 16-bit MAC ≈ 1 pJ ⇒ per-byte at 2 bytes/word).
#[derive(Clone, Copy, Debug)]
pub struct Hierarchy {
    pub dram_pj_b: f64,
    pub glb_pj_b: f64,
    pub noc_pj_b: f64,
    pub rf_pj_b: f64,
    /// average on-chip reuse: how many MACs each operand byte feeds from RF
    pub rf_reuse: f64,
    /// GLB reuse factor for activations
    pub glb_reuse: f64,
}

impl Default for Hierarchy {
    fn default() -> Self {
        Hierarchy {
            dram_pj_b: 100.0,
            glb_pj_b: 3.0,
            noc_pj_b: 1.0,
            rf_pj_b: 0.5,
            rf_reuse: 16.0,
            glb_reuse: 4.0,
        }
    }
}

/// Energy report for one inference (all in mJ).
#[derive(Clone, Debug)]
pub struct EnergyReport {
    pub compute_mj: f64,
    pub dram_mj: f64,
    pub onchip_mj: f64,
    /// per layer-family compute energy: (label, mJ)
    pub by_family: Vec<(String, f64)>,
}

impl EnergyReport {
    pub fn total_mj(&self) -> f64 {
        self.compute_mj + self.dram_mj + self.onchip_mj
    }
}

const PJ_TO_MJ: f64 = 1e-9;

/// Evaluate the energy of one inference described by `ops`.
pub fn energy(ops: &OpsBreakdown, h: &Hierarchy) -> EnergyReport {
    let fam = |name: &str, items: &[(MacStyle, f64)]| {
        let pj: f64 = items.iter().map(|(s, m)| s.energy_pj() * m).sum();
        (name.to_string(), pj * PJ_TO_MJ)
    };
    let families = vec![
        fam("attn_matmul", &ops.attn_matmul),
        fam("attn_linear", &ops.attn_linear),
        fam("mlp", &ops.mlp),
        fam("other", &ops.other),
    ];
    let compute_mj: f64 = families.iter().map(|(_, e)| e).sum();

    // DRAM: weights once + activations once per layer (counted in ops).
    let dram_bytes = ops.weight_bytes + ops.act_bytes;
    let dram_mj = dram_bytes * h.dram_pj_b * PJ_TO_MJ;

    // On-chip: every MAC pulls operands through GLB→NoC→RF with reuse.
    // Operand traffic ≈ macs × bytes/operand ÷ reuse at each level.
    let total_macs = ops.total_macs();
    let avg_bytes: f64 = {
        let wb: f64 = ops
            .all()
            .iter()
            .map(|(s, m)| s.weight_bytes() * m)
            .sum::<f64>();
        4.0 + wb / total_macs.max(1.0) // 4B activation + style-dependent weight
    };
    let onchip_pj = total_macs * avg_bytes
        * (h.glb_pj_b / h.glb_reuse + h.noc_pj_b / h.glb_reuse + h.rf_pj_b / h.rf_reuse);
    let onchip_mj = onchip_pj * PJ_TO_MJ;

    EnergyReport {
        compute_mj,
        dram_mj,
        onchip_mj,
        by_family: families,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::config::classifier;
    use crate::model::ops::{count, Variant};

    fn total(name: &str, v: Variant) -> f64 {
        let spec = classifier(name);
        energy(&count(&spec, v), &Hierarchy::default()).total_mj()
    }

    #[test]
    fn shiftadd_saves_energy_vs_msa() {
        // Paper Table 3: 19.4%–42.9% savings. Shape check: ShiftAddViT-MoE
        // must cost 15–60% less than the MSA baseline.
        let base = total("pvtv2_b0", Variant::MSA);
        let ours = total("pvtv2_b0", Variant::SHIFTADD_MOE);
        // (vs the *MSA* baseline the saving is larger than the paper's
        // vs-Ecoformer 19.4–42.9% band — MSA also pays quadratic attention.)
        let saving = 1.0 - ours / base;
        assert!(saving > 0.15 && saving < 0.90, "saving {saving}");
    }

    #[test]
    fn full_shift_saves_more_than_moe() {
        let moe = total("pvtv2_b0", Variant::SHIFTADD_MOE);
        let shift = total("pvtv2_b0", Variant::ADD_SHIFT_BOTH);
        assert!(shift < moe);
    }

    #[test]
    fn bigger_models_cost_more() {
        assert!(total("pvtv2_b2", Variant::MSA) > total("pvtv2_b1", Variant::MSA));
        assert!(total("pvtv2_b1", Variant::MSA) > total("pvtv2_b0", Variant::MSA));
    }

    #[test]
    fn add_reduces_attention_matmul_energy_dramatically() {
        // Fig. 3: Add layers cut MatMul energy by ~93.8% on DeiT-T.
        let spec = classifier("deit_t");
        let lin = energy(&count(&spec, Variant::LINEAR), &Hierarchy::default());
        let add = energy(&count(&spec, Variant::ADD), &Hierarchy::default());
        let e_lin = lin.by_family[0].1;
        let e_add = add.by_family[0].1;
        assert!(e_add < 0.1 * e_lin, "{e_add} vs {e_lin}");
    }

    #[test]
    fn report_components_nonnegative() {
        let spec = classifier("pvtv2_b0");
        let r = energy(&count(&spec, Variant::SHIFTADD_MOE), &Hierarchy::default());
        assert!(r.compute_mj > 0.0 && r.dram_mj > 0.0 && r.onchip_mj > 0.0);
        assert!(r.total_mj() > r.compute_mj);
    }
}
