//! Eyeriss-like analytical energy/latency model — the paper's hardware
//! evaluation substrate (Table 1 op costs, Fig. 3 energy breakdowns,
//! Tables 3/5/11 energy columns, Table 13 area-constrained latency).

pub mod area;
pub mod eyeriss;
pub mod ops;
