//! Row-parallel blocked backends executing on the persistent
//! [`crate::util::pool::Pool`] — the perf headline of the registry redesign:
//! the MoE Shift expert (and any large-`m` caller) finally exploits the
//! worker pool instead of running single-threaded.
//!
//! Parallelization is by contiguous row ranges: each pool job computes a
//! row-range core (`matshift_fast_rows` / `matadd_pm1_rows`, or the simd
//! cores for the `*/simd` backends) over its chunk against the `Arc`-shared
//! prepared weights, and the results are stitched back in order. Per-row
//! accumulation order is identical to the serial kernels, so the parallel
//! backends are *bit-exact* vs `matshift/planes` and `matadd/bitplane`
//! (asserted by the property suite).
//!
//! The scheduling skeleton is shared: [`run_matadd_rows`],
//! [`run_matshift_rows`], and [`run_grouped_matadd_forked`] take the row
//! core as a function pointer, so `*/rowpar` (serial cores) and `*/simd`
//! (vectorized cores, `kernels::simd`) are the same dispatch logic around
//! different inner loops.
//!
//! Do not call these backends from inside pool jobs themselves: a job that
//! blocks on `Pool::scatter` can deadlock once every worker is blocked the
//! same way.

use std::sync::{Arc, OnceLock};

use crate::energy::ops::MacStyle;
use crate::kernels::api::{
    check_grouped_shapes, LinearKernel, Operand, PreparedWeights, Primitive, RawWeights,
};
use crate::kernels::backends::{MatAddBitplane, MatShiftPlanes, SHIFT_TOL};
use crate::kernels::matadd::PackedPm1;
use crate::kernels::matshift::{ShiftPlanes, PREC};
use crate::kernels::{matadd, matshift};
use crate::util::pool::Pool;

/// Below this many rows the pool dispatch overhead dominates and the
/// backends fall back to the serial row core inline.
pub const MIN_PAR_ROWS: usize = 32;

static POOL: OnceLock<Pool> = OnceLock::new();

/// The process-wide kernel worker pool, spawned on first use and sized to
/// the available hardware parallelism.
pub fn shared_pool() -> &'static Pool {
    POOL.get_or_init(|| {
        let n = std::thread::available_parallelism()
            .map(|n| n.get())
            .unwrap_or(4);
        Pool::new(n)
    })
}

/// Split `m` rows into at most `chunks` contiguous `(r0, r1)` ranges of
/// near-equal size (the last may be short).
pub fn row_chunks(m: usize, chunks: usize) -> Vec<(usize, usize)> {
    if m == 0 {
        return Vec::new();
    }
    let per = m.div_ceil(chunks.clamp(1, m));
    (0..m)
        .step_by(per)
        .map(|r0| (r0, (r0 + per).min(m)))
        .collect()
}

/// A ±1 MatAdd row-range core: rows `r0..r1` of the operand against the
/// packed weights, `(r1-r0)×n` output. Both the serial and simd cores fit.
pub type MatAddRowsFn = fn(&[f32], &PackedPm1, usize, usize) -> Vec<f32>;

/// A MatShift row-range core: rows `r0..r1` of the INT8-widened operand
/// against the shift planes, `(r1-r0)×n` i64 accumulators.
pub type MatShiftRowsFn = fn(&[i32], &ShiftPlanes, usize, usize) -> Vec<i64>;

/// Shared ±1 MatAdd execution skeleton: unpack weights/operand, run the
/// row core inline below [`MIN_PAR_ROWS`], otherwise fan contiguous row
/// chunks across the shared pool and stitch results back in order.
pub fn run_matadd_rows(
    rows_fn: MatAddRowsFn,
    who: &'static str,
    w: &PreparedWeights,
    x: &Operand,
    out: &mut [f32],
) {
    let packed = match w {
        PreparedWeights::Pm1(p) => p.clone(),
        other => panic!("{who}: expected pm1 weights, got {}", other.variant_name()),
    };
    let (xv, m) = match x {
        Operand::F32 { m, k, x } => {
            assert_eq!(*k, packed.k, "{who}: operand k mismatch");
            (x.clone(), *m)
        }
        Operand::Int8 { .. } => panic!("{who}: expected f32 operand"),
    };
    let n = packed.n;
    assert_eq!(out.len(), m * n, "{who}: output is not m*n");
    let pool = shared_pool();
    if m < MIN_PAR_ROWS || pool.len() == 1 {
        out.copy_from_slice(&rows_fn(&xv, &packed, 0, m));
        return;
    }
    let ranges = row_chunks(m, pool.len() * 2);
    let jobs: Vec<_> = ranges
        .iter()
        .map(|&(r0, r1)| {
            let packed = packed.clone();
            let xv = xv.clone();
            move || rows_fn(&xv, &packed, r0, r1)
        })
        .collect();
    let results = pool.scatter(jobs);
    for ((r0, _), chunk) in ranges.into_iter().zip(results) {
        out[r0 * n..r0 * n + chunk.len()].copy_from_slice(&chunk);
    }
}

/// Shared MatShift execution skeleton: accept either operand form
/// (quantizing f32 through the one shared path), run the row core inline
/// below [`MIN_PAR_ROWS`], otherwise fan row chunks across the pool;
/// dequantize the i64 accumulators with the operand scale.
pub fn run_matshift_rows(
    rows_fn: MatShiftRowsFn,
    who: &'static str,
    w: &PreparedWeights,
    x: &Operand,
    out: &mut [f32],
) {
    let planes = match w {
        PreparedWeights::Planes(p) => p.clone(),
        other => panic!(
            "{who}: expected planes weights, got {}",
            other.variant_name()
        ),
    };
    let (xq, m, scale) = match x {
        Operand::Int8 { m, k, xq, scale } => {
            assert_eq!(*k, planes.rows, "{who}: operand k mismatch");
            (xq.clone(), *m, *scale)
        }
        Operand::F32 { m, k, x } => {
            // Route through the one quantization path every shift
            // backend shares, so calibration changes stay in sync.
            assert_eq!(*k, planes.rows, "{who}: operand k mismatch");
            match Operand::quantized(x, *m, *k) {
                Operand::Int8 { xq, scale, .. } => (xq, *m, scale),
                Operand::F32 { .. } => unreachable!("quantized() yields Int8"),
            }
        }
    };
    let n = planes.cols;
    assert_eq!(out.len(), m * n, "{who}: output is not m*n");
    let s = scale / (PREC as f32).exp2();
    let pool = shared_pool();
    if m < MIN_PAR_ROWS || pool.len() == 1 {
        let acc = rows_fn(&xq, &planes, 0, m);
        for (o, &a) in out.iter_mut().zip(&acc) {
            *o = a as f32 * s;
        }
        return;
    }
    let ranges = row_chunks(m, pool.len() * 2);
    let jobs: Vec<_> = ranges
        .iter()
        .map(|&(r0, r1)| {
            let planes = planes.clone();
            let xq = xq.clone();
            move || rows_fn(&xq, &planes, r0, r1)
        })
        .collect();
    let results = pool.scatter(jobs);
    for ((r0, _), acc) in ranges.into_iter().zip(results) {
        let dst = &mut out[r0 * n..r0 * n + acc.len()];
        for (o, &a) in dst.iter_mut().zip(&acc) {
            *o = a as f32 * s;
        }
    }
}

/// Shared grouped fork/join skeleton for ±1 MatAdd backends: all `G` small
/// groups in ONE pool fork/join (one job per group running the row core),
/// instead of the default per-group run loop. Each job executes the row
/// core over its own group's operand and pm1 weights, so per-row
/// accumulation order — and therefore the bit-exactness contract vs
/// `matadd/bitplane` — is unchanged. Groups that are individually large
/// enough to row-chunk (`m ≥ MIN_PAR_ROWS`) go through `kernel.run`
/// instead, which spreads each group's rows across the whole pool —
/// grouping those would strand a big group on a single worker.
pub fn run_grouped_matadd_forked(
    kernel: &dyn LinearKernel,
    rows_fn: MatAddRowsFn,
    who: &'static str,
    ws: &[PreparedWeights],
    x: &[f32],
    m: usize,
    out: &mut [f32],
) {
    let (g, k, n) = check_grouped_shapes(ws, x.len(), out.len(), m);
    if m >= MIN_PAR_ROWS {
        for (gi, w) in ws.iter().enumerate() {
            let op = kernel.prepare_operand(&x[gi * m * k..(gi + 1) * m * k], m, k);
            kernel.run(w, &op, &mut out[gi * m * n..(gi + 1) * m * n]);
        }
        return;
    }
    let packed: Vec<_> = ws
        .iter()
        .map(|w| match w {
            PreparedWeights::Pm1(p) => {
                assert_eq!(p.k, k, "{who}: grouped operand k mismatch");
                p.clone()
            }
            other => panic!("{who}: expected pm1 weights, got {}", other.variant_name()),
        })
        .collect();
    let pool = shared_pool();
    if g == 1 || g * m < MIN_PAR_ROWS || pool.len() == 1 {
        for (gi, p) in packed.iter().enumerate() {
            let chunk = rows_fn(&x[gi * m * k..(gi + 1) * m * k], p, 0, m);
            out[gi * m * n..(gi + 1) * m * n].copy_from_slice(&chunk);
        }
        return;
    }
    let xs = Arc::new(x.to_vec());
    let jobs: Vec<_> = packed
        .iter()
        .enumerate()
        .map(|(gi, p)| {
            let p = p.clone();
            let xs = xs.clone();
            move || rows_fn(&xs[gi * m * k..(gi + 1) * m * k], &p, 0, m)
        })
        .collect();
    for (gi, chunk) in pool.scatter(jobs).into_iter().enumerate() {
        out[gi * m * n..(gi + 1) * m * n].copy_from_slice(&chunk);
    }
}

/// `matshift/rowpar` — row-parallel blocked MatShift on the shared pool.
pub struct MatShiftRowPar;

impl LinearKernel for MatShiftRowPar {
    fn primitive(&self) -> Primitive {
        Primitive::MatShift
    }

    fn backend(&self) -> &'static str {
        "rowpar"
    }

    fn mac_style(&self) -> MacStyle {
        MacStyle::ShiftInt32
    }

    fn tolerance(&self) -> f32 {
        SHIFT_TOL
    }

    /// Same deployment format as the serial `matshift/planes` backend —
    /// delegated so the bit-exactness contract cannot drift.
    fn prepare(&self, w: &RawWeights) -> PreparedWeights {
        MatShiftPlanes.prepare(w)
    }

    fn prepare_operand(&self, x: &[f32], m: usize, k: usize) -> Operand {
        MatShiftPlanes.prepare_operand(x, m, k)
    }

    fn run(&self, w: &PreparedWeights, x: &Operand, out: &mut [f32]) {
        run_matshift_rows(matshift::matshift_fast_rows, "matshift/rowpar", w, x, out);
    }
}

/// `matadd/rowpar` — row-parallel ±1 MatAdd on the shared pool.
pub struct MatAddRowPar;

impl LinearKernel for MatAddRowPar {
    fn primitive(&self) -> Primitive {
        Primitive::MatAdd
    }

    fn backend(&self) -> &'static str {
        "rowpar"
    }

    fn mac_style(&self) -> MacStyle {
        MacStyle::AddInt32
    }

    /// Same deployment format as the serial `matadd/bitplane` backend —
    /// delegated so the bit-exactness contract cannot drift.
    fn prepare(&self, w: &RawWeights) -> PreparedWeights {
        MatAddBitplane.prepare(w)
    }

    fn run(&self, w: &PreparedWeights, x: &Operand, out: &mut [f32]) {
        run_matadd_rows(matadd::matadd_pm1_rows, "matadd/rowpar", w, x, out);
    }

    /// Fused grouped dispatch: all `G` small groups in ONE pool fork/join
    /// (see [`run_grouped_matadd_forked`] for the scheduling contract).
    fn run_grouped(&self, ws: &[PreparedWeights], x: &[f32], m: usize, out: &mut [f32]) {
        run_grouped_matadd_forked(self, matadd::matadd_pm1_rows, "matadd/rowpar", ws, x, m, out);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn row_chunks_cover_exactly() {
        for (m, c) in [(10usize, 3usize), (1, 8), (32, 32), (100, 7), (0, 4)] {
            let r = row_chunks(m, c);
            let total: usize = r.iter().map(|(a, b)| b - a).sum();
            assert_eq!(total, m, "m={m} c={c}");
            let mut prev = 0;
            for &(a, b) in &r {
                assert_eq!(a, prev);
                assert!(b > a);
                prev = b;
            }
            assert!(r.len() <= c.max(1));
        }
    }

    #[test]
    fn shared_pool_is_reused() {
        let a = shared_pool() as *const Pool;
        let b = shared_pool() as *const Pool;
        assert_eq!(a, b);
        assert!(shared_pool().len() >= 1);
    }
}
