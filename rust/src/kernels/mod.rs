//! Pure-Rust CPU kernels for the paper's multiplication primitives, behind a
//! unified trait/registry/planner API.
//!
//! # Architecture
//!
//! - [`api::LinearKernel`] — the one trait every backend implements:
//!   `prepare` (one-time weight pack/quantize into a deployment format),
//!   `prepare_operand` (per-call activation layout, where INT8 quantization
//!   happens), and `run` under a uniform `(m, k, n)` shape contract. Each
//!   backend self-describes its Eyeriss `MacStyle` and its numeric
//!   tolerance vs the dense oracle.
//! - [`registry::KernelRegistry`] — named backends per [`api::Primitive`],
//!   addressed as `"primitive/backend"`. Defaults: `matmul/{naive,blocked}`,
//!   `matadd/{ref,packed,bitplane,rowpar,simd}`,
//!   `matshift/{ref,planes,rowpar,simd}`, `fakeshift/{ref,cached}`.
//!   Registering a new backend automatically enrolls it in the fig4/fig5
//!   sweeps and the property suite.
//! - [`planner::Planner`] — benchmarks-or-looks-up the fastest backend per
//!   (primitive, shape), memoizes the choice, and records measurements;
//!   `pin` installs offline-autotuned choices without measuring, `force`
//!   overrides a whole primitive for per-backend experiments, and saved
//!   lookup tables are stamped with the host CPU feature set.
//! - [`parallel`] — the row-parallel `*/rowpar` backends executing on the
//!   persistent `util::Pool` (bit-exact vs their serial counterparts), plus
//!   the shared pooled-row/grouped scheduling skeletons.
//! - [`simd`] — explicit-SIMD `*/simd` backends: AVX2/NEON `core::arch`
//!   inner loops behind runtime CPU-feature detection (override:
//!   `SHIFTADD_NO_SIMD=1`), with a portable chunked fallback on every
//!   platform; bit-exact vs `matadd/ref` / `matshift/ref`.
//!
//! These are the *true-arithmetic* counterparts of the L1 Pallas kernels:
//! MatShift really executes integer `<<`/`>>` on INT8/INT32 operands, MatAdd
//! really executes sign-masked accumulation with no multiply in the inner
//! loop. They serve three purposes: the Fig. 4/5 (and 7/8) micro-benchmarks,
//! oracles/property tests for the quantization semantics shared with the
//! Pallas kernels, and the kernel-level MoE expert execution in
//! `moe::experts`.
//!
//! # Legacy free functions (deprecated)
//!
//! The per-module free functions (`matmul::matmul_f32`, `matadd::matadd_pm1`,
//! `matshift::matshift_fast`, …) are the implementation layer the backends
//! wrap. They remain public for one release as thin compatibility shims, but
//! all in-repo call sites (harness figures, MoE experts, fig4/fig5 benches,
//! Eyeriss op counting) now resolve kernels through the registry — new code
//! must do the same so planner dispatch and the property suite see it.
//! Deprecation is doc-level for this release rather than `#[deprecated]`:
//! the oracle property suite and the backends themselves legitimately call
//! the free functions, and the attribute would trip CI's `-D warnings` gate
//! on those internal uses. The attribute lands when the shims are dropped
//! next release.

pub mod api;
pub mod backends;
pub mod fakeshift;
pub mod matadd;
pub mod matmul;
pub mod matshift;
pub mod parallel;
pub mod planner;
pub mod registry;
pub mod simd;

pub use api::{LinearKernel, Operand, PreparedWeights, Primitive, RawWeights};
pub use planner::{Planner, Shape};
pub use registry::KernelRegistry;

/// Row-major matrix view helpers shared by the kernels.
pub fn idx(r: usize, c: usize, cols: usize) -> usize {
    r * cols + c
}
