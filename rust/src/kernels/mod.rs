//! Pure-Rust CPU kernels for the paper's multiplication primitives.
//!
//! These are the *true-arithmetic* counterparts of the L1 Pallas kernels:
//! MatShift really executes integer `<<`/`>>` on INT8/INT32 operands, MatAdd
//! really executes sign-masked accumulation with no multiply in the inner
//! loop. They serve two purposes:
//!
//! 1. the Fig. 4/5 (and 7/8) micro-benchmarks — speedups of MatShift/MatAdd
//!    over MatMul and FakeShift baselines across the paper's PVT shapes,
//! 2. oracles/property tests for the quantization semantics shared with the
//!    Pallas kernels.

pub mod fakeshift;
pub mod matadd;
pub mod matmul;
pub mod matshift;

/// Row-major matrix view helpers shared by the kernels.
pub fn idx(r: usize, c: usize, cols: usize) -> usize {
    r * cols + c
}
