//! The planner: benchmark-or-look-up the fastest backend per
//! (primitive, shape) and record every decision.
//!
//! `choose` memoizes per shape: the first call for a (primitive, m×k×n)
//! triple times every registered backend on synthetic data (prepared
//! formats built outside the timed region, exactly like deployment) and
//! caches the winner; later calls are a map lookup. `pin` installs a choice
//! without measuring — the hook for offline-autotuned lookup tables, the
//! ROADMAP's per-shape dispatch direction — and `force` overrides every
//! shape of one primitive (the per-backend experiment hook the
//! `native_engine` bench sweeps kernel families with).
//!
//! Lookup tables are **portable across hosts**: [`table_json`] stamps the
//! CPU feature set the table was autotuned under (`cpu_features`, see
//! `kernels::simd::detect`), and [`Planner::pin_table_json`] skips —
//! with a warning, instead of failing — entries whose backend (or
//! primitive) this registry does not have, so a table pinned on one host
//! degrades to lazy re-planning of the affected shapes rather than
//! crashing at startup or dispatch time.

use std::collections::HashMap;
use std::path::Path;
use std::sync::{Arc, Mutex};
use std::time::Instant;

use anyhow::{anyhow, Result};

use crate::kernels::api::{LinearKernel, Primitive, RawWeights};
use crate::kernels::registry::KernelRegistry;
use crate::kernels::simd::detect;
use crate::log_warn;
use crate::util::json::Json;
use crate::util::rng::XorShift64;

/// An `(m, k, n)` problem shape.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub struct Shape {
    pub m: usize,
    pub k: usize,
    pub n: usize,
}

impl Shape {
    pub fn new(m: usize, k: usize, n: usize) -> Shape {
        Shape { m, k, n }
    }
}

/// One planning decision, kept for reporting and the bench JSON dumps.
#[derive(Clone, Debug)]
pub struct Choice {
    pub primitive: Primitive,
    pub shape: Shape,
    /// winning backend name (within the primitive)
    pub backend: String,
    /// (backend id, best-of-reps ms) per candidate; empty for pinned entries
    pub measured_ms: Vec<(String, f64)>,
}

/// Fastest-backend selector over a shared [`KernelRegistry`].
pub struct Planner {
    registry: Arc<KernelRegistry>,
    cache: Mutex<HashMap<(Primitive, Shape), Arc<dyn LinearKernel>>>,
    /// whole-primitive overrides installed by [`Planner::force`]
    forced: Mutex<HashMap<Primitive, Arc<dyn LinearKernel>>>,
    log: Mutex<Vec<Choice>>,
    reps: usize,
}

impl Planner {
    pub fn new(registry: Arc<KernelRegistry>) -> Planner {
        Planner {
            registry,
            cache: Mutex::new(HashMap::new()),
            forced: Mutex::new(HashMap::new()),
            log: Mutex::new(Vec::new()),
            reps: 3,
        }
    }

    pub fn registry(&self) -> &KernelRegistry {
        &self.registry
    }

    /// The fastest backend for `(primitive, shape)`: cached lookup, or a
    /// one-shot benchmark over every registered backend of the primitive.
    ///
    /// Concurrent callers racing on the same uncached shape may benchmark
    /// redundantly, but exactly one decision wins: the first insert is kept
    /// (losers adopt it) and only the winning measurement is logged, so
    /// [`Planner::choices`] holds at most one entry per decided shape.
    pub fn choose(&self, primitive: Primitive, shape: Shape) -> Arc<dyn LinearKernel> {
        if let Some(k) = self.forced_for(primitive, shape) {
            return k;
        }
        if let Some(k) = self.cache.lock().unwrap().get(&(primitive, shape)) {
            return k.clone();
        }
        let (chosen, choice) = self.benchmark(primitive, shape);
        let mut cache = self.cache.lock().unwrap();
        if let Some(winner) = cache.get(&(primitive, shape)) {
            return winner.clone(); // lost the race: keep the first decision
        }
        cache.insert((primitive, shape), chosen.clone());
        drop(cache);
        self.log.lock().unwrap().push(choice);
        chosen
    }

    /// Shape-family-aware [`Planner::choose`] for the fused grouped
    /// dispatches, whose row count `m` follows the model geometry
    /// (`heads·(head_dim+1)`) while `(k, n)` stays fixed: an exact
    /// `(primitive, m×k×n)` hit is returned as usual; otherwise a cached or
    /// pinned decision for the same `(primitive, k, n)` at the **nearest**
    /// `m` is adopted and cached for this shape (so tables saved afterwards
    /// carry it), and only an entirely unknown `(k, n)` family falls back
    /// to a live benchmark. This is what lets a pinned lookup table answer
    /// every row count of a family it has seen once — including tables
    /// written before the fused geometry existed, which pinned the
    /// per-head `m = head_dim` shape — with zero startup benchmarking.
    pub fn choose_batched(&self, primitive: Primitive, shape: Shape) -> Arc<dyn LinearKernel> {
        if let Some(k) = self.forced_for(primitive, shape) {
            return k;
        }
        // Exact hit, family lookup, and cache insert all happen under ONE
        // cache lock so a racing `choose` on the same shape can neither be
        // overwritten nor double-logged (the one-decision-per-shape
        // invariant `choose` documents).
        let adopted = {
            let mut cache = self.cache.lock().unwrap();
            if let Some(k) = cache.get(&(primitive, shape)) {
                return k.clone();
            }
            let family = cache
                .iter()
                .filter(|((p, s), _)| *p == primitive && s.k == shape.k && s.n == shape.n)
                .min_by_key(|((_, s), _)| s.m.abs_diff(shape.m))
                .map(|(_, k)| k.clone());
            if let Some(kernel) = &family {
                cache.insert((primitive, shape), kernel.clone());
            }
            family
        };
        match adopted {
            Some(kernel) => {
                self.log.lock().unwrap().push(Choice {
                    primitive,
                    shape,
                    backend: kernel.backend().to_string(),
                    measured_ms: Vec::new(),
                });
                kernel
            }
            None => self.choose(primitive, shape),
        }
    }

    /// Force **every** `choose`/`choose_batched` for `primitive` — any
    /// shape, decided or not — to return `backend`: the per-backend
    /// experiment hook (the `native_engine` bench sweeps kernel families
    /// end to end with it). Each forced shape is cached and logged once as
    /// a pinned-style choice, so saved tables record what actually ran.
    /// Panics if the backend is not registered.
    pub fn force(&self, primitive: Primitive, backend: &str) {
        let k = self
            .registry
            .get(primitive, backend)
            .unwrap_or_else(|| panic!("no backend {}/{backend}", primitive.name()));
        self.forced.lock().unwrap().insert(primitive, k);
    }

    /// Resolve a [`Planner::force`] override for one shape, caching and
    /// logging the first sighting of each shape (same bookkeeping as
    /// [`Planner::pin`], so tables saved afterwards carry it).
    fn forced_for(&self, primitive: Primitive, shape: Shape) -> Option<Arc<dyn LinearKernel>> {
        let kernel = self.forced.lock().unwrap().get(&primitive)?.clone();
        let mut cache = self.cache.lock().unwrap();
        let fresh = match cache.get(&(primitive, shape)) {
            Some(cached) => cached.id() != kernel.id(),
            None => true,
        };
        if fresh {
            cache.insert((primitive, shape), kernel.clone());
            drop(cache);
            let mut log = self.log.lock().unwrap();
            // replace any superseded decision for this shape, so choices()
            // (and hence saved tables and the chosen_backend gauge) keep
            // the one-entry-per-decided-shape invariant under force
            log.retain(|c| !(c.primitive == primitive && c.shape == shape));
            log.push(Choice {
                primitive,
                shape,
                backend: kernel.backend().to_string(),
                measured_ms: Vec::new(),
            });
        }
        Some(kernel)
    }

    /// Install a backend for a shape without measuring (lookup tables,
    /// reproducible runs). Panics if the backend is not registered.
    pub fn pin(&self, primitive: Primitive, shape: Shape, backend: &str) {
        let k = self
            .registry
            .get(primitive, backend)
            .unwrap_or_else(|| panic!("no backend {}/{backend}", primitive.name()));
        self.cache
            .lock()
            .unwrap()
            .insert((primitive, shape), k.clone());
        self.log.lock().unwrap().push(Choice {
            primitive,
            shape,
            backend: backend.to_string(),
            measured_ms: Vec::new(),
        });
    }

    /// Every decision made so far (benchmarked and pinned), in order.
    pub fn choices(&self) -> Vec<Choice> {
        self.log.lock().unwrap().clone()
    }

    // ---- offline-autotuned lookup tables ---------------------------------

    /// Serialize every decision to a lookup-table JSON (`{"choices":
    /// [{"primitive", "m", "k", "n", "backend"}, ...]}`) — the offline
    /// artifact [`Planner::load_table`] pins on startup, removing
    /// first-request benchmarking entirely.
    pub fn to_table_json(&self) -> Json {
        table_json(&self.choices())
    }

    /// Pin every entry of a lookup-table JSON. Returns the number of pinned
    /// choices. Entries naming a backend (or primitive) this registry does
    /// not have are **skipped with a warning** instead of failing the whole
    /// load: a table autotuned on another host — see the table's
    /// `cpu_features` stamp — must degrade to lazy re-planning of the
    /// affected shapes, never crash at startup or dispatch time.
    /// Structurally malformed tables (missing keys, wrong types) still
    /// fail.
    pub fn pin_table_json(&self, table: &Json) -> Result<usize> {
        if let Some(stamp) = table.get("cpu_features").and_then(|v| v.as_str()) {
            let host = detect::active_level().name();
            if stamp != host {
                log_warn!(
                    "planner: table was autotuned with cpu_features={stamp}, this host runs \
                     {host}; choices may be suboptimal and unknown backends will re-plan"
                );
            }
        }
        let rows = table
            .req("choices")?
            .as_arr()
            .ok_or_else(|| anyhow!("'choices' is not an array"))?;
        let mut pinned = 0usize;
        let mut skipped = 0usize;
        for row in rows {
            let prim_name = row
                .req("primitive")?
                .as_str()
                .ok_or_else(|| anyhow!("'primitive' is not a string"))?;
            let backend = row
                .req("backend")?
                .as_str()
                .ok_or_else(|| anyhow!("'backend' is not a string"))?;
            let shape = Shape::new(
                row.req("m")?.as_usize().ok_or_else(|| anyhow!("bad m"))?,
                row.req("k")?.as_usize().ok_or_else(|| anyhow!("bad k"))?,
                row.req("n")?.as_usize().ok_or_else(|| anyhow!("bad n"))?,
            );
            let Some(primitive) = Primitive::parse(prim_name) else {
                log_warn!(
                    "planner: skipping table entry for unknown primitive '{prim_name}' \
                     (shape {}x{}x{} will re-plan)",
                    shape.m, shape.k, shape.n
                );
                skipped += 1;
                continue;
            };
            if self.registry.get(primitive, backend).is_none() {
                log_warn!(
                    "planner: skipping table entry {}/{backend} — not in this registry \
                     (shape {}x{}x{} will re-plan)",
                    primitive.name(),
                    shape.m,
                    shape.k,
                    shape.n
                );
                skipped += 1;
                continue;
            }
            self.pin(primitive, shape, backend);
            pinned += 1;
        }
        if skipped > 0 {
            log_warn!(
                "planner: {skipped} table entries skipped; affected shapes re-plan on first use"
            );
        }
        Ok(pinned)
    }

    /// Write the current decisions to `path` as a lookup table.
    pub fn save_table(&self, path: &Path) -> Result<()> {
        std::fs::write(path, self.to_table_json().to_string())?;
        Ok(())
    }

    /// Load a lookup table written by [`Planner::save_table`] and pin every
    /// entry. Returns the number of pinned choices.
    pub fn load_table(&self, path: &Path) -> Result<usize> {
        let text = std::fs::read_to_string(path)?;
        self.pin_table_json(&Json::parse(&text)?)
    }

    fn benchmark(&self, primitive: Primitive, shape: Shape) -> (Arc<dyn LinearKernel>, Choice) {
        let candidates = self.registry.for_primitive(primitive);
        assert!(
            !candidates.is_empty(),
            "no backends registered for {}",
            primitive.name()
        );
        let mut rng = XorShift64::new(0xBE7C4);
        let x = rng.normals(shape.m * shape.k);
        let raw = RawWeights::new(rng.normals(shape.k * shape.n), shape.k, shape.n);
        let mut out = vec![0.0f32; shape.m * shape.n];
        let mut best: Option<(f64, Arc<dyn LinearKernel>)> = None;
        let mut measured = Vec::new();
        for kernel in candidates {
            let w = kernel.prepare(&raw);
            let op = kernel.prepare_operand(&x, shape.m, shape.k);
            kernel.run(&w, &op, &mut out); // warmup (pool spawn, caches)
            let mut best_ms = f64::INFINITY;
            for _ in 0..self.reps {
                let t0 = Instant::now();
                kernel.run(&w, &op, &mut out);
                best_ms = best_ms.min(t0.elapsed().as_secs_f64() * 1e3);
            }
            measured.push((kernel.id(), best_ms));
            let improves = match &best {
                None => true,
                Some((current, _)) => best_ms < *current,
            };
            if improves {
                best = Some((best_ms, kernel.clone()));
            }
        }
        let (_, chosen) = best.expect("at least one candidate");
        let choice = Choice {
            primitive,
            shape,
            backend: chosen.backend().to_string(),
            measured_ms: measured,
        };
        (chosen, choice)
    }
}

/// Lookup-table JSON for an arbitrary decision list (lets serving code dump
/// a backend's choices without holding the [`Planner`] itself). The table
/// is stamped with the CPU feature set it was autotuned under
/// (`cpu_features`), so a load on a differently-equipped host can warn and
/// degrade instead of silently mis-pinning.
pub fn table_json(choices: &[Choice]) -> Json {
    let rows = choices
        .iter()
        .map(|c| {
            Json::obj(vec![
                ("primitive", Json::str(c.primitive.name())),
                ("m", Json::num(c.shape.m as f64)),
                ("k", Json::num(c.shape.k as f64)),
                ("n", Json::num(c.shape.n as f64)),
                ("backend", Json::str(c.backend.as_str())),
            ])
        })
        .collect();
    Json::obj(vec![
        ("cpu_features", Json::str(detect::active_level().name())),
        ("choices", Json::Arr(rows)),
    ])
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn choose_caches_per_shape() {
        let planner = Planner::new(Arc::new(KernelRegistry::with_defaults()));
        let shape = Shape::new(6, 5, 4);
        let a = planner.choose(Primitive::MatAdd, shape);
        let b = planner.choose(Primitive::MatAdd, shape);
        assert_eq!(a.id(), b.id());
        assert_eq!(
            planner.choices().len(),
            1,
            "second choose must hit the cache"
        );
        assert_eq!(planner.choices()[0].measured_ms.len(), 5);
    }

    #[test]
    fn pin_overrides_benchmarking() {
        let planner = Planner::new(Arc::new(KernelRegistry::with_defaults()));
        let shape = Shape::new(8, 8, 8);
        planner.pin(Primitive::MatShift, shape, "rowpar");
        assert_eq!(
            planner.choose(Primitive::MatShift, shape).id(),
            "matshift/rowpar"
        );
        assert!(planner.choices()[0].measured_ms.is_empty());
    }

    #[test]
    #[should_panic(expected = "no backend")]
    fn pin_unknown_backend_panics() {
        let planner = Planner::new(Arc::new(KernelRegistry::with_defaults()));
        planner.pin(Primitive::MatMul, Shape::new(1, 1, 1), "gpu");
    }

    #[test]
    fn table_roundtrip_pins_choices_without_benchmarking() {
        let a = Planner::new(Arc::new(KernelRegistry::with_defaults()));
        a.choose(Primitive::MatMul, Shape::new(8, 4, 4));
        a.choose(Primitive::MatAdd, Shape::new(3, 5, 7));
        let table = a.to_table_json();

        let b = Planner::new(Arc::new(KernelRegistry::with_defaults()));
        assert_eq!(b.pin_table_json(&table).unwrap(), 2);
        let log = b.choices();
        assert_eq!(log.len(), 2);
        assert!(
            log.iter().all(|c| c.measured_ms.is_empty()),
            "pinned entries must not re-benchmark"
        );
        // pinned decisions answer choose() without measuring
        let k = b.choose(Primitive::MatMul, Shape::new(8, 4, 4));
        assert_eq!(k.backend(), log[0].backend);
        assert_eq!(b.choices().len(), 2, "choose() after pin must hit cache");
    }

    #[test]
    fn table_file_roundtrip() {
        let dir = std::env::temp_dir().join("savit_planner_table_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("table.json");
        let a = Planner::new(Arc::new(KernelRegistry::with_defaults()));
        a.choose(Primitive::MatShift, Shape::new(16, 8, 8));
        a.save_table(&path).unwrap();
        let b = Planner::new(Arc::new(KernelRegistry::with_defaults()));
        assert_eq!(b.load_table(&path).unwrap(), 1);
        assert_eq!(
            b.choose(Primitive::MatShift, Shape::new(16, 8, 8)).id(),
            a.choose(Primitive::MatShift, Shape::new(16, 8, 8)).id()
        );
    }

    #[test]
    fn table_with_unknown_backend_skips_and_replans() {
        // Portability contract: a table pinned on a host whose registry had
        // a backend this one lacks (e.g. a different CPU feature set, per
        // the cpu_features stamp) must load anyway — the bogus entries are
        // skipped and their shapes fall back to live planning, instead of
        // failing the whole load (or worse, failing at dispatch time).
        let p = Planner::new(Arc::new(KernelRegistry::with_defaults()));
        let table = Json::parse(
            r#"{"cpu_features": "avx512-unicorn", "choices": [
                {"primitive": "matmul", "m": 6, "k": 5, "n": 4, "backend": "gpu"},
                {"primitive": "hologram", "m": 1, "k": 1, "n": 1, "backend": "ref"},
                {"primitive": "matadd", "m": 3, "k": 5, "n": 7, "backend": "bitplane"}
            ]}"#,
        )
        .unwrap();
        assert_eq!(p.pin_table_json(&table).unwrap(), 1, "only the valid row pins");
        // the pinned row answers without measuring
        let k = p.choose(Primitive::MatAdd, Shape::new(3, 5, 7));
        assert_eq!(k.id(), "matadd/bitplane");
        assert!(p.choices().iter().all(|c| c.measured_ms.is_empty()));
        // the skipped shape re-plans live instead of crashing
        let k = p.choose(Primitive::MatMul, Shape::new(6, 5, 4));
        assert_eq!(k.primitive(), Primitive::MatMul);
        assert!(
            p.choices().iter().any(|c| !c.measured_ms.is_empty()),
            "skipped shape must have been re-benchmarked"
        );
    }

    #[test]
    fn table_with_malformed_entry_still_fails() {
        let p = Planner::new(Arc::new(KernelRegistry::with_defaults()));
        let table =
            Json::parse(r#"{"choices": [{"primitive": "matmul", "m": 1, "k": 1}]}"#).unwrap();
        assert!(p.pin_table_json(&table).is_err(), "missing keys are structural");
    }

    #[test]
    fn table_json_is_stamped_with_cpu_features() {
        let p = Planner::new(Arc::new(KernelRegistry::with_defaults()));
        p.pin(Primitive::MatAdd, Shape::new(2, 2, 2), "simd");
        let table = p.to_table_json();
        let stamp = table.get("cpu_features").and_then(|v| v.as_str()).unwrap();
        assert_eq!(
            stamp,
            crate::kernels::simd::active_level().name(),
            "stamp must reflect the level the choices were made under"
        );
        // and a fresh planner accepts its own stamp
        let q = Planner::new(Arc::new(KernelRegistry::with_defaults()));
        assert_eq!(q.pin_table_json(&table).unwrap(), 1);
    }

    #[test]
    fn force_overrides_every_shape_of_a_primitive() {
        let planner = Planner::new(Arc::new(KernelRegistry::with_defaults()));
        // decide one shape normally first: force must override it too
        let before = planner.choose(Primitive::MatAdd, Shape::new(6, 5, 4));
        assert_eq!(before.primitive(), Primitive::MatAdd);
        planner.force(Primitive::MatAdd, "simd");
        assert_eq!(
            planner.choose(Primitive::MatAdd, Shape::new(6, 5, 4)).id(),
            "matadd/simd"
        );
        assert_eq!(
            planner
                .choose_batched(Primitive::MatAdd, Shape::new(60, 5, 4))
                .id(),
            "matadd/simd"
        );
        // other primitives are untouched
        assert_eq!(
            planner.choose(Primitive::MatMul, Shape::new(4, 4, 4)).primitive(),
            Primitive::MatMul
        );
        // forced decisions are logged unmeasured, so saved tables carry
        // them — and a superseded benchmark entry is REPLACED, keeping one
        // log entry per decided shape (the gauge/table invariant)
        let shape_entries = planner
            .choices()
            .iter()
            .filter(|c| c.primitive == Primitive::MatAdd && c.shape == Shape::new(6, 5, 4))
            .count();
        assert_eq!(shape_entries, 1, "force must not duplicate a shape's log entry");
        // the fresh 60×5×4 shape always logs an unmeasured forced entry;
        // 6×5×4 is replaced only if the benchmark had picked another
        // backend (if simd won outright, its measured entry stands)
        let forced_logged = planner
            .choices()
            .iter()
            .filter(|c| c.backend == "simd" && c.measured_ms.is_empty())
            .count();
        assert!(forced_logged >= 1, "forced choices must be logged");
        // every decided matadd shape resolves to the forced backend
        assert!(planner
            .choices()
            .iter()
            .filter(|c| c.primitive == Primitive::MatAdd)
            .all(|c| c.backend == "simd"));
    }

    #[test]
    #[should_panic(expected = "no backend")]
    fn force_unknown_backend_panics() {
        let planner = Planner::new(Arc::new(KernelRegistry::with_defaults()));
        planner.force(Primitive::MatAdd, "gpu");
    }

    #[test]
    fn choose_batched_reuses_shape_family_without_benchmarking() {
        let planner = Planner::new(Arc::new(KernelRegistry::with_defaults()));
        let small = Shape::new(6, 16, 8);
        let chosen = planner.choose(Primitive::MatAdd, small);
        assert_eq!(planner.choices().len(), 1);
        // same (k, n) family at a larger row count: adopt, don't re-measure
        let big = planner.choose_batched(Primitive::MatAdd, Shape::new(60, 16, 8));
        assert_eq!(big.id(), chosen.id());
        let log = planner.choices();
        assert_eq!(log.len(), 2);
        assert!(
            log[1].measured_ms.is_empty(),
            "family fallback must not benchmark"
        );
        // exact repeat hits the cache without a new log entry
        planner.choose_batched(Primitive::MatAdd, Shape::new(60, 16, 8));
        assert_eq!(planner.choices().len(), 2);
        // an entirely unknown (k, n) family still benchmarks
        planner.choose_batched(Primitive::MatAdd, Shape::new(60, 9, 8));
        assert!(!planner.choices()[2].measured_ms.is_empty());
    }

    #[test]
    fn table_roundtrip_plans_fused_shape_family_without_benchmarking() {
        // A pinned table must answer every row count of a (k, n) family it
        // has seen once. The compat case that matters: a table written
        // before the fused image path existed pinned the per-head
        // m = head_dim MatAdd shape; a model built today requests the
        // fused m = heads·(head_dim+1) shape — same (tokens, bits) family —
        // and must plan off the pinned row with zero startup benchmarking.
        let dir = std::env::temp_dir().join("savit_planner_fused_table_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("fused.json");
        let a = Planner::new(Arc::new(KernelRegistry::with_defaults()));
        // pre-fused-path table: per-head shape (m = hd, k = tokens, n = bits)
        a.choose(Primitive::MatAdd, Shape::new(16, 64, 16));
        a.save_table(&path).unwrap();

        let b = Planner::new(Arc::new(KernelRegistry::with_defaults()));
        assert_eq!(b.load_table(&path).unwrap(), 1);
        // today's construction shape: m = heads·(hd+1) = 2·17
        let k = b.choose_batched(Primitive::MatAdd, Shape::new(2 * 17, 64, 16));
        assert_eq!(k.backend(), a.choices()[0].backend);
        assert!(
            b.choices().iter().all(|c| c.measured_ms.is_empty()),
            "loaded table must answer the fused shape family without measuring"
        );
        // and the adopted decision round-trips into b's own saved table
        let table = b.to_table_json();
        let rows = table.get("choices").unwrap().as_arr().unwrap();
        assert!(
            rows.iter()
                .any(|r| r.get("m").and_then(|m| m.as_usize()) == Some(34)),
            "adopted fused shape missing from the saved table"
        );
    }

    #[test]
    fn choices_record_the_winner() {
        let planner = Planner::new(Arc::new(KernelRegistry::with_defaults()));
        let chosen = planner.choose(Primitive::MatMul, Shape::new(4, 4, 4));
        let log = planner.choices();
        assert_eq!(log[0].backend, chosen.backend());
        assert!(log[0].measured_ms.iter().all(|(_, ms)| *ms >= 0.0));
    }
}
