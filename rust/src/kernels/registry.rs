//! The backend registry: named [`LinearKernel`] implementations per
//! primitive, addressable as `"primitive/backend"` (e.g. `"matshift/rowpar"`).
//!
//! Call sites resolve kernels here instead of hard-wiring free functions, so
//! a new backend registered in [`KernelRegistry::with_defaults`] is picked
//! up by the fig4/fig5 sweeps, the property suite, and the
//! [`crate::kernels::planner::Planner`] without any call-site edits.

use std::sync::Arc;

use crate::kernels::api::{LinearKernel, Operand, PreparedWeights, Primitive};
use crate::kernels::backends::{
    FakeShiftCached, FakeShiftRef, MatAddBitplane, MatAddPacked, MatAddRef, MatMulBlocked,
    MatMulNaive, MatShiftPlanes, MatShiftRef,
};
use crate::kernels::parallel::{MatAddRowPar, MatShiftRowPar};
use crate::kernels::simd::{MatAddSimd, MatShiftSimd};
use crate::obs::trace as otrace;

/// Run `kernel` on one prepared operand, bracketing the call in a span
/// named after the kernel's `"primitive/backend"` id (dispatch shape as
/// args) parented on the ambient tracing context — this is where a traced
/// request's span tree bottoms out at actual kernel work. With tracing
/// disabled the wrapper is a direct call (one relaxed atomic load).
pub fn dispatch(kernel: &dyn LinearKernel, w: &PreparedWeights, x: &Operand, out: &mut [f32]) {
    if !otrace::enabled() {
        return kernel.run(w, x, out);
    }
    let mut span = otrace::span(&kernel.id(), otrace::current());
    span.arg("m", (out.len() / w.n().max(1)).to_string());
    span.arg("k", w.k().to_string());
    span.arg("n", w.n().to_string());
    kernel.run(w, x, out);
}

/// [`dispatch`] for one fused grouped call ([`LinearKernel::run_grouped`]):
/// one span covers all `ws.len()` groups, which is exactly the fused
/// image-path attention's amortization story rendered in the trace.
pub fn dispatch_grouped(
    kernel: &dyn LinearKernel,
    ws: &[PreparedWeights],
    x: &[f32],
    m: usize,
    out: &mut [f32],
) {
    if !otrace::enabled() {
        return kernel.run_grouped(ws, x, m, out);
    }
    let mut span = otrace::span(&kernel.id(), otrace::current());
    span.arg("groups", ws.len().to_string());
    span.arg("m", m.to_string());
    if let Some(w) = ws.first() {
        span.arg("k", w.k().to_string());
        span.arg("n", w.n().to_string());
    }
    kernel.run_grouped(ws, x, m, out);
}

/// An ordered collection of backends (registration order is enumeration
/// order, so defaults list reference kernels before deployment ones).
pub struct KernelRegistry {
    backends: Vec<Arc<dyn LinearKernel>>,
}

impl KernelRegistry {
    /// An empty registry (embedders compose their own backend set).
    pub fn new() -> KernelRegistry {
        KernelRegistry {
            backends: Vec::new(),
        }
    }

    /// Every built-in backend: matmul/{naive,blocked}, matadd/{ref,packed,
    /// bitplane,rowpar,simd}, matshift/{ref,planes,rowpar,simd},
    /// fakeshift/{ref,cached}. The `*/simd` backends always register —
    /// their portable fallback runs everywhere; runtime detection (and the
    /// `SHIFTADD_NO_SIMD` override) picks the instruction set per process.
    pub fn with_defaults() -> KernelRegistry {
        let mut r = KernelRegistry::new();
        r.register(Arc::new(MatMulNaive));
        r.register(Arc::new(MatMulBlocked));
        r.register(Arc::new(MatAddRef));
        r.register(Arc::new(MatAddPacked));
        r.register(Arc::new(MatAddBitplane));
        r.register(Arc::new(MatAddRowPar));
        r.register(Arc::new(MatAddSimd));
        r.register(Arc::new(MatShiftRef));
        r.register(Arc::new(MatShiftPlanes));
        r.register(Arc::new(MatShiftRowPar));
        r.register(Arc::new(MatShiftSimd));
        r.register(Arc::new(FakeShiftRef));
        r.register(Arc::new(FakeShiftCached));
        r
    }

    /// Register a backend; an existing backend with the same id is replaced
    /// in place (so embedders can override a default).
    pub fn register(&mut self, backend: Arc<dyn LinearKernel>) {
        let id = backend.id();
        if let Some(slot) = self.backends.iter_mut().find(|b| b.id() == id) {
            *slot = backend;
        } else {
            self.backends.push(backend);
        }
    }

    pub fn get(&self, primitive: Primitive, backend: &str) -> Option<Arc<dyn LinearKernel>> {
        self.backends
            .iter()
            .find(|b| b.primitive() == primitive && b.backend() == backend)
            .cloned()
    }

    /// Lookup by `"primitive/backend"` id.
    pub fn lookup(&self, id: &str) -> Option<Arc<dyn LinearKernel>> {
        let (p, b) = id.split_once('/')?;
        self.get(Primitive::parse(p)?, b)
    }

    /// All backends registered for one primitive, in registration order.
    pub fn for_primitive(&self, primitive: Primitive) -> Vec<Arc<dyn LinearKernel>> {
        self.backends
            .iter()
            .filter(|b| b.primitive() == primitive)
            .cloned()
            .collect()
    }

    pub fn iter(&self) -> impl Iterator<Item = &Arc<dyn LinearKernel>> {
        self.backends.iter()
    }

    pub fn ids(&self) -> Vec<String> {
        self.backends.iter().map(|b| b.id()).collect()
    }

    pub fn len(&self) -> usize {
        self.backends.len()
    }

    pub fn is_empty(&self) -> bool {
        self.backends.is_empty()
    }
}

impl Default for KernelRegistry {
    fn default() -> Self {
        KernelRegistry::with_defaults()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn defaults_cover_every_primitive() {
        let r = KernelRegistry::with_defaults();
        assert!(r.len() >= 13);
        for p in Primitive::ALL {
            assert!(!r.for_primitive(p).is_empty(), "{}", p.name());
        }
    }

    #[test]
    fn lookup_by_id() {
        let r = KernelRegistry::with_defaults();
        assert_eq!(r.lookup("matshift/rowpar").unwrap().id(), "matshift/rowpar");
        assert_eq!(r.lookup("matadd/packed").unwrap().backend(), "packed");
        assert!(r.lookup("matmul/does-not-exist").is_none());
        assert!(r.lookup("conv/naive").is_none());
        assert!(r.lookup("garbage").is_none());
    }

    #[test]
    fn register_replaces_same_id() {
        let mut r = KernelRegistry::with_defaults();
        let before = r.len();
        r.register(Arc::new(MatMulNaive));
        assert_eq!(r.len(), before, "same-id registration must replace");
    }

    #[test]
    fn ids_are_primitive_slash_backend() {
        let r = KernelRegistry::with_defaults();
        for id in r.ids() {
            let (p, b) = id.split_once('/').expect("id shape");
            assert!(Primitive::parse(p).is_some(), "{id}");
            assert!(!b.is_empty(), "{id}");
        }
    }
}
