//! MatShift — true integer bitwise-shift matmul (Fig. 4/7).
//!
//! Inputs are INT8-quantized activations widened to i32; weights are the
//! (sign, exponent) INT8 planes from [`crate::quant::pow2`]. The inner loop
//! is `acc ± (x << p)` / `acc ± (x >> -p)` — **no multiply instruction** —
//! exactly the paper's kernel. Output accumulates in i64 and dequantizes
//! with `x_scale · 2^0` (the weight is exactly a power of two, folded into
//! the shift).

use crate::quant::int8::Int8Quant;
use crate::quant::pow2::Pow2Weights;

/// Integer core: `xq (m×k) i32 @ (sign,exp) (k×n) → acc (m×n) i64`.
///
/// Negative exponents would truncate in integer arithmetic, so activations
/// are pre-shifted left by `PREC` bits and the result carries a 2^-PREC
/// factor — fixed-point with `PREC` fractional bits.
pub const PREC: i8 = 8;

pub fn matshift_i64(
    xq: &[i32],
    w: &Pow2Weights,
    m: usize,
) -> Vec<i64> {
    let (k, n) = (w.rows, w.cols);
    assert_eq!(xq.len(), m * k);
    let mut acc = vec![0i64; m * n];
    for r in 0..m {
        let xrow = &xq[r * k..(r + 1) * k];
        let orow = &mut acc[r * n..(r + 1) * n];
        for kk in 0..k {
            let xv = xrow[kk] as i64;
            if xv == 0 {
                continue;
            }
            let srow = &w.sign[kk * n..(kk + 1) * n];
            let erow = &w.exp[kk * n..(kk + 1) * n];
            for c in 0..n {
                let sh = erow[c] + PREC; // ≥ 0 for exp ≥ -PREC
                let v = xv << sh;
                // sign flip = conditional negate, not a multiply
                orow[c] += if srow[c] > 0 { v } else { -v };
            }
        }
    }
    acc
}

/// Full pipeline: f32 activations → INT8 → shift-accumulate → f32 output.
pub fn matshift_f32(x: &[f32], w: &Pow2Weights, m: usize) -> Vec<f32> {
    let q = Int8Quant::calibrate(x);
    let xq: Vec<i32> = q.quantize(x).iter().map(|&v| v as i32).collect();
    let acc = matshift_i64(&xq, w, m);
    let scale = q.scale / (PREC as f32).exp2();
    acc.iter().map(|&a| a as f32 * scale).collect()
}

/// Deployment layout for the shift planes: per-weight shift amount
/// (exponent + PREC, always ≥ 0) and a negate mask (0 or -1) — the inner
/// loop becomes branchless `((x << sh) ^ neg) - neg` + add, vectorizable
/// with variable-shift SIMD (§Perf L3-4).
#[derive(Clone, Debug)]
pub struct ShiftPlanes {
    pub rows: usize,
    pub cols: usize,
    pub sh: Vec<i32>,
    pub neg: Vec<i32>,
}

impl ShiftPlanes {
    pub fn from_pow2(w: &Pow2Weights) -> ShiftPlanes {
        ShiftPlanes {
            rows: w.rows,
            cols: w.cols,
            sh: w.exp.iter().map(|&p| (p + PREC) as i32).collect(),
            neg: w.sign.iter().map(|&s| if s < 0 { -1 } else { 0 }).collect(),
        }
    }
}

/// Branchless blocked MatShift: K is tiled so a per-tile i32 accumulator
/// (|x·2^sh| ≤ 2^22, 32 accumulations ⇒ < 2^27) stays exact, then flushed
/// into the i64 output. No multiply, no branch in the inner loop.
pub fn matshift_fast(xq: &[i32], w: &ShiftPlanes, m: usize) -> Vec<i64> {
    assert_eq!(xq.len(), m * w.rows);
    matshift_fast_rows(xq, w, 0, m)
}

/// Row-range core of [`matshift_fast`]: rows `r0..r1` of the full operand
/// only, returning a `(r1-r0)×n` buffer — the unit of work the row-parallel
/// `matshift/rowpar` backend schedules on the worker pool. Row results are
/// bit-identical to the full kernel's (same tiling, same accumulation
/// order), so chunked execution is exact.
pub fn matshift_fast_rows(xq: &[i32], w: &ShiftPlanes, r0: usize, r1: usize) -> Vec<i64> {
    let (k, n) = (w.rows, w.cols);
    assert!(r0 <= r1 && r1 * k <= xq.len());
    const BK: usize = 32;
    let mut acc = vec![0i64; (r1 - r0) * n];
    let mut tile = vec![0i32; n];
    for r in r0..r1 {
        let xrow = &xq[r * k..(r + 1) * k];
        let orow = &mut acc[(r - r0) * n..(r - r0 + 1) * n];
        for k0 in (0..k).step_by(BK) {
            let kend = (k0 + BK).min(k);
            tile.iter_mut().for_each(|t| *t = 0);
            for kk in k0..kend {
                let xv = xrow[kk];
                let shrow = &w.sh[kk * n..(kk + 1) * n];
                let negrow = &w.neg[kk * n..(kk + 1) * n];
                for c in 0..n {
                    let v = xv.wrapping_shl(shrow[c] as u32);
                    tile[c] = tile[c].wrapping_add((v ^ negrow[c]).wrapping_sub(negrow[c]));
                }
            }
            for c in 0..n {
                orow[c] += tile[c] as i64;
            }
        }
    }
    acc
}

/// Fast full pipeline (deployment path): INT8 quant → branchless
/// shift-accumulate → dequantize.
pub fn matshift_f32_fast(x: &[f32], w: &ShiftPlanes, m: usize) -> Vec<f32> {
    let q = Int8Quant::calibrate(x);
    let xq: Vec<i32> = q.quantize(x).iter().map(|&v| v as i32).collect();
    let acc = matshift_fast(&xq, w, m);
    let scale = q.scale / (PREC as f32).exp2();
    acc.iter().map(|&a| a as f32 * scale).collect()
}

/// Weight bytes moved per call: 2 INT8 planes (the paper's bit-reduction
/// argument — a f32 matmul moves 4·k·n bytes, MatShift moves 2·k·n).
pub fn weight_bytes(w: &Pow2Weights) -> usize {
    2 * w.rows * w.cols
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kernels::matmul::matmul_naive;
    use crate::quant::pow2::{dequantize, quantize};
    use crate::util::prop::{assert_close, check};
    use crate::util::rng::XorShift64;

    #[test]
    fn matches_float_product_of_dequantized_weights() {
        check("matshift-vs-dequant-matmul", 25, 20, |rng, size| {
            let (m, k, n) = (size, size + 2, size + 1);
            let x: Vec<f32> = rng.normals(m * k);
            let wf: Vec<f32> = rng.normals(k * n).iter().map(|v| v * 0.5).collect();
            let w = quantize(&wf, k, n);
            let got = matshift_f32(&x, &w, m);
            let want = matmul_naive(&x, &dequantize(&w), m, k, n);
            // INT8 activation quantization bounds the error.
            assert_close(&got, &want, 0.08)
        });
    }

    #[test]
    fn exact_for_integer_activations_and_unit_exponents() {
        // x ∈ small ints, w = ±1 (exp 0) ⇒ product is exactly representable.
        let mut rng = XorShift64::new(7);
        let (m, k, n) = (8, 16, 8);
        // x ∈ [-127, 127] integers with max 127 present ⇒ INT8 scale = 1 ⇒
        // the activation grid is exact.
        let mut x: Vec<f32> = (0..m * k)
            .map(|_| (rng.range(0, 255) as f32) - 127.0)
            .collect();
        x[0] = 127.0;
        let wf: Vec<f32> = (0..k * n)
            .map(|_| if rng.uniform() < 0.5 { -1.0 } else { 1.0 })
            .collect();
        let w = quantize(&wf, k, n);
        let got = matshift_f32(&x, &w, m);
        let want = matmul_naive(&x, &wf, m, k, n);
        // Exact up to the INT8 activation grid (here: exact since |x| ≤ 8 ⇒
        // scale = 8/127 and x/scale is not integral... allow tiny tolerance).
        assert_close(&got, &want, 0.02).unwrap();
    }

    #[test]
    fn negative_exponents_preserved_by_fixed_point() {
        let x = vec![127.0f32];
        let wf = vec![0.25f32]; // exp -2
        let w = quantize(&wf, 1, 1);
        let got = matshift_f32(&x, &w, 1);
        assert!((got[0] - 31.75).abs() < 0.2, "{}", got[0]);
    }

    #[test]
    fn weight_bytes_half_of_f32() {
        let w = quantize(&vec![1.0; 64 * 32], 64, 32);
        assert_eq!(weight_bytes(&w), 2 * 64 * 32);
    }

    #[test]
    fn fast_path_matches_reference_exactly() {
        check("matshift-fast-vs-ref", 25, 20, |rng, size| {
            let (m, k, n) = (size, size * 2 + 1, size + 3);
            let xq: Vec<i32> = (0..m * k).map(|_| rng.range(0, 255) as i32 - 127).collect();
            let wf = rng.normals(k * n);
            let w = quantize(&wf, k, n);
            let planes = super::ShiftPlanes::from_pow2(&w);
            let a = matshift_i64(&xq, &w, m);
            let b = super::matshift_fast(&xq, &planes, m);
            if a != b {
                return Err("fast path diverged from reference".into());
            }
            Ok(())
        });
    }
}
