//! Built-in serial backends for each primitive (the row-parallel ones live
//! in [`crate::kernels::parallel`]). Each struct wraps one of the legacy
//! free-function kernels behind the [`LinearKernel`] contract; the free
//! functions remain callable for one release but new code should resolve
//! backends through [`crate::kernels::registry::KernelRegistry`].

use std::sync::Arc;

use crate::energy::ops::MacStyle;
use crate::kernels::api::{LinearKernel, Operand, PreparedWeights, Primitive, RawWeights};
use crate::kernels::matshift::PREC;
use crate::kernels::{fakeshift, matadd, matmul, matshift};
use crate::quant::binary;
use crate::quant::pow2;

/// INT8-activation error budget shared by the MatShift backends: per-element
/// activation quantization error is ≤ scale/2 ≈ amax/254, which accumulated
/// over k terms against O(1) weights stays inside this relative bound for
/// the shapes the property suite draws.
pub const SHIFT_TOL: f32 = 0.25;

// ---- shared helpers -------------------------------------------------------

fn expect_dense<'a>(w: &'a PreparedWeights, who: &str) -> (&'a [f32], usize, usize) {
    match w {
        PreparedWeights::Dense { k, n, w } => (w.as_slice(), *k, *n),
        other => panic!("{who}: expected dense weights, got {}", other.variant_name()),
    }
}

fn expect_f32<'a>(x: &'a Operand, who: &str) -> (&'a [f32], usize) {
    match x {
        Operand::F32 { m, x, .. } => (x.as_slice(), *m),
        Operand::Int8 { .. } => panic!("{who}: expected f32 operand"),
    }
}

/// {-1, 0, +1} codes: exact zeros stay zero (the packed nz-mask path).
fn ternarize(w: &[f32]) -> Vec<i8> {
    w.iter()
        .map(|&v| {
            if v > 0.0 {
                1
            } else if v < 0.0 {
                -1
            } else {
                0
            }
        })
        .collect()
}

fn check_run_shapes(x_len: usize, out_len: usize, m: usize, k: usize, n: usize, who: &str) {
    assert_eq!(x_len, m * k, "{who}: operand is not m*k");
    assert_eq!(out_len, m * n, "{who}: output is not m*n");
}

/// Shared MatShift execution: accept either operand form, quantizing f32
/// on the fly (the prepared-operand path keeps quantization off hot loops).
pub(crate) fn run_matshift_planes(
    planes: &matshift::ShiftPlanes,
    x: &Operand,
    out: &mut [f32],
    who: &str,
) {
    let (k, n) = (planes.rows, planes.cols);
    match x {
        Operand::Int8 { m, k: xk, xq, scale } => {
            assert_eq!(*xk, k, "{who}: operand k mismatch");
            check_run_shapes(xq.len(), out.len(), *m, k, n, who);
            let acc = matshift::matshift_fast(xq, planes, *m);
            let s = scale / (PREC as f32).exp2();
            for (o, &a) in out.iter_mut().zip(&acc) {
                *o = a as f32 * s;
            }
        }
        Operand::F32 { m, k: xk, x } => {
            assert_eq!(*xk, k, "{who}: operand k mismatch");
            check_run_shapes(x.len(), out.len(), *m, k, n, who);
            out.copy_from_slice(&matshift::matshift_f32_fast(x, planes, *m));
        }
    }
}

// ---- MatMul ---------------------------------------------------------------

/// `matmul/naive` — unblocked reference ("PyTorch einsum" stand-in).
pub struct MatMulNaive;

impl LinearKernel for MatMulNaive {
    fn primitive(&self) -> Primitive {
        Primitive::MatMul
    }

    fn backend(&self) -> &'static str {
        "naive"
    }

    fn mac_style(&self) -> MacStyle {
        MacStyle::MultFp32
    }

    fn prepare(&self, w: &RawWeights) -> PreparedWeights {
        PreparedWeights::Dense {
            k: w.k,
            n: w.n,
            w: Arc::new(w.data.clone()),
        }
    }

    fn run(&self, w: &PreparedWeights, x: &Operand, out: &mut [f32]) {
        let (wf, k, n) = expect_dense(w, "matmul/naive");
        let (xf, m) = expect_f32(x, "matmul/naive");
        check_run_shapes(xf.len(), out.len(), m, k, n, "matmul/naive");
        out.copy_from_slice(&matmul::matmul_naive(xf, wf, m, k, n));
    }
}

/// `matmul/blocked` — cache-blocked dense kernel ("TVM MatMul" stand-in).
pub struct MatMulBlocked;

impl LinearKernel for MatMulBlocked {
    fn primitive(&self) -> Primitive {
        Primitive::MatMul
    }

    fn backend(&self) -> &'static str {
        "blocked"
    }

    fn mac_style(&self) -> MacStyle {
        MacStyle::MultFp32
    }

    fn prepare(&self, w: &RawWeights) -> PreparedWeights {
        PreparedWeights::Dense {
            k: w.k,
            n: w.n,
            w: Arc::new(w.data.clone()),
        }
    }

    fn run(&self, w: &PreparedWeights, x: &Operand, out: &mut [f32]) {
        let (wf, k, n) = expect_dense(w, "matmul/blocked");
        let (xf, m) = expect_f32(x, "matmul/blocked");
        check_run_shapes(xf.len(), out.len(), m, k, n, "matmul/blocked");
        out.copy_from_slice(&matmul::matmul_f32(xf, wf, m, k, n));
    }
}

// ---- MatAdd ---------------------------------------------------------------

/// `matadd/ref` — branchy {-1,0,+1} reference (the oracle kernel).
pub struct MatAddRef;

impl LinearKernel for MatAddRef {
    fn primitive(&self) -> Primitive {
        Primitive::MatAdd
    }

    fn backend(&self) -> &'static str {
        "ref"
    }

    fn mac_style(&self) -> MacStyle {
        MacStyle::AddFp32
    }

    fn prepare(&self, w: &RawWeights) -> PreparedWeights {
        PreparedWeights::Ternary {
            k: w.k,
            n: w.n,
            b: Arc::new(ternarize(&w.data)),
        }
    }

    fn run(&self, w: &PreparedWeights, x: &Operand, out: &mut [f32]) {
        let (b, k, n) = match w {
            PreparedWeights::Ternary { k, n, b } => (b.as_slice(), *k, *n),
            other => panic!("matadd/ref: expected ternary weights, got {}", other.variant_name()),
        };
        let (xf, m) = expect_f32(x, "matadd/ref");
        check_run_shapes(xf.len(), out.len(), m, k, n, "matadd/ref");
        out.copy_from_slice(&matadd::matadd_f32(xf, b, m, k, n));
    }
}

/// `matadd/packed` — branchless sign/nonzero bit-mask kernel (ternary
/// deployment format; INT32-accumulate on the Eyeriss target).
pub struct MatAddPacked;

impl LinearKernel for MatAddPacked {
    fn primitive(&self) -> Primitive {
        Primitive::MatAdd
    }

    fn backend(&self) -> &'static str {
        "packed"
    }

    fn mac_style(&self) -> MacStyle {
        MacStyle::AddInt32
    }

    fn prepare(&self, w: &RawWeights) -> PreparedWeights {
        PreparedWeights::Packed(Arc::new(matadd::PackedB::pack(
            &ternarize(&w.data),
            w.k,
            w.n,
        )))
    }

    fn run(&self, w: &PreparedWeights, x: &Operand, out: &mut [f32]) {
        let packed = match w {
            PreparedWeights::Packed(p) => p,
            other => panic!("matadd/packed: expected packed weights, got {}", other.variant_name()),
        };
        let (xf, m) = expect_f32(x, "matadd/packed");
        check_run_shapes(xf.len(), out.len(), m, packed.k, packed.n, "matadd/packed");
        out.copy_from_slice(&matadd::matadd_packed(xf, packed, m));
    }
}

/// `matadd/bitplane` — ±1 sign-byte kernel (binary deployment format: one
/// byte per weight, the paper's data-movement argument).
pub struct MatAddBitplane;

impl LinearKernel for MatAddBitplane {
    fn primitive(&self) -> Primitive {
        Primitive::MatAdd
    }

    fn backend(&self) -> &'static str {
        "bitplane"
    }

    fn mac_style(&self) -> MacStyle {
        MacStyle::AddInt32
    }

    fn prepare(&self, w: &RawWeights) -> PreparedWeights {
        PreparedWeights::Pm1(Arc::new(matadd::PackedPm1::pack(
            &binary::binarize(&w.data),
            w.k,
            w.n,
        )))
    }

    fn run(&self, w: &PreparedWeights, x: &Operand, out: &mut [f32]) {
        let packed = match w {
            PreparedWeights::Pm1(p) => p,
            other => panic!("matadd/bitplane: expected pm1 weights, got {}", other.variant_name()),
        };
        let (xf, m) = expect_f32(x, "matadd/bitplane");
        check_run_shapes(xf.len(), out.len(), m, packed.k, packed.n, "matadd/bitplane");
        out.copy_from_slice(&matadd::matadd_pm1(xf, packed, m));
    }
}

// ---- MatShift -------------------------------------------------------------

/// `matshift/ref` — (sign, exponent) plane reference kernel.
pub struct MatShiftRef;

impl LinearKernel for MatShiftRef {
    fn primitive(&self) -> Primitive {
        Primitive::MatShift
    }

    fn backend(&self) -> &'static str {
        "ref"
    }

    fn mac_style(&self) -> MacStyle {
        MacStyle::ShiftInt32
    }

    fn tolerance(&self) -> f32 {
        SHIFT_TOL
    }

    fn prepare(&self, w: &RawWeights) -> PreparedWeights {
        PreparedWeights::Pow2(Arc::new(pow2::quantize(&w.data, w.k, w.n)))
    }

    fn prepare_operand(&self, x: &[f32], m: usize, k: usize) -> Operand {
        Operand::quantized(x, m, k)
    }

    fn run(&self, w: &PreparedWeights, x: &Operand, out: &mut [f32]) {
        let pw = match w {
            PreparedWeights::Pow2(p) => p,
            other => panic!("matshift/ref: expected pow2 weights, got {}", other.variant_name()),
        };
        let (k, n) = (pw.rows, pw.cols);
        match x {
            Operand::Int8 { m, k: xk, xq, scale } => {
                assert_eq!(*xk, k, "matshift/ref: operand k mismatch");
                check_run_shapes(xq.len(), out.len(), *m, k, n, "matshift/ref");
                let acc = matshift::matshift_i64(xq, pw, *m);
                let s = scale / (PREC as f32).exp2();
                for (o, &a) in out.iter_mut().zip(&acc) {
                    *o = a as f32 * s;
                }
            }
            Operand::F32 { m, k: xk, x } => {
                assert_eq!(*xk, k, "matshift/ref: operand k mismatch");
                check_run_shapes(x.len(), out.len(), *m, k, n, "matshift/ref");
                out.copy_from_slice(&matshift::matshift_f32(x, pw, *m));
            }
        }
    }
}

/// `matshift/planes` — branchless blocked shift/negate kernel (deployment).
pub struct MatShiftPlanes;

impl LinearKernel for MatShiftPlanes {
    fn primitive(&self) -> Primitive {
        Primitive::MatShift
    }

    fn backend(&self) -> &'static str {
        "planes"
    }

    fn mac_style(&self) -> MacStyle {
        MacStyle::ShiftInt32
    }

    fn tolerance(&self) -> f32 {
        SHIFT_TOL
    }

    fn prepare(&self, w: &RawWeights) -> PreparedWeights {
        let q = pow2::quantize(&w.data, w.k, w.n);
        PreparedWeights::Planes(Arc::new(matshift::ShiftPlanes::from_pow2(&q)))
    }

    fn prepare_operand(&self, x: &[f32], m: usize, k: usize) -> Operand {
        Operand::quantized(x, m, k)
    }

    fn run(&self, w: &PreparedWeights, x: &Operand, out: &mut [f32]) {
        let planes = match w {
            PreparedWeights::Planes(p) => p,
            other => panic!("matshift/planes: expected planes weights, got {}", other.variant_name()),
        };
        run_matshift_planes(planes, x, out, "matshift/planes");
    }
}

// ---- FakeShift ------------------------------------------------------------

/// `fakeshift/ref` — float multiply with in-loop pow2 rematerialization
/// (the naive "PyTorch FakeShift" graph).
pub struct FakeShiftRef;

impl LinearKernel for FakeShiftRef {
    fn primitive(&self) -> Primitive {
        Primitive::FakeShift
    }

    fn backend(&self) -> &'static str {
        "ref"
    }

    fn mac_style(&self) -> MacStyle {
        MacStyle::MultFp32
    }

    fn prepare(&self, w: &RawWeights) -> PreparedWeights {
        PreparedWeights::Pow2(Arc::new(pow2::quantize(&w.data, w.k, w.n)))
    }

    fn run(&self, w: &PreparedWeights, x: &Operand, out: &mut [f32]) {
        let pw = match w {
            PreparedWeights::Pow2(p) => p,
            other => panic!("fakeshift/ref: expected pow2 weights, got {}", other.variant_name()),
        };
        let (xf, m) = expect_f32(x, "fakeshift/ref");
        check_run_shapes(xf.len(), out.len(), m, pw.rows, pw.cols, "fakeshift/ref");
        out.copy_from_slice(&fakeshift::fakeshift_rematerialize(xf, pw, m));
    }
}

/// `fakeshift/cached` — pow2 weights expanded to f32 once at prepare time,
/// then a blocked dense matmul (the tuned-graph FakeShift comparator).
pub struct FakeShiftCached;

impl LinearKernel for FakeShiftCached {
    fn primitive(&self) -> Primitive {
        Primitive::FakeShift
    }

    fn backend(&self) -> &'static str {
        "cached"
    }

    fn mac_style(&self) -> MacStyle {
        MacStyle::MultFp32
    }

    fn prepare(&self, w: &RawWeights) -> PreparedWeights {
        let q = pow2::quantize(&w.data, w.k, w.n);
        PreparedWeights::Dense {
            k: w.k,
            n: w.n,
            w: Arc::new(pow2::dequantize(&q)),
        }
    }

    fn run(&self, w: &PreparedWeights, x: &Operand, out: &mut [f32]) {
        let (wf, k, n) = expect_dense(w, "fakeshift/cached");
        let (xf, m) = expect_f32(x, "fakeshift/cached");
        check_run_shapes(xf.len(), out.len(), m, k, n, "fakeshift/cached");
        out.copy_from_slice(&matmul::matmul_f32(xf, wf, m, k, n));
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::assert_close;
    use crate::util::rng::XorShift64;

    #[test]
    fn prepare_preserves_shape_metadata() {
        let raw = RawWeights::new(vec![0.5; 6], 2, 3);
        for kernel in [
            &MatMulBlocked as &dyn LinearKernel,
            &MatAddPacked,
            &MatAddBitplane,
            &MatShiftPlanes,
            &FakeShiftCached,
        ] {
            let w = kernel.prepare(&raw);
            assert_eq!((w.k(), w.n()), (2, 3), "{}", kernel.id());
            assert_eq!(w.dense().len(), 6, "{}", kernel.id());
        }
    }

    #[test]
    fn fakeshift_variants_agree_through_the_trait() {
        let mut rng = XorShift64::new(21);
        let (m, k, n) = (5, 7, 4);
        let raw = RawWeights::new(rng.normals(k * n), k, n);
        let x = rng.normals(m * k);
        let mut a = vec![0.0f32; m * n];
        let mut b = vec![0.0f32; m * n];
        let kr = FakeShiftRef;
        let kc = FakeShiftCached;
        kr.run(&kr.prepare(&raw), &kr.prepare_operand(&x, m, k), &mut a);
        kc.run(&kc.prepare(&raw), &kc.prepare_operand(&x, m, k), &mut b);
        assert_close(&a, &b, 1e-4).unwrap();
    }

    #[test]
    #[should_panic(expected = "expected dense weights")]
    fn wrong_weight_variant_panics() {
        let raw = RawWeights::new(vec![1.0; 4], 2, 2);
        let w = MatShiftPlanes.prepare(&raw);
        let op = Operand::from_f32(&[1.0; 4], 2, 2);
        let mut out = vec![0.0; 4];
        MatMulBlocked.run(&w, &op, &mut out);
    }
}
