//! MatAdd — accumulation-only matmul against a {-1, 0, +1} operand
//! (Fig. 5/8). The inner loop contains adds/subtracts only; this is what the
//! binarized-Q/K attention MatMuls reduce to.
//!
//! Two implementations:
//! - [`matadd_f32`] — readable reference (branchy select), used as oracle;
//! - [`PackedB`] + [`matadd_packed`] — the *deployment* kernel: the binary
//!   operand is pre-packed into sign/nonzero bit-masks (this is the storage
//!   format binarization produces anyway), and the inner loop is branchless
//!   `(x ^ sign) & nz` + add — pure bitwise ops + adder, no multiplier, and
//!   auto-vectorizable (§Perf L3-3).

/// Pre-packed binary operand: per-element f32 sign-flip mask and nonzero
/// mask (the format the MatAdd deployment kernel consumes).
#[derive(Clone, Debug)]
pub struct PackedB {
    pub k: usize,
    pub n: usize,
    /// 0x8000_0000 where b = -1, else 0
    pub sign: Vec<u32>,
    /// 0xFFFF_FFFF where b ≠ 0, else 0
    pub nz: Vec<u32>,
}

impl PackedB {
    pub fn pack(b: &[i8], k: usize, n: usize) -> PackedB {
        assert_eq!(b.len(), k * n);
        PackedB {
            k,
            n,
            sign: b
                .iter()
                .map(|&v| if v < 0 { 0x8000_0000 } else { 0 })
                .collect(),
            nz: b.iter().map(|&v| if v != 0 { u32::MAX } else { 0 }).collect(),
        }
    }
}

/// ±1-specialized packed operand: one *byte* per weight (bit 7 = sign), so
/// the kernel streams 4× fewer weight bytes than an f32 matmul — the
/// data-movement advantage the paper attributes MatAdd's speedup to.
#[derive(Clone, Debug)]
pub struct PackedPm1 {
    pub k: usize,
    pub n: usize,
    /// 0x80 where b = -1, else 0
    pub sign: Vec<u8>,
}

impl PackedPm1 {
    pub fn pack(b: &[i8], k: usize, n: usize) -> PackedPm1 {
        assert_eq!(b.len(), k * n);
        assert!(b.iter().all(|&v| v == 1 || v == -1), "operand must be ±1");
        PackedPm1 {
            k,
            n,
            sign: b.iter().map(|&v| if v < 0 { 0x80 } else { 0 }).collect(),
        }
    }
}

/// Branchless ±1 kernel: one byte-load + widen + xor + add per MAC.
pub fn matadd_pm1(x: &[f32], b: &PackedPm1, m: usize) -> Vec<f32> {
    assert_eq!(x.len(), m * b.k);
    matadd_pm1_rows(x, b, 0, m)
}

/// Row-range core of [`matadd_pm1`]: rows `r0..r1` of the full operand only,
/// returning a `(r1-r0)×n` buffer — the unit of work the row-parallel
/// `matadd/rowpar` backend schedules on the worker pool. Per-row accumulation
/// order is unchanged, so chunked execution is bit-identical.
pub fn matadd_pm1_rows(x: &[f32], b: &PackedPm1, r0: usize, r1: usize) -> Vec<f32> {
    let (k, n) = (b.k, b.n);
    assert!(r0 <= r1 && r1 * k <= x.len());
    let mut o = vec![0.0f32; (r1 - r0) * n];
    for r in r0..r1 {
        let xrow = &x[r * k..(r + 1) * k];
        let orow = &mut o[(r - r0) * n..(r - r0 + 1) * n];
        for kk in 0..k {
            let xb = xrow[kk].to_bits();
            let srow = &b.sign[kk * n..(kk + 1) * n];
            for c in 0..n {
                // sign byte << 24 lands on the f32 sign bit
                orow[c] += f32::from_bits(xb ^ ((srow[c] as u32) << 24));
            }
        }
    }
    o
}

/// Branchless accumulation-only kernel: o[m,n] += f32::from_bits((x.bits ^
/// sign) & nz). Sign flip is an XOR, zero-skip is an AND — no multiplies.
pub fn matadd_packed(x: &[f32], b: &PackedB, m: usize) -> Vec<f32> {
    let (k, n) = (b.k, b.n);
    assert_eq!(x.len(), m * k);
    let mut o = vec![0.0f32; m * n];
    for r in 0..m {
        let xrow = &x[r * k..(r + 1) * k];
        let orow = &mut o[r * n..(r + 1) * n];
        for kk in 0..k {
            let xb = xrow[kk].to_bits();
            let srow = &b.sign[kk * n..(kk + 1) * n];
            let zrow = &b.nz[kk * n..(kk + 1) * n];
            for c in 0..n {
                orow[c] += f32::from_bits((xb ^ srow[c]) & zrow[c]);
            }
        }
    }
    o
}

/// `o (m×n) = x (m×k) @ b (k×n)` with `b ∈ {-1,0,+1}` — f32 accumulate.
pub fn matadd_f32(x: &[f32], b: &[i8], m: usize, k: usize, n: usize) -> Vec<f32> {
    assert_eq!(x.len(), m * k);
    assert_eq!(b.len(), k * n);
    let mut o = vec![0.0f32; m * n];
    for r in 0..m {
        let xrow = &x[r * k..(r + 1) * k];
        let orow = &mut o[r * n..(r + 1) * n];
        for kk in 0..k {
            let xv = xrow[kk];
            let brow = &b[kk * n..(kk + 1) * n];
            for c in 0..n {
                // accumulation only: +x, -x, or skip
                match brow[c] {
                    1 => orow[c] += xv,
                    -1 => orow[c] -= xv,
                    _ => {}
                }
            }
        }
    }
    o
}

/// Transposed-operand variant `o = bᵀ (n×k) ... ` — `o (m×n) = x (m×k) @
/// bT (n×k)ᵀ`: iterating b row-major over n gives better locality when the
/// binary operand is produced token-major (the Q·(KᵀV) case).
pub fn matadd_f32_bt(x: &[f32], bt: &[i8], m: usize, k: usize, n: usize) -> Vec<f32> {
    assert_eq!(bt.len(), n * k);
    let mut o = vec![0.0f32; m * n];
    for r in 0..m {
        let xrow = &x[r * k..(r + 1) * k];
        let orow = &mut o[r * n..(r + 1) * n];
        for c in 0..n {
            let brow = &bt[c * k..(c + 1) * k];
            let mut acc = 0.0f32;
            for kk in 0..k {
                match brow[kk] {
                    1 => acc += xrow[kk],
                    -1 => acc -= xrow[kk],
                    _ => {}
                }
            }
            orow[c] = acc;
        }
    }
    o
}

/// Integer accumulate (INT8 activations → i32) — exact, no rounding.
pub fn matadd_i32(xq: &[i8], b: &[i8], m: usize, k: usize, n: usize) -> Vec<i32> {
    let mut o = vec![0i32; m * n];
    for r in 0..m {
        let xrow = &xq[r * k..(r + 1) * k];
        let orow = &mut o[r * n..(r + 1) * n];
        for kk in 0..k {
            let xv = xrow[kk] as i32;
            let brow = &b[kk * n..(kk + 1) * n];
            for c in 0..n {
                match brow[c] {
                    1 => orow[c] += xv,
                    -1 => orow[c] -= xv,
                    _ => {}
                }
            }
        }
    }
    o
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kernels::matmul::matmul_naive;
    use crate::util::prop::{assert_close, check};

    fn rand_b(rng: &mut crate::util::rng::XorShift64, len: usize) -> Vec<i8> {
        (0..len)
            .map(|_| match rng.range(0, 3) {
                0 => -1i8,
                1 => 0,
                _ => 1,
            })
            .collect()
    }

    #[test]
    fn matches_dense_product() {
        check("matadd-vs-matmul", 30, 24, |rng, size| {
            let (m, k, n) = (size, size + 1, size + 2);
            let x = rng.normals(m * k);
            let b = rand_b(rng, k * n);
            let bf: Vec<f32> = b.iter().map(|&v| v as f32).collect();
            assert_close(
                &matadd_f32(&x, &b, m, k, n),
                &matmul_naive(&x, &bf, m, k, n),
                1e-4,
            )
        });
    }

    #[test]
    fn transposed_variant_agrees() {
        check("matadd-bt-vs-b", 20, 16, |rng, size| {
            let (m, k, n) = (size + 1, size + 2, size);
            let x = rng.normals(m * k);
            let b = rand_b(rng, k * n);
            // transpose b (k×n) → bt (n×k)
            let mut bt = vec![0i8; n * k];
            for kk in 0..k {
                for c in 0..n {
                    bt[c * k + kk] = b[kk * n + c];
                }
            }
            assert_close(
                &matadd_f32_bt(&x, &bt, m, k, n),
                &matadd_f32(&x, &b, m, k, n),
                1e-5,
            )
        });
    }

    #[test]
    fn integer_accumulation_is_exact() {
        let (m, k, n) = (4, 8, 4);
        let xq: Vec<i8> = (0..m * k).map(|i| (i as i8 % 11) - 5).collect();
        let b: Vec<i8> = (0..k * n).map(|i| ((i % 3) as i8) - 1).collect();
        let got = matadd_i32(&xq, &b, m, k, n);
        for r in 0..m {
            for c in 0..n {
                let mut want = 0i32;
                for kk in 0..k {
                    want += xq[r * k + kk] as i32 * b[kk * n + c] as i32;
                }
                assert_eq!(got[r * n + c], want);
            }
        }
    }

    #[test]
    fn zero_operand_skips() {
        let x = vec![1.0, 2.0];
        let b = vec![0i8, 0];
        assert_eq!(matadd_f32(&x, &b, 1, 2, 1), vec![0.0]);
    }

    #[test]
    fn packed_matches_reference() {
        check("matadd-packed-vs-ref", 30, 24, |rng, size| {
            let (m, k, n) = (size, size + 2, size + 1);
            let x = rng.normals(m * k);
            let b = rand_b(rng, k * n);
            let packed = PackedB::pack(&b, k, n);
            assert_close(
                &matadd_packed(&x, &packed, m),
                &matadd_f32(&x, &b, m, k, n),
                1e-5,
            )
        });
    }

    #[test]
    fn pm1_matches_reference() {
        check("matadd-pm1-vs-ref", 30, 24, |rng, size| {
            let (m, k, n) = (size, size + 2, size + 1);
            let x = rng.normals(m * k);
            let b: Vec<i8> = (0..k * n)
                .map(|_| if rng.uniform() < 0.5 { -1 } else { 1 })
                .collect();
            let packed = PackedPm1::pack(&b, k, n);
            assert_close(
                &matadd_pm1(&x, &packed, m),
                &matadd_f32(&x, &b, m, k, n),
                1e-5,
            )
        });
    }

    #[test]
    fn packed_handles_negative_zero_inputs() {
        // x = -0.0 with b = -1 must contribute +0.0, not corrupt the sum.
        let x = vec![-0.0f32, 1.0];
        let b = vec![-1i8, 1];
        let packed = PackedB::pack(&b, 2, 1);
        let got = matadd_packed(&x, &packed, 1);
        assert_eq!(got, vec![1.0]);
    }
}
