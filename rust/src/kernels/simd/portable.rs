//! Portable chunked fallback cores — the `*/simd` backends' guaranteed
//! floor on every platform.
//!
//! Both cores block the output columns into [`LANES`]-wide tiles with the
//! per-lane arithmetic kept *identical* to the serial kernels
//! (`matadd::matadd_pm1_rows`, `matshift::matshift_fast_rows`): each output
//! element still accumulates its contributions in ascending `k`, so the
//! blocked execution is bit-exact vs the serial references (and hence vs
//! `matadd/ref` / `matshift/ref`). The MatAdd core additionally reads each
//! tile's 8 sign bytes through one `u64` load (SWAR-style) instead of 8 byte
//! loads; the register-resident accumulator tiles are what the
//! autovectorizer needs to emit real vector code even without intrinsics.

use crate::kernels::matadd::PackedPm1;
use crate::kernels::matshift::ShiftPlanes;

/// Column-block width shared by the simd cores: one AVX2 vector, two NEON
/// vectors, or one unrolled portable tile.
pub const LANES: usize = 8;

/// K-tile width, matching `matshift_fast_rows`: ≤ 32 accumulations of
/// `|x·2^sh| < 2^22` keep the i32 tile exact before the i64 flush.
pub(crate) const BK: usize = 32;

/// Scalar-tail MatAdd column — the exact serial formula, shared by every
/// core's ragged right edge.
#[inline]
pub(crate) fn matadd_pm1_tail(xrow: &[f32], sign: &[u8], n: usize, c: usize) -> f32 {
    let mut a = 0.0f32;
    for (kk, xv) in xrow.iter().enumerate() {
        a += f32::from_bits(xv.to_bits() ^ ((sign[kk * n + c] as u32) << 24));
    }
    a
}

/// Scalar-tail MatShift column — the reference k-tiling on one column,
/// shared by every core's ragged right edge.
#[inline]
pub(crate) fn matshift_tail(xrow: &[i32], w: &ShiftPlanes, n: usize, c: usize) -> i64 {
    let k = xrow.len();
    let mut acc = 0i64;
    for k0 in (0..k).step_by(BK) {
        let kend = (k0 + BK).min(k);
        let mut tile = 0i32;
        for kk in k0..kend {
            let v = xrow[kk].wrapping_shl(w.sh[kk * n + c] as u32);
            tile = tile.wrapping_add((v ^ w.neg[kk * n + c]).wrapping_sub(w.neg[kk * n + c]));
        }
        acc += tile as i64;
    }
    acc
}

/// Portable ±1 MatAdd row core: rows `r0..r1`, column-blocked with one
/// `u64` sign-byte load per tile row. Bit-exact vs `matadd_pm1_rows`.
pub fn matadd_pm1_rows_portable(x: &[f32], b: &PackedPm1, r0: usize, r1: usize) -> Vec<f32> {
    let (k, n) = (b.k, b.n);
    assert!(r0 <= r1 && r1 * k <= x.len());
    let mut o = vec![0.0f32; (r1 - r0) * n];
    for r in r0..r1 {
        let xrow = &x[r * k..(r + 1) * k];
        let orow = &mut o[(r - r0) * n..(r - r0 + 1) * n];
        let mut c0 = 0usize;
        while c0 + LANES <= n {
            let mut acc = [0.0f32; LANES];
            for (kk, xv) in xrow.iter().enumerate() {
                let xb = xv.to_bits();
                let base = kk * n + c0;
                // one u64 covers the tile's 8 sign bytes; from_le_bytes
                // keeps byte l in lane l on every endianness
                let sw = u64::from_le_bytes(b.sign[base..base + LANES].try_into().unwrap());
                for (l, a) in acc.iter_mut().enumerate() {
                    let s = ((sw >> (8 * l)) & 0xFF) as u32;
                    *a += f32::from_bits(xb ^ (s << 24));
                }
            }
            orow[c0..c0 + LANES].copy_from_slice(&acc);
            c0 += LANES;
        }
        for (c, o) in orow.iter_mut().enumerate().skip(c0) {
            *o = matadd_pm1_tail(xrow, &b.sign, n, c);
        }
    }
    o
}

/// Portable MatShift row core: rows `r0..r1`, column-blocked over the same
/// `BK` k-tiling as `matshift_fast_rows`. Bit-exact vs the serial kernel
/// (integer arithmetic, no i32 overflow within a tile by the INT8 operand
/// contract).
pub fn matshift_rows_portable(xq: &[i32], w: &ShiftPlanes, r0: usize, r1: usize) -> Vec<i64> {
    let (k, n) = (w.rows, w.cols);
    assert!(r0 <= r1 && r1 * k <= xq.len());
    let mut acc = vec![0i64; (r1 - r0) * n];
    for r in r0..r1 {
        let xrow = &xq[r * k..(r + 1) * k];
        let orow = &mut acc[(r - r0) * n..(r - r0 + 1) * n];
        let mut c0 = 0usize;
        while c0 + LANES <= n {
            for k0 in (0..k).step_by(BK) {
                let kend = (k0 + BK).min(k);
                let mut tile = [0i32; LANES];
                for kk in k0..kend {
                    let xv = xrow[kk];
                    let base = kk * n + c0;
                    let shrow = &w.sh[base..base + LANES];
                    let negrow = &w.neg[base..base + LANES];
                    for (l, t) in tile.iter_mut().enumerate() {
                        let v = xv.wrapping_shl(shrow[l] as u32);
                        *t = t.wrapping_add((v ^ negrow[l]).wrapping_sub(negrow[l]));
                    }
                }
                for (l, t) in tile.iter().enumerate() {
                    orow[c0 + l] += *t as i64;
                }
            }
            c0 += LANES;
        }
        for (c, o) in orow.iter_mut().enumerate().skip(c0) {
            *o = matshift_tail(xrow, w, n, c);
        }
    }
    acc
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kernels::{matadd, matshift};
    use crate::quant::pow2;
    use crate::util::prop::check;
    use crate::util::rng::XorShift64;

    fn pm1(rng: &mut XorShift64, len: usize) -> Vec<i8> {
        (0..len)
            .map(|_| if rng.uniform() < 0.5 { -1 } else { 1 })
            .collect()
    }

    #[test]
    fn matadd_core_bit_exact_vs_serial_rows() {
        check("portable-matadd-vs-serial", 24, 20, |rng, size| {
            // non-multiple-of-LANES widths by construction
            let (m, k, n) = (size + 1, size + 3, size + 2);
            let x = rng.normals(m * k);
            let packed = matadd::PackedPm1::pack(&pm1(rng, k * n), k, n);
            let got = matadd_pm1_rows_portable(&x, &packed, 0, m);
            let want = matadd::matadd_pm1_rows(&x, &packed, 0, m);
            if got != want {
                return Err(format!("diverged at m={m} k={k} n={n}"));
            }
            // row sub-ranges agree too (the pool-chunk contract)
            let lo = matadd_pm1_rows_portable(&x, &packed, 1.min(m), m);
            if lo != matadd::matadd_pm1_rows(&x, &packed, 1.min(m), m) {
                return Err("row range diverged".into());
            }
            Ok(())
        });
    }

    #[test]
    fn matshift_core_bit_exact_vs_serial_rows() {
        check("portable-matshift-vs-serial", 24, 20, |rng, size| {
            let (m, k, n) = (size + 1, size * 2 + 1, size + 3);
            let xq: Vec<i32> = (0..m * k).map(|_| rng.range(0, 255) as i32 - 127).collect();
            let q = pow2::quantize(&rng.normals(k * n), k, n);
            let planes = matshift::ShiftPlanes::from_pow2(&q);
            let got = matshift_rows_portable(&xq, &planes, 0, m);
            let want = matshift::matshift_fast_rows(&xq, &planes, 0, m);
            if got != want {
                return Err(format!("diverged at m={m} k={k} n={n}"));
            }
            Ok(())
        });
    }

    #[test]
    fn exact_lane_width_columns() {
        // n = LANES and n = 2·LANES exercise the all-vector (no tail) path.
        let mut rng = XorShift64::new(9);
        for n in [LANES, 2 * LANES] {
            let (m, k) = (3, 5);
            let x = rng.normals(m * k);
            let packed = matadd::PackedPm1::pack(&pm1(&mut rng, k * n), k, n);
            assert_eq!(
                matadd_pm1_rows_portable(&x, &packed, 0, m),
                matadd::matadd_pm1_rows(&x, &packed, 0, m),
                "n={n}"
            );
        }
    }
}
