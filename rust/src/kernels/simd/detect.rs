//! Runtime CPU-feature detection for the `*/simd` backends.
//!
//! Detection resolves to a [`SimdLevel`]: the best instruction set the host
//! can execute (AVX2 on x86-64, NEON on aarch64, otherwise the portable
//! chunked fallback). The `SHIFTADD_NO_SIMD` environment variable forces the
//! portable level regardless of hardware — the knob CI uses to exercise the
//! fallback path on machines whose vector units would otherwise shadow it.
//!
//! [`active_level`] caches the decision process-wide (one env read, one
//! feature probe), so the override must be set before the first kernel
//! dispatch — in practice, before the process starts. [`detect_level`] and
//! [`resolve_level`] stay uncached for tests.

use std::sync::OnceLock;

/// Environment variable forcing the portable fallback when set to anything
/// other than empty or `0`.
pub const NO_SIMD_ENV: &str = "SHIFTADD_NO_SIMD";

/// The instruction-set tiers the simd cores are implemented for.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum SimdLevel {
    /// x86-64 AVX2 (8×f32 / 8×i32 vectors, variable per-lane shifts)
    Avx2,
    /// aarch64 NEON (4-lane vectors, paired for 8-wide column blocks)
    Neon,
    /// chunked-`u64`/unrolled scalar fallback — every platform
    Portable,
}

impl SimdLevel {
    /// Tag used for planner-table stamps and bench JSON.
    pub fn name(self) -> &'static str {
        match self {
            SimdLevel::Avx2 => "avx2",
            SimdLevel::Neon => "neon",
            SimdLevel::Portable => "portable",
        }
    }

    /// Inverse of [`SimdLevel::name`] (reading table stamps).
    pub fn parse(s: &str) -> Option<SimdLevel> {
        [SimdLevel::Avx2, SimdLevel::Neon, SimdLevel::Portable]
            .into_iter()
            .find(|l| l.name() == s)
    }

    /// True when this host can execute the level *right now* — the safety
    /// gate every dispatch into a `target_feature` core goes through.
    pub fn available(self) -> bool {
        match self {
            SimdLevel::Portable => true,
            SimdLevel::Avx2 => {
                #[cfg(target_arch = "x86_64")]
                {
                    std::arch::is_x86_feature_detected!("avx2")
                }
                #[cfg(not(target_arch = "x86_64"))]
                {
                    false
                }
            }
            SimdLevel::Neon => {
                #[cfg(target_arch = "aarch64")]
                {
                    std::arch::is_aarch64_feature_detected!("neon")
                }
                #[cfg(not(target_arch = "aarch64"))]
                {
                    false
                }
            }
        }
    }
}

/// Best level the hardware supports, ignoring the env override.
pub fn hardware_level() -> SimdLevel {
    if SimdLevel::Avx2.available() {
        SimdLevel::Avx2
    } else if SimdLevel::Neon.available() {
        SimdLevel::Neon
    } else {
        SimdLevel::Portable
    }
}

/// True when [`NO_SIMD_ENV`] asks for the portable path.
pub fn no_simd_env() -> bool {
    match std::env::var(NO_SIMD_ENV) {
        Ok(v) => !v.is_empty() && v != "0",
        Err(_) => false,
    }
}

/// Pure resolution step: what level an override flag + this hardware yield.
/// Split out so tests can exercise the override without mutating process
/// env (env mutation races other tests in the same binary).
pub fn resolve_level(no_simd: bool) -> SimdLevel {
    if no_simd {
        SimdLevel::Portable
    } else {
        hardware_level()
    }
}

/// Uncached detection: env override + hardware probe.
pub fn detect_level() -> SimdLevel {
    resolve_level(no_simd_env())
}

/// The process-wide level every `*/simd` backend dispatches on (cached on
/// first use).
pub fn active_level() -> SimdLevel {
    static LEVEL: OnceLock<SimdLevel> = OnceLock::new();
    *LEVEL.get_or_init(detect_level)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn names_roundtrip() {
        for l in [SimdLevel::Avx2, SimdLevel::Neon, SimdLevel::Portable] {
            assert_eq!(SimdLevel::parse(l.name()), Some(l));
        }
        assert_eq!(SimdLevel::parse("avx512-unicorn"), None);
    }

    #[test]
    fn portable_is_always_available() {
        assert!(SimdLevel::Portable.available());
        // The hardware level is by construction executable here.
        assert!(hardware_level().available());
    }

    #[test]
    fn override_forces_portable() {
        assert_eq!(resolve_level(true), SimdLevel::Portable);
        assert_eq!(resolve_level(false), hardware_level());
    }

    #[test]
    fn active_level_is_consistent_with_env() {
        // Whatever the cached decision was, it must match what the current
        // env + hardware resolve to (tests never mutate the env).
        assert_eq!(active_level(), detect_level());
        assert!(active_level().available());
    }
}
