//! Explicit-SIMD kernel subsystem: vectorized MatAdd / MatShift inner
//! loops behind runtime CPU-feature detection, with a portable fallback so
//! `matadd/simd` and `matshift/simd` exist on every platform.
//!
//! # Architecture
//!
//! - [`detect`] — resolves a [`SimdLevel`] once per process (AVX2 on
//!   x86-64, NEON on aarch64, portable otherwise), honoring the
//!   `SHIFTADD_NO_SIMD` env override (CI's forced-fallback knob).
//! - [`portable`] — chunked-`u64`/unrolled scalar cores, the guaranteed
//!   floor and the oracle the intrinsic cores are property-tested against.
//! - `x86` / `arm` — `core::arch` AVX2 and NEON cores behind
//!   `cfg(target_arch)` + `#[target_feature]`, reached only through the
//!   level-clamping dispatchers below (never without a runtime probe).
//! - [`MatAddSimd`] / [`MatShiftSimd`] — the registry backends: simd inner
//!   loops on the rowpar-style pool fan-out, including the grouped
//!   fork/join override the fused batched attention path dispatches
//!   through.
//!
//! # Correctness contract
//!
//! Every core vectorizes over output columns while walking `k` in serial
//! order, so each output element accumulates its contributions in exactly
//! the sequence the serial kernels use — the subsystem is **bit-exact** vs
//! `matadd/ref` and `matshift/ref` on every shape (enforced by
//! `rust/tests/prop_simd.rs` across odd shapes, non-multiple-of-lane-width
//! k/n, and every KSH bit width).

pub mod detect;
pub mod portable;

#[cfg(target_arch = "aarch64")]
mod arm;
#[cfg(target_arch = "x86_64")]
mod x86;

mod backends;

pub use backends::{MatAddSimd, MatShiftSimd};
pub use detect::{active_level, SimdLevel, NO_SIMD_ENV};

use crate::kernels::matadd::PackedPm1;
use crate::kernels::matshift::ShiftPlanes;

/// Clamp a requested level to what this host can actually execute — the
/// safety gate in front of the `target_feature` cores.
fn executable(level: SimdLevel) -> SimdLevel {
    if level.available() {
        level
    } else {
        SimdLevel::Portable
    }
}

/// ±1 MatAdd rows `r0..r1` at an explicit [`SimdLevel`] (clamped to this
/// host). Bit-exact across levels; tests use this to compare every
/// available core against the portable oracle.
pub fn matadd_pm1_rows_at(
    level: SimdLevel,
    x: &[f32],
    b: &PackedPm1,
    r0: usize,
    r1: usize,
) -> Vec<f32> {
    match executable(level) {
        #[cfg(target_arch = "x86_64")]
        // SAFETY: `executable` returned Avx2 only after a runtime probe.
        SimdLevel::Avx2 => unsafe { x86::matadd_pm1_rows_avx2(x, b, r0, r1) },
        #[cfg(target_arch = "aarch64")]
        // SAFETY: `executable` returned Neon only after a runtime probe.
        SimdLevel::Neon => unsafe { arm::matadd_pm1_rows_neon(x, b, r0, r1) },
        _ => portable::matadd_pm1_rows_portable(x, b, r0, r1),
    }
}

/// ±1 MatAdd rows at the process-wide [`active_level`] — the `matadd/simd`
/// backend's row core.
pub fn matadd_pm1_rows_simd(x: &[f32], b: &PackedPm1, r0: usize, r1: usize) -> Vec<f32> {
    matadd_pm1_rows_at(detect::active_level(), x, b, r0, r1)
}

/// MatShift rows `r0..r1` at an explicit [`SimdLevel`] (clamped to this
/// host). Bit-exact across levels.
pub fn matshift_rows_at(
    level: SimdLevel,
    xq: &[i32],
    w: &ShiftPlanes,
    r0: usize,
    r1: usize,
) -> Vec<i64> {
    match executable(level) {
        #[cfg(target_arch = "x86_64")]
        // SAFETY: `executable` returned Avx2 only after a runtime probe.
        SimdLevel::Avx2 => unsafe { x86::matshift_rows_avx2(xq, w, r0, r1) },
        #[cfg(target_arch = "aarch64")]
        // SAFETY: `executable` returned Neon only after a runtime probe.
        SimdLevel::Neon => unsafe { arm::matshift_rows_neon(xq, w, r0, r1) },
        _ => portable::matshift_rows_portable(xq, w, r0, r1),
    }
}

/// MatShift rows at the process-wide [`active_level`] — the
/// `matshift/simd` backend's row core.
pub fn matshift_rows_simd(xq: &[i32], w: &ShiftPlanes, r0: usize, r1: usize) -> Vec<i64> {
    matshift_rows_at(detect::active_level(), xq, w, r0, r1)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kernels::{matadd, matshift};
    use crate::quant::pow2;
    use crate::util::rng::XorShift64;

    #[test]
    fn unavailable_levels_clamp_to_portable() {
        // At most one intrinsic level is available on any host, so the
        // other must transparently fall back instead of hitting UB.
        let mut rng = XorShift64::new(4);
        let (m, k, n) = (3, 7, 11);
        let x = rng.normals(m * k);
        let codes: Vec<i8> = (0..k * n)
            .map(|_| if rng.uniform() < 0.5 { -1 } else { 1 })
            .collect();
        let packed = matadd::PackedPm1::pack(&codes, k, n);
        let want = matadd::matadd_pm1_rows(&x, &packed, 0, m);
        for level in [SimdLevel::Avx2, SimdLevel::Neon, SimdLevel::Portable] {
            assert_eq!(matadd_pm1_rows_at(level, &x, &packed, 0, m), want, "{level:?}");
        }
    }

    #[test]
    fn active_dispatch_matches_serial() {
        let mut rng = XorShift64::new(5);
        let (m, k, n) = (5, 9, 13);
        let xq: Vec<i32> = (0..m * k).map(|_| rng.range(0, 255) as i32 - 127).collect();
        let planes = matshift::ShiftPlanes::from_pow2(&pow2::quantize(&rng.normals(k * n), k, n));
        assert_eq!(
            matshift_rows_simd(&xq, &planes, 0, m),
            matshift::matshift_fast_rows(&xq, &planes, 0, m)
        );
    }
}
