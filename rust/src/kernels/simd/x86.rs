//! AVX2 cores for the `*/simd` backends (x86-64).
//!
//! Both kernels vectorize over **output columns** — 8 f32/i32 lanes per
//! tile — while walking `k` in the same order as the serial kernels, so
//! every output element accumulates its contributions in the identical
//! sequence and the results are bit-exact vs `matadd/ref` / `matshift/ref`
//! (IEEE lane adds are the same operation as the scalar `+`; integer lane
//! ops are wrapping, like the scalar cores).
//!
//! Every function here is `#[target_feature(enable = "avx2")]`: callers
//! must have runtime-verified AVX2 (see `detect::SimdLevel::available`)
//! before dispatching in — `simd::matadd_pm1_rows_at` is the only caller
//! and clamps unavailable levels to the portable core.

use std::arch::x86_64::{
    __m128i, __m256i, _mm256_add_epi32, _mm256_add_epi64, _mm256_add_ps, _mm256_castsi256_ps,
    _mm256_castsi256_si128, _mm256_cvtepi32_epi64, _mm256_cvtepu8_epi32, _mm256_extracti128_si256,
    _mm256_loadu_si256, _mm256_set1_epi32, _mm256_setzero_ps, _mm256_setzero_si256,
    _mm256_slli_epi32, _mm256_sllv_epi32, _mm256_storeu_ps, _mm256_storeu_si256, _mm256_sub_epi32,
    _mm256_xor_si256, _mm_loadl_epi64,
};

use crate::kernels::matadd::PackedPm1;
use crate::kernels::matshift::ShiftPlanes;
use crate::kernels::simd::portable::{matadd_pm1_tail, matshift_tail, BK, LANES};

/// AVX2 ±1 MatAdd row core: rows `r0..r1`, 8 columns per vector.
///
/// # Safety
/// The caller must have verified AVX2 support at runtime
/// (`SimdLevel::Avx2.available()`).
#[target_feature(enable = "avx2")]
pub(crate) unsafe fn matadd_pm1_rows_avx2(
    x: &[f32],
    b: &PackedPm1,
    r0: usize,
    r1: usize,
) -> Vec<f32> {
    let (k, n) = (b.k, b.n);
    assert!(r0 <= r1 && r1 * k <= x.len());
    let mut o = vec![0.0f32; (r1 - r0) * n];
    for r in r0..r1 {
        let xrow = &x[r * k..(r + 1) * k];
        let obase = (r - r0) * n;
        let mut c0 = 0usize;
        while c0 + LANES <= n {
            let mut acc = _mm256_setzero_ps();
            for (kk, xv) in xrow.iter().enumerate() {
                let xb = _mm256_set1_epi32(xv.to_bits() as i32);
                // 8 sign bytes → 8 u32 lanes → sign-bit masks (byte << 24)
                let sb = _mm_loadl_epi64(b.sign.as_ptr().add(kk * n + c0) as *const __m128i);
                let flip = _mm256_slli_epi32::<24>(_mm256_cvtepu8_epi32(sb));
                acc = _mm256_add_ps(acc, _mm256_castsi256_ps(_mm256_xor_si256(xb, flip)));
            }
            _mm256_storeu_ps(o.as_mut_ptr().add(obase + c0), acc);
            c0 += LANES;
        }
        for (c, out) in o[obase..obase + n].iter_mut().enumerate().skip(c0) {
            *out = matadd_pm1_tail(xrow, &b.sign, n, c);
        }
    }
    o
}

/// AVX2 MatShift row core: rows `r0..r1`, 8 columns per vector, the serial
/// kernel's `BK` k-tiling with an i32 vector tile flushed into two i64
/// vectors.
///
/// # Safety
/// The caller must have verified AVX2 support at runtime
/// (`SimdLevel::Avx2.available()`).
#[target_feature(enable = "avx2")]
pub(crate) unsafe fn matshift_rows_avx2(
    xq: &[i32],
    w: &ShiftPlanes,
    r0: usize,
    r1: usize,
) -> Vec<i64> {
    let (k, n) = (w.rows, w.cols);
    assert!(r0 <= r1 && r1 * k <= xq.len());
    let mut acc = vec![0i64; (r1 - r0) * n];
    for r in r0..r1 {
        let xrow = &xq[r * k..(r + 1) * k];
        let obase = (r - r0) * n;
        let mut c0 = 0usize;
        while c0 + LANES <= n {
            // i64 accumulators for columns c0..c0+4 and c0+4..c0+8
            let mut lo = _mm256_setzero_si256();
            let mut hi = _mm256_setzero_si256();
            for k0 in (0..k).step_by(BK) {
                let kend = (k0 + BK).min(k);
                let mut tile = _mm256_setzero_si256();
                for kk in k0..kend {
                    let xv = _mm256_set1_epi32(xrow[kk]);
                    let sh = _mm256_loadu_si256(w.sh.as_ptr().add(kk * n + c0) as *const __m256i);
                    let neg = _mm256_loadu_si256(w.neg.as_ptr().add(kk * n + c0) as *const __m256i);
                    let v = _mm256_sllv_epi32(xv, sh);
                    tile = _mm256_add_epi32(tile, _mm256_sub_epi32(_mm256_xor_si256(v, neg), neg));
                }
                let hi128 = _mm256_extracti128_si256::<1>(tile);
                lo = _mm256_add_epi64(lo, _mm256_cvtepi32_epi64(_mm256_castsi256_si128(tile)));
                hi = _mm256_add_epi64(hi, _mm256_cvtepi32_epi64(hi128));
            }
            _mm256_storeu_si256(acc.as_mut_ptr().add(obase + c0) as *mut __m256i, lo);
            _mm256_storeu_si256(acc.as_mut_ptr().add(obase + c0 + 4) as *mut __m256i, hi);
            c0 += LANES;
        }
        for (c, out) in acc[obase..obase + n].iter_mut().enumerate().skip(c0) {
            *out = matshift_tail(xrow, w, n, c);
        }
    }
    acc
}
