//! The `matadd/simd` and `matshift/simd` registry backends: the rowpar
//! scheduling skeleton (`kernels::parallel`) around the vectorized row
//! cores, so the backends get simd inner loops *and* the pool fan-out —
//! including the grouped fork/join override the fused batched attention
//! path dispatches through.
//!
//! Deployment formats are delegated to the serial backends
//! (`matadd/bitplane` → pm1 sign bytes, `matshift/planes` → shift/negate
//! planes) so the bit-exactness contract vs `matadd/ref` / `matshift/ref`
//! cannot drift: same weights, same operand preparation, same per-element
//! accumulation order, different instruction selection.

use crate::energy::ops::MacStyle;
use crate::kernels::api::{LinearKernel, Operand, PreparedWeights, Primitive, RawWeights};
use crate::kernels::backends::{MatAddBitplane, MatShiftPlanes, SHIFT_TOL};
use crate::kernels::parallel::{run_grouped_matadd_forked, run_matadd_rows, run_matshift_rows};
use crate::kernels::simd::{matadd_pm1_rows_simd, matshift_rows_simd};

/// `matadd/simd` — vectorized ±1 MatAdd (AVX2 / NEON / portable) on the
/// shared pool.
pub struct MatAddSimd;

impl LinearKernel for MatAddSimd {
    fn primitive(&self) -> Primitive {
        Primitive::MatAdd
    }

    fn backend(&self) -> &'static str {
        "simd"
    }

    fn mac_style(&self) -> MacStyle {
        MacStyle::AddInt32
    }

    /// Same deployment format as the serial `matadd/bitplane` backend —
    /// delegated so the bit-exactness contract cannot drift.
    fn prepare(&self, w: &RawWeights) -> PreparedWeights {
        MatAddBitplane.prepare(w)
    }

    fn run(&self, w: &PreparedWeights, x: &Operand, out: &mut [f32]) {
        run_matadd_rows(matadd_pm1_rows_simd, "matadd/simd", w, x, out);
    }

    /// Fused grouped dispatch: all `G` small groups in ONE pool fork/join,
    /// each job running the simd row core (see
    /// [`run_grouped_matadd_forked`] for the scheduling contract).
    fn run_grouped(&self, ws: &[PreparedWeights], x: &[f32], m: usize, out: &mut [f32]) {
        run_grouped_matadd_forked(self, matadd_pm1_rows_simd, "matadd/simd", ws, x, m, out);
    }
}

/// `matshift/simd` — vectorized variable-shift MatShift (AVX2 / NEON /
/// portable) on the shared pool.
pub struct MatShiftSimd;

impl LinearKernel for MatShiftSimd {
    fn primitive(&self) -> Primitive {
        Primitive::MatShift
    }

    fn backend(&self) -> &'static str {
        "simd"
    }

    fn mac_style(&self) -> MacStyle {
        MacStyle::ShiftInt32
    }

    fn tolerance(&self) -> f32 {
        SHIFT_TOL
    }

    /// Same deployment format as the serial `matshift/planes` backend —
    /// delegated so the bit-exactness contract cannot drift.
    fn prepare(&self, w: &RawWeights) -> PreparedWeights {
        MatShiftPlanes.prepare(w)
    }

    fn prepare_operand(&self, x: &[f32], m: usize, k: usize) -> Operand {
        MatShiftPlanes.prepare_operand(x, m, k)
    }

    fn run(&self, w: &PreparedWeights, x: &Operand, out: &mut [f32]) {
        run_matshift_rows(matshift_rows_simd, "matshift/simd", w, x, out);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kernels::registry::KernelRegistry;
    use crate::util::rng::XorShift64;

    #[test]
    fn simd_backends_are_registered_with_defaults() {
        let r = KernelRegistry::with_defaults();
        assert_eq!(r.lookup("matadd/simd").unwrap().backend(), "simd");
        assert_eq!(r.lookup("matshift/simd").unwrap().backend(), "simd");
    }

    #[test]
    fn matadd_simd_matches_bitplane_bit_exactly() {
        let r = KernelRegistry::with_defaults();
        let simd = r.lookup("matadd/simd").unwrap();
        let serial = r.lookup("matadd/bitplane").unwrap();
        let mut rng = XorShift64::new(31);
        // spans the inline path and the pooled path
        for m in [3usize, 40] {
            let (k, n) = (11, 13);
            let raw = RawWeights::new(rng.normals(k * n), k, n);
            let x = rng.normals(m * k);
            let mut a = vec![0.0f32; m * n];
            let mut b = vec![0.0f32; m * n];
            simd.run(&simd.prepare(&raw), &simd.prepare_operand(&x, m, k), &mut a);
            serial.run(&serial.prepare(&raw), &serial.prepare_operand(&x, m, k), &mut b);
            assert_eq!(a, b, "m={m}");
        }
    }

    #[test]
    fn matshift_simd_matches_planes_bit_exactly() {
        let r = KernelRegistry::with_defaults();
        let simd = r.lookup("matshift/simd").unwrap();
        let serial = r.lookup("matshift/planes").unwrap();
        let mut rng = XorShift64::new(37);
        for m in [5usize, 48] {
            let (k, n) = (9, 10);
            let raw = RawWeights::new(rng.normals(k * n), k, n);
            let x = rng.normals(m * k);
            // one shared quantized operand so both see identical INT8 data
            let op = Operand::quantized(&x, m, k);
            let mut a = vec![0.0f32; m * n];
            let mut b = vec![0.0f32; m * n];
            simd.run(&simd.prepare(&raw), &op, &mut a);
            serial.run(&serial.prepare(&raw), &op, &mut b);
            assert_eq!(a, b, "m={m}");
        }
    }
}
