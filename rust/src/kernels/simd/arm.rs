//! NEON cores for the `*/simd` backends (aarch64).
//!
//! Same structure as the AVX2 cores: vectorize over output columns, walk
//! `k` in serial order, so per-element accumulation sequences — and hence
//! the results — are bit-exact vs `matadd/ref` / `matshift/ref`. MatAdd
//! uses an 8-wide column tile (two 4-lane vectors, matching the shared
//! `LANES` block and the one-`u64`-of-sign-bytes load); MatShift uses a
//! 4-wide tile (its shift/negate planes are i32, one vector per load).
//!
//! Every function is `#[target_feature(enable = "neon")]` — NEON is
//! baseline on aarch64, but dispatch still goes through the runtime
//! `detect` gate so `SHIFTADD_NO_SIMD` can force the portable core.

use std::arch::aarch64::{
    vaddq_f32, vaddq_s32, vaddq_s64, vdupq_n_f32, vdupq_n_s32, vdupq_n_s64, vdupq_n_u32,
    veorq_s32, veorq_u32, vget_high_s32, vget_high_u16, vget_low_s32, vget_low_u16, vld1_u8,
    vld1q_s32, vmovl_s32, vmovl_u16, vmovl_u8, vreinterpretq_f32_u32, vshlq_n_u32, vshlq_s32,
    vst1q_f32, vst1q_s64, vsubq_s32,
};

use crate::kernels::matadd::PackedPm1;
use crate::kernels::matshift::ShiftPlanes;
use crate::kernels::simd::portable::{matadd_pm1_tail, matshift_tail, BK, LANES};

/// NEON ±1 MatAdd row core: rows `r0..r1`, 8 columns per tile (two 4-lane
/// accumulators).
///
/// # Safety
/// The caller must have verified NEON support at runtime
/// (`SimdLevel::Neon.available()`).
#[target_feature(enable = "neon")]
pub(crate) unsafe fn matadd_pm1_rows_neon(
    x: &[f32],
    b: &PackedPm1,
    r0: usize,
    r1: usize,
) -> Vec<f32> {
    let (k, n) = (b.k, b.n);
    assert!(r0 <= r1 && r1 * k <= x.len());
    let mut o = vec![0.0f32; (r1 - r0) * n];
    for r in r0..r1 {
        let xrow = &x[r * k..(r + 1) * k];
        let obase = (r - r0) * n;
        let mut c0 = 0usize;
        while c0 + LANES <= n {
            let mut acc0 = vdupq_n_f32(0.0);
            let mut acc1 = vdupq_n_f32(0.0);
            for (kk, xv) in xrow.iter().enumerate() {
                let xb = vdupq_n_u32(xv.to_bits());
                // 8 sign bytes → u16x8 → two u32x4 sign-bit masks
                let sw = vmovl_u8(vld1_u8(b.sign.as_ptr().add(kk * n + c0)));
                let flip0 = vshlq_n_u32::<24>(vmovl_u16(vget_low_u16(sw)));
                let flip1 = vshlq_n_u32::<24>(vmovl_u16(vget_high_u16(sw)));
                acc0 = vaddq_f32(acc0, vreinterpretq_f32_u32(veorq_u32(xb, flip0)));
                acc1 = vaddq_f32(acc1, vreinterpretq_f32_u32(veorq_u32(xb, flip1)));
            }
            vst1q_f32(o.as_mut_ptr().add(obase + c0), acc0);
            vst1q_f32(o.as_mut_ptr().add(obase + c0 + 4), acc1);
            c0 += LANES;
        }
        for (c, out) in o[obase..obase + n].iter_mut().enumerate().skip(c0) {
            *out = matadd_pm1_tail(xrow, &b.sign, n, c);
        }
    }
    o
}

/// NEON MatShift row core: rows `r0..r1`, 4 columns per tile, the serial
/// kernel's `BK` k-tiling with an i32 vector tile flushed into two i64
/// vectors.
///
/// # Safety
/// The caller must have verified NEON support at runtime
/// (`SimdLevel::Neon.available()`).
#[target_feature(enable = "neon")]
pub(crate) unsafe fn matshift_rows_neon(
    xq: &[i32],
    w: &ShiftPlanes,
    r0: usize,
    r1: usize,
) -> Vec<i64> {
    let (k, n) = (w.rows, w.cols);
    assert!(r0 <= r1 && r1 * k <= xq.len());
    const CN: usize = 4;
    let mut acc = vec![0i64; (r1 - r0) * n];
    for r in r0..r1 {
        let xrow = &xq[r * k..(r + 1) * k];
        let obase = (r - r0) * n;
        let mut c0 = 0usize;
        while c0 + CN <= n {
            // i64 accumulators for columns c0..c0+2 and c0+2..c0+4
            let mut lo = vdupq_n_s64(0);
            let mut hi = vdupq_n_s64(0);
            for k0 in (0..k).step_by(BK) {
                let kend = (k0 + BK).min(k);
                let mut tile = vdupq_n_s32(0);
                for kk in k0..kend {
                    let xv = vdupq_n_s32(xrow[kk]);
                    let sh = vld1q_s32(w.sh.as_ptr().add(kk * n + c0));
                    let neg = vld1q_s32(w.neg.as_ptr().add(kk * n + c0));
                    // vshlq_s32: per-lane left shift (all counts ≥ 0 here)
                    let v = vshlq_s32(xv, sh);
                    tile = vaddq_s32(tile, vsubq_s32(veorq_s32(v, neg), neg));
                }
                lo = vaddq_s64(lo, vmovl_s32(vget_low_s32(tile)));
                hi = vaddq_s64(hi, vmovl_s32(vget_high_s32(tile)));
            }
            vst1q_s64(acc.as_mut_ptr().add(obase + c0), lo);
            vst1q_s64(acc.as_mut_ptr().add(obase + c0 + 2), hi);
            c0 += CN;
        }
        for (c, out) in acc[obase..obase + n].iter_mut().enumerate().skip(c0) {
            *out = matshift_tail(xrow, w, n, c);
        }
    }
    acc
}
