//! Dense f32 matmul baseline (the "PyTorch/TVM MatMul" comparator of
//! Fig. 4/5), with a cache-blocked inner loop so the comparison against
//! MatShift/MatAdd is honest.

/// `o (m×n) = x (m×k) @ w (k×n)`, row-major, cache-blocked.
pub fn matmul_f32(x: &[f32], w: &[f32], m: usize, k: usize, n: usize) -> Vec<f32> {
    assert_eq!(x.len(), m * k);
    assert_eq!(w.len(), k * n);
    let mut o = vec![0.0f32; m * n];
    const BK: usize = 64;
    for k0 in (0..k).step_by(BK) {
        let kend = (k0 + BK).min(k);
        for r in 0..m {
            let xrow = &x[r * k..(r + 1) * k];
            let orow = &mut o[r * n..(r + 1) * n];
            for kk in k0..kend {
                let xv = xrow[kk];
                if xv == 0.0 {
                    continue;
                }
                let wrow = &w[kk * n..(kk + 1) * n];
                for (ov, wv) in orow.iter_mut().zip(wrow) {
                    *ov += xv * wv;
                }
            }
        }
    }
    o
}

/// Batched wrapper: x (b×m×k) @ w (k×n) → (b×m×n); weights shared across
/// the batch (the MLP/Linear case of Fig. 4).
pub fn bmm_shared(x: &[f32], w: &[f32], b: usize, m: usize, k: usize, n: usize) -> Vec<f32> {
    let mut out = Vec::with_capacity(b * m * n);
    for bi in 0..b {
        out.extend(matmul_f32(&x[bi * m * k..(bi + 1) * m * k], w, m, k, n));
    }
    out
}

/// Naive reference (no blocking) for oracle tests.
pub fn matmul_naive(x: &[f32], w: &[f32], m: usize, k: usize, n: usize) -> Vec<f32> {
    let mut o = vec![0.0f32; m * n];
    for r in 0..m {
        for c in 0..n {
            let mut acc = 0.0;
            for kk in 0..k {
                acc += x[r * k + kk] * w[kk * n + c];
            }
            o[r * n + c] = acc;
        }
    }
    o
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::{assert_close, check};

    #[test]
    fn blocked_matches_naive() {
        check("matmul-blocked-vs-naive", 30, 24, |rng, size| {
            let (m, k, n) = (size, size + 3, size + 1);
            let x = rng.normals(m * k);
            let w = rng.normals(k * n);
            assert_close(
                &matmul_f32(&x, &w, m, k, n),
                &matmul_naive(&x, &w, m, k, n),
                1e-4,
            )
        });
    }

    #[test]
    fn identity_matmul() {
        let m = 4;
        let mut eye = vec![0.0; m * m];
        for i in 0..m {
            eye[i * m + i] = 1.0;
        }
        let x: Vec<f32> = (0..m * m).map(|i| i as f32).collect();
        assert_eq!(matmul_f32(&x, &eye, m, m, m), x);
    }

    #[test]
    fn batched_equals_per_slice() {
        let (b, m, k, n) = (3, 4, 5, 6);
        let x: Vec<f32> = (0..b * m * k).map(|i| (i % 7) as f32 - 3.0).collect();
        let w: Vec<f32> = (0..k * n).map(|i| (i % 5) as f32 - 2.0).collect();
        let full = bmm_shared(&x, &w, b, m, k, n);
        for bi in 0..b {
            let one = matmul_f32(&x[bi * m * k..(bi + 1) * m * k], &w, m, k, n);
            assert_eq!(&full[bi * m * n..(bi + 1) * m * n], &one[..]);
        }
    }
}
