//! FakeShift baseline [17]: floating-point multiplication with power-of-two
//! weights — the "PyTorch/TVM FakeShift" comparator in Fig. 4/7. Same
//! numerics as MatShift but executes real multiplies against f32-expanded
//! weights, so it moves 4 bytes/weight and spends a mult per MAC.

use crate::quant::pow2::{dequantize, Pow2Weights};

/// `o = x @ dequantize(w)` — float multiply against expanded pow2 weights.
pub fn fakeshift_f32(x: &[f32], w: &Pow2Weights, m: usize) -> Vec<f32> {
    let wf = dequantize(w);
    crate::kernels::matmul::matmul_f32(x, &wf, m, w.rows, w.cols)
}

/// FakeShift with the expansion done *inside* the loop (no cached dequant) —
/// mirrors a naive PyTorch `x @ (s * 2**p)` graph that re-materializes the
/// float weight every call.
pub fn fakeshift_rematerialize(x: &[f32], w: &Pow2Weights, m: usize) -> Vec<f32> {
    let (k, n) = (w.rows, w.cols);
    let mut o = vec![0.0f32; m * n];
    for r in 0..m {
        let xrow = &x[r * k..(r + 1) * k];
        let orow = &mut o[r * n..(r + 1) * n];
        for kk in 0..k {
            let xv = xrow[kk];
            for c in 0..n {
                let wv = w.sign[kk * n + c] as f32 * (w.exp[kk * n + c] as f32).exp2();
                orow[c] += xv * wv;
            }
        }
    }
    o
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::pow2::quantize;
    use crate::util::prop::{assert_close, check};

    #[test]
    fn both_fakeshift_variants_agree() {
        check("fakeshift-variants", 20, 16, |rng, size| {
            let (m, k, n) = (size, size + 1, size);
            let x = rng.normals(m * k);
            let w = quantize(&rng.normals(k * n), k, n);
            assert_close(
                &fakeshift_f32(&x, &w, m),
                &fakeshift_rematerialize(&x, &w, m),
                1e-4,
            )
        });
    }
}
