//! The unified kernel API: one trait for every multiplication primitive.
//!
//! A [`LinearKernel`] is a named implementation ("backend") of one
//! [`Primitive`] under a uniform `(m, k, n)` shape contract:
//!
//! - [`LinearKernel::prepare`] — one-time conversion of raw f32 weights into
//!   the backend's deployment format (f32 copy, pow2 shift planes, ±1
//!   bitplanes, …). This is model-conversion work, never on the hot path.
//! - [`LinearKernel::prepare_operand`] — per-call activation layout (and the
//!   place INT8 activation quantization happens, outside any timed region).
//! - [`LinearKernel::run`] — `out (m×n) = x (m×k) @ W (k×n)` against the
//!   prepared formats.
//!
//! Backends self-describe their Eyeriss [`MacStyle`] and their numeric
//! [`LinearKernel::tolerance`] vs the dense oracle, so energy accounting and
//! the property suite derive from the registry instead of hardcoded tags.
//! Payloads are `Arc`-shared: row-parallel backends hand them to pool
//! workers without copying.

use std::sync::Arc;

use crate::energy::ops::MacStyle;
use crate::kernels::matadd::{PackedB, PackedPm1};
use crate::kernels::matshift::{PREC, ShiftPlanes};
use crate::quant::int8::Int8Quant;
use crate::quant::pow2::{self, Pow2Weights};

/// The paper's multiplication-primitive families.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum Primitive {
    MatMul,
    MatAdd,
    MatShift,
    FakeShift,
}

impl Primitive {
    pub const ALL: [Primitive; 4] = [
        Primitive::MatMul,
        Primitive::MatAdd,
        Primitive::MatShift,
        Primitive::FakeShift,
    ];

    pub fn name(self) -> &'static str {
        match self {
            Primitive::MatMul => "matmul",
            Primitive::MatAdd => "matadd",
            Primitive::MatShift => "matshift",
            Primitive::FakeShift => "fakeshift",
        }
    }

    /// Inverse of [`Primitive::name`] (used by `"primitive/backend"` ids).
    pub fn parse(s: &str) -> Option<Primitive> {
        Primitive::ALL.iter().copied().find(|p| p.name() == s)
    }
}

/// Raw dense f32 weights (k×n row-major) — the conversion-time input every
/// backend's [`LinearKernel::prepare`] consumes.
#[derive(Clone, Debug)]
pub struct RawWeights {
    pub k: usize,
    pub n: usize,
    pub data: Vec<f32>,
}

impl RawWeights {
    pub fn new(data: Vec<f32>, k: usize, n: usize) -> RawWeights {
        assert_eq!(data.len(), k * n, "weight buffer is not k*n");
        RawWeights { k, n, data }
    }
}

/// Deployment weight formats a backend's `prepare` can produce.
#[derive(Clone, Debug)]
pub enum PreparedWeights {
    /// Plain f32 (MatMul baselines, cached FakeShift).
    Dense {
        k: usize,
        n: usize,
        w: Arc<Vec<f32>>,
    },
    /// (sign, exponent) INT8 planes — MatShift reference format.
    Pow2(Arc<Pow2Weights>),
    /// Branchless shift/negate planes — MatShift deployment format.
    Planes(Arc<ShiftPlanes>),
    /// {-1, 0, +1} codes — MatAdd reference format.
    Ternary {
        k: usize,
        n: usize,
        b: Arc<Vec<i8>>,
    },
    /// Sign/nonzero bit-masks — ternary MatAdd deployment format.
    Packed(Arc<PackedB>),
    /// ±1 sign bytes — binary MatAdd deployment format.
    Pm1(Arc<PackedPm1>),
}

impl PreparedWeights {
    pub fn k(&self) -> usize {
        match self {
            PreparedWeights::Dense { k, .. } | PreparedWeights::Ternary { k, .. } => *k,
            PreparedWeights::Pow2(w) => w.rows,
            PreparedWeights::Planes(p) => p.rows,
            PreparedWeights::Packed(p) => p.k,
            PreparedWeights::Pm1(p) => p.k,
        }
    }

    pub fn n(&self) -> usize {
        match self {
            PreparedWeights::Dense { n, .. } | PreparedWeights::Ternary { n, .. } => *n,
            PreparedWeights::Pow2(w) => w.cols,
            PreparedWeights::Planes(p) => p.cols,
            PreparedWeights::Packed(p) => p.n,
            PreparedWeights::Pm1(p) => p.n,
        }
    }

    /// Short format tag for diagnostics (panic messages, JSON dumps).
    pub fn variant_name(&self) -> &'static str {
        match self {
            PreparedWeights::Dense { .. } => "dense",
            PreparedWeights::Pow2(_) => "pow2",
            PreparedWeights::Planes(_) => "planes",
            PreparedWeights::Ternary { .. } => "ternary",
            PreparedWeights::Packed(_) => "packed",
            PreparedWeights::Pm1(_) => "pm1",
        }
    }

    /// The effective dense weights this prepared form encodes — the oracle
    /// operand: every backend must satisfy `run(w, x) ≈ x @ w.dense()`
    /// within its declared [`LinearKernel::tolerance`].
    pub fn dense(&self) -> Vec<f32> {
        match self {
            PreparedWeights::Dense { w, .. } => w.as_ref().clone(),
            PreparedWeights::Pow2(w) => pow2::dequantize(w),
            PreparedWeights::Planes(p) => p
                .sh
                .iter()
                .zip(&p.neg)
                .map(|(&sh, &neg)| {
                    let mag = ((sh - PREC as i32) as f32).exp2();
                    if neg != 0 {
                        -mag
                    } else {
                        mag
                    }
                })
                .collect(),
            PreparedWeights::Ternary { b, .. } => b.iter().map(|&v| v as f32).collect(),
            PreparedWeights::Packed(p) => p
                .sign
                .iter()
                .zip(&p.nz)
                .map(|(&s, &nz)| {
                    if nz == 0 {
                        0.0
                    } else if s != 0 {
                        -1.0
                    } else {
                        1.0
                    }
                })
                .collect(),
            PreparedWeights::Pm1(p) => p
                .sign
                .iter()
                .map(|&s| if s != 0 { -1.0 } else { 1.0 })
                .collect(),
        }
    }
}

/// Activations in the layout a backend's `run` consumes.
#[derive(Clone, Debug)]
pub enum Operand {
    F32 {
        m: usize,
        k: usize,
        x: Arc<Vec<f32>>,
    },
    /// INT8-quantized activations widened to i32, plus the dequant scale.
    Int8 {
        m: usize,
        k: usize,
        xq: Arc<Vec<i32>>,
        scale: f32,
    },
}

impl Operand {
    pub fn from_f32(x: &[f32], m: usize, k: usize) -> Operand {
        assert_eq!(x.len(), m * k, "operand buffer is not m*k");
        Operand::F32 {
            m,
            k,
            x: Arc::new(x.to_vec()),
        }
    }

    /// INT8-quantize (per-tensor symmetric) — the shift backends' layout.
    pub fn quantized(x: &[f32], m: usize, k: usize) -> Operand {
        Operand::quantized_with_scale(x, m, k, Int8Quant::calibrate(x).scale)
    }

    /// INT8-quantize with a caller-fixed scale instead of per-tensor
    /// calibration — row-independent, so outputs do not depend on which
    /// rows share the operand (the streaming session path's requirement).
    pub fn quantized_with_scale(x: &[f32], m: usize, k: usize, scale: f32) -> Operand {
        assert_eq!(x.len(), m * k, "operand buffer is not m*k");
        let q = Int8Quant { scale };
        let xq: Vec<i32> = q.quantize(x).iter().map(|&v| v as i32).collect();
        Operand::Int8 {
            m,
            k,
            xq: Arc::new(xq),
            scale,
        }
    }

    pub fn m(&self) -> usize {
        match self {
            Operand::F32 { m, .. } | Operand::Int8 { m, .. } => *m,
        }
    }

    pub fn k(&self) -> usize {
        match self {
            Operand::F32 { k, .. } | Operand::Int8 { k, .. } => *k,
        }
    }
}

/// One backend of one primitive, under the uniform `(m, k, n)` contract.
///
/// Implementations are stateless values registered in a
/// [`crate::kernels::registry::KernelRegistry`]; callers select them by
/// `"primitive/backend"` id or let the
/// [`crate::kernels::planner::Planner`] pick the fastest for a shape.
pub trait LinearKernel: Send + Sync {
    fn primitive(&self) -> Primitive;

    /// Backend name within the primitive, e.g. `"blocked"`, `"rowpar"`.
    fn backend(&self) -> &'static str;

    /// Registry id: `"primitive/backend"`.
    fn id(&self) -> String {
        format!("{}/{}", self.primitive().name(), self.backend())
    }

    /// Hardware MAC style of this backend's deployment target — feeds the
    /// Eyeriss op counting (`model::ops::PrimitiveStyles`).
    fn mac_style(&self) -> MacStyle;

    /// Max elementwise relative error of `run` vs `x @ prepare(w).dense()`
    /// (the property-suite bound). Backends that quantize activations
    /// override this with their INT8 error budget.
    fn tolerance(&self) -> f32 {
        1e-4
    }

    /// One-time weight conversion into this backend's deployment format.
    fn prepare(&self, w: &RawWeights) -> PreparedWeights;

    /// Per-call activation layout; default is a plain f32 copy.
    fn prepare_operand(&self, x: &[f32], m: usize, k: usize) -> Operand {
        Operand::from_f32(x, m, k)
    }

    /// `out (m×n) = x (m×k) @ w (k×n)`. Panics if handed weight/operand
    /// variants this backend's `prepare`/`prepare_operand` does not produce.
    fn run(&self, w: &PreparedWeights, x: &Operand, out: &mut [f32]);

    /// One **fused grouped dispatch**: `G = ws.len()` independent same-shape
    /// problems `out_g (m×n) = x_g (m×k) @ w_g (k×n)` in a single call. The
    /// operand is packed group-major (`x`: G·m×k, group `g` owning rows
    /// `g·m..(g+1)·m`) and the output is packed the same way. This is the
    /// entry point the batched image-path attention uses to issue one
    /// MatAdd call per layer instead of one per (image, head) — the weights
    /// (the ±1 Q/K code matrices) differ per group, which is why plain
    /// row-stacking into one `run` cannot express it.
    ///
    /// The default walks the groups over [`LinearKernel::run`], so it is
    /// bit-exact against per-group dispatch by construction. Backends may
    /// override it to sweep every group in one parallel fork/join (see
    /// `matadd/rowpar` and `matadd/simd`, both built on
    /// `parallel::run_grouped_matadd_forked`), provided per-row
    /// accumulation order is unchanged.
    fn run_grouped(&self, ws: &[PreparedWeights], x: &[f32], m: usize, out: &mut [f32]) {
        let (_, k, n) = check_grouped_shapes(ws, x.len(), out.len(), m);
        for (gi, w) in ws.iter().enumerate() {
            let op = self.prepare_operand(&x[gi * m * k..(gi + 1) * m * k], m, k);
            self.run(w, &op, &mut out[gi * m * n..(gi + 1) * m * n]);
        }
    }
}

/// Validate a grouped dispatch's packing: every group shares one `(k, n)`,
/// the operand is G·m·k and the output G·m·n. Returns `(G, k, n)`.
pub fn check_grouped_shapes(
    ws: &[PreparedWeights],
    x_len: usize,
    out_len: usize,
    m: usize,
) -> (usize, usize, usize) {
    let g = ws.len();
    assert!(g > 0, "run_grouped: no groups");
    let (k, n) = (ws[0].k(), ws[0].n());
    assert!(
        ws.iter().all(|w| w.k() == k && w.n() == n),
        "run_grouped: groups must share one (k, n) shape"
    );
    assert_eq!(x_len, g * m * k, "run_grouped: operand is not G·m·k");
    assert_eq!(out_len, g * m * n, "run_grouped: output is not G·m·n");
    (g, k, n)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn primitive_names_roundtrip() {
        for p in Primitive::ALL {
            assert_eq!(Primitive::parse(p.name()), Some(p));
        }
        assert_eq!(Primitive::parse("conv"), None);
    }

    #[test]
    fn dense_of_planes_matches_pow2_dequant() {
        let wf = vec![1.0f32, -2.0, 0.25, -0.5];
        let q = pow2::quantize(&wf, 2, 2);
        let planes = PreparedWeights::Planes(Arc::new(ShiftPlanes::from_pow2(&q)));
        assert_eq!(planes.dense(), pow2::dequantize(&q));
        assert_eq!(planes.k(), 2);
        assert_eq!(planes.n(), 2);
    }

    #[test]
    fn dense_of_packed_forms() {
        let b = vec![1i8, -1, 0, 1];
        let packed = PreparedWeights::Packed(Arc::new(PackedB::pack(&b, 2, 2)));
        assert_eq!(packed.dense(), vec![1.0, -1.0, 0.0, 1.0]);
        let pm1 = vec![1i8, -1, -1, 1];
        let p = PreparedWeights::Pm1(Arc::new(PackedPm1::pack(&pm1, 2, 2)));
        assert_eq!(p.dense(), vec![1.0, -1.0, -1.0, 1.0]);
    }

    #[test]
    fn quantized_operand_carries_scale() {
        let x = vec![0.0f32, 63.5, -127.0, 12.0];
        let op = Operand::quantized(&x, 2, 2);
        match op {
            Operand::Int8 { m, k, xq, scale } => {
                assert_eq!((m, k), (2, 2));
                assert_eq!(xq.len(), 4);
                assert!((scale - 1.0).abs() < 1e-6);
                assert_eq!(xq[2], -127);
            }
            _ => panic!("expected Int8 operand"),
        }
    }
}
