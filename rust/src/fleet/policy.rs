//! Routing policies: which worker gets the next request.
//!
//! Policies are pure decision functions over a snapshot of worker state
//! ([`WorkerView`]), so they are unit-testable without threads, and every
//! source of arbitrariness is a seeded RNG — placement is reproducible for
//! a given seed and call sequence.

use crate::util::rng::XorShift64;

use anyhow::Result;

/// One worker as the policy sees it: id, whether it currently admits
/// requests, and its load gauge (queued + in-flight requests).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct WorkerView {
    pub id: usize,
    pub ready: bool,
    pub load: usize,
}

/// A load-balancing decision procedure. `shape_key` is a stable
/// fingerprint of the request's shape (pixel count for images, token-buffer
/// length for streams) — only [`Affinity`] uses it. Views arrive sorted by
/// worker id; the policy returns the chosen worker's id, or `None` when no
/// worker is ready.
pub trait RoutingPolicy: Send {
    fn name(&self) -> &'static str;
    fn pick(&mut self, shape_key: u64, workers: &[WorkerView]) -> Option<usize>;
}

/// Which policy the router runs (CLI/config surface).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum PolicyKind {
    RoundRobin,
    LeastLoaded,
    Affinity,
}

impl PolicyKind {
    pub fn parse(s: &str) -> Result<PolicyKind> {
        match s {
            "round-robin" => Ok(PolicyKind::RoundRobin),
            "least-loaded" => Ok(PolicyKind::LeastLoaded),
            "affinity" => Ok(PolicyKind::Affinity),
            other => anyhow::bail!(
                "unknown routing policy '{other}' (round-robin|least-loaded|affinity)"
            ),
        }
    }

    pub fn name(self) -> &'static str {
        match self {
            PolicyKind::RoundRobin => "round-robin",
            PolicyKind::LeastLoaded => "least-loaded",
            PolicyKind::Affinity => "affinity",
        }
    }

    /// Instantiate the policy. `seed` feeds every tiebreak, so two routers
    /// built with the same seed place identical request sequences
    /// identically.
    pub fn build(self, seed: u64) -> Box<dyn RoutingPolicy> {
        match self {
            PolicyKind::RoundRobin => Box::new(RoundRobin::new()),
            PolicyKind::LeastLoaded => Box::new(LeastLoaded::new(seed)),
            PolicyKind::Affinity => Box::new(Affinity::new(seed)),
        }
    }
}

/// Cycle over the ready workers in id order.
pub struct RoundRobin {
    cursor: usize,
}

impl RoundRobin {
    pub fn new() -> RoundRobin {
        RoundRobin { cursor: 0 }
    }
}

impl Default for RoundRobin {
    fn default() -> Self {
        RoundRobin::new()
    }
}

impl RoutingPolicy for RoundRobin {
    fn name(&self) -> &'static str {
        "round-robin"
    }

    fn pick(&mut self, _shape_key: u64, workers: &[WorkerView]) -> Option<usize> {
        let ready: Vec<&WorkerView> = workers.iter().filter(|w| w.ready).collect();
        if ready.is_empty() {
            return None;
        }
        let chosen = ready[self.cursor % ready.len()].id;
        self.cursor = self.cursor.wrapping_add(1);
        Some(chosen)
    }
}

/// Fewest queued + in-flight requests; ties broken by a seeded draw (the
/// RNG only advances on an actual tie, so tie-free sequences are
/// placement-identical across seeds).
pub struct LeastLoaded {
    rng: XorShift64,
}

impl LeastLoaded {
    pub fn new(seed: u64) -> LeastLoaded {
        LeastLoaded {
            rng: XorShift64::new(seed | 1),
        }
    }
}

impl RoutingPolicy for LeastLoaded {
    fn name(&self) -> &'static str {
        "least-loaded"
    }

    fn pick(&mut self, _shape_key: u64, workers: &[WorkerView]) -> Option<usize> {
        let ready: Vec<&WorkerView> = workers.iter().filter(|w| w.ready).collect();
        let min = ready.iter().map(|w| w.load).min()?;
        let cands: Vec<usize> = ready
            .iter()
            .filter(|w| w.load == min)
            .map(|w| w.id)
            .collect();
        if cands.len() == 1 {
            return Some(cands[0]);
        }
        Some(cands[self.rng.next_u64() as usize % cands.len()])
    }
}

/// Stable shape → worker pinning: equal request shapes land on one worker
/// (per-worker planner tables and warmed caches stay hot), different
/// shapes spread by hash. Remaps only when the ready set changes.
pub struct Affinity {
    seed: u64,
}

impl Affinity {
    pub fn new(seed: u64) -> Affinity {
        Affinity { seed }
    }
}

/// FNV-1a over the little-endian bytes of `x`, offset by `seed`.
fn fnv1a(x: u64, seed: u64) -> u64 {
    let mut h = 0xcbf2_9ce4_8422_2325u64 ^ seed;
    for b in x.to_le_bytes() {
        h ^= b as u64;
        h = h.wrapping_mul(0x1_0000_0000_01b3);
    }
    h
}

impl RoutingPolicy for Affinity {
    fn name(&self) -> &'static str {
        "affinity"
    }

    fn pick(&mut self, shape_key: u64, workers: &[WorkerView]) -> Option<usize> {
        let ready: Vec<&WorkerView> = workers.iter().filter(|w| w.ready).collect();
        if ready.is_empty() {
            return None;
        }
        let h = fnv1a(shape_key, self.seed);
        Some(ready[h as usize % ready.len()].id)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn views(loads: &[(usize, bool, usize)]) -> Vec<WorkerView> {
        loads
            .iter()
            .map(|&(id, ready, load)| WorkerView { id, ready, load })
            .collect()
    }

    #[test]
    fn round_robin_cycles_ready_workers_in_id_order() {
        let ws = views(&[(0, true, 0), (1, true, 0), (2, true, 0)]);
        let mut p = RoundRobin::new();
        let picks: Vec<usize> = (0..7).map(|_| p.pick(0, &ws).unwrap()).collect();
        assert_eq!(picks, vec![0, 1, 2, 0, 1, 2, 0]);
    }

    #[test]
    fn round_robin_skips_not_ready_and_handles_empty() {
        let ws = views(&[(0, false, 0), (1, true, 0), (2, true, 0)]);
        let mut p = RoundRobin::new();
        let picks: Vec<usize> = (0..4).map(|_| p.pick(0, &ws).unwrap()).collect();
        assert_eq!(picks, vec![1, 2, 1, 2]);
        assert_eq!(p.pick(0, &views(&[(0, false, 0)])), None);
        assert_eq!(p.pick(0, &[]), None);
    }

    #[test]
    fn least_loaded_picks_the_minimum() {
        let mut p = LeastLoaded::new(7);
        let ws = views(&[(0, true, 2), (1, true, 0), (2, true, 1)]);
        assert_eq!(p.pick(0, &ws), Some(1));
        // not-ready workers never win, even at zero load
        let ws = views(&[(0, false, 0), (1, true, 3), (2, true, 5)]);
        assert_eq!(p.pick(0, &ws), Some(1));
    }

    #[test]
    fn least_loaded_tiebreak_is_seed_deterministic() {
        let ws = views(&[(0, true, 1), (1, true, 1), (2, true, 1), (3, true, 1)]);
        let seq = |seed: u64| -> Vec<usize> {
            let mut p = LeastLoaded::new(seed);
            (0..16).map(|_| p.pick(0, &ws).unwrap()).collect()
        };
        assert_eq!(seq(42), seq(42), "same seed, same placement");
        // a tie among 4 workers over 16 draws lands on more than one worker
        let s = seq(42);
        assert!(s.iter().any(|&w| w != s[0]), "tiebreak must spread");
    }

    #[test]
    fn least_loaded_rng_only_advances_on_ties() {
        // Tie-free sequences are placement-identical across seeds.
        let ws = views(&[(0, true, 3), (1, true, 1), (2, true, 2)]);
        let mut a = LeastLoaded::new(1);
        let mut b = LeastLoaded::new(999);
        for _ in 0..8 {
            assert_eq!(a.pick(0, &ws), b.pick(0, &ws));
        }
    }

    #[test]
    fn affinity_pins_equal_shapes_and_spreads_distinct_ones() {
        let ws = views(&[(0, true, 0), (1, true, 0), (2, true, 0), (3, true, 0)]);
        let mut p = Affinity::new(0xA11F);
        let first = p.pick(2352, &ws).unwrap();
        for _ in 0..10 {
            assert_eq!(p.pick(2352, &ws), Some(first), "equal shapes stay pinned");
        }
        // many distinct shapes reach more than one worker
        let hit: std::collections::BTreeSet<usize> =
            (0..64u64).map(|k| p.pick(k * 97 + 5, &ws).unwrap()).collect();
        assert!(hit.len() > 1, "distinct shapes must spread across the fleet");
    }

    #[test]
    fn affinity_remaps_when_the_pinned_worker_leaves() {
        let mut p = Affinity::new(9);
        let all = views(&[(0, true, 0), (1, true, 0), (2, true, 0)]);
        let pinned = p.pick(77, &all).unwrap();
        let mut shrunk = all.clone();
        shrunk[pinned].ready = false;
        let moved = p.pick(77, &shrunk).unwrap();
        assert_ne!(moved, pinned, "draining worker must not be picked");
        // and the remap itself is stable
        assert_eq!(p.pick(77, &shrunk), Some(moved));
    }

    #[test]
    fn kind_parse_build_and_names() {
        assert_eq!(PolicyKind::parse("round-robin").unwrap(), PolicyKind::RoundRobin);
        assert_eq!(PolicyKind::parse("least-loaded").unwrap(), PolicyKind::LeastLoaded);
        assert_eq!(PolicyKind::parse("affinity").unwrap(), PolicyKind::Affinity);
        assert!(PolicyKind::parse("random").is_err());
        for k in [PolicyKind::RoundRobin, PolicyKind::LeastLoaded, PolicyKind::Affinity] {
            assert_eq!(k.build(1).name(), k.name());
            assert_eq!(PolicyKind::parse(k.name()).unwrap(), k);
        }
    }
}
