//! One engine worker on its own thread.
//!
//! A [`FleetWorker`] owns a `Box<dyn InferenceBackend>` — built *inside*
//! the worker thread by a factory closure, so the engine, its planner, and
//! its caches are thread-local — and drives it with a stepping loop fed by
//! an inbox channel. The router talks to the worker only through that
//! inbox plus a shared atomic state block ([`WorkerShared`]): a health
//! state machine (`Starting → Ready → Draining → Dead`), a liveness
//! heartbeat advanced every loop iteration, and load/served gauges the
//! routing policies read.
//!
//! Completed [`RequestOutput`]s are filed into the fleet-wide done map
//! keyed by the router-assigned fleet request id, so results survive the
//! worker that produced them — the router polls one map no matter which
//! worker (or which *re*-placement, after a death) served a request.

use std::collections::{HashMap, HashSet, VecDeque};
use std::sync::atomic::{AtomicU64, AtomicU8, AtomicUsize, Ordering};
use std::sync::mpsc::{self, RecvTimeoutError, TryRecvError};
use std::sync::{Arc, Condvar, Mutex};
use std::thread::{self, JoinHandle};
use std::time::Duration;

use anyhow::{anyhow, Result};

use crate::coordinator::backend::{InferenceBackend, RequestOutput, Ticket};
use crate::coordinator::batcher::Request;
use crate::coordinator::metrics::Metrics;
use crate::obs::trace as otrace;

/// Builds a worker's engine inside its thread. Shared by every spawn so
/// `add_worker` clones are identical (same config ⇒ same seeded weights ⇒
/// bit-identical outputs across the fleet).
pub type BackendFactory = Arc<dyn Fn() -> Result<Box<dyn InferenceBackend>> + Send + Sync>;

/// Fleet-wide completed-output map: fleet request id → output.
pub type DoneMap = Arc<DoneTable>;

/// Cancelled-id tombstones the table remembers, so a worker filing a
/// cancelled request's output late finds the tombstone and drops it.
/// Bounded: the set only needs to cover the cancel→late-file window, and
/// an evicted tombstone degrades to (at worst) one retained output.
const CANCELLED_CAP: usize = 4096;

/// The condvar-backed table behind [`DoneMap`]. Workers file outputs with
/// [`DoneTable::insert`], which notifies every waiter, so pollers block on
/// [`DoneTable::wait_remove`] instead of sleep-spinning — important once
/// many HTTP handlers wait in `Router::poll_wait` concurrently.
///
/// Requests that time out (or were delivered while a resubmission raced)
/// are [`DoneTable::cancel`]led: any already-filed output is dropped on
/// the spot, and a tombstone drops the output if a worker files it later —
/// otherwise every abandoned ticket would pin a logits vector forever.
#[derive(Default)]
pub struct DoneTable {
    inner: Mutex<DoneInner>,
    completed: Condvar,
}

#[derive(Default)]
struct DoneInner {
    map: HashMap<u64, RequestOutput>,
    cancelled: HashSet<u64>,
    /// insertion order of `cancelled`, for FIFO eviction past the cap
    cancelled_order: VecDeque<u64>,
}

impl DoneTable {
    pub fn new() -> DoneMap {
        Arc::new(DoneTable::default())
    }

    /// File one completed output and wake every waiter. Output for a
    /// cancelled id is dropped (consuming the tombstone — fleet ids are
    /// never reused, so at most one late filing can arrive per cancel).
    pub fn insert(&self, fleet_id: u64, out: RequestOutput) {
        let mut inner = self.inner.lock().unwrap();
        if inner.cancelled.remove(&fleet_id) {
            inner.cancelled_order.retain(|id| *id != fleet_id);
            return;
        }
        inner.map.insert(fleet_id, out);
        drop(inner);
        self.completed.notify_all();
    }

    /// Remove and return `fleet_id`'s output, if filed.
    pub fn remove(&self, fleet_id: u64) -> Option<RequestOutput> {
        self.inner.lock().unwrap().map.remove(&fleet_id)
    }

    /// Give up on `fleet_id`: drop its output if already filed, and leave
    /// a tombstone so a late filing is dropped instead of retained forever
    /// (timed-out front-door requests, delivered-then-resubmitted races).
    pub fn cancel(&self, fleet_id: u64) {
        let mut inner = self.inner.lock().unwrap();
        if inner.map.remove(&fleet_id).is_some() {
            return; // the output existed and is now dropped; no late filing follows
        }
        if inner.cancelled.insert(fleet_id) {
            inner.cancelled_order.push_back(fleet_id);
            while inner.cancelled_order.len() > CANCELLED_CAP {
                if let Some(old) = inner.cancelled_order.pop_front() {
                    inner.cancelled.remove(&old);
                }
            }
        }
    }

    pub fn contains(&self, fleet_id: u64) -> bool {
        self.inner.lock().unwrap().map.contains_key(&fleet_id)
    }

    pub fn len(&self) -> usize {
        self.inner.lock().unwrap().map.len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Snapshot of the filed fleet ids (the supervision pass checks these
    /// before resubmitting stranded work).
    pub fn ids(&self) -> HashSet<u64> {
        self.inner.lock().unwrap().map.keys().copied().collect()
    }

    /// Block until `fleet_id`'s output is filed or `timeout` elapses,
    /// removing and returning it on success. One bounded wait slice — the
    /// caller loops, interleaving its own bookkeeping (supervision,
    /// deadline checks) between slices.
    pub fn wait_remove(&self, fleet_id: u64, timeout: Duration) -> Option<RequestOutput> {
        let mut inner = self.inner.lock().unwrap();
        if let Some(out) = inner.map.remove(&fleet_id) {
            return Some(out);
        }
        let (mut inner, _) = self.completed.wait_timeout(inner, timeout).unwrap();
        inner.map.remove(&fleet_id)
    }
}

/// The worker health state machine. Transitions:
/// `Starting → Ready` (engine built + warmed), `Ready → Draining`
/// (remove_worker), `Draining → Dead` (live work finished), and any state
/// `→ Dead` on kill, engine error, or thread exit.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum WorkerHealth {
    Starting,
    Ready,
    Draining,
    Dead,
}

impl WorkerHealth {
    pub fn name(self) -> &'static str {
        match self {
            WorkerHealth::Starting => "starting",
            WorkerHealth::Ready => "ready",
            WorkerHealth::Draining => "draining",
            WorkerHealth::Dead => "dead",
        }
    }

    fn from_u8(v: u8) -> WorkerHealth {
        match v {
            0 => WorkerHealth::Starting,
            1 => WorkerHealth::Ready,
            2 => WorkerHealth::Draining,
            _ => WorkerHealth::Dead,
        }
    }

    fn as_u8(self) -> u8 {
        match self {
            WorkerHealth::Starting => 0,
            WorkerHealth::Ready => 1,
            WorkerHealth::Draining => 2,
            WorkerHealth::Dead => 3,
        }
    }
}

/// State shared between the router and one worker thread. All gauges are
/// atomics so health probes never block the step loop; the metrics mutex
/// is held only across one engine step or one report snapshot.
pub struct WorkerShared {
    state: AtomicU8,
    heartbeat: AtomicU64,
    /// requests routed here and not yet completed (queued + in-flight)
    load: AtomicUsize,
    /// requests this worker completed
    served: AtomicUsize,
    metrics: Mutex<Metrics>,
    error: Mutex<Option<String>>,
}

impl WorkerShared {
    fn new() -> Arc<WorkerShared> {
        Arc::new(WorkerShared {
            state: AtomicU8::new(WorkerHealth::Starting.as_u8()),
            heartbeat: AtomicU64::new(0),
            load: AtomicUsize::new(0),
            served: AtomicUsize::new(0),
            metrics: Mutex::new(Metrics::default()),
            error: Mutex::new(None),
        })
    }

    pub fn health(&self) -> WorkerHealth {
        WorkerHealth::from_u8(self.state.load(Ordering::SeqCst))
    }

    fn set_health(&self, h: WorkerHealth) {
        self.state.store(h.as_u8(), Ordering::SeqCst);
    }

    pub fn heartbeat(&self) -> u64 {
        self.heartbeat.load(Ordering::SeqCst)
    }

    pub fn load(&self) -> usize {
        self.load.load(Ordering::SeqCst)
    }

    pub fn served(&self) -> usize {
        self.served.load(Ordering::SeqCst)
    }

    fn fail(&self, msg: String) {
        *self.error.lock().unwrap() = Some(msg);
        self.set_health(WorkerHealth::Dead);
    }
}

enum Command {
    /// (fleet request id, payload)
    Submit(u64, Request),
    /// stop admitting, finish live work, then exit (state → Dead)
    Drain,
    /// exit immediately, stranding live work (chaos/test hook)
    Kill,
}

/// Router-side handle to one worker thread.
pub struct FleetWorker {
    pub id: usize,
    tx: mpsc::Sender<Command>,
    shared: Arc<WorkerShared>,
    handle: Option<JoinHandle<()>>,
}

impl FleetWorker {
    /// Spawn a worker: the thread builds its engine via `factory`, warms it
    /// up, flips to `Ready`, then steps its inbox. `step_delay_ms > 0`
    /// throttles the loop (rate-limit / chaos-test hook).
    pub fn spawn(
        id: usize,
        factory: BackendFactory,
        max_batch: usize,
        step_delay_ms: f64,
        done: DoneMap,
    ) -> FleetWorker {
        let shared = WorkerShared::new();
        let (tx, rx) = mpsc::channel::<Command>();
        let thread_shared = Arc::clone(&shared);
        let handle = thread::Builder::new()
            .name(format!("fleet-worker-{id}"))
            .spawn(move || {
                worker_main(id, factory, max_batch, step_delay_ms, rx, done, thread_shared)
            })
            .expect("spawn fleet worker thread");
        FleetWorker {
            id,
            tx,
            shared,
            handle: Some(handle),
        }
    }

    /// Health, corrected for a thread that exited without reporting: a
    /// finished thread is `Dead` whatever the state block says.
    pub fn health(&self) -> WorkerHealth {
        let h = self.shared.health();
        let thread_gone = match &self.handle {
            Some(j) => j.is_finished(),
            None => true,
        };
        if h != WorkerHealth::Dead && thread_gone {
            self.shared.set_health(WorkerHealth::Dead);
            return WorkerHealth::Dead;
        }
        h
    }

    pub fn heartbeat(&self) -> u64 {
        self.shared.heartbeat()
    }

    pub fn load(&self) -> usize {
        self.shared.load()
    }

    pub fn served(&self) -> usize {
        self.shared.served()
    }

    pub fn error(&self) -> Option<String> {
        self.shared.error.lock().unwrap().clone()
    }

    /// Route one request here. Fails when the worker is not admitting
    /// (draining/dead) or its inbox is gone.
    pub fn submit(&self, fleet_id: u64, request: Request) -> Result<()> {
        if self.health() != WorkerHealth::Ready {
            return Err(anyhow!(
                "worker {} is {} — not admitting requests",
                self.id,
                self.health().name()
            ));
        }
        self.shared.load.fetch_add(1, Ordering::SeqCst);
        self.tx
            .send(Command::Submit(fleet_id, request))
            .map_err(|_| {
                self.shared.load.fetch_sub(1, Ordering::SeqCst);
                self.shared.set_health(WorkerHealth::Dead);
                anyhow!("worker {} inbox closed", self.id)
            })
    }

    /// Begin a graceful drain (stop admitting, finish live work, exit).
    pub fn drain(&self) {
        let _ = self.tx.send(Command::Drain);
    }

    /// Kill the worker mid-flight, stranding live work (chaos/test hook —
    /// the router's supervise pass resubmits stranded requests).
    pub fn kill(&self) {
        let _ = self.tx.send(Command::Kill);
    }

    /// Block until the worker reaches `target` (or `Dead`, which is
    /// terminal). Errors on timeout or on dying before a non-Dead target.
    pub fn wait_health(&self, target: WorkerHealth, timeout: Duration) -> Result<()> {
        let t0 = std::time::Instant::now();
        loop {
            let h = self.health();
            if h == target {
                return Ok(());
            }
            if h == WorkerHealth::Dead {
                return Err(anyhow!(
                    "worker {} died while waiting for {}: {}",
                    self.id,
                    target.name(),
                    self.error().unwrap_or_else(|| "no error recorded".into())
                ));
            }
            if t0.elapsed() > timeout {
                return Err(anyhow!(
                    "worker {} stuck in {} waiting for {}",
                    self.id,
                    h.name(),
                    target.name()
                ));
            }
            thread::sleep(Duration::from_micros(200));
        }
    }

    /// Snapshot-read this worker's metrics.
    pub fn with_metrics<T>(&self, f: impl FnOnce(&Metrics) -> T) -> T {
        f(&self.shared.metrics.lock().unwrap())
    }

    /// Join the worker thread (after drain/kill).
    pub fn join(mut self) {
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
    }
}

/// What the inbox handler decided the loop should do next.
enum Flow {
    Continue,
    Exit,
}

fn handle_command(
    cmd: Command,
    backend: &dyn InferenceBackend,
    pending: &mut Vec<(u64, Ticket)>,
    draining: &mut bool,
    shared: &WorkerShared,
) -> Flow {
    match cmd {
        Command::Submit(fleet_id, request) => {
            // The inbox span bridges the thread hop: it parents on the
            // ingress span carried by the request, and the engine-step span
            // the backend later records parents on the same context.
            let mut span = otrace::span("worker_inbox", request.trace);
            if otrace::enabled() {
                span.arg("fleet_id", fleet_id.to_string());
                span.arg("request_id", request.id.to_string());
            }
            let ticket = backend.submit(request);
            pending.push((fleet_id, ticket));
            Flow::Continue
        }
        Command::Drain => {
            *draining = true;
            shared.set_health(WorkerHealth::Draining);
            Flow::Continue
        }
        Command::Kill => {
            shared.set_health(WorkerHealth::Dead);
            Flow::Exit
        }
    }
}

/// Non-blocking inbox sweep. A disconnected inbox (router handle dropped)
/// flips the worker into drain mode: finish live work, then exit.
fn drain_inbox(
    rx: &mpsc::Receiver<Command>,
    backend: &dyn InferenceBackend,
    pending: &mut Vec<(u64, Ticket)>,
    draining: &mut bool,
    shared: &WorkerShared,
) -> Flow {
    loop {
        match rx.try_recv() {
            Ok(cmd) => {
                if let Flow::Exit = handle_command(cmd, backend, pending, draining, shared) {
                    return Flow::Exit;
                }
            }
            Err(TryRecvError::Empty) => return Flow::Continue,
            Err(TryRecvError::Disconnected) => {
                *draining = true;
                return Flow::Continue;
            }
        }
    }
}

fn worker_main(
    id: usize,
    factory: BackendFactory,
    max_batch: usize,
    step_delay_ms: f64,
    rx: mpsc::Receiver<Command>,
    done: DoneMap,
    shared: Arc<WorkerShared>,
) {
    let backend = match factory().and_then(|b| {
        b.warmup()?;
        Ok(b)
    }) {
        Ok(b) => b,
        Err(e) => {
            shared.fail(format!("worker {id} failed to start: {e}"));
            return;
        }
    };
    // Plan-time gauge: warmup settled the planner's backend choices.
    shared
        .metrics
        .lock()
        .unwrap()
        .record_plan(&backend.planner_choices());
    shared.set_health(WorkerHealth::Ready);

    let mut pending: Vec<(u64, Ticket)> = Vec::new();
    let mut draining = false;
    loop {
        shared.heartbeat.fetch_add(1, Ordering::SeqCst);

        // Idle (nothing queued, nothing awaiting poll): block briefly on the
        // inbox instead of spinning. Everything else drains it non-blocking.
        if pending.is_empty() && backend.queued() == 0 && !draining {
            match rx.recv_timeout(Duration::from_millis(1)) {
                Ok(cmd) => {
                    if let Flow::Exit =
                        handle_command(cmd, backend.as_ref(), &mut pending, &mut draining, &shared)
                    {
                        return;
                    }
                }
                Err(RecvTimeoutError::Timeout) => continue,
                Err(RecvTimeoutError::Disconnected) => {
                    // Router gone, no work left: clean exit.
                    shared.set_health(WorkerHealth::Dead);
                    return;
                }
            }
        }
        if let Flow::Exit =
            drain_inbox(&rx, backend.as_ref(), &mut pending, &mut draining, &shared)
        {
            return;
        }

        if backend.queued() > 0 {
            if step_delay_ms > 0.0 {
                // Throttle hook (rate limiting / chaos tests). Re-drain the
                // inbox after the sleep so a Kill sent during the window
                // wins over the step — its live work is reliably stranded.
                thread::sleep(Duration::from_secs_f64(step_delay_ms / 1e3));
                if let Flow::Exit =
                    drain_inbox(&rx, backend.as_ref(), &mut pending, &mut draining, &shared)
                {
                    return;
                }
            }
            let step = {
                let mut metrics = shared.metrics.lock().unwrap();
                backend.step(max_batch.max(1), &mut metrics)
            };
            if let Err(e) = step {
                shared.fail(format!("worker {id} engine step failed: {e}"));
                return;
            }
        }

        // File finished outputs into the fleet-wide done map.
        let mut completed = 0usize;
        pending.retain(|(fleet_id, ticket)| match backend.poll(ticket) {
            Some(out) => {
                done.insert(*fleet_id, out);
                completed += 1;
                false
            }
            None => true,
        });
        if completed > 0 {
            shared.load.fetch_sub(completed, Ordering::SeqCst);
            shared.served.fetch_add(completed, Ordering::SeqCst);
        }

        if draining && pending.is_empty() && backend.queued() == 0 {
            shared.set_health(WorkerHealth::Dead);
            return;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::backend::NativeBackend;
    use crate::data::synth_images;
    use crate::model::ops::Variant;
    use std::time::Instant;

    fn factory() -> BackendFactory {
        Arc::new(|| {
            let b: Box<dyn InferenceBackend> = Box::new(NativeBackend::tiny(Variant::SHIFTADD_MOE));
            Ok(b)
        })
    }

    fn request(id: usize) -> Request {
        let s = synth_images::gen_image(40_000 + id as u32);
        Request {
            id,
            pixels: s.pixels,
            label: Some(s.label),
            arrived: Instant::now(),
            trace: crate::obs::trace::TraceCtx::NONE,
        }
    }

    #[test]
    fn worker_lifecycle_serves_then_drains() {
        let done = DoneTable::new();
        let w = FleetWorker::spawn(0, factory(), 4, 0.0, Arc::clone(&done));
        w.wait_health(WorkerHealth::Ready, Duration::from_secs(60)).unwrap();
        let hb0 = w.heartbeat();
        w.submit(10, request(0)).unwrap();
        w.submit(11, request(1)).unwrap();
        // outputs land in the shared map — wake on the completion condvar
        let t0 = Instant::now();
        while done.len() < 2 {
            assert!(t0.elapsed() < Duration::from_secs(60), "worker never completed");
            let _ = done.wait_remove(u64::MAX, Duration::from_millis(5));
        }
        assert_eq!(w.load(), 0);
        assert_eq!(w.served(), 2);
        assert!(w.heartbeat() > hb0, "step loop must advance the heartbeat");
        let out = done.remove(10).unwrap();
        assert_eq!(out.request_id, 0);
        w.drain();
        w.wait_health(WorkerHealth::Dead, Duration::from_secs(60)).unwrap();
        assert!(w.submit(12, request(2)).is_err(), "dead workers admit nothing");
        w.join();
    }

    #[test]
    fn kill_strands_live_work_without_filing_outputs() {
        let done = DoneTable::new();
        // Big step delay: the kill lands before the first step completes.
        let w = FleetWorker::spawn(3, factory(), 4, 200.0, Arc::clone(&done));
        w.wait_health(WorkerHealth::Ready, Duration::from_secs(60)).unwrap();
        w.submit(7, request(0)).unwrap();
        w.kill();
        w.wait_health(WorkerHealth::Dead, Duration::from_secs(60)).unwrap();
        w.join();
        assert!(
            !done.contains(7),
            "killed worker must not have filed the stranded output"
        );
    }

    #[test]
    fn failed_factory_reports_dead_with_error() {
        let done = DoneTable::new();
        let boom: BackendFactory = Arc::new(|| Err(anyhow!("no engine here")));
        let w = FleetWorker::spawn(9, boom, 4, 0.0, done);
        assert!(w.wait_health(WorkerHealth::Ready, Duration::from_secs(60)).is_err());
        assert_eq!(w.health(), WorkerHealth::Dead);
        assert!(w.error().unwrap().contains("no engine here"));
        w.join();
    }

    fn output(request_id: usize) -> RequestOutput {
        RequestOutput {
            id: 0,
            request_id,
            logits: vec![1.0],
            dispatch_mask_blk0: Vec::new(),
            batch_ms: 0.1,
            modularized_ms: 0.1,
            batch_size: 1,
            arrived: Instant::now(),
            finished: Instant::now(),
            label: None,
        }
    }

    #[test]
    fn cancel_drops_filed_outputs_and_tombstones_late_filings() {
        let done = DoneTable::new();
        // Cancel after filing: the output is dropped on the spot.
        done.insert(1, output(10));
        done.cancel(1);
        assert!(done.is_empty(), "cancel must drop the filed output");
        assert!(done.remove(1).is_none());
        // Cancel before filing: the tombstone drops the late filing.
        done.cancel(2);
        done.insert(2, output(20));
        assert!(!done.contains(2), "late filing of a cancelled id is dropped");
        // The tombstone is consumed — an unrelated later id still files.
        done.insert(3, output(30));
        assert!(done.contains(3));
    }

    #[test]
    fn wait_remove_blocks_until_insert_and_consumes() {
        let done = DoneTable::new();
        assert!(
            done.wait_remove(1, Duration::from_millis(5)).is_none(),
            "timeout with nothing filed"
        );
        let peer = Arc::clone(&done);
        let filer = thread::spawn(move || {
            thread::sleep(Duration::from_millis(20));
            let s = synth_images::gen_image(1);
            peer.insert(
                1,
                RequestOutput {
                    id: 0,
                    request_id: 42,
                    logits: vec![1.0],
                    dispatch_mask_blk0: Vec::new(),
                    batch_ms: 0.1,
                    modularized_ms: 0.1,
                    batch_size: 1,
                    arrived: Instant::now(),
                    finished: Instant::now(),
                    label: Some(s.label),
                },
            );
        });
        // loop wait slices exactly like poll_wait does
        let t0 = Instant::now();
        let out = loop {
            if let Some(out) = done.wait_remove(1, Duration::from_millis(5)) {
                break out;
            }
            assert!(t0.elapsed() < Duration::from_secs(30), "insert never woke us");
        };
        assert_eq!(out.request_id, 42);
        assert!(done.is_empty(), "wait_remove consumes the output");
        filer.join().unwrap();
    }
}
