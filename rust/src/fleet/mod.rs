//! The fleet layer: sharded multi-worker serving behind one router.
//!
//! One process used to own exactly one engine ([`crate::coordinator`]'s
//! `InferenceBackend` or `SessionEngine`), so every kernel win stopped
//! scaling at a single worker. This subsystem fronts **N** engine workers
//! behind a single submit/poll surface (the sglang router shape: pluggable
//! routing policy, worker registry with add/remove, health probes):
//!
//! - [`worker::FleetWorker`] — one engine on its own thread: an inbox
//!   channel, a stepping loop that drives `submit/step/poll`, and a health
//!   state machine (`Starting → Ready → Draining → Dead`) with liveness
//!   heartbeats advanced by the step loop;
//! - [`policy`] — the [`policy::RoutingPolicy`] trait with
//!   [`policy::RoundRobin`], [`policy::LeastLoaded`] (fewest in-flight
//!   requests, from the per-worker occupancy gauges), and
//!   [`policy::Affinity`] (stable hash of the request shape → worker, so
//!   planner tables and warmed caches stay hot per worker), all
//!   deterministic under a seeded tiebreak;
//! - [`router::Router`] — `submit(Request) -> FleetTicket`, `poll`,
//!   runtime `add_worker`/`remove_worker` (remove drains: stop admitting,
//!   finish live work, join the thread), `/liveness`-`/readiness`-
//!   `/metrics`-shaped reports, and resubmission of requests stranded on a
//!   dead worker;
//! - [`http`] — the HTTP/1.1 front door ([`http::HttpFrontDoor`]): the
//!   probe reports and classify/stream ingress served over a real TCP
//!   socket (`serve --http PORT`), with bounded concurrency, per-request
//!   timeouts, and graceful drain.
//!
//! Workers are built by a factory closure, so native and XLA engines mix
//! in one fleet — they already share the request-level contract from
//! [`crate::coordinator::backend`]. Construction happens *inside* the
//! worker thread (each worker owns its engine, planner, and caches), which
//! is what makes shape affinity worth routing for.

pub mod http;
pub mod policy;
pub mod router;
pub mod worker;

pub use http::{FrontDoorConfig, HttpFrontDoor};
pub use policy::{PolicyKind, RoutingPolicy, WorkerView};
pub use router::{FleetTicket, Router, RouterConfig, WorkerBreakdown};
pub use worker::{FleetWorker, WorkerHealth};
