//! The fleet router: one submit/poll surface over N engine workers.
//!
//! [`Router::submit`] places each request on a worker chosen by the
//! configured [`RoutingPolicy`] and returns a [`FleetTicket`]; workers
//! step autonomously on their own threads and file outputs into one
//! fleet-wide done map, so [`Router::poll`] works no matter which worker
//! (or re-placement) served the request. The router also keeps a copy of
//! every in-flight request, which is what makes [`Router::supervise`]
//! able to resubmit work stranded on a dead worker — kill a worker
//! mid-flight and every submitted request still completes on a survivor.
//!
//! Runtime membership: [`Router::add_worker`] grows the fleet;
//! [`Router::remove_worker`] drains (stops admitting, finishes live work,
//! joins the thread). Health surfaces mirror the usual probe endpoints:
//! [`Router::liveness`], [`Router::readiness`], [`Router::metrics_json`].

use std::collections::{HashMap, HashSet};
use std::sync::Arc;
use std::time::{Duration, Instant};

use anyhow::{anyhow, Result};

use crate::coordinator::backend::{create_backend_with, load_bundle, RequestOutput};
use crate::coordinator::batcher::Request;
use crate::coordinator::config::{BackendKind, ServerConfig};
use crate::coordinator::metrics::Metrics;
use crate::fleet::policy::{PolicyKind, RoutingPolicy, WorkerView};
use crate::fleet::worker::{BackendFactory, DoneMap, DoneTable, FleetWorker, WorkerHealth};
use crate::kernels::planner::{table_json, Choice};
use crate::log_warn;
use crate::obs::trace as otrace;
use crate::util::json::Json;

/// Default seed for policy tiebreaks (override via [`RouterConfig`]).
pub const DEFAULT_POLICY_SEED: u64 = 0xF1EE7;

/// How long a worker may take to build + warm its engine (the planner may
/// benchmark kernels during warmup).
const READY_TIMEOUT: Duration = Duration::from_secs(120);

/// How long a drain (finish live work) may take.
const DRAIN_TIMEOUT: Duration = Duration::from_secs(120);

/// Fleet shape and knobs.
#[derive(Clone, Debug)]
pub struct RouterConfig {
    pub workers: usize,
    /// per-worker fused-batch cap (each worker's `step(max_batch)`)
    pub max_batch: usize,
    pub policy: PolicyKind,
    pub policy_seed: u64,
    /// throttle each worker's step loop (ms); 0 = full speed. Chaos tests
    /// use this to hold work in flight long enough to kill a worker.
    pub step_delay_ms: f64,
}

impl Default for RouterConfig {
    fn default() -> Self {
        RouterConfig {
            workers: 1,
            max_batch: 8,
            policy: PolicyKind::RoundRobin,
            policy_seed: DEFAULT_POLICY_SEED,
            step_delay_ms: 0.0,
        }
    }
}

/// Handle to a routed request: the worker it was placed on (initial
/// placement — resubmission may move it) plus the fleet-wide request id.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct FleetTicket {
    pub worker: usize,
    pub id: u64,
}

/// A request the fleet has accepted but the caller has not yet polled.
/// The payload copy is the resubmission source if its worker dies.
struct Inflight {
    request: Request,
    worker: usize,
}

/// One worker's row in the liveness probe.
#[derive(Clone, Copy, Debug)]
pub struct WorkerProbe {
    pub id: usize,
    pub state: WorkerHealth,
    pub heartbeat: u64,
    pub load: usize,
    pub served: usize,
}

/// `/liveness` shape: per-worker state + heartbeat, `live` while any
/// worker is not dead.
#[derive(Clone, Debug)]
pub struct LivenessReport {
    pub workers: Vec<WorkerProbe>,
    pub live: bool,
}

impl LivenessReport {
    pub fn to_json(&self) -> Json {
        let rows: Vec<Json> = self
            .workers
            .iter()
            .map(|p| {
                Json::obj(vec![
                    ("id", Json::num(p.id as f64)),
                    ("state", Json::str(p.state.name())),
                    ("heartbeat", Json::num(p.heartbeat as f64)),
                    ("load", Json::num(p.load as f64)),
                    ("served", Json::num(p.served as f64)),
                ])
            })
            .collect();
        Json::obj(vec![
            ("live", Json::str(if self.live { "true" } else { "false" })),
            ("workers", Json::Arr(rows)),
        ])
    }
}

/// `/readiness` shape: ready while at least one worker admits requests.
/// Carries the bundle digest so a deployer can confirm which artifact the
/// fleet warm-started from.
#[derive(Clone, Debug)]
pub struct ReadinessReport {
    pub total: usize,
    pub ready_workers: usize,
    pub ready: bool,
    pub bundle_digest: Option<String>,
}

impl ReadinessReport {
    pub fn to_json(&self) -> Json {
        let mut rows = vec![
            ("ready", Json::str(if self.ready { "true" } else { "false" })),
            ("ready_workers", Json::num(self.ready_workers as f64)),
            ("total_workers", Json::num(self.total as f64)),
        ];
        if let Some(d) = &self.bundle_digest {
            rows.push(("bundle_digest", Json::str(d)));
        }
        Json::obj(rows)
    }
}

/// Per-worker slice of a serving report (`/metrics` shape, and the
/// `ServeReport`/`StreamReport` per-worker breakdowns).
#[derive(Clone, Debug)]
pub struct WorkerBreakdown {
    pub id: usize,
    pub state: &'static str,
    /// requests this worker completed
    pub requests: usize,
    /// fused engine batches it stepped
    pub batches: usize,
    /// queued + in-flight requests at snapshot time
    pub load: usize,
}

impl WorkerBreakdown {
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("id", Json::num(self.id as f64)),
            ("state", Json::str(self.state)),
            ("requests", Json::num(self.requests as f64)),
            ("batches", Json::num(self.batches as f64)),
            ("load", Json::num(self.load as f64)),
        ])
    }
}

/// The router fleet.
pub struct Router {
    cfg: RouterConfig,
    factory: BackendFactory,
    policy: Box<dyn RoutingPolicy>,
    /// sorted by id (ids are monotonic; removal preserves order)
    workers: Vec<FleetWorker>,
    done: DoneMap,
    inflight: HashMap<u64, Inflight>,
    next_fleet_id: u64,
    next_worker_id: usize,
    resubmitted: usize,
    /// digest of the verified bundle every worker warm-started from
    bundle_digest: Option<String>,
    /// planner choices autotuned once in the fleet factory and shared
    /// with every worker (empty when workers own their planning)
    factory_choices: Vec<Choice>,
}

impl Router {
    /// Spawn `cfg.workers` workers from `factory` and wait until every one
    /// is `Ready` (workers warm their engines in their own threads).
    pub fn new(cfg: RouterConfig, factory: BackendFactory) -> Result<Router> {
        let mut router = Router {
            policy: cfg.policy.build(cfg.policy_seed),
            cfg,
            factory,
            workers: Vec::new(),
            done: DoneTable::new(),
            inflight: HashMap::new(),
            next_fleet_id: 0,
            next_worker_id: 0,
            resubmitted: 0,
            bundle_digest: None,
            factory_choices: Vec::new(),
        };
        for _ in 0..router.cfg.workers.max(1) {
            router.add_worker()?;
        }
        Ok(router)
    }

    /// Build a fleet whose workers run the engine described by a
    /// [`ServerConfig`]. For the native backend the factory does the
    /// expensive work ONCE before any worker spawns: it verifies the
    /// configured bundle and autotunes the planner on a throwaway probe
    /// engine, then every worker warm-starts from the same loaded params
    /// and pinned table — no per-worker re-verification or benchmarking.
    pub fn from_server_config(cfg: &ServerConfig) -> Result<Router> {
        let bundle = load_bundle(cfg)?;
        let digest = bundle.as_ref().map(|b| b.digest.clone());
        let workers = cfg.workers.max(1);
        let engine_cfg = cfg.clone();
        let mut choices: Vec<Choice> = Vec::new();
        let factory: BackendFactory = if cfg.backend == BackendKind::Native {
            let probe = create_backend_with(cfg, bundle.as_deref(), None)?;
            choices = probe.planner_choices();
            let table = table_json(&choices).to_string();
            println!(
                "fleet: planner tuned once in the factory ({} choices shared with {workers} workers)",
                choices.len()
            );
            Arc::new(move || create_backend_with(&engine_cfg, bundle.as_deref(), Some(&table)))
        } else {
            Arc::new(move || create_backend_with(&engine_cfg, None, None))
        };
        let mut router = Router::new(
            RouterConfig {
                workers,
                max_batch: cfg.max_batch,
                policy: cfg.policy,
                policy_seed: DEFAULT_POLICY_SEED,
                step_delay_ms: 0.0,
            },
            factory,
        )?;
        router.bundle_digest = digest;
        router.factory_choices = choices;
        Ok(router)
    }

    /// Digest of the verified bundle the fleet warm-started from.
    pub fn bundle_digest(&self) -> Option<&str> {
        self.bundle_digest.as_deref()
    }

    /// Planner choices autotuned once in the fleet factory (what
    /// `--save-planner-table` persists for a fleet run).
    pub fn factory_choices(&self) -> &[Choice] {
        &self.factory_choices
    }

    pub fn worker_count(&self) -> usize {
        self.workers.len()
    }

    pub fn worker_ids(&self) -> Vec<usize> {
        self.workers.iter().map(|w| w.id).collect()
    }

    /// Requests resubmitted after their worker died.
    pub fn resubmitted(&self) -> usize {
        self.resubmitted
    }

    pub fn policy_name(&self) -> &'static str {
        self.policy.name()
    }

    fn views(&self) -> Vec<WorkerView> {
        self.workers
            .iter()
            .map(|w| WorkerView {
                id: w.id,
                ready: w.health() == WorkerHealth::Ready,
                load: w.load(),
            })
            .collect()
    }

    fn worker(&self, id: usize) -> Result<&FleetWorker> {
        self.workers
            .iter()
            .find(|w| w.id == id)
            .ok_or_else(|| anyhow!("no worker {id} in the fleet"))
    }

    /// Place `fleet_id` on a policy-chosen worker; re-picks when a worker
    /// races to dead between the snapshot and the send.
    fn place(&mut self, fleet_id: u64, request: &Request) -> Result<usize> {
        let mut span = otrace::span("place", request.trace);
        let shape_key = request.pixels.len() as u64;
        for _ in 0..self.workers.len().max(1) {
            let views = self.views();
            let Some(wid) = self.policy.pick(shape_key, &views) else {
                break;
            };
            if self.worker(wid)?.submit(fleet_id, request.clone()).is_ok() {
                if otrace::enabled() {
                    span.arg("worker", wid.to_string());
                    span.arg("policy", self.policy.name().to_string());
                    span.arg("fleet_id", fleet_id.to_string());
                }
                return Ok(wid);
            }
        }
        Err(anyhow!(
            "no ready worker to route to (fleet of {})",
            self.workers.len()
        ))
    }

    /// Route one request. Returns the placement + fleet request id.
    pub fn submit(&mut self, request: Request) -> Result<FleetTicket> {
        let fleet_id = self.next_fleet_id;
        let worker = self.place(fleet_id, &request)?;
        self.next_fleet_id += 1;
        self.inflight.insert(fleet_id, Inflight { request, worker });
        Ok(FleetTicket {
            worker,
            id: fleet_id,
        })
    }

    /// Remove and return a finished request's output, if ready.
    pub fn poll(&mut self, ticket: &FleetTicket) -> Option<RequestOutput> {
        let out = self.done.remove(ticket.id)?;
        self.inflight.remove(&ticket.id);
        Some(out)
    }

    /// The fleet-wide completed-output table. Front-door handlers clone
    /// this so they can block on its completion condvar without holding
    /// the router lock.
    pub fn done_map(&self) -> DoneMap {
        Arc::clone(&self.done)
    }

    /// Retire a request the caller is finished with — either its output
    /// was taken straight off the done table's condvar (the HTTP front
    /// door), or the caller gave up on it (timeout). Drops the in-flight
    /// resubmission copy AND cancels the id in the done table, so an
    /// output filed late — by a worker finishing after a timeout, or by a
    /// resubmission that raced the delivery — is dropped instead of
    /// pinned in the table forever.
    pub fn acknowledge(&mut self, id: u64) {
        self.inflight.remove(&id);
        self.done.cancel(id);
    }

    /// Health sweep: reap workers whose thread died, then resubmit every
    /// in-flight request whose worker is gone and whose output was never
    /// filed. A request that completed just before its worker died is NOT
    /// resubmitted (the done map is checked first), so outputs are neither
    /// lost nor duplicated. Errors when stranded work exists but no ready
    /// worker remains.
    pub fn supervise(&mut self) -> Result<usize> {
        // Reap dead workers; their filed outputs live in the shared map.
        let any_dead = self
            .workers
            .iter()
            .any(|w| w.health() == WorkerHealth::Dead);
        if any_dead {
            let mut kept = Vec::with_capacity(self.workers.len());
            for w in self.workers.drain(..) {
                if w.health() == WorkerHealth::Dead {
                    if let Some(e) = w.error() {
                        log_warn!("fleet: reaping worker {}: {e}", w.id);
                    } else {
                        log_warn!("fleet: reaping dead worker {}", w.id);
                    }
                    w.join();
                } else {
                    kept.push(w);
                }
            }
            self.workers = kept;
        }

        // Resubmit stranded work: placed on a worker no longer in the
        // fleet, output never filed.
        let alive: HashSet<usize> = self.workers.iter().map(|w| w.id).collect();
        let completed: HashSet<u64> = self.done.ids();
        let stranded: Vec<u64> = self
            .inflight
            .iter()
            .filter(|(fid, inf)| !alive.contains(&inf.worker) && !completed.contains(fid))
            .map(|(fid, _)| *fid)
            .collect();
        let mut moved = 0usize;
        for fid in stranded {
            let request = self
                .inflight
                .get(&fid)
                .expect("stranded id came from inflight")
                .request
                .clone();
            let mut span = otrace::span("resubmit", request.trace);
            if otrace::enabled() {
                span.arg("fleet_id", fid.to_string());
            }
            let worker = self.place(fid, &request).map_err(|e| {
                anyhow!("request {fid} stranded on a dead worker and could not be re-placed: {e}")
            })?;
            self.inflight
                .get_mut(&fid)
                .expect("stranded id came from inflight")
                .worker = worker;
            moved += 1;
        }
        self.resubmitted += moved;
        Ok(moved)
    }

    /// Poll with supervision: block until the output arrives, resubmitting
    /// stranded work along the way. Blocks on the done table's completion
    /// condvar (bounded slices, so supervision and the deadline still run
    /// between waits) instead of sleep-spinning — workers wake every
    /// waiter the moment they file an output.
    pub fn poll_wait(&mut self, ticket: &FleetTicket, timeout: Duration) -> Result<RequestOutput> {
        let t0 = Instant::now();
        loop {
            if let Some(out) = self.poll(ticket) {
                return Ok(out);
            }
            self.supervise()?;
            if t0.elapsed() > timeout {
                // Abandon the request: retire its in-flight copy and cancel
                // the done-table id, so a worker completing it after this
                // deadline doesn't leak the output into the table.
                self.acknowledge(ticket.id);
                return Err(anyhow!(
                    "request {} not completed within {timeout:?}",
                    ticket.id
                ));
            }
            if let Some(out) = self.done.wait_remove(ticket.id, Duration::from_millis(5)) {
                self.acknowledge(ticket.id);
                return Ok(out);
            }
        }
    }

    /// Grow the fleet by one worker; blocks until it is `Ready`.
    pub fn add_worker(&mut self) -> Result<usize> {
        let id = self.next_worker_id;
        self.next_worker_id += 1;
        let w = FleetWorker::spawn(
            id,
            Arc::clone(&self.factory),
            self.cfg.max_batch,
            self.cfg.step_delay_ms,
            Arc::clone(&self.done),
        );
        if let Err(e) = w.wait_health(WorkerHealth::Ready, READY_TIMEOUT) {
            w.kill();
            w.join();
            return Err(e);
        }
        self.workers.push(w);
        Ok(id)
    }

    /// Drain one worker out of the fleet: it stops admitting immediately
    /// (no longer a policy candidate), finishes its live work, then its
    /// thread is joined. Completed-but-unpolled outputs survive in the
    /// fleet-wide done map.
    pub fn remove_worker(&mut self, id: usize) -> Result<()> {
        let pos = self
            .workers
            .iter()
            .position(|w| w.id == id)
            .ok_or_else(|| anyhow!("no worker {id} in the fleet"))?;
        let w = self.workers.remove(pos);
        w.drain();
        let drained = w.wait_health(WorkerHealth::Dead, DRAIN_TIMEOUT);
        w.join();
        drained.map_err(|e| anyhow!("worker {id} failed to drain: {e}"))
    }

    /// Chaos hook: kill a worker mid-flight (no drain). The next
    /// [`Router::supervise`] reaps it and resubmits its stranded work.
    pub fn kill_worker(&mut self, id: usize) -> Result<()> {
        self.worker(id)?.kill();
        Ok(())
    }

    /// Orderly fleet shutdown: drain everyone, join every thread.
    pub fn shutdown(&mut self) -> Result<()> {
        for w in &self.workers {
            w.drain();
        }
        let mut first_err = None;
        for w in self.workers.drain(..) {
            if let Err(e) = w.wait_health(WorkerHealth::Dead, DRAIN_TIMEOUT) {
                first_err.get_or_insert(e);
            }
            w.join();
        }
        match first_err {
            None => Ok(()),
            Some(e) => Err(e),
        }
    }

    /// `/liveness`: per-worker health + heartbeat.
    pub fn liveness(&self) -> LivenessReport {
        let workers: Vec<WorkerProbe> = self
            .workers
            .iter()
            .map(|w| WorkerProbe {
                id: w.id,
                state: w.health(),
                heartbeat: w.heartbeat(),
                load: w.load(),
                served: w.served(),
            })
            .collect();
        let live = workers.iter().any(|p| p.state != WorkerHealth::Dead);
        LivenessReport { workers, live }
    }

    /// `/readiness`: can the fleet admit a request right now?
    pub fn readiness(&self) -> ReadinessReport {
        let ready_workers = self
            .workers
            .iter()
            .filter(|w| w.health() == WorkerHealth::Ready)
            .count();
        ReadinessReport {
            total: self.workers.len(),
            ready_workers,
            ready: ready_workers > 0,
            bundle_digest: self.bundle_digest.clone(),
        }
    }

    /// Merged fleet metrics plus the per-worker breakdown.
    pub fn metrics_report(&self) -> (Metrics, Vec<WorkerBreakdown>) {
        let mut merged = Metrics::default();
        let mut per_worker = Vec::new();
        for w in &self.workers {
            let state = w.health().name();
            w.with_metrics(|m| {
                merged.merge(m);
                per_worker.push(WorkerBreakdown {
                    id: w.id,
                    state,
                    requests: w.served(),
                    batches: m.batches,
                    load: w.load(),
                });
            });
        }
        merged.bundle_digest = self.bundle_digest.clone();
        (merged, per_worker)
    }

    /// `/metrics`: merged engine metrics, per-worker rows, resubmissions.
    pub fn metrics_json(&self) -> Json {
        let (merged, per_worker) = self.metrics_report();
        let mut rows = vec![
            ("policy", Json::str(self.policy.name())),
            ("resubmitted", Json::num(self.resubmitted as f64)),
            (
                "workers",
                Json::Arr(per_worker.iter().map(|b| b.to_json()).collect()),
            ),
            ("engine", merged.to_json()),
        ];
        if let Some(d) = &self.bundle_digest {
            rows.push(("bundle_digest", Json::str(d)));
        }
        Json::obj(rows)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::backend::NativeBackend;
    use crate::coordinator::backend::InferenceBackend;
    use crate::data::synth_images;
    use crate::model::ops::Variant;

    fn factory() -> BackendFactory {
        Arc::new(|| {
            let b: Box<dyn InferenceBackend> = Box::new(NativeBackend::tiny(Variant::SHIFTADD_MOE));
            Ok(b)
        })
    }

    fn request(id: usize) -> Request {
        let s = synth_images::gen_image(70_000 + id as u32);
        Request {
            id,
            pixels: s.pixels,
            label: Some(s.label),
            arrived: Instant::now(),
            trace: crate::obs::trace::TraceCtx::NONE,
        }
    }

    fn router(workers: usize, policy: PolicyKind) -> Router {
        Router::new(
            RouterConfig {
                workers,
                max_batch: 4,
                policy,
                ..RouterConfig::default()
            },
            factory(),
        )
        .expect("fleet starts")
    }

    #[test]
    fn round_robin_fleet_serves_and_reports() {
        let mut r = router(2, PolicyKind::RoundRobin);
        assert_eq!(r.worker_ids(), vec![0, 1]);
        assert!(r.readiness().ready);
        let tickets: Vec<FleetTicket> = (0..4).map(|i| r.submit(request(i)).unwrap()).collect();
        // deterministic round-robin placement across the two workers
        assert_eq!(
            tickets.iter().map(|t| t.worker).collect::<Vec<_>>(),
            vec![0, 1, 0, 1]
        );
        for t in &tickets {
            let out = r.poll_wait(t, Duration::from_secs(60)).unwrap();
            assert!(!out.logits.is_empty());
            assert!(r.poll(t).is_none(), "poll consumes");
        }
        let live = r.liveness();
        assert!(live.live);
        assert_eq!(live.workers.len(), 2);
        assert!(live.workers.iter().all(|p| p.heartbeat > 0));
        let (merged, per_worker) = r.metrics_report();
        assert_eq!(merged.requests, 4);
        assert_eq!(per_worker.iter().map(|b| b.requests).sum::<usize>(), 4);
        // probe JSON shapes parse back
        let j = r.metrics_json();
        assert_eq!(j.get("resubmitted").and_then(|v| v.as_usize()), Some(0));
        assert!(r.liveness().to_json().get("workers").is_some());
        assert!(r.readiness().to_json().get("ready").is_some());
        r.shutdown().unwrap();
        assert_eq!(r.worker_count(), 0);
    }

    #[test]
    fn timed_out_request_does_not_leak_its_output() {
        // Slow steps guarantee the deadline passes before the work lands.
        let mut r = Router::new(
            RouterConfig {
                workers: 1,
                max_batch: 1,
                policy: PolicyKind::RoundRobin,
                step_delay_ms: 100.0,
                ..RouterConfig::default()
            },
            factory(),
        )
        .expect("fleet starts");
        let t = r.submit(request(0)).unwrap();
        assert!(
            r.poll_wait(&t, Duration::from_millis(1)).is_err(),
            "deadline too tight to meet"
        );
        // The worker still completes the abandoned request; the cancel
        // tombstone must drop its output instead of retaining it forever.
        let done = r.done_map();
        let t0 = Instant::now();
        while r.liveness().workers[0].served < 1 {
            assert!(t0.elapsed() < Duration::from_secs(60), "worker never finished");
            std::thread::sleep(Duration::from_millis(5));
        }
        assert!(done.is_empty(), "cancelled output must not be retained");
        assert!(r.poll(&t).is_none());
        r.shutdown().unwrap();
    }

    #[test]
    fn add_and_remove_worker_at_runtime() {
        let mut r = router(1, PolicyKind::RoundRobin);
        let added = r.add_worker().unwrap();
        assert_eq!(added, 1);
        assert_eq!(r.worker_ids(), vec![0, 1]);
        // keep the new worker busy so remove has live work to drain
        let tickets: Vec<FleetTicket> = (0..6).map(|i| r.submit(request(i)).unwrap()).collect();
        r.remove_worker(1).unwrap();
        assert_eq!(r.worker_ids(), vec![0]);
        // drained outputs survive; everything completes, nothing duplicated
        for t in &tickets {
            assert!(r.poll_wait(t, Duration::from_secs(60)).is_ok());
        }
        assert_eq!(r.resubmitted(), 0, "a drain strands nothing");
        assert!(r.remove_worker(7).is_err(), "unknown worker id");
        r.shutdown().unwrap();
    }
}
