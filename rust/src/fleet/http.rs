//! The HTTP/1.1 front door: the fleet's probe reports and inference
//! ingress served over a real TCP socket (`shiftaddvit serve --http PORT`).
//!
//! Routes:
//!
//! - `GET /liveness` / `GET /readiness` — the [`Router`]'s probe reports
//!   as JSON (200 when live/ready, 503 otherwise), byte-identical to the
//!   in-process `to_json()` shapes;
//! - `GET /metrics` — [`Router::metrics_json`] plus a `front_door` section
//!   (HTTP-stage latencies and a bounded recent window of the ingress
//!   request-id audit trail — the full totals live in the counters);
//! - `GET /metrics.prom` (or `/metrics?format=prometheus`) — the same
//!   registry as Prometheus text exposition, scrape-ready;
//! - `GET /trace` — the span ring as Chrome trace-event JSON (open in
//!   Perfetto / `chrome://tracing`);
//! - `POST /classify` — `{"pixels": [f32; H·W·3], "label"?: n}` →
//!   submit to the fleet, block on the done table's condvar, answer
//!   `{"id", "pred", "logits", ...}` (the logits round-trip JSON exactly —
//!   see `util::json`'s shortest-roundtrip number printing);
//! - `POST /stream` — `{"tokens": [f32; n·dim]}` → a session on the
//!   [`SessionEngine`] service thread, answered as a chunked
//!   `application/jsonl` stream of `progress` events and one final `done`
//!   event carrying the logits.
//!
//! Shape: one bounded accept loop (503 above `max_inflight`) dispatching
//! connections onto a persistent [`Pool`] of handler threads. Handlers
//! lock the router only for submit/poll bookkeeping — waiting happens on
//! the [`DoneMap`] condvar, so N handlers block concurrently while the
//! worker threads step. Shutdown is graceful: stop accepting, drain the
//! handler pool, retire the stream service, then drain the fleet.

use std::io::BufReader;
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::{mpsc, Arc, Mutex};
use std::thread;
use std::time::{Duration, Instant};

use anyhow::{anyhow, bail, Result};

use crate::coordinator::backend::{create_planner, load_bundle, RequestOutput};
use crate::coordinator::batcher::Request;
use crate::coordinator::config::{BackendKind, ServerConfig};
use crate::coordinator::metrics::Metrics;
use crate::coordinator::server::engine_mode;
use crate::coordinator::sessions::{SessionEngine, StreamStatus, StreamTicket};
use crate::data::synth_images;
use crate::fleet::router::{FleetTicket, Router};
use crate::infer::session::{SessionSpec, StreamAttn, StreamModel};
use crate::obs::trace::{self as otrace, TraceCtx};
use crate::util::httpd::{read_request, write_response, ChunkedWriter, HttpRequest};
use crate::util::json::Json;
use crate::util::pool::Pool;

/// Most-recent request ids the `/metrics` front-door section reports (the
/// in-memory trail is already bounded at `metrics::REQUEST_ID_CAP`; the
/// wire response stays smaller still).
const RECENT_IDS: usize = 64;

/// Front-door knobs.
#[derive(Clone, Copy, Debug)]
pub struct FrontDoorConfig {
    /// handler threads (concurrent requests actually being served)
    pub handlers: usize,
    /// accepted-but-unfinished connection cap; beyond it new connections
    /// get an immediate 503 instead of queueing unboundedly
    pub max_inflight: usize,
    /// per-request deadline (classify poll wait, stream event wait)
    pub request_timeout: Duration,
    /// socket read/write timeout (slow-client guard)
    pub io_timeout: Duration,
    /// expected flattened pixel count for `/classify` bodies
    pub pixels: usize,
}

impl Default for FrontDoorConfig {
    fn default() -> Self {
        FrontDoorConfig {
            handlers: 4,
            max_inflight: 64,
            request_timeout: Duration::from_secs(60),
            io_timeout: Duration::from_secs(10),
            pixels: synth_images::IMG * synth_images::IMG * 3,
        }
    }
}

/// One streaming event the `/stream` endpoint forwards as a chunk.
enum StreamEvent {
    Progress { fed: usize, total: usize },
    Done { tokens: usize, logits: Vec<f32> },
}

struct StreamJob {
    tokens: Vec<f32>,
    events: mpsc::Sender<StreamEvent>,
    /// ingress span of the `/stream` handler that submitted this job —
    /// the engine's decode/prefill spans parent on it across the hop to
    /// the service thread
    trace: TraceCtx,
}

/// The `/stream` service: one thread owning one [`SessionEngine`],
/// continuously batching every HTTP stream session; handlers feed it jobs
/// and receive per-step events back on their own channel.
struct StreamService {
    tx: Mutex<Option<mpsc::Sender<StreamJob>>>,
    handle: Mutex<Option<thread::JoinHandle<()>>>,
    dim: usize,
}

impl StreamService {
    fn start(mut engine: SessionEngine, metrics: Arc<Mutex<Metrics>>) -> StreamService {
        let dim = engine.model.spec.dim;
        let (tx, rx) = mpsc::channel::<StreamJob>();
        let handle = thread::Builder::new()
            .name("http-stream".to_string())
            .spawn(move || {
                let mut live: Vec<(StreamTicket, mpsc::Sender<StreamEvent>, usize)> = Vec::new();
                let mut open = true;
                loop {
                    // Intake: block only when the engine has nothing to do.
                    if live.is_empty() && open {
                        match rx.recv_timeout(Duration::from_millis(50)) {
                            Ok(job) => {
                                let t = engine.submit_traced(job.tokens, job.trace);
                                live.push((t, job.events, 0));
                            }
                            Err(mpsc::RecvTimeoutError::Timeout) => {}
                            Err(mpsc::RecvTimeoutError::Disconnected) => open = false,
                        }
                    }
                    loop {
                        match rx.try_recv() {
                            Ok(job) => {
                                let t = engine.submit_traced(job.tokens, job.trace);
                                live.push((t, job.events, 0));
                            }
                            Err(mpsc::TryRecvError::Empty) => break,
                            Err(mpsc::TryRecvError::Disconnected) => {
                                open = false;
                                break;
                            }
                        }
                    }
                    if live.is_empty() {
                        if !open {
                            break;
                        }
                        continue;
                    }
                    {
                        let mut m = metrics.lock().unwrap();
                        engine.step(&mut m);
                    }
                    live.retain_mut(|(t, events, last_fed)| {
                        if let Some(out) = engine.poll(t) {
                            // a dropped receiver just means the client went
                            // away mid-stream; the session still completed
                            let _ = events.send(StreamEvent::Done {
                                tokens: out.tokens,
                                logits: out.logits,
                            });
                            return false;
                        }
                        if let StreamStatus::Streaming { fed, total } = engine.status(t) {
                            if fed != *last_fed {
                                *last_fed = fed;
                                let _ = events.send(StreamEvent::Progress { fed, total });
                            }
                        }
                        true
                    });
                }
            })
            .expect("spawn http stream service thread");
        StreamService {
            tx: Mutex::new(Some(tx)),
            handle: Mutex::new(Some(handle)),
            dim,
        }
    }

    fn submit(
        &self,
        tokens: Vec<f32>,
        events: mpsc::Sender<StreamEvent>,
        trace: TraceCtx,
    ) -> Result<()> {
        let guard = self.tx.lock().unwrap();
        let tx = guard
            .as_ref()
            .ok_or_else(|| anyhow!("stream service is draining"))?;
        tx.send(StreamJob {
            tokens,
            events,
            trace,
        })
        .map_err(|_| anyhow!("stream service thread exited"))
    }

    /// Drain: close the inbox, let live sessions finish, join the thread.
    fn stop(&self) {
        drop(self.tx.lock().unwrap().take());
        if let Some(h) = self.handle.lock().unwrap().take() {
            let _ = h.join();
        }
    }
}

struct Shared {
    router: Mutex<Router>,
    done: crate::fleet::worker::DoneMap,
    /// front-door stage latencies + ingress audit trail, plus the stream
    /// engine's gauges (its service thread steps into this same object)
    metrics: Arc<Mutex<Metrics>>,
    stream: Option<StreamService>,
    bundle_digest: Option<String>,
    next_id: AtomicUsize,
    inflight: AtomicUsize,
    shutdown: AtomicBool,
    cfg: FrontDoorConfig,
}

/// The running front door: accept loop + handler pool over one [`Router`].
pub struct HttpFrontDoor {
    addr: SocketAddr,
    shared: Arc<Shared>,
    accept: Option<thread::JoinHandle<()>>,
}

impl HttpFrontDoor {
    /// Bind `bind` (e.g. `"127.0.0.1:0"` for an ephemeral test port) and
    /// start serving `router` — plus `/stream` when a [`SessionEngine`] is
    /// supplied (native backends only; without one `/stream` answers 503).
    pub fn start(
        router: Router,
        stream_engine: Option<SessionEngine>,
        bind: &str,
        cfg: FrontDoorConfig,
    ) -> Result<HttpFrontDoor> {
        let listener = TcpListener::bind(bind)?;
        let addr = listener.local_addr()?;
        let metrics = Arc::new(Mutex::new(Metrics::default()));
        let shared = Arc::new(Shared {
            done: router.done_map(),
            bundle_digest: router.bundle_digest().map(String::from),
            stream: stream_engine.map(|e| StreamService::start(e, Arc::clone(&metrics))),
            router: Mutex::new(router),
            metrics,
            next_id: AtomicUsize::new(0),
            inflight: AtomicUsize::new(0),
            shutdown: AtomicBool::new(false),
            cfg,
        });
        let accept_shared = Arc::clone(&shared);
        let accept = thread::Builder::new()
            .name("http-accept".to_string())
            .spawn(move || {
                // the pool lives (and drains, via Drop) in the accept thread
                let pool = Pool::new(accept_shared.cfg.handlers);
                for conn in listener.incoming() {
                    if accept_shared.shutdown.load(Ordering::Acquire) {
                        break;
                    }
                    let mut sock = match conn {
                        Ok(s) => s,
                        Err(_) => continue,
                    };
                    if accept_shared.inflight.load(Ordering::SeqCst)
                        >= accept_shared.cfg.max_inflight
                    {
                        let body = error_body("server at capacity");
                        let _ = write_response(&mut sock, 503, "application/json", &body);
                        continue;
                    }
                    accept_shared.inflight.fetch_add(1, Ordering::SeqCst);
                    let sh = Arc::clone(&accept_shared);
                    drop(pool.submit(move || {
                        handle_connection(&sh, sock);
                        sh.inflight.fetch_sub(1, Ordering::SeqCst);
                    }));
                }
            })
            .map_err(|e| anyhow!("spawn http accept thread: {e}"))?;
        Ok(HttpFrontDoor {
            addr,
            shared,
            accept: Some(accept),
        })
    }

    /// The bound address (resolves `:0` bindings for tests).
    pub fn addr(&self) -> SocketAddr {
        self.addr
    }

    /// Chaos hook: kill a fleet worker under live HTTP traffic.
    pub fn kill_worker(&self, id: usize) -> Result<()> {
        self.shared.router.lock().unwrap().kill_worker(id)
    }

    /// Graceful drain: stop accepting, finish in-flight handlers, retire
    /// the stream service, then drain and join every fleet worker.
    pub fn shutdown(mut self) -> Result<()> {
        self.shared.shutdown.store(true, Ordering::Release);
        // unblock the accept loop (it re-checks the flag per connection)
        let _ = TcpStream::connect(self.addr);
        if let Some(h) = self.accept.take() {
            let _ = h.join();
        }
        if let Some(svc) = &self.shared.stream {
            svc.stop();
        }
        self.shared.router.lock().unwrap().shutdown()
    }
}

fn error_body(msg: &str) -> Vec<u8> {
    Json::obj(vec![("error", Json::str(msg))])
        .to_string()
        .into_bytes()
}

fn respond(sock: &mut TcpStream, status: u16, body: &Json) {
    let _ = write_response(sock, status, "application/json", body.to_string().as_bytes());
}

fn respond_error(sock: &mut TcpStream, status: u16, msg: &str) {
    let _ = write_response(sock, status, "application/json", &error_body(msg));
}

/// True when a `/metrics` request asked for Prometheus text exposition
/// (`?format=prometheus`).
fn wants_prometheus(req: &HttpRequest) -> bool {
    req.query
        .as_deref()
        .is_some_and(|q| q.split('&').any(|kv| kv == "format=prometheus"))
}

/// One Prometheus exposition over everything this process measures: the
/// fleet's merged engine metrics folded together with the front door's
/// HTTP-stage metrics (disjoint stage labels, so the merge is lossless).
fn prometheus_body(shared: &Shared) -> String {
    let (mut merged, _) = shared.router.lock().unwrap().metrics_report();
    merged.merge(&shared.metrics.lock().unwrap());
    merged.to_prometheus()
}

/// Replace a Metrics JSON section's full `request_ids` audit list with a
/// bounded `recent_request_ids` window, keeping the `/metrics` response
/// size independent of how long the server has been up (the `requests`
/// counter carries the total).
fn bound_request_ids(section: &mut Json) {
    if let Json::Obj(map) = section {
        if let Some(ids) = map.remove("request_ids") {
            let ids = ids.as_arr().unwrap_or(&[]);
            let start = ids.len().saturating_sub(RECENT_IDS);
            map.insert(
                "recent_request_ids".to_string(),
                Json::Arr(ids[start..].to_vec()),
            );
        }
    }
}

fn handle_connection(shared: &Shared, mut sock: TcpStream) {
    let _ = sock.set_read_timeout(Some(shared.cfg.io_timeout));
    let _ = sock.set_write_timeout(Some(shared.cfg.io_timeout));
    let reader_sock = match sock.try_clone() {
        Ok(s) => s,
        Err(_) => return,
    };
    let mut reader = BufReader::new(reader_sock);
    let req = match read_request(&mut reader) {
        Ok(Some(r)) => r,
        Ok(None) => return, // peer connected and left (e.g. the shutdown poke)
        Err(e) => {
            respond_error(&mut sock, 400, &format!("{e:#}"));
            return;
        }
    };
    match (req.method.as_str(), req.path.as_str()) {
        ("GET", "/liveness") => {
            let report = shared.router.lock().unwrap().liveness();
            respond(&mut sock, if report.live { 200 } else { 503 }, &report.to_json());
        }
        ("GET", "/readiness") => {
            let report = shared.router.lock().unwrap().readiness();
            respond(&mut sock, if report.ready { 200 } else { 503 }, &report.to_json());
        }
        ("GET", "/metrics") if wants_prometheus(&req) => {
            let body = prometheus_body(shared);
            let _ = write_response(
                &mut sock,
                200,
                "text/plain; version=0.0.4; charset=utf-8",
                body.as_bytes(),
            );
        }
        ("GET", "/metrics") => {
            let mut j = shared.router.lock().unwrap().metrics_json();
            if let Json::Obj(map) = &mut j {
                if let Some(engine) = map.get_mut("engine") {
                    bound_request_ids(engine);
                }
                let mut front = shared.metrics.lock().unwrap().to_json();
                bound_request_ids(&mut front);
                map.insert("front_door".to_string(), front);
            }
            respond(&mut sock, 200, &j);
        }
        ("GET", "/metrics.prom") => {
            let body = prometheus_body(shared);
            let _ = write_response(
                &mut sock,
                200,
                "text/plain; version=0.0.4; charset=utf-8",
                body.as_bytes(),
            );
        }
        ("GET", "/trace") => {
            respond(&mut sock, 200, &otrace::export_chrome());
        }
        ("POST", "/classify") => classify(shared, &req, &mut sock),
        ("POST", "/stream") => stream(shared, &req, &mut sock),
        (
            _,
            "/liveness" | "/readiness" | "/metrics" | "/metrics.prom" | "/trace" | "/classify"
            | "/stream",
        ) => {
            respond_error(
                &mut sock,
                405,
                &format!("{} does not accept {}", req.path, req.method),
            );
        }
        (_, path) => respond_error(&mut sock, 404, &format!("no route for {path}")),
    }
}

/// Parse a `/classify` body: `{"pixels": [f32; expected], "label"?: n}`.
fn parse_classify(body: &str, expected: usize) -> Result<(Vec<f32>, Option<usize>)> {
    let j = Json::parse(body)?;
    let arr = j
        .get("pixels")
        .and_then(|v| v.as_arr())
        .ok_or_else(|| anyhow!("body must be {{\"pixels\": [f32; {expected}], \"label\"?: n}}"))?;
    if arr.len() != expected {
        bail!("expected {expected} pixels, got {}", arr.len());
    }
    let pixels: Vec<f32> = arr
        .iter()
        .map(|v| {
            v.as_f64()
                .map(|f| f as f32)
                .ok_or_else(|| anyhow!("pixels must all be numbers"))
        })
        .collect::<Result<_>>()?;
    Ok((pixels, j.get("label").and_then(|v| v.as_usize())))
}

fn argmax(xs: &[f32]) -> usize {
    let mut best = 0usize;
    for (i, &v) in xs.iter().enumerate() {
        if v > xs[best] {
            best = i;
        }
    }
    best
}

/// Block until the fleet completes `ticket`: poll + supervise under a
/// brief router lock, then wait a bounded slice on the done-table condvar
/// with the lock released — N handlers park here concurrently while the
/// worker threads step.
fn wait_for(
    shared: &Shared,
    ticket: &FleetTicket,
    timeout: Duration,
) -> std::result::Result<RequestOutput, (u16, String)> {
    let t0 = Instant::now();
    loop {
        {
            let mut r = shared.router.lock().unwrap();
            if let Some(out) = r.poll(ticket) {
                return Ok(out);
            }
            if let Err(e) = r.supervise() {
                return Err((503, format!("{e:#}")));
            }
        }
        if t0.elapsed() > timeout {
            // Give up on the request for real: retire the in-flight copy
            // (so supervision stops resubmitting it) and cancel its
            // done-table id (so the worker's late completion is dropped
            // instead of pinned in the table forever).
            shared.router.lock().unwrap().acknowledge(ticket.id);
            return Err((
                504,
                format!("request {} not completed within {timeout:?}", ticket.id),
            ));
        }
        if let Some(out) = shared.done.wait_remove(ticket.id, Duration::from_millis(5)) {
            shared.router.lock().unwrap().acknowledge(ticket.id);
            return Ok(out);
        }
    }
}

fn classify(shared: &Shared, req: &HttpRequest, sock: &mut TcpStream) {
    let t0 = Instant::now();
    let body = match req.body_text() {
        Ok(b) => b,
        Err(e) => return respond_error(sock, 400, &format!("{e:#}")),
    };
    let (pixels, label) = match parse_classify(body, shared.cfg.pixels) {
        Ok(p) => p,
        Err(e) => return respond_error(sock, 400, &format!("{e:#}")),
    };
    let id = shared.next_id.fetch_add(1, Ordering::SeqCst);
    // Ingress root span: covers placement, the condvar wait, and the
    // response write; every downstream span (place → worker_inbox →
    // backend_step → kernel dispatches) parents on its context.
    let mut span = otrace::root("http_classify");
    if otrace::enabled() {
        span.arg("id", id.to_string());
    }
    let request = Request {
        id,
        pixels,
        label,
        arrived: Instant::now(),
        trace: span.ctx(),
    };
    let ticket = match shared.router.lock().unwrap().submit(request) {
        Ok(t) => t,
        Err(e) => return respond_error(sock, 503, &format!("{e:#}")),
    };
    let out = match wait_for(shared, &ticket, shared.cfg.request_timeout) {
        Ok(o) => o,
        Err((status, msg)) => return respond_error(sock, status, &msg),
    };
    let mut rows = vec![
        ("id", Json::num(id as f64)),
        ("pred", Json::num(argmax(&out.logits) as f64)),
        (
            "logits",
            Json::Arr(out.logits.iter().map(|&v| Json::Num(v as f64)).collect()),
        ),
        ("latency_ms", Json::num(out.latency_ms())),
    ];
    if let Some(d) = &shared.bundle_digest {
        rows.push(("bundle_digest", Json::str(d)));
    }
    respond(sock, 200, &Json::obj(rows));
    let mut m = shared.metrics.lock().unwrap();
    m.record("http_classify", t0.elapsed().as_secs_f64() * 1e3);
    m.requests += 1;
    m.push_request_id(id);
}

/// Parse a `/stream` body: `{"tokens": [f32; n·dim]}` with `n ≥ 1`.
fn parse_stream(body: &str, dim: usize) -> Result<Vec<f32>> {
    let j = Json::parse(body)?;
    let arr = j
        .get("tokens")
        .and_then(|v| v.as_arr())
        .ok_or_else(|| anyhow!("body must be {{\"tokens\": [f32; n*{dim}]}}"))?;
    if arr.is_empty() || arr.len() % dim != 0 {
        bail!(
            "tokens must be a non-empty multiple of dim={dim} floats, got {}",
            arr.len()
        );
    }
    arr.iter()
        .map(|v| {
            v.as_f64()
                .map(|f| f as f32)
                .ok_or_else(|| anyhow!("tokens must all be numbers"))
        })
        .collect()
}

fn stream_event_line(event: &str, rows: Vec<(&str, Json)>) -> Vec<u8> {
    let mut all = vec![("event", Json::str(event))];
    all.extend(rows);
    let mut line = Json::obj(all).to_string();
    line.push('\n');
    line.into_bytes()
}

fn stream(shared: &Shared, req: &HttpRequest, sock: &mut TcpStream) {
    let t0 = Instant::now();
    let Some(svc) = &shared.stream else {
        return respond_error(sock, 503, "no stream service (serve a native backend)");
    };
    let body = match req.body_text() {
        Ok(b) => b,
        Err(e) => return respond_error(sock, 400, &format!("{e:#}")),
    };
    // Pre-validate so a bad shape is a 400 here, not an assert in the
    // engine's submit on the service thread.
    let tokens = match parse_stream(body, svc.dim) {
        Ok(t) => t,
        Err(e) => return respond_error(sock, 400, &format!("{e:#}")),
    };
    let (etx, erx) = mpsc::channel();
    // Ingress root span for the stream: the engine's step/decode/prefill
    // spans parent on it through the session's stored context.
    let span = otrace::root("http_stream");
    if let Err(e) = svc.submit(tokens, etx, span.ctx()) {
        return respond_error(sock, 503, &format!("{e:#}"));
    }
    let mut cw = match ChunkedWriter::begin(sock, 200, "application/jsonl") {
        Ok(c) => c,
        Err(_) => return,
    };
    let deadline = t0 + shared.cfg.request_timeout;
    loop {
        let now = Instant::now();
        if now >= deadline {
            let _ = cw.chunk(&stream_event_line(
                "error",
                vec![("error", Json::str("stream timed out"))],
            ));
            break;
        }
        match erx.recv_timeout((deadline - now).min(Duration::from_millis(100))) {
            Ok(StreamEvent::Progress { fed, total }) => {
                let line = stream_event_line(
                    "progress",
                    vec![
                        ("fed", Json::num(fed as f64)),
                        ("total", Json::num(total as f64)),
                    ],
                );
                if cw.chunk(&line).is_err() {
                    return; // client went away; the session finishes anyway
                }
            }
            Ok(StreamEvent::Done { tokens, logits }) => {
                let line = stream_event_line(
                    "done",
                    vec![
                        ("tokens", Json::num(tokens as f64)),
                        (
                            "logits",
                            Json::Arr(logits.iter().map(|&v| Json::Num(v as f64)).collect()),
                        ),
                    ],
                );
                let _ = cw.chunk(&line);
                break;
            }
            Err(mpsc::RecvTimeoutError::Timeout) => continue,
            Err(mpsc::RecvTimeoutError::Disconnected) => {
                let _ = cw.chunk(&stream_event_line(
                    "error",
                    vec![("error", Json::str("stream service exited"))],
                ));
                break;
            }
        }
    }
    let _ = cw.finish();
    let mut m = shared.metrics.lock().unwrap();
    m.record("http_stream", t0.elapsed().as_secs_f64() * 1e3);
    m.requests += 1;
}

/// Build the `/stream` engine from a [`ServerConfig`] (native only): the
/// same planner/bundle path as `serve_stream`, one engine for the whole
/// front door.
fn build_stream_engine(cfg: &ServerConfig) -> Result<SessionEngine> {
    let bundle = load_bundle(cfg)?;
    let planner = create_planner(cfg)?;
    if let Some(b) = &bundle {
        let pinned = planner.pin_table_json(&b.table)?;
        println!("bundle: pinned {pinned} planner choices for the stream engine");
    }
    let model = StreamModel::new(
        SessionSpec::tiny(StreamAttn::LinearAdd, crate::model::ops::Lin::Shift),
        planner,
    );
    Ok(SessionEngine::with_mode(
        model,
        cfg.stream_chunk.max(1),
        cfg.max_live.max(1),
        engine_mode(cfg),
    ))
}

/// `shiftaddvit serve --http PORT`: build the fleet from `cfg`, start the
/// front door on `0.0.0.0:port`, and serve until the process is killed
/// (the CI smoke backgrounds and SIGKILLs it).
pub fn serve_http(cfg: &ServerConfig, port: usize) -> Result<()> {
    // The front door always records spans: `GET /trace` is only useful
    // when the ring has something in it, and the off-path cost is one
    // bounded ring append per span.
    otrace::set_enabled(true);
    let router = Router::from_server_config(cfg)?;
    println!(
        "fleet: {} workers ready  policy {}",
        router.worker_count(),
        router.policy_name()
    );
    let stream_engine = if cfg.backend == BackendKind::Native {
        Some(build_stream_engine(cfg)?)
    } else {
        None
    };
    let door = HttpFrontDoor::start(
        router,
        stream_engine,
        &format!("0.0.0.0:{port}"),
        FrontDoorConfig::default(),
    )?;
    println!("http: front door listening on {}", door.addr());
    println!(
        "http: GET /liveness | /readiness | /metrics | /metrics.prom | /trace   POST /classify | /stream"
    );
    loop {
        thread::sleep(Duration::from_secs(3600));
    }
}
