//! Real model configurations from the paper's evaluation.

/// One pyramid stage (or the single stage of an isotropic model).
#[derive(Clone, Copy, Debug)]
pub struct Stage {
    /// tokens in this stage (H/stride × W/stride)
    pub tokens: usize,
    pub dim: usize,
    pub depth: usize,
    pub heads: usize,
    pub mlp_ratio: usize,
}

/// A full backbone: a sequence of stages.
#[derive(Clone, Debug)]
pub struct ModelSpec {
    pub name: &'static str,
    pub input: usize,
    pub stages: Vec<Stage>,
}

impl ModelSpec {
    pub fn total_blocks(&self) -> usize {
        self.stages.iter().map(|s| s.depth).sum()
    }
}

fn pvt_stages(dims: [usize; 4], depths: [usize; 4], heads: [usize; 4], ratios: [usize; 4], input: usize) -> Vec<Stage> {
    let sides = [input / 4, input / 8, input / 16, input / 32];
    (0..4)
        .map(|i| Stage {
            tokens: sides[i] * sides[i],
            dim: dims[i],
            depth: depths[i],
            heads: heads[i],
            mlp_ratio: ratios[i],
        })
        .collect()
}

/// The five classification models of Tables 3/4/6 (true shapes, 224²).
pub fn classifier(name: &str) -> ModelSpec {
    let input = 224;
    match name {
        "pvtv2_b0" => ModelSpec {
            name: "PVTv2-B0",
            input,
            stages: pvt_stages([32, 64, 160, 256], [2, 2, 2, 2], [1, 2, 5, 8], [8, 8, 4, 4], input),
        },
        "pvtv2_b1" => ModelSpec {
            name: "PVTv2-B1",
            input,
            stages: pvt_stages([64, 128, 320, 512], [2, 2, 2, 2], [1, 2, 5, 8], [8, 8, 4, 4], input),
        },
        "pvtv2_b2" => ModelSpec {
            name: "PVTv2-B2",
            input,
            stages: pvt_stages([64, 128, 320, 512], [3, 4, 6, 3], [1, 2, 5, 8], [8, 8, 4, 4], input),
        },
        "pvtv1_t" => ModelSpec {
            name: "PVTv1-T",
            input,
            stages: pvt_stages([64, 128, 320, 512], [2, 2, 2, 2], [1, 2, 5, 8], [8, 8, 4, 4], input),
        },
        "deit_t" => ModelSpec {
            name: "DeiT-T",
            input,
            stages: vec![Stage {
                tokens: 197,
                dim: 192,
                depth: 12,
                heads: 3,
                mlp_ratio: 4,
            }],
        },
        other => panic!("unknown model '{other}'"),
    }
}

/// GNT-style NVS model (Table 5): ray/view transformers over sampled points.
/// Per rendered ray: `points` transformer tokens through `depth` blocks.
pub fn gnt() -> ModelSpec {
    ModelSpec {
        name: "GNT",
        input: 0,
        stages: vec![Stage {
            tokens: 192, // coarse points per ray (paper Appendix E)
            dim: 256,
            depth: 8,
            heads: 4,
            mlp_ratio: 2,
        }],
    }
}

/// NeRF MLP baseline (Table 5): 8×256 MLP per point, no attention.
pub fn nerf() -> ModelSpec {
    ModelSpec {
        name: "NeRF",
        input: 0,
        stages: vec![Stage {
            tokens: 192,
            dim: 256,
            depth: 8,
            heads: 1,
            mlp_ratio: 1,
        }],
    }
}

/// LRA transformer (Table 11): 2-layer, d=64 (the LRA benchmark default
/// small config), at the given sequence length.
pub fn lra(seq: usize) -> ModelSpec {
    ModelSpec {
        name: "LRA-Transformer",
        input: 0,
        stages: vec![Stage {
            tokens: seq,
            dim: 64,
            depth: 2,
            heads: 2,
            mlp_ratio: 2,
        }],
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pvt_token_counts() {
        let b0 = classifier("pvtv2_b0");
        assert_eq!(b0.stages[0].tokens, 56 * 56);
        assert_eq!(b0.stages[3].tokens, 7 * 7);
        assert_eq!(b0.total_blocks(), 8);
    }

    #[test]
    fn b2_deeper_than_b1() {
        assert!(classifier("pvtv2_b2").total_blocks() > classifier("pvtv2_b1").total_blocks());
    }

    #[test]
    #[should_panic]
    fn unknown_model_panics() {
        classifier("nope");
    }
}
