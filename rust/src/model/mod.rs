//! Model zoo metadata: the *real* paper model configurations (PVTv1/v2,
//! DeiT, GNT, LRA transformers) with per-layer operation counting under each
//! ShiftAddViT variant. The analytical energy/latency tables (3/5/11/13,
//! Fig. 3) are computed from these true shapes; the *runnable* JAX models
//! are tiny analogues (python/compile/model.py) whose measured latencies
//! provide the wall-clock columns.

pub mod config;
pub mod ops;
