//! Per-layer operation counting under each ShiftAddViT variant — the input
//! to the Eyeriss energy/latency model.

use std::sync::OnceLock;

use crate::energy::ops::MacStyle;
use crate::kernels::api::Primitive;
use crate::kernels::registry::KernelRegistry;
use crate::model::config::ModelSpec;

/// Which primitives implement each layer family (mirrors
/// `python/compile/model.py::Variant`).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct Variant {
    pub attn: Attn,
    pub attn_linear: Lin,
    pub mlp: Mlp,
}

#[derive(Clone, Copy, Debug, PartialEq)]
pub enum Attn {
    /// softmax MSA, quadratic in tokens
    Msa,
    /// linear attention Q(KV), full precision
    Linear,
    /// linear attention with binarized Q/K → MatAdd accumulations
    LinearAdd,
}

#[derive(Clone, Copy, Debug, PartialEq)]
pub enum Lin {
    Mult,
    Shift,
}

#[derive(Clone, Copy, Debug, PartialEq)]
pub enum Mlp {
    Mult,
    Shift,
    /// MoE: `mult_frac` of tokens to the Mult expert, rest to Shift.
    Moe { mult_frac_pct: u8 },
}

impl Variant {
    pub const MSA: Variant = Variant {
        attn: Attn::Msa,
        attn_linear: Lin::Mult,
        mlp: Mlp::Mult,
    };
    pub const LINEAR: Variant = Variant {
        attn: Attn::Linear,
        attn_linear: Lin::Mult,
        mlp: Mlp::Mult,
    };
    pub const ADD: Variant = Variant {
        attn: Attn::LinearAdd,
        attn_linear: Lin::Mult,
        mlp: Mlp::Mult,
    };
    pub const ADD_SHIFT_ATTN: Variant = Variant {
        attn: Attn::LinearAdd,
        attn_linear: Lin::Shift,
        mlp: Mlp::Mult,
    };
    pub const ADD_SHIFT_BOTH: Variant = Variant {
        attn: Attn::LinearAdd,
        attn_linear: Lin::Shift,
        mlp: Mlp::Shift,
    };
    pub const SHIFTADD_MOE: Variant = Variant {
        attn: Attn::LinearAdd,
        attn_linear: Lin::Shift,
        mlp: Mlp::Moe { mult_frac_pct: 50 },
    };
}

/// MAC counts bucketed by primitive style, plus byte traffic.
#[derive(Clone, Debug, Default)]
pub struct OpsBreakdown {
    /// (style, macs) pairs per layer family
    pub attn_matmul: Vec<(MacStyle, f64)>,
    pub attn_linear: Vec<(MacStyle, f64)>,
    pub mlp: Vec<(MacStyle, f64)>,
    pub other: Vec<(MacStyle, f64)>,
    /// activation bytes moved through DRAM (per inference)
    pub act_bytes: f64,
    /// weight bytes moved through DRAM (per inference)
    pub weight_bytes: f64,
}

impl OpsBreakdown {
    pub fn total_macs(&self) -> f64 {
        self.all().iter().map(|(_, m)| m).sum()
    }

    pub fn all(&self) -> Vec<(MacStyle, f64)> {
        let mut v = self.attn_matmul.clone();
        v.extend(self.attn_linear.clone());
        v.extend(self.mlp.clone());
        v.extend(self.other.clone());
        v
    }
}

/// MAC styles contributed by the *deployment* kernel backends, resolved
/// from a [`KernelRegistry`] so the Eyeriss op counting always reflects what
/// the kernel layer actually executes rather than hardcoded tags: register a
/// backend with a different `mac_style()` and every energy/latency table
/// follows.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct PrimitiveStyles {
    pub matmul: MacStyle,
    pub matadd: MacStyle,
    pub matshift: MacStyle,
}

impl PrimitiveStyles {
    /// Resolve from the deployment backend of each primitive (the format
    /// model conversion produces); a missing backend keeps the paper tag.
    pub fn from_registry(registry: &KernelRegistry) -> PrimitiveStyles {
        let style = |p: Primitive, backend: &str, fallback: MacStyle| {
            registry
                .get(p, backend)
                .map(|k| k.mac_style())
                .unwrap_or(fallback)
        };
        PrimitiveStyles {
            matmul: style(Primitive::MatMul, "blocked", MacStyle::MultFp32),
            matadd: style(Primitive::MatAdd, "packed", MacStyle::AddInt32),
            matshift: style(Primitive::MatShift, "planes", MacStyle::ShiftInt32),
        }
    }
}

impl Default for PrimitiveStyles {
    /// Styles of the default registry, resolved once: `count()` runs in
    /// tight harness loops, and the default backends are static.
    fn default() -> Self {
        static DEFAULT: OnceLock<PrimitiveStyles> = OnceLock::new();
        *DEFAULT.get_or_init(|| PrimitiveStyles::from_registry(&KernelRegistry::with_defaults()))
    }
}

/// Count one inference (batch 1) of `spec` under `var`, with MAC styles
/// taken from the default registry's deployment backends.
pub fn count(spec: &ModelSpec, var: Variant) -> OpsBreakdown {
    count_with(spec, var, &PrimitiveStyles::default())
}

/// [`count`] against an explicit style mapping (custom registries).
pub fn count_with(spec: &ModelSpec, var: Variant, styles: &PrimitiveStyles) -> OpsBreakdown {
    let lin_style = |l: Lin| match l {
        Lin::Mult => styles.matmul,
        Lin::Shift => styles.matshift,
    };
    let mut b = OpsBreakdown::default();
    for st in &spec.stages {
        let n = st.tokens as f64;
        let d = st.dim as f64;
        let dk = (st.dim / st.heads.max(1)) as f64;
        let h = (st.mlp_ratio as f64) * d;
        for _ in 0..st.depth {
            // --- attention MatMuls -------------------------------------
            let lstyle = lin_style(var.attn_linear);
            match var.attn {
                Attn::Msa => {
                    // QKᵀ + AV: 2·N²·d (softmax itself not MAC-counted)
                    b.attn_matmul.push((styles.matmul, 2.0 * n * n * d));
                }
                Attn::Linear => {
                    // KV + Q(KV): 2·N·d·dk, full precision
                    b.attn_matmul.push((styles.matmul, 2.0 * n * d * dk));
                    b.other.push((styles.matmul, 9.0 * n * d)); // DWConv
                }
                Attn::LinearAdd => {
                    // binarized operand ⇒ accumulation-only MACs
                    b.attn_matmul.push((styles.matadd, 2.0 * n * d * dk));
                    b.other.push((styles.matmul, 9.0 * n * d)); // DWConv
                }
            }
            // --- the four attention Linears -----------------------------
            b.attn_linear.push((lstyle, 4.0 * n * d * d));
            // --- MLP ----------------------------------------------------
            let mlp_macs = 2.0 * n * d * h;
            match var.mlp {
                Mlp::Mult => b.mlp.push((styles.matmul, mlp_macs)),
                Mlp::Shift => b.mlp.push((styles.matshift, mlp_macs)),
                Mlp::Moe { mult_frac_pct } => {
                    let f = mult_frac_pct as f64 / 100.0;
                    b.mlp.push((styles.matmul, mlp_macs * f));
                    b.mlp.push((styles.matshift, mlp_macs * (1.0 - f)));
                    // router: N·d·2
                    b.other.push((styles.matmul, 2.0 * n * d));
                }
            }
            // --- bytes ---------------------------------------------------
            // activations in+out per sublayer (4 sublayers worth of N·d f32)
            b.act_bytes += 4.0 * 4.0 * n * d;
            // weights: attention linears + MLP, bytes per weight by style
            b.weight_bytes += 4.0 * d * d * lstyle.weight_bytes();
            let mlp_wbytes = match var.mlp {
                Mlp::Mult => styles.matmul.weight_bytes(),
                Mlp::Shift => styles.matshift.weight_bytes(),
                // MoE stores both experts
                Mlp::Moe { .. } => {
                    styles.matmul.weight_bytes() + styles.matshift.weight_bytes()
                }
            };
            b.weight_bytes += 2.0 * d * h * mlp_wbytes;
        }
    }
    b
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::config::classifier;

    #[test]
    fn msa_quadratic_dominates_stage1() {
        // At 56×56 = 3136 tokens, MSA attention MACs exceed linear's.
        let spec = classifier("pvtv2_b0");
        let msa = count(&spec, Variant::MSA);
        let lin = count(&spec, Variant::LINEAR);
        let msa_attn: f64 = msa.attn_matmul.iter().map(|(_, m)| m).sum();
        let lin_attn: f64 = lin.attn_matmul.iter().map(|(_, m)| m).sum();
        assert!(msa_attn > 10.0 * lin_attn, "{msa_attn} vs {lin_attn}");
    }

    #[test]
    fn reparameterization_preserves_total_macs_roughly() {
        // Shift/Add change the *style*, not the count (modulo DWConv/router).
        let spec = classifier("pvtv2_b0");
        let lin = count(&spec, Variant::LINEAR).total_macs();
        let sa = count(&spec, Variant::ADD_SHIFT_BOTH).total_macs();
        assert!((lin - sa).abs() / lin < 0.02, "{lin} vs {sa}");
    }

    #[test]
    fn mlp_dominates_flops_on_pvt() {
        // Paper intro: MLPs ≈ 63% of FLOPs (DeiT-B); PVT similar ballpark.
        let spec = classifier("pvtv2_b0");
        let b = count(&spec, Variant::LINEAR);
        let mlp: f64 = b.mlp.iter().map(|(_, m)| m).sum();
        assert!(mlp / b.total_macs() > 0.45, "{}", mlp / b.total_macs());
    }

    #[test]
    fn shift_weights_move_half_the_bytes() {
        let spec = classifier("pvtv2_b0");
        let mult = count(&spec, Variant::LINEAR);
        let shift = count(&spec, Variant::ADD_SHIFT_BOTH);
        assert!(shift.weight_bytes < 0.6 * mult.weight_bytes);
    }

    #[test]
    fn styles_resolve_from_registry_backends() {
        // The default mapping must match the paper's deployment tags…
        let styles = PrimitiveStyles::default();
        assert_eq!(styles.matmul, MacStyle::MultFp32);
        assert_eq!(styles.matadd, MacStyle::AddInt32);
        assert_eq!(styles.matshift, MacStyle::ShiftInt32);
        // …and an empty registry falls back rather than panicking.
        let empty = KernelRegistry::new();
        assert_eq!(PrimitiveStyles::from_registry(&empty), styles);
    }

    #[test]
    fn count_with_custom_styles_changes_energy_tags() {
        // An embedder swapping the shift deployment backend for an INT8-mult
        // one must see the tag flow through the breakdown.
        let spec = classifier("pvtv2_b0");
        let styles = PrimitiveStyles {
            matshift: MacStyle::MultInt8,
            ..PrimitiveStyles::default()
        };
        let b = count_with(&spec, Variant::ADD_SHIFT_BOTH, &styles);
        assert!(b.mlp.iter().all(|(s, _)| *s == MacStyle::MultInt8));
        assert!(b.attn_linear.iter().all(|(s, _)| *s == MacStyle::MultInt8));
    }

    #[test]
    fn moe_splits_mlp_between_styles() {
        let spec = classifier("pvtv2_b0");
        let b = count(&spec, Variant::SHIFTADD_MOE);
        let styles: Vec<_> = b.mlp.iter().map(|(s, _)| *s).collect();
        assert!(styles.contains(&MacStyle::MultFp32));
        assert!(styles.contains(&MacStyle::ShiftInt32));
    }
}
