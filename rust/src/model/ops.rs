//! Per-layer operation counting under each ShiftAddViT variant — the input
//! to the Eyeriss energy/latency model.

use crate::energy::ops::MacStyle;
use crate::model::config::ModelSpec;

/// Which primitives implement each layer family (mirrors
/// `python/compile/model.py::Variant`).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct Variant {
    pub attn: Attn,
    pub attn_linear: Lin,
    pub mlp: Mlp,
}

#[derive(Clone, Copy, Debug, PartialEq)]
pub enum Attn {
    /// softmax MSA, quadratic in tokens
    Msa,
    /// linear attention Q(KV), full precision
    Linear,
    /// linear attention with binarized Q/K → MatAdd accumulations
    LinearAdd,
}

#[derive(Clone, Copy, Debug, PartialEq)]
pub enum Lin {
    Mult,
    Shift,
}

#[derive(Clone, Copy, Debug, PartialEq)]
pub enum Mlp {
    Mult,
    Shift,
    /// MoE: `mult_frac` of tokens to the Mult expert, rest to Shift.
    Moe { mult_frac_pct: u8 },
}

impl Variant {
    pub const MSA: Variant = Variant {
        attn: Attn::Msa,
        attn_linear: Lin::Mult,
        mlp: Mlp::Mult,
    };
    pub const LINEAR: Variant = Variant {
        attn: Attn::Linear,
        attn_linear: Lin::Mult,
        mlp: Mlp::Mult,
    };
    pub const ADD: Variant = Variant {
        attn: Attn::LinearAdd,
        attn_linear: Lin::Mult,
        mlp: Mlp::Mult,
    };
    pub const ADD_SHIFT_ATTN: Variant = Variant {
        attn: Attn::LinearAdd,
        attn_linear: Lin::Shift,
        mlp: Mlp::Mult,
    };
    pub const ADD_SHIFT_BOTH: Variant = Variant {
        attn: Attn::LinearAdd,
        attn_linear: Lin::Shift,
        mlp: Mlp::Shift,
    };
    pub const SHIFTADD_MOE: Variant = Variant {
        attn: Attn::LinearAdd,
        attn_linear: Lin::Shift,
        mlp: Mlp::Moe { mult_frac_pct: 50 },
    };
}

/// MAC counts bucketed by primitive style, plus byte traffic.
#[derive(Clone, Debug, Default)]
pub struct OpsBreakdown {
    /// (style, macs) pairs per layer family
    pub attn_matmul: Vec<(MacStyle, f64)>,
    pub attn_linear: Vec<(MacStyle, f64)>,
    pub mlp: Vec<(MacStyle, f64)>,
    pub other: Vec<(MacStyle, f64)>,
    /// activation bytes moved through DRAM (per inference)
    pub act_bytes: f64,
    /// weight bytes moved through DRAM (per inference)
    pub weight_bytes: f64,
}

impl OpsBreakdown {
    pub fn total_macs(&self) -> f64 {
        self.all().iter().map(|(_, m)| m).sum()
    }

    pub fn all(&self) -> Vec<(MacStyle, f64)> {
        let mut v = self.attn_matmul.clone();
        v.extend(self.attn_linear.clone());
        v.extend(self.mlp.clone());
        v.extend(self.other.clone());
        v
    }
}

fn lin_style(l: Lin) -> MacStyle {
    match l {
        Lin::Mult => MacStyle::MultFp32,
        Lin::Shift => MacStyle::ShiftInt32,
    }
}

/// Count one inference (batch 1) of `spec` under `var`.
pub fn count(spec: &ModelSpec, var: Variant) -> OpsBreakdown {
    let mut b = OpsBreakdown::default();
    for st in &spec.stages {
        let n = st.tokens as f64;
        let d = st.dim as f64;
        let dk = (st.dim / st.heads.max(1)) as f64;
        let h = (st.mlp_ratio as f64) * d;
        for _ in 0..st.depth {
            // --- attention MatMuls -------------------------------------
            let lstyle = lin_style(var.attn_linear);
            match var.attn {
                Attn::Msa => {
                    // QKᵀ + AV: 2·N²·d (softmax itself not MAC-counted)
                    b.attn_matmul.push((MacStyle::MultFp32, 2.0 * n * n * d));
                }
                Attn::Linear => {
                    // KV + Q(KV): 2·N·d·dk, full precision
                    b.attn_matmul.push((MacStyle::MultFp32, 2.0 * n * d * dk));
                    b.other.push((MacStyle::MultFp32, 9.0 * n * d)); // DWConv
                }
                Attn::LinearAdd => {
                    // binarized operand ⇒ accumulation-only MACs
                    b.attn_matmul.push((MacStyle::AddInt32, 2.0 * n * d * dk));
                    b.other.push((MacStyle::MultFp32, 9.0 * n * d)); // DWConv
                }
            }
            // --- the four attention Linears -----------------------------
            b.attn_linear.push((lstyle, 4.0 * n * d * d));
            // --- MLP ----------------------------------------------------
            let mlp_macs = 2.0 * n * d * h;
            match var.mlp {
                Mlp::Mult => b.mlp.push((MacStyle::MultFp32, mlp_macs)),
                Mlp::Shift => b.mlp.push((MacStyle::ShiftInt32, mlp_macs)),
                Mlp::Moe { mult_frac_pct } => {
                    let f = mult_frac_pct as f64 / 100.0;
                    b.mlp.push((MacStyle::MultFp32, mlp_macs * f));
                    b.mlp.push((MacStyle::ShiftInt32, mlp_macs * (1.0 - f)));
                    // router: N·d·2
                    b.other.push((MacStyle::MultFp32, 2.0 * n * d));
                }
            }
            // --- bytes ---------------------------------------------------
            // activations in+out per sublayer (4 sublayers worth of N·d f32)
            b.act_bytes += 4.0 * 4.0 * n * d;
            // weights: attention linears + MLP, bytes per weight by style
            b.weight_bytes += 4.0 * d * d * lstyle.weight_bytes();
            let mlp_wbytes = match var.mlp {
                Mlp::Mult => MacStyle::MultFp32.weight_bytes(),
                Mlp::Shift => MacStyle::ShiftInt32.weight_bytes(),
                // MoE stores both experts
                Mlp::Moe { .. } => {
                    MacStyle::MultFp32.weight_bytes() + MacStyle::ShiftInt32.weight_bytes()
                }
            };
            b.weight_bytes += 2.0 * d * h * mlp_wbytes;
        }
    }
    b
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::config::classifier;

    #[test]
    fn msa_quadratic_dominates_stage1() {
        // At 56×56 = 3136 tokens, MSA attention MACs exceed linear's.
        let spec = classifier("pvtv2_b0");
        let msa = count(&spec, Variant::MSA);
        let lin = count(&spec, Variant::LINEAR);
        let msa_attn: f64 = msa.attn_matmul.iter().map(|(_, m)| m).sum();
        let lin_attn: f64 = lin.attn_matmul.iter().map(|(_, m)| m).sum();
        assert!(msa_attn > 10.0 * lin_attn, "{msa_attn} vs {lin_attn}");
    }

    #[test]
    fn reparameterization_preserves_total_macs_roughly() {
        // Shift/Add change the *style*, not the count (modulo DWConv/router).
        let spec = classifier("pvtv2_b0");
        let lin = count(&spec, Variant::LINEAR).total_macs();
        let sa = count(&spec, Variant::ADD_SHIFT_BOTH).total_macs();
        assert!((lin - sa).abs() / lin < 0.02, "{lin} vs {sa}");
    }

    #[test]
    fn mlp_dominates_flops_on_pvt() {
        // Paper intro: MLPs ≈ 63% of FLOPs (DeiT-B); PVT similar ballpark.
        let spec = classifier("pvtv2_b0");
        let b = count(&spec, Variant::LINEAR);
        let mlp: f64 = b.mlp.iter().map(|(_, m)| m).sum();
        assert!(mlp / b.total_macs() > 0.45, "{}", mlp / b.total_macs());
    }

    #[test]
    fn shift_weights_move_half_the_bytes() {
        let spec = classifier("pvtv2_b0");
        let mult = count(&spec, Variant::LINEAR);
        let shift = count(&spec, Variant::ADD_SHIFT_BOTH);
        assert!(shift.weight_bytes < 0.6 * mult.weight_bytes);
    }

    #[test]
    fn moe_splits_mlp_between_styles() {
        let spec = classifier("pvtv2_b0");
        let b = count(&spec, Variant::SHIFTADD_MOE);
        let styles: Vec<_> = b.mlp.iter().map(|(s, _)| *s).collect();
        assert!(styles.contains(&MacStyle::MultFp32));
        assert!(styles.contains(&MacStyle::ShiftInt32));
    }
}
