//! Cross-request continuous batching of streaming sessions (sglang-style
//! router, shrunk to this repo's shape): every [`SessionEngine::step`]
//! packs the next token chunk of EVERY live session into one fused
//! [`StreamModel::extend_batch`] — a single MatMul/MatShift dispatch per
//! linear per layer shared by all live requests — then retires finished
//! sessions and admits queued ones, so requests of different lengths join
//! and leave the batch without ever stalling each other.
//!
//! The engine is deliberately synchronous and deterministic: callers own
//! the step loop (a serving thread, a bench, or a test driving it to
//! completion), and because the fused step is bit-exact against solo
//! stepping (see `infer::session`), every result equals the one-shot
//! full-prefix recompute of that request alone.

use std::collections::{HashMap, VecDeque};
use std::time::Instant;

use crate::coordinator::metrics::Metrics;
use crate::infer::session::{SessionState, StreamModel};

/// Handle to a submitted streaming request.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct StreamTicket {
    pub id: usize,
}

/// Where a streaming request currently is.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum StreamStatus {
    /// waiting for a live slot
    Queued,
    /// live: `fed` of `total` tokens streamed so far
    Streaming { fed: usize, total: usize },
    /// finished — result waiting in [`SessionEngine::poll`]
    Done,
    /// unknown ticket (never submitted, or already polled)
    Unknown,
}

/// Finished request: logits plus latency/stepping diagnostics.
#[derive(Clone, Debug)]
pub struct StreamOutput {
    pub logits: Vec<f32>,
    /// tokens the session streamed end to end
    pub tokens: usize,
    /// engine steps the session was live in
    pub steps: usize,
    pub arrived: Instant,
    pub finished: Instant,
}

impl StreamOutput {
    pub fn latency_ms(&self) -> f64 {
        self.finished.duration_since(self.arrived).as_secs_f64() * 1e3
    }
}

/// Diagnostics from one [`SessionEngine::step`].
#[derive(Clone, Copy, Debug, Default)]
pub struct StepStats {
    /// sessions live during the step
    pub live: usize,
    /// token rows packed into the fused dispatches
    pub tokens: usize,
    /// sessions retired by the step
    pub finished: usize,
    pub step_ms: f64,
}

struct LiveSession {
    id: usize,
    state: SessionState,
    tokens: Vec<f32>,
    /// tokens already streamed
    fed: usize,
    steps: usize,
    arrived: Instant,
}

/// The continuous-batching scheduler over one [`StreamModel`].
pub struct SessionEngine {
    pub model: StreamModel,
    /// tokens each live session contributes per step
    chunk: usize,
    /// live-session cap (admission control)
    max_live: usize,
    queue: VecDeque<(usize, Vec<f32>, Instant)>,
    live: Vec<LiveSession>,
    done: HashMap<usize, StreamOutput>,
    next_id: usize,
}

impl SessionEngine {
    pub fn new(model: StreamModel, chunk: usize, max_live: usize) -> SessionEngine {
        assert!(chunk > 0, "chunk must be positive");
        assert!(max_live > 0, "max_live must be positive");
        SessionEngine {
            model,
            chunk,
            max_live,
            queue: VecDeque::new(),
            live: Vec::new(),
            done: HashMap::new(),
            next_id: 0,
        }
    }

    /// Enqueue one request: a flattened (n × dim) token sequence.
    pub fn submit(&mut self, tokens: Vec<f32>) -> StreamTicket {
        let d = self.model.spec.dim;
        assert!(
            !tokens.is_empty() && tokens.len() % d == 0,
            "request must be a non-empty multiple of dim={d} floats"
        );
        let id = self.next_id;
        self.next_id += 1;
        self.queue.push_back((id, tokens, Instant::now()));
        StreamTicket { id }
    }

    pub fn queued(&self) -> usize {
        self.queue.len()
    }

    pub fn live_count(&self) -> usize {
        self.live.len()
    }

    /// True when no request is queued, live, or waiting to be polled... the
    /// engine has nothing left to do.
    pub fn idle(&self) -> bool {
        self.queue.is_empty() && self.live.is_empty()
    }

    pub fn status(&self, ticket: &StreamTicket) -> StreamStatus {
        if self.queue.iter().any(|(id, _, _)| *id == ticket.id) {
            return StreamStatus::Queued;
        }
        if let Some(s) = self.live.iter().find(|s| s.id == ticket.id) {
            return StreamStatus::Streaming {
                fed: s.fed,
                total: s.tokens.len() / self.model.spec.dim,
            };
        }
        if self.done.contains_key(&ticket.id) {
            return StreamStatus::Done;
        }
        StreamStatus::Unknown
    }

    /// One continuous-batching step: admit queued requests into free live
    /// slots, stream each live session's next chunk through ONE fused
    /// [`StreamModel::extend_batch`], retire finished sessions.
    pub fn step(&mut self, metrics: &mut Metrics) -> StepStats {
        // --- admission ---------------------------------------------------
        while self.live.len() < self.max_live {
            match self.queue.pop_front() {
                Some((id, tokens, arrived)) => self.live.push(LiveSession {
                    id,
                    state: self.model.begin(),
                    tokens,
                    fed: 0,
                    steps: 0,
                    arrived,
                }),
                None => break,
            }
        }
        if self.live.is_empty() {
            return StepStats::default();
        }

        // --- one fused multi-session step --------------------------------
        let t0 = Instant::now();
        let d = self.model.spec.dim;
        let chunk = self.chunk;
        let chunks: Vec<Vec<f32>> = self
            .live
            .iter()
            .map(|s| {
                let total = s.tokens.len() / d;
                let hi = (s.fed + chunk).min(total);
                s.tokens[s.fed * d..hi * d].to_vec()
            })
            .collect();
        let refs: Vec<&[f32]> = chunks.iter().map(|c| c.as_slice()).collect();
        let mut states: Vec<&mut SessionState> =
            self.live.iter_mut().map(|s| &mut s.state).collect();
        let trace = self.model.extend_batch(&mut states, &refs);

        // --- bookkeeping + retirement ------------------------------------
        let live = self.live.len();
        for (s, c) in self.live.iter_mut().zip(&chunks) {
            s.fed += c.len() / d;
            s.steps += 1;
        }
        let mut finished = 0usize;
        let model = &self.model;
        let done = &mut self.done;
        let req_ids = &mut metrics.request_ids;
        self.live.retain(|s| {
            if s.fed * d < s.tokens.len() {
                return true;
            }
            finished += 1;
            req_ids.push(s.id);
            done.insert(
                s.id,
                StreamOutput {
                    logits: model.finish(&s.state),
                    tokens: s.fed,
                    steps: s.steps,
                    arrived: s.arrived,
                    finished: Instant::now(),
                },
            );
            false
        });

        let step_ms = t0.elapsed().as_secs_f64() * 1e3;
        metrics.record("stream_step", step_ms);
        metrics.record_step_occupancy(live, self.max_live, trace.total_tokens);
        metrics.live_sessions.push(live as f64);
        metrics.batches += 1;
        metrics.requests += finished;
        StepStats {
            live,
            tokens: trace.total_tokens,
            finished,
            step_ms,
        }
    }

    /// Remove and return a finished request's output, if ready.
    pub fn poll(&mut self, ticket: &StreamTicket) -> Option<StreamOutput> {
        self.done.remove(&ticket.id)
    }

    /// Step until every submitted request is done. Returns steps taken.
    pub fn run_to_completion(&mut self, metrics: &mut Metrics) -> usize {
        let mut steps = 0usize;
        while !self.idle() {
            self.step(metrics);
            steps += 1;
        }
        steps
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::infer::session::{StreamAttn, StreamModel};
    use crate::model::ops::Lin;
    use crate::util::rng::XorShift64;

    fn engine(chunk: usize, max_live: usize) -> SessionEngine {
        SessionEngine::new(StreamModel::tiny(StreamAttn::LinearAdd, Lin::Mult), chunk, max_live)
    }

    #[test]
    fn mixed_length_requests_complete_and_match_solo() {
        let mut eng = engine(3, 2);
        let d = eng.model.spec.dim;
        let lens = [2usize, 7, 5, 1];
        let seqs: Vec<Vec<f32>> = lens
            .iter()
            .enumerate()
            .map(|(i, &n)| XorShift64::new(100 + i as u64).normals(n * d))
            .collect();
        let tickets: Vec<StreamTicket> =
            seqs.iter().map(|s| eng.submit(s.clone())).collect();
        assert_eq!(eng.queued(), 4);
        let mut m = Metrics::default();
        let steps = eng.run_to_completion(&mut m);
        assert!(steps >= 3, "7-token session at chunk 3 needs ≥3 steps");
        assert!(eng.idle());
        for (t, s) in tickets.iter().zip(&seqs) {
            let out = eng.poll(t).expect("completed");
            assert_eq!(out.tokens, s.len() / d);
            assert_eq!(
                out.logits,
                eng.model.forward_full(s),
                "fused interleaved stepping diverged from solo full-prefix"
            );
        }
        // occupancy gauges populated, live cap respected
        assert_eq!(m.live_sessions.len(), steps);
        assert!(m.live_sessions.iter().all(|&l| l <= 2.0));
        assert!(m.batch_occupancy.iter().any(|&o| o == 1.0));
        assert_eq!(m.requests, 4);
    }

    #[test]
    fn status_tracks_the_request_lifecycle() {
        let mut eng = engine(2, 1);
        let d = eng.model.spec.dim;
        let ta = eng.submit(XorShift64::new(1).normals(4 * d));
        let tb = eng.submit(XorShift64::new(2).normals(2 * d));
        assert_eq!(eng.status(&ta), StreamStatus::Queued);
        let mut m = Metrics::default();
        eng.step(&mut m); // admits only A (max_live 1)
        assert_eq!(eng.status(&ta), StreamStatus::Streaming { fed: 2, total: 4 });
        assert_eq!(eng.status(&tb), StreamStatus::Queued);
        eng.step(&mut m); // A finishes
        assert_eq!(eng.status(&ta), StreamStatus::Done);
        eng.run_to_completion(&mut m);
        assert_eq!(eng.status(&tb), StreamStatus::Done);
        let out = eng.poll(&ta).unwrap();
        assert_eq!(out.steps, 2);
        assert!(out.latency_ms() >= 0.0);
        assert_eq!(eng.status(&ta), StreamStatus::Unknown, "poll consumes");
    }

    #[test]
    fn continuous_admission_refills_free_slots() {
        let mut eng = engine(4, 2);
        let d = eng.model.spec.dim;
        // A is long, B short: when B retires, C must join A's batch.
        let ta = eng.submit(XorShift64::new(3).normals(12 * d));
        let _tb = eng.submit(XorShift64::new(4).normals(4 * d));
        let tc = eng.submit(XorShift64::new(5).normals(4 * d));
        let mut m = Metrics::default();
        let s1 = eng.step(&mut m);
        assert_eq!((s1.live, s1.finished), (2, 1)); // B done
        let s2 = eng.step(&mut m);
        assert_eq!(s2.live, 2, "C admitted into the slot B freed");
        assert_eq!(eng.status(&tc), StreamStatus::Done);
        eng.run_to_completion(&mut m);
        assert_eq!(eng.status(&ta), StreamStatus::Done);
    }

    #[test]
    #[should_panic(expected = "multiple of dim")]
    fn submit_rejects_ragged_buffers() {
        let mut eng = engine(2, 2);
        eng.submit(vec![0.0; 5]);
    }
}
