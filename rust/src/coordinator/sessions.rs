//! Cross-request continuous batching of streaming sessions (sglang-style
//! scheduler, shrunk to this repo's shape), now **phase-disaggregated**:
//! prefill (catching a newly arrived prompt's backlog up to steady state)
//! and decode (advancing warmed live streams) run as separate fused
//! dispatches with separate queues, mirroring the prefill/decode
//! disaggregation in the sglang scheduler.
//!
//! - **Decode phase** (priority): every *warmed* live session contributes
//!   its next `chunk` tokens to ONE fused [`StreamModel::extend_batch`] —
//!   a single MatMul/MatShift dispatch per linear per layer shared by all
//!   live requests. Because no prompt backlog rides in this dispatch, its
//!   cost — and therefore every live stream's per-token latency — is
//!   bounded by `max_live · chunk` no matter what just arrived.
//! - **Prefill phase**: newly submitted sessions wait in the
//!   [`PrefillQueue`] and catch up their backlog in *budgeted* heterogeneous
//!   chunks (up to `prefill_budget` tokens per step across the whole
//!   queue, FIFO). A session graduates to the live set once its remaining
//!   backlog fits in one decode chunk — it enters the decode batch warm,
//!   and it can keep warming even while every live slot is taken.
//!
//! [`SchedulerMode::SinglePhase`] keeps the legacy fused loop (admission
//! straight into the shared step) as the measured baseline; both modes are
//! bit-exact against solo full-prefix inference under any budget and any
//! arrival interleaving, because every per-token operation in
//! `infer::session` is row-independent.
//!
//! The engine is deliberately synchronous and deterministic: callers own
//! the step loop (a serving thread, a bench, or a test driving it to
//! completion).

use std::collections::{HashMap, VecDeque};
use std::time::Instant;

use crate::coordinator::metrics::Metrics;
use crate::infer::session::{SessionState, StreamModel};
use crate::obs::trace::{self as otrace, TraceCtx};

/// Handle to a submitted streaming request.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct StreamTicket {
    pub id: usize,
}

/// Where a streaming request currently is.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum StreamStatus {
    /// waiting in the prefill queue, nothing fed yet
    Queued,
    /// tokens flowing — prefilling in the queue or live in the decode set:
    /// `fed` of `total` tokens streamed so far
    Streaming { fed: usize, total: usize },
    /// finished — result waiting in [`SessionEngine::poll`]
    Done,
    /// unknown ticket (never submitted, or already polled)
    Unknown,
}

/// How [`SessionEngine::step`] schedules admission and stepping.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SchedulerMode {
    /// legacy baseline: arrivals are admitted straight into the one fused
    /// step that also advances live streams
    SinglePhase,
    /// prefill/decode disaggregation: decode dispatches first and alone;
    /// arrivals catch up in a separate budgeted prefill dispatch
    /// (`prefill_budget` tokens per step, `usize::MAX` = unbounded)
    Disaggregated { prefill_budget: usize },
}

impl SchedulerMode {
    pub fn name(self) -> &'static str {
        match self {
            SchedulerMode::SinglePhase => "single-phase",
            SchedulerMode::Disaggregated { .. } => "disaggregated",
        }
    }
}

/// Finished request: logits plus latency/stepping diagnostics.
#[derive(Clone, Debug)]
pub struct StreamOutput {
    pub logits: Vec<f32>,
    /// tokens the session streamed end to end
    pub tokens: usize,
    /// engine steps that fed this session ≥ 1 token (prefill or decode)
    pub steps: usize,
    pub arrived: Instant,
    /// when the session's first tokens entered a fused dispatch
    pub first_fed: Instant,
    /// when the fused step that first fed it completed
    pub first_done: Instant,
    pub finished: Instant,
    /// tracing context of the ingress span that submitted this session
    /// ([`TraceCtx::NONE`] when untraced), echoed back so callers can
    /// close out their own request spans
    pub trace: TraceCtx,
}

impl StreamOutput {
    pub fn latency_ms(&self) -> f64 {
        self.finished.duration_since(self.arrived).as_secs_f64() * 1e3
    }

    /// Arrival → first admission into a fused dispatch (queue wait).
    pub fn queue_wait_ms(&self) -> f64 {
        self.first_fed.duration_since(self.arrived).as_secs_f64() * 1e3
    }

    /// Arrival → completion of the step that first fed it
    /// (time-to-first-token).
    pub fn ttft_ms(&self) -> f64 {
        self.first_done.duration_since(self.arrived).as_secs_f64() * 1e3
    }
}

/// Diagnostics from one [`SessionEngine::step`].
#[derive(Clone, Copy, Debug, Default)]
pub struct StepStats {
    /// sessions live in the decode set during the step
    pub live: usize,
    /// total token rows packed into the step's fused dispatches
    pub tokens: usize,
    /// sessions retired by the step
    pub finished: usize,
    pub step_ms: f64,
    /// tokens the decode dispatch advanced (single-phase: the whole fused
    /// step, prompts included — that is exactly the baseline's problem)
    pub decode_tokens: usize,
    /// tokens the budgeted prefill dispatch fed (single-phase: always 0)
    pub prefill_tokens: usize,
    /// queued sessions the prefill dispatch touched this step
    pub prefill_sessions: usize,
    /// sessions graduated from the prefill queue into the live set
    pub admitted: usize,
    pub decode_ms: f64,
    pub prefill_ms: f64,
}

/// One streaming request anywhere in its lifecycle: waiting/prefilling in
/// the [`PrefillQueue`] or live in the decode set. Its `state` is begun at
/// submit, so prefill progress survives the move between phases.
struct Session {
    id: usize,
    state: SessionState,
    tokens: Vec<f32>,
    /// tokens already streamed
    fed: usize,
    steps: usize,
    arrived: Instant,
    first_fed: Option<Instant>,
    first_done: Option<Instant>,
    trace: TraceCtx,
}

impl Session {
    fn total(&self, d: usize) -> usize {
        self.tokens.len() / d
    }

    fn remaining(&self, d: usize) -> usize {
        self.total(d) - self.fed
    }
}

/// FIFO of sessions still catching up their prompt backlog (plus, under
/// admission control, warmed sessions waiting for a free live slot).
type PrefillQueue = VecDeque<Session>;

/// The continuous-batching scheduler over one [`StreamModel`].
pub struct SessionEngine {
    pub model: StreamModel,
    /// tokens each live session contributes per decode step
    chunk: usize,
    /// live-session cap (admission control)
    max_live: usize,
    mode: SchedulerMode,
    queue: PrefillQueue,
    live: Vec<Session>,
    done: HashMap<usize, StreamOutput>,
    next_id: usize,
}

impl SessionEngine {
    /// Legacy single-phase engine (the measured baseline).
    pub fn new(model: StreamModel, chunk: usize, max_live: usize) -> SessionEngine {
        SessionEngine::with_mode(model, chunk, max_live, SchedulerMode::SinglePhase)
    }

    /// Phase-disaggregated engine with a per-step prefill token budget.
    pub fn disaggregated(
        model: StreamModel,
        chunk: usize,
        max_live: usize,
        prefill_budget: usize,
    ) -> SessionEngine {
        SessionEngine::with_mode(
            model,
            chunk,
            max_live,
            SchedulerMode::Disaggregated { prefill_budget },
        )
    }

    pub fn with_mode(
        model: StreamModel,
        chunk: usize,
        max_live: usize,
        mode: SchedulerMode,
    ) -> SessionEngine {
        assert!(chunk > 0, "chunk must be positive");
        assert!(max_live > 0, "max_live must be positive");
        if let SchedulerMode::Disaggregated { prefill_budget } = mode {
            assert!(prefill_budget > 0, "prefill budget must be positive");
        }
        SessionEngine {
            model,
            chunk,
            max_live,
            mode,
            queue: PrefillQueue::new(),
            live: Vec::new(),
            done: HashMap::new(),
            next_id: 0,
        }
    }

    pub fn mode(&self) -> SchedulerMode {
        self.mode
    }

    /// Enqueue one request: a flattened (n × dim) token sequence.
    pub fn submit(&mut self, tokens: Vec<f32>) -> StreamTicket {
        self.submit_traced(tokens, TraceCtx::NONE)
    }

    /// [`SessionEngine::submit`] with an explicit tracing context: the
    /// decode/prefill phase spans that later feed this session parent on
    /// `ctx` (the ingress span), connecting the request's span tree across
    /// the queue.
    pub fn submit_traced(&mut self, tokens: Vec<f32>, ctx: TraceCtx) -> StreamTicket {
        let d = self.model.spec.dim;
        assert!(
            !tokens.is_empty() && tokens.len() % d == 0,
            "request must be a non-empty multiple of dim={d} floats"
        );
        let id = self.next_id;
        self.next_id += 1;
        self.queue.push_back(Session {
            id,
            state: self.model.begin(),
            tokens,
            fed: 0,
            steps: 0,
            arrived: Instant::now(),
            first_fed: None,
            first_done: None,
            trace: ctx,
        });
        StreamTicket { id }
    }

    /// Sessions in the prefill queue (waiting or mid-catch-up).
    pub fn queued(&self) -> usize {
        self.queue.len()
    }

    /// Queued sessions that have already streamed some tokens (prefilling).
    pub fn prefilling(&self) -> usize {
        self.queue.iter().filter(|s| s.fed > 0).count()
    }

    pub fn live_count(&self) -> usize {
        self.live.len()
    }

    /// True when no request is queued, live, or waiting to be polled... the
    /// engine has nothing left to do.
    pub fn idle(&self) -> bool {
        self.queue.is_empty() && self.live.is_empty()
    }

    pub fn status(&self, ticket: &StreamTicket) -> StreamStatus {
        let d = self.model.spec.dim;
        if let Some(s) = self.queue.iter().find(|s| s.id == ticket.id) {
            return if s.fed == 0 {
                StreamStatus::Queued
            } else {
                StreamStatus::Streaming {
                    fed: s.fed,
                    total: s.total(d),
                }
            };
        }
        if let Some(s) = self.live.iter().find(|s| s.id == ticket.id) {
            return StreamStatus::Streaming {
                fed: s.fed,
                total: s.total(d),
            };
        }
        if self.done.contains_key(&ticket.id) {
            return StreamStatus::Done;
        }
        StreamStatus::Unknown
    }

    /// One scheduler step. Single-phase: admit into free slots, then one
    /// fused step over everything live. Disaggregated: graduate warmed
    /// sessions, decode dispatch (live only), then the budgeted prefill
    /// dispatch over the queue.
    pub fn step(&mut self, metrics: &mut Metrics) -> StepStats {
        // Parent the step span on the first traced session anywhere in the
        // engine (falling back to the ambient context), so one HTTP request
        // connects through to the fused dispatches that fed it.
        let parent = self
            .live
            .iter()
            .chain(self.queue.iter())
            .map(|s| s.trace)
            .find(|t| t.is_active())
            .unwrap_or_else(otrace::current);
        let mut span = otrace::span("stream_step", parent);
        let _cur = otrace::set_current(span.ctx());
        let stats = match self.mode {
            SchedulerMode::SinglePhase => self.step_single_phase(metrics),
            SchedulerMode::Disaggregated { prefill_budget } => {
                self.step_disaggregated(prefill_budget, metrics)
            }
        };
        if otrace::enabled() {
            span.arg("live", stats.live.to_string());
            span.arg("tokens", stats.tokens.to_string());
            span.arg("mode", self.mode.name().to_string());
        }
        stats
    }

    fn step_single_phase(&mut self, metrics: &mut Metrics) -> StepStats {
        // --- admission: arrivals go straight into the shared fused step ---
        let mut admitted = 0usize;
        while self.live.len() < self.max_live {
            match self.queue.pop_front() {
                Some(s) => {
                    self.live.push(s);
                    admitted += 1;
                }
                None => break,
            }
        }
        if self.live.is_empty() {
            return StepStats::default();
        }
        let waiting = self.queue.len();

        // --- one fused multi-session step (prompts and streams mixed) -----
        let t0 = Instant::now();
        let chunk = self.chunk;
        let takes = vec![chunk; self.live.len()];
        let trace = {
            let mut sp = otrace::span("stream_decode", otrace::current());
            if otrace::enabled() {
                sp.arg("sessions", self.live.len().to_string());
            }
            let _cur = otrace::set_current(sp.ctx());
            fused_feed(&self.model, &mut self.live, &takes)
        };
        let live = self.live.len();
        let finished = self.retire(metrics);
        let step_ms = t0.elapsed().as_secs_f64() * 1e3;

        self.record_step(metrics, live, waiting, trace.total_tokens, 0, step_ms);
        metrics.record("stream_decode", step_ms);
        metrics.requests += finished;
        StepStats {
            live,
            tokens: trace.total_tokens,
            finished,
            step_ms,
            decode_tokens: trace.total_tokens,
            prefill_tokens: 0,
            prefill_sessions: 0,
            admitted,
            decode_ms: step_ms,
            prefill_ms: 0.0,
        }
    }

    fn step_disaggregated(&mut self, prefill_budget: usize, metrics: &mut Metrics) -> StepStats {
        let d = self.model.spec.dim;
        let chunk = self.chunk;

        // --- graduation: warmed sessions take free live slots (FIFO) ------
        let mut admitted = 0usize;
        let mut i = 0usize;
        while self.live.len() < self.max_live && i < self.queue.len() {
            if self.queue[i].remaining(d) <= chunk {
                let s = self.queue.remove(i).expect("index checked");
                self.live.push(s);
                admitted += 1;
            } else {
                i += 1;
            }
        }
        if self.live.is_empty() && self.queue.is_empty() {
            return StepStats::default();
        }
        let waiting = self.queue.len();
        let t0 = Instant::now();

        // --- decode phase: live streams only, one fused dispatch ----------
        // No prompt backlog rides here, so decode cost is bounded by
        // max_live · chunk no matter what just arrived.
        let (decode_tokens, decode_ms, finished) = if self.live.is_empty() {
            (0, 0.0, 0)
        } else {
            let td = Instant::now();
            let takes = vec![chunk; self.live.len()];
            let trace = {
                let mut sp = otrace::span("stream_decode", otrace::current());
                if otrace::enabled() {
                    sp.arg("sessions", self.live.len().to_string());
                }
                let _cur = otrace::set_current(sp.ctx());
                fused_feed(&self.model, &mut self.live, &takes)
            };
            let finished = self.retire(metrics);
            let decode_ms = td.elapsed().as_secs_f64() * 1e3;
            metrics.record("stream_decode", decode_ms);
            (trace.total_tokens, decode_ms, finished)
        };
        let live = self.live.len() + finished;

        // --- prefill phase: budgeted catch-up over the queue, FIFO --------
        // Each session may feed up to its backlog-minus-one-chunk (the last
        // chunk is left for the decode batch it will graduate into), and
        // the whole dispatch never exceeds the budget.
        let mut budget = prefill_budget;
        let mut takes = vec![0usize; self.queue.len()];
        for (s, take) in self.queue.iter().zip(takes.iter_mut()) {
            if budget == 0 {
                break;
            }
            let r = s.remaining(d);
            if r <= chunk {
                continue; // warmed: waiting for a live slot
            }
            *take = (r - chunk).min(budget);
            budget -= *take;
        }
        let prefill_sessions = takes.iter().filter(|&&t| t > 0).count();
        let (prefill_tokens, prefill_ms) = if prefill_sessions == 0 {
            (0, 0.0)
        } else {
            let tp = Instant::now();
            let trace = {
                let mut sp = otrace::span("stream_prefill", otrace::current());
                if otrace::enabled() {
                    sp.arg("sessions", prefill_sessions.to_string());
                }
                let _cur = otrace::set_current(sp.ctx());
                fused_feed(&self.model, self.queue.make_contiguous(), &takes)
            };
            let prefill_ms = tp.elapsed().as_secs_f64() * 1e3;
            metrics.record("stream_prefill", prefill_ms);
            (trace.total_tokens, prefill_ms)
        };

        let step_ms = t0.elapsed().as_secs_f64() * 1e3;
        let tokens = decode_tokens + prefill_tokens;
        self.record_step(metrics, live, waiting, decode_tokens, prefill_tokens, step_ms);
        metrics.requests += finished;
        StepStats {
            live,
            tokens,
            finished,
            step_ms,
            decode_tokens,
            prefill_tokens,
            prefill_sessions,
            admitted,
            decode_ms,
            prefill_ms,
        }
    }

    /// Shared per-step gauge recording (both scheduler modes).
    fn record_step(
        &self,
        metrics: &mut Metrics,
        live: usize,
        waiting: usize,
        decode_tokens: usize,
        prefill_tokens: usize,
        step_ms: f64,
    ) {
        metrics.record("stream_step", step_ms);
        metrics.record_step_occupancy(live, self.max_live, decode_tokens + prefill_tokens);
        metrics.live_sessions.record(live as f64);
        metrics.decode_tokens.record(decode_tokens as f64);
        metrics.prefill_tokens.record(prefill_tokens as f64);
        metrics.prefill_queue.record(waiting as f64);
        metrics.batches += 1;
    }

    /// Move finished live sessions into the done map. Returns the count.
    fn retire(&mut self, metrics: &mut Metrics) -> usize {
        let d = self.model.spec.dim;
        let mut finished = 0usize;
        let model = &self.model;
        let done = &mut self.done;
        let mut retired: Vec<usize> = Vec::new();
        self.live.retain(|s| {
            if s.fed * d < s.tokens.len() {
                return true;
            }
            finished += 1;
            retired.push(s.id);
            done.insert(
                s.id,
                StreamOutput {
                    logits: model.finish(&s.state),
                    tokens: s.fed,
                    steps: s.steps,
                    arrived: s.arrived,
                    first_fed: s.first_fed.expect("finished session was fed"),
                    first_done: s.first_done.expect("finished session was fed"),
                    finished: Instant::now(),
                    trace: s.trace,
                },
            );
            false
        });
        for id in retired {
            metrics.push_request_id(id);
        }
        finished
    }

    /// Remove and return a finished request's output, if ready.
    pub fn poll(&mut self, ticket: &StreamTicket) -> Option<StreamOutput> {
        self.done.remove(&ticket.id)
    }

    /// Step until every submitted request is done. Returns steps taken.
    pub fn run_to_completion(&mut self, metrics: &mut Metrics) -> usize {
        let mut steps = 0usize;
        while !self.idle() {
            self.step(metrics);
            steps += 1;
        }
        steps
    }
}

/// Feed `takes[i]` tokens (clamped to the session's remaining backlog;
/// 0 = skip) from each session through ONE fused
/// [`StreamModel::extend_batch`] with heterogeneous per-session chunk
/// lengths, stamping first-fed/first-done instants.
fn fused_feed(
    model: &StreamModel,
    sessions: &mut [Session],
    takes: &[usize],
) -> crate::infer::session::StepTrace {
    let d = model.spec.dim;
    let chunks: Vec<Vec<f32>> = sessions
        .iter()
        .zip(takes)
        .map(|(s, &take)| {
            let hi = (s.fed + take).min(s.total(d));
            s.tokens[s.fed * d..hi * d].to_vec()
        })
        .collect();
    let refs: Vec<&[f32]> = chunks.iter().map(|c| c.as_slice()).collect();
    let fed_at = Instant::now();
    let mut states: Vec<&mut SessionState> =
        sessions.iter_mut().map(|s| &mut s.state).collect();
    let trace = model.extend_batch(&mut states, &refs);
    let done_at = Instant::now();
    for (s, c) in sessions.iter_mut().zip(&chunks) {
        let m = c.len() / d;
        if m == 0 {
            continue;
        }
        if s.first_fed.is_none() {
            s.first_fed = Some(fed_at);
            s.first_done = Some(done_at);
        }
        s.fed += m;
        s.steps += 1;
    }
    trace
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::infer::session::{StreamAttn, StreamModel};
    use crate::model::ops::Lin;
    use crate::util::rng::XorShift64;

    fn engine(chunk: usize, max_live: usize) -> SessionEngine {
        SessionEngine::new(StreamModel::tiny(StreamAttn::LinearAdd, Lin::Mult), chunk, max_live)
    }

    fn phased(chunk: usize, max_live: usize, budget: usize) -> SessionEngine {
        SessionEngine::disaggregated(
            StreamModel::tiny(StreamAttn::LinearAdd, Lin::Mult),
            chunk,
            max_live,
            budget,
        )
    }

    #[test]
    fn mixed_length_requests_complete_and_match_solo() {
        let mut eng = engine(3, 2);
        let d = eng.model.spec.dim;
        let lens = [2usize, 7, 5, 1];
        let seqs: Vec<Vec<f32>> = lens
            .iter()
            .enumerate()
            .map(|(i, &n)| XorShift64::new(100 + i as u64).normals(n * d))
            .collect();
        let tickets: Vec<StreamTicket> =
            seqs.iter().map(|s| eng.submit(s.clone())).collect();
        assert_eq!(eng.queued(), 4);
        let mut m = Metrics::default();
        let steps = eng.run_to_completion(&mut m);
        assert!(steps >= 3, "7-token session at chunk 3 needs ≥3 steps");
        assert!(eng.idle());
        for (t, s) in tickets.iter().zip(&seqs) {
            let out = eng.poll(t).expect("completed");
            assert_eq!(out.tokens, s.len() / d);
            assert_eq!(
                out.logits,
                eng.model.forward_full(s),
                "fused interleaved stepping diverged from solo full-prefix"
            );
        }
        // occupancy gauges populated, live cap respected
        assert_eq!(m.live_sessions.count() as usize, steps);
        assert!(m.live_sessions.max() <= 2.0);
        assert_eq!(m.batch_occupancy.max(), 1.0, "live cap was saturated");
        assert_eq!(m.requests, 4);
        // single-phase: every token counts as decode, prefill gauge stays 0
        assert_eq!(m.prefill_tokens.max(), 0.0);
        assert_eq!(m.decode_tokens.sum(), lens.iter().sum::<usize>() as f64);
    }

    #[test]
    fn status_tracks_the_request_lifecycle() {
        let mut eng = engine(2, 1);
        let d = eng.model.spec.dim;
        let ta = eng.submit(XorShift64::new(1).normals(4 * d));
        let tb = eng.submit(XorShift64::new(2).normals(2 * d));
        assert_eq!(eng.status(&ta), StreamStatus::Queued);
        let mut m = Metrics::default();
        eng.step(&mut m); // admits only A (max_live 1)
        assert_eq!(eng.status(&ta), StreamStatus::Streaming { fed: 2, total: 4 });
        assert_eq!(eng.status(&tb), StreamStatus::Queued);
        eng.step(&mut m); // A finishes
        assert_eq!(eng.status(&ta), StreamStatus::Done);
        eng.run_to_completion(&mut m);
        assert_eq!(eng.status(&tb), StreamStatus::Done);
        let out = eng.poll(&ta).unwrap();
        assert_eq!(out.steps, 2);
        assert!(out.latency_ms() >= 0.0);
        assert!(out.queue_wait_ms() >= 0.0);
        assert!(out.ttft_ms() >= out.queue_wait_ms());
        assert!(out.latency_ms() >= out.ttft_ms());
        assert_eq!(eng.status(&ta), StreamStatus::Unknown, "poll consumes");
    }

    #[test]
    fn continuous_admission_refills_free_slots() {
        let mut eng = engine(4, 2);
        let d = eng.model.spec.dim;
        // A is long, B short: when B retires, C must join A's batch.
        let ta = eng.submit(XorShift64::new(3).normals(12 * d));
        let _tb = eng.submit(XorShift64::new(4).normals(4 * d));
        let tc = eng.submit(XorShift64::new(5).normals(4 * d));
        let mut m = Metrics::default();
        let s1 = eng.step(&mut m);
        assert_eq!((s1.live, s1.finished), (2, 1)); // B done
        let s2 = eng.step(&mut m);
        assert_eq!(s2.live, 2, "C admitted into the slot B freed");
        assert_eq!(eng.status(&tc), StreamStatus::Done);
        eng.run_to_completion(&mut m);
        assert_eq!(eng.status(&ta), StreamStatus::Done);
    }

    #[test]
    fn disaggregated_engine_is_bit_exact_and_budget_bounded() {
        let budget = 5usize;
        let mut eng = phased(3, 2, budget);
        let d = eng.model.spec.dim;
        let lens = [2usize, 17, 5, 9, 1];
        let seqs: Vec<Vec<f32>> = lens
            .iter()
            .enumerate()
            .map(|(i, &n)| XorShift64::new(300 + i as u64).normals(n * d))
            .collect();
        let tickets: Vec<StreamTicket> =
            seqs.iter().map(|s| eng.submit(s.clone())).collect();
        let mut m = Metrics::default();
        let mut steps = 0usize;
        while !eng.idle() {
            let st = eng.step(&mut m);
            steps += 1;
            assert!(
                st.prefill_tokens <= budget,
                "prefill dispatch exceeded budget: {} > {budget}",
                st.prefill_tokens
            );
            assert!(
                st.decode_tokens <= 2 * 3,
                "decode dispatch exceeded max_live·chunk: {}",
                st.decode_tokens
            );
            assert!(st.live <= 2);
        }
        assert!(steps > 3);
        for (t, s) in tickets.iter().zip(&seqs) {
            let out = eng.poll(t).expect("completed");
            assert_eq!(
                out.logits,
                eng.model.forward_full(s),
                "disaggregated stepping diverged from solo full-prefix"
            );
        }
        assert_eq!(m.requests, lens.len());
        // both phases actually ran: the 17- and 9-token prompts must have
        // prefilled (backlog > chunk), the short ones decoded straight away
        assert!(m.prefill_tokens.sum() > 0.0);
        assert!(m.decode_tokens.sum() > 0.0);
        assert_eq!(
            m.prefill_tokens.sum() + m.decode_tokens.sum(),
            lens.iter().sum::<usize>() as f64
        );
    }

    #[test]
    fn long_prompt_prefills_while_live_slots_are_full() {
        // Live set saturated by two endlessly... well, long-enough streams;
        // a long arrival must still make prefill progress in the queue.
        let mut eng = phased(2, 2, 4);
        let d = eng.model.spec.dim;
        let _a = eng.submit(XorShift64::new(7).normals(2 * d));
        let _b = eng.submit(XorShift64::new(8).normals(2 * d));
        let mut m = Metrics::default();
        eng.step(&mut m); // both graduate (remaining ≤ chunk) and finish next
        let tl = eng.submit(XorShift64::new(9).normals(20 * d));
        let _c = eng.submit(XorShift64::new(10).normals(2 * d));
        let _d2 = eng.submit(XorShift64::new(11).normals(2 * d));
        let st = eng.step(&mut m);
        // the two short arrivals grabbed the freed slots; the long prompt
        // prefilled under budget in the same step
        assert_eq!(eng.status(&tl), StreamStatus::Streaming { fed: 4, total: 20 });
        assert!(st.prefill_tokens == 4 && st.prefill_sessions == 1);
        assert_eq!(eng.prefilling(), 1);
        eng.run_to_completion(&mut m);
        let out = eng.poll(&tl).unwrap();
        assert_eq!(out.tokens, 20);
    }

    #[test]
    fn single_phase_and_disaggregated_agree_bit_exactly() {
        use crate::infer::session::SessionSpec;
        use crate::kernels::planner::Planner;
        use crate::kernels::registry::KernelRegistry;
        use std::sync::Arc;
        // One shared planner: every engine's model resolves to the same
        // kernel backends, so equality is a pure scheduling statement.
        let planner = Arc::new(Planner::new(Arc::new(KernelRegistry::with_defaults())));
        let spec = SessionSpec::tiny(StreamAttn::LinearAdd, Lin::Mult);
        let d = spec.dim;
        let lens = [6usize, 14, 3, 8];
        let seqs: Vec<Vec<f32>> = lens
            .iter()
            .enumerate()
            .map(|(i, &n)| XorShift64::new(500 + i as u64).normals(n * d))
            .collect();
        let mut run = |mode: SchedulerMode| -> Vec<Vec<f32>> {
            let model = StreamModel::new(spec.clone(), Arc::clone(&planner));
            let mut eng = SessionEngine::with_mode(model, 4, 2, mode);
            let tickets: Vec<StreamTicket> =
                seqs.iter().map(|s| eng.submit(s.clone())).collect();
            let mut m = Metrics::default();
            eng.run_to_completion(&mut m);
            tickets
                .iter()
                .map(|t| eng.poll(t).unwrap().logits)
                .collect()
        };
        let a = run(SchedulerMode::SinglePhase);
        let b = run(SchedulerMode::Disaggregated { prefill_budget: 1 });
        let c = run(SchedulerMode::Disaggregated {
            prefill_budget: usize::MAX,
        });
        assert_eq!(a, b, "1-token budget diverged from the legacy path");
        assert_eq!(a, c, "unbounded budget diverged from the legacy path");
    }

    #[test]
    #[should_panic(expected = "multiple of dim")]
    fn submit_rejects_ragged_buffers() {
        let mut eng = engine(2, 2);
        eng.submit(vec![0.0; 5]);
    }

    #[test]
    #[should_panic(expected = "budget must be positive")]
    fn zero_prefill_budget_is_rejected() {
        phased(2, 2, 0);
    }
}
