//! The serving loops behind `shiftaddvit serve`:
//!
//! - [`serve_backend`] — image classification on the request-level
//!   [`InferenceBackend`] contract: a synthetic client thread issues image
//!   requests (open-loop Poisson-ish or closed-loop), the coordinator
//!   `submit`s them, `step`s the backend (each step fuses the queued
//!   requests into one engine batch), and `poll`s results for
//!   latency/throughput/accuracy/occupancy reporting;
//! - [`serve_stream`] — token-streaming sessions on
//!   [`SessionEngine`]: N sessions of varying lengths continuously batched,
//!   each step packing one chunk per live session into fused kernel
//!   dispatches.
//!
//! [`serve_auto`] resolves the configured backend through
//! [`create_backend`] (the single construction path — planner lookup
//! tables and `--backend` apply uniformly) and dispatches on
//! `cfg.workload`.

use std::sync::mpsc;
use std::thread;
use std::time::{Duration, Instant};

use anyhow::Result;

use crate::coordinator::backend::{create_backend, create_planner, InferenceBackend, Ticket};
use crate::coordinator::batcher::{Batcher, Request};
use crate::coordinator::config::{BackendKind, ServerConfig, Workload};
use crate::coordinator::metrics::Metrics;
use crate::coordinator::scheduler::MoePipeline;
use crate::coordinator::sessions::SessionEngine;
use crate::data::synth_images;
use crate::infer::session::{SessionSpec, StreamAttn, StreamModel};
use crate::kernels::planner::{table_json, Choice};
use crate::model::ops::Lin;
use crate::runtime::artifact::Manifest;
use crate::util::rng::XorShift64;
use crate::util::stats::Summary;

/// Outcome of a classification serving run.
pub struct ServeReport {
    pub metrics: Metrics,
    pub latency: Summary,
    pub modularized_latency: Summary,
    pub throughput_rps: f64,
    pub accuracy: f64,
    /// first few dispatch masks for visualisation
    pub sample_masks: Vec<Vec<bool>>,
    /// per-step batch occupancy (served / max_batch)
    pub occupancy: Option<Summary>,
    /// per-step fused token rows
    pub step_tokens: Option<Summary>,
}

/// Run the serving benchmark against the XLA artifact pipeline (the
/// pre-refactor entry point, kept for artifact-driven callers).
pub fn serve(manifest: &Manifest, cfg: &ServerConfig) -> Result<ServeReport> {
    let pipeline = MoePipeline::new(manifest, cfg.dispatch)?;
    serve_backend(&pipeline, cfg)
}

/// Resolve `cfg.backend` ([`create_backend`]) and serve `cfg.workload` on
/// it — the engine-agnostic entry point behind `shiftaddvit serve`.
/// (The stream workload is native-only; it reports through
/// [`StreamReport`], so callers wanting it use [`serve_stream`] directly.)
pub fn serve_auto(cfg: &ServerConfig) -> Result<ServeReport> {
    let backend = create_backend(cfg)?;
    let report = serve_backend(backend.as_ref(), cfg)?;
    save_planner_table(cfg, &backend.planner_choices())?;
    Ok(report)
}

/// Dump planner decisions to `cfg.planner_table_save` (no-op when unset or
/// when the backend made no decisions, e.g. xla).
fn save_planner_table(cfg: &ServerConfig, choices: &[Choice]) -> Result<()> {
    if let Some(path) = &cfg.planner_table_save {
        if choices.is_empty() {
            println!("planner table not saved: backend logged no decisions");
        } else {
            std::fs::write(path, table_json(choices).to_string())?;
            println!("planner: saved {} choices to {path}", choices.len());
        }
    }
    Ok(())
}

fn argmax(xs: &[f32]) -> usize {
    let mut best = 0usize;
    for (i, &v) in xs.iter().enumerate() {
        if v > xs[best] {
            best = i;
        }
    }
    best
}

/// Run the classification serving benchmark described by `cfg` on any
/// engine, through the request-level submit/step/poll contract.
pub fn serve_backend(backend: &dyn InferenceBackend, cfg: &ServerConfig) -> Result<ServeReport> {
    backend.warmup()?;

    let (tx, rx) = mpsc::channel::<Request>();
    let n_req = cfg.requests;
    let arrival_ms = cfg.arrival_ms;

    // Client thread: deterministic synthetic images, optional pacing.
    let client = thread::spawn(move || {
        let mut rng = XorShift64::new(0xC11E17);
        for id in 0..n_req {
            let sample = synth_images::gen_image(5_000_000 + id as u32);
            let req = Request {
                id,
                pixels: sample.pixels,
                label: Some(sample.label),
                arrived: Instant::now(),
            };
            if tx.send(req).is_err() {
                return;
            }
            if arrival_ms > 0.0 {
                // exponential-ish jitter around the mean
                let jitter = 0.5 + rng.uniform() as f64;
                thread::sleep(Duration::from_secs_f64(arrival_ms * jitter / 1e3));
            }
        }
    });

    let batcher = Batcher::new(cfg.max_batch, cfg.batch_deadline_ms);
    let mut metrics = Metrics::default();
    // Plan-time gauge: which kernel backend each (primitive, shape) of the
    // engine resolved to at construction/warmup (xla plans nothing).
    metrics.record_plan(&backend.planner_choices());
    let mut latencies = Vec::new();
    let mut modularized = Vec::new();
    let mut correct = 0usize;
    let mut total = 0usize;
    let mut sample_masks = Vec::new();
    let t0 = Instant::now();

    while let Some(batch) = batcher.next_batch(&rx) {
        let mut tickets: Vec<Ticket> = batch
            .requests
            .into_iter()
            .map(|r| backend.submit(r))
            .collect();
        while backend.queued() > 0 {
            let rep = backend.step(cfg.max_batch, &mut metrics)?;
            if rep.served == 0 {
                anyhow::bail!("backend step made no progress");
            }
            modularized.push(rep.modularized_ms);
            // Continuous intake: requests that arrived while the step ran
            // join the next fused batch instead of waiting out a fresh
            // batching window.
            for r in batcher.drain_ready(&rx).requests {
                tickets.push(backend.submit(r));
            }
        }
        for t in &tickets {
            let out = backend
                .poll(t)
                .expect("stepped to completion, result must be ready");
            // per-request latency uses the serving step's completion stamp,
            // not the end of the whole drain loop
            latencies.push(out.latency_ms());
            if let Some(label) = out.label {
                total += 1;
                if argmax(&out.logits) == label {
                    correct += 1;
                }
            }
            if sample_masks.len() < 8 && !out.dispatch_mask_blk0.is_empty() {
                sample_masks.push(out.dispatch_mask_blk0);
            }
        }
    }
    let wall_s = t0.elapsed().as_secs_f64();
    client.join().expect("client thread");
    // Refresh the gauge: batched geometries may have planned lazily during
    // the run (record_plan rebuilds, so this never double-counts).
    metrics.record_plan(&backend.planner_choices());

    Ok(ServeReport {
        latency: Summary::from(&latencies),
        modularized_latency: Summary::from(&modularized),
        throughput_rps: metrics.requests as f64 / wall_s,
        accuracy: if total > 0 {
            correct as f64 / total as f64
        } else {
            0.0
        },
        occupancy: metrics.occupancy_summary(),
        step_tokens: metrics.step_tokens_summary(),
        metrics,
        sample_masks,
    })
}

impl ServeReport {
    pub fn print(&self) {
        println!("== serving report ==");
        println!(
            "requests {}  throughput {:.1} img/s  accuracy {:.2}%",
            self.metrics.requests,
            self.throughput_rps,
            100.0 * self.accuracy
        );
        println!(
            "request latency  mean {:.2} ms  p50 {:.2}  p99 {:.2}",
            self.latency.mean, self.latency.p50, self.latency.p99
        );
        println!(
            "batch modularized latency (ideal parallelism)  mean {:.2} ms",
            self.modularized_latency.mean
        );
        self.metrics.print();
    }
}

// ---------------------------------------------------------------------------
// Token-streaming serving (sessions through the continuous batcher)
// ---------------------------------------------------------------------------

/// Outcome of a streaming serving run.
pub struct StreamReport {
    pub sessions: usize,
    pub total_tokens: usize,
    pub steps: usize,
    pub wall_ms: f64,
    pub tokens_per_sec: f64,
    /// per-session end-to-end latency (submit → logits)
    pub latency: Summary,
    pub occupancy: Option<Summary>,
    pub step_tokens: Option<Summary>,
    pub metrics: Metrics,
}

impl StreamReport {
    pub fn print(&self) {
        println!("== streaming report ==");
        println!(
            "sessions {}  tokens {}  steps {}  wall {:.1} ms  throughput {:.0} tok/s",
            self.sessions, self.total_tokens, self.steps, self.wall_ms, self.tokens_per_sec
        );
        println!(
            "session latency  mean {:.2} ms  p50 {:.2}  p99 {:.2}",
            self.latency.mean, self.latency.p50, self.latency.p99
        );
        self.metrics.print();
    }
}

/// Deterministic synthetic token sequence lengths for the stream workload:
/// spread over [mean/2, mean/2 + mean) so sessions join and leave the
/// continuous batch at different times.
pub fn stream_workload_lens(sessions: usize, mean_tokens: usize) -> Vec<usize> {
    let mean = mean_tokens.max(2);
    (0..sessions)
        .map(|i| mean / 2 + (i * 7 + 3) % mean)
        .collect()
}

/// Deterministic arrival-offset schedule (ms, non-decreasing, first at 0)
/// for the open-loop streaming client: session `i` arrives after `i`
/// jittered gaps of `mean_ms · (0.5 + u)`, `u ∈ [0, 1)` drawn from `seed` —
/// the same exponential-ish pacing the classification client thread uses,
/// but precomputed so runs are reproducible and the schedule is testable.
/// `mean_ms = 0` degenerates to the closed-loop schedule (all zeros).
pub fn stream_arrival_schedule(sessions: usize, mean_ms: f64, seed: u64) -> Vec<f64> {
    let mut rng = XorShift64::new(seed);
    let mut at = 0.0f64;
    (0..sessions)
        .map(|_| {
            let now = at;
            at += mean_ms * (0.5 + rng.uniform() as f64);
            now
        })
        .collect()
}

/// Seed of the open-loop arrival schedule (fixed: serving runs are
/// reproducible; vary `cfg.arrival_ms` to change the traffic, not the draw).
const STREAM_ARRIVAL_SEED: u64 = 0x0FE2_107;

/// Serve `cfg.requests` token-streaming sessions on the native streaming
/// engine (the paper's deployed mixture: Hamming LinearAdd attention +
/// shift linears), continuously batched `cfg.max_live` at a time in
/// `cfg.stream_chunk`-token steps.
///
/// With `cfg.arrival_ms > 0` the client is **open-loop**: sessions are
/// submitted on the deterministic [`stream_arrival_schedule`] while the
/// engine keeps stepping whatever is live, so admission control
/// (`max_live`) is exercised by staggered arrivals instead of one up-front
/// burst. `arrival_ms = 0` keeps the closed-loop behavior (all sessions
/// submitted before the first step).
pub fn serve_stream(cfg: &ServerConfig) -> Result<StreamReport> {
    if cfg.backend != BackendKind::Native {
        anyhow::bail!(
            "the stream workload runs on the native streaming engine only \
             (got --backend {}); the XLA artifacts have no token-level entry point",
            cfg.backend.name()
        );
    }
    let planner = create_planner(cfg)?;
    let model = StreamModel::new(SessionSpec::tiny(StreamAttn::LinearAdd, Lin::Shift), planner);
    let dim = model.spec.dim;
    let mut engine = SessionEngine::new(model, cfg.stream_chunk.max(1), cfg.max_live.max(1));

    let lens = stream_workload_lens(cfg.requests, cfg.stream_tokens);
    let schedule = stream_arrival_schedule(lens.len(), cfg.arrival_ms, STREAM_ARRIVAL_SEED);
    let total_tokens: usize = lens.iter().sum();
    let mut seqs: Vec<Vec<f32>> = lens
        .iter()
        .enumerate()
        .map(|(i, &n)| XorShift64::new(0x70C0 + i as u64).normals(n * dim))
        .collect();

    let mut metrics = Metrics::default();
    let mut tickets = Vec::with_capacity(lens.len());
    let mut steps = 0usize;
    let mut next = 0usize;
    let t0 = Instant::now();
    while next < seqs.len() || !engine.idle() {
        let now_ms = t0.elapsed().as_secs_f64() * 1e3;
        while next < seqs.len() && schedule[next] <= now_ms {
            tickets.push(engine.submit(std::mem::take(&mut seqs[next])));
            next += 1;
        }
        if engine.idle() {
            // Open-loop gap: nothing live, next arrival is in the future.
            let wait_ms = schedule[next] - now_ms;
            if wait_ms > 0.0 {
                thread::sleep(Duration::from_secs_f64(wait_ms / 1e3));
            }
            continue;
        }
        engine.step(&mut metrics);
        steps += 1;
    }
    let wall_ms = t0.elapsed().as_secs_f64() * 1e3;

    let mut latencies = Vec::with_capacity(tickets.len());
    for t in &tickets {
        let out = engine.poll(t).expect("serve loop finished all sessions");
        latencies.push(out.latency_ms());
    }
    metrics.record_plan(&engine.model.planner.choices());
    save_planner_table(cfg, &engine.model.planner.choices())?;

    Ok(StreamReport {
        sessions: lens.len(),
        total_tokens,
        steps,
        wall_ms,
        tokens_per_sec: total_tokens as f64 / (wall_ms / 1e3).max(1e-12),
        latency: Summary::from(&latencies),
        occupancy: metrics.occupancy_summary(),
        step_tokens: metrics.step_tokens_summary(),
        metrics,
    })
}

/// Dispatch `cfg.workload`: classification through [`serve_auto`], or
/// streaming through [`serve_stream`] (printing its own report). Used by
/// the `serve` subcommand so one flag switches request shapes.
pub fn serve_workload(cfg: &ServerConfig) -> Result<()> {
    match cfg.workload {
        Workload::Classify => {
            let report = serve_auto(cfg)?;
            report.print();
        }
        Workload::Stream => {
            let report = serve_stream(cfg)?;
            report.print();
        }
    }
    Ok(())
}
