//! The serving loops behind `shiftaddvit serve`:
//!
//! - [`serve_backend`] — image classification on the request-level
//!   [`InferenceBackend`] contract: a synthetic client thread issues image
//!   requests (open-loop Poisson-ish or closed-loop), the coordinator
//!   `submit`s them, `step`s the backend (each step fuses the queued
//!   requests into one engine batch), and `poll`s results for
//!   latency/throughput/accuracy/occupancy reporting;
//! - [`serve_stream`] — token-streaming sessions on
//!   [`SessionEngine`]: N sessions of varying lengths continuously batched,
//!   each step packing one chunk per live session into fused kernel
//!   dispatches.
//!
//! [`serve_auto`] resolves the configured backend through
//! [`crate::coordinator::backend::create_backend`] (the single
//! construction path — planner lookup tables, `--bundle`, and `--backend`
//! apply uniformly) and dispatches on `cfg.workload`.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{mpsc, Arc};
use std::thread;
use std::time::{Duration, Instant};

use anyhow::Result;

use crate::coordinator::backend::{
    create_backend_with, create_planner, load_bundle, InferenceBackend, Ticket,
};
use crate::coordinator::batcher::{Batcher, Request};
use crate::coordinator::config::{BackendKind, SchedulerKind, ServerConfig, Workload};
use crate::coordinator::metrics::Metrics;
use crate::coordinator::scheduler::MoePipeline;
use crate::coordinator::sessions::{SchedulerMode, SessionEngine, StreamTicket};
use crate::data::synth_images;
use crate::fleet::policy::WorkerView;
use crate::fleet::router::{Router, WorkerBreakdown};
use crate::infer::session::{SessionSpec, StreamAttn, StreamModel};
use crate::kernels::planner::{table_json, Choice, Planner};
use crate::kernels::registry::KernelRegistry;
use crate::model::ops::Lin;
use crate::obs::trace::TraceCtx;
use crate::runtime::artifact::Manifest;
use crate::util::json::Json;
use crate::util::rng::XorShift64;
use crate::util::stats::Summary;

/// Outcome of a classification serving run.
pub struct ServeReport {
    pub metrics: Metrics,
    pub latency: Summary,
    pub modularized_latency: Summary,
    pub throughput_rps: f64,
    pub accuracy: f64,
    /// first few dispatch masks for visualisation
    pub sample_masks: Vec<Vec<bool>>,
    /// per-step batch occupancy (served / max_batch)
    pub occupancy: Option<Summary>,
    /// per-step fused token rows
    pub step_tokens: Option<Summary>,
    /// per-worker breakdown (fleet runs; empty on the single-engine path)
    pub per_worker: Vec<WorkerBreakdown>,
    /// digest of the verified bundle the engine(s) warm-started from
    pub bundle_digest: Option<String>,
}

/// Run the serving benchmark against the XLA artifact pipeline (the
/// pre-refactor entry point, kept for artifact-driven callers).
pub fn serve(manifest: &Manifest, cfg: &ServerConfig) -> Result<ServeReport> {
    let pipeline = MoePipeline::new(manifest, cfg.dispatch)?;
    serve_backend(&pipeline, cfg)
}

/// Resolve `cfg.backend` ([`create_backend`]) and serve `cfg.workload` on
/// it — the engine-agnostic entry point behind `shiftaddvit serve`.
/// (The stream workload is native-only; it reports through
/// [`StreamReport`], so callers wanting it use [`serve_stream`] directly.)
pub fn serve_auto(cfg: &ServerConfig) -> Result<ServeReport> {
    if cfg.workers > 1 {
        return serve_fleet(cfg);
    }
    let bundle = load_bundle(cfg)?;
    let backend = create_backend_with(cfg, bundle.as_deref(), None)?;
    let mut report = serve_backend(backend.as_ref(), cfg)?;
    if let Some(b) = &bundle {
        report.bundle_digest = Some(b.digest.clone());
        report.metrics.bundle_digest = Some(b.digest.clone());
    }
    save_planner_table(cfg, &backend.planner_choices())?;
    Ok(report)
}

/// Dump planner decisions to `cfg.planner_table_save` (no-op when unset or
/// when the backend made no decisions, e.g. xla).
fn save_planner_table(cfg: &ServerConfig, choices: &[Choice]) -> Result<()> {
    if let Some(path) = &cfg.planner_table_save {
        if choices.is_empty() {
            println!("planner table not saved: backend logged no decisions");
        } else {
            std::fs::write(path, table_json(choices).to_string())?;
            println!("planner: saved {} choices to {path}", choices.len());
        }
    }
    Ok(())
}

fn argmax(xs: &[f32]) -> usize {
    let mut best = 0usize;
    for (i, &v) in xs.iter().enumerate() {
        if v > xs[best] {
            best = i;
        }
    }
    best
}

/// Run the classification serving benchmark described by `cfg` on any
/// engine, through the request-level submit/step/poll contract.
pub fn serve_backend(backend: &dyn InferenceBackend, cfg: &ServerConfig) -> Result<ServeReport> {
    backend.warmup()?;

    let (tx, rx) = mpsc::channel::<Request>();
    let n_req = cfg.requests;
    let arrival_ms = cfg.arrival_ms;

    // Client thread: deterministic synthetic images, optional pacing.
    let client = thread::spawn(move || {
        let mut rng = XorShift64::new(0xC11E17);
        for id in 0..n_req {
            let sample = synth_images::gen_image(5_000_000 + id as u32);
            let req = Request {
                id,
                pixels: sample.pixels,
                label: Some(sample.label),
                arrived: Instant::now(),
                trace: TraceCtx::NONE,
            };
            if tx.send(req).is_err() {
                return;
            }
            if arrival_ms > 0.0 {
                // exponential-ish jitter around the mean
                let jitter = 0.5 + rng.uniform() as f64;
                thread::sleep(Duration::from_secs_f64(arrival_ms * jitter / 1e3));
            }
        }
    });

    let batcher = Batcher::new(cfg.max_batch, cfg.batch_deadline_ms);
    let mut metrics = Metrics::default();
    // Plan-time gauge: which kernel backend each (primitive, shape) of the
    // engine resolved to at construction/warmup (xla plans nothing).
    metrics.record_plan(&backend.planner_choices());
    let mut latencies = Vec::new();
    let mut modularized = Vec::new();
    let mut correct = 0usize;
    let mut total = 0usize;
    let mut sample_masks = Vec::new();
    let t0 = Instant::now();

    while let Some(batch) = batcher.next_batch(&rx) {
        let mut tickets: Vec<Ticket> = batch
            .requests
            .into_iter()
            .map(|r| backend.submit(r))
            .collect();
        while backend.queued() > 0 {
            let rep = backend.step(cfg.max_batch, &mut metrics)?;
            if rep.served == 0 {
                anyhow::bail!("backend step made no progress");
            }
            modularized.push(rep.modularized_ms);
            // Continuous intake: requests that arrived while the step ran
            // join the next fused batch instead of waiting out a fresh
            // batching window.
            for r in batcher.drain_ready(&rx).requests {
                tickets.push(backend.submit(r));
            }
        }
        for t in &tickets {
            let out = backend
                .poll(t)
                .expect("stepped to completion, result must be ready");
            // per-request latency uses the serving step's completion stamp,
            // not the end of the whole drain loop
            latencies.push(out.latency_ms());
            if let Some(label) = out.label {
                total += 1;
                if argmax(&out.logits) == label {
                    correct += 1;
                }
            }
            if sample_masks.len() < 8 && !out.dispatch_mask_blk0.is_empty() {
                sample_masks.push(out.dispatch_mask_blk0);
            }
        }
    }
    let wall_s = t0.elapsed().as_secs_f64();
    client.join().expect("client thread");
    // Refresh the gauge: batched geometries may have planned lazily during
    // the run (record_plan rebuilds, so this never double-counts).
    metrics.record_plan(&backend.planner_choices());

    Ok(ServeReport {
        latency: Summary::from(&latencies),
        modularized_latency: Summary::from(&modularized),
        throughput_rps: metrics.requests as f64 / wall_s,
        accuracy: if total > 0 {
            correct as f64 / total as f64
        } else {
            0.0
        },
        occupancy: metrics.occupancy_summary(),
        step_tokens: metrics.step_tokens_summary(),
        metrics,
        sample_masks,
        per_worker: Vec::new(),
        bundle_digest: None,
    })
}

/// Classification serving across a fleet of engine workers behind the
/// [`Router`] (`cfg.workers > 1`): the same synthetic client, but requests
/// are placed by the configured routing policy and every worker fuses its
/// own queue on its own thread. Outputs are collected through the
/// supervised poll, so the run survives worker death by resubmission.
/// The planner is tuned ONCE in the router's factory and every worker
/// pins the shared table (see [`Router::from_server_config`]), exactly
/// like the stream fleet — workers never re-benchmark the same shapes.
pub fn serve_fleet(cfg: &ServerConfig) -> Result<ServeReport> {
    let mut router = Router::from_server_config(cfg)?;
    println!(
        "fleet: {} workers ready  policy {}",
        router.worker_count(),
        router.policy_name()
    );

    let (tx, rx) = mpsc::channel::<Request>();
    let n_req = cfg.requests;
    let arrival_ms = cfg.arrival_ms;
    // Same deterministic client as the single-engine loop, so fleet and
    // solo runs see identical request sets.
    let client = thread::spawn(move || {
        let mut rng = XorShift64::new(0xC11E17);
        for id in 0..n_req {
            let sample = synth_images::gen_image(5_000_000 + id as u32);
            let req = Request {
                id,
                pixels: sample.pixels,
                label: Some(sample.label),
                arrived: Instant::now(),
                trace: TraceCtx::NONE,
            };
            if tx.send(req).is_err() {
                return;
            }
            if arrival_ms > 0.0 {
                let jitter = 0.5 + rng.uniform() as f64;
                thread::sleep(Duration::from_secs_f64(arrival_ms * jitter / 1e3));
            }
        }
    });

    let t0 = Instant::now();
    let mut tickets = Vec::with_capacity(n_req);
    while let Ok(req) = rx.recv() {
        tickets.push(router.submit(req)?);
    }
    client.join().expect("client thread");

    let mut latencies = Vec::with_capacity(tickets.len());
    let mut modularized = Vec::with_capacity(tickets.len());
    let mut correct = 0usize;
    let mut total = 0usize;
    let mut sample_masks = Vec::new();
    for t in &tickets {
        let out = router.poll_wait(t, Duration::from_secs(120))?;
        latencies.push(out.latency_ms());
        // per-request view of the ideal-parallel makespan: each output
        // carries its serving batch's modularized time
        modularized.push(out.modularized_ms);
        if let Some(label) = out.label {
            total += 1;
            if argmax(&out.logits) == label {
                correct += 1;
            }
        }
        if sample_masks.len() < 8 && !out.dispatch_mask_blk0.is_empty() {
            sample_masks.push(out.dispatch_mask_blk0);
        }
    }
    let wall_s = t0.elapsed().as_secs_f64();
    if router.resubmitted() > 0 {
        println!(
            "fleet: {} requests resubmitted after worker death",
            router.resubmitted()
        );
    }
    let (metrics, per_worker) = router.metrics_report();
    // The factory tuned the planner once and shared the table with every
    // worker, so its decision log IS the fleet's table.
    save_planner_table(cfg, router.factory_choices())?;
    let bundle_digest = router.bundle_digest().map(String::from);
    router.shutdown()?;

    Ok(ServeReport {
        latency: Summary::from(&latencies),
        modularized_latency: Summary::from(&modularized),
        throughput_rps: metrics.requests as f64 / wall_s,
        accuracy: if total > 0 {
            correct as f64 / total as f64
        } else {
            0.0
        },
        occupancy: metrics.occupancy_summary(),
        step_tokens: metrics.step_tokens_summary(),
        metrics,
        sample_masks,
        per_worker,
        bundle_digest,
    })
}

impl ServeReport {
    pub fn print(&self) {
        println!("== serving report ==");
        println!(
            "requests {}  throughput {:.1} img/s  accuracy {:.2}%",
            self.metrics.requests,
            self.throughput_rps,
            100.0 * self.accuracy
        );
        println!(
            "request latency  mean {:.2} ms  p50 {:.2}  p99 {:.2}",
            self.latency.mean, self.latency.p50, self.latency.p99
        );
        println!(
            "batch modularized latency (ideal parallelism)  mean {:.2} ms",
            self.modularized_latency.mean
        );
        print_per_worker(&self.per_worker);
        self.metrics.print();
    }
}

/// Shared per-worker report lines (classify + stream fleet paths).
fn print_per_worker(per_worker: &[WorkerBreakdown]) {
    if per_worker.is_empty() {
        return;
    }
    println!("per-worker breakdown:");
    for b in per_worker {
        println!(
            "  worker {:2} [{:8}]  requests {:5}  batches {:5}  load {}",
            b.id, b.state, b.requests, b.batches, b.load
        );
    }
}

// ---------------------------------------------------------------------------
// Token-streaming serving (sessions through the continuous batcher)
// ---------------------------------------------------------------------------

/// Outcome of a streaming serving run.
pub struct StreamReport {
    pub sessions: usize,
    pub total_tokens: usize,
    pub steps: usize,
    pub wall_ms: f64,
    pub tokens_per_sec: f64,
    /// per-session end-to-end latency (submit → logits)
    pub latency: Summary,
    /// per-token latency (session latency / tokens streamed) — the
    /// p50/p95/p99 the phase-disaggregated scheduler is judged on
    pub token_latency: Summary,
    /// per-session queue wait (arrival → first admission into a fused
    /// dispatch): how long intake sat behind the admission budget
    pub queue_wait: Summary,
    /// per-session time-to-first-token (arrival → completion of the step
    /// that first fed it)
    pub ttft: Summary,
    pub occupancy: Option<Summary>,
    pub step_tokens: Option<Summary>,
    pub metrics: Metrics,
    /// per-worker breakdown (fleet runs; empty on the single-engine path)
    pub per_worker: Vec<WorkerBreakdown>,
    /// digest of the verified bundle whose planner table the engine pinned
    pub bundle_digest: Option<String>,
}

fn summary_json(s: &Summary) -> Json {
    Json::obj(vec![
        ("mean", Json::num(s.mean)),
        ("p50", Json::num(s.p50)),
        ("p95", Json::num(s.p95)),
        ("p99", Json::num(s.p99)),
        ("max", Json::num(s.max)),
    ])
}

impl StreamReport {
    pub fn print(&self) {
        println!("== streaming report ==");
        println!(
            "sessions {}  tokens {}  steps {}  wall {:.1} ms  throughput {:.0} tok/s",
            self.sessions, self.total_tokens, self.steps, self.wall_ms, self.tokens_per_sec
        );
        println!(
            "session latency  mean {:.2} ms  p50 {:.2}  p99 {:.2}",
            self.latency.mean, self.latency.p50, self.latency.p99
        );
        println!(
            "per-token latency  p50 {:.3} ms  p95 {:.3}  p99 {:.3}",
            self.token_latency.p50, self.token_latency.p95, self.token_latency.p99
        );
        println!(
            "queue wait  p50 {:.3} ms  p95 {:.3}  p99 {:.3}   ttft  p50 {:.3} ms  p95 {:.3}  p99 {:.3}",
            self.queue_wait.p50,
            self.queue_wait.p95,
            self.queue_wait.p99,
            self.ttft.p50,
            self.ttft.p95,
            self.ttft.p99
        );
        print_per_worker(&self.per_worker);
        self.metrics.print();
    }

    /// JSON shape for benches/tooling (trailing-JSON convention).
    pub fn to_json(&self) -> Json {
        let mut rows = vec![
            ("sessions", Json::num(self.sessions as f64)),
            ("total_tokens", Json::num(self.total_tokens as f64)),
            ("steps", Json::num(self.steps as f64)),
            ("wall_ms", Json::num(self.wall_ms)),
            ("tokens_per_sec", Json::num(self.tokens_per_sec)),
            ("latency_ms", summary_json(&self.latency)),
            ("token_latency_ms", summary_json(&self.token_latency)),
            ("queue_wait_ms", summary_json(&self.queue_wait)),
            ("ttft_ms", summary_json(&self.ttft)),
            (
                "per_worker",
                Json::Arr(self.per_worker.iter().map(|b| b.to_json()).collect()),
            ),
        ];
        if let Some(d) = &self.bundle_digest {
            rows.push(("bundle_digest", Json::str(d)));
        }
        Json::obj(rows)
    }
}

/// Deterministic synthetic token sequence lengths for the stream workload:
/// spread over [mean/2, mean/2 + mean) so sessions join and leave the
/// continuous batch at different times.
pub fn stream_workload_lens(sessions: usize, mean_tokens: usize) -> Vec<usize> {
    let mean = mean_tokens.max(2);
    (0..sessions)
        .map(|i| mean / 2 + (i * 7 + 3) % mean)
        .collect()
}

/// Deterministic arrival-offset schedule (ms, non-decreasing, first at 0)
/// for the open-loop streaming client: session `i` arrives after `i`
/// jittered gaps of `mean_ms · (0.5 + u)`, `u ∈ [0, 1)` drawn from `seed` —
/// the same exponential-ish pacing the classification client thread uses,
/// but precomputed so runs are reproducible and the schedule is testable.
/// `mean_ms = 0` degenerates to the closed-loop schedule (all zeros).
pub fn stream_arrival_schedule(sessions: usize, mean_ms: f64, seed: u64) -> Vec<f64> {
    let mut rng = XorShift64::new(seed);
    let mut at = 0.0f64;
    (0..sessions)
        .map(|_| {
            let now = at;
            at += mean_ms * (0.5 + rng.uniform() as f64);
            now
        })
        .collect()
}

/// Seed of the open-loop arrival schedule (fixed: serving runs are
/// reproducible; vary `cfg.arrival_ms` to change the traffic, not the draw).
const STREAM_ARRIVAL_SEED: u64 = 0x0FE2_107;

/// Serve `cfg.requests` token-streaming sessions on the native streaming
/// engine (the paper's deployed mixture: Hamming LinearAdd attention +
/// shift linears), continuously batched `cfg.max_live` at a time in
/// `cfg.stream_chunk`-token steps.
///
/// With `cfg.arrival_ms > 0` the client is **open-loop**: sessions are
/// submitted on the deterministic [`stream_arrival_schedule`] while the
/// engine keeps stepping whatever is live, so admission control
/// (`max_live`) is exercised by staggered arrivals instead of one up-front
/// burst. `arrival_ms = 0` keeps the closed-loop behavior (all sessions
/// submitted before the first step).
pub fn serve_stream(cfg: &ServerConfig) -> Result<StreamReport> {
    if cfg.backend != BackendKind::Native {
        anyhow::bail!(
            "the stream workload runs on the native streaming engine only \
             (got --backend {}); the XLA artifacts have no token-level entry point",
            cfg.backend.name()
        );
    }
    if cfg.workers > 1 {
        return serve_stream_fleet(cfg);
    }
    // A bundle pins the streaming planner to its shipped table (stream
    // weights are spec-seeded; the image path owns the params blob).
    let bundle = load_bundle(cfg)?;
    let planner = create_planner(cfg)?;
    if let Some(b) = &bundle {
        let pinned = planner.pin_table_json(&b.table)?;
        println!("bundle: pinned {pinned} planner choices from the bundle");
    }
    let model = StreamModel::new(SessionSpec::tiny(StreamAttn::LinearAdd, Lin::Shift), planner);
    let dim = model.spec.dim;
    let mode = engine_mode(cfg);
    print_scheduler(mode);
    let mut engine =
        SessionEngine::with_mode(model, cfg.stream_chunk.max(1), cfg.max_live.max(1), mode);

    let lens = stream_workload_lens(cfg.requests, cfg.stream_tokens);
    let schedule = stream_arrival_schedule(lens.len(), cfg.arrival_ms, STREAM_ARRIVAL_SEED);
    let total_tokens: usize = lens.iter().sum();
    let mut seqs: Vec<Vec<f32>> = lens
        .iter()
        .enumerate()
        .map(|(i, &n)| XorShift64::new(0x70C0 + i as u64).normals(n * dim))
        .collect();

    let mut metrics = Metrics::default();
    let mut tickets = Vec::with_capacity(lens.len());
    let mut steps = 0usize;
    let mut next = 0usize;
    let t0 = Instant::now();
    while next < seqs.len() || !engine.idle() {
        let now_ms = t0.elapsed().as_secs_f64() * 1e3;
        while next < seqs.len() && schedule[next] <= now_ms {
            tickets.push(engine.submit(std::mem::take(&mut seqs[next])));
            next += 1;
        }
        if engine.idle() {
            // Open-loop gap: nothing live, next arrival is in the future.
            let wait_ms = schedule[next] - now_ms;
            if wait_ms > 0.0 {
                thread::sleep(Duration::from_secs_f64(wait_ms / 1e3));
            }
            continue;
        }
        engine.step(&mut metrics);
        steps += 1;
    }
    let wall_ms = t0.elapsed().as_secs_f64() * 1e3;

    let mut latencies = Vec::with_capacity(tickets.len());
    let mut token_latencies = Vec::with_capacity(tickets.len());
    let mut queue_waits = Vec::with_capacity(tickets.len());
    let mut ttfts = Vec::with_capacity(tickets.len());
    for t in &tickets {
        let out = engine.poll(t).expect("serve loop finished all sessions");
        latencies.push(out.latency_ms());
        token_latencies.push(out.latency_ms() / out.tokens.max(1) as f64);
        queue_waits.push(out.queue_wait_ms());
        ttfts.push(out.ttft_ms());
    }
    metrics.record_plan(&engine.model.planner.choices());
    save_planner_table(cfg, &engine.model.planner.choices())?;
    let bundle_digest = bundle.map(|b| b.digest.clone());
    metrics.bundle_digest = bundle_digest.clone();

    Ok(StreamReport {
        sessions: lens.len(),
        total_tokens,
        steps,
        wall_ms,
        tokens_per_sec: total_tokens as f64 / (wall_ms / 1e3).max(1e-12),
        latency: Summary::from(&latencies),
        token_latency: Summary::from(&token_latencies),
        queue_wait: Summary::from(&queue_waits),
        ttft: Summary::from(&ttfts),
        occupancy: metrics.occupancy_summary(),
        step_tokens: metrics.step_tokens_summary(),
        metrics,
        per_worker: Vec::new(),
        bundle_digest,
    })
}

/// Map the configured scheduler onto the engine's mode, resolving the
/// auto-sized prefill budget. `pub(crate)` so the HTTP front door builds
/// its stream engine exactly the way `serve_stream` would.
pub(crate) fn engine_mode(cfg: &ServerConfig) -> SchedulerMode {
    match cfg.scheduler {
        SchedulerKind::SinglePhase => SchedulerMode::SinglePhase,
        SchedulerKind::Disaggregated => SchedulerMode::Disaggregated {
            prefill_budget: cfg.resolve_prefill_budget(),
        },
    }
}

fn print_scheduler(mode: SchedulerMode) {
    match mode {
        SchedulerMode::SinglePhase => println!("stream scheduler: single-phase (legacy)"),
        SchedulerMode::Disaggregated { prefill_budget } => println!(
            "stream scheduler: disaggregated (prefill budget {prefill_budget} tokens/step)"
        ),
    }
}

/// What one stream fleet worker hands back when its inbox closes and its
/// engine drains.
struct StreamWorkerResult {
    sessions: usize,
    steps: usize,
    latencies: Vec<f64>,
    token_latencies: Vec<f64>,
    queue_waits: Vec<f64>,
    ttfts: Vec<f64>,
    metrics: Metrics,
}

/// The stream workload across `cfg.workers` [`SessionEngine`]s, one per
/// thread. `SessionEngine` steps by `&mut self`, so each worker owns its
/// engine outright; the main thread plays router: it walks the open-loop
/// arrival schedule and places each session with the configured fleet
/// policy over live-load gauges that workers decrement as sessions retire
/// (shape key = the session's token count).
///
/// The planner is tuned ONCE in the factory — a probe model autotunes (or
/// pins `cfg.planner_table`) on the main thread — and every worker pins
/// the resulting table via [`Planner::pin_table_json`], so N workers never
/// re-benchmark the same shapes N times and all place identical kernels.
fn serve_stream_fleet(cfg: &ServerConfig) -> Result<StreamReport> {
    let workers = cfg.workers;
    let lens = stream_workload_lens(cfg.requests, cfg.stream_tokens);
    let schedule = stream_arrival_schedule(lens.len(), cfg.arrival_ms, STREAM_ARRIVAL_SEED);
    let total_tokens: usize = lens.iter().sum();
    let dim = SessionSpec::tiny(StreamAttn::LinearAdd, Lin::Shift).dim;
    let mut seqs: Vec<Vec<f32>> = lens
        .iter()
        .enumerate()
        .map(|(i, &n)| XorShift64::new(0x70C0 + i as u64).normals(n * dim))
        .collect();
    let mode = engine_mode(cfg);
    print_scheduler(mode);

    // Plan once in the factory: the probe model autotunes every shape the
    // workers will need (or pins them from cfg.planner_table / the
    // verified bundle's table), then the table is shared with every
    // worker at spawn.
    let bundle = load_bundle(cfg)?;
    let factory_planner = create_planner(cfg)?;
    if let Some(b) = &bundle {
        let pinned = factory_planner.pin_table_json(&b.table)?;
        println!("bundle: pinned {pinned} planner choices from the bundle");
    }
    let _probe = StreamModel::new(
        SessionSpec::tiny(StreamAttn::LinearAdd, Lin::Shift),
        Arc::clone(&factory_planner),
    );
    let table_text = factory_planner.to_table_json().to_string();
    println!(
        "fleet: planner tuned once in the factory ({} choices shared with {workers} workers)",
        factory_planner.choices().len()
    );
    save_planner_table(cfg, &factory_planner.choices())?;

    let mut inboxes = Vec::with_capacity(workers);
    let mut loads: Vec<Arc<AtomicUsize>> = Vec::with_capacity(workers);
    let mut handles = Vec::with_capacity(workers);
    for w in 0..workers {
        let (tx, rx) = mpsc::channel::<Vec<f32>>();
        let load = Arc::new(AtomicUsize::new(0));
        let chunk = cfg.stream_chunk.max(1);
        let max_live = cfg.max_live.max(1);
        let thread_load = Arc::clone(&load);
        let worker_table = table_text.clone();
        let handle = thread::Builder::new()
            .name(format!("stream-worker-{w}"))
            .spawn(move || -> StreamWorkerResult {
                let planner = Arc::new(Planner::new(Arc::new(KernelRegistry::with_defaults())));
                planner
                    .pin_table_json(&Json::parse(&worker_table).expect("factory table parses"))
                    .expect("factory table pins on the worker planner");
                let model = StreamModel::new(
                    SessionSpec::tiny(StreamAttn::LinearAdd, Lin::Shift),
                    planner,
                );
                let mut engine = SessionEngine::with_mode(model, chunk, max_live, mode);
                let mut metrics = Metrics::default();
                let mut tickets: Vec<StreamTicket> = Vec::new();
                let mut steps = 0usize;
                let mut open = true;
                loop {
                    loop {
                        match rx.try_recv() {
                            Ok(seq) => tickets.push(engine.submit(seq)),
                            Err(mpsc::TryRecvError::Empty) => break,
                            Err(mpsc::TryRecvError::Disconnected) => {
                                open = false;
                                break;
                            }
                        }
                    }
                    if engine.idle() {
                        if !open {
                            break;
                        }
                        // open-loop gap: next arrival is in the future
                        thread::sleep(Duration::from_micros(200));
                        continue;
                    }
                    let st = engine.step(&mut metrics);
                    steps += 1;
                    if st.finished > 0 {
                        thread_load.fetch_sub(st.finished, Ordering::SeqCst);
                    }
                }
                metrics.record_plan(&engine.model.planner.choices());
                let mut latencies = Vec::with_capacity(tickets.len());
                let mut token_latencies = Vec::with_capacity(tickets.len());
                let mut queue_waits = Vec::with_capacity(tickets.len());
                let mut ttfts = Vec::with_capacity(tickets.len());
                for t in &tickets {
                    let out = engine.poll(t).expect("stream worker drained its sessions");
                    latencies.push(out.latency_ms());
                    token_latencies.push(out.latency_ms() / out.tokens.max(1) as f64);
                    queue_waits.push(out.queue_wait_ms());
                    ttfts.push(out.ttft_ms());
                }
                StreamWorkerResult {
                    sessions: tickets.len(),
                    steps,
                    latencies,
                    token_latencies,
                    queue_waits,
                    ttfts,
                    metrics,
                }
            })
            .expect("spawn stream worker thread");
        inboxes.push(tx);
        loads.push(load);
        handles.push(handle);
    }

    let mut policy = cfg.policy.build(crate::fleet::router::DEFAULT_POLICY_SEED);
    println!(
        "fleet: {} stream workers  policy {}",
        workers,
        policy.name()
    );
    let t0 = Instant::now();
    for (i, len) in lens.iter().enumerate() {
        let wait_ms = schedule[i] - t0.elapsed().as_secs_f64() * 1e3;
        if wait_ms > 0.0 {
            thread::sleep(Duration::from_secs_f64(wait_ms / 1e3));
        }
        let views: Vec<WorkerView> = loads
            .iter()
            .enumerate()
            .map(|(id, load)| WorkerView {
                id,
                ready: true,
                load: load.load(Ordering::SeqCst),
            })
            .collect();
        let w = policy
            .pick(*len as u64, &views)
            .expect("every stream worker admits");
        loads[w].fetch_add(1, Ordering::SeqCst);
        inboxes[w]
            .send(std::mem::take(&mut seqs[i]))
            .expect("stream worker inbox open");
    }
    drop(inboxes); // workers drain and exit

    let mut merged = Metrics::default();
    let mut latencies = Vec::with_capacity(lens.len());
    let mut token_latencies = Vec::with_capacity(lens.len());
    let mut queue_waits = Vec::with_capacity(lens.len());
    let mut ttfts = Vec::with_capacity(lens.len());
    let mut steps = 0usize;
    let mut per_worker = Vec::with_capacity(workers);
    for (w, handle) in handles.into_iter().enumerate() {
        let res = handle.join().expect("stream worker thread");
        steps += res.steps;
        latencies.extend_from_slice(&res.latencies);
        token_latencies.extend_from_slice(&res.token_latencies);
        queue_waits.extend_from_slice(&res.queue_waits);
        ttfts.extend_from_slice(&res.ttfts);
        merged.merge(&res.metrics);
        per_worker.push(WorkerBreakdown {
            id: w,
            state: "done",
            requests: res.sessions,
            batches: res.steps,
            load: 0,
        });
    }
    let wall_ms = t0.elapsed().as_secs_f64() * 1e3;
    let bundle_digest = bundle.map(|b| b.digest.clone());
    merged.bundle_digest = bundle_digest.clone();

    Ok(StreamReport {
        sessions: lens.len(),
        total_tokens,
        steps,
        wall_ms,
        tokens_per_sec: total_tokens as f64 / (wall_ms / 1e3).max(1e-12),
        latency: Summary::from(&latencies),
        token_latency: Summary::from(&token_latencies),
        queue_wait: Summary::from(&queue_waits),
        ttft: Summary::from(&ttfts),
        occupancy: merged.occupancy_summary(),
        step_tokens: merged.step_tokens_summary(),
        metrics: merged,
        per_worker,
        bundle_digest,
    })
}

/// Dispatch `cfg.workload`: classification through [`serve_auto`], or
/// streaming through [`serve_stream`] (printing its own report). Used by
/// the `serve` subcommand so one flag switches request shapes. With
/// `--http PORT` set, both workloads are instead served over a real TCP
/// socket by the fleet's HTTP front door until the process is killed.
///
/// `--trace-out PATH` turns on span recording for the run and writes the
/// ring as Chrome trace-event JSON when the workload finishes (the HTTP
/// front door records too, but exports live via `GET /trace` since it
/// never returns).
pub fn serve_workload(cfg: &ServerConfig) -> Result<()> {
    if let Some(path) = &cfg.trace_out {
        crate::obs::trace::set_enabled(true);
        println!("tracing: span ring on, will write {path}");
    }
    if cfg.http_port > 0 {
        return crate::fleet::http::serve_http(cfg, cfg.http_port);
    }
    match cfg.workload {
        Workload::Classify => {
            let report = serve_auto(cfg)?;
            report.print();
        }
        Workload::Stream => {
            let report = serve_stream(cfg)?;
            report.print();
        }
    }
    if let Some(path) = &cfg.trace_out {
        let trace = crate::obs::trace::export_chrome();
        std::fs::write(path, trace.to_string())?;
        println!(
            "tracing: wrote {} spans to {path} (load in Perfetto / chrome://tracing)",
            crate::obs::trace::len()
        );
    }
    Ok(())
}
