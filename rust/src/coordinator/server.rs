//! The serving loop: a synthetic client thread issues image requests
//! (open-loop Poisson-ish or closed-loop), the coordinator batches them,
//! runs them through an [`InferenceBackend`] (native engine or XLA artifact
//! pipeline), and reports latency/throughput/accuracy — the end-to-end
//! driver behind `shiftaddvit serve` and
//! `examples/serve_classification.rs`.

use std::sync::mpsc;
use std::thread;
use std::time::{Duration, Instant};

use anyhow::Result;

use crate::coordinator::backend::{create_backend, InferenceBackend};
use crate::coordinator::batcher::{Batcher, Request};
use crate::coordinator::config::ServerConfig;
use crate::coordinator::metrics::Metrics;
use crate::coordinator::scheduler::MoePipeline;
use crate::data::synth_images;
use crate::runtime::artifact::Manifest;
use crate::util::rng::XorShift64;
use crate::util::stats::Summary;

/// Outcome of a serving run.
pub struct ServeReport {
    pub metrics: Metrics,
    pub latency: Summary,
    pub modularized_latency: Summary,
    pub throughput_rps: f64,
    pub accuracy: f64,
    /// first few dispatch masks for visualisation
    pub sample_masks: Vec<Vec<bool>>,
}

/// Run the serving benchmark against the XLA artifact pipeline (the
/// pre-refactor entry point, kept for artifact-driven callers).
pub fn serve(manifest: &Manifest, cfg: &ServerConfig) -> Result<ServeReport> {
    let pipeline = MoePipeline::new(manifest, cfg.dispatch)?;
    serve_backend(&pipeline, cfg)
}

/// Resolve `cfg.backend` ([`create_backend`]) and serve on it — the
/// engine-agnostic entry point behind `shiftaddvit serve`.
pub fn serve_auto(cfg: &ServerConfig) -> Result<ServeReport> {
    let backend = create_backend(cfg)?;
    serve_backend(backend.as_ref(), cfg)
}

/// Run the serving benchmark described by `cfg` on any engine.
pub fn serve_backend(backend: &dyn InferenceBackend, cfg: &ServerConfig) -> Result<ServeReport> {
    backend.warmup()?;

    let (tx, rx) = mpsc::channel::<Request>();
    let n_req = cfg.requests;
    let arrival_ms = cfg.arrival_ms;

    // Client thread: deterministic synthetic images, optional pacing.
    let client = thread::spawn(move || {
        let mut rng = XorShift64::new(0xC11E17);
        for id in 0..n_req {
            let sample = synth_images::gen_image(5_000_000 + id as u32);
            let req = Request {
                id,
                pixels: sample.pixels,
                label: Some(sample.label),
                arrived: Instant::now(),
            };
            if tx.send(req).is_err() {
                return;
            }
            if arrival_ms > 0.0 {
                // exponential-ish jitter around the mean
                let jitter = 0.5 + rng.uniform() as f64;
                thread::sleep(Duration::from_secs_f64(arrival_ms * jitter / 1e3));
            }
        }
    });

    let batcher = Batcher::new(cfg.max_batch, cfg.batch_deadline_ms);
    let mut metrics = Metrics::default();
    let mut latencies = Vec::new();
    let mut modularized = Vec::new();
    let mut correct = 0usize;
    let mut total = 0usize;
    let mut sample_masks = Vec::new();
    let t0 = Instant::now();

    while let Some(batch) = batcher.next_batch(&rx) {
        let pixels = batch.pixels();
        let out = backend.run_batch(&pixels, batch.len(), &mut metrics)?;
        let preds = out.logits.argmax_last()?;
        let done = Instant::now();
        for (r, p) in batch.requests.iter().zip(&preds) {
            latencies.push(done.duration_since(r.arrived).as_secs_f64() * 1e3);
            if let Some(label) = r.label {
                total += 1;
                if *p == label {
                    correct += 1;
                }
            }
        }
        modularized.push(out.modularized_ms);
        if sample_masks.len() < 8 {
            let room = 8 - sample_masks.len();
            sample_masks.extend(out.dispatch_mask_blk0.into_iter().take(room));
        }
    }
    let wall_s = t0.elapsed().as_secs_f64();
    client.join().expect("client thread");

    Ok(ServeReport {
        latency: Summary::from(&latencies),
        modularized_latency: Summary::from(&modularized),
        throughput_rps: metrics.requests as f64 / wall_s,
        accuracy: if total > 0 {
            correct as f64 / total as f64
        } else {
            0.0
        },
        metrics,
        sample_masks,
    })
}

impl ServeReport {
    pub fn print(&self) {
        println!("== serving report ==");
        println!(
            "requests {}  throughput {:.1} img/s  accuracy {:.2}%",
            self.metrics.requests,
            self.throughput_rps,
            100.0 * self.accuracy
        );
        println!(
            "request latency  mean {:.2} ms  p50 {:.2}  p99 {:.2}",
            self.latency.mean, self.latency.p50, self.latency.p99
        );
        println!(
            "batch modularized latency (ideal parallelism)  mean {:.2} ms",
            self.modularized_latency.mean
        );
        self.metrics.print();
    }
}
