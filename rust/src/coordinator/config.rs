//! Serving configuration (JSON file or CLI flags).

use std::path::Path;

use anyhow::Result;

use crate::fleet::policy::PolicyKind;
use crate::util::json::Json;

/// How MoE expert execution is timed/executed.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum DispatchMode {
    /// experts run concurrently on their own engine workers; the layer
    /// completes when the slowest finishes (real wall-clock, Table 4/6 "†")
    Real,
    /// experts run sequentially but the layer is charged max(expert times) —
    /// the paper's "modularized latency, ideal parallelism" ("*")
    Modularized,
    /// dense fallback: every token through both experts (PVT+MoE baseline)
    Dense,
}

impl DispatchMode {
    pub fn parse(s: &str) -> Result<DispatchMode> {
        match s {
            "real" => Ok(DispatchMode::Real),
            "modularized" => Ok(DispatchMode::Modularized),
            "dense" => Ok(DispatchMode::Dense),
            other => anyhow::bail!("unknown dispatch mode '{other}' (real|modularized|dense)"),
        }
    }
}

/// Which inference engine serves the traffic.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum BackendKind {
    /// pure-Rust `infer` engine — zero artifacts, runs out of the box
    Native,
    /// AOT-compiled HLO artifacts on the PJRT engine pool
    Xla,
}

impl BackendKind {
    pub fn parse(s: &str) -> Result<BackendKind> {
        match s {
            "native" => Ok(BackendKind::Native),
            "xla" => Ok(BackendKind::Xla),
            other => anyhow::bail!("unknown backend '{other}' (native|xla)"),
        }
    }

    pub fn name(self) -> &'static str {
        match self {
            BackendKind::Native => "native",
            BackendKind::Xla => "xla",
        }
    }
}

/// How the streaming engine schedules prefill vs decode.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SchedulerKind {
    /// legacy baseline: arrivals prefill inside the same fused step that
    /// advances live streams
    SinglePhase,
    /// phase-disaggregated: decode dispatches first and alone; new prompts
    /// catch up in a separate prefill dispatch under `prefill_budget`
    Disaggregated,
}

impl SchedulerKind {
    pub fn parse(s: &str) -> Result<SchedulerKind> {
        match s {
            "single-phase" => Ok(SchedulerKind::SinglePhase),
            "disaggregated" => Ok(SchedulerKind::Disaggregated),
            other => {
                anyhow::bail!("unknown scheduler '{other}' (single-phase|disaggregated)")
            }
        }
    }

    pub fn name(self) -> &'static str {
        match self {
            SchedulerKind::SinglePhase => "single-phase",
            SchedulerKind::Disaggregated => "disaggregated",
        }
    }
}

/// Which request shape the server drives.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Workload {
    /// one-shot image classification requests
    Classify,
    /// token-streaming sessions through `SessionEngine`
    Stream,
}

impl Workload {
    pub fn parse(s: &str) -> Result<Workload> {
        match s {
            "classify" => Ok(Workload::Classify),
            "stream" => Ok(Workload::Stream),
            other => anyhow::bail!("unknown workload '{other}' (classify|stream)"),
        }
    }

    pub fn name(self) -> &'static str {
        match self {
            Workload::Classify => "classify",
            Workload::Stream => "stream",
        }
    }
}

/// Coordinator settings.
#[derive(Clone, Debug)]
pub struct ServerConfig {
    /// max images per formed batch
    pub max_batch: usize,
    /// how long the batcher waits to fill a batch (ms)
    pub batch_deadline_ms: f64,
    pub dispatch: DispatchMode,
    /// which engine executes batches
    pub backend: BackendKind,
    /// number of requests the synthetic client issues (sessions, for the
    /// stream workload)
    pub requests: usize,
    /// mean request inter-arrival (ms); 0 = closed-loop. Applies to both
    /// workloads: the classify client thread paces its sends, and the
    /// stream workload submits sessions on a deterministic seeded
    /// open-loop schedule (`server::stream_arrival_schedule`).
    pub arrival_ms: f64,
    /// request shape (`classify` | `stream`)
    pub workload: Workload,
    /// stream workload: mean tokens per session
    pub stream_tokens: usize,
    /// stream workload: tokens each live session contributes per step
    pub stream_chunk: usize,
    /// stream workload: live-session cap (continuous-batching slots)
    pub max_live: usize,
    /// stream workload: how prefill and decode share the step loop
    pub scheduler: SchedulerKind,
    /// stream workload: max prompt tokens the prefill phase feeds per step
    /// (0 = auto-size to `stream_chunk · max_live`, so one step of intake
    /// never outweighs a full decode batch and decode is never starved)
    pub prefill_budget: usize,
    /// offline-autotuned planner table to pin on startup (JSON path)
    pub planner_table: Option<String>,
    /// where to dump the planner's decisions after the run (JSON path)
    pub planner_table_save: Option<String>,
    /// signed `.sabundle` to verify once and warm-start every engine from
    /// (params + pinned planner table); native backend only
    pub bundle: Option<String>,
    /// HMAC key for bundle verification (default: the dev signing key)
    pub bundle_key: Option<String>,
    /// engine workers behind the fleet router; 1 = the classic
    /// single-engine loop (no fleet layer)
    pub workers: usize,
    /// how the fleet router places requests across workers
    pub policy: PolicyKind,
    /// serve over HTTP on this port instead of running the synthetic
    /// benchmark client (0 = off)
    pub http_port: usize,
    /// write the run's span ring as Chrome trace-event JSON here after the
    /// workload finishes (Perfetto-loadable); also enables tracing
    pub trace_out: Option<String>,
}

impl Default for ServerConfig {
    fn default() -> Self {
        ServerConfig {
            max_batch: 8,
            batch_deadline_ms: 2.0,
            dispatch: DispatchMode::Real,
            backend: BackendKind::Native,
            requests: 128,
            arrival_ms: 0.0,
            workload: Workload::Classify,
            stream_tokens: 64,
            stream_chunk: 8,
            max_live: 8,
            scheduler: SchedulerKind::Disaggregated,
            prefill_budget: 0,
            planner_table: None,
            planner_table_save: None,
            bundle: None,
            bundle_key: None,
            workers: 1,
            policy: PolicyKind::RoundRobin,
            http_port: 0,
            trace_out: None,
        }
    }
}

impl ServerConfig {
    /// Load from a JSON file; missing keys keep defaults.
    pub fn from_file(path: &Path) -> Result<ServerConfig> {
        let text = std::fs::read_to_string(path)?;
        let j = Json::parse(&text)?;
        let mut c = ServerConfig::default();
        if let Some(v) = j.get("max_batch").and_then(|v| v.as_usize()) {
            c.max_batch = v;
        }
        if let Some(v) = j.get("batch_deadline_ms").and_then(|v| v.as_f64()) {
            c.batch_deadline_ms = v;
        }
        if let Some(v) = j.get("dispatch").and_then(|v| v.as_str()) {
            c.dispatch = DispatchMode::parse(v)?;
        }
        if let Some(v) = j.get("backend").and_then(|v| v.as_str()) {
            c.backend = BackendKind::parse(v)?;
        }
        if let Some(v) = j.get("requests").and_then(|v| v.as_usize()) {
            c.requests = v;
        }
        if let Some(v) = j.get("arrival_ms").and_then(|v| v.as_f64()) {
            c.arrival_ms = v;
        }
        if let Some(v) = j.get("workload").and_then(|v| v.as_str()) {
            c.workload = Workload::parse(v)?;
        }
        if let Some(v) = j.get("stream_tokens").and_then(|v| v.as_usize()) {
            c.stream_tokens = v;
        }
        if let Some(v) = j.get("stream_chunk").and_then(|v| v.as_usize()) {
            c.stream_chunk = v;
        }
        if let Some(v) = j.get("max_live").and_then(|v| v.as_usize()) {
            c.max_live = v;
        }
        if let Some(v) = j.get("scheduler").and_then(|v| v.as_str()) {
            c.scheduler = SchedulerKind::parse(v)?;
        }
        if let Some(v) = j.get("prefill_budget").and_then(|v| v.as_usize()) {
            c.prefill_budget = v;
        }
        if let Some(v) = j.get("planner_table").and_then(|v| v.as_str()) {
            c.planner_table = Some(v.to_string());
        }
        if let Some(v) = j.get("planner_table_save").and_then(|v| v.as_str()) {
            c.planner_table_save = Some(v.to_string());
        }
        if let Some(v) = j.get("bundle").and_then(|v| v.as_str()) {
            c.bundle = Some(v.to_string());
        }
        if let Some(v) = j.get("bundle_key").and_then(|v| v.as_str()) {
            c.bundle_key = Some(v.to_string());
        }
        if let Some(v) = j.get("workers").and_then(|v| v.as_usize()) {
            c.workers = v;
        }
        if let Some(v) = j.get("policy").and_then(|v| v.as_str()) {
            c.policy = PolicyKind::parse(v)?;
        }
        if let Some(v) = j.get("http_port").and_then(|v| v.as_usize()) {
            c.http_port = v;
        }
        if let Some(v) = j.get("trace_out").and_then(|v| v.as_str()) {
            c.trace_out = Some(v.to_string());
        }
        Ok(c)
    }

    /// Effective per-step prefill token budget: the explicit
    /// `prefill_budget`, or (when 0) auto-sized to one full decode batch
    /// (`stream_chunk · max_live`) so intake keeps pace with decode without
    /// ever outweighing it in a single step.
    pub fn resolve_prefill_budget(&self) -> usize {
        if self.prefill_budget > 0 {
            self.prefill_budget
        } else {
            (self.stream_chunk.max(1) * self.max_live.max(1)).max(1)
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_config_file() {
        let dir = std::env::temp_dir().join("savit_cfg_test");
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("cfg.json");
        std::fs::write(&p, r#"{"max_batch": 4, "dispatch": "modularized"}"#).unwrap();
        let c = ServerConfig::from_file(&p).unwrap();
        assert_eq!(c.max_batch, 4);
        assert_eq!(c.dispatch, DispatchMode::Modularized);
        assert_eq!(c.requests, 128); // default preserved
    }

    #[test]
    fn dispatch_mode_parse() {
        assert!(DispatchMode::parse("real").is_ok());
        assert!(DispatchMode::parse("nope").is_err());
    }

    #[test]
    fn backend_kind_parse_and_default() {
        assert_eq!(BackendKind::parse("native").unwrap(), BackendKind::Native);
        assert_eq!(BackendKind::parse("xla").unwrap(), BackendKind::Xla);
        assert!(BackendKind::parse("tpu").is_err());
        assert_eq!(ServerConfig::default().backend, BackendKind::Native);
        assert_eq!(BackendKind::Xla.name(), "xla");
    }

    #[test]
    fn stream_and_planner_fields_parse() {
        let dir = std::env::temp_dir().join("savit_cfg_stream_test");
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("cfg.json");
        std::fs::write(
            &p,
            r#"{"workload": "stream", "stream_tokens": 32, "stream_chunk": 4,
                "max_live": 3, "planner_table": "t.json"}"#,
        )
        .unwrap();
        let c = ServerConfig::from_file(&p).unwrap();
        assert_eq!(c.workload, Workload::Stream);
        assert_eq!(c.stream_tokens, 32);
        assert_eq!(c.stream_chunk, 4);
        assert_eq!(c.max_live, 3);
        assert_eq!(c.planner_table.as_deref(), Some("t.json"));
        assert!(c.planner_table_save.is_none());
        // defaults
        let d = ServerConfig::default();
        assert_eq!(d.workload, Workload::Classify);
        assert!(Workload::parse("nope").is_err());
        assert_eq!(Workload::Stream.name(), "stream");
    }

    #[test]
    fn scheduler_fields_parse_default_and_autosize() {
        let dir = std::env::temp_dir().join("savit_cfg_sched_test");
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("cfg.json");
        std::fs::write(
            &p,
            r#"{"scheduler": "single-phase", "prefill_budget": 24,
                "stream_chunk": 4, "max_live": 3}"#,
        )
        .unwrap();
        let c = ServerConfig::from_file(&p).unwrap();
        assert_eq!(c.scheduler, SchedulerKind::SinglePhase);
        assert_eq!(c.prefill_budget, 24);
        assert_eq!(c.resolve_prefill_budget(), 24, "explicit budget wins");
        // defaults: disaggregated scheduler, budget auto-sized to one full
        // decode batch
        let d = ServerConfig::default();
        assert_eq!(d.scheduler, SchedulerKind::Disaggregated);
        assert_eq!(d.prefill_budget, 0);
        assert_eq!(d.resolve_prefill_budget(), d.stream_chunk * d.max_live);
        assert!(SchedulerKind::parse("nope").is_err());
        assert_eq!(SchedulerKind::Disaggregated.name(), "disaggregated");
    }

    #[test]
    fn fleet_fields_parse_and_default() {
        let dir = std::env::temp_dir().join("savit_cfg_fleet_test");
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("cfg.json");
        std::fs::write(&p, r#"{"workers": 3, "policy": "least-loaded"}"#).unwrap();
        let c = ServerConfig::from_file(&p).unwrap();
        assert_eq!(c.workers, 3);
        assert_eq!(c.policy, PolicyKind::LeastLoaded);
        let d = ServerConfig::default();
        assert_eq!(d.workers, 1);
        assert_eq!(d.policy, PolicyKind::RoundRobin);
    }

    #[test]
    fn http_port_parses_and_defaults_off() {
        let dir = std::env::temp_dir().join("savit_cfg_http_test");
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("cfg.json");
        std::fs::write(&p, r#"{"http_port": 8077}"#).unwrap();
        assert_eq!(ServerConfig::from_file(&p).unwrap().http_port, 8077);
        assert_eq!(ServerConfig::default().http_port, 0, "off by default");
    }

    #[test]
    fn trace_out_parses_and_defaults_off() {
        let dir = std::env::temp_dir().join("savit_cfg_trace_test");
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("cfg.json");
        std::fs::write(&p, r#"{"trace_out": "run.trace.json"}"#).unwrap();
        assert_eq!(
            ServerConfig::from_file(&p).unwrap().trace_out.as_deref(),
            Some("run.trace.json")
        );
        assert!(ServerConfig::default().trace_out.is_none());
    }

    #[test]
    fn bundle_fields_parse() {
        let dir = std::env::temp_dir().join("savit_cfg_bundle_test");
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("cfg.json");
        std::fs::write(&p, r#"{"bundle": "m.sabundle", "bundle_key": "sekrit"}"#).unwrap();
        let c = ServerConfig::from_file(&p).unwrap();
        assert_eq!(c.bundle.as_deref(), Some("m.sabundle"));
        assert_eq!(c.bundle_key.as_deref(), Some("sekrit"));
        let d = ServerConfig::default();
        assert!(d.bundle.is_none());
        assert!(d.bundle_key.is_none());
    }

    #[test]
    fn backend_parsed_from_config_file() {
        let dir = std::env::temp_dir().join("savit_cfg_backend_test");
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("cfg.json");
        std::fs::write(&p, r#"{"backend": "xla"}"#).unwrap();
        assert_eq!(
            ServerConfig::from_file(&p).unwrap().backend,
            BackendKind::Xla
        );
    }
}
