//! The MoE pipeline scheduler: runs one batch through the decomposed
//! serving artifacts with *real* sparse token dispatch between the Mult and
//! Shift experts (Fig. 1(c) at serving time).
//!
//! Stage graph per batch (B images, N tokens, d dims):
//!
//! ```text
//!   stem(B) → [ blk_i_attn(B) → blk_i_premlp(B) → route → ┬ expert_mult ┐
//!                                                         └ expert_shift┘
//!              → scatter+residual ]×depth → head(B) → logits
//! ```
//!
//! Experts execute on dedicated engine workers (one PJRT client each, since
//! the handles are !Send) — truly concurrent in `Real` mode; `Modularized`
//! mode times them separately and charges max() (the paper's "*" rows).

use std::time::Instant;

use anyhow::{anyhow, Result};

use crate::coordinator::config::DispatchMode;
use crate::coordinator::metrics::Metrics;
use crate::moe::dispatch::{self, padding_waste};
use crate::moe::router::{self, EXPERT_MULT, EXPERT_SHIFT};
use crate::runtime::artifact::{Manifest, ServeConfig};
use crate::runtime::tensor::Tensor;
use crate::runtime::worker::{EnginePool, Pending};

// `BatchOutput` moved to the engine-agnostic backend module; re-exported
// here so existing `scheduler::BatchOutput` imports keep compiling.
pub use crate::coordinator::backend::BatchOutput;
use crate::coordinator::backend::{
    InferenceBackend, RequestOutput, RequestQueue, StepReport, Ticket,
};
use crate::coordinator::batcher::Request;

/// The pipeline over `serve_*` artifacts.
pub struct MoePipeline {
    pub serve: ServeConfig,
    pool: EnginePool,
    pub mode: DispatchMode,
    /// request-level bookkeeping for the submit/step/poll contract
    queue: RequestQueue,
}

/// worker 0: backbone; worker 1: Mult expert; worker 2: Shift expert.
const W_BACKBONE: usize = 0;
const W_MULT: usize = 1;
const W_SHIFT: usize = 2;

impl MoePipeline {
    pub fn new(manifest: &Manifest, mode: DispatchMode) -> Result<MoePipeline> {
        let serve = manifest
            .serve
            .clone()
            .ok_or_else(|| anyhow!("manifest has no serving topology — rebuild artifacts"))?;
        let pool = EnginePool::new(3, manifest);
        Ok(MoePipeline {
            serve,
            pool,
            mode,
            queue: RequestQueue::new(),
        })
    }

    /// Pre-compile every pipeline artifact on its worker (keeps compile time
    /// out of the measured hot path).
    pub fn warmup(&self) -> Result<()> {
        let s = &self.serve;
        let mut backbone = Vec::new();
        for &b in &s.batch_buckets {
            backbone.push(format!("serve_stem_bs{b}"));
            backbone.push(format!("serve_head_bs{b}"));
            for i in 0..s.depth {
                backbone.push(format!("serve_blk{i}_attn_bs{b}"));
                backbone.push(format!("serve_blk{i}_premlp_bs{b}"));
            }
        }
        self.pool.worker(W_BACKBONE).preload(&backbone)?;
        let mut mult = Vec::new();
        let mut shift = Vec::new();
        for i in 0..s.depth {
            for &nb in &s.token_buckets {
                mult.push(format!("serve_expert_mult_blk{i}_n{nb}"));
                shift.push(format!("serve_expert_shift_blk{i}_n{nb}"));
            }
        }
        self.pool.worker(W_MULT).preload(&mult)?;
        self.pool.worker(W_SHIFT).preload(&shift)?;
        Ok(())
    }

    fn batch_bucket(&self, n: usize) -> Result<usize> {
        self.serve
            .batch_buckets
            .iter()
            .copied()
            .find(|&b| b >= n)
            .ok_or_else(|| anyhow!("batch {n} exceeds largest compiled bucket"))
    }

    /// Run one batch of images (flattened HWC f32, `n` images).
    pub fn run_batch(
        &self,
        images: &[f32],
        n: usize,
        metrics: &mut Metrics,
    ) -> Result<BatchOutput> {
        let s = &self.serve;
        let px = s.img * s.img * 3;
        assert_eq!(images.len(), n * px);
        let t_batch = Instant::now();
        let mut modularized_ms = 0.0f64;

        // Pad the image batch to a compiled bucket.
        let b = self.batch_bucket(n)?;
        let mut padded = vec![0.0f32; b * px];
        padded[..n * px].copy_from_slice(images);
        let x = Tensor::f32(vec![b, s.img, s.img, 3], padded);

        let backbone = self.pool.worker(W_BACKBONE);
        let t0 = Instant::now();
        let mut t = backbone
            .call(&format!("serve_stem_bs{b}"), vec![x])?
            .remove(0);
        let stem_ms = ms_since(t0);
        metrics.record("stem", stem_ms);
        modularized_ms += stem_ms;

        let mut dispatch_mask_blk0 = Vec::new();
        for i in 0..s.depth {
            // --- attention sublayer ------------------------------------
            let t0 = Instant::now();
            t = backbone
                .call(&format!("serve_blk{i}_attn_bs{b}"), vec![t])?
                .remove(0);
            let attn_ms = ms_since(t0);
            metrics.record(&format!("blk{i}_attn"), attn_ms);
            modularized_ms += attn_ms;

            // --- pre-MLP: LN + router gates ------------------------------
            let t0 = Instant::now();
            let mut out = backbone.call(&format!("serve_blk{i}_premlp_bs{b}"), vec![t.clone()])?;
            let u = out.remove(0); // (b, N, d) normalized tokens
            let gates = out.remove(0); // (b, N, 2)
            let premlp_ms = ms_since(t0);
            metrics.record(&format!("blk{i}_premlp"), premlp_ms);
            modularized_ms += premlp_ms;

            // --- route (only the n real images' tokens) -----------------
            let tokens_per_img = s.tokens;
            let total_tokens = n * tokens_per_img;
            let routes = router::route(&gates.as_f32()?[..total_tokens * 2], 2);
            metrics.expert_tokens[EXPERT_MULT] +=
                routes.iter().filter(|r| r.expert == EXPERT_MULT).count();
            metrics.expert_tokens[EXPERT_SHIFT] +=
                routes.iter().filter(|r| r.expert == EXPERT_SHIFT).count();
            for g in gates.as_f32()?[..total_tokens * 2].chunks(2) {
                metrics.expert_gates[0] += g[0] as f64;
                metrics.expert_gates[1] += g[1] as f64;
            }
            if i == 0 {
                for img in 0..n {
                    dispatch_mask_blk0.push(
                        routes[img * tokens_per_img..(img + 1) * tokens_per_img]
                            .iter()
                            .map(|r| r.expert == EXPERT_MULT)
                            .collect(),
                    );
                }
            }

            // --- dispatch -------------------------------------------------
            let u_flat = &u.as_f32()?[..total_tokens * s.dim];
            let mut parts =
                dispatch::partition(u_flat, s.dim, &routes, 2, &s.token_buckets);
            metrics.padding_waste.record(padding_waste(&parts));

            let mut y = vec![0.0f32; total_tokens * s.dim];
            let t0 = Instant::now();
            match self.mode {
                DispatchMode::Real => {
                    // Submit every partition to its expert worker, then sync.
                    // `padded` buffers are MOVED into the worker messages —
                    // no per-partition clone on the hot path (§Perf L3-2).
                    let pend: Vec<(usize, Pending)> = parts
                        .iter_mut()
                        .map(|p| {
                            let w = if p.expert == EXPERT_MULT { W_MULT } else { W_SHIFT };
                            let name = self.expert_name(i, p.expert, p.bucket);
                            let padded = std::mem::take(&mut p.padded);
                            (
                                p.expert,
                                self.pool.worker(w).call_async(
                                    &name,
                                    vec![Tensor::f32(vec![p.bucket, s.dim], padded)],
                                ),
                            )
                        })
                        .collect();
                    for ((_e, pnd), part) in pend.into_iter().zip(&parts) {
                        let out = pnd.wait()?.remove(0);
                        dispatch::scatter(&mut y, s.dim, part, out.as_f32()?, &routes);
                    }
                    let real_ms = ms_since(t0);
                    metrics.record(&format!("blk{i}_moe"), real_ms);
                    modularized_ms += real_ms;
                }
                DispatchMode::Modularized => {
                    // Sequential execution, charged max(per-expert time).
                    let mut per_expert = [0.0f64; 2];
                    for part in &mut parts {
                        let w = if part.expert == EXPERT_MULT { W_MULT } else { W_SHIFT };
                        let name = self.expert_name(i, part.expert, part.bucket);
                        let padded = std::mem::take(&mut part.padded);
                        let te = Instant::now();
                        let out = self.pool.worker(w).call(
                            &name,
                            vec![Tensor::f32(vec![part.bucket, s.dim], padded)],
                        )?;
                        per_expert[part.expert] += ms_since(te);
                        dispatch::scatter(&mut y, s.dim, part, out[0].as_f32()?, &routes);
                    }
                    metrics.expert_times[0].record(per_expert[0]);
                    metrics.expert_times[1].record(per_expert[1]);
                    let charged = per_expert[0].max(per_expert[1]);
                    metrics.record(&format!("blk{i}_moe"), charged);
                    modularized_ms += charged;
                }
                DispatchMode::Dense => {
                    // PVT+MoE baseline: all tokens through BOTH experts.
                    for expert in [EXPERT_MULT, EXPERT_SHIFT] {
                        let all: Vec<_> = (0..total_tokens)
                            .map(|ti| crate::moe::router::Route {
                                expert,
                                gate: routes[ti].gate
                                    * if routes[ti].expert == expert { 1.0 } else { 0.0 },
                            })
                            .collect();
                        let dense_parts =
                            dispatch::partition(u_flat, s.dim, &all, 2, &s.token_buckets);
                        for part in &dense_parts {
                            let w = if expert == EXPERT_MULT { W_MULT } else { W_SHIFT };
                            let name = self.expert_name(i, expert, part.bucket);
                            let out = self.pool.worker(w).call(
                                &name,
                                vec![Tensor::f32(vec![part.bucket, s.dim], part.padded.clone())],
                            )?;
                            // scatter adds gated output; gate=0 rows add 0
                            let mut tmp = vec![0.0f32; total_tokens * s.dim];
                            dispatch::scatter(&mut tmp, s.dim, part, out[0].as_f32()?, &all);
                            for (yy, tt) in y.iter_mut().zip(&tmp) {
                                *yy += *tt;
                            }
                        }
                    }
                    let dense_ms = ms_since(t0);
                    metrics.record(&format!("blk{i}_moe"), dense_ms);
                    modularized_ms += dense_ms;
                }
            }

            // --- residual add (padded rows stay as-is; they are discarded) -
            let tdata = t.as_f32_mut()?;
            for (ti, yv) in y.iter().enumerate() {
                tdata[ti] += yv;
            }
        }

        let t0 = Instant::now();
        let logits_full = backbone
            .call(&format!("serve_head_bs{b}"), vec![t])?
            .remove(0);
        let head_ms = ms_since(t0);
        metrics.record("head", head_ms);
        modularized_ms += head_ms;

        // Slice off padded images.
        let nc = s.num_classes;
        let logits = Tensor::f32(
            vec![n, nc],
            logits_full.as_f32()?[..n * nc].to_vec(),
        );
        metrics.batches += 1;
        metrics.requests += n;
        Ok(BatchOutput {
            logits,
            dispatch_mask_blk0,
            batch_ms: ms_since(t_batch),
            modularized_ms,
        })
    }

    fn expert_name(&self, blk: usize, expert: usize, bucket: usize) -> String {
        let e = if expert == EXPERT_MULT { "mult" } else { "shift" };
        format!("serve_expert_{e}_blk{blk}_n{bucket}")
    }
}

impl InferenceBackend for MoePipeline {
    fn name(&self) -> String {
        format!("xla ({}, {:?})", self.serve.model, self.mode)
    }

    fn img(&self) -> usize {
        self.serve.img
    }

    fn tokens(&self) -> usize {
        self.serve.tokens
    }

    fn num_classes(&self) -> usize {
        self.serve.num_classes
    }

    fn warmup(&self) -> Result<()> {
        MoePipeline::warmup(self)
    }

    fn submit(&self, request: Request) -> Ticket {
        self.queue.submit(request)
    }

    fn queued(&self) -> usize {
        self.queue.queued()
    }

    fn step(&self, max_batch: usize, metrics: &mut Metrics) -> Result<StepReport> {
        let batch = self.queue.take(max_batch.max(1));
        if batch.is_empty() {
            return Ok(StepReport::default());
        }
        let n = batch.len();
        let px = self.serve.img * self.serve.img * 3;
        let mut pixels = Vec::with_capacity(n * px);
        for (_, r) in &batch {
            pixels.extend_from_slice(&r.pixels);
        }
        let out = MoePipeline::run_batch(self, &pixels, n, metrics)?;
        metrics.record_step_occupancy(n, max_batch.max(1), n * self.serve.tokens);
        for (_, r) in &batch {
            metrics.push_request_id(r.id);
        }
        let rep = StepReport {
            served: n,
            batch_ms: out.batch_ms,
            modularized_ms: out.modularized_ms,
        };
        self.queue.complete(batch, &out)?;
        Ok(rep)
    }

    fn poll(&self, ticket: &Ticket) -> Option<RequestOutput> {
        self.queue.poll(ticket)
    }
}

fn ms_since(t: Instant) -> f64 {
    t.elapsed().as_secs_f64() * 1e3
}
