//! Dynamic batcher: groups incoming requests into batches bounded by
//! `max_batch` and a fill deadline — the standard serving trade-off between
//! throughput (bigger batches) and tail latency (shorter waits).

use std::sync::mpsc;
use std::time::{Duration, Instant};

use crate::obs::trace::TraceCtx;

/// One inference request. `Clone` exists for the fleet router, which keeps
/// a copy of every in-flight request so work stranded on a dead worker can
/// be resubmitted.
#[derive(Debug, Clone)]
pub struct Request {
    pub id: usize,
    /// flattened HWC image
    pub pixels: Vec<f32>,
    /// ground-truth label (for online accuracy accounting); None in prod
    pub label: Option<usize>,
    pub arrived: Instant,
    /// tracing context of the ingress span that admitted this request
    /// ([`TraceCtx::NONE`] when untraced): placement, worker-step, and
    /// kernel-dispatch spans parent on it across thread hops
    pub trace: TraceCtx,
}

/// A formed batch.
#[derive(Debug)]
pub struct Batch {
    pub requests: Vec<Request>,
}

impl Batch {
    pub fn len(&self) -> usize {
        self.requests.len()
    }

    pub fn is_empty(&self) -> bool {
        self.requests.is_empty()
    }

    /// Concatenate request pixels into one buffer.
    pub fn pixels(&self) -> Vec<f32> {
        let mut out = Vec::with_capacity(self.requests.len() * self.requests[0].pixels.len());
        for r in &self.requests {
            out.extend_from_slice(&r.pixels);
        }
        out
    }
}

/// Pull requests from `rx` into batches.
pub struct Batcher {
    pub max_batch: usize,
    pub deadline: Duration,
}

impl Batcher {
    pub fn new(max_batch: usize, deadline_ms: f64) -> Batcher {
        Batcher {
            max_batch: max_batch.max(1),
            deadline: Duration::from_secs_f64(deadline_ms / 1e3),
        }
    }

    /// Form the next batch. Blocks for the first request; then fills until
    /// `max_batch` or the deadline since the first arrival. Returns None when
    /// the channel is closed and drained.
    pub fn next_batch(&self, rx: &mpsc::Receiver<Request>) -> Option<Batch> {
        let first = rx.recv().ok()?;
        let t0 = Instant::now();
        let mut requests = vec![first];
        while requests.len() < self.max_batch {
            let left = self.deadline.saturating_sub(t0.elapsed());
            if left.is_zero() {
                break;
            }
            match rx.recv_timeout(left) {
                Ok(r) => requests.push(r),
                Err(mpsc::RecvTimeoutError::Timeout) => break,
                Err(mpsc::RecvTimeoutError::Disconnected) => break,
            }
        }
        Some(Batch { requests })
    }

    /// Non-blocking intake for continuous stepping: take whatever is
    /// already waiting, up to `max_batch`, without honoring the fill
    /// deadline. Returns an empty batch when nothing is pending — callers
    /// driving a request-level engine submit these between `step()`s so
    /// late arrivals join the next fused batch instead of waiting out a
    /// full batching window.
    pub fn drain_ready(&self, rx: &mpsc::Receiver<Request>) -> Batch {
        let mut requests = Vec::new();
        while requests.len() < self.max_batch {
            match rx.try_recv() {
                Ok(r) => requests.push(r),
                Err(_) => break,
            }
        }
        Batch { requests }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn req(id: usize) -> Request {
        Request {
            id,
            pixels: vec![id as f32; 4],
            label: None,
            arrived: Instant::now(),
            trace: TraceCtx::NONE,
        }
    }

    #[test]
    fn fills_to_max_batch_without_waiting() {
        let (tx, rx) = mpsc::channel();
        for i in 0..5 {
            tx.send(req(i)).unwrap();
        }
        let b = Batcher::new(4, 50.0).next_batch(&rx).unwrap();
        assert_eq!(b.len(), 4);
        assert_eq!(b.requests[0].id, 0);
    }

    #[test]
    fn deadline_flushes_partial_batch() {
        let (tx, rx) = mpsc::channel();
        tx.send(req(0)).unwrap();
        let t0 = Instant::now();
        let b = Batcher::new(8, 5.0).next_batch(&rx).unwrap();
        assert_eq!(b.len(), 1);
        assert!(t0.elapsed() >= Duration::from_millis(4));
    }

    #[test]
    fn closed_channel_returns_none_after_drain() {
        let (tx, rx) = mpsc::channel();
        tx.send(req(0)).unwrap();
        drop(tx);
        let batcher = Batcher::new(2, 1.0);
        assert_eq!(batcher.next_batch(&rx).unwrap().len(), 1);
        assert!(batcher.next_batch(&rx).is_none());
    }

    #[test]
    fn drain_ready_never_blocks() {
        let (tx, rx) = mpsc::channel();
        let b = Batcher::new(4, 1000.0);
        assert!(b.drain_ready(&rx).is_empty(), "empty channel, empty batch");
        for i in 0..6 {
            tx.send(req(i)).unwrap();
        }
        let t0 = Instant::now();
        let first = b.drain_ready(&rx);
        assert_eq!(first.len(), 4, "caps at max_batch");
        assert_eq!(b.drain_ready(&rx).len(), 2);
        assert!(t0.elapsed() < Duration::from_millis(500), "must ignore the fill deadline");
    }

    #[test]
    fn pixels_concatenate_in_order() {
        let b = Batch {
            requests: vec![req(1), req(2)],
        };
        let px = b.pixels();
        assert_eq!(px[0], 1.0);
        assert_eq!(px[4], 2.0);
    }
}
