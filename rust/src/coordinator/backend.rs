//! The engine-agnostic serving contract, redesigned around **requests**:
//! callers `submit(Request) -> Ticket`, drive execution with `step`
//! (which packs up to `max_batch` queued requests into ONE fused engine
//! batch), and collect results with `poll(Ticket)`. Two engines ship:
//!
//! - [`crate::coordinator::scheduler::MoePipeline`] — the AOT-compiled HLO
//!   artifact pipeline on the PJRT engine pool (requires `make artifacts`);
//! - [`NativeBackend`] — the pure-Rust [`crate::infer`] engine (zero
//!   artifacts, runs out of the box).
//!
//! The old one-shot [`InferenceBackend::run_batch`] survives as a default
//! trait method — a thin adapter that submits every image as a request,
//! steps the queue dry, and reassembles the batch output — so existing
//! callers and tests keep working on top of the request path.
//!
//! [`create_backend`] resolves a [`ServerConfig`]'s `backend` field to a
//! boxed implementation; it is the single construction path, so planner
//! lookup tables (`planner_table`) and backend flags apply uniformly.
//! Token-*streaming* requests take the session route instead
//! ([`crate::coordinator::sessions::SessionEngine`]).

use std::collections::{HashMap, VecDeque};
use std::path::Path;
use std::sync::{Arc, Mutex};
use std::time::Instant;

use anyhow::{anyhow, Result};

use crate::bundle::archive::{self, LoadedBundle};
use crate::bundle::sign;
use crate::coordinator::batcher::Request;
use crate::coordinator::config::{BackendKind, DispatchMode, ServerConfig};
use crate::coordinator::metrics::Metrics;
use crate::coordinator::scheduler::MoePipeline;
use crate::infer::model::{NativeModel, NativeModelConfig};
use crate::kernels::planner::{Choice, Planner};
use crate::kernels::registry::KernelRegistry;
use crate::log_warn;
use crate::model::ops::Variant;
use crate::obs::trace::{self as otrace, TraceCtx};
use crate::runtime::artifact::Manifest;
use crate::runtime::tensor::Tensor;
use crate::util::json::Json;

/// Result of one batch, whichever engine produced it.
pub struct BatchOutput {
    pub logits: Tensor,
    /// per-image routed-to-Mult token masks of the FIRST MoE block (for the
    /// Fig. 6/9 visualisation)
    pub dispatch_mask_blk0: Vec<Vec<bool>>,
    pub batch_ms: f64,
    /// makespan the batch *would* have under ideal parallelism (paper "*")
    pub modularized_ms: f64,
}

/// Handle to a submitted request.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Ticket {
    pub id: usize,
}

/// Completed result of one request.
#[derive(Clone, Debug)]
pub struct RequestOutput {
    /// ticket id (engine-assigned)
    pub id: usize,
    /// caller-supplied request id
    pub request_id: usize,
    pub logits: Vec<f32>,
    /// routed-to-Mult token mask of the first MoE block (may be empty)
    pub dispatch_mask_blk0: Vec<bool>,
    /// wall-clock of the fused batch that served this request
    pub batch_ms: f64,
    pub modularized_ms: f64,
    /// how many requests shared that batch (occupancy)
    pub batch_size: usize,
    pub arrived: Instant,
    /// when the serving step completed this request (latency = finished − arrived)
    pub finished: Instant,
    pub label: Option<usize>,
}

impl RequestOutput {
    pub fn latency_ms(&self) -> f64 {
        self.finished.duration_since(self.arrived).as_secs_f64() * 1e3
    }
}

/// Outcome of one [`InferenceBackend::step`].
#[derive(Clone, Copy, Debug, Default)]
pub struct StepReport {
    /// requests served this step (0 = queue was empty)
    pub served: usize,
    pub batch_ms: f64,
    pub modularized_ms: f64,
}

/// Completed outputs a queue holds before it starts warning that nobody
/// is polling. A serving loop that polls promptly never comes near this;
/// a caller that submits and walks away grows the done map (every output
/// holds a logits vector), so the queue complains loudly past this point.
pub const DEFAULT_DONE_CAP: usize = 4096;

/// Shared submit/poll bookkeeping every backend embeds: a pending queue and
/// a done map behind one mutex, so the trait methods stay `&self`.
///
/// The done side never drops a completed output: a ticket whose work
/// finished always polls successfully, however late the caller is —
/// evicting unpolled outputs (the pre-PR-9 behavior) made `poll_wait` spin
/// to timeout on requests that had actually completed. Instead the map
/// grows, with a loud rate-limited warning each time it doubles past
/// `done_cap`, so an abandoning caller is diagnosed rather than silently
/// served result loss.
#[derive(Default)]
pub struct RequestQueue {
    inner: Mutex<QueueInner>,
}

struct QueueInner {
    pending: VecDeque<(usize, Request)>,
    done: HashMap<usize, RequestOutput>,
    done_cap: usize,
    /// next done-map size that triggers a leak warning (doubles each time)
    warn_at: usize,
    next_id: usize,
}

impl Default for QueueInner {
    fn default() -> Self {
        QueueInner {
            pending: VecDeque::new(),
            done: HashMap::new(),
            done_cap: DEFAULT_DONE_CAP,
            warn_at: DEFAULT_DONE_CAP,
            next_id: 0,
        }
    }
}

impl RequestQueue {
    pub fn new() -> RequestQueue {
        RequestQueue::default()
    }

    /// A queue with a custom warn threshold (tests use tiny caps to
    /// exercise the leak warning).
    pub fn with_done_cap(cap: usize) -> RequestQueue {
        let q = RequestQueue::default();
        let mut inner = q.inner.lock().unwrap();
        inner.done_cap = cap.max(1);
        inner.warn_at = cap.max(1);
        drop(inner);
        q
    }

    pub fn submit(&self, request: Request) -> Ticket {
        let mut q = self.inner.lock().unwrap();
        let id = q.next_id;
        q.next_id += 1;
        q.pending.push_back((id, request));
        Ticket { id }
    }

    pub fn queued(&self) -> usize {
        self.inner.lock().unwrap().pending.len()
    }

    /// Completed-but-unpolled outputs currently held.
    pub fn done_len(&self) -> usize {
        self.inner.lock().unwrap().done.len()
    }

    /// Dequeue up to `max` requests (FIFO) for one fused batch.
    pub fn take(&self, max: usize) -> Vec<(usize, Request)> {
        let mut q = self.inner.lock().unwrap();
        let n = q.pending.len().min(max);
        q.pending.drain(..n).collect()
    }

    /// File per-request outputs sliced out of one batch result, stamping
    /// each with the step's completion time. Completed outputs are kept
    /// until polled — if the map outgrows its cap, the caller is leaking
    /// tickets, and the queue says so (once per doubling) instead of
    /// losing results.
    pub fn complete(&self, batch: Vec<(usize, Request)>, out: &BatchOutput) -> Result<()> {
        let n = batch.len();
        let logits = out.logits.as_f32()?;
        let nc = logits.len() / n.max(1);
        let finished = Instant::now();
        let mut q = self.inner.lock().unwrap();
        for (i, (id, req)) in batch.into_iter().enumerate() {
            q.done.insert(
                id,
                RequestOutput {
                    id,
                    request_id: req.id,
                    logits: logits[i * nc..(i + 1) * nc].to_vec(),
                    dispatch_mask_blk0: out
                        .dispatch_mask_blk0
                        .get(i)
                        .cloned()
                        .unwrap_or_default(),
                    batch_ms: out.batch_ms,
                    modularized_ms: out.modularized_ms,
                    batch_size: n,
                    arrived: req.arrived,
                    finished,
                    label: req.label,
                },
            );
        }
        if q.done.len() > q.warn_at {
            log_warn!(
                "request queue: {} completed outputs held and nobody is polling \
                 (warn threshold {}); results are kept — poll your tickets",
                q.done.len(),
                q.done_cap
            );
            q.warn_at = q.warn_at.saturating_mul(2).max(q.done.len());
        }
        Ok(())
    }

    /// Remove and return a finished request's output, if ready.
    pub fn poll(&self, ticket: &Ticket) -> Option<RequestOutput> {
        self.inner.lock().unwrap().done.remove(&ticket.id)
    }
}

/// One inference engine behind the coordinator, under the request-level
/// contract: `submit` enqueues, `step` executes one fused batch over queued
/// requests, `poll` collects. Implementations record per-stage latency,
/// expert-load, and batch-occupancy diagnostics into the shared
/// [`Metrics`].
pub trait InferenceBackend {
    /// Short engine label for reports ("native", "xla").
    fn name(&self) -> String;

    /// Input image side length (pixels).
    fn img(&self) -> usize;

    /// Tokens per image in the first (routed) stage — the Fig. 6/9 mask
    /// grid size.
    fn tokens(&self) -> usize;

    fn num_classes(&self) -> usize;

    /// One-time warm-up (compiles artifacts / runs the planner) — keeps
    /// first-request latency out of the measured path.
    fn warmup(&self) -> Result<()>;

    /// Enqueue one request.
    fn submit(&self, request: Request) -> Ticket;

    /// Requests waiting for a step.
    fn queued(&self) -> usize;

    /// Execute ONE fused batch over up to `max_batch` queued requests.
    /// Returns `served == 0` when the queue was empty.
    fn step(&self, max_batch: usize, metrics: &mut Metrics) -> Result<StepReport>;

    /// Remove and return a finished request's output, if ready.
    fn poll(&self, ticket: &Ticket) -> Option<RequestOutput>;

    /// Planner decisions made so far (native engines only) — the source of
    /// offline-autotuned lookup tables. Default: none.
    fn planner_choices(&self) -> Vec<Choice> {
        Vec::new()
    }

    /// One-shot batch API, kept as a thin adapter over submit/step/poll so
    /// pre-redesign callers and tests keep working.
    fn run_batch(&self, images: &[f32], n: usize, metrics: &mut Metrics) -> Result<BatchOutput> {
        assert!(n > 0, "run_batch needs at least one image");
        let px = self.img() * self.img() * 3;
        assert_eq!(images.len(), n * px, "image buffer is not n·img²·3");
        let tickets: Vec<Ticket> = (0..n)
            .map(|i| {
                self.submit(Request {
                    id: i,
                    pixels: images[i * px..(i + 1) * px].to_vec(),
                    label: None,
                    arrived: Instant::now(),
                    trace: otrace::current(),
                })
            })
            .collect();
        let mut batch_ms = 0.0f64;
        let mut modularized_ms = 0.0f64;
        while self.queued() > 0 {
            let rep = self.step(n, metrics)?;
            if rep.served == 0 {
                anyhow::bail!("step() made no progress with {} queued", self.queued());
            }
            batch_ms += rep.batch_ms;
            modularized_ms += rep.modularized_ms;
        }
        let nc = self.num_classes();
        let mut logits = vec![0.0f32; n * nc];
        let mut masks = Vec::new();
        for (i, t) in tickets.iter().enumerate() {
            let out = self
                .poll(t)
                .ok_or_else(|| anyhow!("request {i} not completed by step()"))?;
            logits[i * nc..(i + 1) * nc].copy_from_slice(&out.logits);
            if !out.dispatch_mask_blk0.is_empty() {
                masks.push(out.dispatch_mask_blk0);
            }
        }
        Ok(BatchOutput {
            logits: Tensor::f32(vec![n, nc], logits),
            dispatch_mask_blk0: masks,
            batch_ms,
            modularized_ms,
        })
    }
}

/// The native pure-Rust engine behind the [`InferenceBackend`] contract.
pub struct NativeBackend {
    pub model: NativeModel,
    queue: RequestQueue,
}

impl NativeBackend {
    /// The tiny serving analogue under the paper's full reparameterization
    /// (LinearAdd attention + shift linears + Mult/Shift MoE).
    pub fn tiny(variant: Variant) -> NativeBackend {
        NativeBackend {
            model: NativeModel::tiny(variant),
            queue: RequestQueue::new(),
        }
    }

    pub fn from_config(cfg: NativeModelConfig) -> NativeBackend {
        let planner = Arc::new(Planner::new(Arc::new(KernelRegistry::with_defaults())));
        NativeBackend::with_planner(cfg, planner)
    }

    /// Build on an externally prepared planner (e.g. one pre-pinned from an
    /// offline-autotuned lookup table).
    pub fn with_planner(cfg: NativeModelConfig, planner: Arc<Planner>) -> NativeBackend {
        NativeBackend::from_model(NativeModel::new(cfg, planner))
    }

    /// Wrap an already-built model (e.g. one warm-started from bundle
    /// params via [`NativeModel::from_params`]).
    pub fn from_model(model: NativeModel) -> NativeBackend {
        NativeBackend {
            model,
            queue: RequestQueue::new(),
        }
    }
}

impl InferenceBackend for NativeBackend {
    fn name(&self) -> String {
        format!("native ({})", self.model.cfg.spec.name)
    }

    fn img(&self) -> usize {
        self.model.cfg.img
    }

    fn tokens(&self) -> usize {
        self.model.tokens()
    }

    fn num_classes(&self) -> usize {
        self.model.cfg.num_classes
    }

    fn warmup(&self) -> Result<()> {
        // One bs-1 forward settles the planner's backend choices and the
        // worker pool spawn before anything is timed.
        let zeros = vec![0.0f32; self.img() * self.img() * 3];
        self.model.forward(&zeros, 1);
        Ok(())
    }

    fn submit(&self, request: Request) -> Ticket {
        self.queue.submit(request)
    }

    fn queued(&self) -> usize {
        self.queue.queued()
    }

    fn step(&self, max_batch: usize, metrics: &mut Metrics) -> Result<StepReport> {
        let batch = self.queue.take(max_batch.max(1));
        if batch.is_empty() {
            return Ok(StepReport::default());
        }
        let n = batch.len();
        let px = self.img() * self.img() * 3;
        let mut pixels = Vec::with_capacity(n * px);
        for (_, r) in &batch {
            pixels.extend_from_slice(&r.pixels);
        }

        // The step span parents on the first traced request in the batch
        // (requests that joined an already-traced batch show up in its
        // `request_ids` arg); kernel dispatches deeper in the forward pass
        // parent on this span through the thread-local ambient context.
        let parent = batch
            .iter()
            .map(|(_, r)| r.trace)
            .find(|t| t.is_active())
            .unwrap_or(TraceCtx::NONE);
        let t0 = Instant::now();
        let (logits, trace) = {
            let mut span = otrace::span("backend_step", parent);
            if otrace::enabled() {
                span.arg("batch", n.to_string());
                let ids: Vec<String> = batch.iter().map(|(_, r)| r.id.to_string()).collect();
                span.arg("request_ids", ids.join(","));
            }
            let _cur = otrace::set_current(span.ctx());
            self.model.forward(&pixels, n)
        };
        let batch_ms = t0.elapsed().as_secs_f64() * 1e3;
        for (name, ms) in &trace.stage_ms {
            metrics.record(name, *ms);
        }
        metrics.expert_tokens[0] += trace.expert_tokens[0];
        metrics.expert_tokens[1] += trace.expert_tokens[1];
        metrics.expert_gates[0] += trace.gate_sums[0];
        metrics.expert_gates[1] += trace.gate_sums[1];
        // Modularized accounting (paper "*"): experts ran sequentially in
        // the native engine, so the ideal-parallel makespan replaces each
        // MoE layer's e0+e1 with max(e0, e1).
        let mut modularized_ms = batch_ms;
        for [e0, e1] in &trace.expert_ms {
            metrics.expert_times[0].record(*e0);
            metrics.expert_times[1].record(*e1);
            modularized_ms -= e0.min(*e1);
        }
        for &w in &trace.padding_waste {
            metrics.padding_waste.record(w);
        }
        metrics.batches += 1;
        metrics.requests += n;
        for (_, r) in &batch {
            metrics.push_request_id(r.id);
        }
        metrics.record_step_occupancy(n, max_batch.max(1), n * self.tokens());
        if trace.blocks > 0 {
            // Fused-path amortization gauge: attention kernel calls per
            // block layer for this step's batch (2 grouped calls however
            // many requests were fused; the per-image path would pay
            // b·heads·4 plain calls).
            metrics
                .attn_dispatches_per_layer
                .record(trace.attn_dispatches as f64 / trace.blocks as f64);
        }

        let out = BatchOutput {
            logits: Tensor::f32(vec![n, self.num_classes()], logits),
            dispatch_mask_blk0: trace.mask_blk0,
            batch_ms,
            modularized_ms,
        };
        self.queue.complete(batch, &out)?;
        Ok(StepReport {
            served: n,
            batch_ms,
            modularized_ms,
        })
    }

    fn poll(&self, ticket: &Ticket) -> Option<RequestOutput> {
        self.queue.poll(ticket)
    }

    fn planner_choices(&self) -> Vec<Choice> {
        self.model.planner.choices()
    }
}

/// Resolve the configured backend — the single construction path for every
/// caller (`serve_auto`, examples, benches), so `--backend` and planner
/// lookup tables apply uniformly. `Native` needs nothing on disk; `Xla`
/// loads the artifact manifest (fails fast with the usual
/// "run `make artifacts`" context when absent).
pub fn create_backend(cfg: &ServerConfig) -> Result<Box<dyn InferenceBackend>> {
    let bundle = load_bundle(cfg)?;
    create_backend_with(cfg, bundle.as_deref(), None)
}

/// Verify the configured `.sabundle` once — signature over the manifest
/// digest, then every entry's content hash — and load it. Returns `None`
/// when no bundle is configured. The fleet factories call this before any
/// worker spawns, so a tampered bundle is rejected up front.
pub fn load_bundle(cfg: &ServerConfig) -> Result<Option<Arc<LoadedBundle>>> {
    let path = match &cfg.bundle {
        Some(p) => p,
        None => return Ok(None),
    };
    if cfg.backend != BackendKind::Native {
        anyhow::bail!(
            "--bundle needs the native backend (the xla path bakes \
             weights into its artifacts)"
        );
    }
    if cfg.planner_table.is_some() {
        anyhow::bail!(
            "--bundle and --planner-table are mutually exclusive \
             (the bundle pins its own table)"
        );
    }
    let key = cfg.bundle_key.as_deref().unwrap_or(sign::DEFAULT_KEY);
    let b = archive::open(Path::new(path), key.as_bytes())?;
    println!(
        "bundle: verified {path} (model {}, {} weights, cpu_features {}) digest {}",
        b.model,
        if b.untrained { "seeded-untrained" } else { "trained" },
        b.cpu_features,
        b.digest
    );
    Ok(Some(Arc::new(b)))
}

/// Like [`create_backend`], but taking an already-verified bundle and/or a
/// pre-serialized planner table to pin. Fleet factories verify the bundle
/// once, autotune once, and hand every worker the same `(bundle, table)`
/// pair so workers never re-verify or re-benchmark.
pub fn create_backend_with(
    cfg: &ServerConfig,
    bundle: Option<&LoadedBundle>,
    pinned_table: Option<&str>,
) -> Result<Box<dyn InferenceBackend>> {
    match cfg.backend {
        BackendKind::Native => {
            // The native engine always executes real sparse dispatch (and
            // reports modularized accounting alongside); the dispatch-mode
            // comparison (real/modularized/dense) is an XLA-pipeline
            // experiment — fail loudly instead of measuring the wrong thing.
            if cfg.dispatch != DispatchMode::Real {
                anyhow::bail!(
                    "dispatch mode {:?} needs the xla backend (--backend xla); \
                     the native engine always runs real sparse dispatch",
                    cfg.dispatch
                );
            }
            let planner = match pinned_table {
                Some(text) => {
                    // A fleet worker: pin the factory's table silently (the
                    // factory already printed the shared-table line).
                    let reg = Arc::new(KernelRegistry::with_defaults());
                    let planner = Arc::new(Planner::new(reg));
                    planner.pin_table_json(&Json::parse(text)?)?;
                    planner
                }
                None => {
                    let planner = create_planner(cfg)?;
                    if let Some(b) = bundle {
                        let pinned = planner.pin_table_json(&b.table)?;
                        println!("bundle: pinned {pinned} planner choices from the bundle");
                    }
                    planner
                }
            };
            let model_cfg = NativeModelConfig::tiny(Variant::SHIFTADD_MOE);
            let model = match bundle {
                Some(b) => {
                    if b.model != model_cfg.spec.name {
                        anyhow::bail!(
                            "bundle is for model '{}', this server runs '{}'",
                            b.model,
                            model_cfg.spec.name
                        );
                    }
                    NativeModel::from_params(model_cfg, planner, &b.params)?
                }
                None => NativeModel::new(model_cfg, planner),
            };
            Ok(Box::new(NativeBackend::from_model(model)))
        }
        BackendKind::Xla => {
            let manifest = Manifest::load(&Manifest::default_dir())?;
            Ok(Box::new(MoePipeline::new(&manifest, cfg.dispatch)?))
        }
    }
}

/// Build the planner every native engine (image or streaming) shares:
/// default registry, plus pinned choices from the configured offline
/// lookup table so no first-request benchmarking happens.
pub fn create_planner(cfg: &ServerConfig) -> Result<Arc<Planner>> {
    let planner = Arc::new(Planner::new(Arc::new(KernelRegistry::with_defaults())));
    if let Some(path) = &cfg.planner_table {
        let pinned = planner.load_table(Path::new(path))?;
        println!("planner: pinned {pinned} choices from {path} (no startup benchmarking)");
    }
    Ok(planner)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn native_backend_serves_a_batch() {
        let backend = NativeBackend::tiny(Variant::SHIFTADD_MOE);
        backend.warmup().unwrap();
        let (xs, _) = crate::data::synth_images::gen_batch(900, 2);
        let mut metrics = Metrics::default();
        let out = backend.run_batch(&xs, 2, &mut metrics).unwrap();
        assert_eq!(out.logits.shape, vec![2, backend.num_classes()]);
        assert_eq!(out.dispatch_mask_blk0.len(), 2);
        assert!(out.batch_ms > 0.0);
        assert!(out.modularized_ms <= out.batch_ms + 1e-9);
        assert_eq!(metrics.requests, 2);
        assert!(metrics.expert_tokens.iter().sum::<usize>() > 0);
        // the adapter went through the request path, so occupancy gauges
        // must be populated
        assert_eq!(metrics.batch_occupancy.count(), 1);
        assert!((metrics.batch_occupancy.max() - 1.0).abs() < 1e-12);
        assert_eq!(metrics.step_tokens.sum(), (2 * backend.tokens()) as f64);
    }

    #[test]
    fn submit_step_poll_matches_run_batch() {
        let backend = NativeBackend::tiny(Variant::SHIFTADD_MOE);
        let (xs, _) = crate::data::synth_images::gen_batch(77, 3);
        let px = backend.img() * backend.img() * 3;
        let mut m = Metrics::default();
        let whole = backend.run_batch(&xs, 3, &mut m).unwrap();

        let tickets: Vec<Ticket> = (0..3)
            .map(|i| {
                backend.submit(Request {
                    id: 100 + i,
                    pixels: xs[i * px..(i + 1) * px].to_vec(),
                    label: Some(i),
                    arrived: Instant::now(),
                    trace: TraceCtx::NONE,
                })
            })
            .collect();
        assert_eq!(backend.queued(), 3);
        let rep = backend.step(8, &mut m).unwrap();
        assert_eq!(rep.served, 3);
        assert_eq!(backend.queued(), 0);
        let nc = backend.num_classes();
        for (i, t) in tickets.iter().enumerate() {
            let out = backend.poll(t).expect("completed");
            assert_eq!(out.request_id, 100 + i);
            assert_eq!(out.label, Some(i));
            assert_eq!(out.batch_size, 3);
            assert_eq!(
                out.logits,
                &whole.logits.as_f32().unwrap()[i * nc..(i + 1) * nc],
                "request path diverged from one-shot batch at image {i}"
            );
            assert!(backend.poll(t).is_none(), "poll must consume the result");
        }
    }

    #[test]
    fn step_on_empty_queue_is_a_no_op() {
        let backend = NativeBackend::tiny(Variant::SHIFTADD_MOE);
        let mut m = Metrics::default();
        let rep = backend.step(4, &mut m).unwrap();
        assert_eq!(rep.served, 0);
        assert!(m.batch_occupancy.is_empty());
    }

    #[test]
    fn native_batching_consistent_with_singles() {
        // Per-tensor INT8 calibration spans the batch, so batched and
        // per-image execution agree only approximately (documented).
        let backend = NativeBackend::tiny(Variant::SHIFTADD_MOE);
        let (xs, _) = crate::data::synth_images::gen_batch(300, 2);
        let mut m = Metrics::default();
        let both = backend.run_batch(&xs, 2, &mut m).unwrap();
        let px = backend.img() * backend.img() * 3;
        let nc = backend.num_classes();
        for i in 0..2 {
            let one = backend
                .run_batch(&xs[i * px..(i + 1) * px], 1, &mut m)
                .unwrap();
            let a = &both.logits.as_f32().unwrap()[i * nc..(i + 1) * nc];
            let b = one.logits.as_f32().unwrap();
            for (x, y) in a.iter().zip(b) {
                assert!((x - y).abs() < 0.5, "batched {x} vs single {y}");
            }
        }
    }

    #[test]
    fn step_threads_request_ids_into_metrics() {
        let backend = NativeBackend::tiny(Variant::SHIFTADD_MOE);
        let (xs, _) = crate::data::synth_images::gen_batch(41, 2);
        let px = backend.img() * backend.img() * 3;
        for i in 0..2 {
            backend.submit(Request {
                id: 500 + i,
                pixels: xs[i * px..(i + 1) * px].to_vec(),
                label: None,
                arrived: Instant::now(),
                trace: TraceCtx::NONE,
            });
        }
        let mut m = Metrics::default();
        backend.step(8, &mut m).unwrap();
        assert_eq!(m.request_ids, vec![500, 501]);
    }

    #[test]
    fn completed_outputs_survive_past_the_done_cap() {
        // Regression (PR 9): the old oldest-first eviction could drop a
        // completed-but-never-polled output, making `poll_wait` spin to
        // timeout on a request that actually finished. Filling way past
        // the cap must lose nothing — every unpolled ticket still polls.
        let q = RequestQueue::with_done_cap(3);
        let complete_one = |q: &RequestQueue, i: usize| {
            let t = q.submit(Request {
                id: i,
                pixels: vec![0.0; 4],
                label: None,
                arrived: Instant::now(),
                trace: TraceCtx::NONE,
            });
            let batch = q.take(1);
            let out = BatchOutput {
                logits: Tensor::f32(vec![1, 2], vec![i as f32, 0.0]),
                dispatch_mask_blk0: Vec::new(),
                batch_ms: 0.1,
                modularized_ms: 0.1,
            };
            q.complete(batch, &out).unwrap();
            t
        };
        let tickets: Vec<Ticket> = (0..10).map(|i| complete_one(&q, i)).collect();
        assert_eq!(q.done_len(), 10, "nothing is evicted past the cap");
        for (i, t) in tickets.iter().enumerate() {
            let out = q.poll(t).expect("late polls still find their output");
            assert_eq!(out.logits[0], i as f32);
            assert_eq!(out.request_id, i);
        }
        assert_eq!(q.done_len(), 0, "polling drains the map");
        // prompt polling keeps the map empty, whatever the cap
        let t = complete_one(&q, 99);
        assert_eq!(q.poll(&t).unwrap().request_id, 99);
        assert_eq!(q.done_len(), 0);
    }

    #[test]
    fn native_backend_exposes_planner_choices() {
        let backend = NativeBackend::tiny(Variant::SHIFTADD_MOE);
        assert!(
            !backend.planner_choices().is_empty(),
            "model construction must log planner decisions"
        );
    }
}
