//! The engine-agnostic serving contract: `serve()`, the batcher, and the
//! e2e tests talk to an [`InferenceBackend`] instead of the XLA artifact
//! pipeline directly. Two implementations ship:
//!
//! - [`crate::coordinator::scheduler::MoePipeline`] — the AOT-compiled HLO
//!   artifact pipeline on the PJRT engine pool (requires `make artifacts`);
//! - [`NativeBackend`] — the pure-Rust [`crate::infer`] engine (zero
//!   artifacts, runs out of the box).
//!
//! [`create_backend`] resolves a [`ServerConfig`]'s `backend` field to a
//! boxed implementation.

use std::time::Instant;

use anyhow::Result;

use crate::coordinator::config::{BackendKind, DispatchMode, ServerConfig};
use crate::coordinator::metrics::Metrics;
use crate::coordinator::scheduler::MoePipeline;
use crate::infer::model::{NativeModel, NativeModelConfig};
use crate::model::ops::Variant;
use crate::runtime::artifact::Manifest;
use crate::runtime::tensor::Tensor;

/// Result of one batch, whichever engine produced it.
pub struct BatchOutput {
    pub logits: Tensor,
    /// per-image routed-to-Mult token masks of the FIRST MoE block (for the
    /// Fig. 6/9 visualisation)
    pub dispatch_mask_blk0: Vec<Vec<bool>>,
    pub batch_ms: f64,
    /// makespan the batch *would* have under ideal parallelism (paper "*")
    pub modularized_ms: f64,
}

/// One inference engine behind the coordinator: warm it once, then feed it
/// image batches. Implementations record per-stage latency and expert-load
/// diagnostics into the shared [`Metrics`].
pub trait InferenceBackend {
    /// Short engine label for reports ("native", "xla").
    fn name(&self) -> String;

    /// Input image side length (pixels).
    fn img(&self) -> usize;

    /// Tokens per image in the first (routed) stage — the Fig. 6/9 mask
    /// grid size.
    fn tokens(&self) -> usize;

    fn num_classes(&self) -> usize;

    /// One-time warm-up (compiles artifacts / runs the planner) — keeps
    /// first-request latency out of the measured path.
    fn warmup(&self) -> Result<()>;

    /// Run `n` flattened HWC images through the model.
    fn run_batch(&self, images: &[f32], n: usize, metrics: &mut Metrics) -> Result<BatchOutput>;
}

/// The native pure-Rust engine behind the [`InferenceBackend`] contract.
pub struct NativeBackend {
    pub model: NativeModel,
}

impl NativeBackend {
    /// The tiny serving analogue under the paper's full reparameterization
    /// (LinearAdd attention + shift linears + Mult/Shift MoE).
    pub fn tiny(variant: Variant) -> NativeBackend {
        NativeBackend {
            model: NativeModel::tiny(variant),
        }
    }

    pub fn from_config(cfg: NativeModelConfig) -> NativeBackend {
        use crate::kernels::planner::Planner;
        use crate::kernels::registry::KernelRegistry;
        use std::sync::Arc;
        let planner = Arc::new(Planner::new(Arc::new(KernelRegistry::with_defaults())));
        NativeBackend {
            model: NativeModel::new(cfg, planner),
        }
    }
}

impl InferenceBackend for NativeBackend {
    fn name(&self) -> String {
        format!("native ({})", self.model.cfg.spec.name)
    }

    fn img(&self) -> usize {
        self.model.cfg.img
    }

    fn tokens(&self) -> usize {
        self.model.tokens()
    }

    fn num_classes(&self) -> usize {
        self.model.cfg.num_classes
    }

    fn warmup(&self) -> Result<()> {
        // One bs-1 forward settles the planner's backend choices and the
        // worker pool spawn before anything is timed.
        let zeros = vec![0.0f32; self.img() * self.img() * 3];
        self.model.forward(&zeros, 1);
        Ok(())
    }

    fn run_batch(&self, images: &[f32], n: usize, metrics: &mut Metrics) -> Result<BatchOutput> {
        let t0 = Instant::now();
        let (logits, trace) = self.model.forward(images, n);
        let batch_ms = t0.elapsed().as_secs_f64() * 1e3;
        for (name, ms) in &trace.stage_ms {
            metrics.record(name, *ms);
        }
        metrics.expert_tokens[0] += trace.expert_tokens[0];
        metrics.expert_tokens[1] += trace.expert_tokens[1];
        metrics.expert_gates[0] += trace.gate_sums[0];
        metrics.expert_gates[1] += trace.gate_sums[1];
        // Modularized accounting (paper "*"): experts ran sequentially in
        // the native engine, so the ideal-parallel makespan replaces each
        // MoE layer's e0+e1 with max(e0, e1).
        let mut modularized_ms = batch_ms;
        for [e0, e1] in &trace.expert_ms {
            metrics.expert_times[0].push(*e0);
            metrics.expert_times[1].push(*e1);
            modularized_ms -= e0.min(*e1);
        }
        metrics.padding_waste.extend(trace.padding_waste.iter());
        metrics.batches += 1;
        metrics.requests += n;
        Ok(BatchOutput {
            logits: Tensor::f32(vec![n, self.num_classes()], logits),
            dispatch_mask_blk0: trace.mask_blk0,
            batch_ms,
            modularized_ms,
        })
    }
}

/// Resolve the configured backend. `Native` needs nothing on disk; `Xla`
/// loads the artifact manifest (fails fast with the usual
/// "run `make artifacts`" context when absent).
pub fn create_backend(cfg: &ServerConfig) -> Result<Box<dyn InferenceBackend>> {
    match cfg.backend {
        BackendKind::Native => {
            // The native engine always executes real sparse dispatch (and
            // reports modularized accounting alongside); the dispatch-mode
            // comparison (real/modularized/dense) is an XLA-pipeline
            // experiment — fail loudly instead of measuring the wrong thing.
            if cfg.dispatch != DispatchMode::Real {
                anyhow::bail!(
                    "dispatch mode {:?} needs the xla backend (--backend xla); \
                     the native engine always runs real sparse dispatch",
                    cfg.dispatch
                );
            }
            Ok(Box::new(NativeBackend::tiny(Variant::SHIFTADD_MOE)))
        }
        BackendKind::Xla => {
            let manifest = Manifest::load(&Manifest::default_dir())?;
            Ok(Box::new(MoePipeline::new(&manifest, cfg.dispatch)?))
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn native_backend_serves_a_batch() {
        let backend = NativeBackend::tiny(Variant::SHIFTADD_MOE);
        backend.warmup().unwrap();
        let (xs, _) = crate::data::synth_images::gen_batch(900, 2);
        let mut metrics = Metrics::default();
        let out = backend.run_batch(&xs, 2, &mut metrics).unwrap();
        assert_eq!(out.logits.shape, vec![2, backend.num_classes()]);
        assert_eq!(out.dispatch_mask_blk0.len(), 2);
        assert!(out.batch_ms > 0.0);
        assert!(out.modularized_ms <= out.batch_ms + 1e-9);
        assert_eq!(metrics.requests, 2);
        assert!(metrics.expert_tokens.iter().sum::<usize>() > 0);
    }

    #[test]
    fn native_batching_consistent_with_singles() {
        // Per-tensor INT8 calibration spans the batch, so batched and
        // per-image execution agree only approximately (documented).
        let backend = NativeBackend::tiny(Variant::SHIFTADD_MOE);
        let (xs, _) = crate::data::synth_images::gen_batch(300, 2);
        let mut m = Metrics::default();
        let both = backend.run_batch(&xs, 2, &mut m).unwrap();
        let px = backend.img() * backend.img() * 3;
        let nc = backend.num_classes();
        for i in 0..2 {
            let one = backend
                .run_batch(&xs[i * px..(i + 1) * px], 1, &mut m)
                .unwrap();
            let a = &both.logits.as_f32().unwrap()[i * nc..(i + 1) * nc];
            let b = one.logits.as_f32().unwrap();
            for (x, y) in a.iter().zip(b) {
                assert!((x - y).abs() < 0.5, "batched {x} vs single {y}");
            }
        }
    }
}
