//! L3 — the serving coordinator (the paper's system contribution, serving
//! shape): dynamic batching, real sparse MoE token dispatch with parallel
//! experts and latency-aware balancing, and serving metrics — all behind
//! the engine-agnostic [`backend::InferenceBackend`] trait, with the XLA
//! artifact pipeline (`scheduler`) and the native pure-Rust engine
//! (`backend::NativeBackend`) as interchangeable engines.

pub mod backend;
pub mod batcher;
pub mod config;
pub mod metrics;
pub mod scheduler;
pub mod server;
