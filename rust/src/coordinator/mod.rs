//! L3 — the serving coordinator (the paper's system contribution, serving
//! shape): dynamic batching, real sparse MoE token dispatch with parallel
//! experts and latency-aware balancing, and serving metrics — all behind
//! the engine-agnostic [`backend::InferenceBackend`] trait, with the XLA
//! artifact pipeline (`scheduler`) and the native pure-Rust engine
//! (`backend::NativeBackend`) as interchangeable engines.
//!
//! Two request shapes are served:
//!
//! - **image classification** — `submit(Request) -> Ticket`, `step()`
//!   fuses queued requests into one engine batch, `poll(Ticket)` collects
//!   (the old one-shot `run_batch` remains as an adapter);
//! - **token streaming** — [`sessions::SessionEngine`] continuously
//!   batches live `infer::session` sessions, packing each one's next chunk
//!   into one fused kernel dispatch per layer per step.

pub mod backend;
pub mod batcher;
pub mod config;
pub mod metrics;
pub mod scheduler;
pub mod server;
pub mod sessions;
