//! L3 — the serving coordinator (the paper's system contribution, serving
//! shape): dynamic batching, the pipeline scheduler over the decomposed
//! model artifacts, real sparse MoE token dispatch with parallel experts and
//! latency-aware balancing, and serving metrics.

pub mod batcher;
pub mod config;
pub mod metrics;
pub mod scheduler;
pub mod server;
