//! Serving metrics: per-stage latency accumulation, expert load tracking,
//! and the LL-loss diagnostics surfaced by the `metrics` CLI output.

use std::collections::BTreeMap;

use crate::kernels::planner::Choice;
use crate::moe::balance;
use crate::util::json::Json;
use crate::util::stats::Summary;

/// Accumulates samples per named stage.
#[derive(Default, Debug, Clone)]
pub struct Metrics {
    stages: BTreeMap<String, Vec<f64>>,
    /// tokens routed per expert (cumulative)
    pub expert_tokens: [usize; 2],
    /// gate-value sums per expert (cumulative)
    pub expert_gates: [f64; 2],
    /// measured per-expert batch times (ms)
    pub expert_times: [Vec<f64>; 2],
    pub batches: usize,
    pub requests: usize,
    pub padding_waste: Vec<f64>,
    /// per-step batch occupancy: requests served / `max_batch` (image path)
    /// or live sessions / `max_live` (streaming path) ∈ (0, 1]
    pub batch_occupancy: Vec<f64>,
    /// per-step token rows packed into the fused dispatches
    pub step_tokens: Vec<f64>,
    /// per-step attention kernel calls per block layer (native image
    /// path): the fused path holds this at 2 grouped calls per LinearAdd
    /// layer no matter the batch size — each grouped call packs all
    /// images×heads into one operand, with per-group fan-out left to the
    /// backend — where per-image execution pays b·heads·4 plain calls
    pub attn_dispatches_per_layer: Vec<f64>,
    /// per-step live session count (streaming path only)
    pub live_sessions: Vec<f64>,
    /// per-step token rows advanced by the decode dispatch (streaming
    /// path; the single-phase scheduler reports its whole fused step here,
    /// prompts included — that asymmetry IS the phase-disaggregation story)
    pub decode_tokens: Vec<f64>,
    /// per-step token rows fed by the budgeted prefill dispatch (streaming
    /// path; 0 under the single-phase scheduler)
    pub prefill_tokens: Vec<f64>,
    /// per-step prefill-queue depth at step start (streaming path)
    pub prefill_queue: Vec<f64>,
    /// caller-supplied ids of the requests completed so far, in completion
    /// order — the audit trail a fleet merge preserves (every submitted id
    /// shows up exactly once across all workers)
    pub request_ids: Vec<usize>,
    /// per-primitive chosen-backend gauge, recorded from the planner's
    /// plan-time decisions (`NativeBackend` / streaming engine
    /// construction): `"primitive/backend"` id → number of shapes that
    /// resolved to it, so serve reports show which kernel family actually
    /// ran (the XLA pipeline plans nothing and leaves this empty)
    pub chosen_backends: BTreeMap<String, usize>,
    /// digest of the verified `.sabundle` the engine warm-started from
    /// (`None` when serving from seeded init)
    pub bundle_digest: Option<String>,
}

impl Metrics {
    pub fn record(&mut self, stage: &str, ms: f64) {
        self.stages.entry(stage.to_string()).or_default().push(ms);
    }

    /// Rebuild the chosen-backend gauge from a planner decision log (plan
    /// time + any lazy decisions since) — idempotent, so serve loops can
    /// refresh it after construction and again before reporting.
    pub fn record_plan(&mut self, choices: &[Choice]) {
        self.chosen_backends.clear();
        for c in choices {
            let id = format!("{}/{}", c.primitive.name(), c.backend);
            *self.chosen_backends.entry(id).or_insert(0) += 1;
        }
    }

    /// Record one engine step's occupancy gauges (shared by the image
    /// request path and the streaming session path).
    pub fn record_step_occupancy(&mut self, served: usize, capacity: usize, tokens: usize) {
        self.batch_occupancy
            .push(served as f64 / capacity.max(1) as f64);
        self.step_tokens.push(tokens as f64);
    }

    pub fn occupancy_summary(&self) -> Option<Summary> {
        if self.batch_occupancy.is_empty() {
            None
        } else {
            Some(Summary::from(&self.batch_occupancy))
        }
    }

    pub fn step_tokens_summary(&self) -> Option<Summary> {
        if self.step_tokens.is_empty() {
            None
        } else {
            Some(Summary::from(&self.step_tokens))
        }
    }

    pub fn stage_summary(&self, stage: &str) -> Option<Summary> {
        self.stages.get(stage).map(|v| Summary::from(v))
    }

    /// Observed expert load fractions.
    pub fn load_split(&self) -> [f64; 2] {
        let total = (self.expert_tokens[0] + self.expert_tokens[1]).max(1) as f64;
        [
            self.expert_tokens[0] as f64 / total,
            self.expert_tokens[1] as f64 / total,
        ]
    }

    /// Evaluate the paper's Eq. 4 losses over the traffic seen so far, using
    /// measured mean expert times for the α coefficients.
    pub fn ll_loss(&self) -> Option<(f64, f64)> {
        if self.expert_times[0].is_empty() || self.expert_times[1].is_empty() {
            return None;
        }
        let lat = [
            mean(&self.expert_times[0]),
            mean(&self.expert_times[1]),
        ];
        let a = balance::alphas(&lat);
        let imp = balance::importance_loss(&self.expert_gates.map(|g| g), &a);
        let load = balance::load_loss(&self.expert_tokens, &a);
        Some((imp, load))
    }

    /// Bound every per-sample vector to its most recent `cap` entries,
    /// leaving the scalar counters (which carry the full totals) intact.
    /// Long-running servers — the HTTP front door records into one Metrics
    /// forever — call this after recording so memory stays O(cap); batch
    /// serve runs never call it and keep their complete sample sets.
    pub fn cap_samples(&mut self, cap: usize) {
        fn trim(v: &mut Vec<f64>, cap: usize) {
            if v.len() > cap {
                let excess = v.len() - cap;
                v.drain(..excess);
            }
        }
        for v in self.stages.values_mut() {
            trim(v, cap);
        }
        for v in &mut self.expert_times {
            trim(v, cap);
        }
        trim(&mut self.padding_waste, cap);
        trim(&mut self.batch_occupancy, cap);
        trim(&mut self.step_tokens, cap);
        trim(&mut self.attn_dispatches_per_layer, cap);
        trim(&mut self.live_sessions, cap);
        trim(&mut self.decode_tokens, cap);
        trim(&mut self.prefill_tokens, cap);
        trim(&mut self.prefill_queue, cap);
        if self.request_ids.len() > cap {
            let excess = self.request_ids.len() - cap;
            self.request_ids.drain(..excess);
        }
    }

    /// Fold another engine's metrics into this one (fleet aggregation:
    /// stage samples concatenate, counters add, gauges concatenate, the
    /// chosen-backend gauge sums per id, request ids concatenate).
    pub fn merge(&mut self, other: &Metrics) {
        for (k, v) in &other.stages {
            self.stages
                .entry(k.clone())
                .or_default()
                .extend_from_slice(v);
        }
        for e in 0..2 {
            self.expert_tokens[e] += other.expert_tokens[e];
            self.expert_gates[e] += other.expert_gates[e];
            self.expert_times[e].extend_from_slice(&other.expert_times[e]);
        }
        self.batches += other.batches;
        self.requests += other.requests;
        self.padding_waste.extend_from_slice(&other.padding_waste);
        self.batch_occupancy.extend_from_slice(&other.batch_occupancy);
        self.step_tokens.extend_from_slice(&other.step_tokens);
        self.attn_dispatches_per_layer
            .extend_from_slice(&other.attn_dispatches_per_layer);
        self.live_sessions.extend_from_slice(&other.live_sessions);
        self.decode_tokens.extend_from_slice(&other.decode_tokens);
        self.prefill_tokens.extend_from_slice(&other.prefill_tokens);
        self.prefill_queue.extend_from_slice(&other.prefill_queue);
        self.request_ids.extend_from_slice(&other.request_ids);
        for (id, n) in &other.chosen_backends {
            *self.chosen_backends.entry(id.clone()).or_insert(0) += n;
        }
        if self.bundle_digest.is_none() {
            self.bundle_digest = other.bundle_digest.clone();
        }
    }

    /// JSON dump for tooling.
    pub fn to_json(&self) -> Json {
        let mut pairs: Vec<(&str, Json)> = vec![
            ("batches", Json::num(self.batches as f64)),
            ("requests", Json::num(self.requests as f64)),
            (
                "expert_tokens",
                Json::arr_num(&[self.expert_tokens[0] as f64, self.expert_tokens[1] as f64]),
            ),
        ];
        let mut stage_obj = Vec::new();
        for (k, v) in &self.stages {
            let s = Summary::from(v);
            stage_obj.push((
                k.as_str(),
                Json::obj(vec![
                    ("mean_ms", Json::num(s.mean)),
                    ("p50_ms", Json::num(s.p50)),
                    ("p99_ms", Json::num(s.p99)),
                    ("n", Json::num(s.n as f64)),
                ]),
            ));
        }
        pairs.push(("stages", Json::obj(stage_obj)));
        if let Some(s) = self.occupancy_summary() {
            pairs.push((
                "batch_occupancy",
                Json::obj(vec![
                    ("mean", Json::num(s.mean)),
                    ("p50", Json::num(s.p50)),
                    ("n", Json::num(s.n as f64)),
                ]),
            ));
        }
        if let Some(s) = self.step_tokens_summary() {
            pairs.push((
                "step_tokens",
                Json::obj(vec![
                    ("mean", Json::num(s.mean)),
                    ("p50", Json::num(s.p50)),
                    ("n", Json::num(s.n as f64)),
                ]),
            ));
        }
        if !self.attn_dispatches_per_layer.is_empty() {
            let s = Summary::from(&self.attn_dispatches_per_layer);
            pairs.push((
                "attn_dispatches_per_layer",
                Json::obj(vec![
                    ("mean", Json::num(s.mean)),
                    ("max", Json::num(s.max)),
                    ("n", Json::num(s.n as f64)),
                ]),
            ));
        }
        if !self.live_sessions.is_empty() {
            let s = Summary::from(&self.live_sessions);
            pairs.push((
                "live_sessions",
                Json::obj(vec![
                    ("mean", Json::num(s.mean)),
                    ("max", Json::num(s.max)),
                    ("n", Json::num(s.n as f64)),
                ]),
            ));
        }
        for (key, gauge) in [
            ("decode_tokens", &self.decode_tokens),
            ("prefill_tokens", &self.prefill_tokens),
            ("prefill_queue", &self.prefill_queue),
        ] {
            if gauge.is_empty() {
                continue;
            }
            let s = Summary::from(gauge);
            pairs.push((
                key,
                Json::obj(vec![
                    ("mean", Json::num(s.mean)),
                    ("p99", Json::num(s.p99)),
                    ("max", Json::num(s.max)),
                    ("n", Json::num(s.n as f64)),
                ]),
            ));
        }
        if !self.chosen_backends.is_empty() {
            let chosen: Vec<(&str, Json)> = self
                .chosen_backends
                .iter()
                .map(|(id, n)| (id.as_str(), Json::num(*n as f64)))
                .collect();
            pairs.push(("chosen_backend", Json::obj(chosen)));
        }
        if !self.request_ids.is_empty() {
            let ids: Vec<f64> = self.request_ids.iter().map(|&id| id as f64).collect();
            pairs.push(("request_ids", Json::arr_num(&ids)));
        }
        if let Some(d) = &self.bundle_digest {
            pairs.push(("bundle_digest", Json::str(d)));
        }
        Json::obj(pairs)
    }

    pub fn print(&self) {
        println!("-- serving metrics --");
        println!(
            "batches {}  requests {}  expert load split {:?}",
            self.batches,
            self.requests,
            self.load_split()
        );
        if let Some((imp, load)) = self.ll_loss() {
            println!("LL-loss diagnostics: L_IMP {imp:.4}  L_LOAD {load:.4}");
        }
        for (k, v) in &self.stages {
            let s = Summary::from(v);
            println!(
                "  {k:28} mean {:8.3} ms  p50 {:8.3}  p99 {:8.3}  (n={})",
                s.mean, s.p50, s.p99, s.n
            );
        }
        if !self.padding_waste.is_empty() {
            println!(
                "  bucket padding waste: {:.1}%",
                100.0 * mean(&self.padding_waste)
            );
        }
        if let Some(s) = self.occupancy_summary() {
            println!(
                "  batch occupancy: mean {:.1}%  p50 {:.1}%  (n={})",
                100.0 * s.mean,
                100.0 * s.p50,
                s.n
            );
        }
        if let Some(s) = self.step_tokens_summary() {
            println!(
                "  tokens per step: mean {:.1}  p50 {:.1}  (n={})",
                s.mean, s.p50, s.n
            );
        }
        if !self.attn_dispatches_per_layer.is_empty() {
            let s = Summary::from(&self.attn_dispatches_per_layer);
            println!(
                "  attn dispatches per layer: mean {:.1}  max {:.0}",
                s.mean, s.max
            );
        }
        if !self.live_sessions.is_empty() {
            println!(
                "  live sessions per step: mean {:.1}  max {:.0}",
                mean(&self.live_sessions),
                self.live_sessions.iter().cloned().fold(0.0, f64::max)
            );
        }
        if !self.decode_tokens.is_empty() {
            let dec = Summary::from(&self.decode_tokens);
            let pre = Summary::from(&self.prefill_tokens);
            println!(
                "  decode tokens per step: mean {:.1}  p99 {:.0}  |  prefill: mean {:.1}  p99 {:.0}",
                dec.mean, dec.p99, pre.mean, pre.p99
            );
        }
        if self.prefill_queue.iter().any(|&q| q > 0.0) {
            let s = Summary::from(&self.prefill_queue);
            println!(
                "  prefill queue depth: mean {:.1}  max {:.0}",
                s.mean, s.max
            );
        }
        if !self.chosen_backends.is_empty() {
            let parts: Vec<String> = self
                .chosen_backends
                .iter()
                .map(|(id, n)| format!("{id}×{n}"))
                .collect();
            println!("  planned kernel backends: {}", parts.join("  "));
        }
        if let Some(d) = &self.bundle_digest {
            println!("  bundle digest: {d}");
        }
    }
}

fn mean(v: &[f64]) -> f64 {
    v.iter().sum::<f64>() / v.len().max(1) as f64
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stage_accumulation() {
        let mut m = Metrics::default();
        m.record("stem", 1.0);
        m.record("stem", 3.0);
        let s = m.stage_summary("stem").unwrap();
        assert_eq!(s.n, 2);
        assert!((s.mean - 2.0).abs() < 1e-12);
        assert!(m.stage_summary("missing").is_none());
    }

    #[test]
    fn load_split_fractions() {
        let mut m = Metrics::default();
        m.expert_tokens = [30, 10];
        let f = m.load_split();
        assert!((f[0] - 0.75).abs() < 1e-12);
    }

    #[test]
    fn ll_loss_requires_both_experts() {
        let mut m = Metrics::default();
        assert!(m.ll_loss().is_none());
        m.expert_times[0].push(2.0);
        m.expert_times[1].push(1.0);
        m.expert_tokens = [100, 200];
        m.expert_gates = [60.0, 110.0];
        let (imp, load) = m.ll_loss().unwrap();
        assert!(imp >= 0.0 && load >= 0.0);
    }

    #[test]
    fn json_dump_parses() {
        let mut m = Metrics::default();
        m.record("head", 0.5);
        m.batches = 1;
        let j = m.to_json();
        assert_eq!(j.get("batches").unwrap().as_usize(), Some(1));
        assert!(j.get("batch_occupancy").is_none(), "no steps, no gauge");
    }

    #[test]
    fn chosen_backend_gauge_counts_per_id_and_serializes() {
        use crate::kernels::api::Primitive;
        use crate::kernels::planner::Shape;
        let mk = |p, backend: &str| Choice {
            primitive: p,
            shape: Shape::new(4, 4, 4),
            backend: backend.to_string(),
            measured_ms: Vec::new(),
        };
        let mut m = Metrics::default();
        assert!(m.to_json().get("chosen_backend").is_none(), "empty → absent");
        m.record_plan(&[
            mk(Primitive::MatAdd, "simd"),
            mk(Primitive::MatAdd, "simd"),
            mk(Primitive::MatShift, "rowpar"),
        ]);
        assert_eq!(m.chosen_backends.get("matadd/simd"), Some(&2));
        assert_eq!(m.chosen_backends.get("matshift/rowpar"), Some(&1));
        let j = m.to_json();
        let gauge = j.get("chosen_backend").expect("gauge serialized");
        assert_eq!(gauge.get("matadd/simd").and_then(|v| v.as_usize()), Some(2));
        // idempotent refresh: re-recording replaces, never double-counts
        m.record_plan(&[mk(Primitive::MatAdd, "simd")]);
        assert_eq!(m.chosen_backends.get("matadd/simd"), Some(&1));
        assert!(m.chosen_backends.get("matshift/rowpar").is_none());
        m.print(); // should not panic
    }

    #[test]
    fn cap_samples_keeps_most_recent_and_preserves_counters() {
        let mut m = Metrics::default();
        for i in 0..10 {
            m.record("http_classify", i as f64);
            m.request_ids.push(i);
            m.batch_occupancy.push(i as f64);
            m.requests += 1;
        }
        m.cap_samples(4);
        assert_eq!(m.requests, 10, "counters keep the full total");
        assert_eq!(m.stage_summary("http_classify").unwrap().n, 4);
        assert_eq!(m.request_ids, vec![6, 7, 8, 9], "most recent survive");
        assert_eq!(m.batch_occupancy, vec![6.0, 7.0, 8.0, 9.0]);
        // idempotent under the cap
        m.cap_samples(4);
        assert_eq!(m.request_ids.len(), 4);
        m.cap_samples(100);
        assert_eq!(m.request_ids.len(), 4, "a looser cap drops nothing");
    }

    #[test]
    fn merge_folds_counters_samples_and_request_ids() {
        let mut a = Metrics::default();
        a.record("stem", 1.0);
        a.batches = 2;
        a.requests = 3;
        a.expert_tokens = [10, 5];
        a.request_ids = vec![0, 2];
        a.chosen_backends.insert("matadd/simd".into(), 2);
        let mut b = Metrics::default();
        b.record("stem", 3.0);
        b.record("head", 0.5);
        b.batches = 1;
        b.requests = 2;
        b.expert_tokens = [1, 4];
        b.request_ids = vec![1, 3];
        b.chosen_backends.insert("matadd/simd".into(), 1);
        b.chosen_backends.insert("matshift/rowpar".into(), 1);
        b.bundle_digest = Some("abc123".to_string());
        a.merge(&b);
        assert_eq!(a.batches, 3);
        assert_eq!(a.requests, 5);
        assert_eq!(a.expert_tokens, [11, 9]);
        assert_eq!(a.bundle_digest.as_deref(), Some("abc123"));
        assert_eq!(a.stage_summary("stem").unwrap().n, 2);
        assert_eq!(a.stage_summary("head").unwrap().n, 1);
        assert_eq!(a.request_ids, vec![0, 2, 1, 3]);
        assert_eq!(a.chosen_backends.get("matadd/simd"), Some(&3));
        assert_eq!(a.chosen_backends.get("matshift/rowpar"), Some(&1));
        // request ids and the bundle digest round-trip through JSON
        let j = a.to_json();
        assert!(j.get("request_ids").is_some());
        assert_eq!(
            j.get("bundle_digest").and_then(|v| v.as_str()),
            Some("abc123")
        );
        // Clone gives an independent copy (fleet snapshot semantics)
        let c = a.clone();
        assert_eq!(c.requests, a.requests);
    }

    #[test]
    fn phase_gauges_merge_and_serialize() {
        let mut a = Metrics::default();
        assert!(a.to_json().get("decode_tokens").is_none(), "empty → absent");
        a.decode_tokens.push(8.0);
        a.prefill_tokens.push(16.0);
        a.prefill_queue.push(2.0);
        let mut b = Metrics::default();
        b.decode_tokens.push(4.0);
        b.prefill_tokens.push(0.0);
        b.prefill_queue.push(0.0);
        a.merge(&b);
        assert_eq!(a.decode_tokens, vec![8.0, 4.0]);
        assert_eq!(a.prefill_tokens, vec![16.0, 0.0]);
        assert_eq!(a.prefill_queue, vec![2.0, 0.0]);
        let j = a.to_json();
        let dec = j.get("decode_tokens").expect("gauge serialized");
        assert_eq!(dec.get("n").and_then(|v| v.as_usize()), Some(2));
        assert!(j.get("prefill_tokens").is_some());
        assert!(j.get("prefill_queue").is_some());
        a.print(); // should not panic
    }

    #[test]
    fn occupancy_gauges_accumulate_and_serialize() {
        let mut m = Metrics::default();
        assert!(m.occupancy_summary().is_none());
        m.record_step_occupancy(2, 8, 128);
        m.record_step_occupancy(8, 8, 512);
        let occ = m.occupancy_summary().unwrap();
        assert_eq!(occ.n, 2);
        assert!((occ.mean - 0.625).abs() < 1e-12);
        let tok = m.step_tokens_summary().unwrap();
        assert!((tok.mean - 320.0).abs() < 1e-12);
        m.live_sessions.push(2.0);
        let j = m.to_json();
        assert!(j.get("batch_occupancy").is_some());
        assert!(j.get("step_tokens").is_some());
        assert!(j.get("live_sessions").is_some());
        m.print(); // should not panic
    }
}
