//! Serving metrics: per-stage latency accumulation, expert load tracking,
//! and the LL-loss diagnostics surfaced by the `metrics` CLI output.
//!
//! Since PR 10 every per-sample series is a bounded log-bucketed
//! [`Hist`] (64 buckets, O(1) record) instead of an unbounded `Vec<f64>`:
//! long-running servers no longer trim samples (`cap_samples` is gone),
//! and fleet aggregation merges bucket counts exactly, so merged
//! percentiles equal what one recorder would have measured over the union
//! of the traffic — the old concatenate-after-trim bias is structurally
//! impossible. Counts, sums, means, min/max stay exact; percentiles carry
//! the histogram's documented ≤19% bucket error.

use std::collections::{BTreeMap, VecDeque};

use crate::kernels::planner::Choice;
use crate::moe::balance;
use crate::obs::hist::Hist;
use crate::obs::prom::PromWriter;
use crate::util::json::Json;
use crate::util::stats::Summary;

/// Most-recent completed-request ids the audit trail retains. Bounded
/// serve runs stay far below this, so their reports see the full trail;
/// a server behind the HTTP front door completes requests forever and
/// keeps only this recent window (the `requests` counter keeps the total).
pub const REQUEST_ID_CAP: usize = 4096;

/// Accumulates samples per named stage.
#[derive(Default, Debug, Clone)]
pub struct Metrics {
    stages: BTreeMap<String, Hist>,
    /// tokens routed per expert (cumulative)
    pub expert_tokens: [usize; 2],
    /// gate-value sums per expert (cumulative)
    pub expert_gates: [f64; 2],
    /// measured per-expert batch times (ms)
    pub expert_times: [Hist; 2],
    pub batches: usize,
    pub requests: usize,
    pub padding_waste: Hist,
    /// per-step batch occupancy: requests served / `max_batch` (image path)
    /// or live sessions / `max_live` (streaming path) ∈ (0, 1]
    pub batch_occupancy: Hist,
    /// per-step token rows packed into the fused dispatches
    pub step_tokens: Hist,
    /// per-step attention kernel calls per block layer (native image
    /// path): the fused path holds this at 2 grouped calls per LinearAdd
    /// layer no matter the batch size — each grouped call packs all
    /// images×heads into one operand, with per-group fan-out left to the
    /// backend — where per-image execution pays b·heads·4 plain calls
    pub attn_dispatches_per_layer: Hist,
    /// per-step live session count (streaming path only)
    pub live_sessions: Hist,
    /// per-step token rows advanced by the decode dispatch (streaming
    /// path; the single-phase scheduler reports its whole fused step here,
    /// prompts included — that asymmetry IS the phase-disaggregation story)
    pub decode_tokens: Hist,
    /// per-step token rows fed by the budgeted prefill dispatch (streaming
    /// path; 0 under the single-phase scheduler)
    pub prefill_tokens: Hist,
    /// per-step prefill-queue depth at step start (streaming path)
    pub prefill_queue: Hist,
    /// caller-supplied ids of the requests completed so far, in completion
    /// order — the audit trail a fleet merge preserves (every submitted id
    /// shows up exactly once across all workers while under
    /// [`REQUEST_ID_CAP`]); a bounded FIFO ring, so long-running servers
    /// retain only the most recent window — append via
    /// [`Metrics::push_request_id`]
    pub request_ids: VecDeque<usize>,
    /// per-primitive chosen-backend gauge, recorded from the planner's
    /// plan-time decisions (`NativeBackend` / streaming engine
    /// construction): `"primitive/backend"` id → number of shapes that
    /// resolved to it, so serve reports show which kernel family actually
    /// ran (the XLA pipeline plans nothing and leaves this empty)
    pub chosen_backends: BTreeMap<String, usize>,
    /// digest of the verified `.sabundle` the engine warm-started from
    /// (`None` when serving from seeded init)
    pub bundle_digest: Option<String>,
}

impl Metrics {
    pub fn record(&mut self, stage: &str, ms: f64) {
        self.stages.entry(stage.to_string()).or_default().record(ms);
    }

    /// Rebuild the chosen-backend gauge from a planner decision log (plan
    /// time + any lazy decisions since) — idempotent, so serve loops can
    /// refresh it after construction and again before reporting.
    pub fn record_plan(&mut self, choices: &[Choice]) {
        self.chosen_backends.clear();
        for c in choices {
            let id = format!("{}/{}", c.primitive.name(), c.backend);
            *self.chosen_backends.entry(id).or_insert(0) += 1;
        }
    }

    /// Append one completed request id to the audit trail, evicting the
    /// oldest entry past [`REQUEST_ID_CAP`] so long-running servers stay
    /// bounded.
    pub fn push_request_id(&mut self, id: usize) {
        self.request_ids.push_back(id);
        if self.request_ids.len() > REQUEST_ID_CAP {
            self.request_ids.pop_front();
        }
    }

    /// Record one engine step's occupancy gauges (shared by the image
    /// request path and the streaming session path).
    pub fn record_step_occupancy(&mut self, served: usize, capacity: usize, tokens: usize) {
        self.batch_occupancy
            .record(served as f64 / capacity.max(1) as f64);
        self.step_tokens.record(tokens as f64);
    }

    pub fn occupancy_summary(&self) -> Option<Summary> {
        if self.batch_occupancy.is_empty() {
            None
        } else {
            Some(self.batch_occupancy.summary())
        }
    }

    pub fn step_tokens_summary(&self) -> Option<Summary> {
        if self.step_tokens.is_empty() {
            None
        } else {
            Some(self.step_tokens.summary())
        }
    }

    pub fn stage_summary(&self, stage: &str) -> Option<Summary> {
        self.stages.get(stage).map(|h| h.summary())
    }

    /// Observed expert load fractions.
    pub fn load_split(&self) -> [f64; 2] {
        let total = (self.expert_tokens[0] + self.expert_tokens[1]).max(1) as f64;
        [
            self.expert_tokens[0] as f64 / total,
            self.expert_tokens[1] as f64 / total,
        ]
    }

    /// Evaluate the paper's Eq. 4 losses over the traffic seen so far, using
    /// measured mean expert times for the α coefficients.
    pub fn ll_loss(&self) -> Option<(f64, f64)> {
        if self.expert_times[0].is_empty() || self.expert_times[1].is_empty() {
            return None;
        }
        let lat = [self.expert_times[0].mean(), self.expert_times[1].mean()];
        let a = balance::alphas(&lat);
        let imp = balance::importance_loss(&self.expert_gates.map(|g| g), &a);
        let load = balance::load_loss(&self.expert_tokens, &a);
        Some((imp, load))
    }

    /// Fold another engine's metrics into this one (fleet aggregation:
    /// histograms merge with exact bucket counts, counters add, the
    /// chosen-backend gauge sums per id, request ids concatenate). Merged
    /// percentiles equal the percentiles of the union of the samples.
    pub fn merge(&mut self, other: &Metrics) {
        for (k, h) in &other.stages {
            self.stages.entry(k.clone()).or_default().merge(h);
        }
        for e in 0..2 {
            self.expert_tokens[e] += other.expert_tokens[e];
            self.expert_gates[e] += other.expert_gates[e];
            self.expert_times[e].merge(&other.expert_times[e]);
        }
        self.batches += other.batches;
        self.requests += other.requests;
        self.padding_waste.merge(&other.padding_waste);
        self.batch_occupancy.merge(&other.batch_occupancy);
        self.step_tokens.merge(&other.step_tokens);
        self.attn_dispatches_per_layer
            .merge(&other.attn_dispatches_per_layer);
        self.live_sessions.merge(&other.live_sessions);
        self.decode_tokens.merge(&other.decode_tokens);
        self.prefill_tokens.merge(&other.prefill_tokens);
        self.prefill_queue.merge(&other.prefill_queue);
        for &id in &other.request_ids {
            self.push_request_id(id);
        }
        for (id, n) in &other.chosen_backends {
            *self.chosen_backends.entry(id.clone()).or_insert(0) += n;
        }
        if self.bundle_digest.is_none() {
            self.bundle_digest = other.bundle_digest.clone();
        }
    }

    /// JSON dump for tooling.
    pub fn to_json(&self) -> Json {
        let mut pairs: Vec<(&str, Json)> = vec![
            ("batches", Json::num(self.batches as f64)),
            ("requests", Json::num(self.requests as f64)),
            (
                "expert_tokens",
                Json::arr_num(&[self.expert_tokens[0] as f64, self.expert_tokens[1] as f64]),
            ),
        ];
        let mut stage_obj = Vec::new();
        for (k, h) in &self.stages {
            let s = h.summary();
            stage_obj.push((
                k.as_str(),
                Json::obj(vec![
                    ("mean_ms", Json::num(s.mean)),
                    ("p50_ms", Json::num(s.p50)),
                    ("p99_ms", Json::num(s.p99)),
                    ("n", Json::num(s.n as f64)),
                ]),
            ));
        }
        pairs.push(("stages", Json::obj(stage_obj)));
        if let Some(s) = self.occupancy_summary() {
            pairs.push((
                "batch_occupancy",
                Json::obj(vec![
                    ("mean", Json::num(s.mean)),
                    ("p50", Json::num(s.p50)),
                    ("n", Json::num(s.n as f64)),
                ]),
            ));
        }
        if let Some(s) = self.step_tokens_summary() {
            pairs.push((
                "step_tokens",
                Json::obj(vec![
                    ("mean", Json::num(s.mean)),
                    ("p50", Json::num(s.p50)),
                    ("n", Json::num(s.n as f64)),
                ]),
            ));
        }
        if !self.attn_dispatches_per_layer.is_empty() {
            let s = self.attn_dispatches_per_layer.summary();
            pairs.push((
                "attn_dispatches_per_layer",
                Json::obj(vec![
                    ("mean", Json::num(s.mean)),
                    ("max", Json::num(s.max)),
                    ("n", Json::num(s.n as f64)),
                ]),
            ));
        }
        if !self.live_sessions.is_empty() {
            let s = self.live_sessions.summary();
            pairs.push((
                "live_sessions",
                Json::obj(vec![
                    ("mean", Json::num(s.mean)),
                    ("max", Json::num(s.max)),
                    ("n", Json::num(s.n as f64)),
                ]),
            ));
        }
        for (key, gauge) in [
            ("decode_tokens", &self.decode_tokens),
            ("prefill_tokens", &self.prefill_tokens),
            ("prefill_queue", &self.prefill_queue),
        ] {
            if gauge.is_empty() {
                continue;
            }
            let s = gauge.summary();
            pairs.push((
                key,
                Json::obj(vec![
                    ("mean", Json::num(s.mean)),
                    ("p99", Json::num(s.p99)),
                    ("max", Json::num(s.max)),
                    ("n", Json::num(s.n as f64)),
                ]),
            ));
        }
        if !self.chosen_backends.is_empty() {
            let chosen: Vec<(&str, Json)> = self
                .chosen_backends
                .iter()
                .map(|(id, n)| (id.as_str(), Json::num(*n as f64)))
                .collect();
            pairs.push(("chosen_backend", Json::obj(chosen)));
        }
        if !self.request_ids.is_empty() {
            let ids: Vec<f64> = self.request_ids.iter().map(|&id| id as f64).collect();
            pairs.push(("request_ids", Json::arr_num(&ids)));
        }
        if let Some(d) = &self.bundle_digest {
            pairs.push(("bundle_digest", Json::str(d)));
        }
        Json::obj(pairs)
    }

    /// Prometheus text exposition of the same registry `to_json` reads —
    /// the `/metrics.prom` (and `/metrics?format=prometheus`) body.
    pub fn to_prometheus(&self) -> String {
        let mut w = PromWriter::new();
        w.counter(
            "shiftaddvit_requests_total",
            "requests completed",
            &[],
            self.requests as f64,
        );
        w.counter(
            "shiftaddvit_batches_total",
            "fused engine steps",
            &[],
            self.batches as f64,
        );
        for e in 0..2 {
            let expert = if e == 0 { "0" } else { "1" };
            w.counter(
                "shiftaddvit_expert_tokens_total",
                "tokens routed per MoE expert",
                &[("expert", expert)],
                self.expert_tokens[e] as f64,
            );
            w.counter(
                "shiftaddvit_expert_gate_sum",
                "cumulative gate-value mass per MoE expert",
                &[("expert", expert)],
                self.expert_gates[e],
            );
            if !self.expert_times[e].is_empty() {
                w.histogram(
                    "shiftaddvit_expert_time_ms",
                    "measured per-expert batch time (ms)",
                    &[("expert", expert)],
                    &self.expert_times[e],
                );
            }
        }
        for (k, h) in &self.stages {
            w.histogram(
                "shiftaddvit_stage_duration_ms",
                "per-stage latency (ms)",
                &[("stage", k.as_str())],
                h,
            );
        }
        for (name, help, h) in [
            (
                "shiftaddvit_batch_occupancy",
                "per-step served/capacity fraction",
                &self.batch_occupancy,
            ),
            (
                "shiftaddvit_step_tokens",
                "token rows per fused step",
                &self.step_tokens,
            ),
            (
                "shiftaddvit_attn_dispatches_per_layer",
                "attention kernel calls per block layer per step",
                &self.attn_dispatches_per_layer,
            ),
            (
                "shiftaddvit_live_sessions",
                "live streaming sessions per step",
                &self.live_sessions,
            ),
            (
                "shiftaddvit_decode_tokens",
                "token rows advanced by the decode dispatch per step",
                &self.decode_tokens,
            ),
            (
                "shiftaddvit_prefill_tokens",
                "token rows fed by the budgeted prefill dispatch per step",
                &self.prefill_tokens,
            ),
            (
                "shiftaddvit_prefill_queue",
                "prefill queue depth at step start",
                &self.prefill_queue,
            ),
            (
                "shiftaddvit_padding_waste",
                "fraction of padded rows in bucketed batches",
                &self.padding_waste,
            ),
        ] {
            if !h.is_empty() {
                w.histogram(name, help, &[], h);
            }
        }
        for (id, n) in &self.chosen_backends {
            w.gauge(
                "shiftaddvit_planner_backend_shapes",
                "shapes resolved to each kernel backend at plan time",
                &[("backend", id.as_str())],
                *n as f64,
            );
        }
        if let Some(d) = &self.bundle_digest {
            w.gauge(
                "shiftaddvit_bundle_info",
                "digest of the verified bundle the engine warm-started from",
                &[("digest", d.as_str())],
                1.0,
            );
        }
        w.finish()
    }

    pub fn print(&self) {
        println!("-- serving metrics --");
        println!(
            "batches {}  requests {}  expert load split {:?}",
            self.batches,
            self.requests,
            self.load_split()
        );
        if let Some((imp, load)) = self.ll_loss() {
            println!("LL-loss diagnostics: L_IMP {imp:.4}  L_LOAD {load:.4}");
        }
        for (k, h) in &self.stages {
            let s = h.summary();
            println!(
                "  {k:28} mean {:8.3} ms  p50 {:8.3}  p99 {:8.3}  (n={})",
                s.mean, s.p50, s.p99, s.n
            );
        }
        if !self.padding_waste.is_empty() {
            println!(
                "  bucket padding waste: {:.1}%",
                100.0 * self.padding_waste.mean()
            );
        }
        if let Some(s) = self.occupancy_summary() {
            println!(
                "  batch occupancy: mean {:.1}%  p50 {:.1}%  (n={})",
                100.0 * s.mean,
                100.0 * s.p50,
                s.n
            );
        }
        if let Some(s) = self.step_tokens_summary() {
            println!(
                "  tokens per step: mean {:.1}  p50 {:.1}  (n={})",
                s.mean, s.p50, s.n
            );
        }
        if !self.attn_dispatches_per_layer.is_empty() {
            println!(
                "  attn dispatches per layer: mean {:.1}  max {:.0}",
                self.attn_dispatches_per_layer.mean(),
                self.attn_dispatches_per_layer.max()
            );
        }
        if !self.live_sessions.is_empty() {
            println!(
                "  live sessions per step: mean {:.1}  max {:.0}",
                self.live_sessions.mean(),
                self.live_sessions.max()
            );
        }
        if !self.decode_tokens.is_empty() {
            println!(
                "  decode tokens per step: mean {:.1}  p99 {:.0}  |  prefill: mean {:.1}  p99 {:.0}",
                self.decode_tokens.mean(),
                self.decode_tokens.percentile(0.99),
                self.prefill_tokens.mean(),
                self.prefill_tokens.percentile(0.99)
            );
        }
        if self.prefill_queue.max() > 0.0 {
            println!(
                "  prefill queue depth: mean {:.1}  max {:.0}",
                self.prefill_queue.mean(),
                self.prefill_queue.max()
            );
        }
        if !self.chosen_backends.is_empty() {
            let parts: Vec<String> = self
                .chosen_backends
                .iter()
                .map(|(id, n)| format!("{id}×{n}"))
                .collect();
            println!("  planned kernel backends: {}", parts.join("  "));
        }
        if let Some(d) = &self.bundle_digest {
            println!("  bundle digest: {d}");
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stage_accumulation() {
        let mut m = Metrics::default();
        m.record("stem", 1.0);
        m.record("stem", 3.0);
        let s = m.stage_summary("stem").unwrap();
        assert_eq!(s.n, 2);
        assert!((s.mean - 2.0).abs() < 1e-12);
        assert!(m.stage_summary("missing").is_none());
    }

    #[test]
    fn load_split_fractions() {
        let mut m = Metrics::default();
        m.expert_tokens = [30, 10];
        let f = m.load_split();
        assert!((f[0] - 0.75).abs() < 1e-12);
    }

    #[test]
    fn ll_loss_requires_both_experts() {
        let mut m = Metrics::default();
        assert!(m.ll_loss().is_none());
        m.expert_times[0].record(2.0);
        m.expert_times[1].record(1.0);
        m.expert_tokens = [100, 200];
        m.expert_gates = [60.0, 110.0];
        let (imp, load) = m.ll_loss().unwrap();
        assert!(imp >= 0.0 && load >= 0.0);
    }

    #[test]
    fn json_dump_parses() {
        let mut m = Metrics::default();
        m.record("head", 0.5);
        m.batches = 1;
        let j = m.to_json();
        assert_eq!(j.get("batches").unwrap().as_usize(), Some(1));
        assert!(j.get("batch_occupancy").is_none(), "no steps, no gauge");
    }

    #[test]
    fn chosen_backend_gauge_counts_per_id_and_serializes() {
        use crate::kernels::api::Primitive;
        use crate::kernels::planner::Shape;
        let mk = |p, backend: &str| Choice {
            primitive: p,
            shape: Shape::new(4, 4, 4),
            backend: backend.to_string(),
            measured_ms: Vec::new(),
        };
        let mut m = Metrics::default();
        assert!(m.to_json().get("chosen_backend").is_none(), "empty → absent");
        m.record_plan(&[
            mk(Primitive::MatAdd, "simd"),
            mk(Primitive::MatAdd, "simd"),
            mk(Primitive::MatShift, "rowpar"),
        ]);
        assert_eq!(m.chosen_backends.get("matadd/simd"), Some(&2));
        assert_eq!(m.chosen_backends.get("matshift/rowpar"), Some(&1));
        let j = m.to_json();
        let gauge = j.get("chosen_backend").expect("gauge serialized");
        assert_eq!(gauge.get("matadd/simd").and_then(|v| v.as_usize()), Some(2));
        // idempotent refresh: re-recording replaces, never double-counts
        m.record_plan(&[mk(Primitive::MatAdd, "simd")]);
        assert_eq!(m.chosen_backends.get("matadd/simd"), Some(&1));
        assert!(m.chosen_backends.get("matshift/rowpar").is_none());
        m.print(); // should not panic
    }

    #[test]
    fn request_id_trail_is_a_bounded_ring() {
        let mut m = Metrics::default();
        for id in 0..(REQUEST_ID_CAP + 10) {
            m.push_request_id(id);
        }
        assert_eq!(m.request_ids.len(), REQUEST_ID_CAP);
        assert_eq!(m.request_ids.front(), Some(&10), "oldest ids evicted FIFO");
        assert_eq!(m.request_ids.back(), Some(&(REQUEST_ID_CAP + 9)));
    }

    #[test]
    fn unbounded_traffic_needs_no_trimming() {
        // The cap_samples era is over: 100k samples cost the same fixed
        // footprint as 10, and nothing is dropped from the statistics.
        let mut m = Metrics::default();
        for i in 0..100_000 {
            m.record("http_classify", (i % 97) as f64 + 0.5);
            m.batch_occupancy.record(((i % 8) + 1) as f64 / 8.0);
            m.requests += 1;
        }
        assert_eq!(m.requests, 100_000);
        assert_eq!(m.stage_summary("http_classify").unwrap().n, 100_000);
        assert_eq!(m.batch_occupancy.count(), 100_000);
        // exact moments survive at any scale
        let s = m.stage_summary("http_classify").unwrap();
        assert!(s.mean > 0.0 && s.max <= 97.0);
    }

    #[test]
    fn merge_folds_counters_samples_and_request_ids() {
        let mut a = Metrics::default();
        a.record("stem", 1.0);
        a.batches = 2;
        a.requests = 3;
        a.expert_tokens = [10, 5];
        a.request_ids = VecDeque::from(vec![0, 2]);
        a.chosen_backends.insert("matadd/simd".into(), 2);
        let mut b = Metrics::default();
        b.record("stem", 3.0);
        b.record("head", 0.5);
        b.batches = 1;
        b.requests = 2;
        b.expert_tokens = [1, 4];
        b.request_ids = VecDeque::from(vec![1, 3]);
        b.chosen_backends.insert("matadd/simd".into(), 1);
        b.chosen_backends.insert("matshift/rowpar".into(), 1);
        b.bundle_digest = Some("abc123".to_string());
        a.merge(&b);
        assert_eq!(a.batches, 3);
        assert_eq!(a.requests, 5);
        assert_eq!(a.expert_tokens, [11, 9]);
        assert_eq!(a.bundle_digest.as_deref(), Some("abc123"));
        assert_eq!(a.stage_summary("stem").unwrap().n, 2);
        assert_eq!(a.stage_summary("head").unwrap().n, 1);
        assert_eq!(a.request_ids, vec![0, 2, 1, 3]);
        assert_eq!(a.chosen_backends.get("matadd/simd"), Some(&3));
        assert_eq!(a.chosen_backends.get("matshift/rowpar"), Some(&1));
        // request ids and the bundle digest round-trip through JSON
        let j = a.to_json();
        assert!(j.get("request_ids").is_some());
        assert_eq!(
            j.get("bundle_digest").and_then(|v| v.as_str()),
            Some("abc123")
        );
        // Clone gives an independent copy (fleet snapshot semantics)
        let c = a.clone();
        assert_eq!(c.requests, a.requests);
    }

    #[test]
    fn merged_percentiles_equal_solo_on_identical_traffic() {
        // Regression for the fleet-merge bias: N workers' histograms
        // merged must report exactly the percentiles one solo recorder
        // sees over the union of the samples (the old Vec concatenation
        // after per-worker capping biased toward the least-trimmed worker).
        let samples: Vec<f64> = (0..10_000).map(|i| ((i * 37) % 991) as f64 + 0.25).collect();
        let mut solo = Metrics::default();
        for &v in &samples {
            solo.record("http_classify", v);
        }
        let mut merged = Metrics::default();
        for w in 0..4 {
            let mut worker = Metrics::default();
            for (i, &v) in samples.iter().enumerate() {
                if i % 4 == w {
                    worker.record("http_classify", v);
                }
            }
            merged.merge(&worker);
        }
        let s = solo.stage_summary("http_classify").unwrap();
        let m = merged.stage_summary("http_classify").unwrap();
        assert_eq!(s.n, m.n);
        assert_eq!(s.mean, m.mean);
        assert_eq!(s.p50, m.p50);
        assert_eq!(s.p95, m.p95);
        assert_eq!(s.p99, m.p99);
        assert_eq!(s.max, m.max);
    }

    #[test]
    fn phase_gauges_merge_and_serialize() {
        let mut a = Metrics::default();
        assert!(a.to_json().get("decode_tokens").is_none(), "empty → absent");
        a.decode_tokens.record(8.0);
        a.prefill_tokens.record(16.0);
        a.prefill_queue.record(2.0);
        let mut b = Metrics::default();
        b.decode_tokens.record(4.0);
        b.prefill_tokens.record(0.0);
        b.prefill_queue.record(0.0);
        a.merge(&b);
        assert_eq!(a.decode_tokens.count(), 2);
        assert_eq!(a.decode_tokens.sum(), 12.0);
        assert_eq!(a.prefill_tokens.sum(), 16.0);
        assert_eq!(a.prefill_queue.max(), 2.0);
        let j = a.to_json();
        let dec = j.get("decode_tokens").expect("gauge serialized");
        assert_eq!(dec.get("n").and_then(|v| v.as_usize()), Some(2));
        assert!(j.get("prefill_tokens").is_some());
        assert!(j.get("prefill_queue").is_some());
        a.print(); // should not panic
    }

    #[test]
    fn occupancy_gauges_accumulate_and_serialize() {
        let mut m = Metrics::default();
        assert!(m.occupancy_summary().is_none());
        m.record_step_occupancy(2, 8, 128);
        m.record_step_occupancy(8, 8, 512);
        let occ = m.occupancy_summary().unwrap();
        assert_eq!(occ.n, 2);
        assert!((occ.mean - 0.625).abs() < 1e-12);
        let tok = m.step_tokens_summary().unwrap();
        assert!((tok.mean - 320.0).abs() < 1e-12);
        m.live_sessions.record(2.0);
        let j = m.to_json();
        assert!(j.get("batch_occupancy").is_some());
        assert!(j.get("step_tokens").is_some());
        assert!(j.get("live_sessions").is_some());
        m.print(); // should not panic
    }

    #[test]
    fn prometheus_exposition_lints_clean() {
        let mut m = Metrics::default();
        m.requests = 7;
        m.batches = 3;
        m.record("http_classify", 1.5);
        m.record("forward", 0.8);
        m.expert_times[0].record(0.4);
        m.expert_times[1].record(0.6);
        m.record_step_occupancy(4, 8, 64);
        m.chosen_backends.insert("matadd/simd".into(), 2);
        m.bundle_digest = Some("deadbeef".into());
        let text = m.to_prometheus();
        crate::obs::prom::lint(&text).expect("exposition lints clean");
        assert!(text.contains("# TYPE shiftaddvit_requests_total counter"));
        assert!(text.contains("shiftaddvit_requests_total 7"));
        assert!(text.contains("# TYPE shiftaddvit_stage_duration_ms histogram"));
        assert!(text.contains("stage=\"http_classify\""));
        assert!(text.contains("shiftaddvit_planner_backend_shapes{backend=\"matadd/simd\"} 2"));
    }
}
