//! Observability: request tracing, bounded histogram metrics, and
//! Prometheus/Chrome-trace export — all zero-dependency (DESIGN.md §6).
//!
//! Three pillars:
//! - [`trace`] — a process-global bounded ring of span events plus the
//!   [`trace::TraceCtx`] threaded through `Request`/`StreamOutput`, so one
//!   HTTP request yields a connected span tree: socket ingress → router
//!   placement → worker step → per-kernel grouped dispatch. Exported as
//!   Chrome trace-event JSON (`--trace-out`, `GET /trace`) for Perfetto.
//! - [`hist`] — fixed-size log-bucketed histograms backing
//!   `coordinator::metrics`: O(1) record, exact-count merge (fleet
//!   aggregation is unbiased), percentiles within a documented ≤19%
//!   bucket error while count/sum/mean/min/max stay exact.
//! - [`prom`] — Prometheus text exposition rendered from the same metrics
//!   that feed the JSON endpoints (`GET /metrics.prom`), plus the minimal
//!   format lint the test suite and CI smoke assert against.

pub mod hist;
pub mod prom;
pub mod trace;
