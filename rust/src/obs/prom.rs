//! Prometheus text exposition (version 0.0.4) rendered from the same
//! metrics registry that feeds the JSON endpoints — no external crates.
//!
//! [`PromWriter`] enforces the two invariants scrapers trip over most:
//! every sample line belongs to a family with exactly one `# TYPE` line,
//! and no two sample lines share a series key (name + label set).
//! Histograms emit cumulative `_bucket{le=...}` lines (empty runs are
//! compressed away; `+Inf`, `_sum` and `_count` are always present).

use std::collections::BTreeSet;
use std::fmt::Write as _;

use crate::obs::hist::{upper_bound, Hist, BUCKETS};

/// Builder for one exposition document.
#[derive(Default)]
pub struct PromWriter {
    out: String,
    typed: BTreeSet<String>,
    series: BTreeSet<String>,
}

fn escape_label(v: &str) -> String {
    let mut s = String::with_capacity(v.len());
    for c in v.chars() {
        match c {
            '\\' => s.push_str("\\\\"),
            '"' => s.push_str("\\\""),
            '\n' => s.push_str("\\n"),
            c => s.push(c),
        }
    }
    s
}

fn label_str(labels: &[(&str, &str)]) -> String {
    if labels.is_empty() {
        return String::new();
    }
    let inner: Vec<String> = labels
        .iter()
        .map(|(k, v)| format!("{k}=\"{}\"", escape_label(v)))
        .collect();
    format!("{{{}}}", inner.join(","))
}

fn fmt_value(v: f64) -> String {
    if v.is_infinite() {
        if v > 0.0 { "+Inf".into() } else { "-Inf".into() }
    } else if v.is_nan() {
        "NaN".into()
    } else {
        format!("{v}")
    }
}

impl PromWriter {
    pub fn new() -> PromWriter {
        PromWriter::default()
    }

    fn type_line(&mut self, name: &str, help: &str, kind: &str) {
        if self.typed.insert(name.to_string()) {
            let _ = writeln!(self.out, "# HELP {name} {help}");
            let _ = writeln!(self.out, "# TYPE {name} {kind}");
        }
    }

    fn sample(&mut self, name: &str, labels: &[(&str, &str)], value: f64) {
        let key = format!("{name}{}", label_str(labels));
        if !self.series.insert(key.clone()) {
            debug_assert!(false, "duplicate Prometheus series {key}");
            return;
        }
        let _ = writeln!(self.out, "{key} {}", fmt_value(value));
    }

    pub fn counter(&mut self, name: &str, help: &str, labels: &[(&str, &str)], value: f64) {
        self.type_line(name, help, "counter");
        self.sample(name, labels, value);
    }

    pub fn gauge(&mut self, name: &str, help: &str, labels: &[(&str, &str)], value: f64) {
        self.type_line(name, help, "gauge");
        self.sample(name, labels, value);
    }

    /// Emit one labeled histogram series set from a [`Hist`]: cumulative
    /// `_bucket` lines (runs of unchanged cumulative count are skipped),
    /// then `_sum` and `_count`.
    pub fn histogram(&mut self, name: &str, help: &str, labels: &[(&str, &str)], h: &Hist) {
        self.type_line(name, help, "histogram");
        let bucket = format!("{name}_bucket");
        let mut cum = 0u64;
        let mut last_emitted = u64::MAX;
        for (i, &c) in h.bucket_counts().iter().enumerate() {
            cum += c;
            let is_last = i == BUCKETS - 1;
            if cum == last_emitted && !is_last {
                continue;
            }
            let le = fmt_value(upper_bound(i));
            let mut ls: Vec<(&str, &str)> = labels.to_vec();
            ls.push(("le", le.as_str()));
            self.sample(&bucket, &ls, cum as f64);
            last_emitted = cum;
        }
        self.sample(&format!("{name}_sum"), labels, h.sum());
        self.sample(&format!("{name}_count"), labels, h.count() as f64);
    }

    pub fn finish(self) -> String {
        self.out
    }
}

/// Minimal exposition-format lint (what the property suite and CI smoke
/// assert): every sample line belongs to a `# TYPE`-declared family
/// (histogram suffixes `_bucket`/`_sum`/`_count` resolve to their base
/// family), and no two sample lines repeat a series key.
pub fn lint(text: &str) -> Result<(), String> {
    use std::collections::BTreeMap;
    let mut types: BTreeMap<String, String> = BTreeMap::new();
    let mut seen: BTreeSet<String> = BTreeSet::new();
    for (no, line) in text.lines().enumerate() {
        let line = line.trim_end();
        if line.is_empty() {
            continue;
        }
        if let Some(rest) = line.strip_prefix("# TYPE ") {
            let mut it = rest.split_whitespace();
            let name = it.next().ok_or_else(|| format!("line {no}: bare TYPE"))?;
            let kind = it.next().ok_or_else(|| format!("line {no}: TYPE without kind"))?;
            if types.insert(name.to_string(), kind.to_string()).is_some() {
                return Err(format!("line {no}: duplicate TYPE for {name}"));
            }
            continue;
        }
        if line.starts_with('#') {
            continue;
        }
        // sample line: name[{labels}] value
        let name_end = line
            .find(|c: char| c == '{' || c == ' ')
            .ok_or_else(|| format!("line {no}: malformed sample: {line}"))?;
        let name = &line[..name_end];
        let series_end = if line.as_bytes()[name_end] == b'{' {
            line.find('}')
                .ok_or_else(|| format!("line {no}: unclosed labels: {line}"))?
                + 1
        } else {
            name_end
        };
        let series = &line[..series_end];
        let value = line[series_end..].trim();
        value
            .parse::<f64>()
            .or_else(|e| match value {
                "+Inf" | "-Inf" | "NaN" => Ok(0.0),
                _ => Err(e),
            })
            .map_err(|e| format!("line {no}: bad value '{value}': {e}"))?;
        let family = ["_bucket", "_sum", "_count"]
            .iter()
            .find_map(|suf| {
                let base = name.strip_suffix(suf)?;
                (types.get(base).map(String::as_str) == Some("histogram")).then_some(base)
            })
            .unwrap_or(name);
        if !types.contains_key(family) {
            return Err(format!("line {no}: sample {name} has no # TYPE for {family}"));
        }
        if !seen.insert(series.to_string()) {
            return Err(format!("line {no}: duplicate series {series}"));
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn writer_output_passes_lint() {
        let mut w = PromWriter::new();
        w.counter("sa_requests_total", "requests served", &[], 42.0);
        w.gauge("sa_live", "live sessions", &[("worker", "0")], 3.0);
        let mut h = Hist::new();
        for i in 1..200 {
            h.record(i as f64 * 0.1);
        }
        w.histogram("sa_stage_ms", "stage timings", &[("stage", "forward")], &h);
        w.histogram("sa_stage_ms", "stage timings", &[("stage", "dispatch")], &h);
        let text = w.finish();
        lint(&text).expect("writer output lints clean");
        // one TYPE line even with two label sets in the family
        assert_eq!(text.matches("# TYPE sa_stage_ms histogram").count(), 1);
        assert!(text.contains("le=\"+Inf\""));
    }

    #[test]
    fn lint_rejects_untyped_and_duplicate_series() {
        assert!(lint("nope 1\n").is_err());
        let dup = "# TYPE a counter\na 1\na 2\n";
        assert!(lint(dup).is_err());
        let ok = "# TYPE a counter\na{x=\"1\"} 1\na{x=\"2\"} 2\n";
        assert!(lint(ok).is_ok());
    }

    #[test]
    fn label_values_are_escaped() {
        let mut w = PromWriter::new();
        w.gauge("g", "h", &[("k", "a\"b\\c\nd")], 1.0);
        let text = w.finish();
        assert!(text.contains("k=\"a\\\"b\\\\c\\nd\""));
        lint(&text).expect("escaped labels lint clean");
    }
}
