//! Fixed-size log-bucketed latency/size histograms — the bounded
//! replacement for the per-sample `Vec<f64>` buffers that
//! `coordinator::metrics` used to trim with `cap_samples`.
//!
//! Layout: 64 half-octave buckets (successive upper bounds grow by √2)
//! spanning `2^-16 .. 2^16`, which covers sub-microsecond stage timings in
//! milliseconds up through 65 k-token gauges. Recording is O(1) and
//! allocation-free; `merge` adds bucket counts exactly, so fleet-merged
//! percentiles equal the percentiles a single recorder would have produced
//! over the union of the samples — no per-worker trimming bias.
//!
//! Accuracy: percentiles are reported at the geometric midpoint of the
//! selected bucket (clamped into the exact observed `[min, max]`), so the
//! relative error of any quantile is at most `2^(1/4) − 1 ≈ 19%` for
//! in-range positive samples. Count, sum, mean, min and max are exact.

use crate::util::stats::Summary;

/// Number of buckets (half-octaves over `2^-16 .. 2^16`).
pub const BUCKETS: usize = 64;

/// log2 of bucket 0's lower edge.
const MIN_EXP: f64 = -16.0;

/// Buckets per octave (√2 spacing).
const PER_OCTAVE: f64 = 2.0;

/// A bounded histogram: 64 bucket counts plus exact count/sum/sum²/min/max.
#[derive(Clone, Debug)]
pub struct Hist {
    counts: [u64; BUCKETS],
    count: u64,
    sum: f64,
    sum_sq: f64,
    min: f64,
    max: f64,
}

impl Default for Hist {
    fn default() -> Self {
        Hist {
            counts: [0; BUCKETS],
            count: 0,
            sum: 0.0,
            sum_sq: 0.0,
            min: f64::INFINITY,
            max: f64::NEG_INFINITY,
        }
    }
}

/// Bucket index for a sample: values ≤ 0 (and NaN) land in bucket 0,
/// values above the range clamp into the top bucket.
fn index(v: f64) -> usize {
    if !(v > 0.0) {
        return 0;
    }
    let i = ((v.log2() - MIN_EXP) * PER_OCTAVE).floor();
    if i < 0.0 {
        0
    } else if i >= (BUCKETS - 1) as f64 {
        BUCKETS - 1
    } else {
        i as usize
    }
}

/// Upper edge of bucket `i` (inclusive for classification purposes). The
/// top bucket is unbounded (`+∞`) because overflow clamps into it.
pub fn upper_bound(i: usize) -> f64 {
    if i >= BUCKETS - 1 {
        f64::INFINITY
    } else {
        ((MIN_EXP + (i as f64 + 1.0) / PER_OCTAVE) * std::f64::consts::LN_2).exp()
    }
}

/// Geometric midpoint of bucket `i` — the representative value percentile
/// queries report (before clamping into the exact `[min, max]`).
fn midpoint(i: usize) -> f64 {
    ((MIN_EXP + (i as f64 + 0.5) / PER_OCTAVE) * std::f64::consts::LN_2).exp()
}

impl Hist {
    pub fn new() -> Hist {
        Hist::default()
    }

    /// Record one sample. O(1), allocation-free.
    pub fn record(&mut self, v: f64) {
        self.counts[index(v)] += 1;
        self.count += 1;
        self.sum += v;
        self.sum_sq += v * v;
        if v < self.min {
            self.min = v;
        }
        if v > self.max {
            self.max = v;
        }
    }

    pub fn is_empty(&self) -> bool {
        self.count == 0
    }

    /// Exact number of recorded samples.
    pub fn count(&self) -> u64 {
        self.count
    }

    /// Exact sum of recorded samples.
    pub fn sum(&self) -> f64 {
        self.sum
    }

    /// Exact mean (0 when empty).
    pub fn mean(&self) -> f64 {
        if self.count == 0 {
            0.0
        } else {
            self.sum / self.count as f64
        }
    }

    /// Exact minimum (0 when empty).
    pub fn min(&self) -> f64 {
        if self.count == 0 {
            0.0
        } else {
            self.min
        }
    }

    /// Exact maximum (0 when empty).
    pub fn max(&self) -> f64 {
        if self.count == 0 {
            0.0
        } else {
            self.max
        }
    }

    /// Raw bucket counts (for Prometheus `_bucket` series).
    pub fn bucket_counts(&self) -> &[u64; BUCKETS] {
        &self.counts
    }

    /// Fold another histogram in. Bucket counts add exactly, so the merge
    /// of N workers' histograms yields the same percentiles as one
    /// histogram fed all N workers' samples — the property the fleet
    /// aggregation path relies on.
    pub fn merge(&mut self, other: &Hist) {
        for i in 0..BUCKETS {
            self.counts[i] += other.counts[i];
        }
        self.count += other.count;
        self.sum += other.sum;
        self.sum_sq += other.sum_sq;
        if other.count > 0 {
            if other.min < self.min {
                self.min = other.min;
            }
            if other.max > self.max {
                self.max = other.max;
            }
        }
    }

    /// Quantile `q ∈ [0, 1]` with bucket resolution: walks cumulative
    /// counts to the bucket holding rank `q·(n−1)` and reports its
    /// geometric midpoint clamped into the exact `[min, max]`. Relative
    /// error ≤ `2^(1/4) − 1 ≈ 19%` for in-range positive samples; exact
    /// when all samples share one value.
    pub fn percentile(&self, q: f64) -> f64 {
        if self.count == 0 {
            return 0.0;
        }
        let target = q.clamp(0.0, 1.0) * (self.count - 1) as f64;
        let mut cum = 0u64;
        for (i, &c) in self.counts.iter().enumerate() {
            cum += c;
            if cum as f64 > target {
                return midpoint(i).clamp(self.min, self.max);
            }
        }
        self.max()
    }

    /// Standard deviation from the exact moment sums (0 when empty).
    pub fn std(&self) -> f64 {
        if self.count == 0 {
            return 0.0;
        }
        let mean = self.mean();
        (self.sum_sq / self.count as f64 - mean * mean).max(0.0).sqrt()
    }

    /// Project into the repo-wide [`Summary`] shape: n/mean/std/min/max are
    /// exact, percentiles carry the documented bucket error.
    pub fn summary(&self) -> Summary {
        if self.count == 0 {
            return Summary::empty();
        }
        Summary {
            n: self.count as usize,
            mean: self.mean(),
            std: self.std(),
            min: self.min(),
            p50: self.percentile(0.50),
            p90: self.percentile(0.90),
            p95: self.percentile(0.95),
            p99: self.percentile(0.99),
            max: self.max(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn empty_hist_is_zeroed() {
        let h = Hist::new();
        assert!(h.is_empty());
        assert_eq!(h.count(), 0);
        assert_eq!(h.mean(), 0.0);
        assert_eq!(h.percentile(0.5), 0.0);
        assert_eq!(h.summary().n, 0);
    }

    #[test]
    fn constant_samples_are_exact() {
        let mut h = Hist::new();
        for _ in 0..100 {
            h.record(5.0);
        }
        assert_eq!(h.count(), 100);
        assert_eq!(h.mean(), 5.0);
        // min == max == 5 ⇒ the midpoint clamps to the exact value
        assert_eq!(h.percentile(0.5), 5.0);
        assert_eq!(h.percentile(0.99), 5.0);
        assert_eq!(h.summary().std, 0.0);
    }

    #[test]
    fn percentiles_within_documented_bucket_error() {
        let mut h = Hist::new();
        let samples: Vec<f64> = (1..=1000).map(|i| i as f64 * 0.37).collect();
        for &s in &samples {
            h.record(s);
        }
        let mut sorted = samples.clone();
        sorted.sort_by(|a, b| a.total_cmp(b));
        for q in [0.5, 0.9, 0.95, 0.99] {
            let exact = crate::util::stats::percentile(&sorted, q);
            let approx = h.percentile(q);
            assert!(
                (approx - exact).abs() / exact <= 0.20,
                "q={q}: approx {approx} vs exact {exact}"
            );
        }
    }

    #[test]
    fn merge_equals_union() {
        let mut a = Hist::new();
        let mut b = Hist::new();
        let mut all = Hist::new();
        for i in 0..500 {
            let v = (i as f64 * 0.13).exp().min(1e4);
            if i % 2 == 0 {
                a.record(v);
            } else {
                b.record(v);
            }
            all.record(v);
        }
        a.merge(&b);
        assert_eq!(a.count(), all.count());
        assert_eq!(a.sum(), all.sum());
        for q in [0.1, 0.5, 0.9, 0.99] {
            assert_eq!(a.percentile(q), all.percentile(q));
        }
    }

    #[test]
    fn zero_and_overflow_samples_are_counted() {
        let mut h = Hist::new();
        h.record(0.0);
        h.record(1e12); // far above the top bucket's edge
        assert_eq!(h.count(), 2);
        assert_eq!(h.min(), 0.0);
        assert_eq!(h.max(), 1e12);
        // percentiles stay inside the observed range
        assert!(h.percentile(0.99) <= 1e12);
    }

    #[test]
    fn upper_bounds_grow_by_sqrt_two() {
        let r = upper_bound(10) / upper_bound(9);
        assert!((r - std::f64::consts::SQRT_2).abs() < 1e-9);
        assert!(upper_bound(BUCKETS - 1).is_infinite());
    }
}
