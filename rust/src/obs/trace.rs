//! Request tracing: a process-global bounded ring of span events plus the
//! [`TraceCtx`] threaded through `Request`/`StreamOutput` so one request
//! produces a connected span tree from the HTTP socket down to individual
//! grouped kernel dispatches.
//!
//! Design constraints (DESIGN.md §6: no external crates):
//! - **Off means free**: every hot-path entry checks one relaxed atomic and
//!   returns an inert guard without allocating. Call sites that format
//!   span arguments guard the formatting behind [`enabled`].
//! - **Bounded**: the ring holds at most [`RING_CAP`] finished spans;
//!   older spans are evicted FIFO, mirroring how the histogram metrics
//!   bound their memory.
//! - **Deterministic ids**: span/trace ids come from one process-global
//!   counter ([`reset`] rewinds it), so identical single-threaded
//!   executions emit identical id sequences — tests walk parent links by
//!   value.
//!
//! Spans are recorded when their RAII [`SpanGuard`] drops, so the ring
//! stores children before parents; [`export_chrome`] emits Chrome
//! trace-event JSON (`ph: "X"` complete events, µs timestamps) loadable in
//! Perfetto or `chrome://tracing`.

use std::cell::Cell;
use std::collections::VecDeque;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Mutex, OnceLock};
use std::time::Instant;

use crate::util::json::Json;

/// Maximum finished spans held; older spans are evicted FIFO.
pub const RING_CAP: usize = 65_536;

/// Trace id + parent span id carried by a request as it crosses threads.
/// `NONE` (all zeros) means "not traced" — spans opened under it become
/// roots of fresh traces when recording is on.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct TraceCtx {
    pub trace: u64,
    pub span: u64,
}

impl TraceCtx {
    pub const NONE: TraceCtx = TraceCtx { trace: 0, span: 0 };

    pub fn is_active(&self) -> bool {
        self.trace != 0
    }
}

/// One finished span.
#[derive(Clone, Debug)]
pub struct SpanEvent {
    pub trace: u64,
    pub id: u64,
    /// 0 for roots.
    pub parent: u64,
    pub name: String,
    /// µs since the recorder epoch.
    pub start_us: f64,
    pub dur_us: f64,
    /// Small dense per-thread id (Chrome `tid`).
    pub tid: u64,
    pub args: Vec<(String, String)>,
}

static ENABLED: AtomicBool = AtomicBool::new(false);
static NEXT_ID: AtomicU64 = AtomicU64::new(1);
static NEXT_TID: AtomicU64 = AtomicU64::new(1);

fn ring() -> &'static Mutex<VecDeque<SpanEvent>> {
    static RING: OnceLock<Mutex<VecDeque<SpanEvent>>> = OnceLock::new();
    RING.get_or_init(|| Mutex::new(VecDeque::new()))
}

fn epoch() -> Instant {
    static EPOCH: OnceLock<Instant> = OnceLock::new();
    *EPOCH.get_or_init(Instant::now)
}

thread_local! {
    static CURRENT: Cell<TraceCtx> = Cell::new(TraceCtx::NONE);
    static TID: Cell<u64> = Cell::new(0);
}

fn tid() -> u64 {
    TID.with(|t| {
        if t.get() == 0 {
            t.set(NEXT_TID.fetch_add(1, Ordering::Relaxed));
        }
        t.get()
    })
}

/// Is the recorder on? Hot paths check this before formatting span args.
pub fn enabled() -> bool {
    ENABLED.load(Ordering::Relaxed)
}

pub fn set_enabled(on: bool) {
    ENABLED.store(on, Ordering::SeqCst);
}

/// Rewind the id counter and drop all recorded spans (tests; fresh runs).
pub fn reset() {
    NEXT_ID.store(1, Ordering::SeqCst);
    ring().lock().unwrap().clear();
}

/// Number of finished spans currently held.
pub fn len() -> usize {
    ring().lock().unwrap().len()
}

/// Snapshot of all finished spans (oldest first).
pub fn events() -> Vec<SpanEvent> {
    ring().lock().unwrap().iter().cloned().collect()
}

/// The calling thread's ambient context (set around engine steps so kernel
/// dispatches deep in the forward pass can parent themselves without every
/// intermediate signature carrying a `TraceCtx`).
pub fn current() -> TraceCtx {
    CURRENT.with(|c| c.get())
}

/// Install `ctx` as the thread's ambient context until the guard drops
/// (the previous value is restored, so nesting works).
pub fn set_current(ctx: TraceCtx) -> CurrentGuard {
    let prev = CURRENT.with(|c| c.replace(ctx));
    CurrentGuard { prev }
}

pub struct CurrentGuard {
    prev: TraceCtx,
}

impl Drop for CurrentGuard {
    fn drop(&mut self) {
        let prev = self.prev;
        CURRENT.with(|c| c.set(prev));
    }
}

struct SpanInner {
    ctx: TraceCtx,
    parent: u64,
    name: String,
    args: Vec<(String, String)>,
    start: Instant,
    start_us: f64,
}

/// RAII span: opened by [`span`]/[`root`], recorded into the ring when
/// dropped. Inert (no allocation, no time capture) while recording is off.
pub struct SpanGuard {
    inner: Option<SpanInner>,
}

impl SpanGuard {
    /// The context children should use as their parent. `NONE` when the
    /// recorder was off at open time.
    pub fn ctx(&self) -> TraceCtx {
        self.inner.as_ref().map(|i| i.ctx).unwrap_or(TraceCtx::NONE)
    }

    /// Attach a key/value argument (shown in the Perfetto side panel).
    /// No-op on inert guards.
    pub fn arg(&mut self, key: &str, value: impl Into<String>) {
        if let Some(i) = &mut self.inner {
            i.args.push((key.to_string(), value.into()));
        }
    }
}

impl Drop for SpanGuard {
    fn drop(&mut self) {
        if let Some(i) = self.inner.take() {
            let dur_us = i.start.elapsed().as_secs_f64() * 1e6;
            let ev = SpanEvent {
                trace: i.ctx.trace,
                id: i.ctx.span,
                parent: i.parent,
                name: i.name,
                start_us: i.start_us,
                dur_us,
                tid: tid(),
                args: i.args,
            };
            let mut r = ring().lock().unwrap();
            if r.len() >= RING_CAP {
                r.pop_front();
            }
            r.push_back(ev);
        }
    }
}

/// Open a span under `parent`. If `parent` is [`TraceCtx::NONE`] the span
/// roots a fresh trace. Inert when recording is off.
pub fn span(name: &str, parent: TraceCtx) -> SpanGuard {
    if !enabled() {
        return SpanGuard { inner: None };
    }
    let id = NEXT_ID.fetch_add(1, Ordering::SeqCst);
    let (trace, parent_span) = if parent.is_active() {
        (parent.trace, parent.span)
    } else {
        (NEXT_ID.fetch_add(1, Ordering::SeqCst), 0)
    };
    let start = Instant::now();
    let start_us = start.duration_since(epoch()).as_secs_f64() * 1e6;
    SpanGuard {
        inner: Some(SpanInner {
            ctx: TraceCtx { trace, span: id },
            parent: parent_span,
            name: name.to_string(),
            args: Vec::new(),
            start,
            start_us,
        }),
    }
}

/// Open a root span of a brand-new trace (request ingress).
pub fn root(name: &str) -> SpanGuard {
    span(name, TraceCtx::NONE)
}

fn event_json(e: &SpanEvent) -> Json {
    let mut args = std::collections::BTreeMap::new();
    args.insert("trace_id".to_string(), Json::num(e.trace as f64));
    args.insert("span_id".to_string(), Json::num(e.id as f64));
    args.insert("parent_id".to_string(), Json::num(e.parent as f64));
    for (k, v) in &e.args {
        args.insert(k.clone(), Json::str(v.clone()));
    }
    Json::obj(vec![
        ("name", Json::str(e.name.clone())),
        ("cat", Json::str("shiftaddvit")),
        ("ph", Json::str("X")),
        ("ts", Json::num(e.start_us)),
        ("dur", Json::num(e.dur_us)),
        ("pid", Json::num(1.0)),
        ("tid", Json::num(e.tid as f64)),
        ("args", Json::Obj(args)),
    ])
}

/// Export all recorded spans as Chrome trace-event JSON (the object form:
/// `{"traceEvents": [...]}`), loadable in Perfetto / `chrome://tracing`.
/// Span/parent/trace ids ride in each event's `args`, so tools (and the
/// repo's tests) can walk the tree structurally.
pub fn export_chrome() -> Json {
    let events = events();
    Json::obj(vec![
        (
            "traceEvents",
            Json::Arr(events.iter().map(event_json).collect()),
        ),
        ("displayTimeUnit", Json::str("ms")),
    ])
}

#[cfg(test)]
mod tests {
    use super::*;

    // The recorder is process-global; unit tests that toggle it serialize
    // on this lock so parallel test threads don't interleave rings.
    fn test_lock() -> &'static Mutex<()> {
        static LOCK: OnceLock<Mutex<()>> = OnceLock::new();
        LOCK.get_or_init(|| Mutex::new(()))
    }

    #[test]
    fn disabled_recorder_emits_nothing() {
        let _l = test_lock().lock().unwrap();
        set_enabled(false);
        reset();
        {
            let mut s = root("noop");
            s.arg("k", "v");
            assert_eq!(s.ctx(), TraceCtx::NONE);
        }
        assert_eq!(len(), 0);
    }

    #[test]
    fn spans_nest_and_link_parents() {
        let _l = test_lock().lock().unwrap();
        set_enabled(true);
        reset();
        let (root_ctx, child_ctx);
        {
            let r = root("ingress");
            root_ctx = r.ctx();
            {
                let c = span("step", r.ctx());
                child_ctx = c.ctx();
                let _g = set_current(c.ctx());
                let _k = span("kernel", current());
            }
        }
        set_enabled(false);
        let evs = events();
        assert_eq!(evs.len(), 3);
        // children recorded before parents (drop order)
        assert_eq!(evs[0].name, "kernel");
        assert_eq!(evs[1].name, "step");
        assert_eq!(evs[2].name, "ingress");
        assert_eq!(evs[2].parent, 0);
        assert_eq!(evs[1].parent, root_ctx.span);
        assert_eq!(evs[0].parent, child_ctx.span);
        assert!(evs.iter().all(|e| e.trace == root_ctx.trace));
        reset();
    }

    #[test]
    fn current_guard_restores_previous_ctx() {
        let _l = test_lock().lock().unwrap();
        let outer = TraceCtx { trace: 7, span: 9 };
        let _a = set_current(outer);
        {
            let inner = TraceCtx { trace: 7, span: 11 };
            let _b = set_current(inner);
            assert_eq!(current(), inner);
        }
        assert_eq!(current(), outer);
    }

    #[test]
    fn ring_is_bounded() {
        let _l = test_lock().lock().unwrap();
        set_enabled(true);
        reset();
        for _ in 0..(RING_CAP + 10) {
            let _s = root("x");
        }
        assert_eq!(len(), RING_CAP);
        set_enabled(false);
        reset();
    }

    #[test]
    fn chrome_export_parses_and_carries_ids() {
        let _l = test_lock().lock().unwrap();
        set_enabled(true);
        reset();
        {
            let r = root("req");
            let _c = span("work", r.ctx());
        }
        set_enabled(false);
        let text = export_chrome().to_string();
        reset();
        let v = Json::parse(&text).expect("chrome trace JSON parses");
        let evs = v.get("traceEvents").unwrap().as_arr().unwrap();
        assert_eq!(evs.len(), 2);
        for e in evs {
            assert_eq!(e.get("ph").unwrap().as_str(), Some("X"));
            assert!(e.get("args").unwrap().get("span_id").is_some());
        }
    }
}
