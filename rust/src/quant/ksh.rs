//! Kernelized-hashing binarization (Ecoformer [34] stand-in): hash codes are
//! signs of a fixed random projection, `h(x) = sign(x R)`. Shared projection
//! for Q and K (KSH requires Q ≡ K treatment — paper §5.4 observation (2)).

use crate::quant::binary::binarize;
use crate::util::rng::XorShift64;

/// A KSH hash family: `bits` random hyperplanes in `dim` dimensions.
#[derive(Clone, Debug)]
pub struct KshHasher {
    pub dim: usize,
    pub bits: usize,
    /// (dim × bits) row-major projection.
    pub proj: Vec<f32>,
}

impl KshHasher {
    pub fn new(dim: usize, bits: usize, seed: u64) -> KshHasher {
        let mut rng = XorShift64::new(seed);
        let scale = 1.0 / (dim as f32).sqrt();
        let proj = (0..dim * bits).map(|_| rng.normal() * scale).collect();
        KshHasher { dim, bits, proj }
    }

    /// Hash one vector to ±1 codes.
    pub fn hash(&self, x: &[f32]) -> Vec<i8> {
        assert_eq!(x.len(), self.dim);
        let mut proj_out = vec![0.0f32; self.bits];
        for (i, &xi) in x.iter().enumerate() {
            let row = &self.proj[i * self.bits..(i + 1) * self.bits];
            for (o, &p) in proj_out.iter_mut().zip(row) {
                *o += xi * p;
            }
        }
        binarize(&proj_out)
    }

    /// Hash a row-major (n × dim) matrix to (n × bits) codes.
    pub fn hash_matrix(&self, xs: &[f32], n: usize) -> Vec<i8> {
        let mut out = Vec::with_capacity(n * self.bits);
        for r in 0..n {
            out.extend(self.hash(&xs[r * self.dim..(r + 1) * self.dim]));
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn hash_is_deterministic() {
        let h = KshHasher::new(8, 16, 1);
        let x: Vec<f32> = (0..8).map(|i| i as f32 - 3.5).collect();
        assert_eq!(h.hash(&x), h.hash(&x));
    }

    #[test]
    fn similar_vectors_share_most_bits() {
        // LSH property: nearby vectors collide on most hyperplanes.
        let h = KshHasher::new(16, 64, 2);
        let mut rng = XorShift64::new(3);
        let x = rng.normals(16);
        let mut y = x.clone();
        y[0] += 0.01;
        let hx = h.hash(&x);
        let hy = h.hash(&y);
        let matches = hx.iter().zip(&hy).filter(|(a, b)| a == b).count();
        assert!(matches >= 60, "only {matches}/64 bits match");
    }

    #[test]
    fn opposite_vectors_flip_all_bits() {
        let h = KshHasher::new(8, 32, 4);
        let mut rng = XorShift64::new(5);
        let x = rng.normals(8);
        let neg: Vec<f32> = x.iter().map(|v| -v).collect();
        let hx = h.hash(&x);
        let hn = h.hash(&neg);
        // sign(-xR) = -sign(xR) except exact zeros (measure zero).
        assert!(hx.iter().zip(&hn).all(|(a, b)| *a == -*b));
    }
}
