//! Vanilla binary quantization [27] of activations: msign(x) ∈ {-1,+1},
//! plus a per-tensor scaling factor applied *after* accumulation (Appendix E
//! "the scaling factor can be multiplied after add operations").

/// Binarize to ±1 (0 maps to +1, matching `ref.binary_quantize`).
pub fn binarize(x: &[f32]) -> Vec<i8> {
    x.iter().map(|&v| if v >= 0.0 { 1 } else { -1 }).collect()
}

/// Mean-|x| scaling factor (layer-wise); multiply MatAdd outputs by this to
/// approximate the full-precision product.
pub fn scale(x: &[f32]) -> f32 {
    if x.is_empty() {
        return 1.0;
    }
    x.iter().map(|v| v.abs()).sum::<f32>() / x.len() as f32
}

/// Pack ±1 codes into u64 words (bit = 1 for +1) for popcount-based Hamming
/// kernels: 64 codes per word — the deployment format.
pub fn pack_bits(codes: &[i8]) -> Vec<u64> {
    let mut out = vec![0u64; codes.len().div_ceil(64)];
    for (i, &c) in codes.iter().enumerate() {
        if c > 0 {
            out[i / 64] |= 1 << (i % 64);
        }
    }
    out
}

/// Hamming *similarity* (matching positions) between two packed rows of
/// `bits` valid bits: matches = bits - popcount(a ^ b).
pub fn hamming_sim(a: &[u64], b: &[u64], bits: usize) -> u32 {
    let mut diff = 0u32;
    for (x, y) in a.iter().zip(b) {
        diff += (x ^ y).count_ones();
    }
    bits as u32 - diff
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn binarize_signs() {
        assert_eq!(binarize(&[0.5, -0.1, 0.0]), vec![1, -1, 1]);
    }

    #[test]
    fn scale_is_mean_abs() {
        assert!((scale(&[1.0, -3.0]) - 2.0).abs() < 1e-6);
    }

    #[test]
    fn pack_roundtrip_via_hamming() {
        let a = vec![1i8, -1, 1, 1, -1];
        let pa = pack_bits(&a);
        // identical rows → all 5 positions match
        assert_eq!(hamming_sim(&pa, &pa, 5), 5);
        let b = vec![1i8, 1, 1, 1, -1]; // one flip
        let pb = pack_bits(&b);
        assert_eq!(hamming_sim(&pa, &pb, 5), 4);
    }

    #[test]
    fn hamming_matches_dot_product_identity() {
        // For ±1 vectors: dot = 2·matches − d.
        let a = vec![1i8, -1, -1, 1, 1, -1, 1, 1];
        let b = vec![-1i8, -1, 1, 1, -1, -1, 1, -1];
        let dot: i32 = a.iter().zip(&b).map(|(&x, &y)| (x as i32) * (y as i32)).sum();
        let m = hamming_sim(&pack_bits(&a), &pack_bits(&b), 8) as i32;
        assert_eq!(dot, 2 * m - 8);
    }
}
