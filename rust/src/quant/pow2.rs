//! Power-of-two (shift) weight reparameterization: `W ≈ s · 2^P`
//! (DeepShift-PS [17]; paper Eq. 3). Mirrors `ref.pow2_quantize`.

/// A weight matrix stored as sign and exponent INT8 planes — the storage
/// format the MatShift kernel consumes (4× smaller than f32; the paper's
/// data-movement argument).
#[derive(Clone, Debug)]
pub struct Pow2Weights {
    pub rows: usize,
    pub cols: usize,
    /// sign ∈ {-1, +1}
    pub sign: Vec<i8>,
    /// exponent ∈ [P_MIN, P_MAX]
    pub exp: Vec<i8>,
}

pub const P_MIN: i8 = -8;
pub const P_MAX: i8 = 7;

/// Quantize a dense row-major matrix to (sign, exponent) planes.
pub fn quantize(w: &[f32], rows: usize, cols: usize) -> Pow2Weights {
    assert_eq!(w.len(), rows * cols);
    let mut sign = Vec::with_capacity(w.len());
    let mut exp = Vec::with_capacity(w.len());
    for &v in w {
        sign.push(if v < 0.0 { -1 } else { 1 });
        let a = v.abs();
        let p = if a > 0.0 {
            a.log2().round().clamp(P_MIN as f32, P_MAX as f32) as i8
        } else {
            P_MIN
        };
        exp.push(p);
    }
    Pow2Weights {
        rows,
        cols,
        sign,
        exp,
    }
}

/// Reconstruct float weights (for oracle comparisons).
pub fn dequantize(q: &Pow2Weights) -> Vec<f32> {
    q.sign
        .iter()
        .zip(&q.exp)
        .map(|(&s, &p)| s as f32 * (p as f32).exp2())
        .collect()
}

/// Quantization error (relative, per element) — bounded by the octave:
/// `|wq/w| ∈ [2^-0.5, 2^0.5]` wherever `|w| ∈ [2^P_MIN, 2^P_MAX]`.
pub fn max_relative_error(w: &[f32], q: &Pow2Weights) -> f32 {
    let deq = dequantize(q);
    w.iter()
        .zip(&deq)
        .filter(|(w, _)| w.abs() > (P_MIN as f32).exp2() && w.abs() < (P_MAX as f32).exp2())
        .map(|(w, d)| ((w - d) / w).abs())
        .fold(0.0, f32::max)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::XorShift64;

    #[test]
    fn exact_for_powers_of_two() {
        let w = [1.0, 2.0, 0.5, -4.0, -0.25];
        let q = quantize(&w, 1, 5);
        let d = dequantize(&q);
        assert_eq!(d, w.to_vec());
    }

    #[test]
    fn sign_preserved() {
        let w = [-0.3, 0.3, -1.7, 0.0];
        let q = quantize(&w, 2, 2);
        assert_eq!(q.sign, vec![-1, 1, -1, 1]);
    }

    #[test]
    fn exponent_clipped() {
        let q = quantize(&[1e9, 1e-9], 1, 2);
        assert_eq!(q.exp[0], P_MAX);
        assert_eq!(q.exp[1], P_MIN);
    }

    #[test]
    fn relative_error_within_octave() {
        let mut rng = XorShift64::new(5);
        let w: Vec<f32> = rng.normals(256).iter().map(|x| x * 0.5).collect();
        let q = quantize(&w, 16, 16);
        // round(log2) ⇒ ratio within [2^-1/2, 2^1/2] ⇒ rel err ≤ 1 - 2^-1/2 ≈ 0.293...
        // allow a little slack for boundary rounding.
        assert!(max_relative_error(&w, &q) < 0.42);
    }
}
