//! INT8 affine activation quantization [28] — used by the integer MatShift
//! kernel (the paper's "INT32 and INT8 for inputs and shift signs/weights").

/// Symmetric per-tensor INT8 quantization parameters.
#[derive(Clone, Copy, Debug)]
pub struct Int8Quant {
    pub scale: f32,
}

impl Int8Quant {
    /// Calibrate from the absolute max of the data.
    pub fn calibrate(x: &[f32]) -> Int8Quant {
        let amax = x.iter().fold(0.0f32, |m, v| m.max(v.abs()));
        Int8Quant {
            scale: if amax > 0.0 { amax / 127.0 } else { 1.0 },
        }
    }

    pub fn quantize(&self, x: &[f32]) -> Vec<i8> {
        x.iter()
            .map(|&v| (v / self.scale).round().clamp(-127.0, 127.0) as i8)
            .collect()
    }

    pub fn dequantize(&self, q: &[i8]) -> Vec<f32> {
        q.iter().map(|&v| v as f32 * self.scale).collect()
    }

    /// Dequantize an i32 accumulator (post-MatAdd/MatShift).
    pub fn dequant_acc(&self, acc: i64) -> f32 {
        acc as f32 * self.scale
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::XorShift64;

    #[test]
    fn roundtrip_error_bounded_by_half_step() {
        let mut rng = XorShift64::new(1);
        let x = rng.normals(512);
        let q = Int8Quant::calibrate(&x);
        let back = q.dequantize(&q.quantize(&x));
        for (a, b) in x.iter().zip(&back) {
            assert!((a - b).abs() <= q.scale * 0.5 + 1e-6, "{a} vs {b}");
        }
    }

    #[test]
    fn zero_data_does_not_divide_by_zero() {
        let q = Int8Quant::calibrate(&[0.0; 8]);
        assert_eq!(q.quantize(&[0.0])[0], 0);
    }

    #[test]
    fn saturates_at_127() {
        let q = Int8Quant { scale: 1.0 };
        assert_eq!(q.quantize(&[1e6])[0], 127);
        assert_eq!(q.quantize(&[-1e6])[0], -127);
    }
}
