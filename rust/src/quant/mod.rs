//! Quantization substrates: power-of-two (shift) reparameterization, binary
//! quantization, kernelized-hashing binarization, and INT8 affine
//! quantization — the host-side mirror of `python/compile/kernels/ref.py`.

pub mod binary;
pub mod int8;
pub mod ksh;
pub mod pow2;
