//! Table 11 — LRA benchmark: accuracy (from training runs) + latency/energy
//! on the Eyeriss model at the paper's sequence lengths, for each attention
//! family.

use anyhow::Result;

use crate::data::lra as lra_data;
use crate::energy::eyeriss::{energy, Hierarchy};
use crate::harness::results::Results;
use crate::model::config::lra as lra_spec;
use crate::model::ops::{count, Attn, Lin, Mlp, Variant};
use crate::runtime::engine::Engine;
use crate::runtime::tensor::Tensor;
use crate::util::bench::{f2, time_ms, Table};
use crate::util::stats::Summary;

/// The five attention families of Table 11 and their op-counting variants.
/// (Reformer/Linformer/Performer all break the N² term; for op counting we
/// model them as linear attention with full-precision MACs.)
pub const FAMILIES: [(&str, &str); 5] = [
    ("Transformer", "transformer"),
    ("Reformer", "reformer"),
    ("Linformer", "linformer"),
    ("Performer", "performer"),
    ("ShiftAdd-Transformer", "shiftadd"),
];

fn variant_for(family: &str) -> Variant {
    match family {
        "transformer" => Variant::MSA,
        "shiftadd" => Variant {
            attn: Attn::LinearAdd,
            attn_linear: Lin::Shift,
            mlp: Mlp::Shift,
        },
        _ => Variant::LINEAR,
    }
}

/// Measured latency of the runnable LRA artifacts (seq 128 tiny analogue).
pub fn lra_latency_ms(engine: &Engine, family: &str) -> Result<f64> {
    let name = format!("lra_{family}_bs1");
    let compiled = engine.load(&name)?;
    let meta = engine.manifest().get(&name)?;
    let seq = meta.inputs[0].shape[1];
    let toks = lra_data::gen_sequences(7, 1, seq);
    let input = Tensor::i32(vec![1, seq], toks);
    let samples = time_ms(
        || {
            engine.run(&compiled, std::slice::from_ref(&input)).unwrap();
        },
        2,
        7,
    );
    Ok(Summary::from(&samples).p50)
}

pub fn table11(engine: Option<&Engine>) -> Result<()> {
    let results = Results::load();
    let h = Hierarchy::default();
    let mut t = Table::new(&[
        "Model",
        "Text",
        "Listops",
        "Retr.",
        "Image",
        "Avg acc",
        "Eyeriss lat@avg-seq (ms)",
        "Energy (mJ)",
        "measured ms (seq128)",
    ]);
    for (label, family) in FAMILIES {
        let var = variant_for(family);
        // average the paper's per-task sequence lengths
        let mut lat = 0.0;
        let mut en = 0.0;
        for task in lra_data::TASKS {
            let spec = lra_spec(lra_data::paper_seq_len(task));
            let ops = count(&spec, var);
            en += energy(&ops, &h).total_mj();
            lat += crate::energy::area::AreaModel::default().latency_ms(&ops);
        }
        lat /= lra_data::TASKS.len() as f64;
        en /= lra_data::TASKS.len() as f64;
        let accs: Vec<String> = lra_data::TASKS
            .iter()
            .map(|task| results.fmt_acc(&format!("lra_{task}_{family}")))
            .collect();
        let avg = {
            let vals: Vec<f64> = lra_data::TASKS
                .iter()
                .filter_map(|task| results.acc_pct(&format!("lra_{task}_{family}")))
                .collect();
            if vals.is_empty() {
                "n/a".into()
            } else {
                f2(vals.iter().sum::<f64>() / vals.len() as f64)
            }
        };
        let measured = engine
            .and_then(|e| lra_latency_ms(e, family).ok())
            .map(f2)
            .unwrap_or_else(|| "n/a".into());
        t.row(&[
            label.to_string(),
            accs[0].clone(),
            accs[1].clone(),
            accs[2].clone(),
            accs[3].clone(),
            avg,
            f2(lat),
            f2(en),
            measured,
        ]);
    }
    t.print("Table 11 — LRA: accuracy (synthetic tasks), Eyeriss latency/energy at paper seq lengths");
    Ok(())
}
