//! Figures 3/4/5 (+7/8): the Eyeriss energy breakdown and the MatShift /
//! MatAdd kernel speedup sweeps over the paper's PVT shapes.
//!
//! The kernel sweeps enumerate `KernelRegistry` backends instead of calling
//! free functions: registering a new backend adds a column to the fig4/fig5
//! tables (and an entry to the bench JSON) with no edits here. The planner's
//! per-shape pick is reported alongside.

use std::sync::Arc;

use crate::energy::eyeriss::{energy, Hierarchy};
use crate::energy::ops::MacStyle;
use crate::kernels::api::{LinearKernel, Primitive, RawWeights};
use crate::kernels::planner::{Planner, Shape};
use crate::kernels::registry::KernelRegistry;
use crate::kernels::simd;
use crate::model::config::{classifier, gnt};
use crate::model::ops::{count, Variant};
use crate::util::bench::{f2, time_ms, Table};
use crate::util::json::Json;
use crate::util::rng::XorShift64;
use crate::util::stats::Summary;

/// Fig. 3 — energy breakdown on DeiT-T and GNT, baseline vs ShiftAddViT.
pub fn fig3_energy_breakdown() {
    let h = Hierarchy::default();
    let mut t = Table::new(&[
        "Model", "Variant", "attn_matmul", "attn_linear", "mlp", "other", "DRAM", "total (mJ)",
    ]);
    for (mname, spec) in [("DeiT-T", classifier("deit_t")), ("GNT", gnt())] {
        for (vname, var) in [
            ("baseline", Variant::LINEAR),
            ("+Add", Variant::ADD),
            ("+Add+Shift", Variant::ADD_SHIFT_BOTH),
            ("+Add+Shift+MoE", Variant::SHIFTADD_MOE),
        ] {
            let r = energy(&count(&spec, var), &h);
            t.row(&[
                mname.to_string(),
                vname.to_string(),
                f2(r.by_family[0].1),
                f2(r.by_family[1].1),
                f2(r.by_family[2].1),
                f2(r.by_family[3].1),
                f2(r.dram_mj),
                f2(r.total_mj()),
            ]);
        }
    }
    t.print("Fig. 3 — Eyeriss energy breakdown (mJ per inference, true shapes)");
}

/// The PVT shapes used by Fig. 4 (inputs B×K×M, weights K×N).
pub const FIG4_SHAPES: [(usize, usize, usize); 5] = [
    (3136, 32, 128),
    (784, 64, 256),
    (196, 160, 640),
    (49, 256, 1024),
    (196, 160, 160),
];

fn median_ms<F: FnMut()>(f: F) -> f64 {
    Summary::from(&time_ms(f, 2, 7)).p50
}

/// Median run time of one registry backend on `(m×k) @ (k×n)`. Preparation
/// (weight packing + activation quantization) happens once outside the
/// timed region — deployment formats are produced at model-conversion time,
/// mirroring the paper's INT8-weight-plane TVM kernels.
fn time_kernel(kernel: &dyn LinearKernel, raw: &RawWeights, x: &[f32], m: usize) -> f64 {
    let w = kernel.prepare(raw);
    let op = kernel.prepare_operand(x, m, raw.k);
    let mut out = vec![0.0f32; m * raw.n];
    median_ms(|| {
        kernel.run(&w, &op, &mut out);
        std::hint::black_box(&out);
    })
}

/// Registry-driven kernel sweep behind Figs. 4/5: time two baseline
/// backends and every backend of `contender`, plus the planner's pick.
/// Prints the human table and returns the same measurements as JSON, so
/// callers never measure twice (table and JSON stay consistent).
fn kernel_sweep(
    title: &str,
    shapes: &[(usize, usize, usize)],
    batch: usize,
    baselines: [&str; 2],
    contender: Primitive,
    seed: u64,
) -> Json {
    let registry = Arc::new(KernelRegistry::with_defaults());
    let planner = Planner::new(registry.clone());
    let contenders = registry.for_primitive(contender);
    let mut headers: Vec<String> = vec!["MxKxN".into()];
    for b in baselines {
        headers.push(format!("{b} (ms)"));
    }
    for c in &contenders {
        headers.push(format!("{} (ms)", c.id()));
    }
    headers.push("planner pick".into());
    headers.push("best speedup".into());
    let header_refs: Vec<&str> = headers.iter().map(|s| s.as_str()).collect();
    let mut t = Table::new(&header_refs);

    let mut rng = XorShift64::new(seed);
    let mut speedup_sum = 0.0;
    let mut shape_objs = Vec::new();
    for &(m0, k, n) in shapes {
        let m = m0 * batch;
        let x = rng.normals(m * k);
        let raw = RawWeights::new(rng.normals(k * n), k, n);
        let mut row = vec![format!("{m}x{k}x{n}")];
        let mut base_ms = f64::INFINITY;
        let mut baseline_pairs = Vec::new();
        for b in baselines {
            let kernel = registry.lookup(b).unwrap_or_else(|| panic!("no {b}"));
            let ms = time_kernel(&*kernel, &raw, &x, m);
            base_ms = base_ms.min(ms);
            baseline_pairs.push((b.to_string(), Json::num(ms)));
            row.push(f2(ms));
        }
        let mut best_ms = f64::INFINITY;
        let mut best_backend = "";
        let mut backend_pairs = Vec::new();
        for c in &contenders {
            let ms = time_kernel(&**c, &raw, &x, m);
            if ms < best_ms {
                best_ms = ms;
                best_backend = c.backend();
            }
            // full "primitive/backend" ids, consistent with `chosen`
            backend_pairs.push((c.id(), Json::num(ms)));
            row.push(f2(ms));
        }
        // Seed the planner with the measurement just taken (instead of
        // letting choose() re-benchmark the same shape on fresh data, which
        // wastes bench wall-clock and can contradict the printed column).
        planner.pin(contender, Shape::new(m, k, n), best_backend);
        let pick = planner.choose(contender, Shape::new(m, k, n));
        row.push(pick.id());
        row.push(format!("{:.2}x", base_ms / best_ms));
        speedup_sum += base_ms / best_ms;
        t.row(&row);
        shape_objs.push(Json::obj(vec![
            ("m", Json::num(m as f64)),
            ("k", Json::num(k as f64)),
            ("n", Json::num(n as f64)),
            ("baseline_ms", Json::Obj(baseline_pairs.into_iter().collect())),
            ("backend_ms", Json::Obj(backend_pairs.into_iter().collect())),
            ("chosen", Json::str(pick.id())),
        ]));
    }
    t.print(&format!(
        "{title}; avg best-backend speedup {:.2}x vs best baseline \
         (cpu_features: {})",
        speedup_sum / shapes.len() as f64,
        simd::active_level().name()
    ));
    Json::obj(vec![
        ("primitive", Json::str(contender.name())),
        ("batch", Json::num(batch as f64)),
        // which vector unit the */simd columns ran on (the perf-trajectory
        // key for simd-vs-rowpar-vs-ref comparisons across hosts)
        ("cpu_features", Json::str(simd::active_level().name())),
        ("shapes", Json::Arr(shape_objs)),
    ])
}

/// Fig. 4/7 — every registered MatShift backend vs the MatMul / FakeShift
/// baselines across PVT MLP shapes. Prints the table; the returned JSON
/// carries the same measurements (the benches dump it to stdout).
pub fn fig4_matshift(batch: usize) -> Json {
    kernel_sweep(
        &format!("Fig. 4/7 — MatShift backends (batch {batch})"),
        &FIG4_SHAPES,
        batch,
        ["matmul/blocked", "fakeshift/ref"],
        Primitive::MatShift,
        11,
    )
}

/// The attention shapes of Fig. 5 (B×H×K×M inputs).
pub const FIG5_SHAPES: [(usize, usize, usize); 5] = [
    (3136, 32, 32),
    (784, 64, 64),
    (196, 160, 160),
    (49, 256, 256),
    (784, 64, 256),
];

/// Fig. 5/8 — every registered MatAdd backend vs the MatMul baselines
/// across PVT attention shapes: "PyTorch MatMul" (`matmul/naive`) and
/// "TVM MatMul" (`matmul/blocked`). Prints the table; the returned JSON
/// carries the same measurements (the benches dump it to stdout).
pub fn fig5_matadd(batch: usize) -> Json {
    kernel_sweep(
        &format!("Fig. 5/8 — MatAdd backends (batch {batch})"),
        &FIG5_SHAPES,
        batch,
        ["matmul/naive", "matmul/blocked"],
        Primitive::MatAdd,
        13,
    )
}

/// Energy-per-op summary (Table 1 reprint with MAC-style aggregates).
pub fn table1() {
    let mut t = Table::new(&["Op", "Energy (pJ)", "Area (um^2)"]);
    for op in crate::energy::ops::Op::ALL {
        t.row(&[
            op.name().to_string(),
            format!("{}", op.energy_pj()),
            format!("{}", op.area_um2()),
        ]);
    }
    t.print("Table 1 — unit energy/area, 45nm CMOS");
    let mut t2 = Table::new(&["MAC style", "Energy (pJ/MAC)", "Area (um^2)", "W bytes/MAC"]);
    for s in [
        MacStyle::MultFp32,
        MacStyle::MultInt8,
        MacStyle::ShiftInt32,
        MacStyle::AddInt32,
        MacStyle::AddFp32,
    ] {
        t2.row(&[
            format!("{s:?}"),
            format!("{:.2}", s.energy_pj()),
            format!("{:.0}", s.area_um2()),
            format!("{:.3}", s.weight_bytes()),
        ]);
    }
    t2.print("MAC-style aggregates");
}
