//! Figures 3/4/5 (+7/8): the Eyeriss energy breakdown and the MatShift /
//! MatAdd kernel speedup sweeps over the paper's PVT shapes.

use crate::energy::eyeriss::{energy, Hierarchy};
use crate::energy::ops::MacStyle;
use crate::kernels::{fakeshift, matadd, matmul, matshift};
use crate::model::config::{classifier, gnt};
use crate::model::ops::{count, Variant};
use crate::quant::pow2;
use crate::util::bench::{f2, time_ms, Table};
use crate::util::rng::XorShift64;
use crate::util::stats::Summary;

/// Fig. 3 — energy breakdown on DeiT-T and GNT, baseline vs ShiftAddViT.
pub fn fig3_energy_breakdown() {
    let h = Hierarchy::default();
    let mut t = Table::new(&[
        "Model", "Variant", "attn_matmul", "attn_linear", "mlp", "other", "DRAM", "total (mJ)",
    ]);
    for (mname, spec) in [("DeiT-T", classifier("deit_t")), ("GNT", gnt())] {
        for (vname, var) in [
            ("baseline", Variant::LINEAR),
            ("+Add", Variant::ADD),
            ("+Add+Shift", Variant::ADD_SHIFT_BOTH),
            ("+Add+Shift+MoE", Variant::SHIFTADD_MOE),
        ] {
            let r = energy(&count(&spec, var), &h);
            t.row(&[
                mname.to_string(),
                vname.to_string(),
                f2(r.by_family[0].1),
                f2(r.by_family[1].1),
                f2(r.by_family[2].1),
                f2(r.by_family[3].1),
                f2(r.dram_mj),
                f2(r.total_mj()),
            ]);
        }
    }
    t.print("Fig. 3 — Eyeriss energy breakdown (mJ per inference, true shapes)");
}

/// The PVT shapes used by Fig. 4 (inputs B×K×M, weights K×N).
pub const FIG4_SHAPES: [(usize, usize, usize); 5] = [
    (3136, 32, 128),
    (784, 64, 256),
    (196, 160, 640),
    (49, 256, 1024),
    (196, 160, 160),
];

fn median_ms<F: FnMut()>(f: F) -> f64 {
    Summary::from(&time_ms(f, 2, 7)).p50
}

/// Fig. 4/7 — MatShift vs MatMul / FakeShift across PVT MLP shapes.
pub fn fig4_matshift(batch: usize) {
    let mut t = Table::new(&[
        "MxKxN", "MatMul (ms)", "FakeShift (ms)", "MatShift (ms)", "vs MatMul", "vs FakeShift",
    ]);
    let mut rng = XorShift64::new(11);
    let mut speedups = (0.0, 0.0);
    for (m0, k, n) in FIG4_SHAPES {
        let m = m0 * batch;
        let x = rng.normals(m * k);
        let wf = rng.normals(k * n);
        let w = pow2::quantize(&wf, k, n);
        // Deployment formats are prepared once (binarization/quantization is
        // part of model conversion, not the kernel) — mirroring the paper's
        // INT8-weight-plane TVM kernels.
        let planes = matshift::ShiftPlanes::from_pow2(&w);
        let xq: Vec<i32> = crate::quant::int8::Int8Quant::calibrate(&x)
            .quantize(&x)
            .iter()
            .map(|&v| v as i32)
            .collect();
        let t_mm = median_ms(|| {
            std::hint::black_box(matmul::matmul_f32(&x, &wf, m, k, n));
        });
        let t_fake = median_ms(|| {
            std::hint::black_box(fakeshift::fakeshift_rematerialize(&x, &w, m));
        });
        let t_shift = median_ms(|| {
            std::hint::black_box(matshift::matshift_fast(&xq, &planes, m));
        });
        speedups.0 += t_mm / t_shift;
        speedups.1 += t_fake / t_shift;
        t.row(&[
            format!("{m}x{k}x{n}"),
            f2(t_mm),
            f2(t_fake),
            f2(t_shift),
            format!("{:.2}x", t_mm / t_shift),
            format!("{:.2}x", t_fake / t_shift),
        ]);
    }
    t.print(&format!(
        "Fig. 4/7 — MatShift speedups (batch {batch}); avg {:.2}x vs MatMul, {:.2}x vs FakeShift",
        speedups.0 / FIG4_SHAPES.len() as f64,
        speedups.1 / FIG4_SHAPES.len() as f64
    ));
}

/// The attention shapes of Fig. 5 (B×H×K×M inputs).
pub const FIG5_SHAPES: [(usize, usize, usize); 5] = [
    (3136, 32, 32),
    (784, 64, 64),
    (196, 160, 160),
    (49, 256, 256),
    (784, 64, 256),
];

/// Fig. 5/8 — MatAdd vs MatMul across PVT attention shapes.
///
/// Two baselines, mirroring the paper: "PyTorch MatMul" (the default einsum
/// operator — our unblocked naive kernel plays that role) and "TVM MatMul"
/// (a tuned kernel — our cache-blocked `matmul_f32`).
pub fn fig5_matadd(batch: usize) {
    let mut t = Table::new(&[
        "MxKxN",
        "naiveMM (ms)",
        "tunedMM (ms)",
        "MatAdd (ms)",
        "vs naive",
        "vs tuned",
    ]);
    let mut rng = XorShift64::new(13);
    let mut speedups = (0.0, 0.0);
    for (m0, k, n) in FIG5_SHAPES {
        let m = m0 * batch;
        let x = rng.normals(m * k);
        let b: Vec<i8> = (0..k * n)
            .map(|_| if rng.uniform() < 0.5 { -1 } else { 1 })
            .collect();
        let bf: Vec<f32> = b.iter().map(|&v| v as f32).collect();
        // Binary codes arrive pre-packed (the binarizer's output format).
        let packed = matadd::PackedPm1::pack(&b, k, n);
        let t_naive = median_ms(|| {
            std::hint::black_box(matmul::matmul_naive(&x, &bf, m, k, n));
        });
        let t_mm = median_ms(|| {
            std::hint::black_box(matmul::matmul_f32(&x, &bf, m, k, n));
        });
        let t_add = median_ms(|| {
            std::hint::black_box(matadd::matadd_pm1(&x, &packed, m));
        });
        speedups.0 += t_naive / t_add;
        speedups.1 += t_mm / t_add;
        t.row(&[
            format!("{m}x{k}x{n}"),
            f2(t_naive),
            f2(t_mm),
            f2(t_add),
            format!("{:.2}x", t_naive / t_add),
            format!("{:.2}x", t_mm / t_add),
        ]);
    }
    t.print(&format!(
        "Fig. 5/8 — MatAdd speedups (batch {batch}); avg {:.2}x vs naive (PyTorch-like), {:.2}x vs tuned (TVM-like) MatMul",
        speedups.0 / FIG5_SHAPES.len() as f64,
        speedups.1 / FIG5_SHAPES.len() as f64
    ));
}

/// Energy-per-op summary (Table 1 reprint with MAC-style aggregates).
pub fn table1() {
    let mut t = Table::new(&["Op", "Energy (pJ)", "Area (um^2)"]);
    for op in crate::energy::ops::Op::ALL {
        t.row(&[
            op.name().to_string(),
            format!("{}", op.energy_pj()),
            format!("{}", op.area_um2()),
        ]);
    }
    t.print("Table 1 — unit energy/area, 45nm CMOS");
    let mut t2 = Table::new(&["MAC style", "Energy (pJ/MAC)", "Area (um^2)", "W bytes/MAC"]);
    for s in [
        MacStyle::MultFp32,
        MacStyle::MultInt8,
        MacStyle::ShiftInt32,
        MacStyle::AddInt32,
        MacStyle::AddFp32,
    ] {
        t2.row(&[
            format!("{s:?}"),
            format!("{:.2}", s.energy_pj()),
            format!("{:.0}", s.area_um2()),
            format!("{:.3}", s.weight_bytes()),
        ]);
    }
    t2.print("MAC-style aggregates");
}
