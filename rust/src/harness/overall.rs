//! Table 3 — overall comparison: accuracy / latency / Eyeriss energy for the
//! five classification models, Ecoformer(-like) baseline vs ShiftAddViT.
//! Latency cells come from the XLA artifacts when available and fall back
//! to the native `infer` engine otherwise.

use anyhow::Result;

use crate::data::synth_images;
use crate::energy::eyeriss::{energy, Hierarchy};
use crate::harness::results::Results;
use crate::infer::model::tiny_latency_ms;
use crate::model::config::classifier;
use crate::model::ops::{count, Variant};
use crate::runtime::engine::Engine;
use crate::runtime::tensor::Tensor;
use crate::util::bench::{f2, time_ms, Table};
use crate::util::stats::Summary;

/// Measure BS=1 latency of a classifier artifact (ms); None if missing.
pub fn cls_latency_ms(engine: &Engine, model: &str, variant: &str, bs: usize) -> Result<f64> {
    let name = format!("cls_{model}_{variant}_bs{bs}");
    let compiled = engine.load(&name)?;
    let (xs, _) = synth_images::gen_batch(1000, bs);
    let input = Tensor::f32(vec![bs, 32, 32, 3], xs);
    let samples = time_ms(
        || {
            engine.run(&compiled, std::slice::from_ref(&input)).unwrap();
        },
        3,
        10,
    );
    Ok(Summary::from(&samples).p50)
}

/// Throughput (img/s) at batch 32.
pub fn cls_throughput(engine: &Engine, model: &str, variant: &str) -> Result<f64> {
    let ms = cls_latency_ms(engine, model, variant, 32)?;
    Ok(32.0 / (ms / 1e3))
}

pub const MODELS: [&str; 5] = ["pvtv2_b0", "pvtv1_t", "pvtv2_b1", "pvtv2_b2", "deit_t"];

/// Print Table 3. `ecoformer` here = linear attention + KSH binarization
/// (the paper's most competitive baseline); ShiftAddViT = +Shift/MoE.
///
/// With no [`Engine`] (or per-cell when an artifact is missing), latency
/// falls back to the native `infer` engine's tiny analogue, marked
/// "(native)". The native numbers are measured once per variant and reused
/// across model rows (the tiny analogue does not vary per backbone).
pub fn table3(engine: Option<&Engine>) -> Result<()> {
    let results = Results::load();
    let h = Hierarchy::default();
    let mut native_eco: Option<String> = None;
    let mut native_ours: Option<String> = None;
    let native_lat = |variant: Variant, cache: &mut Option<String>| -> String {
        cache
            .get_or_insert_with(|| format!("{} (native)", f2(tiny_latency_ms(variant, 1))))
            .clone()
    };
    let mut t = Table::new(&[
        "Model", "Method", "Acc (%)", "Lat (ms)", "Energy (mJ)",
    ]);
    for model in MODELS {
        let spec = classifier(model);
        // Ecoformer-like baseline row.
        let eco_lat = engine
            .and_then(|e| cls_latency_ms(e, model, "add_ksh", 1).ok())
            .map(f2)
            .unwrap_or_else(|| native_lat(Variant::ADD, &mut native_eco));
        let eco_energy = energy(&count(&spec, Variant::ADD), &h).total_mj();
        t.row(&[
            spec.name.to_string(),
            "Ecoformer".into(),
            results.fmt_acc(&format!("{model}_add_ksh")),
            eco_lat,
            f2(eco_energy),
        ]);
        // ShiftAddViT (MoE on both) row.
        let our_lat = engine
            .and_then(|e| cls_latency_ms(e, model, "add_quant_moe_both", 1).ok())
            .map(f2)
            .unwrap_or_else(|| native_lat(Variant::SHIFTADD_MOE, &mut native_ours));
        let our_energy = energy(&count(&spec, Variant::SHIFTADD_MOE), &h).total_mj();
        t.row(&[
            spec.name.to_string(),
            "ShiftAddViT".into(),
            results.fmt_acc(&format!("{model}_add_quant_moe_both")),
            our_lat,
            f2(our_energy),
        ]);
    }
    t.print("Table 3 — overall comparison (energy: Eyeriss model, true shapes; latency: CPU-PJRT tiny analogues, '(native)' = pure-Rust engine)");
    Ok(())
}
