//! Tables 4 & 6 — the breakdown ladder: each reparameterization applied in
//! turn (linear attention, KSH vs vanilla Q/K binarization, Shift layers,
//! MoE) with BS=1 latency, BS=32 throughput, and accuracy; MoE rows also get
//! real-dispatch ("†") vs modularized ("*") latencies from the coordinator.

use anyhow::Result;

use crate::coordinator::config::{DispatchMode, ServerConfig};
use crate::coordinator::server::serve;
use crate::harness::overall::{cls_latency_ms, cls_throughput};
use crate::harness::results::Results;
use crate::runtime::artifact::Manifest;
use crate::runtime::engine::Engine;
use crate::util::bench::{f2, Table};

/// The ladder rows: (display label, variant tag, acc tag).
pub const LADDER: [(&str, &str); 8] = [
    ("MSA", "msa"),
    ("PVT (linear attn)", "linear"),
    ("+KSH (Ecoformer)", "add_ksh"),
    ("+Quant Q/K", "add_quant"),
    ("+Shift(Attn), KSH", "add_ksh_shiftattn"),
    ("+Shift(Both), Quant", "add_quant_shift_both"),
    ("+MoE(Both), KSH", "add_ksh_moe_both"),
    ("+MoE(Both), Quant", "add_quant_moe_both"),
];

/// Print the breakdown table for one model (Table 4: pvtv2_b0/pvtv1_t,
/// Table 6: pvtv2_b1/pvtv2_b2).
pub fn breakdown(engine: &Engine, model: &str) -> Result<()> {
    let results = Results::load();
    let mut t = Table::new(&["Method", "Acc (%)", "Lat bs1 (ms)", "T. bs32 (img/s)"]);
    for (label, variant) in LADDER {
        let lat = cls_latency_ms(engine, model, variant, 1)
            .map(f2)
            .unwrap_or_else(|_| "n/a".into());
        let thr = cls_throughput(engine, model, variant)
            .map(|v| format!("{v:.0}"))
            .unwrap_or_else(|_| "n/a".into());
        t.row(&[
            label.to_string(),
            results.fmt_acc(&format!("{model}_{variant}")),
            lat,
            thr,
        ]);
    }
    t.print(&format!("Table 4/6 breakdown — {model}"));
    Ok(())
}

/// MoE real ("†") vs modularized ("*") serving latency — the coordinator
/// measurement behind the paper's dual latency columns.
pub fn moe_dual_latency(manifest: &Manifest, requests: usize) -> Result<()> {
    let mut t = Table::new(&["Dispatch", "Batch lat (ms)", "p99 (ms)", "Throughput (img/s)"]);
    for (label, mode) in [
        ("real (†)", DispatchMode::Real),
        ("modularized (*)", DispatchMode::Modularized),
        ("dense (PVT+MoE)", DispatchMode::Dense),
    ] {
        let cfg = ServerConfig {
            requests,
            dispatch: mode,
            ..ServerConfig::default()
        };
        let report = serve(manifest, &cfg)?;
        let shown = if mode == DispatchMode::Modularized {
            report.modularized_latency.mean
        } else {
            report.latency.mean
        };
        t.row(&[
            label.to_string(),
            f2(shown),
            f2(report.latency.p99),
            format!("{:.0}", report.throughput_rps),
        ]);
    }
    t.print("Table 4/6 MoE rows — real vs modularized vs dense dispatch (serving pipeline)");
    Ok(())
}
