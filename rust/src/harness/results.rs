//! Training-results loader: accuracy/PSNR numbers recorded by
//! `python -m compile.train` land in `python/trained/results.json`; latency
//! benches join them into the tables. Missing entries render as "n/a"
//! (latency columns still measure — EXPERIMENTS.md records which runs had
//! trained checkpoints).

use std::path::PathBuf;

use crate::util::json::Json;

#[derive(Clone, Debug, Default)]
pub struct Results {
    root: Option<Json>,
}

impl Results {
    pub fn load() -> Results {
        let path = Self::path();
        match std::fs::read_to_string(&path) {
            Ok(text) => Results {
                root: Json::parse(&text).ok(),
            },
            Err(_) => Results { root: None },
        }
    }

    pub fn path() -> PathBuf {
        std::env::var("SHIFTADDVIT_RESULTS")
            .map(PathBuf::from)
            .unwrap_or_else(|_| PathBuf::from("python/trained/results.json"))
    }

    /// Accuracy (%) for a recorded run tag, e.g. "pvtv2_b0_msa".
    pub fn acc_pct(&self, tag: &str) -> Option<f64> {
        self.root
            .as_ref()?
            .get(tag)?
            .get("acc")?
            .as_f64()
            .map(|a| a * 100.0)
    }

    /// PSNR for an NVS run tag, e.g. "nvs_orchids_gnt".
    pub fn psnr(&self, tag: &str) -> Option<f64> {
        self.root.as_ref()?.get(tag)?.get("psnr")?.as_f64()
    }

    pub fn fmt_acc(&self, tag: &str) -> String {
        self.acc_pct(tag)
            .map(|a| format!("{a:.2}"))
            .unwrap_or_else(|| "n/a".into())
    }

    pub fn fmt_psnr(&self, tag: &str) -> String {
        self.psnr(tag)
            .map(|a| format!("{a:.2}"))
            .unwrap_or_else(|| "n/a".into())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn missing_file_yields_na() {
        std::env::set_var("SHIFTADDVIT_RESULTS", "/nonexistent/results.json");
        let r = Results::load();
        assert_eq!(r.fmt_acc("x"), "n/a");
        std::env::remove_var("SHIFTADDVIT_RESULTS");
    }
}
