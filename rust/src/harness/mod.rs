//! Table/figure regeneration harness — one module per paper exhibit.
//! Each function prints the paper's rows from live measurements (latency:
//! CPU-PJRT wall clock; energy/area-latency: Eyeriss model; accuracy:
//! `python/trained/results.json` written by the training presets).

pub mod breakdown;
pub mod figures;
pub mod lra;
pub mod nvs;
pub mod overall;
pub mod results;
pub mod scaling;
