//! Table 12 — latency of attention types vs batch size and input resolution
//! (the "linear attention only wins at scale" analysis, §5.2 / Appendix F).
//!
//! Measured analytically from MAC counts (the crossover is shape-driven) AND
//! by wall clock: on the runnable XLA artifacts when present, and always on
//! the native `infer` engine — the latency claims are measured even on a
//! box that never ran `make artifacts`.

use anyhow::Result;

use crate::harness::overall::cls_latency_ms;
use crate::infer::model::tiny_latencies_ms;
use crate::model::config::{classifier, ModelSpec, Stage};
use crate::model::ops::{count, Variant};
use crate::runtime::engine::Engine;
use crate::util::bench::{f2, Table};

/// Scale a spec's token counts for a different input resolution.
fn at_resolution(base: &ModelSpec, res: usize) -> ModelSpec {
    let scale = (res * res) as f64 / (base.input * base.input) as f64;
    ModelSpec {
        name: base.name,
        input: res,
        stages: base
            .stages
            .iter()
            .map(|s| Stage {
                tokens: ((s.tokens as f64) * scale).round() as usize,
                ..*s
            })
            .collect(),
    }
}

/// Analytic FLOP-proportional latency (normalized so MSA@bs1@224 = the
/// paper's 4.62 ms) across batch sizes and resolutions.
pub fn table12_analytic() {
    let base = classifier("pvtv2_b0");
    let msa_macs = count(&base, Variant::MSA).total_macs();
    let norm = 4.62 / msa_macs; // ms per MAC so the anchor cell matches
    let mut t = Table::new(&[
        "Attention", "res", "bs1", "bs2", "bs4", "bs8", "bs16", "bs32", "bs64",
    ]);
    for (label, var) in [("MSA", Variant::MSA), ("Linear", Variant::LINEAR)] {
        for res in [224usize, 448] {
            let spec = at_resolution(&base, res);
            let macs = count(&spec, var).total_macs();
            let mut row = vec![label.to_string(), res.to_string()];
            for bs in [1usize, 2, 4, 8, 16, 32, 64] {
                // small batches underutilize the device: latency flattens at
                // a floor (the paper's observed constant region) modeled as
                // max(fixed overhead+macs·bs·norm_parallel, ...)
                let compute = macs * bs as f64 * norm;
                let floor = 4.0 + 0.05 * bs as f64; // kernel-launch floor (ms)
                row.push(f2(compute.max(floor)));
            }
            t.row(&row);
        }
    }
    t.print("Table 12 — analytic latency (ms) vs batch & resolution (anchored to paper MSA@bs1)");
}

/// Wall-clock companion: measured bs1/bs32 latencies of the tiny analogues.
///
/// XLA-artifact rows run when an [`Engine`] is supplied; with `None` an
/// explicit "skipped (no artifacts)" row is printed instead of silently
/// producing nothing. Native-engine rows always run — `make artifacts` is
/// no longer a prerequisite for measured latency.
pub fn table12_measured(engine: Option<&Engine>) -> Result<()> {
    let mut t = Table::new(&["Attention", "engine", "bs1 (ms)", "bs32 (ms)"]);
    match engine {
        Some(engine) => {
            for (label, variant) in
                [("MSA", "msa"), ("Linear", "linear"), ("Linear+Add", "add_quant")]
            {
                let l1 = cls_latency_ms(engine, "pvtv2_b0", variant, 1)
                    .map(f2)
                    .unwrap_or_else(|_| "n/a".into());
                let l32 = cls_latency_ms(engine, "pvtv2_b0", variant, 32)
                    .map(f2)
                    .unwrap_or_else(|_| "n/a".into());
                t.row(&[label.to_string(), "xla".into(), l1, l32]);
            }
        }
        None => t.row(&[
            "all".into(),
            "xla".into(),
            "skipped (no artifacts)".into(),
            "skipped (no artifacts)".into(),
        ]),
    }
    for (label, variant) in [
        ("MSA", Variant::MSA),
        ("Linear", Variant::LINEAR),
        ("Linear+Add", Variant::ADD),
    ] {
        let lat = tiny_latencies_ms(variant, &[1, 32]);
        t.row(&[
            label.to_string(),
            "native".into(),
            f2(lat[0]),
            f2(lat[1]),
        ]);
    }
    t.print("Table 12 (measured) — tiny-analogue wall clock (CPU PJRT artifacts + native engine)");
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn linear_attention_wins_at_high_resolution() {
        // The crossover the paper demonstrates: at 448², MSA's quadratic
        // attention dwarfs linear attention's cost.
        let base = classifier("pvtv2_b0");
        let hi = at_resolution(&base, 448);
        let msa = count(&hi, Variant::MSA).total_macs();
        let lin = count(&hi, Variant::LINEAR).total_macs();
        assert!(msa > 2.0 * lin, "msa {msa} lin {lin}");
    }

    #[test]
    fn resolution_scaling_quadratic_for_msa() {
        let base = classifier("pvtv2_b0");
        let m224: f64 = count(&base, Variant::MSA)
            .attn_matmul
            .iter()
            .map(|(_, m)| m)
            .sum();
        let m448: f64 = count(&at_resolution(&base, 448), Variant::MSA)
            .attn_matmul
            .iter()
            .map(|(_, m)| m)
            .sum();
        // tokens ×4 ⇒ N² attention ×16
        assert!((m448 / m224 - 16.0).abs() < 0.5, "{}", m448 / m224);
    }
}
